"""Quickstart: discover a causal graph from nonlinear data with CV-LR.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.api import DataSpec, EngineOptions, causal_discover
from repro.core.metrics import shd_cpdag, skeleton_f1
from repro.core.graph import dag_to_cpdag
from repro.core.score_common import ScoreConfig
from repro.data.synthetic import generate_scm_data


def main():
    # 7 variables, nonlinear post-nonlinear SCM (paper Sec. 7.4)
    ds = generate_scm_data(d=7, n=500, density=0.35, kind="continuous", seed=42)
    print(f"data: {ds.data.shape}, true edges: {int(ds.dag.sum())}")

    # DataSpec.infer guesses per-variable kinds (continuous here); build
    # one explicitly with DataSpec.from_arrays(data, dims=..., discrete=...)
    spec = DataSpec.infer(ds.data)
    print("inferred variables:", [(v.name, v.kind) for v in spec.variables])

    res = causal_discover(
        ds.data,
        method="cvlr",  # the paper's O(n) score; method="cv" = exact O(n^3)
        spec=spec,
        # the default engine: batched frontier scoring, bitwise-exact vs
        # the sequential oracle; see EngineOptions for every knob
        options=EngineOptions(engine="batched", precision="bitwise"),
        config=ScoreConfig(m_max=100, q_folds=10),
        verbose=True,
    )

    print("\nestimated CPDAG:")
    print(res.cpdag)
    print(f"skeleton F1:   {skeleton_f1(res.cpdag, ds.dag):.3f}")
    print(f"normalized SHD: {shd_cpdag(res.cpdag, dag_to_cpdag(ds.dag)):.3f}")
    print(f"forward steps: {res.forward_steps}, backward: {res.backward_steps}")


if __name__ == "__main__":
    main()
