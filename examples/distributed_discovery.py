"""Distributed causal discovery: the paper's score on a device mesh.

Demonstrates (1) the sharded GES engine — `EngineOptions(engine="sharded")`
routes every sweep's frontier through the stacked distributed scoring
pipeline, no hand-rolled batch_hook — and (2) the shard_map
sample-parallel scorer that the multi-pod dry-run lowers on the
production mesh.  Runs on however many devices are available (1 on this
CPU container; set XLA_FLAGS=--xla_force_host_platform_device_count=8 to
fan out).

    PYTHONPATH=src python examples/distributed_discovery.py
"""

import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import contextlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import DiscoverySession, EngineOptions
from repro.core.distributed_score import (
    block_folds,
    cvlr_scores_stacked,
    make_sharded_scorer,
)
from repro.core.metrics import skeleton_f1
from repro.core.score_common import ScoreConfig
from repro.data.synthetic import generate_scm_data


def main():
    ds = generate_scm_data(d=6, n=400, density=0.35, kind="continuous", seed=3)

    # 1) GES through the sharded engine: every sweep's frontier is scored
    #    by the stacked distributed pipeline (repro.core.distributed_score)
    #    — selected declaratively, no batch_hook threading.
    session = DiscoverySession(
        ds.data,
        options=EngineOptions(engine="sharded"),
        config=ScoreConfig(seed=1),
    )
    t0 = time.perf_counter()
    res = session.run()
    print(
        f"sharded GES: {time.perf_counter()-t0:.1f}s, "
        f"F1={skeleton_f1(res.cpdag, ds.dag):.3f}, "
        f"{session.scorer.cache_size} local scores evaluated over "
        f"{len(session.sweep_log)} sweeps"
    )
    scorer = session.scorer  # feature bank reused by the shard_map demo

    # 2) shard_map scorer on a device mesh (samples over 'data',
    #    candidates over 'model') — the multi-pod dry-run workload
    n_dev = len(jax.devices())
    if n_dev >= 2:
        try:  # jax >= 0.5 spells the mesh axis types explicitly
            from jax.sharding import AxisType

            mesh = jax.make_mesh(
                (2, n_dev // 2), ("model", "data"),
                axis_types=(AxisType.Auto,) * 2,
            )
        except ImportError:
            from jax.sharding import Mesh

            mesh = Mesh(
                np.array(jax.devices()).reshape(2, n_dev // 2),
                ("model", "data"),
            )
        fn = make_sharded_scorer(mesh)
        q = 4
        lam = scorer.features((0,))
        lx = jnp.stack([block_folds(lam, q)] * 4)
        lz = jnp.stack([block_folds(scorer.features((1,)), q)] * 4)
        ctx = (
            jax.set_mesh(mesh)
            if hasattr(jax, "set_mesh")
            else contextlib.nullcontext()
        )
        with ctx:
            sharded = fn(lx, lz)
        ref = cvlr_scores_stacked(lx, lz)
        err = float(jnp.max(jnp.abs(sharded - ref)))
        print(f"shard_map scorer on {n_dev} devices: max |delta| vs single = {err:.2e}")
    else:
        print("single device: skipping shard_map demo")


if __name__ == "__main__":
    main()
