"""End-to-end LM training driver: train a ~100M-param tinyllama-family
model for a few hundred steps on the synthetic Markov token stream, with
checkpointing + resume.  On CPU this runs a width-reduced variant by
default; pass --m100 for the full ~100M config (slow on CPU, sized for a
single TPU host).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import dataclasses

import jax

from repro.configs.tinyllama_1b import config
from repro.launch.train import make_train_step, train
from repro.models.registry import build_model


def m100_config():
    """~100M-param llama-family config (12L x 768, 12 heads)."""
    return dataclasses.replace(
        config(),
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=4,
        d_ff=2048,
        vocab_size=32000,
        attn_chunk=1024,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--m100", action="store_true", help="full ~100M config")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    if args.m100:
        cfg = m100_config()
        model = build_model(cfg)
        n = sum(x.size for x in jax.tree.leaves(model.init(jax.random.PRNGKey(0))[0]))
        print(f"training {n/1e6:.0f}M params for {args.steps} steps")
        # route through the generic trainer with this model
        import repro.launch.train as T

        orig = T.load_arch
        T.load_arch = lambda *a, **k: (cfg, model)
        try:
            train(steps=args.steps, batch=4, seq=512, ckpt_dir=args.ckpt_dir)
        finally:
            T.load_arch = orig
    else:
        state, losses = train(
            arch="tinyllama_1b",
            reduced=True,
            steps=args.steps,
            batch=8,
            seq=128,
            ckpt_dir=args.ckpt_dir,
        )
        assert losses[-1] < losses[0], "loss did not decrease"
        print("loss decreased — training works end to end")


if __name__ == "__main__":
    main()
