"""Feature policies: choose the factorization backend per variable kind —
or per variable — and reuse built factors across sessions.

    PYTHONPATH=src python examples/feature_policies.py

The paper's "sampling algorithms for different data types" is a registry
(`repro.features.backends`): Alg. 1 ICL / Alg. 2 exact-discrete (the
defaults, bitwise-identical to pre-PR-5 behavior), random Fourier
features, and landmark Nystroem with uniform / leverage / stratified
samplers.  A `FeaturePolicy` on `EngineOptions(features=...)` routes
variable sets to backends; per-variable overrides ride on the `DataSpec`;
a `FeatureBank` caches the built factors with full telemetry.
"""

import time

import numpy as np

from repro.core.api import DataSpec, DiscoverySession, EngineOptions, VariableSpec
from repro.core.metrics import skeleton_f1
from repro.core.score_common import ScoreConfig
from repro.data.synthetic import generate_scm_data
from repro.features.policy import BackendChoice, FeaturePolicy


def main():
    # mixed data: half the variables equal-frequency discretized
    ds = generate_scm_data(d=5, n=400, density=0.35, kind="mixed", seed=3)
    spec = DataSpec.from_arrays(ds.data, dims=ds.dims, discrete=ds.discrete)
    cfg = ScoreConfig(seed=0)
    print("variables:", [(v.name, v.kind) for v in spec.variables])

    # -- 1. the default policy (ICL + exact-discrete, the paper's routing)
    session = DiscoverySession(ds.data, spec=spec, config=cfg)
    res = session.run()
    print(
        f"\ndefault policy:   F1={skeleton_f1(res.cpdag, ds.dag):.3f}  "
        f"bank={session.feature_bank.stats}"
    )
    for rec in session.sweep_log[:2]:
        print("  sweep", rec["sweep"], rec["phase"], "feature_bank:", rec["feature_bank"])

    # -- 2. a mixed-data composite: stratified-Nystroem landmarks for
    # discrete sets, random Fourier features for continuous ones
    policy = FeaturePolicy(
        continuous=BackendChoice("rff"),
        discrete=BackendChoice.of("nystrom", sampler="stratified"),
        seed=0,
    )
    s2 = DiscoverySession(
        ds.data, spec=spec, config=cfg,
        options=EngineOptions(features=policy),
    )
    res2 = s2.run()
    print(
        f"rff+nystrom:      F1={skeleton_f1(res2.cpdag, ds.dag):.3f}  "
        f"bank={s2.feature_bank.stats}"
    )
    print("  per-set backends:", {
        e["vars"]: (e["backend"], e["m_eff"]) for e in s2.feature_bank.entry_log()[:4]
    })

    # -- 3. per-variable override riding on the DataSpec: pin one variable
    # to leverage-score Nystroem, everything else keeps the defaults
    spec3 = DataSpec(
        tuple(
            VariableSpec(
                name=v.name, dim=v.dim, kind=v.kind,
                backend="nystrom", backend_params={"sampler": "leverage"},
            )
            if v.name == "x0"
            else v
            for v in spec.variables
        )
    )
    s3 = DiscoverySession(ds.data, spec=spec3, config=cfg)
    s3.run()
    built = {e["vars"]: e["backend"] for e in s3.feature_bank.entry_log()}
    print(f"override x0:      x0 built by {built[(0,)]!r}, x1 by {built[(1,)]!r}")

    # -- 4. session-owned bank reuse: a second run over the same data
    # rebuilds nothing (the multi-sweep/multi-session win)
    t0 = time.perf_counter()
    s4 = DiscoverySession(
        ds.data, spec=spec, config=cfg, feature_bank=session.feature_bank
    )
    s4.run()
    dt = time.perf_counter() - t0
    print(
        f"shared bank rerun: {dt:.2f}s, rebuilds this run = "
        f"{s4.sweep_log[0]['feature_bank']['builds']} "
        f"(bank carried {session.feature_bank.stats['entries']} factors)"
    )


if __name__ == "__main__":
    main()
