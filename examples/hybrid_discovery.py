"""Hybrid discovery: a kernel-CI skeleton gates the GES frontier.

Runs the same mixed (continuous + discrete) dataset twice — ungated
GES, then the hybrid pipeline (``EngineOptions(restrict="skeleton")``):
a PC-stable skeleton built from factor-based kernel CI tests
(`repro.constraint`) prunes the forward frontier before the score
phase starts.  Both phases fetch factors through one `FeatureBank`, so
the constraint phase adds zero duplicate builds — the bank counters at
the end prove it.

    PYTHONPATH=src python examples/hybrid_discovery.py
"""

import time

import numpy as np

from repro.core.api import DataSpec, DiscoverySession, EngineOptions
from repro.core.graph import dag_to_cpdag
from repro.core.metrics import shd_cpdag, skeleton_f1
from repro.data.synthetic import generate_scm_data


def main():
    # 10 variables, mixed continuous/discrete SCM (paper Sec. 7.4)
    ds = generate_scm_data(d=10, n=800, density=0.2, kind="mixed", seed=7)
    spec = DataSpec.infer(ds.data)
    kinds = [v.kind for v in spec.variables]
    print(f"data: {ds.data.shape}, true edges: {int(ds.dag.sum())}")
    print(f"variable kinds: {kinds}")

    results = {}
    for restrict in ("none", "skeleton"):
        sess = DiscoverySession(
            ds.data, spec=spec, options=EngineOptions(restrict=restrict)
        )
        t0 = time.perf_counter()
        res = sess.run()
        wall = time.perf_counter() - t0
        results[restrict] = res
        print(f"\nrestrict={restrict!r}: {wall:.2f}s, "
              f"{len(sess.sweep_log)} sweeps")
        if restrict == "skeleton":
            c = sess.sweep_log[0]["constraint"]
            d = sess.spec.num_vars
            print(f"  skeleton: {c['ci_tests']} CI tests in "
                  f"{c['skeleton_s']:.2f}s, pruned {c['pruned_pairs']}/"
                  f"{d * (d - 1)} frontier pairs")
            bank = sess.feature_bank.stats
            print(f"  feature bank: builds={bank['builds']} "
                  f"entries={bank['entries']} (zero duplicates)")
        true_cpdag = dag_to_cpdag(ds.dag)
        print(f"  skeleton F1 vs truth: "
              f"{skeleton_f1(res.cpdag, ds.dag):.3f}, "
              f"SHD: {shd_cpdag(res.cpdag, true_cpdag, normalize=False):.0f}")

    agree = np.array_equal(
        results["none"].cpdag, results["skeleton"].cpdag
    )
    print(f"\ngated CPDAG == ungated CPDAG: {agree}")


if __name__ == "__main__":
    main()
