"""Discrete-network discovery (paper Sec. 7.5): SACHS benchmark with the
exact discrete low-rank decomposition (Alg. 2) — and a CV-LR vs CV runtime
comparison on one local score.

    PYTHONPATH=src python examples/discrete_networks.py
"""

import time

import numpy as np

from repro.core.api import DataSpec, causal_discover, make_scorer
from repro.core.metrics import skeleton_f1
from repro.core.score_common import ScoreConfig
from repro.data.networks import SACHS, sample_network


def main():
    data, truth = sample_network(SACHS, n=1000, seed=0)
    print(f"SACHS: {data.shape[0]} samples x {data.shape[1]} vars "
          f"(cardinalities <= 4), {int(truth.sum())} true edges")

    # Named, typed variable frontend: every SACHS node is discrete, which
    # routes the paper's exact Alg.-2 factorization.  (DataSpec.infer(data)
    # reaches the same conclusion from the cardinalities.)
    spec = DataSpec.from_arrays(
        data, discrete=[True] * SACHS.d, names=list(SACHS.nodes)
    )

    # single-score timing: exact CV vs CV-LR on the same configuration
    for method in ("cv", "cvlr"):
        sc = make_scorer(data, method=method, spec=spec,
                         config=ScoreConfig(seed=0))
        t0 = time.perf_counter()
        s = sc.local_score(0, (7, 8))  # Raf | PKA, PKC
        dt = time.perf_counter() - t0
        print(f"  {method:5s}: local score = {s:.3f}  ({dt*1e3:.1f} ms)")

    t0 = time.perf_counter()
    res = causal_discover(
        data, method="cvlr", spec=spec,
        config=ScoreConfig(seed=0),
    )
    dt = time.perf_counter() - t0
    print(f"\nGES+CV-LR on SACHS: {dt:.1f}s, "
          f"skeleton F1 = {skeleton_f1(res.cpdag, truth):.3f}")
    names = SACHS.nodes
    for i in range(SACHS.d):
        for j in range(SACHS.d):
            if res.cpdag[i, j] and not res.cpdag[j, i]:
                print(f"  {names[i]} -> {names[j]}")


if __name__ == "__main__":
    main()
