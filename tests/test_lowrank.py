"""Alg. 1 (ICL) and Alg. 2 (discrete exact decomposition) tests — hosted
by the feature-bank subsystem (`repro.features.backends`).  The old
`repro.core.lowrank` shim served its one release and is removed."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernel_fns import KernelSpec, kernel_matrix, median_heuristic_width
from repro.features.backends import (
    count_distinct_rows,
    discrete_lowrank,
    incomplete_cholesky,
    lowrank_features,
)


def test_icl_full_rank_exact():
    """With m_max = n and eta ~ 0, ICL reconstructs K exactly."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal((40, 2))
    spec = KernelSpec("rbf", median_heuristic_width(x))
    k = np.asarray(kernel_matrix(x, x, spec))
    lam, m_eff = incomplete_cholesky(x, spec, m_max=40, eta=1e-14)
    np.testing.assert_allclose(np.asarray(lam @ lam.T), k, atol=1e-8)


def test_icl_eta_bound():
    """||Lam Lam^T - K||_F respects the trace-residual stopping bound."""
    rng = np.random.default_rng(1)
    x = rng.standard_normal((150, 1))
    spec = KernelSpec("rbf", median_heuristic_width(x))
    k = np.asarray(kernel_matrix(x, x, spec))
    lam, m_eff = incomplete_cholesky(x, spec, m_max=100, eta=1e-6)
    err = np.abs(np.asarray(lam @ lam.T) - k).max()
    assert int(m_eff) < 100  # smooth 1-d RBF: early stop well before budget
    assert err < 1e-3


def test_icl_monotone_residual():
    """More pivots -> no worse approximation (greedy is monotone)."""
    rng = np.random.default_rng(2)
    x = rng.standard_normal((120, 3))
    spec = KernelSpec("rbf", median_heuristic_width(x))
    k = np.asarray(kernel_matrix(x, x, spec))
    errs = []
    for m in (5, 15, 40):
        lam, _ = incomplete_cholesky(x, spec, m_max=m, eta=0.0)
        errs.append(np.linalg.norm(np.asarray(lam @ lam.T) - k))
    assert errs[0] >= errs[1] >= errs[2]


@pytest.mark.parametrize("card", [2, 3, 6])
def test_discrete_exact(card):
    """Lemma 4.3: for discrete data the decomposition is EXACT."""
    rng = np.random.default_rng(3)
    x = rng.integers(0, card, size=(200, 1)).astype(np.float64)
    spec = KernelSpec("rbf", 1.7)
    k = np.asarray(kernel_matrix(x, x, spec))
    lam, m_d = discrete_lowrank(x, spec, m_max=32)
    assert m_d <= card  # Lemma 4.1 rank bound
    np.testing.assert_allclose(np.asarray(lam @ lam.T), k, atol=1e-7)


def test_discrete_multivariate_exact():
    rng = np.random.default_rng(4)
    x = rng.integers(0, 3, size=(150, 2)).astype(np.float64)
    spec = KernelSpec("rbf", 1.0)
    k = np.asarray(kernel_matrix(x, x, spec))
    lam, m_d = discrete_lowrank(x, spec, m_max=16)
    assert m_d <= 9
    np.testing.assert_allclose(np.asarray(lam @ lam.T), k, atol=1e-7)


def test_discrete_lowrank_pallas_backend_matches_jnp():
    """backend='pallas' routes the kernel strip through the tiled Pallas
    kernel (interpret mode on CPU, f32 accumulation): same factorization
    to f32 accuracy."""
    rng = np.random.default_rng(5)
    x = rng.integers(0, 4, size=(130, 1)).astype(np.float64)
    spec = KernelSpec("rbf", 1.5)
    lam_j, m_j = discrete_lowrank(x, spec, m_max=16, backend="jnp")
    lam_p, m_p = discrete_lowrank(x, spec, m_max=16, backend="pallas")
    assert m_j == m_p
    np.testing.assert_allclose(
        np.asarray(lam_p), np.asarray(lam_j), atol=1e-5
    )


def test_discrete_lowrank_pallas_backend_rejects_non_rbf():
    """Pre-PR-5 the pallas backend was silently ignored for non-RBF
    kernel kinds; now the unsupported combination raises."""
    x = np.array([[0.0], [1.0], [1.0], [2.0]])
    with pytest.raises(ValueError, match="rbf"):
        discrete_lowrank(x, KernelSpec("delta", 1.0), m_max=8, backend="pallas")
    with pytest.raises(ValueError, match="backend"):
        discrete_lowrank(x, KernelSpec("rbf", 1.0), m_max=8, backend="mosaic")


def test_count_distinct_rows_cap():
    x = np.arange(100)[:, None].astype(float)
    assert count_distinct_rows(x, cap=10) == 11  # early exit just past cap
    assert count_distinct_rows(np.zeros((50, 2)), cap=10) == 1


def test_lowrank_features_routes_discrete():
    rng = np.random.default_rng(5)
    x = rng.integers(0, 4, size=(300,)).astype(np.float64)
    lam, m_eff, spec = lowrank_features(x, discrete=True, m_max=100)
    assert m_eff <= 4
    # centered: column means ~ 0
    np.testing.assert_allclose(np.asarray(lam).mean(axis=0), 0.0, atol=1e-10)


def test_lowrank_features_centering_matches_centered_kernel():
    rng = np.random.default_rng(6)
    x = rng.standard_normal((100, 1))
    lam, m_eff, spec = lowrank_features(x, m_max=100, eta=1e-12)
    from repro.core.kernel_fns import center_gram, standardize

    k = kernel_matrix(standardize(x), standardize(x), spec)
    kc = np.asarray(center_gram(k))
    np.testing.assert_allclose(np.asarray(lam @ lam.T), kc, atol=1e-5)


def test_core_lowrank_shim_is_gone():
    """The one-release `repro.core.lowrank` deprecation shim is past its
    release: the module must no longer exist, and the package-level
    re-export must raise a plain AttributeError (no silent fallback)."""
    import repro.core

    with pytest.raises(ImportError):
        import repro.core.lowrank  # noqa: F401
    with pytest.raises(AttributeError):
        repro.core.lowrank_features
