"""Integration of the Pallas kernels into the scorer path + elastic
checkpoint re-shard."""

import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.kernel_fns import KernelSpec
from repro.features.backends import discrete_lowrank


def test_discrete_lowrank_pallas_backend_matches_jnp():
    rng = np.random.default_rng(0)
    x = rng.integers(0, 5, size=(400, 2)).astype(np.float64)
    spec = KernelSpec("rbf", 1.3)
    lam_j, md_j = discrete_lowrank(x, spec, m_max=32, backend="jnp")
    lam_p, md_p = discrete_lowrank(x, spec, m_max=32, backend="pallas")
    assert md_j == md_p
    # pallas strip is f32; factorization agrees to f32 precision
    np.testing.assert_allclose(
        np.asarray(lam_j @ lam_j.T),
        np.asarray(lam_p @ lam_p.T),
        atol=5e-5,
    )


def test_elastic_reshard_subprocess():
    """Checkpoint written single-device restores onto an 8-device mesh via
    sharding_fn (elastic scaling)."""
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.store import save_checkpoint, restore_checkpoint

        tree = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones((4,))}
        d = tempfile.mkdtemp()
        save_checkpoint(d, 5, tree)

        try:  # jax >= 0.5 spells the mesh axis types explicitly
            from jax.sharding import AxisType
            mesh = jax.make_mesh((8,), ("data",),
                                 axis_types=(AxisType.Auto,))
        except ImportError:
            mesh = jax.make_mesh((8,), ("data",))
        # tree leaves sort by key: index 0 = "b" (replicated), 1 = "w"
        shardings = [
            NamedSharding(mesh, P()),
            NamedSharding(mesh, P("data", None)),
        ]
        restored = restore_checkpoint(
            d, 5, tree,
            sharding_fn=lambda i, a: jax.device_put(a, shardings[i]),
        )
        leaves = jax.tree.leaves(restored)
        assert len(leaves[1].sharding.device_set) == 8
        np.testing.assert_array_equal(np.asarray(leaves[1]), np.asarray(tree["w"]))
        print("ELASTIC_OK")
        """
    )
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=240,
        # forced-host-device test: never probe for accelerators (a present
        # libtpu otherwise stalls child startup on TPU metadata lookups)
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert "ELASTIC_OK" in proc.stdout, proc.stderr[-2000:]
