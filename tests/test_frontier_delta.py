"""Incremental frontier-delta sweeps (PR 8): differential harness.

`EngineOptions(incremental=True)` turns on two caches that must be
invisible in the results: the session diffs consecutive frontiers and
scores only the delta, and `repro.core.ges._FrontierDelta` carries
per-pair candidate lists across sweeps under the incidence rule.  The
non-incremental run is kept as the oracle, and this suite proves the two
produce *bitwise identical* output — CPDAG, applied-step trace, final
score, and every memo'd per-config score — across all three engines
(batched / sharded / sequential-lazy) and all three data regimes
(continuous / discrete / mixed), plus kill+resume: a checkpoint restores
the warm delta state and the resumed run still matches the uninterrupted
non-incremental oracle.

The engine-level fast path (`cvlr_scores_batched(small_batch=True)`) and
the score-memo bound (`EngineOptions(score_memo_entries=...)`) are
covered here too: both are latency/memory knobs that must never change a
score.  Set-equality of the carried enumeration itself is
property-tested in tests/test_frontier_delta_props.py (hypothesis).
"""

import json

import numpy as np
import pytest

from repro.core import ges as ges_mod
from repro.core.api import DiscoverySession
from repro.core.runstate import (
    FaultPlan,
    InjectedFault,
    RunState,
    load_latest_runstate,
    load_runstate,
)
from repro.core.score_common import ScoreConfig, config_key
from repro.core.score_lowrank import CVLRScorer
from repro.core.spec import DataSpec, EngineOptions
from repro.data.synthetic import generate_scm_data
from repro.obs import Recorder, engine_stage_split
from repro.obs import trace as obs_trace

_CFG = ScoreConfig(q_folds=5, m_max=40)

ENGINES = {
    "batched": {},
    "sharded": {"engine": "sharded", "shard_workers": 2},
    "sequential": {"engine": "sequential"},
}


def _chain_data(n=80, seed=0):
    rng = np.random.default_rng(seed)
    x0 = rng.standard_normal(n)
    x1 = 0.9 * x0 + 0.4 * rng.standard_normal(n)
    x2 = np.tanh(x1) + 0.4 * rng.standard_normal(n)
    x3 = rng.standard_normal(n)
    return np.stack([x0, x1, x2, x3], axis=1)


def _discrete_fixture(n=80, seed=0):
    x = _chain_data(n, seed)
    out = np.empty_like(x)
    for j in range(x.shape[1]):
        ranks = np.argsort(np.argsort(x[:, j]))
        out[:, j] = ranks * 3 // n
    return out, DataSpec.from_arrays(out, discrete=[True] * 4)


def _mixed_fixture(n=80, seed=2):
    ds = generate_scm_data(d=4, n=n, kind="mixed", seed=seed)
    return ds.data, DataSpec.from_arrays(ds.data, dims=ds.dims,
                                         discrete=ds.discrete)


FIXTURES = {
    "continuous": lambda: (_chain_data(), None),
    "discrete": _discrete_fixture,
    "mixed": _mixed_fixture,
}


def _run(data, spec=None, config=_CFG, **kw):
    sess = DiscoverySession(data, spec=spec, config=config, **kw)
    return sess, sess.run()


def _assert_bitwise(inc_pair, full_pair):
    """Incremental == non-incremental, bit for bit, on everything the
    search produced."""
    inc_sess, inc = inc_pair
    full_sess, full = full_pair
    np.testing.assert_array_equal(inc.cpdag, full.cpdag)
    assert inc.trace == full.trace
    assert inc.forward_steps == full.forward_steps
    assert inc.backward_steps == full.backward_steps
    assert inc.score == full.score  # bitwise, not approx
    # every per-config score both runs computed must agree bitwise
    mi, mf = inc_sess.scorer._score_cache, full_sess.scorer._score_cache
    shared = set(mi) & set(mf)
    assert shared, "no overlapping configs scored — fixture degenerate"
    bad = [k for k in shared if mi[k] != mf[k]]
    assert not bad, f"per-config score drift on {bad[:5]}"


@pytest.mark.parametrize("regime", sorted(FIXTURES))
@pytest.mark.parametrize("engine", sorted(ENGINES))
def test_differential_incremental_vs_full(engine, regime):
    data, spec = FIXTURES[regime]()
    engine_kw = ENGINES[engine]
    full = _run(data, spec=spec,
                options=EngineOptions(incremental=False, **engine_kw))
    inc = _run(data, spec=spec,
               options=EngineOptions(incremental=True, **engine_kw))
    _assert_bitwise(inc, full)
    # the delta engine actually engaged: warm sweeps carried configs and
    # the enumeration cache carried pairs
    log = inc[0].sweep_log
    assert all("frontier" in r for r in log)
    assert sum(r["frontier"]["carried"] for r in log) > 0
    assert sum(r.get("enum", {}).get("pairs_carried", 0) for r in log) > 0
    # ... and the oracle never diffed anything
    assert all("frontier" not in r for r in full[0].sweep_log)


def test_incremental_is_default():
    assert EngineOptions().incremental is True
    sess = DiscoverySession(_chain_data(), config=_CFG)
    assert sess.incremental is True


# -- kill + warm resume ---------------------------------------------------


def test_resume_restores_warm_delta_state(tmp_path):
    """Kill mid-search; resume="auto" must restore the score memo and the
    previous-frontier set (fingerprint-guarded) and the resumed run must
    still match the uninterrupted NON-incremental oracle bitwise."""
    data = _chain_data()
    _, ref = _run(data, options=EngineOptions(incremental=False))
    opts = EngineOptions(checkpoint_dir=str(tmp_path), checkpoint_every=1)
    with pytest.raises(InjectedFault):
        _run(data, options=opts, fault_plan=FaultPlan(kill_at_sweep=2))
    sess = DiscoverySession(data, config=_CFG, options=opts, resume="auto")
    assert sess.resumed_from == 2
    # warm: the memo holds the first two sweeps' scores, the delta state
    # holds sweep 1's frontier
    assert len(sess.scorer._score_cache) > 0
    assert sess._prev_frontier
    res = sess.run()
    np.testing.assert_array_equal(res.cpdag, ref.cpdag)
    assert res.trace == [tuple(s) for s in ref.trace]
    assert res.score == ref.score
    # the first post-resume sweep scored only a delta, not the full
    # frontier — the warm state was actually used
    first = sess.sweep_log[2]
    assert first["frontier"]["carried"] > 0
    assert first["n_scored"] < first["n_configs"]


def test_foreign_fingerprint_resumes_cold_but_correct(tmp_path):
    """A checkpoint whose score fingerprint does not match the resuming
    session must be restored COLD (no memo, no frontier) — and still
    reproduce the oracle exactly."""
    data = _chain_data()
    _, ref = _run(data, options=EngineOptions(incremental=False))
    opts = EngineOptions(checkpoint_dir=str(tmp_path), checkpoint_every=1)
    with pytest.raises(InjectedFault):
        _run(data, options=opts, fault_plan=FaultPlan(kill_at_sweep=2))
    step, state = load_latest_runstate(str(tmp_path))
    state.score_fp = "not-this-session"
    # same-step re-save is an idempotent no-op, so commit one step later
    state.save(str(tmp_path), step + 1)
    sess = DiscoverySession(data, config=_CFG, options=opts, resume="auto")
    assert not sess.scorer._score_cache
    assert sess._prev_frontier is None
    res = sess.run()
    np.testing.assert_array_equal(res.cpdag, ref.cpdag)
    assert res.score == ref.score


def test_runstate_warm_fields_roundtrip(tmp_path):
    rs = RunState.fresh(3)
    rs.score_memo = [[0, [1, 2], -12.5], [1, [], 3.25]]
    rs.frontier = [[0, []], [2, [0, 1]]]
    rs.score_fp = "abc123"
    rs.save(str(tmp_path), 1)
    back = load_runstate(str(tmp_path), 1)
    assert back.score_memo == rs.score_memo
    assert back.frontier == rs.frontier
    assert back.score_fp == "abc123"


def test_runstate_v1_backcompat_without_warm_fields():
    """A pre-PR-8 "repro.runstate.v1" payload (no warm fields) must load
    with cold defaults — the format id did not change."""
    tree = RunState.fresh(3).to_tree()
    payload = json.loads(bytes(tree["payload"]).decode())
    for key in ("score_memo", "frontier", "score_fp"):
        payload.pop(key)
    raw = np.frombuffer(json.dumps(payload).encode(), dtype=np.uint8)
    back = RunState.from_tree(tree["cpdag"], raw)
    assert back.score_memo == []
    assert back.frontier is None
    assert back.score_fp is None


# -- score-memo bound (the unbounded-cache fix) ---------------------------


def test_score_memo_bound_large_enough_is_bitwise():
    """A bound that holds the sweep working set changes nothing at all."""
    data = _chain_data()
    _, ref = _run(data, options=EngineOptions(incremental=False))
    sess, res = _run(data, options=EngineOptions(score_memo_entries=512))
    assert sess.scorer.score_memo_evictions == 0
    np.testing.assert_array_equal(res.cpdag, ref.cpdag)
    assert res.trace == ref.trace
    assert res.score == ref.score


def test_score_memo_tight_bound_evicts_and_stays_correct():
    """A bound far below the frontier working set MUST evict — and the
    search must still land on the same equivalence class.  Bitwise trace
    equality is out of reach by construction here: an evicted config is
    recomputed through the lazy path, which matches the batched engine
    to 1e-8 relative (tests/test_frontier_batch.py), not to the ulp — so
    the assertions are structural + toleranced, the honest contract of
    the memory knob."""
    data = _chain_data()
    _, ref = _run(data, options=EngineOptions(incremental=False))
    sess, res = _run(data, options=EngineOptions(score_memo_entries=8))
    assert len(sess.scorer._score_cache) <= 8
    assert sess.scorer.score_memo_evictions > 0
    np.testing.assert_array_equal(res.cpdag, ref.cpdag)
    assert [s[:4] for s in res.trace] == [s[:4] for s in ref.trace]
    assert abs(res.score - ref.score) <= 1e-8 * max(1.0, abs(ref.score))
    last = sess.sweep_log[-1]["score_cache"]
    assert last["entries"] <= 8 and last["evictions"] > 0


def test_score_cache_telemetry_recorded():
    sess, _ = _run(_chain_data())
    for rec in sess.sweep_log:
        assert rec["score_cache"]["entries"] > 0
        assert rec["score_cache"]["evictions"] == 0  # unbounded default
        assert rec["elapsed_s"] >= 0


def test_score_memo_entries_validation():
    with pytest.raises(ValueError, match="score_memo_entries"):
        EngineOptions(score_memo_entries=0)


# -- the engine-level small-batch fast path -------------------------------


def _frontier_configs(d):
    cfgs = [(i, ()) for i in range(d)]
    cfgs += [(i, (j,)) for i in range(d) for j in range(d) if j != i]
    cfgs += [(0, (1, 2)), (3, (0, 2))]
    return cfgs


def test_small_batch_path_bitwise_equals_default():
    """The small-batch mode (host path, small chunks, pure-pow2 padding)
    must score bitwise-identically to the default device pipeline."""
    data = _chain_data(n=120)
    cfgs = _frontier_configs(4)
    small = CVLRScorer(data, config=_CFG)
    rec_small = Recorder(mode="trace")
    with obs_trace.use(rec_small):
        assert small.prefetch(cfgs, small_batch=True) == len(cfgs)
    t_small = engine_stage_split(rec_small)
    assert t_small["path"] == "host" and t_small["small_batch"] is True
    full = CVLRScorer(data, config=_CFG)
    rec_full = Recorder(mode="trace")
    with obs_trace.use(rec_full):
        assert full.prefetch(cfgs) == len(cfgs)
    t_full = engine_stage_split(rec_full)
    assert "small_batch" not in t_full
    for i, ps in cfgs:
        key = config_key(i, ps)
        assert small._score_cache[key] == full._score_cache[key], key


def test_small_batch_is_optin_and_capped(monkeypatch):
    """Bare prefetch keeps the configured device/host path no matter how
    small the frontier (the device-bank contract); the opt-in flag only
    engages the fast path under the documented uncached-count threshold."""
    assert CVLRScorer.SMALL_BATCH_CONFIGS == 128
    data = _chain_data()
    s = CVLRScorer(data, config=_CFG)
    rec = Recorder(mode="trace")
    with obs_trace.use(rec):
        s.prefetch([(0, ()), (0, (1,)), (1, ())])
    t = engine_stage_split(rec)
    assert "small_batch" not in t  # no hijack without the session's opt-in
    monkeypatch.setattr(CVLRScorer, "SMALL_BATCH_CONFIGS", 1)
    over = CVLRScorer(data, config=_CFG)
    rec2 = Recorder(mode="trace")
    with obs_trace.use(rec2):
        over.prefetch([(0, ()), (0, (1,)), (1, ())], small_batch=True)
    t2 = engine_stage_split(rec2)
    assert "small_batch" not in t2  # eligible but over the cap: full path


def test_session_warm_sweeps_use_small_batch():
    """The incremental session marks warm delta sweeps small-batch
    eligible: sweep 0 (no previous frontier) takes the full pipeline,
    later sweeps' deltas take the fast path."""
    data = _chain_data(n=120)
    sess = DiscoverySession(
        data, config=_CFG, options=EngineOptions(incremental=True)
    )
    calls = []
    real = sess.scorer.prefetch

    def spy(configs, small_batch=False):
        calls.append((len(list(configs)), small_batch))
        return real(configs, small_batch=small_batch)

    sess.scorer.prefetch = spy
    base = [(i, ()) for i in range(4)]
    sess.begin_sweep("t")
    sess.score_frontier(base)
    sess.end_sweep(None)
    sess.begin_sweep("t")
    sess.score_frontier(base + [(0, (1,))])
    sess.end_sweep(None)
    assert calls == [(4, False), (1, True)]


# -- incidence helper -----------------------------------------------------


def test_step_incidence_from_adjacency_diff():
    a = np.zeros((5, 5), np.int8)
    b = a.copy()
    assert ges_mod.step_incidence(a, b) == frozenset()
    b[0, 1] = 1  # new directed edge 0 -> 1
    b[2, 3] = b[3, 2] = 1  # new undirected edge 2 -- 3
    assert ges_mod.step_incidence(a, b) == frozenset({0, 1, 2, 3})
    c = b.copy()
    c[0, 1] = 0
    c[1, 0] = 1  # reorientation must count for both endpoints
    assert ges_mod.step_incidence(b, c) == frozenset({0, 1})
