"""Launch-layer tests: trainer E2E (loss decreases, checkpoint/resume),
serve driver, HLO collective parser, sharding resolver."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.dryrun import _shape_bytes, collective_bytes
from repro.launch.train import train
from repro.launch.serve import serve
from repro.models.config import ShardingResolver


def test_train_loss_decreases(tmp_path):
    state, losses = train(
        arch="tinyllama_1b",
        reduced=True,
        steps=30,
        batch=4,
        seq=64,
        lr=1e-3,
        ckpt_dir=None,
    )
    assert losses[-1] < losses[0], f"{losses[0]} -> {losses[-1]}"


def test_train_checkpoint_resume(tmp_path):
    d = str(tmp_path / "ck")
    train(arch="olmo_1b", reduced=True, steps=10, batch=2, seq=32, ckpt_dir=d, ckpt_every=5)
    from repro.checkpoint.store import latest_step

    assert latest_step(d) == 10
    # resume continues (no error, steps pick up from 10)
    state, losses = train(
        arch="olmo_1b", reduced=True, steps=12, batch=2, seq=32, ckpt_dir=d, ckpt_every=5
    )
    assert len(losses) == 2  # only steps 10, 11 re-run


def test_train_with_compression():
    state, losses = train(
        arch="tinyllama_1b",
        reduced=True,
        steps=20,
        batch=4,
        seq=64,
        lr=1e-3,
        compress=True,
    )
    assert losses[-1] < losses[0]


def test_serve_driver():
    out = serve(arch="tinyllama_1b", batch=2, prompt_len=8, gen=4)
    assert out.shape == (2, 4)
    assert int(out.max()) < 256  # reduced vocab


# ----------------------------------------------------- HLO parsing units
def test_shape_bytes():
    assert _shape_bytes("bf16[16,128]") == 16 * 128 * 2
    assert _shape_bytes("f32[2,3,4]") == 96
    assert _shape_bytes("pred[8]") == 8
    assert _shape_bytes("f32[]") == 4


def test_collective_bytes_parser():
    hlo = """
  %ar = bf16[16,128]{1,0} all-reduce(bf16[16,128] %x), replica_groups={}
  %ag = (f32[4,8]{1,0}, f32[2]{0}) all-gather(f32[2,8] %y, f32[1] %z)
  %cp = f32[64]{0} collective-permute(f32[64] %w)
  %dot = f32[4,4]{1,0} dot(f32[4,8] %a, f32[8,4] %b)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce_bytes"] == 16 * 128 * 2
    assert out["all-gather_bytes"] == 4 * 8 * 4 + 2 * 4
    assert out["collective-permute_bytes"] == 256
    assert out["all-to-all_bytes"] == 0
    assert out["total_collective_bytes"] == 4096 + 136 + 256
    assert out["all-reduce_count"] == 1


# ------------------------------------------------- sharding resolver units
class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape

    @property
    def axis_names(self):
        return tuple(self.shape)


def test_resolver_divisibility_fallback():
    mesh = _FakeMesh({"data": 16, "model": 16})
    r = ShardingResolver(mesh)
    # 8 heads cannot shard 16 ways -> None + fallback recorded
    spec = r.spec((2048, 8, 256), ("embed", "heads", "head_dim"))
    assert spec[0] == "data" and spec[1] is None
    assert any(f[0] == "heads" for f in r.fallbacks)
    # 32 heads shard fine
    spec2 = r.spec((2048, 32, 64), ("embed", "heads", "head_dim"))
    assert spec2[1] == "model"


def test_resolver_multi_pod_fsdp():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    r = ShardingResolver(mesh)
    spec = r.spec((32000, 2048), ("vocab", "embed"))
    assert spec[0] == "model"
    assert spec[1] == ("pod", "data")


def test_resolver_no_axis_reuse():
    mesh = _FakeMesh({"data": 16, "model": 16})
    r = ShardingResolver(mesh)
    # two dims both wanting 'model': only the first gets it
    spec = r.spec((128, 6400), ("expert", "mlp"))
    assert spec[0] == "model" and spec[1] is None
