"""The device-resident fold pipeline (PR 3) vs the host-assembly engine.

Gram blocks scattered into `DeviceGramBank` slots and index-gathered by the
fold jit must be *bit-identical* on CPU to the PR-2 path that drains every
block to host numpy and re-assembles padded V/U chunks — the scatter and
gather are pure data movement around the very same einsums.  On top of
that, the cache's device tier must honor its contracts: LRU slot reuse
spills to the host tier and re-promotes on the next use, `device_bank_mb=0`
opts out entirely, and a sweep that cannot fit the budget falls back to the
host path for that sweep without changing any score.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import make_scorer
from repro.core.score_common import (
    DeviceGramBank,
    GramBlockCache,
    ScoreConfig,
    config_key,
)
from repro.core.score_lowrank import (
    CVLRScorer,
    cvlr_score_from_features,
    cvlr_scores_batched,
)
from repro.data.synthetic import generate_scm_data
from repro.obs import Recorder, engine_stage_split
from repro.obs import trace as obs_trace


def _frontier_configs(d, extra=()):
    configs = [(y, ()) for y in range(d)]
    configs += [(y, (x,)) for x in range(d) for y in range(d) if x != y]
    return configs + list(extra)


def _scores(scorer, configs):
    return np.array([scorer._score_cache[config_key(i, ps)] for i, ps in configs])


# -- engine: device path == host path, bit for bit ------------------------


def test_device_path_matches_host_path_bitwise():
    """Same frontier, same data: the bank engine and the host-assembly
    engine must produce identical float64 bits — including |Z|=0 and
    multi-parent (ragged bucket) configurations."""
    ds = generate_scm_data(d=6, n=260, density=0.4, kind="continuous", seed=21)
    mk = lambda mb: CVLRScorer(
        ds.data, config=ScoreConfig(seed=3), device_bank_mb=mb
    )
    dev, host = mk(CVLRScorer.DEFAULT_DEVICE_BANK_MB), mk(0)
    configs = _frontier_configs(6, extra=[(5, (0, 1)), (0, (2, 3, 4))])
    assert dev.prefetch(configs) == len(configs)
    assert host.prefetch(configs) == len(configs)
    np.testing.assert_array_equal(_scores(dev, configs), _scores(host, configs))
    st = dev.gram_cache.stats
    assert st["device_entries"] > 0 and st["bank_fallbacks"] == 0, st
    assert host.gram_cache.stats["device_entries"] == 0


def test_direct_banks_device_equals_host_and_oracle():
    """Direct bank/pairs API with ragged live ranks and a |Z|=0 zero
    factor: device cache == host cache bitwise, both == sequential oracle
    to <= 1e-8."""
    rng = np.random.default_rng(5)
    n, q, m_pad = 200, 10, 24

    def factor(m_live):
        lam = rng.standard_normal((n, m_live))
        lam = np.concatenate([lam, np.zeros((n, m_pad - m_live))], axis=1)
        lam -= lam.mean(axis=0, keepdims=True)
        return jnp.asarray(lam)

    x_bank = [factor(m) for m in (3, 7, 5)]
    z_bank = [factor(m) for m in (4, 11)] + [jnp.zeros((n, m_pad))]
    m_eff_x = [3, 7, 5]
    m_eff_z = [4, 11, 0]
    pairs = [(xi, zi) for xi in range(3) for zi in range(3)]
    kw = dict(m_eff_x=m_eff_x, m_eff_z=m_eff_z)
    got_dev = cvlr_scores_batched(
        x_bank, z_bank, pairs, q,
        gram_cache=GramBlockCache(device_bank_mb=64), **kw,
    )
    got_host = cvlr_scores_batched(
        x_bank, z_bank, pairs, q, gram_cache=GramBlockCache(), **kw
    )
    np.testing.assert_array_equal(got_dev, got_host)
    lm = jnp.float64(0.01)
    for (xi, zi), g in zip(pairs, got_dev):
        want = float(cvlr_score_from_features(x_bank[xi], z_bank[zi], q, lm, lm))
        assert abs(float(g) - want) / max(1.0, abs(want)) <= 1e-8


def test_device_tier_persists_across_sweeps():
    """A re-scored identical frontier is 100% device hits — no promotions,
    no recompute, and still bitwise-equal scores."""
    rng = np.random.default_rng(11)
    data = rng.standard_normal((220, 4))
    s = CVLRScorer(data, config=ScoreConfig(seed=0))
    configs = _frontier_configs(4)
    s.prefetch(configs)
    first = _scores(s, configs)
    misses0 = s.gram_cache.misses
    s._score_cache.clear()
    s.prefetch(configs)
    np.testing.assert_array_equal(first, _scores(s, configs))
    st = s.gram_cache.stats
    assert st["misses"] == misses0, st  # nothing recomputed
    assert st["promotions"] == 0 and st["spills"] == 0, st


# -- engine: eviction / fallback / opt-out --------------------------------


def _ragged_banks(rng, n=160, q=8, m_pad=16, m_live=5, count=2):
    out = []
    for _ in range(count):
        lam = rng.standard_normal((n, m_live))
        lam = np.concatenate([lam, np.zeros((n, m_pad - m_live))], axis=1)
        lam -= lam.mean(axis=0, keepdims=True)
        out.append(jnp.asarray(lam))
    return out


def test_device_lru_eviction_spills_to_host_and_repromotes():
    """Two disjoint working sets under a budget that holds only ~one of
    them: scoring them alternately forces device-slot LRU reuse (spill to
    host) and, on return, host->device promotion — with every score equal
    to an unbounded host-path scorer's."""
    rng = np.random.default_rng(3)
    n, q, m_pad, m_live = 160, 8, 16, 5
    xa = _ragged_banks(rng, n, q, m_pad, m_live)
    za = _ragged_banks(rng, n, q, m_pad, m_live)
    xb = _ragged_banks(rng, n, q, m_pad, m_live)
    zb = _ragged_banks(rng, n, q, m_pad, m_live)
    pairs = [(xi, zi) for xi in range(2) for zi in range(2)]
    kw = dict(m_eff_x=[m_live] * 2, m_eff_z=[m_live] * 2)
    # slot = q * 8 * 8 * 8B = 4 KiB; frontier working set = 8 blocks ->
    # a 16-slot bank (64 KiB).  72 KiB disallows growing for the second
    # frontier, so its blocks must reuse slots via spill.
    budget_mb = 72 / 1024
    cache = GramBlockCache(device_bank_mb=budget_mb)
    ref = GramBlockCache()  # host-only reference

    def both(x, z, ka, kb):
        keys = dict(x_keys=[(ka, i) for i in range(2)],
                    z_keys=[(kb, i) for i in range(2)])
        got = cvlr_scores_batched(x, z, pairs, q, gram_cache=cache, **kw, **keys)
        want = cvlr_scores_batched(x, z, pairs, q, gram_cache=ref, **kw, **keys)
        np.testing.assert_array_equal(got, want)

    both(xa, za, "ax", "az")
    assert cache.stats["bank_fallbacks"] == 0, cache.stats
    both(xb, zb, "bx", "bz")  # evicts some of A's slots -> spills
    assert cache.spills > 0, cache.stats
    both(xa, za, "ax", "az")  # A's spilled blocks come back -> promotions
    assert cache.promotions > 0, cache.stats
    assert cache.stats["bank_fallbacks"] == 0, cache.stats


def test_budget_too_small_falls_back_to_host_path():
    """A sweep whose working set cannot be device-resident must fall back
    wholesale (counted in bank_fallbacks) and still score identically."""
    ds = generate_scm_data(d=5, n=240, density=0.4, kind="continuous", seed=4)
    tiny = CVLRScorer(
        ds.data, config=ScoreConfig(seed=1), device_bank_mb=1e-3
    )
    host = CVLRScorer(ds.data, config=ScoreConfig(seed=1), device_bank_mb=0)
    configs = _frontier_configs(5)
    tiny.prefetch(configs)
    host.prefetch(configs)
    np.testing.assert_array_equal(_scores(tiny, configs), _scores(host, configs))
    st = tiny.gram_cache.stats
    assert st["bank_fallbacks"] >= 1 and st["device_entries"] == 0, st


def test_device_bank_opt_out_kwarg():
    """EngineOptions(device_bank_mb=0) and =None both run the pure host
    engine; the default enables the device tier."""
    from repro.core.spec import EngineOptions

    rng = np.random.default_rng(9)
    data = rng.standard_normal((200, 3))
    for off in (0, None):
        s = make_scorer(
            data,
            config=ScoreConfig(seed=0),
            options=EngineOptions(device_bank_mb=off),
        )
        assert not s.gram_cache.device_enabled
        s.prefetch(_frontier_configs(3))
        assert s.gram_cache.stats["device_entries"] == 0
    s = make_scorer(data, config=ScoreConfig(seed=0))
    assert s.gram_cache.device_enabled
    s.prefetch(_frontier_configs(3))
    assert s.gram_cache.stats["device_entries"] > 0


def test_prefetch_stage_timings():
    """An active trace recorder captures the pipeline path and the three
    stage slices; `repro.obs.engine_stage_split` folds them back into
    the per-stage keys benchmarks/frontier_scoring.py depends on."""
    rng = np.random.default_rng(2)
    data = rng.standard_normal((180, 3))
    s = CVLRScorer(data, config=ScoreConfig(seed=0))
    rec = Recorder(mode="trace")
    with obs_trace.use(rec):
        s.prefetch(_frontier_configs(3))
    t = engine_stage_split(rec)
    assert t["path"] == "device"
    for k in ("gram_s", "zcores_s", "fold_s"):
        assert t[k] >= 0.0


# -- GramBlockCache device tier: unit-level contracts ---------------------


def _fill_slot(cache, key, value_row):
    """Adopt a slot for `key` and write `value_row` ((q, wa, wb)) into it,
    the way the engine's fused scatter would."""
    slot = cache.device_adopt(key)
    widths = value_row.shape[1:]
    data = cache.bank_data(widths)
    cache.set_bank_data(widths, data.at[slot].set(jnp.asarray(value_row)))
    return slot


def test_cache_device_tier_spill_preserves_trimmed_block():
    """Slot reuse spills the exact trimmed block to the host tier, and a
    later sweep re-promotes it into a (zero-padded) slot."""
    q, w = 2, 8
    rng = np.random.default_rng(0)
    row = np.zeros((q, w, w))
    row[:, :3, :3] = rng.standard_normal((q, 3, 3))
    # budget: exactly the minimal 4-slot bank (4 KiB at q=2, w=8, f64)
    cache = GramBlockCache(device_bank_mb=4 * q * w * w * 8 / 2**20)
    assert cache.begin_device_sweep({"k1": (w, w, 3, 3)}, q=q, dtype=np.float64)
    _fill_slot(cache, "k1", row)
    cache.end_device_sweep()
    np.testing.assert_array_equal(cache.get("k1"), row[:, :3, :3])
    assert "k1" in cache and len(cache) == 1

    # two newcomers > free slots and growth is over budget -> spill k1
    assert cache.begin_device_sweep(
        {"k2": (w, w, 3, 3), "k3": (w, w, 3, 3)}, q=q, dtype=np.float64
    )
    assert cache.spills == 1 and cache.stats["device_entries"] == 0
    _fill_slot(cache, "k2", row)
    _fill_slot(cache, "k3", row)
    cache.end_device_sweep()
    np.testing.assert_array_equal(cache.get("k1"), row[:, :3, :3])  # host now

    # k1 comes back -> promotion into a device slot, padded exactly
    assert cache.begin_device_sweep({"k1": (w, w, 3, 3)}, q=q, dtype=np.float64)
    slot = cache.device_lookup("k1")
    assert slot is not None and cache.promotions == 1
    np.testing.assert_array_equal(
        np.asarray(cache.bank_data((w, w))[slot]), row
    )
    cache.end_device_sweep()


def test_cache_device_tier_reserved_slots_stay_zero():
    """Slot 0 (the |Z|=0 gather target) must remain exactly zero no matter
    what is adopted, promoted, or spilled around it."""
    q, w = 2, 8
    cache = GramBlockCache(device_bank_mb=1)
    assert cache.begin_device_sweep({"k": (w, w, w, w)}, q=q, dtype=np.float64)
    _fill_slot(cache, "k", np.full((q, w, w), 7.0))
    cache.end_device_sweep()
    assert DeviceGramBank.ZERO_SLOT == 0
    np.testing.assert_array_equal(
        np.asarray(cache.bank_data((w, w))[0]), np.zeros((q, w, w))
    )


def test_cache_entry_bound_spans_both_tiers():
    """max_entries bounds host+device entries together; a sweep larger
    than the bound refuses the device path instead of evicting pinned
    working-set blocks."""
    q, w = 2, 8
    cache = GramBlockCache(max_entries=2, device_bank_mb=1)
    specs = {f"k{i}": (w, w, w, w) for i in range(3)}
    assert not cache.begin_device_sweep(specs, q=q, dtype=np.float64)
    assert cache.bank_fallbacks == 1

    for key in ("a", "b", "c"):
        assert cache.begin_device_sweep({key: (w, w, w, w)}, q=q, dtype=np.float64)
        _fill_slot(cache, key, np.ones((q, w, w)))
        cache.end_device_sweep()
    assert len(cache) <= 2 and cache.evictions >= 1, cache.stats


def test_refused_sweep_rolls_back_created_banks():
    """A begin_device_sweep that fails on a later width group must tear
    down the empty banks it already created — a refused sweep may not
    leave zombie allocations eating the budget of every future sweep."""
    q = 2
    # budget fits the small (8, 8) bank but not the (96, 96) one
    cache = GramBlockCache(device_bank_mb=8 * q * 8 * 8 * 8 / 2**20)
    specs = {"small": (8, 8, 8, 8), "big": (96, 96, 96, 96)}
    assert not cache.begin_device_sweep(specs, q=q, dtype=np.float64)
    assert cache.device_nbytes == 0 and cache.bank_data((8, 8)) is None
    # the small-only sweep still fits afterwards
    assert cache.begin_device_sweep({"small": (8, 8, 8, 8)}, q=q, dtype=np.float64)
    cache.end_device_sweep()


def test_spilled_block_keeps_its_lru_age():
    """A spill demotes a block without refreshing it: under entry-count
    pressure the spilled (globally oldest) entry is evicted before
    recently-used host blocks, despite its out-of-order dict position."""
    q, w = 2, 8
    cache = GramBlockCache(max_entries=3, device_bank_mb=4 * q * w * w * 8 / 2**20)
    assert cache.begin_device_sweep({"old": (w, w, w, w)}, q=q, dtype=np.float64)
    _fill_slot(cache, "old", np.ones((q, w, w)))
    cache.end_device_sweep()
    cache.put("h1", np.ones((q, 1, 1)))  # fresher host entries
    cache.put("h2", np.ones((q, 2, 2)))
    # two newcomers force the (unpinned, oldest) "old" slot to spill: it
    # re-enters the host dict at the tail but keeps its old tick
    assert cache.begin_device_sweep(
        {"n1": (w, w, w, w), "n2": (w, w, w, w)}, q=q, dtype=np.float64
    )
    assert cache.spills == 1 and "old" in cache
    _fill_slot(cache, "n1", np.ones((q, w, w)))
    _fill_slot(cache, "n2", np.ones((q, w, w)))
    cache.end_device_sweep()  # 5 entries > max 3: evict the oldest two
    assert "old" not in cache, cache.stats  # oldest tick goes first
    assert "h2" in cache and len(cache) == 3


def test_cache_rejects_negative_budget():
    with pytest.raises(ValueError):
        GramBlockCache(device_bank_mb=-1)
