"""Per-architecture smoke tests: reduced same-family config, one forward /
train-grad step on CPU, output shapes + finiteness.  The FULL configs are
exercised only via the dry-run (ShapeDtypeStruct, no allocation)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ShapeConfig
from repro.models.registry import ARCH_IDS, load_arch

LM_ARCHS = [a for a in ARCH_IDS if a != "cvlr_paper"]
SMOKE_SHAPE = ShapeConfig("smoke", seq_len=64, global_batch=2, kind="train")


def _batch_from_specs(specs, rng):
    batch = {}
    for name, s in specs.items():
        if np.issubdtype(s.dtype, np.integer):
            batch[name] = jnp.asarray(
                rng.integers(0, 200, size=s.shape), s.dtype
            )
        else:
            batch[name] = jnp.asarray(
                rng.standard_normal(s.shape), s.dtype
            )
    return batch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_and_loss(arch):
    cfg, model = load_arch(arch, reduced=True)
    rng = np.random.default_rng(0)
    params, axes = model.init(jax.random.PRNGKey(0))
    # params and logical-axes trees must be congruent
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda x: isinstance(x, tuple)
    )
    batch = _batch_from_specs(model.input_specs(SMOKE_SHAPE), rng)
    loss = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch} loss not finite"
    # CE at init should be near log(vocab)
    assert float(loss) < np.log(cfg.vocab_size) * 3


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_train_grad_step(arch):
    cfg, model = load_arch(arch, reduced=True)
    rng = np.random.default_rng(1)
    params, _ = model.init(jax.random.PRNGKey(1))
    batch = _batch_from_specs(model.input_specs(SMOKE_SHAPE), rng)
    grads = jax.jit(jax.grad(model.loss))(params, batch)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), f"{arch} grad NaN"
    norms = [float(jnp.linalg.norm(g.astype(jnp.float32))) for g in flat]
    assert sum(norms) > 0, f"{arch}: all-zero gradients"


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_decode_step(arch):
    cfg, model = load_arch(arch, reduced=True)
    if not hasattr(model, "decode_step"):
        pytest.skip("no decode step")
    params, _ = model.init(jax.random.PRNGKey(2))
    shape = ShapeConfig("smoke_decode", seq_len=32, global_batch=2, kind="decode")
    cache_specs, tok_spec = model.decode_specs(shape)
    rng = np.random.default_rng(3)
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_specs
    )
    cache["index"] = jnp.asarray(3, jnp.int32)  # pretend 3 tokens prefilled
    tokens = jnp.asarray(rng.integers(0, 100, size=tok_spec.shape), jnp.int32)
    logits, new_cache = jax.jit(model.decode_step)(params, cache, tokens)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch} decode NaN"
    assert int(new_cache["index"]) == 4


def test_transformer_prefill_decode_consistency():
    """Greedy next token from prefill == next token from teacher-forced
    forward on the same prefix (KV-cache correctness)."""
    cfg, model = load_arch("tinyllama_1b", reduced=True)
    params, _ = model.init(jax.random.PRNGKey(4))
    rng = np.random.default_rng(4)
    tokens = jnp.asarray(rng.integers(0, 200, size=(2, 16)), jnp.int32)
    logits_full, _ = jax.jit(model.forward)(params, {"tokens": tokens})
    last_logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_len=32)
    )(params, {"tokens": tokens})
    np.testing.assert_allclose(
        np.asarray(last_logits, np.float32),
        np.asarray(logits_full[:, -1], np.float32),
        atol=2e-2,
        rtol=2e-2,
    )
    # one decode step continues coherently
    nxt = jnp.argmax(last_logits, -1)[:, None].astype(jnp.int32)
    # pad cache seq dim to decode length
    step_logits, cache2 = jax.jit(model.decode_step)(params, cache, nxt)
    full2, _ = jax.jit(model.forward)(
        params, {"tokens": jnp.concatenate([tokens, nxt], axis=1)}
    )
    np.testing.assert_allclose(
        np.asarray(step_logits, np.float32),
        np.asarray(full2[:, -1], np.float32),
        atol=3e-2,
        rtol=3e-2,
    )


def test_param_counts_match_assignment():
    """Exact (eval_shape) parameter counts are in the right ballpark of the
    arch names (sanity that the configs encode the assigned sizes)."""
    from repro.models.registry import load_arch as la, param_count_exact

    expect = {
        "tinyllama_1b": (0.9e9, 1.5e9),
        "gemma_2b": (1.9e9, 3.2e9),
        "starcoder2_15b": (13e9, 19e9),
        "olmo_1b": (0.9e9, 1.5e9),
        "arctic_480b": (400e9, 560e9),
        "phi35_moe": (35e9, 50e9),
        "internvl2_26b": (17e9, 28e9),  # LM backbone (ViT is a stub)
        "xlstm_1b": (1.0e9, 2.2e9),
        "zamba2_1b": (0.9e9, 2.0e9),
        "seamless_m4t_medium": (0.5e9, 1.6e9),
    }
    for arch, (lo, hi) in expect.items():
        cfg, model = la(arch)
        n = param_count_exact(model)
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"
