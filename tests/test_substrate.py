"""Substrate tests: optimizers, compression, checkpointing, data pipeline,
fault tolerance."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.checkpoint.store import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.data.tokens import PrefetchIterator, TokenStream
from repro.distributed.fault_tolerance import FaultTolerantRunner, HeartbeatMonitor
from repro.optim.compression import compress_int8, decompress_int8, ef_allreduce, init_error_state
from repro.optim.optimizers import (
    OptimConfig,
    cosine_schedule,
    global_norm_clip,
    make_optimizer,
)


def _quad_problem(kind):
    """Minimize ||W x - y||^2 — optimizers must make progress."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
    w_true = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
    y = x @ w_true + 0.05 * jnp.asarray(rng.standard_normal((64, 4)), jnp.float32)
    params = {"w": jnp.zeros((8, 4), jnp.float32), "b": jnp.zeros((4,), jnp.float32)}

    def loss_fn(p):
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)

    cfg = OptimConfig(kind=kind, lr=5e-2, warmup_steps=5, total_steps=200, weight_decay=0.0)
    init, update = make_optimizer(cfg)
    state = init(params)
    l0 = float(loss_fn(params))
    for _ in range(100):
        grads = jax.grad(loss_fn)(params)
        params, state, _ = update(grads, state, params)
    return l0, float(loss_fn(params))


@pytest.mark.parametrize("kind", ["adamw", "adafactor"])
def test_optimizer_reduces_loss(kind):
    l0, l1 = _quad_problem(kind)
    assert l1 < 0.5 * l0, f"{kind}: {l0} -> {l1}"


def test_cosine_schedule_shape():
    cfg = OptimConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(cosine_schedule(cfg, s)) for s in range(0, 110, 5)]
    assert lrs[0] < 0.01  # warmup from ~0
    assert abs(max(lrs) - 1.0) < 0.06
    assert lrs[-1] <= 0.2  # decays toward min ratio


def test_global_norm_clip():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = global_norm_clip(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    assert float(norm) > 30


@settings(max_examples=30, deadline=None)
@given(scale=st.floats(1e-3, 1e3), n=st.integers(1, 500))
def test_int8_roundtrip_error_bound(scale, n):
    rng = np.random.default_rng(n)
    x = jnp.asarray(scale * rng.standard_normal(n), jnp.float32)
    q, s = compress_int8(x)
    err = jnp.abs(decompress_int8(q, s) - x).max()
    assert float(err) <= float(s) * 0.5 + 1e-9  # half-ULP of the quant grid


def test_error_feedback_accumulates():
    """EF must preserve the gradient signal over steps: sum of compressed
    gradients tracks the sum of true gradients."""
    rng = np.random.default_rng(1)
    grads = [
        {"w": jnp.asarray(rng.standard_normal(32) * 1e-3, jnp.float32)}
        for _ in range(50)
    ]
    err = init_error_state(grads[0])
    total_c = jnp.zeros(32)
    total_t = jnp.zeros(32)
    for g in grads:
        c, err = ef_allreduce(g, err)
        total_c = total_c + c["w"]
        total_t = total_t + g["w"]
    resid = float(jnp.abs(total_c - total_t).max())
    # the residual equals the final error-feedback buffer, bounded by one
    # quantization step — NOT 50 accumulated steps
    assert resid < 2e-4


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "w": jnp.arange(12.0).reshape(3, 4),
        "nested": {"b": jnp.ones((2,), jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }
    save_checkpoint(str(tmp_path), 100, tree)
    assert latest_step(str(tmp_path)) == 100
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored = restore_checkpoint(str(tmp_path), 100, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_atomicity(tmp_path):
    tree = {"w": jnp.ones((4,))}
    save_checkpoint(str(tmp_path), 1, tree)
    # a leftover tmp dir from a 'crashed' save must not be visible
    os.makedirs(tmp_path / "tmp.2")
    assert latest_step(str(tmp_path)) == 1


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    for step in (10, 20):
        ck.save(step, {"w": jnp.full((8,), float(step))})
    ck.wait()
    assert latest_step(str(tmp_path)) == 20
    restored = restore_checkpoint(str(tmp_path), 20, {"w": jnp.zeros((8,))})
    assert float(restored["w"][0]) == 20.0


def test_token_stream_determinism_and_sharding():
    stream = TokenStream(vocab_size=100, seq_len=16, global_batch=8, seed=3)
    full = stream.batch_at(5)
    half = stream.batch_at(5, rows=range(4, 8))
    np.testing.assert_array_equal(full["tokens"][4:], half["tokens"])
    again = stream.batch_at(5)
    np.testing.assert_array_equal(full["tokens"], again["tokens"])
    assert full["tokens"].max() < 100
    # labels are next tokens
    np.testing.assert_array_equal(full["tokens"][:, 1:], full["labels"][:, :-1])


def test_prefetch_iterator():
    stream = TokenStream(vocab_size=50, seq_len=8, global_batch=2, seed=0)
    it = PrefetchIterator(stream, start_step=3)
    s0, b0 = next(it)
    s1, b1 = next(it)
    it.close()
    assert (s0, s1) == (3, 4)
    np.testing.assert_array_equal(b0["tokens"], stream.batch_at(3)["tokens"])


def test_heartbeat_monitor():
    mon = HeartbeatMonitor(num_workers=3, timeout=1.0, grace=2)
    t0 = 100.0
    for w in range(3):
        mon.beat(w, at=t0)
    alive, suspect, dead = mon.check(at=t0 + 0.5)
    assert alive == [0, 1, 2]
    mon.beat(0, at=t0 + 1.2)
    alive, suspect, dead = mon.check(at=t0 + 1.5)
    assert alive == [0] and set(suspect) == {1, 2}  # one missed window
    # misses are keyed to deadline epochs, not check() calls: re-checking
    # at the same instant must NOT escalate suspect -> dead
    alive, suspect, dead = mon.check(at=t0 + 1.5)
    assert alive == [0] and set(suspect) == {1, 2} and dead == []
    alive, suspect, dead = mon.check(at=t0 + 2.5)
    assert set(dead) == {1, 2}  # grace (2 windows) actually elapsed
    mon.beat(1, at=t0 + 2.6)
    alive, suspect, dead = mon.check(at=t0 + 2.7)
    assert 1 in alive  # a beat resurrects a suspect/dead worker


def test_fault_tolerant_runner_recovers_exactly(tmp_path):
    """Kill the run mid-flight; the resumed run must produce the same final
    state as an uninterrupted run (checkpoint + deterministic data)."""

    def train_step(state, batch):
        new = {"w": state["w"] + batch, "n": state["n"] + 1}
        return new, {"w0": float(new["w"][0])}

    batches = lambda step: jnp.full((4,), float(step + 1))
    init = {"w": jnp.zeros((4,)), "n": jnp.asarray(0, jnp.int32)}

    # uninterrupted reference
    ref = FaultTolerantRunner(train_step, init, str(tmp_path / "ref"), ckpt_every=4)
    ref.run(batches, 10)
    ref_state = ref.state

    # crashing run: dies at step 7
    class Boom(RuntimeError):
        pass

    def fail_once(step, fired=[False]):
        if step == 7 and not fired[0]:
            fired[0] = True
            raise Boom()

    d = str(tmp_path / "crash")
    r1 = FaultTolerantRunner(train_step, init, d, ckpt_every=4)
    with pytest.raises(Boom):
        r1.run(batches, 10, fail_hook=fail_once)
    # restart: picks up from step 4 checkpoint
    r2 = FaultTolerantRunner(train_step, init, d, ckpt_every=4)
    assert r2.step_num == 4
    r2.run(batches, 10)
    np.testing.assert_allclose(np.asarray(r2.state["w"]), np.asarray(ref_state["w"]))
    assert int(r2.state["n"]) == int(ref_state["n"])
