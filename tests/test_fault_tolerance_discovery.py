"""Fault tolerance for discovery runs (PR 6): checkpoint/resume
equivalence, shard death + survivor re-shard, the numerical degradation
ladder, and the checkpoint store's failure contract.

The load-bearing property everything here leans on: GES is a
deterministic replayable search (candidate enumeration is a pure function
of the CPDAG, fold layouts and feature builds are seeded), so killing a
run at an arbitrary sweep boundary and resuming from the last committed
`RunState` must reproduce the uninterrupted run's CPDAG *bit-for-bit* and
its applied-step sequence exactly — on the batched and the sharded
engine, on continuous, discrete, and mixed-data fixtures, and even when
the newest checkpoint on disk is corrupted (resume falls back one step
and replays one extra sweep).
"""

import os

import numpy as np
import pytest

from repro.checkpoint.store import (
    AsyncCheckpointer,
    latest_step,
    list_steps,
    save_checkpoint,
    sweep_orphaned_tmp,
)
from repro.core.api import DiscoverySession, causal_discover
from repro.core.distributed_score import sharded_batch_hook
from repro.core.runstate import (
    FaultPlan,
    InjectedFault,
    RunState,
    load_latest_runstate,
    load_runstate,
)
from repro.core.score_common import ScoreConfig, config_key
from repro.core.spec import DataSpec, EngineOptions
from repro.data.synthetic import generate_scm_data

_CFG = ScoreConfig(q_folds=5, m_max=40)


def _chain_data(n=80, seed=0):
    rng = np.random.default_rng(seed)
    x0 = rng.standard_normal(n)
    x1 = 0.9 * x0 + 0.4 * rng.standard_normal(n)
    x2 = np.tanh(x1) + 0.4 * rng.standard_normal(n)
    x3 = rng.standard_normal(n)
    return np.stack([x0, x1, x2, x3], axis=1)


def _discrete_data(n=80, seed=0):
    """The chain fixture, equal-frequency discretized to 3 levels."""
    x = _chain_data(n, seed)
    out = np.empty_like(x)
    for j in range(x.shape[1]):
        ranks = np.argsort(np.argsort(x[:, j]))
        out[:, j] = ranks * 3 // n
    return out


def _mixed_fixture(n=80, seed=2):
    ds = generate_scm_data(d=4, n=n, kind="mixed", seed=seed)
    spec = DataSpec.from_arrays(ds.data, dims=ds.dims, discrete=ds.discrete)
    return ds.data, spec


def _semantic_log(sweep_log):
    """The per-sweep fields that must survive kill/resume exactly (cache
    counters legitimately differ: a resumed run's scorer starts cold)."""
    return [
        (r["phase"], r["sweep"], r["n_configs"], r["step"]) for r in sweep_log
    ]


def _run(data, spec=None, config=_CFG, **kw):
    sess = DiscoverySession(data, spec=spec, config=config, **kw)
    return sess, sess.run()


# -- checkpoint store: the failure contract ------------------------------


def test_async_checkpointer_reraises_background_failure(tmp_path, monkeypatch):
    """A background-write exception must surface on the next wait()/save(),
    never be swallowed (the pre-fix behavior dropped checkpoints forever)."""
    ck = AsyncCheckpointer(str(tmp_path))
    import repro.checkpoint.store as store

    def _boom(directory, step, tree):
        raise OSError("disk on fire")

    monkeypatch.setattr(store, "save_checkpoint", _boom)
    ck.save(0, {"a": np.zeros(3)})
    with pytest.raises(OSError, match="disk on fire"):
        ck.wait()
    # the failure was drained: the checkpointer is usable again
    monkeypatch.undo()
    ck.save(1, {"a": np.zeros(3)})
    ck.wait()
    assert ck.saved and ck.saved[0].endswith("step_0000000001")


def test_same_step_resave_is_idempotent(tmp_path):
    """Re-committing the step a resumed run restored from must be a no-op,
    not a FileExistsError."""
    d = str(tmp_path)
    p1 = save_checkpoint(d, 3, {"a": np.arange(4)})
    before = os.path.getmtime(os.path.join(p1, "arrays.npz"))
    p2 = save_checkpoint(d, 3, {"a": np.arange(4) + 100})  # ignored
    assert p1 == p2
    assert os.path.getmtime(os.path.join(p2, "arrays.npz")) == before
    with np.load(os.path.join(p2, "arrays.npz")) as data:
        np.testing.assert_array_equal(data["a0"], np.arange(4))


def test_orphaned_tmp_swept_on_startup(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, {"a": np.zeros(2)})
    orphan = os.path.join(d, "tmp.7")
    os.makedirs(orphan)
    with open(os.path.join(orphan, "arrays.npz"), "wb") as f:
        f.write(b"partial")
    removed = sweep_orphaned_tmp(d)
    assert removed == [orphan]
    assert not os.path.exists(orphan)
    assert latest_step(d) == 1  # committed steps untouched
    AsyncCheckpointer(d)  # startup sweep is harmless when there's nothing


def test_manifestless_final_dir_is_replaced(tmp_path):
    """A step dir without a manifest is pre-commit litter, not a
    checkpoint — a re-save must replace it and commit for real."""
    d = str(tmp_path)
    litter = os.path.join(d, "step_0000000002")
    os.makedirs(litter)
    save_checkpoint(d, 2, {"a": np.ones(2)})
    assert list_steps(d) == [2]
    np.testing.assert_array_equal(load_runstate_arrays(d, 2), np.ones(2))


def load_runstate_arrays(directory, step):
    path = os.path.join(directory, f"step_{step:010d}", "arrays.npz")
    with np.load(path) as data:
        return data["a0"]


# -- RunState serialization ----------------------------------------------


def test_runstate_roundtrip(tmp_path):
    rs = RunState.fresh(3)
    rs.cpdag[0, 1] = 1
    rs.phase = "backward"
    rs.sweep = 4
    rs.forward_steps = 2
    rs.trace = [("insert", 0, 1, (2,), 1.5), ("delete", 1, 2, (), 0.25)]
    rs.sweep_log = [{"phase": "forward", "sweep": 0, "n_configs": 9,
                     "n_scored": 9, "step": ("insert", 0, 1, (2,), 1.5)}]
    rs.bank_meta = [[[0], "('icl', 40)"]]
    rs.degradations = {"jittered": 1}
    rs.save(str(tmp_path), 4)
    step, back = (4, load_runstate(str(tmp_path), 4))
    assert np.array_equal(back.cpdag, rs.cpdag)
    assert back.cpdag.dtype == np.int8
    assert (back.phase, back.sweep, back.forward_steps) == ("backward", 4, 2)
    assert back.trace == rs.trace  # tuples restored, not JSON lists
    assert _semantic_log(back.sweep_log) == _semantic_log(rs.sweep_log)
    assert back.bank_meta == rs.bank_meta
    assert back.degradations == rs.degradations
    assert load_latest_runstate(str(tmp_path))[0] == step


def test_load_latest_skips_corrupt_and_foreign(tmp_path):
    d = str(tmp_path)
    RunState.fresh(3).save(d, 1)
    rs2 = RunState.fresh(3)
    rs2.sweep = 2
    rs2.save(d, 2)
    # step 3: a foreign (non-RunState) checkpoint must be skipped, not crash
    save_checkpoint(d, 3, {"w": np.zeros((2, 2)), "b": np.zeros(2), "x": np.zeros(1)})
    step, state = load_latest_runstate(d)
    assert step == 2 and state.sweep == 2
    # corrupt step 2 as well: falls back to step 1
    from repro.core.runstate import corrupt_checkpoint_file

    corrupt_checkpoint_file(d, 2)
    step, state = load_latest_runstate(d)
    assert step == 1 and state.sweep == 0


# -- kill + resume == uninterrupted --------------------------------------


def _assert_resume_equivalent(tmp_path, data, spec=None, engine_kw=None,
                              kill_at=1, config=_CFG):
    engine_kw = engine_kw or {}
    ref_sess, ref = _run(data, spec=spec, config=config,
                         options=EngineOptions(**engine_kw))
    opts = EngineOptions(checkpoint_dir=str(tmp_path), checkpoint_every=1,
                        **engine_kw)
    with pytest.raises(InjectedFault):
        _run(data, spec=spec, config=config, options=opts,
             fault_plan=FaultPlan(kill_at_sweep=kill_at))
    assert latest_step(str(tmp_path)) == kill_at
    sess = DiscoverySession(data, spec=spec, config=config, options=opts,
                            resume="auto")
    assert sess.resumed_from == kill_at
    res = sess.run()
    np.testing.assert_array_equal(res.cpdag, ref.cpdag)  # bitwise
    assert res.trace == [tuple(s) for s in ref.trace]
    assert res.forward_steps == ref.forward_steps
    assert res.backward_steps == ref.backward_steps
    assert res.score == ref.score
    assert _semantic_log(sess.sweep_log) == _semantic_log(ref_sess.sweep_log)
    return sess


def test_resume_equivalence_continuous_batched(tmp_path):
    _assert_resume_equivalent(tmp_path, _chain_data(), kill_at=2)


def test_resume_equivalence_continuous_sharded(tmp_path):
    _assert_resume_equivalent(tmp_path, _chain_data(),
                              engine_kw={"engine": "sharded",
                                         "shard_workers": 2}, kill_at=1)


def test_resume_equivalence_discrete(tmp_path):
    data = _discrete_data()
    spec = DataSpec.from_arrays(data, discrete=[True] * 4)
    _assert_resume_equivalent(tmp_path, data, spec=spec, kill_at=1)


def test_resume_equivalence_mixed(tmp_path):
    data, spec = _mixed_fixture()
    _assert_resume_equivalent(tmp_path, data, spec=spec, kill_at=1)


def test_resume_falls_back_past_corrupted_latest(tmp_path):
    """Corrupt the newest checkpoint on disk: resume restores the
    previous committed step, replays one extra sweep, and still matches."""
    data = _chain_data()
    _, ref = _run(data, options=EngineOptions())
    opts = EngineOptions(checkpoint_dir=str(tmp_path), checkpoint_every=1)
    with pytest.raises(InjectedFault):
        _run(data, options=opts,
             fault_plan=FaultPlan(kill_at_sweep=3, corrupt_checkpoint=3))
    assert latest_step(str(tmp_path)) == 3  # committed, then trashed
    sess = DiscoverySession(data, config=_CFG, options=opts, resume="auto")
    assert sess.resumed_from == 2  # fell back one step
    res = sess.run()
    np.testing.assert_array_equal(res.cpdag, ref.cpdag)
    assert res.trace == [tuple(s) for s in ref.trace]
    assert res.score == ref.score


def test_resume_on_finished_run_skips_to_score(tmp_path):
    data = _chain_data()
    opts = EngineOptions(checkpoint_dir=str(tmp_path), checkpoint_every=1)
    _, ref = _run(data, options=opts)
    sess = DiscoverySession(data, config=_CFG, options=opts, resume="auto")
    assert sess.run_state.phase == "done"
    res = sess.run()
    np.testing.assert_array_equal(res.cpdag, ref.cpdag)
    assert res.score == ref.score
    assert sess.sweep_log == sess.run_state.sweep_log  # aliased, no growth


def test_checkpoint_every_throttles_writes(tmp_path):
    data = _chain_data()
    opts = EngineOptions(checkpoint_dir=str(tmp_path), checkpoint_every=2)
    sess, _ = _run(data, options=opts)
    steps = list_steps(str(tmp_path))
    total = len(sess.sweep_log)
    expected = sorted({s for s in range(2, total + 1, 2)} | {total})
    assert steps == expected  # every 2nd sweep + the final state


def test_resume_auto_requires_checkpoint_dir():
    with pytest.raises(ValueError, match="checkpoint_dir"):
        DiscoverySession(_chain_data(), resume="auto")
    with pytest.raises(ValueError, match="resume must be"):
        DiscoverySession(_chain_data(), resume="always")


def test_resume_rejects_mismatched_bank_fingerprints(tmp_path):
    """A checkpoint written under a different build config must be refused
    — resuming would silently mix factor families."""
    data = _chain_data()
    opts = EngineOptions(checkpoint_dir=str(tmp_path), checkpoint_every=1)
    with pytest.raises(InjectedFault):
        _run(data, options=opts, fault_plan=FaultPlan(kill_at_sweep=1))
    other = ScoreConfig(q_folds=5, m_max=40, width_factor=3.0)
    with pytest.raises(ValueError, match="fingerprint"):
        DiscoverySession(data, config=other, options=opts, resume="auto")


# -- shard fault tolerance ------------------------------------------------


def _frontier(d):
    configs = [config_key(y, ()) for y in range(d)]
    configs += [config_key(y, (x,)) for x in range(d) for y in range(d) if x != y]
    return sorted(set(configs), key=lambda c: (c[1], c[0]))


def _warm_scorer(data, **opt_kw):
    from repro.core.api import make_scorer

    opts = EngineOptions(engine="sharded", **opt_kw)
    scorer = make_scorer(data, options=opts, config=_CFG)
    configs = _frontier(4)
    sharded_batch_hook(scorer, configs, options=opts)
    ref = dict(scorer._score_cache)
    scorer._score_cache.clear()
    return scorer, configs, ref, opts


def test_sharded_survivor_reshard_identical_scores():
    """Kill one worker (raise mode): its frontier slice re-partitions
    across survivors and every score is bitwise-identical (per-candidate
    scoring is partition-independent)."""
    data = _chain_data()
    scorer, configs, ref, opts = _warm_scorer(
        data, shard_workers=3, shard_retries=1)
    tel = {}
    n = sharded_batch_hook(scorer, configs, options=opts,
                           fault_plan=FaultPlan(kill_shard=(1, 0)),
                           sweep=0, telemetry=tel)
    assert n == len(ref)
    assert tel["dead_workers"] == [1]
    assert tel["resharded"] > 0
    assert scorer._score_cache == ref  # bitwise-identical floats


def test_sharded_hang_trips_timeout_then_reshards():
    """Hang mode: the straggler trips the per-shard timeout + heartbeat
    path (not the exception path) and the sweep still completes exactly."""
    data = _chain_data()
    scorer, configs, ref, opts = _warm_scorer(
        data, shard_workers=2, shard_retries=1, shard_timeout_s=0.5)
    tel = {}
    plan = FaultPlan(kill_shard=(0, 0), shard_fault="hang", shard_hang_s=1.5)
    sharded_batch_hook(scorer, configs, options=opts, fault_plan=plan,
                       sweep=0, telemetry=tel)
    assert 0 in tel["dead_workers"]
    assert scorer._score_cache == ref


def test_sharded_all_dead_falls_back_in_process():
    """Every worker dead: the stranded frontier lands on the in-process
    batched engine and the sweep still completes with identical scores."""
    data = _chain_data()
    scorer, configs, ref, opts = _warm_scorer(
        data, shard_workers=1, shard_retries=0)
    tel = {}
    sharded_batch_hook(scorer, configs, options=opts,
                       fault_plan=FaultPlan(kill_shard=(0, 0)),
                       sweep=0, telemetry=tel)
    assert tel["dead_workers"] == [0]
    assert tel["fallback_keys"] == len(ref)
    assert scorer._score_cache == ref


def test_sharded_full_discovery_with_dead_worker_matches_reference():
    data = _chain_data()
    _, ref = _run(data, options=EngineOptions())
    sess, res = _run(
        data,
        options=EngineOptions(engine="sharded", shard_workers=3,
                              shard_retries=1),
        fault_plan=FaultPlan(kill_shard=(2, 0)),
    )
    np.testing.assert_array_equal(res.cpdag, ref.cpdag)
    assert res.score == ref.score
    shard_recs = [r["shards"] for r in sess.sweep_log if "shards" in r]
    assert shard_recs and all(2 in r["dead_workers"] for r in shard_recs)


def test_sharded_default_single_worker_unchanged():
    """shard_workers=1 with no fault plan takes the original single-
    dispatch path — the seed behavior, no thread pool."""
    data = _chain_data()
    _, ref = _run(data, options=EngineOptions())
    sess, res = _run(data, options=EngineOptions(engine="sharded"))
    np.testing.assert_array_equal(res.cpdag, ref.cpdag)
    assert res.score == ref.score
    assert not any("shards" in r for r in sess.sweep_log)


# -- numerical degradation ladder ----------------------------------------


def test_nan_scores_recover_via_jittered_retry():
    data = _chain_data()
    _, ref = _run(data, options=EngineOptions())
    sess, res = _run(data, options=EngineOptions(),
                     fault_plan=FaultPlan(nan_scores=(0, 3)))
    np.testing.assert_array_equal(res.cpdag, ref.cpdag)
    degs = [r["degradations"] for r in sess.sweep_log if "degradations" in r]
    assert degs == [{"jittered": 3, "f64_resolve": 0,
                     "exact_fallback": 0, "unrecovered": 0}]
    assert sess.run_state.degradations["jittered"] == 3


def test_degradation_escalates_to_f64_then_exact():
    data = _chain_data()
    sess, _ = _run(data, options=EngineOptions(),
                   fault_plan=FaultPlan(nan_scores=(0, 2), fail_rungs=1))
    assert sess.scorer.degradations["f64_resolve"] == 2
    sess, _ = _run(data, options=EngineOptions(),
                   fault_plan=FaultPlan(nan_scores=(0, 2), fail_rungs=2))
    assert sess.scorer.degradations["exact_fallback"] == 2
    assert sess.scorer.degradations["jittered"] == 0


def test_degradation_unrecovered_is_counted_and_run_completes():
    data = _chain_data()
    sess, res = _run(data, options=EngineOptions(),
                     fault_plan=FaultPlan(nan_scores=(0, 2), fail_rungs=3))
    assert sess.scorer.degradations["unrecovered"] == 2
    assert res.cpdag.shape == (4, 4)  # search still terminated


def test_degradation_ladder_on_sharded_engine():
    data = _chain_data()
    _, ref = _run(data, options=EngineOptions())
    sess, res = _run(data,
                     options=EngineOptions(engine="sharded", shard_workers=2),
                     fault_plan=FaultPlan(nan_scores=(0, 2)))
    np.testing.assert_array_equal(res.cpdag, ref.cpdag)
    assert sess.scorer.degradations["jittered"] == 2
