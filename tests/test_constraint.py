"""Constraint subsystem: kernel CI tests, PC-stable skeleton, EdgeMask
gating, RunState persistence, and the batched device-bank promotions.

Acceptance bar (PR 9 tentpole): CI-test calibration (type-I <= alpha +
tol on independent fixtures, power >= floor on dependent ones) across
continuous/discrete/mixed data and rff/nystrom/icl backends; the
estimated skeleton a superset of the true skeleton at generous alpha on
linear-Gaussian fixtures (property-tested); `restrict="none"` bitwise
identical to an unrestricted session; `restrict="skeleton"` pruning
frontiers with zero duplicate FeatureBank builds; checkpoint/resume
reusing the persisted skeleton without re-estimation.
"""

import itertools

import numpy as np
import pytest

from repro.constraint import EdgeMask, KernelCITest, estimate_skeleton
from repro.core.api import DiscoverySession, make_scorer
from repro.core.graph import random_dag, skeleton as graph_skeleton
from repro.core.runstate import FaultPlan, InjectedFault
from repro.core.score_common import GramBlockCache
from repro.core.spec import EngineOptions
from repro.features.policy import FeaturePolicy

ALPHA = 0.05
# binomial slack for the empirical type-I fraction over ~60+ pairs
TYPE_I_TOL = 0.06
POWER_FLOOR = 0.7


def _chain_data(n, d, seed, noise=0.5):
    rng = np.random.default_rng(seed)
    cols = [rng.standard_normal(n)]
    for _ in range(d - 1):
        cols.append(np.tanh(cols[-1]) + noise * rng.standard_normal(n))
    return np.stack(cols, axis=1)


def _independent_data(n, d, seed, kind="continuous"):
    rng = np.random.default_rng(seed)
    cols = []
    for j in range(d):
        if kind == "discrete" or (kind == "mixed" and j % 2 == 1):
            cols.append(rng.integers(0, 3, size=n).astype(np.float64))
        else:
            cols.append(rng.standard_normal(n))
    return np.stack(cols, axis=1)


def _policy(backend):
    if backend == "icl":
        return None  # FeaturePolicy.default() routes continuous -> icl
    return FeaturePolicy(continuous=backend, mixed=backend)


# -- calibration: type-I error and power ----------------------------------


@pytest.mark.parametrize("backend", ["icl", "rff", "nystrom"])
@pytest.mark.parametrize("kind", ["continuous", "discrete", "mixed"])
def test_type_one_error_within_tolerance(backend, kind):
    """On jointly independent fixtures the rejection rate at ALPHA must
    stay within binomial slack of ALPHA — per data kind x backend."""
    data = _independent_data(500, 12, seed=hash((backend, kind)) % 2**16)
    opts = EngineOptions(features=_policy(backend))
    ci = KernelCITest(make_scorer(data, options=opts))
    tests = [(x, y, ()) for x, y in itertools.combinations(range(12), 2)]
    ps = np.asarray(ci.batch(tests))
    assert ps.shape == (66,)
    assert np.all((ps >= 0.0) & (ps <= 1.0))
    frac = float((ps < ALPHA).mean())
    assert frac <= ALPHA + TYPE_I_TOL, (
        f"type-I {frac:.3f} > {ALPHA} + {TYPE_I_TOL} ({backend}/{kind})"
    )


@pytest.mark.parametrize("backend", ["icl", "rff", "nystrom"])
def test_power_on_dependent_pairs(backend):
    """Adjacent chain pairs are strongly dependent: the test must reject
    at ALPHA for at least POWER_FLOOR of them."""
    d = 6
    data = _chain_data(600, d, seed=1, noise=0.4)
    opts = EngineOptions(features=_policy(backend))
    ci = KernelCITest(make_scorer(data, options=opts))
    ps = np.asarray(ci.batch([(j, j + 1, ()) for j in range(d - 1)]))
    power = float((ps < ALPHA).mean())
    assert power >= POWER_FLOOR, f"power {power:.2f} < {POWER_FLOOR} ({backend})"


def test_conditional_independence_detected():
    """x0 -> x1 -> x2: marginally dependent, independent given x1."""
    data = _chain_data(600, 3, seed=0)
    ci = KernelCITest(make_scorer(data))
    assert ci.pvalue(0, 2) < ALPHA
    assert ci.pvalue(0, 2, (1,)) > ALPHA
    # symmetric in (x, y) and served from the result cache
    before = dict(ci.stats)
    assert ci.pvalue(2, 0, (1,)) == ci.pvalue(0, 2, (1,))
    assert ci.stats["ci_tests"] == before["ci_tests"]
    assert ci.stats["cached"] > before["cached"]


def test_permutation_null_agrees_with_gamma():
    data = _chain_data(500, 3, seed=2)
    sc = make_scorer(data)
    gamma = KernelCITest(sc)
    perm = KernelCITest(sc, null="permutation", n_perm=300)
    for args in [(0, 1, ()), (0, 2, (1,))]:
        pg, pp = gamma.pvalue(*args), perm.pvalue(*args)
        # same accept/reject decision at the default level
        assert (pg < ALPHA) == (pp < ALPHA), (args, pg, pp)
    assert perm.stats["permutation"] == 2


def test_ci_test_zero_duplicate_builds():
    """Every factor the CI tests touch comes from the scorer's
    FeatureBank: builds == entries even after the score phase reuses
    the same sets."""
    data = _chain_data(300, 4, seed=3)
    sc = make_scorer(data)
    ci = KernelCITest(sc)
    estimate_skeleton(ci, 4, alpha=ALPHA, max_cond=1)
    sc.prefetch([(0, ()), (1, (0,)), (2, (1,)), (3, (2,))])
    bank = sc.feature_bank.stats
    assert bank["builds"] == bank["entries"]


def test_ci_test_input_validation():
    data = _chain_data(200, 3, seed=4)
    sc = make_scorer(data)
    with pytest.raises(ValueError, match="gamma"):
        KernelCITest(sc, null="bootstrap")
    ci = KernelCITest(sc)
    with pytest.raises(ValueError, match="x != y"):
        ci.pvalue(1, 1)
    with pytest.raises(ValueError, match="exclude"):
        ci.pvalue(0, 1, (1,))


# -- skeleton: EdgeMask + superset property -------------------------------


def test_edge_mask_contract():
    m = EdgeMask.full(4)
    assert m.pruned_pairs == 0 and m.allows(0, 3)
    rt = EdgeMask.from_list(m.to_list())
    assert np.array_equal(rt.allowed, m.allowed)
    with pytest.raises(ValueError, match="diagonal"):
        EdgeMask(np.ones((3, 3), dtype=bool))
    bad = np.zeros((3, 3), dtype=bool)
    bad[0, 1] = True  # not symmetric
    with pytest.raises(ValueError, match="symmetric"):
        EdgeMask(bad)


def test_skeleton_on_chain():
    data = _chain_data(600, 4, seed=5, noise=0.4)
    ci = KernelCITest(make_scorer(data))
    mask, info = estimate_skeleton(ci, 4, alpha=ALPHA, max_cond=2)
    # every true chain edge survives
    for j in range(3):
        assert mask.allows(j, j + 1), f"true edge {j}-{j+1} was pruned"
    assert info["pruned_pairs"] == mask.pruned_pairs > 0
    assert info["ci_tests"] > 0 and info["skeleton_s"] > 0
    assert info["levels"][0]["tests"] == 6  # level 0: all unordered pairs


def _linear_gaussian(dag, n, seed):
    d = dag.shape[0]
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.7, 1.3, size=(d, d)) * np.sign(
        rng.standard_normal((d, d))
    )
    data = np.zeros((n, d))
    done: set = set()
    while len(done) < d:  # topological fill (random_dag permutes order)
        for j in range(d):
            parents = np.flatnonzero(dag[:, j])
            if j in done or not set(parents) <= done:
                continue
            data[:, j] = rng.standard_normal(n)
            for p in parents:
                data[:, j] += w[p, j] * data[:, p]
            done.add(j)
    return data


@pytest.mark.parametrize("seed", [0, 7, 41])
def test_skeleton_superset_on_linear_gaussian(seed):
    """At generous alpha the estimated skeleton contains every true edge
    of a linear-Gaussian SCM — gating never deletes edges the score
    phase needs (larger alpha => fewer edges severed).  Randomized-seed
    version in tests/test_constraint_props.py (hypothesis)."""
    d = 6
    dag = random_dag(d, 0.3, np.random.default_rng(seed))
    data = _linear_gaussian(dag, n=500, seed=seed)
    ci = KernelCITest(make_scorer(data))
    mask, _ = estimate_skeleton(ci, d, alpha=0.25, max_cond=2)
    true_skel = graph_skeleton(dag)
    missing = [
        (x, y)
        for x, y in zip(*np.nonzero(true_skel))
        if not mask.allows(int(x), int(y))
    ]
    assert not missing, f"true edges pruned at generous alpha: {missing}"


def test_cap_only_keeps_more_edges():
    """max_sets_per_edge caps enumeration — it can only *keep* edges a
    full enumeration might remove, never remove more."""
    data = _chain_data(500, 5, seed=6)
    sc = make_scorer(data)
    ci = KernelCITest(sc)
    capped, _ = estimate_skeleton(ci, 5, alpha=ALPHA, max_cond=2,
                                  max_sets_per_edge=1)
    full, _ = estimate_skeleton(ci, 5, alpha=ALPHA, max_cond=2,
                                max_sets_per_edge=64)
    assert np.all(capped.allowed >= full.allowed)


# -- EngineOptions / session threading ------------------------------------


def test_engine_options_validation():
    with pytest.raises(ValueError, match="restrict"):
        EngineOptions(restrict="pc")
    with pytest.raises(ValueError, match="ci_alpha"):
        EngineOptions(ci_alpha=1.5)
    with pytest.raises(ValueError, match="ci_max_cond"):
        EngineOptions(ci_max_cond=-1)
    opts = EngineOptions(restrict="skeleton", ci_alpha=0.1, ci_max_cond=1)
    assert (opts.restrict, opts.ci_alpha, opts.ci_max_cond) == (
        "skeleton", 0.1, 1,
    )
    with pytest.raises(ValueError, match="cvlr"):
        DiscoverySession(
            _chain_data(100, 3, seed=0),
            options=EngineOptions(restrict="skeleton"),
            method="cv",
        )


def test_full_mask_bitwise_identical():
    """An all-allowed EdgeMask is the identity: gating with it produces
    the bitwise-identical run to no mask at all (the restrict="none"
    contract, exercised through the session seam ges() actually reads)."""
    data = _chain_data(200, 4, seed=7)
    ref_sess = DiscoverySession(data, options=EngineOptions())
    ref = ref_sess.run()
    sess = DiscoverySession(data, options=EngineOptions())
    sess.edge_mask = EdgeMask.full(4)
    res = sess.run()
    assert np.array_equal(res.cpdag, ref.cpdag)
    assert res.score == ref.score
    assert [tuple(s) for s in res.trace] == [tuple(s) for s in ref.trace]


@pytest.mark.parametrize("engine", ["batched", "sequential"])
def test_restrict_skeleton_end_to_end(engine):
    data = _chain_data(400, 5, seed=8, noise=0.4)
    sess = DiscoverySession(
        data, options=EngineOptions(engine=engine, restrict="skeleton")
    )
    res = sess.run()
    assert sess.edge_mask is not None
    rec = sess.sweep_log[0]["constraint"]
    assert rec["pruned_pairs"] == sess.edge_mask.pruned_pairs > 0
    assert rec["ci_tests"] > 0 and rec["skeleton_s"] > 0
    # zero duplicate factor builds across constraint + score phases
    bank = sess.feature_bank.stats
    assert bank["builds"] == bank["entries"]
    # the estimated CPDAG respects the mask: every edge is an allowed pair
    adj = (res.cpdag + res.cpdag.T) > 0
    assert np.all(~adj | sess.edge_mask.allowed)


def test_gated_frontier_smaller_and_delta_composed():
    """Gating shrinks the forward frontier and composes with the
    incremental delta engine: pruned pairs never enter the enumeration
    cache's bookkeeping (pairs_full + pairs_carried counts allowed
    forward pairs only)."""
    data = _chain_data(400, 6, seed=9, noise=0.4)
    plain = DiscoverySession(data, options=EngineOptions())
    plain.run()
    gated = DiscoverySession(
        data, options=EngineOptions(restrict="skeleton")
    )
    gated.run()
    n_allowed = int(gated.edge_mask.allowed.sum())
    d = 6
    for rec in gated.sweep_log:
        if rec["phase"] != "forward" or "enum" not in rec:
            continue
        enumerated = rec["enum"]["pairs_full"] + rec["enum"]["pairs_carried"]
        assert enumerated <= n_allowed < d * (d - 1)
    assert (
        gated.sweep_log[0]["n_configs"] <= plain.sweep_log[0]["n_configs"]
    )


def test_skeleton_resume_skips_reestimation(tmp_path):
    """A killed gated run resumes from its checkpointed skeleton: the
    fingerprint matches, no CI test re-runs, and the final CPDAG equals
    the uninterrupted gated run's."""
    data = _chain_data(400, 5, seed=10, noise=0.4)
    opts = EngineOptions(
        restrict="skeleton", checkpoint_dir=str(tmp_path / "ckpt")
    )
    ref = DiscoverySession(data, options=EngineOptions(restrict="skeleton"))
    ref_res = ref.run()

    crash = DiscoverySession(
        data, options=opts, fault_plan=FaultPlan(kill_at_sweep=2)
    )
    with pytest.raises(InjectedFault):
        crash.run()
    assert crash.run_state.skeleton is not None

    resumed = DiscoverySession(data, options=opts, resume="auto")
    res = resumed.run()
    assert resumed._constraint.get("restored") is True
    assert resumed._constraint["ci_tests"] == 0
    assert np.array_equal(resumed.edge_mask.allowed, ref.edge_mask.allowed)
    assert np.array_equal(res.cpdag, ref_res.cpdag)
    assert res.score == ref_res.score


def test_skeleton_fp_mismatch_reestimates(tmp_path):
    """A resume under different CI knobs must NOT reuse the persisted
    skeleton (the fingerprint guards alpha/max_cond)."""
    data = _chain_data(300, 4, seed=11)
    dir_ = str(tmp_path / "ckpt")
    first = DiscoverySession(
        data, options=EngineOptions(restrict="skeleton", checkpoint_dir=dir_)
    )
    first.run()
    second = DiscoverySession(
        data,
        options=EngineOptions(
            restrict="skeleton", checkpoint_dir=dir_, ci_alpha=0.2
        ),
        resume="auto",
    )
    second.run()
    assert "restored" not in (second._constraint or {})
    assert second._constraint["ci_tests"] > 0


# -- satellite: batched device-bank promotions ----------------------------


def test_promotions_batched_per_width():
    """Host-tier hits found during a sweep upload as ONE scatter per
    bucket width (promotion_uploads), not one per block (promotions)."""
    q, w = 4, 8
    cache = GramBlockCache(device_bank_mb=64)
    blocks = {
        (("k", i), ("k", i)): np.full((q, 5, 5), float(i + 1))
        for i in range(6)
    }
    for k, v in blocks.items():
        cache.put(k, v)  # host tier
    specs = {k: (w, w, 5, 5) for k in blocks}
    assert cache.begin_device_sweep(specs, q, np.float64)
    slots = {k: cache.device_lookup(k) for k in blocks}
    assert all(s is not None for s in slots.values())
    st = cache.stats
    assert st["promotions"] == 6
    assert st["promotion_uploads"] == 0, "uploads must be deferred"
    # the read seam flushes: one scatter for the whole width group
    data = cache.bank_data((w, w))
    assert cache.stats["promotion_uploads"] == 1
    for k, v in blocks.items():
        got = np.asarray(data[slots[k]])[:, :5, :5]
        np.testing.assert_array_equal(got, v)
    cache.end_device_sweep()
    # blocks stay readable through the host interface afterwards
    for k, v in blocks.items():
        np.testing.assert_array_equal(cache.get(k), v)


def test_promotion_flush_before_spill():
    """Spilling a device entry whose promotion is still queued must see
    the queued block, not the zero-initialized slot."""
    q, w = 2, 8
    cache = GramBlockCache(device_bank_mb=64)
    blk = np.full((q, 3, 3), 7.0)
    cache.put(("a",), blk)
    assert cache.begin_device_sweep({("a",): (w, w, 3, 3)}, q, np.float64)
    assert cache.device_lookup(("a",)) is not None  # queued, not uploaded
    cache.end_device_sweep()
    assert cache.spill_device() == 1
    np.testing.assert_array_equal(cache.get(("a",)), blk)
