"""Distributed scoring: batched == sequential; shard_map == single-device.

The multi-device check runs in a subprocess (XLA_FLAGS must be set before
jax initializes; the main test process keeps 1 device).
"""

import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np

from repro.core.distributed_score import (
    block_folds,
    cvlr_scores_stacked,
    ges_batch_hook,
)
from repro.core.ges import ges
from repro.core.score_common import ScoreConfig
from repro.core.score_lowrank import CVLRScorer, cvlr_score_from_features


def _factors(rng, n, m_live, m_pad):
    lam = rng.standard_normal((n, m_live))
    lam = np.concatenate([lam, np.zeros((n, m_pad - m_live))], axis=1)
    lam -= lam.mean(axis=0, keepdims=True)
    return jnp.asarray(lam)


def test_batched_matches_sequential():
    rng = np.random.default_rng(0)
    n, q, m = 200, 10, 12
    lxs, lzs, expect = [], [], []
    for b in range(5):
        lx = _factors(rng, n, 4 + b, m)
        lz = _factors(rng, n, 3, m)
        lxs.append(block_folds(lx, q))
        lzs.append(block_folds(lz, q))
        expect.append(
            float(
                cvlr_score_from_features(
                    lx, lz, q, jnp.float64(0.01), jnp.float64(0.01)
                )
            )
        )
    got = cvlr_scores_stacked(jnp.stack(lxs), jnp.stack(lzs))
    np.testing.assert_allclose(np.asarray(got), expect, rtol=1e-9)


def test_ges_with_batch_hook_matches_plain():
    rng = np.random.default_rng(1)
    n = 300
    x0 = rng.standard_normal(n)
    x1 = np.tanh(x0) + 0.3 * rng.standard_normal(n)
    x2 = np.sin(x1) + 0.3 * rng.standard_normal(n)
    data = np.stack([x0, x1, x2], axis=1)
    s1 = CVLRScorer(data, config=ScoreConfig(seed=3))
    r1 = ges(s1)
    s2 = CVLRScorer(data, config=ScoreConfig(seed=3))
    r2 = ges(s2, batch_hook=ges_batch_hook)
    np.testing.assert_array_equal(r1.cpdag, r2.cpdag)
    # batched and sequential caches must agree numerically
    for k, v in s1._score_cache.items():
        assert abs(s2._score_cache[k] - v) < 1e-6 * max(1.0, abs(v))


def test_shardmap_multidevice_subprocess():
    code = textwrap.dedent(
        """
        import contextlib, os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        import repro.core  # enables x64
        from repro.core.distributed_score import (
            block_folds, cvlr_scores_stacked, make_sharded_scorer)
        try:  # jax >= 0.5 spells the mesh axis types explicitly
            from jax.sharding import AxisType
            mesh = jax.make_mesh((2, 4), ("model", "data"),
                                 axis_types=(AxisType.Auto,) * 2)
        except ImportError:
            from jax.sharding import Mesh
            mesh = Mesh(np.array(jax.devices()).reshape(2, 4),
                        ("model", "data"))
        rng = np.random.default_rng(0)
        B, n, q, m = 4, 160, 4, 8
        lx = []
        lz = []
        for _ in range(B):
            a = rng.standard_normal((n, m)); a -= a.mean(0)
            b = rng.standard_normal((n, m)); b -= b.mean(0)
            lx.append(block_folds(jnp.asarray(a), q))
            lz.append(block_folds(jnp.asarray(b), q))
        lx = jnp.stack(lx); lz = jnp.stack(lz)
        ref = cvlr_scores_stacked(lx, lz)
        fn = make_sharded_scorer(mesh)
        ctx = (jax.set_mesh(mesh) if hasattr(jax, "set_mesh")
               else contextlib.nullcontext())
        with ctx:
            got = fn(lx, lz)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-9)
        print("SHARDED_OK")
        """
    )
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=300,
        env={
            "PYTHONPATH": "src",
            "PATH": "/usr/bin:/bin",
            # forced-host-device test: never probe for accelerators
            "JAX_PLATFORMS": "cpu",
        },
        cwd="/root/repo",
    )
    assert "SHARDED_OK" in proc.stdout, proc.stderr[-3000:]
