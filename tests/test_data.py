"""Data-generation tests: SCM generator + discrete networks."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core.graph import is_dag
from repro.data.networks import CHILD, SACHS, sample_network
from repro.data.synthetic import generate_scm_data


@pytest.mark.parametrize("kind", ["continuous", "mixed", "multidim"])
def test_scm_shapes(kind):
    ds = generate_scm_data(d=7, n=100, density=0.4, kind=kind, seed=1)
    assert ds.data.shape == (100, sum(ds.dims))
    assert ds.dag.shape == (7, 7)
    assert is_dag(ds.dag)
    assert np.all(np.isfinite(ds.data))
    if kind == "mixed":
        assert sum(ds.discrete) == 4  # 50% (ceil) discretized
        for i, disc in enumerate(ds.discrete):
            if disc:
                col = ds.data[:, sum(ds.dims[:i])]
                assert set(np.unique(col)) <= {1.0, 2.0, 3.0, 4.0, 5.0}
    if kind == "multidim":
        assert any(d > 1 for d in ds.dims)


@settings(max_examples=20, deadline=None)
@given(
    d=st.integers(3, 10),
    density=st.floats(0.2, 0.8),
    seed=st.integers(0, 1000),
)
def test_scm_generator_properties(d, density, seed):
    ds = generate_scm_data(d=d, n=50, density=density, kind="continuous", seed=seed)
    assert is_dag(ds.dag)
    assert np.all(np.isfinite(ds.data))
    # determinism
    ds2 = generate_scm_data(d=d, n=50, density=density, kind="continuous", seed=seed)
    np.testing.assert_array_equal(ds.data, ds2.data)


def test_network_structures():
    assert SACHS.d == 11 and len(SACHS.edges) == 17
    assert CHILD.d == 20 and len(CHILD.edges) == 25
    assert is_dag(SACHS.adjacency()) and is_dag(CHILD.adjacency())


def test_network_sampling():
    data, adj = sample_network(SACHS, n=500, seed=0)
    assert data.shape == (500, 11)
    assert np.array_equal(adj, SACHS.adjacency())
    # integer category codes, small cardinality
    assert np.array_equal(data, np.round(data))
    assert data.max() < 6
    # children depend on parents: mutual information sanity on one edge
    raf, mek = 0, 1  # Raf -> Mek in SACHS
    joint = np.histogram2d(data[:, raf], data[:, mek], bins=4)[0] / 500
    px = joint.sum(1, keepdims=True)
    py = joint.sum(0, keepdims=True)
    with np.errstate(divide="ignore", invalid="ignore"):
        mi = np.nansum(joint * np.log(joint / (px * py)))
    assert mi > 0.01


def test_network_sampling_deterministic():
    d1, _ = sample_network(CHILD, n=100, seed=7)
    d2, _ = sample_network(CHILD, n=100, seed=7)
    np.testing.assert_array_equal(d1, d2)
