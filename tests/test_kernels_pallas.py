"""Per-kernel validation: shape/dtype sweeps, Pallas (interpret) vs ref.py.

The kernels target TPU; on this CPU container they execute the kernel body
in interpret mode — identical math, same BlockSpec tiling/padding paths.
"""

import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional dev dep (requirements-dev.txt): only gates the property test
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover
    given = None

import repro.core  # noqa: F401 — enables x64: the fold-Gram strip kernel
# must be validated at the engine's float64 (rbf/centered tests cast to
# f32 inside their wrappers either way).

from repro.kernels.ops import (
    centered_gram,
    feature_strip,
    fold_gram_blocks,
    fold_gram_strip,
    fold_gram_strip_banked,
    rbf_gram,
)
from repro.kernels.ref import (
    centered_gram_ref,
    feature_strip_ref,
    fold_gram_strip_banked_ref,
    fold_gram_strip_ref,
    rbf_gram_ref,
)


@pytest.mark.parametrize("kind", ["rbf", "delta", "linear"])
@pytest.mark.parametrize("n,m,d", [(37, 5, 1), (130, 33, 3)])
def test_feature_strip_jnp_matches_ref(kind, n, m, d):
    """The dispatcher's non-TPU backend (single-jit strip at the input
    dtype) against the naive broadcast-difference oracle."""
    rng = np.random.default_rng(n + m)
    x = rng.standard_normal((n, d))
    if kind == "delta":
        x = np.round(x)  # give delta genuine collisions
    p = x[rng.choice(n, size=m, replace=False)]
    out = feature_strip(x, p, 1.3, kind=kind)
    assert out.dtype == jnp.float64
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(feature_strip_ref(x, p, 1.3, kind=kind)),
        atol=1e-12,
    )


def test_feature_strip_pallas_path_matches_ref():
    """use_pallas=True runs the tiled rbf_gram kernel (interpret mode on
    CPU) and casts back to the input dtype: f32-accurate vs the oracle."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal((97, 2))
    p = rng.standard_normal((13, 2))
    out = feature_strip(x, p, 0.9, kind="rbf", use_pallas=True, interpret=True)
    assert out.shape == (97, 13) and out.dtype == jnp.float64
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(feature_strip_ref(x, p, 0.9, kind="rbf")),
        atol=1e-5,
    )


def test_feature_strip_forced_pallas_rejects_non_rbf():
    x = np.zeros((4, 1))
    with pytest.raises(ValueError, match="rbf"):
        feature_strip(x, x, 1.0, kind="delta", use_pallas=True)
    with pytest.raises(ValueError, match="kernel kind"):
        feature_strip(x, x, 1.0, kind="matern")


@pytest.mark.parametrize("n", [7, 128, 300, 513])
@pytest.mark.parametrize("m", [1, 100, 128, 257])
@pytest.mark.parametrize("d", [1, 3, 128, 130])
def test_rbf_gram_shape_sweep(n, m, d):
    rng = np.random.default_rng(n * 1000 + m * 10 + d)
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.standard_normal((m, d)).astype(np.float32)
    width = 1.5
    out = rbf_gram(x, y, width, interpret=True)
    ref = rbf_gram_ref(jnp.asarray(x), jnp.asarray(y), width)
    assert out.shape == (n, m)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_rbf_gram_dtypes(dtype):
    rng = np.random.default_rng(0)
    x = rng.standard_normal((64, 4)).astype(dtype)
    y = rng.standard_normal((32, 4)).astype(dtype)
    out = rbf_gram(x, y, 2.0, interpret=True)
    ref = rbf_gram_ref(jnp.asarray(x, jnp.float64), jnp.asarray(y, jnp.float64), 2.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("width", [0.1, 1.0, 10.0])
def test_rbf_gram_width(width):
    rng = np.random.default_rng(1)
    x = rng.standard_normal((50, 2)).astype(np.float32)
    out = rbf_gram(x, x, width, interpret=True)
    ref = rbf_gram_ref(jnp.asarray(x), jnp.asarray(x), width)
    # pre-scaled-coordinate path vs post-divide ref: fp32 agreement
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)
    # diagonal ~= 1 for RBF (fp32 self-distance cancellation at small width)
    np.testing.assert_allclose(np.diag(np.asarray(out)), 1.0, atol=2e-4)


@pytest.mark.parametrize("block_n", [128, 256])
@pytest.mark.parametrize("block_m", [128, 256])
def test_rbf_gram_block_shapes(block_n, block_m):
    rng = np.random.default_rng(2)
    x = rng.standard_normal((block_n + 17, 5)).astype(np.float32)
    y = rng.standard_normal((block_m + 3, 5)).astype(np.float32)
    out = rbf_gram(x, y, 1.0, block_n=block_n, block_m=block_m, interpret=True)
    ref = rbf_gram_ref(jnp.asarray(x), jnp.asarray(y), 1.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("n", [16, 500, 512, 1025])
@pytest.mark.parametrize("m", [4, 100, 128])
def test_centered_gram_shape_sweep(n, m):
    rng = np.random.default_rng(n + m)
    lam = rng.standard_normal((n, m)).astype(np.float32)
    out = centered_gram(lam, interpret=True)
    ref = centered_gram_ref(jnp.asarray(lam))
    assert out.shape == (m, m)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=5e-3 * np.sqrt(n)
    )


def test_centered_gram_nonzero_mean():
    """Fused centering must remove a large common offset."""
    rng = np.random.default_rng(3)
    lam = (rng.standard_normal((512, 32)) + 50.0).astype(np.float32)
    out = centered_gram(lam, interpret=True)
    ref = centered_gram_ref(jnp.asarray(lam, jnp.float64))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=1e-1)


# ---------------------------------------------------------------------------
# fused fold-Gram strip kernel (the batched frontier engine's block stage)
# ---------------------------------------------------------------------------


def _strip_inputs(seed, q, n0, ma, mb, sa=3, sb=4, n_pairs=6):
    rng = np.random.default_rng(seed)
    n_eff = q * n0
    bank_a = jnp.asarray(rng.standard_normal((sa, n_eff, ma)))
    bank_b = jnp.asarray(rng.standard_normal((sb, n_eff, mb)))
    ia = rng.integers(0, sa, size=n_pairs).astype(np.int32)
    ib = rng.integers(0, sb, size=n_pairs).astype(np.int32)
    return bank_a, bank_b, ia, ib


@pytest.mark.parametrize(
    "ma,mb", [(8, 8), (16, 48), (96, 8), (33, 7), (1, 96)]
)
@pytest.mark.parametrize("q,n0", [(2, 64), (10, 37)])
def test_fold_gram_strip_matches_ref(ma, mb, q, n0):
    """Fused strip kernel (interpret mode) == gather-then-einsum oracle
    across bucket-ladder widths and ragged/odd shapes (n0 not a block
    multiple exercises the zero-row fold padding)."""
    bank_a, bank_b, ia, ib = _strip_inputs(q * 100 + ma + mb, q, n0, ma, mb)
    ref = fold_gram_strip_ref(bank_a, bank_b, ia, ib, q)
    got = fold_gram_strip(
        bank_a, bank_b, ia, ib, q, use_pallas=True, interpret=True
    )
    assert got.shape == (len(ia), q, ma, mb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-12)


def test_fold_gram_strip_jnp_dispatch_matches_pallas():
    """The non-TPU dispatch (single-jit gather+einsum) and the Pallas
    interpret path agree with the oracle bit-for-bit shapes."""
    bank_a, bank_b, ia, ib = _strip_inputs(11, 5, 40, 24, 16)
    ref = fold_gram_strip_ref(bank_a, bank_b, ia, ib, 5)
    jnp_out = fold_gram_strip(bank_a, bank_b, ia, ib, 5, use_pallas=False)
    pal_out = fold_gram_strip(
        bank_a, bank_b, ia, ib, 5, use_pallas=True, interpret=True
    )
    np.testing.assert_allclose(np.asarray(jnp_out), np.asarray(ref), atol=1e-12)
    np.testing.assert_allclose(np.asarray(pal_out), np.asarray(ref), atol=1e-12)


def test_fold_gram_strip_pow2_trimmed_ranks():
    """Live-rank trimming invariant: banks whose columns beyond m_eff are
    exactly zero give identical Grams whether contracted at the padded
    width or sliced to a pow2-trimmed width (the engine's bucketing)."""
    rng = np.random.default_rng(3)
    q, n0, m_pad, m_live = 4, 32, 24, 5
    n_eff = q * n0
    live = rng.standard_normal((2, n_eff, m_live))
    bank = jnp.asarray(
        np.concatenate([live, np.zeros((2, n_eff, m_pad - m_live))], axis=-1)
    )
    ia = np.array([0, 1, 1], np.int32)
    full = fold_gram_strip(bank, bank, ia, ia, q, use_pallas=True, interpret=True)
    trimmed = fold_gram_strip(
        bank[:, :, :8], bank[:, :, :8], ia, ia, q,
        use_pallas=True, interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(full)[:, :, :8, :8], np.asarray(trimmed), atol=1e-12
    )
    assert np.all(np.asarray(full)[:, :, m_live:, :] == 0.0)


def test_fold_gram_strip_empty_rank_edge():
    """|Z|=0 edge: a zero-width factor side yields an empty block without
    touching the kernel (and an empty pair list yields an empty batch)."""
    bank_a, bank_b, ia, ib = _strip_inputs(0, 3, 16, 7, 5)
    empty_b = bank_b[:, :, :0]
    out = fold_gram_strip(bank_a, empty_b, ia, ib, 3, use_pallas=True, interpret=True)
    assert out.shape == (len(ia), 3, 7, 0)
    out2 = fold_gram_strip(
        bank_a, bank_b, ia[:0], ib[:0], 3, use_pallas=True, interpret=True
    )
    assert out2.shape == (0, 3, 7, 5)


def _banked_inputs(seed, q, n0, ma, mb, n_slots=8, n_pairs=4):
    bank_a, bank_b, ia, ib = _strip_inputs(seed, q, n0, ma, mb, n_pairs=n_pairs)
    rng = np.random.default_rng(seed + 1)
    out_bank = jnp.asarray(rng.standard_normal((n_slots, q, ma, mb)))
    # distinct real slots, skipping the reserved zero/scratch pair
    slots = np.arange(2, 2 + n_pairs, dtype=np.int32)
    return bank_a, bank_b, ia, ib, out_bank, slots


@pytest.mark.parametrize("ma,mb", [(8, 8), (16, 48), (33, 7)])
@pytest.mark.parametrize("q,n0", [(2, 64), (5, 37)])
def test_fold_gram_strip_banked_matches_ref(ma, mb, q, n0):
    """The fused strip+scatter (both dispatches) == compute-then-assign
    oracle: named slots get their Gram blocks, every other slot of the
    pre-filled bank is preserved bit-for-bit."""
    bank_a, bank_b, ia, ib, out_bank, slots = _banked_inputs(
        q * 100 + ma + mb, q, n0, ma, mb
    )
    # the banked op consumes its out_bank (in-place donation/aliasing):
    # snapshot the host copy first and hand each call its own buffer
    out_np = np.asarray(out_bank)
    ref = fold_gram_strip_banked_ref(bank_a, bank_b, ia, ib, out_np, slots, q)
    got_j = fold_gram_strip_banked(
        bank_a, bank_b, ia, ib, jnp.asarray(out_np), slots, q, use_pallas=False
    )
    got_p = fold_gram_strip_banked(
        bank_a, bank_b, ia, ib, jnp.asarray(out_np), slots, q,
        use_pallas=True, interpret=True,
    )
    untouched = [s for s in range(out_np.shape[0]) if s not in set(slots)]
    for got in (np.asarray(got_j), np.asarray(got_p)):
        np.testing.assert_allclose(got, ref, atol=1e-12)
        np.testing.assert_array_equal(got[untouched], out_np[untouched])


def test_fold_gram_strip_banked_jnp_is_bitwise_vs_unbanked():
    """On the non-TPU dispatch the banked scatter must be pure data
    movement: bank rows carry the exact bits of the unbanked strip — the
    invariant the device-resident engine's bitwise-vs-host guarantee
    rests on."""
    bank_a, bank_b, ia, ib, out_bank, slots = _banked_inputs(17, 4, 50, 24, 16)
    plain = fold_gram_strip(bank_a, bank_b, ia, ib, 4, use_pallas=False)
    banked = fold_gram_strip_banked(
        bank_a, bank_b, ia, ib, out_bank, slots, 4, use_pallas=False
    )
    np.testing.assert_array_equal(
        np.asarray(banked)[slots], np.asarray(plain)
    )


def test_fold_gram_strip_banked_scratch_slot_padding():
    """Chunk-padding rows may all target one write-only scratch slot
    (duplicate writes); real slots must come out exact regardless."""
    q, n0, m = 3, 20, 8
    bank_a, bank_b, ia, ib, out_bank, _ = _banked_inputs(23, q, n0, m, m)
    # rows 2..3 are padding duplicates of row 0 aimed at scratch slot 1
    ia = np.array([ia[0], ia[1], ia[0], ia[0]], np.int32)
    ib = np.array([ib[0], ib[1], ib[0], ib[0]], np.int32)
    slots = np.array([4, 5, 1, 1], np.int32)
    out_np = np.asarray(out_bank)  # snapshot: out_bank is consumed per call
    ref = fold_gram_strip_ref(bank_a, bank_b, ia[:2], ib[:2], q)
    for kw in (dict(use_pallas=False), dict(use_pallas=True, interpret=True)):
        got = np.asarray(
            fold_gram_strip_banked(
                bank_a, bank_b, ia, ib, jnp.asarray(out_np), slots, q, **kw
            )
        )
        np.testing.assert_allclose(got[[4, 5]], np.asarray(ref), atol=1e-12)
        np.testing.assert_array_equal(got[0], out_np[0])


def test_fold_gram_strip_banked_degenerate_edges():
    """Zero-width factors and empty pair lists return the bank untouched."""
    bank_a, bank_b, ia, ib, out_bank, slots = _banked_inputs(29, 3, 16, 7, 5)
    out = fold_gram_strip_banked(
        bank_a, bank_b[:, :, :0], ia, ib, out_bank[:, :, :, :0], slots, 3
    )
    assert out.shape == (out_bank.shape[0], 3, 7, 0)
    out2 = fold_gram_strip_banked(
        bank_a, bank_b, ia[:0], ib[:0], out_bank, slots[:0], 3
    )
    np.testing.assert_array_equal(np.asarray(out2), np.asarray(out_bank))


def test_fold_gram_blocks_identity_gather():
    """The fold-blocked (shard_map) entry point: leading batch dims
    collapse onto the strip kernel's candidate axis with an identity
    gather; einsum dispatch and Pallas interpret agree."""
    rng = np.random.default_rng(9)
    b, q, n0, ma, mb = 3, 5, 24, 12, 9
    fa = jnp.asarray(rng.standard_normal((b, q, n0, ma)))
    fb = jnp.asarray(rng.standard_normal((b, q, n0, mb)))
    ref = jnp.einsum("bqni,bqnj->bqij", fa, fb)
    got_e = fold_gram_blocks(fa, fb, use_pallas=False)
    got_p = fold_gram_blocks(fa, fb, use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got_e), np.asarray(ref), atol=1e-12)
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(ref), atol=1e-12)


if given is not None:

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(2, 300),
        m=st.integers(1, 40),
        scale=st.floats(0.1, 10.0),
    )
    def test_centered_gram_property(n, m, scale):
        """PSD + row-shift invariance: C(lam + c) == C(lam), C is PSD."""
        rng = np.random.default_rng(n * 41 + m)
        lam = (scale * rng.standard_normal((n, m))).astype(np.float32)
        out = np.asarray(centered_gram(lam, interpret=True))
        shifted = np.asarray(centered_gram(lam + 123.0, interpret=True))
        np.testing.assert_allclose(
            out, shifted, atol=2e-2 * scale * scale * np.sqrt(n) + 1e-2
        )
        w = np.linalg.eigvalsh(out.astype(np.float64) + out.astype(np.float64).T) / 2
        assert w.min() > -1e-2 * max(1.0, abs(w).max())

else:  # pragma: no cover

    @pytest.mark.skip(reason="hypothesis not installed (requirements-dev.txt)")
    def test_centered_gram_property():
        pass
