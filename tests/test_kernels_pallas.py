"""Per-kernel validation: shape/dtype sweeps, Pallas (interpret) vs ref.py.

The kernels target TPU; on this CPU container they execute the kernel body
in interpret mode — identical math, same BlockSpec tiling/padding paths.
"""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.kernels.ops import centered_gram, rbf_gram
from repro.kernels.ref import centered_gram_ref, rbf_gram_ref


@pytest.mark.parametrize("n", [7, 128, 300, 513])
@pytest.mark.parametrize("m", [1, 100, 128, 257])
@pytest.mark.parametrize("d", [1, 3, 128, 130])
def test_rbf_gram_shape_sweep(n, m, d):
    rng = np.random.default_rng(n * 1000 + m * 10 + d)
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.standard_normal((m, d)).astype(np.float32)
    width = 1.5
    out = rbf_gram(x, y, width, interpret=True)
    ref = rbf_gram_ref(jnp.asarray(x), jnp.asarray(y), width)
    assert out.shape == (n, m)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_rbf_gram_dtypes(dtype):
    rng = np.random.default_rng(0)
    x = rng.standard_normal((64, 4)).astype(dtype)
    y = rng.standard_normal((32, 4)).astype(dtype)
    out = rbf_gram(x, y, 2.0, interpret=True)
    ref = rbf_gram_ref(jnp.asarray(x, jnp.float64), jnp.asarray(y, jnp.float64), 2.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("width", [0.1, 1.0, 10.0])
def test_rbf_gram_width(width):
    rng = np.random.default_rng(1)
    x = rng.standard_normal((50, 2)).astype(np.float32)
    out = rbf_gram(x, x, width, interpret=True)
    ref = rbf_gram_ref(jnp.asarray(x), jnp.asarray(x), width)
    # pre-scaled-coordinate path vs post-divide ref: fp32 agreement
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)
    # diagonal ~= 1 for RBF (fp32 self-distance cancellation at small width)
    np.testing.assert_allclose(np.diag(np.asarray(out)), 1.0, atol=2e-4)


@pytest.mark.parametrize("block_n", [128, 256])
@pytest.mark.parametrize("block_m", [128, 256])
def test_rbf_gram_block_shapes(block_n, block_m):
    rng = np.random.default_rng(2)
    x = rng.standard_normal((block_n + 17, 5)).astype(np.float32)
    y = rng.standard_normal((block_m + 3, 5)).astype(np.float32)
    out = rbf_gram(x, y, 1.0, block_n=block_n, block_m=block_m, interpret=True)
    ref = rbf_gram_ref(jnp.asarray(x), jnp.asarray(y), 1.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("n", [16, 500, 512, 1025])
@pytest.mark.parametrize("m", [4, 100, 128])
def test_centered_gram_shape_sweep(n, m):
    rng = np.random.default_rng(n + m)
    lam = rng.standard_normal((n, m)).astype(np.float32)
    out = centered_gram(lam, interpret=True)
    ref = centered_gram_ref(jnp.asarray(lam))
    assert out.shape == (m, m)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=5e-3 * np.sqrt(n)
    )


def test_centered_gram_nonzero_mean():
    """Fused centering must remove a large common offset."""
    rng = np.random.default_rng(3)
    lam = (rng.standard_normal((512, 32)) + 50.0).astype(np.float32)
    out = centered_gram(lam, interpret=True)
    ref = centered_gram_ref(jnp.asarray(lam, jnp.float64))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=1e-1)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 300),
    m=st.integers(1, 40),
    scale=st.floats(0.1, 10.0),
)
def test_centered_gram_property(n, m, scale):
    """PSD + row-shift invariance: C(lam + c) == C(lam), C is PSD."""
    rng = np.random.default_rng(n * 41 + m)
    lam = (scale * rng.standard_normal((n, m))).astype(np.float32)
    out = np.asarray(centered_gram(lam, interpret=True))
    shifted = np.asarray(centered_gram(lam + 123.0, interpret=True))
    np.testing.assert_allclose(out, shifted, atol=2e-2 * scale * scale * np.sqrt(n) + 1e-2)
    w = np.linalg.eigvalsh(out.astype(np.float64) + out.astype(np.float64).T) / 2
    assert w.min() > -1e-2 * max(1.0, abs(w).max())
