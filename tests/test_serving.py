"""Multi-tenant serving: isolation, admission, shedding, degradation.

The acceptance bar for `repro.serving` (tentpole of PR 7): N >= 4
concurrent tenants over ONE shared `FeatureBank`, with an active
`FaultPlan` (stalled tenant, mid-request kill, bank-contention storm,
eviction storm) — and every *surviving* tenant's CPDAG / trace / score
bitwise-equal to its solo uninterrupted run, zero duplicate factor
builds for identical (vars_key, fingerprint) requests, and every failed
request rejected with a structured error instead of wedging the queue.

Thread hygiene: pytest.ini sets ``faulthandler_timeout`` so a deadlock
in the lock-striped bank/cache dumps every thread's stack instead of
hanging CI silently.
"""

import os
import threading
import time

import numpy as np
import pytest

from repro.core.api import DiscoverySession
from repro.core.runstate import FaultPlan
from repro.core.score_common import GramBlockCache, ScoreConfig
from repro.core.spec import EngineOptions
from repro.features.bank import FeatureBank
from repro.serving import (
    DeadlineExceeded,
    DiscoveryRequest,
    InjectedFault,
    RequestShed,
    ServingOptions,
    SessionCancelled,
    SessionManager,
    structured_error,
)

N, D = 120, 4


def _chain_data(n=N, d=D, seed=0):
    rng = np.random.default_rng(seed)
    cols = [rng.standard_normal(n)]
    for _ in range(d - 1):
        cols.append(np.tanh(cols[-1]) + 0.4 * rng.standard_normal(n))
    return np.stack(cols, axis=1)


DATA = _chain_data()


@pytest.fixture(scope="module")
def solo():
    """Uninterrupted single-session reference runs, one per config seed.
    Also warms the jit caches so the concurrent tests measure contention,
    not compilation."""
    out = {}
    for seed in (0, 1):
        sess = DiscoverySession(DATA, config=ScoreConfig(seed=seed))
        out[seed] = sess.run()
    return out


def _assert_bitwise(res, ref, label):
    assert np.array_equal(res.cpdag, ref.cpdag), f"{label}: CPDAG differs"
    assert [tuple(s) for s in res.trace] == [
        tuple(s) for s in ref.trace
    ], f"{label}: trace differs"
    assert res.score == ref.score, f"{label}: score differs"


# -- single-flight build dedup (bank unit level) --------------------------


def test_single_flight_one_build_many_waiters():
    bank = FeatureBank()
    started = threading.Event()
    release = threading.Event()
    builds = []

    def build_fn():
        builds.append(threading.get_ident())
        started.set()
        release.wait(timeout=30)
        return ("factor", 42)

    results = [None] * 6
    errs = []

    def worker(i):
        try:
            results[i] = bank.get_or_build((0, 1), ("fp",), build_fn)
        except BaseException as exc:  # pragma: no cover - diagnostic
            errs.append(exc)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    assert started.wait(timeout=30)
    # followers are parked on the in-flight slot; releasing the single
    # leader releases everyone with the SAME build
    release.set()
    for t in threads:
        t.join(timeout=30)
    assert errs == []
    assert len(builds) == 1, "single-flight must collapse to one build"
    assert all(r == ("factor", 42) for r in results)
    assert bank.stats["builds"] == 1
    assert bank.single_flight_waits >= 1


def test_single_flight_leader_failure_promotes_follower():
    bank = FeatureBank()
    first_entered = threading.Event()
    let_first_fail = threading.Event()
    calls = []

    def flaky_build():
        calls.append(None)
        if len(calls) == 1:
            first_entered.set()
            let_first_fail.wait(timeout=30)
            raise RuntimeError("leader died mid-build")
        return "ok"

    out = {}

    def leader():
        with pytest.raises(RuntimeError, match="leader died"):
            bank.get_or_build((0,), ("fp",), flaky_build)

    def follower():
        out["res"] = bank.get_or_build((0,), ("fp",), flaky_build)

    t1 = threading.Thread(target=leader)
    t1.start()
    assert first_entered.wait(timeout=30)
    t2 = threading.Thread(target=follower)
    t2.start()
    time.sleep(0.05)  # let the follower park on the in-flight slot
    let_first_fail.set()
    t1.join(timeout=30)
    t2.join(timeout=30)
    # the follower observed the leader's failure and retried as the new
    # leader rather than caching the exception
    assert out["res"] == "ok"
    assert bank.stats["builds"] == 1  # failed builds don't count


def test_gram_cache_concurrent_put_get_counters_consistent():
    cache = GramBlockCache(max_entries=8, device_bank_mb=None)

    def worker(tid):
        for i in range(200):
            key = ("a", (tid + i) % 12)
            got = cache.get(key)
            if got is None:
                cache.put(key, np.full((2, 2), tid))

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    s = cache.stats
    # counters must reconcile exactly under contention (no lost updates)
    assert s["hits"] + s["misses"] == 4 * 200
    assert len(cache) <= 8
    assert s["evictions"] >= 0


# -- concurrent tenants: sharing + bitwise equality -----------------------


def test_identical_tenants_share_everything_bitwise(solo):
    serving = ServingOptions(max_concurrent=4, queue_limit=16)
    with SessionManager(DATA, serving=serving) as mgr:
        tickets = [
            mgr.submit(DiscoveryRequest(tenant=f"t{i}", seed=0))
            for i in range(4)
        ]
        results = [t.result(timeout=600) for t in tickets]
    for i, res in enumerate(results):
        _assert_bitwise(res, solo[0], f"tenant t{i}")
    bank = mgr.feature_bank.stats
    # zero duplicate builds: every (vars_key, fingerprint) built at most
    # once across all four tenants
    assert bank["builds"] == bank["entries"]
    tel = mgr.telemetry()
    assert tel["stats"]["completed"] == 4
    assert tel["latency"]["n"] == 4 and tel["latency"]["p95"] is not None


def test_mixed_seed_tenants_are_fingerprint_isolated(solo):
    """Different per-request seeds change the build fingerprints: the
    shared bank keeps the factor families apart and each tenant matches
    its own solo run bit for bit."""
    serving = ServingOptions(max_concurrent=4, queue_limit=16)
    seeds = (0, 1, 0, 1)
    with SessionManager(DATA, serving=serving) as mgr:
        tickets = [
            mgr.submit(DiscoveryRequest(tenant=f"t{i}-seed{seed}", seed=seed))
            for i, seed in enumerate(seeds)
        ]
        for seed, ticket in zip(seeds, tickets):
            _assert_bitwise(
                ticket.result(timeout=600), solo[seed], ticket.tenant
            )
    # two distinct workloads -> two gram caches, no cross-talk
    assert len(mgr._gram_caches) == 2
    bank = mgr.feature_bank.stats
    assert bank["builds"] == bank["entries"]


# -- THE isolation proof: fault storm over one shared bank ----------------


def test_fault_storm_isolation_bitwise(solo):
    """Five tenants, one shared FeatureBank, four active fault plans:

    * ``stall``  — stalls 10s mid-run with a 1.5s deadline -> must fail
      with a structured `DeadlineExceeded` at a sweep seam;
    * ``kill``   — mid-request injected kill -> `InjectedFault`;
    * ``storm``  — bank-contention storm (every factor build delayed, on
      the same fingerprints the clean tenant needs) -> must survive;
    * ``evict``  — eviction storm (spills the shared device Gram tier
      every sweep, under the clean tenant's feet) -> must survive;
    * ``clean``  — no faults -> must survive.

    Every surviving tenant's CPDAG / trace / score is bitwise-equal to
    its solo uninterrupted run, and no (vars_key, fingerprint) was built
    twice."""
    serving = ServingOptions(max_concurrent=5, queue_limit=16)
    with SessionManager(DATA, serving=serving) as mgr:
        t_clean = mgr.submit(DiscoveryRequest(tenant="clean", seed=0))
        t_storm = mgr.submit(
            DiscoveryRequest(
                tenant="storm", seed=0,
                fault_plan=FaultPlan(build_delay_s=0.05),
            )
        )
        t_evict = mgr.submit(
            DiscoveryRequest(
                tenant="evict", seed=0,
                fault_plan=FaultPlan(evict_storm=True),
            )
        )
        t_kill = mgr.submit(
            DiscoveryRequest(
                tenant="kill", seed=1,
                fault_plan=FaultPlan(kill_at_sweep=1),
            )
        )
        t_stall = mgr.submit(
            DiscoveryRequest(
                tenant="stall", seed=1, deadline_s=1.5,
                fault_plan=FaultPlan(stall_sweep=(1, 10.0)),
            )
        )

        with pytest.raises(InjectedFault):
            t_kill.result(timeout=600)
        with pytest.raises(DeadlineExceeded) as exc_info:
            t_stall.result(timeout=600)
        # survivors: bitwise-equal to solo in spite of the storm
        _assert_bitwise(t_clean.result(timeout=600), solo[0], "clean")
        _assert_bitwise(t_storm.result(timeout=600), solo[0], "storm")
        _assert_bitwise(t_evict.result(timeout=600), solo[0], "evict")

    err = exc_info.value.to_dict()
    assert err["error"] == "deadline_exceeded"
    assert err["tenant"] == "stall"
    assert err["deadline_s"] == pytest.approx(1.5)
    assert t_stall.error == err  # the ticket carries the same payload
    assert t_kill.error["error"] == "injected_fault"

    bank = mgr.feature_bank.stats
    assert bank["builds"] == bank["entries"], "a fault caused a duplicate build"
    tel = mgr.telemetry()
    assert tel["stats"]["completed"] == 3
    assert tel["stats"]["deadline_exceeded"] == 1
    assert tel["stats"]["failed"] == 1  # the injected kill
    # the eviction storm actually evicted (the fault was live, not inert)
    spills = sum(c["spills"] for c in tel["gram_caches"].values())
    assert spills > 0


# -- admission: shedding, deadlines, cancellation -------------------------


def test_queue_full_sheds_with_structured_retry_after():
    serving = ServingOptions(max_concurrent=1, queue_limit=1)
    with SessionManager(DATA, serving=serving) as mgr:
        hog = mgr.submit(
            DiscoveryRequest(
                tenant="hog", seed=0,
                fault_plan=FaultPlan(stall_sweep=(0, 2.0)),
            )
        )
        time.sleep(0.3)  # let the hog occupy the single worker
        queued = mgr.submit(DiscoveryRequest(tenant="queued", seed=0))
        with pytest.raises(RequestShed) as exc_info:
            mgr.submit(DiscoveryRequest(tenant="unlucky", seed=0))
        err = exc_info.value.to_dict()
        assert err["error"] == "shed"
        assert err["tenant"] == "unlucky"
        assert err["retry_after_s"] >= serving.retry_after_s
        assert "queue full" in err["reason"]
        # the shed request never perturbed the admitted ones
        hog.result(timeout=600)
        queued.result(timeout=600)
    tel = mgr.telemetry()
    assert tel["stats"]["shed"] == 1
    assert tel["stats"]["completed"] == 2


def test_deadline_expired_in_queue_sheds_at_first_seam():
    """deadline_at is stamped at *submission*: a request whose budget
    burned in the queue fails at its first seam without scoring."""
    serving = ServingOptions(max_concurrent=1, queue_limit=4)
    with SessionManager(DATA, serving=serving) as mgr:
        hog = mgr.submit(
            DiscoveryRequest(
                tenant="hog", seed=0,
                fault_plan=FaultPlan(stall_sweep=(0, 1.5)),
            )
        )
        doomed = mgr.submit(
            DiscoveryRequest(tenant="doomed", seed=0, deadline_s=0.5)
        )
        with pytest.raises(DeadlineExceeded) as exc_info:
            doomed.result(timeout=600)
        hog.result(timeout=600)
    err = exc_info.value.to_dict()
    assert err["error"] == "deadline_exceeded"
    assert err["sweep"] == 0, "must shed before any sweep completed"
    assert mgr.stats["deadline_exceeded"] == 1


def test_cancellation_mid_request():
    serving = ServingOptions(max_concurrent=1, queue_limit=4)
    with SessionManager(DATA, serving=serving) as mgr:
        ticket = mgr.submit(
            DiscoveryRequest(
                tenant="goner", seed=0,
                fault_plan=FaultPlan(stall_sweep=(0, 1.0)),
            )
        )
        ticket.cancel()  # mid-request kill: flips the session's event
        with pytest.raises(SessionCancelled) as exc_info:
            ticket.result(timeout=600)
    assert exc_info.value.to_dict() == {
        "error": "cancelled",
        "tenant": "goner",
        "sweep": exc_info.value.sweep,
    }
    assert mgr.stats["cancelled"] == 1


def test_shutdown_sheds_new_requests():
    mgr = SessionManager(DATA, serving=ServingOptions())
    mgr.shutdown()
    with pytest.raises(RequestShed, match="shut down"):
        mgr.submit(DiscoveryRequest(tenant="late"))


def test_structured_error_shapes():
    assert structured_error(ValueError("boom")) == {
        "error": "internal", "type": "ValueError", "detail": "boom",
    }
    assert structured_error(InjectedFault("kill"))["error"] == "injected_fault"
    shed = RequestShed("t", "queue full (x)", 2.0)
    assert shed.to_dict()["retry_after_s"] == 2.0


# -- memory-pressure degradation ladder -----------------------------------


def test_degradation_ladder_rungs(solo):
    """Drive the shared footprint through the three pressure rungs and
    check each one: halved device tier, full evict-to-host, and backend
    reroute — with the rung counters surfaced in the session sweep log
    and every degraded run still returning a valid result."""
    base = SessionManager(DATA, serving=ServingOptions())
    try:
        base.run(DiscoveryRequest(tenant="warm", seed=0))
    finally:
        base.shutdown()
    shared_bank = base.feature_bank
    # at a fresh manager's admission time the measurable footprint is the
    # shared bank's factor bytes (its own gram caches don't exist yet)
    usage_mb = shared_bank.nbytes / 2**20
    assert usage_mb > 0

    def degraded_run(budget_mb):
        mgr = SessionManager(
            DATA,
            serving=ServingOptions(device_budget_mb=budget_mb),
            feature_bank=shared_bank,
        )
        try:
            ticket = mgr.submit(DiscoveryRequest(tenant="t", seed=0))
            res = ticket.result(timeout=600)
            return mgr, ticket, res
        finally:
            mgr.shutdown()

    # rung 1: usage in (0.5, 0.75] of budget -> shrink device tier
    mgr, ticket, res = degraded_run(usage_mb / 0.6)
    _assert_bitwise(res, solo[0], "rung1")
    assert mgr.degradations["shrink_device"] == 1
    serving_recs = [r["serving"] for r in ticket.session.sweep_log if "serving" in r]
    assert serving_recs and serving_recs[-1]["pressure_rung"] == 1
    assert serving_recs[-1]["shrink_device"] == 1

    # rung 2: usage in (0.75, 1.0] -> evict the device tier entirely
    mgr, ticket, res = degraded_run(usage_mb / 0.8)
    _assert_bitwise(res, solo[0], "rung2")
    assert mgr.degradations["evict_to_host"] == 1
    assert ticket.session.options.device_bank_mb == 0
    assert not ticket.session.scorer.gram_cache.device_enabled

    # rung 3: over budget -> also reroute new builds to the cheap backend
    mgr, ticket, res = degraded_run(usage_mb * 0.5)
    assert mgr.degradations["reroute_backend"] == 1
    policy = ticket.session.scorer.policy
    assert policy.continuous.backend == "rff"
    # rerouted factors live under their own fingerprints: approximate
    # scores, but a structurally valid CPDAG of the right shape
    assert res.cpdag.shape == (D, D)
    serving_recs = [r["serving"] for r in ticket.session.sweep_log if "serving" in r]
    assert serving_recs[-1]["pressure_rung"] == 3
    assert serving_recs[-1]["reroute_backend"] == 1


# -- checkpoint/resume under the session manager (satellite) --------------


def test_concurrent_checkpoint_namespaces_do_not_clobber(solo, tmp_path):
    """Two concurrent checkpointing tenants share one checkpoint_root:
    each writes its own RunState under its own tenant namespace, and a
    later ``resume="auto"`` request restores *its own* tenant's state —
    proven by seed-distinct fingerprints (a cross-tenant restore would be
    refused as a mixed factor family) and bitwise-equal final results."""
    root = str(tmp_path / "ckpts")
    serving = ServingOptions(
        max_concurrent=2, queue_limit=8, checkpoint_root=root
    )
    with SessionManager(DATA, serving=serving) as mgr:
        # phase 1: both tenants killed mid-run, checkpoints committed
        ta = mgr.submit(
            DiscoveryRequest(
                tenant="alice", seed=0, checkpoint=True,
                fault_plan=FaultPlan(kill_at_sweep=2),
            )
        )
        tb = mgr.submit(
            DiscoveryRequest(
                tenant="bob", seed=1, checkpoint=True,
                fault_plan=FaultPlan(kill_at_sweep=2),
            )
        )
        with pytest.raises(InjectedFault):
            ta.result(timeout=600)
        with pytest.raises(InjectedFault):
            tb.result(timeout=600)
        assert os.path.isdir(os.path.join(root, "alice"))
        assert os.path.isdir(os.path.join(root, "bob"))

        # phase 2: concurrent resumes restore the right namespace each
        ra = mgr.submit(
            DiscoveryRequest(
                tenant="alice", seed=0, checkpoint=True, resume="auto"
            )
        )
        rb = mgr.submit(
            DiscoveryRequest(
                tenant="bob", seed=1, checkpoint=True, resume="auto"
            )
        )
        res_a = ra.result(timeout=600)
        res_b = rb.result(timeout=600)
        assert ra.session.resumed_from is not None
        assert rb.session.resumed_from is not None
    _assert_bitwise(res_a, solo[0], "alice resumed")
    _assert_bitwise(res_b, solo[1], "bob resumed")


def test_checkpoint_without_root_is_refused():
    with SessionManager(DATA, serving=ServingOptions()) as mgr:
        ticket = mgr.submit(DiscoveryRequest(tenant="t", checkpoint=True))
        with pytest.raises(ValueError, match="checkpoint_root"):
            ticket.result(timeout=600)


# -- session-level seam checks (no manager) -------------------------------


def test_engine_options_deadline_via_plain_session():
    """EngineOptions(deadline_s=...) works without a manager: the clock
    starts at the first sweep seam and trips at a later one."""
    sess = DiscoverySession(
        DATA,
        options=EngineOptions(deadline_s=0.5),
        config=ScoreConfig(seed=0),
        fault_plan=FaultPlan(stall_sweep=(0, 1.0)),
    )
    with pytest.raises(DeadlineExceeded):
        sess.run()


def test_engine_options_deadline_validation():
    with pytest.raises(ValueError, match="deadline_s"):
        EngineOptions(deadline_s=0)
    with pytest.raises(ValueError, match="deadline_s"):
        EngineOptions(deadline_s=float("nan"))
    assert EngineOptions(deadline_s=None).deadline_s is None


# -- constraint telemetry across tenants (PR 9 satellite) -----------------


def test_two_tenant_skeleton_shared_builds():
    """Two tenants under restrict="skeleton" with identical workload
    fingerprints: the constraint phase's factor fetches ride the shared
    FeatureBank (builds == entries — zero duplicate builds across BOTH
    tenants' CI tests and score sweeps), and the manager aggregates
    per-session constraint telemetry."""
    with SessionManager(
        DATA,
        options=EngineOptions(restrict="skeleton"),
        serving=ServingOptions(max_concurrent=2),
    ) as mgr:
        ta = mgr.submit(DiscoveryRequest(tenant="alice"))
        tb = mgr.submit(DiscoveryRequest(tenant="bob"))
        res_a = ta.result(timeout=600)
        res_b = tb.result(timeout=600)
        assert np.array_equal(res_a.cpdag, res_b.cpdag)
        assert ta.session.edge_mask is not None
        assert np.array_equal(
            ta.session.edge_mask.allowed, tb.session.edge_mask.allowed
        )
        bank = mgr.feature_bank.stats
        assert bank["builds"] == bank["entries"]
        tele = mgr.telemetry()["constraint"]
    assert tele["sessions"] == 2
    assert tele["ci_tests"] > 0
    assert tele["pruned_pairs"] == 2 * ta.session.edge_mask.pruned_pairs
    assert tele["skeleton_s"] > 0
