"""Make `repro` (src layout) and `benchmarks` importable however pytest is
invoked.  Does NOT set XLA flags — smoke tests must see 1 CPU device; the
dry-run machinery tests spawn subprocesses with their own XLA_FLAGS."""

import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (os.path.join(_ROOT, "src"), _ROOT):
    if p not in sys.path:
        sys.path.insert(0, p)


def _purge_stale_bytecode(root: str) -> None:
    """Tier-1 collection guard against the stale-bytecode hazard.

    A `__pycache__/*.pyc` whose source was edited (or deleted) can shadow
    the edit when filesystem mtime granularity or a checkout tool defeats
    CPython's mtime-based invalidation — tests then silently exercise old
    code.  Before anything under src/ is imported, drop every cached file
    that is orphaned or not strictly newer than its source."""
    for dirpath, dirnames, filenames in os.walk(root):
        if os.path.basename(dirpath) != "__pycache__":
            continue
        src_dir = os.path.dirname(dirpath)
        for fname in filenames:
            if not fname.endswith(".pyc"):
                continue
            src = os.path.join(src_dir, fname.split(".")[0] + ".py")
            pyc = os.path.join(dirpath, fname)
            try:
                if not os.path.exists(src) or os.path.getmtime(
                    src
                ) >= os.path.getmtime(pyc):
                    os.unlink(pyc)
            except OSError:  # concurrent cleanup / read-only checkout
                pass


# Everything importable in-process is guarded: the library (src/), the
# test modules themselves, and the benchmarks package (also on sys.path).
for _d in ("src", "tests", "benchmarks"):
    _purge_stale_bytecode(os.path.join(_ROOT, _d))
