"""Make `repro` (src layout) and `benchmarks` importable however pytest is
invoked.  Does NOT set XLA flags — smoke tests must see 1 CPU device; the
dry-run machinery tests spawn subprocesses with their own XLA_FLAGS."""

import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (os.path.join(_ROOT, "src"), _ROOT):
    if p not in sys.path:
        sys.path.insert(0, p)
