"""Property tests for the constraint subsystem (hypothesis).

The superset invariant the hybrid pipeline rests on: at a generous
alpha, `estimate_skeleton` keeps every true edge of a linear-Gaussian
SCM, so skeleton gating never severs an edge the score phase needs.
Fixed-seed spot checks of the same property live in
tests/test_constraint.py (`test_skeleton_superset_on_linear_gaussian`);
this module fuzzes the SCM seed.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.constraint import KernelCITest, estimate_skeleton
from repro.core.api import make_scorer
from repro.core.graph import random_dag, skeleton as graph_skeleton

from test_constraint import _linear_gaussian


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_skeleton_superset_property(seed):
    d = 6
    dag = random_dag(d, 0.3, np.random.default_rng(seed))
    data = _linear_gaussian(dag, n=500, seed=seed)
    ci = KernelCITest(make_scorer(data))
    mask, _ = estimate_skeleton(ci, d, alpha=0.25, max_cond=2)
    true_skel = graph_skeleton(dag)
    missing = [
        (x, y)
        for x, y in zip(*np.nonzero(true_skel))
        if not mask.allows(int(x), int(y))
    ]
    assert not missing, f"true edges pruned at generous alpha: {missing}"
