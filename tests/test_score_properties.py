"""Hypothesis property tests on the score's invariants."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core.score_common import ScoreConfig
from repro.core.score_lowrank import CVLRScorer


def _data(n=200, d=3, seed=0):
    rng = np.random.default_rng(seed)
    x0 = rng.standard_normal(n)
    x1 = np.tanh(x0) + 0.3 * rng.standard_normal(n)
    x2 = rng.standard_normal(n)
    return np.stack([x0, x1, x2], axis=1)


@settings(max_examples=10, deadline=None)
@given(scale=st.floats(0.1, 100.0), shift=st.floats(-50.0, 50.0))
def test_affine_invariance(scale, shift):
    """Column z-scoring makes the score invariant to affine rescaling of
    any variable (the kernel width heuristic sees identical data)."""
    x = _data(seed=1)
    cfg = ScoreConfig(seed=2)
    s_base = CVLRScorer(x, config=cfg).local_score(1, (0,))
    x2 = x.copy()
    x2[:, 0] = scale * x2[:, 0] + shift
    s_scaled = CVLRScorer(x2, config=cfg).local_score(1, (0,))
    assert abs(s_base - s_scaled) < 1e-5 * max(1.0, abs(s_base))


def test_determinism():
    x = _data(seed=3)
    cfg = ScoreConfig(seed=5)
    a = CVLRScorer(x, config=cfg).local_score(0, (1, 2))
    b = CVLRScorer(x, config=cfg).local_score(0, (1, 2))
    assert a == b


def test_constant_variable_is_finite():
    """A degenerate (constant) conditioning variable must not blow up."""
    x = _data(seed=4)
    x[:, 2] = 1.0
    sc = CVLRScorer(x, config=ScoreConfig(seed=0))
    s = sc.local_score(0, (2,))
    assert np.isfinite(s)
    # conditioning on a constant ~ conditioning on nothing
    s_empty = sc.local_score(0, ())
    assert abs(s - s_empty) < 0.05 * abs(s_empty)


def test_seed_changes_folds_not_conclusion():
    """Different fold seeds perturb the score slightly but preserve the
    parent-vs-no-parent ordering (local consistency in practice)."""
    x = _data(n=300, seed=6)
    for seed in (0, 1, 2):
        sc = CVLRScorer(x, config=ScoreConfig(seed=seed))
        assert sc.local_score(1, (0,)) > sc.local_score(1, ())


@settings(max_examples=8, deadline=None)
@given(perm_seed=st.integers(0, 100))
def test_parent_order_irrelevant(perm_seed):
    """S(X | Z) must not depend on the order the parent set is given."""
    rng = np.random.default_rng(perm_seed)
    x = _data(n=200, seed=7)
    sc = CVLRScorer(x, config=ScoreConfig(seed=1))
    pa = [0, 2]
    rng.shuffle(pa)
    a = sc.local_score(1, tuple(pa))
    sc2 = CVLRScorer(x, config=ScoreConfig(seed=1))
    b = sc2.local_score(1, (0, 2))
    assert abs(a - b) < 1e-9 * max(1.0, abs(b))


def test_more_pivots_never_hurt_much():
    """Score with m=50 vs m=100 pivots should agree closely on smooth data
    (ICL converges well before the budget)."""
    x = _data(n=250, seed=8)
    s50 = CVLRScorer(x, config=ScoreConfig(seed=3, m_max=50)).local_score(1, (0,))
    s100 = CVLRScorer(x, config=ScoreConfig(seed=3, m_max=100)).local_score(1, (0,))
    assert abs(s50 - s100) < 5e-3 * abs(s100)
