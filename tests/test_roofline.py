"""Roofline derivation units: model FLOPs, analytic memory, term math."""

import pytest

from benchmarks.roofline import (
    analytic_hbm_bytes_per_device,
    model_flops_per_device,
    roofline_row,
)

MESH = {"data": 16, "model": 16}


def test_model_flops_train_dense():
    # tinyllama: ~1.1B params, 6ND over 256 chips
    f = model_flops_per_device("tinyllama_1b", "train_4k", MESH)
    tokens = 4096 * 256
    assert 0.8 * 6 * 1.0e9 * tokens / 256 < f < 6 * 1.6e9 * tokens / 256


def test_model_flops_moe_uses_active_params():
    f_moe = model_flops_per_device("arctic_480b", "train_4k", MESH)
    # active ~17B not total ~482B
    tokens = 4096 * 256
    assert f_moe < 6 * 40e9 * tokens / 256, "MoE must count ACTIVE params"
    assert f_moe > 6 * 8e9 * tokens / 256


def test_decode_flops_tiny():
    f_train = model_flops_per_device("olmo_1b", "train_4k", MESH)
    f_dec = model_flops_per_device("olmo_1b", "decode_32k", MESH)
    assert f_dec < f_train / 1000  # one token vs 4096*256


def test_analytic_memory_orders():
    # decode reads params + cache; train moves much more (activations)
    m_train = analytic_hbm_bytes_per_device("olmo_1b", "train_4k", MESH)
    m_dec = analytic_hbm_bytes_per_device("olmo_1b", "decode_32k", MESH)
    assert m_train > m_dec
    assert m_dec > 2e9 / 256  # at least the sharded bf16 params


def test_roofline_row_terms():
    rec = {
        "status": "ok",
        "arch": "olmo_1b",
        "shape": "train_4k",
        "mesh": "single",
        "mesh_shape": MESH,
        "flops": 197e12,  # exactly 1 second of compute
        "bytes_accessed": 819e9,  # 1 second of (pre-fusion) memory
        "collectives": {
            "total_collective_bytes": 50e9 * 3,
            "all-reduce_count": 2,
            "all-to-all_count": 1,
        },
        "memory": {},
    }
    row = roofline_row(rec)
    assert abs(row["compute_s"] - 1.0) < 1e-9
    assert abs(row["memory_s"] - 1.0) < 1e-9
    assert abs(row["collective_s"] - 3.0) < 1e-9
    assert row["bottleneck"] == "collective"
    assert 0 < row["roofline_fraction"] <= 1.0


def test_roofline_row_error_passthrough():
    row = roofline_row({"status": "error", "arch": "x", "shape": "y"})
    assert row["bottleneck"] == "ERROR"
