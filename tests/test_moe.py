"""MoE dispatch backends must agree: einsum (Mesh-TF) vs gather/scatter."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.phi35_moe import reduced
from repro.models import layers as L
from repro.models.registry import build_model


def _setup(dispatch, dtype=jnp.float32, cap=4.0):
    cfg = dataclasses.replace(
        reduced(), moe_dispatch=dispatch, dtype=dtype, capacity_factor=cap
    )
    params, _ = L.moe_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.mark.parametrize("cap", [4.0, 1.0, 0.5])
def test_dispatch_backends_agree(cap):
    """With identical routing, both dispatch paths produce the same output
    (including capacity drops)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 16, 64)), jnp.float32)
    cfg_e, params = _setup("einsum", cap=cap)
    cfg_g = dataclasses.replace(cfg_e, moe_dispatch="gather")
    y_e, aux_e = L.moe_forward(params, x, cfg_e)
    y_g, aux_g = L.moe_forward(params, x, cfg_g)
    np.testing.assert_allclose(np.asarray(y_e), np.asarray(y_g), atol=2e-5)
    np.testing.assert_allclose(float(aux_e), float(aux_g), rtol=1e-6)


def test_gather_dispatch_grads_finite():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 16, 64)), jnp.float32)
    cfg, params = _setup("gather")

    def loss(p):
        y, aux = L.moe_forward(p, x, cfg)
        return jnp.sum(y * y) + aux

    grads = jax.grad(loss)(params)
    for g in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(g)))


def test_full_model_with_gather_dispatch():
    cfg = dataclasses.replace(reduced(), moe_dispatch="gather")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 200, (2, 32)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 200, (2, 32)), jnp.int32),
    }
    loss = jax.jit(model.loss)(params, batch)
    assert bool(jnp.isfinite(loss))


def test_capacity_drops_tokens():
    """At capacity_factor 0.25, most token-choices are dropped; output is a
    strict subset of the uncapped one (dropped tokens contribute zero)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((1, 32, 64)), jnp.float32)
    cfg_big, params = _setup("gather", cap=8.0)
    cfg_small = dataclasses.replace(cfg_big, capacity_factor=0.25)
    y_big, _ = L.moe_forward(params, x, cfg_big)
    y_small, _ = L.moe_forward(params, x, cfg_small)
    norm_big = float(jnp.linalg.norm(y_big))
    norm_small = float(jnp.linalg.norm(y_small))
    assert norm_small < norm_big  # dropped mass
    assert norm_small > 0
