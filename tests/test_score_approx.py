"""Paper Table 1 reproduction-in-miniature: CV-LR approximates CV with
relative error well under 0.5% at the default pivot budget."""

import numpy as np
import pytest

from repro.core.score_common import ScoreConfig
from repro.core.score_exact import CVScorer
from repro.core.score_lowrank import CVLRScorer


def _mechanism_data(n, d, seed, discrete=False):
    """Small SCM chain: x0 -> x1 -> x2 ... with nonlinear mechanisms."""
    rng = np.random.default_rng(seed)
    cols = [rng.standard_normal(n)]
    for j in range(1, d):
        base = np.tanh(cols[-1]) + 0.3 * np.sin(2.0 * cols[-1])
        cols.append(base + 0.3 * rng.standard_normal(n))
    x = np.stack(cols, axis=1)
    if discrete:
        x = np.floor(3 * (x - x.min(0)) / (np.ptp(x, 0) + 1e-9)).clip(0, 2)
    return x


@pytest.mark.parametrize("discrete", [False, True])
@pytest.mark.parametrize("parents", [(), (1,), (1, 2, 3)])
def test_relative_error_below_half_percent(discrete, parents):
    n = 300
    x = _mechanism_data(n, 5, seed=42, discrete=discrete)
    cfg = ScoreConfig(m_max=100, seed=7)
    disc = [discrete] * 5
    cv = CVScorer(x, discrete=disc, config=cfg)
    lr = CVLRScorer(x, discrete=disc, config=cfg)
    s_cv = cv.local_score(0, parents)
    s_lr = lr.local_score(0, parents)
    rel = abs(s_lr - s_cv) / abs(s_cv)
    assert rel < 5e-3, f"relative error {rel:.2e} exceeds 0.5%"


def test_discrete_path_is_numerically_exact():
    """Alg. 2 features => the LR score equals the exact score to ~1e-6 rel
    (paper Table 1 discrete rows: 'exact' agreement)."""
    x = _mechanism_data(400, 3, seed=3, discrete=True)
    cfg = ScoreConfig(seed=11)
    cv = CVScorer(x, discrete=[True] * 3, config=cfg)
    lr = CVLRScorer(x, discrete=[True] * 3, config=cfg)
    for i, pa in [(0, ()), (2, (0, 1)), (1, (0,))]:
        s_cv = cv.local_score(i, pa)
        s_lr = lr.local_score(i, pa)
        assert abs(s_lr - s_cv) / abs(s_cv) < 1e-6


def test_score_prefers_true_parent():
    """Local consistency smoke check: the score of x1 should improve when
    conditioning on its true parent x0, under both CV and CV-LR."""
    x = _mechanism_data(300, 2, seed=9)
    for cls in (CVScorer, CVLRScorer):
        sc = cls(x, config=ScoreConfig(seed=5))
        assert sc.local_score(1, (0,)) > sc.local_score(1, ())


def test_scorer_cache():
    x = _mechanism_data(200, 3, seed=1)
    sc = CVLRScorer(x, config=ScoreConfig(seed=0))
    a = sc.local_score(0, (1, 2))
    b = sc.local_score(0, (2, 1))  # order-insensitive key
    assert a == b and sc.cache_size == 1
