"""Graph-machinery tests: Meek closure, CPDAG conversion, PDAG extension."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import graph as g


def _dag_from_bits(d, bits, perm):
    a = np.zeros((d, d), dtype=np.int8)
    k = 0
    for i in range(d):
        for j in range(i + 1, d):
            if bits[k]:
                a[perm[i], perm[j]] = 1
            k += 1
    return a


def test_v_structure_is_kept():
    # x -> z <- y, x,y non-adjacent: CPDAG keeps both arrows
    a = np.zeros((3, 3), dtype=np.int8)
    a[0, 2] = 1
    a[1, 2] = 1
    c = g.dag_to_cpdag(a)
    assert g.has_dir(c, 0, 2) and g.has_dir(c, 1, 2)


def test_chain_becomes_undirected():
    # x -> y -> z: equivalence class is the undirected chain
    a = np.zeros((3, 3), dtype=np.int8)
    a[0, 1] = 1
    a[1, 2] = 1
    c = g.dag_to_cpdag(a)
    assert g.has_undir(c, 0, 1) and g.has_undir(c, 1, 2)


def test_pdag_to_dag_roundtrip_chain():
    c = np.zeros((3, 3), dtype=np.int8)
    c[0, 1] = c[1, 0] = 1
    c[1, 2] = c[2, 1] = 1
    dag = g.pdag_to_dag(c)
    assert g.is_dag(dag)
    np.testing.assert_array_equal(g.dag_to_cpdag(dag), c)


@settings(max_examples=60, deadline=None)
@given(
    d=st.integers(3, 6),
    data=st.data(),
)
def test_cpdag_roundtrip_property(d, data):
    """For any DAG G: every consistent extension of cpdag(G) is Markov
    equivalent to G, i.e. cpdag(extension) == cpdag(G)."""
    n_pairs = d * (d - 1) // 2
    bits = data.draw(st.lists(st.booleans(), min_size=n_pairs, max_size=n_pairs))
    perm = data.draw(st.permutations(range(d)))
    dag = _dag_from_bits(d, bits, list(perm))
    assert g.is_dag(dag)
    cpdag = g.dag_to_cpdag(dag)
    ext = g.pdag_to_dag(cpdag)
    assert g.is_dag(ext)
    np.testing.assert_array_equal(g.dag_to_cpdag(ext), cpdag)
    # skeletons agree
    np.testing.assert_array_equal(g.skeleton(ext), g.skeleton(dag))


def test_semi_directed_blocking():
    # y -- w -> x ; blocking {w} cuts the only path
    a = np.zeros((3, 3), dtype=np.int8)
    y, w, x = 0, 1, 2
    a[y, w] = a[w, y] = 1
    a[w, x] = 1
    assert not g.semi_directed_blocked(a, y, x, set())
    assert g.semi_directed_blocked(a, y, x, {w})
    # directed against travel does not open a path
    b = np.zeros((3, 3), dtype=np.int8)
    b[x, w] = 1  # w <- x
    b[y, w] = b[w, y] = 1
    assert g.semi_directed_blocked(b, y, x, set())


def test_random_dag_density():
    rng = np.random.default_rng(0)
    a = g.random_dag(30, 0.5, rng)
    assert g.is_dag(a)
    dens = a.sum() / (30 * 29 / 2)
    assert 0.35 < dens < 0.65
