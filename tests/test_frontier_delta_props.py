"""Hypothesis property tests for the incremental frontier-delta engine.

Two invariants carry the whole correctness argument
(docs/ARCHITECTURE.md, "Incremental frontier-delta engine"):

(a) **Enumeration exactness** — over random valid step sequences, the
    candidate list `repro.core.ges._FrontierDelta` produces by diffing
    against the incidence set is *identical* (order included, which the
    argmax tie-break depends on) to a from-scratch enumeration of the
    same CPDAG.  This is stronger than the set-equality the proof sketch
    needs.

(b) **Conservative invalidation** — scores an incremental session served
    from its memo (carried, never recomputed) match a fresh scorer's
    from-scratch recompute.  A stale carried score — one an applied step
    should have invalidated — would diverge here.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import ges as ges_mod
from repro.core.api import DiscoverySession
from repro.core.score_common import ScoreConfig
from repro.core.spec import EngineOptions
from repro.data.synthetic import generate_scm_data

_CFG = ScoreConfig(q_folds=5, m_max=40)


def _full_candidates(a, phase, max_subset=None):
    gen = (
        ges_mod._forward_candidates
        if phase == "forward"
        else ges_mod._backward_candidates
    )
    return list(gen(a, max_subset))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10 ** 9))
def test_incremental_enumeration_equals_full(seed):
    """Property (a): walk a random trajectory of applied GES steps; at
    every CPDAG along the way the diffed enumeration must equal the full
    one exactly."""
    rng = np.random.default_rng(seed)
    d = int(rng.integers(4, 7))
    a = np.zeros((d, d), np.int8)
    delta = ges_mod._FrontierDelta(max_subset=None)
    for phase in ("forward", "backward"):
        for _ in range(8):
            full = _full_candidates(a, phase)
            assert delta.candidates(a, phase) == full
            if not full:
                break
            op, x, y, sub, _, _ = full[int(rng.integers(len(full)))]
            a = (
                ges_mod._apply_insert(a, x, y, sub)
                if op == "insert"
                else ges_mod._apply_delete(a, x, y, sub)
            )
        # phase flip: the cache must detect it and re-enumerate fully
    # once more on the final graph, after all mutations
    assert delta.candidates(a, "backward") == _full_candidates(a, "backward")


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_carried_scores_match_fresh_recompute(seed):
    """Property (b): run an incremental discovery, then re-derive a
    sample of its memo'd scores with a fresh lazy scorer.  Carried
    scores were *never recomputed* by the incremental run, so any
    over-carrying (a score the step sequence should have invalidated)
    shows up as a mismatch.  Tolerance is the repo's engine==oracle
    bound (1e-8 relative, tests/test_frontier_batch.py): memo entries
    come from the batched engine, the cross-check from the lazy path."""
    ds = generate_scm_data(d=4, n=70, kind="continuous", seed=seed)
    sess = DiscoverySession(ds.data, config=_CFG,
                            options=EngineOptions(incremental=True))
    sess.run()
    memo = list(sess.scorer._score_cache.items())
    assert memo
    rng = np.random.default_rng(seed)
    rng.shuffle(memo)
    fresh = DiscoverySession(
        ds.data, config=_CFG, options=EngineOptions(engine="sequential")
    ).scorer
    for (node, parents), carried in memo[:10]:
        want = fresh.local_score(node, parents)
        err = abs(carried - want) / max(1.0, abs(want))
        assert err <= 1e-8, (node, parents, carried, want)
