"""The dumbbell-form algebra is an *identity*, not an approximation:
the CV-LR score evaluated on factors Lambda must equal the exact Eq.-8 score
evaluated on the kernel K = Lambda Lambda^T to machine precision."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.score_exact import cv_score_from_kernels
from repro.core.score_lowrank import cvlr_score_from_features
from repro.core.score_common import fold_layout


def _centered(rng, n, m, m_pad):
    lam = rng.standard_normal((n, m))
    lam = np.concatenate([lam, np.zeros((n, m_pad - m))], axis=1)
    lam -= lam.mean(axis=0, keepdims=True)
    return jnp.asarray(lam)


@pytest.mark.parametrize("q", [2, 5, 10])
@pytest.mark.parametrize("mx,mz", [(3, 5), (8, 8), (1, 12)])
def test_identity_nonempty_z(q, mx, mz):
    rng = np.random.default_rng(0)
    n = 40 * q
    m_pad = 16
    lam_x = _centered(rng, n, mx, m_pad)
    lam_z = _centered(rng, n, mz, m_pad)
    kx = lam_x @ lam_x.T
    kz = lam_z @ lam_z.T

    _, n_eff, n0, n1, train_idx = fold_layout(n, q, seed=0)
    assert n_eff == n
    lm, gm = jnp.float64(0.01), jnp.float64(0.01)
    s_exact = cv_score_from_kernels(kx, kz, jnp.asarray(train_idx), n0, n1, q, lm, gm)
    s_lr = cvlr_score_from_features(lam_x, lam_z, q, lm, gm)
    np.testing.assert_allclose(float(s_lr), float(s_exact), rtol=1e-9)


def test_identity_empty_z():
    rng = np.random.default_rng(1)
    n, q, m_pad = 200, 10, 16
    lam_x = _centered(rng, n, 6, m_pad)
    kx = lam_x @ lam_x.T
    _, n_eff, n0, n1, train_idx = fold_layout(n, q, seed=0)
    lm, gm = jnp.float64(0.01), jnp.float64(0.01)
    s_exact = cv_score_from_kernels(
        kx, jnp.zeros_like(kx), jnp.asarray(train_idx), n0, n1, q, lm, gm
    )
    s_lr = cvlr_score_from_features(lam_x, jnp.zeros_like(lam_x), q, lm, gm)
    np.testing.assert_allclose(float(s_lr), float(s_exact), rtol=1e-9)


def test_zero_padding_is_exact():
    """Appending zero columns to the factors must not change the score."""
    rng = np.random.default_rng(2)
    n, q = 120, 4
    lam_x = _centered(rng, n, 5, 5)
    lam_z = _centered(rng, n, 7, 7)
    lm, gm = jnp.float64(0.01), jnp.float64(0.01)
    s_small = cvlr_score_from_features(lam_x, lam_z, q, lm, gm)
    pad = lambda a, m: jnp.concatenate([a, jnp.zeros((n, m - a.shape[1]))], axis=1)
    s_padded = cvlr_score_from_features(pad(lam_x, 32), pad(lam_z, 32), q, lm, gm)
    np.testing.assert_allclose(float(s_padded), float(s_small), rtol=1e-10)


def test_lambda_gamma_general():
    """Identity must hold for lambda != gamma too (beta != lambda)."""
    rng = np.random.default_rng(3)
    n, q, m_pad = 80, 4, 12
    lam_x = _centered(rng, n, 4, m_pad)
    lam_z = _centered(rng, n, 9, m_pad)
    kx = lam_x @ lam_x.T
    kz = lam_z @ lam_z.T
    _, _, n0, n1, train_idx = fold_layout(n, q, seed=0)
    lm, gm = jnp.float64(0.03), jnp.float64(0.007)
    s_exact = cv_score_from_kernels(kx, kz, jnp.asarray(train_idx), n0, n1, q, lm, gm)
    s_lr = cvlr_score_from_features(lam_x, lam_z, q, lm, gm)
    np.testing.assert_allclose(float(s_lr), float(s_exact), rtol=1e-9)
