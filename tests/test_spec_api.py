"""The declarative API surface (PR 4): DataSpec / EngineOptions /
DiscoverySession, engine selection, the precision policy, and the
one-release deprecation shims over the old kwargs.

Covers: `DataSpec.infer` dtype/cardinality heuristics (continuous /
discrete / multi-dim columns), `DataSpec` and `EngineOptions` validation
errors, deprecated kwargs emitting `DeprecationWarning` while producing
identical `GESResult`s, `engine="sharded"`/`"sequential"` matching the
paths they replace, `precision="f32_gram"` staying within the policy's
oracle tolerance, the `ges(d=...)` consistency check, and the session
sweep lifecycle.
"""

import warnings

import numpy as np
import pytest

from repro.core.api import (
    DataSpec,
    DiscoverySession,
    EngineOptions,
    VariableSpec,
    causal_discover,
    make_scorer,
)
from repro.core.distributed_score import ges_batch_hook
from repro.core.ges import ges
from repro.core.score_common import ScoreConfig, config_key
from repro.core.score_lowrank import CVLRScorer
from repro.data.synthetic import generate_scm_data


def _chain_data(n=250, seed=1):
    rng = np.random.default_rng(seed)
    x0 = rng.standard_normal(n)
    x1 = np.tanh(x0) + 0.3 * rng.standard_normal(n)
    x2 = np.sin(x1) + 0.3 * rng.standard_normal(n)
    return np.stack([x0, x1, x2], axis=1)


def _frontier_configs(d):
    configs = [(y, ()) for y in range(d)]
    configs += [(y, (x,)) for x in range(d) for y in range(d) if x != y]
    return configs


# -- DataSpec ------------------------------------------------------------


def test_dataspec_from_arrays_absorbs_legacy_lists():
    data = np.zeros((10, 5))
    spec = DataSpec.from_arrays(data, dims=[1, 2, 2], discrete=[True, False, True])
    assert spec.num_vars == 3
    assert spec.dims == [1, 2, 2]
    assert spec.discrete == [True, False, True]
    assert spec.total_cols == 5
    assert spec.names == ["x0", "x1", "x2"]
    # defaults: every column its own continuous variable
    d2 = DataSpec.from_arrays(data)
    assert d2.dims == [1] * 5 and d2.discrete == [False] * 5


def test_dataspec_validation_errors_are_specific():
    data = np.zeros((10, 4))
    with pytest.raises(ValueError, match=r"cover 3 columns .* has 4"):
        DataSpec.from_arrays(data, dims=[1, 2])
    with pytest.raises(ValueError, match=r"discrete has 3 entries for 2"):
        DataSpec.from_arrays(data, dims=[2, 2], discrete=[True, False, True])
    with pytest.raises(ValueError, match="kind"):
        VariableSpec("x", kind="categorical")
    with pytest.raises(ValueError, match="dim"):
        VariableSpec("x", dim=0)
    with pytest.raises(ValueError, match="unique"):
        DataSpec((VariableSpec("a"), VariableSpec("a")))
    spec = DataSpec.from_arrays(data)
    with pytest.raises(ValueError, match=r"4 columns .* has 6"):
        spec.validate(np.zeros((10, 6)))
    bad = data.copy()
    bad[3, 2] = np.nan
    with pytest.raises(ValueError, match=r"non-finite .*'x2'"):
        spec.validate(bad)


def test_dataspec_infer_heuristics():
    rng = np.random.default_rng(0)
    n = 300
    cont = rng.standard_normal(n)  # continuous floats
    disc = rng.integers(0, 4, n).astype(np.float64)  # small-cardinality ints
    idlike = np.arange(n, dtype=np.float64)  # integer but high-cardinality
    spec = DataSpec.infer(np.stack([cont, disc, idlike], axis=1))
    assert [v.kind for v in spec.variables] == [
        "continuous",
        "discrete",
        "continuous",
    ]
    # multi-dim grouping: cardinality is judged on the variable's JOINT
    # rows — a 2-wide block of 0/1 columns is a discrete 4-level variable
    two_bits = rng.integers(0, 2, (n, 2)).astype(np.float64)
    spec2 = DataSpec.infer(
        np.concatenate([two_bits, rng.standard_normal((n, 2))], axis=1),
        dims=[2, 2],
    )
    assert [v.kind for v in spec2.variables] == ["discrete", "continuous"]
    assert spec2.dims == [2, 2]
    # max_levels tightens the discrete cut
    assert (
        DataSpec.infer(disc[:, None], max_levels=3).variables[0].kind
        == "continuous"
    )


def test_dataspec_infer_routes_alg2_like_explicit_spec():
    """An inferred spec must score identically to the hand-written one on
    discrete data (the Alg.-2 routing is driven by the spec alone)."""
    rng = np.random.default_rng(3)
    data = rng.integers(0, 4, size=(240, 3)).astype(np.float64)
    inferred = DataSpec.infer(data)
    assert all(v.discrete for v in inferred.variables)
    s_inf = make_scorer(data, spec=inferred, config=ScoreConfig(seed=1))
    s_exp = make_scorer(
        data,
        spec=DataSpec.from_arrays(data, discrete=[True] * 3),
        config=ScoreConfig(seed=1),
    )
    for i, ps in [(0, ()), (1, (0,)), (2, (0, 1))]:
        assert s_inf.local_score(i, ps) == s_exp.local_score(i, ps)


# -- EngineOptions -------------------------------------------------------


def test_engine_options_validation():
    with pytest.raises(ValueError, match="engine"):
        EngineOptions(engine="warp")
    with pytest.raises(ValueError, match="precision"):
        EngineOptions(precision="f16")
    with pytest.raises(ValueError, match="gram_cache_entries"):
        EngineOptions(gram_cache_entries=0)
    with pytest.raises(ValueError, match="device_bank_mb"):
        EngineOptions(device_bank_mb=-1)
    assert EngineOptions().batched
    assert not EngineOptions(engine="sequential").batched
    assert not EngineOptions(engine="sharded").batched
    # oracle tolerance is keyed off the precision policy
    assert EngineOptions().oracle_rtol == 1e-8
    assert EngineOptions(precision="f32_gram").oracle_rtol == 1e-5


def test_method_engine_conflicts_raise():
    data = _chain_data()
    with pytest.raises(ValueError, match='requires method="cvlr"'):
        make_scorer(data, method="cv", options=EngineOptions(engine="sharded"))
    # the scorer class holds the same line: loose kwargs cannot be
    # silently overridden by an options object
    with pytest.raises(ValueError, match="not both"):
        CVLRScorer(data, batched=False, options=EngineOptions())


# -- removed deprecation shims -------------------------------------------


def test_legacy_kwargs_are_removed():
    """The PR-4 legacy kwargs (`dims`/`discrete`/`batched`/
    `gram_cache_entries`/`device_bank_mb`/`batch_hook`) served their one
    deprecation release; the keyword-only signatures now reject them
    with a plain TypeError instead of warning."""
    data = _chain_data(seed=5)
    with pytest.raises(TypeError):
        causal_discover(data, batched=False)
    with pytest.raises(TypeError):
        causal_discover(data, dims=[1, 1, 1], discrete=[False] * 3)
    with pytest.raises(TypeError):
        causal_discover(data, batch_hook=ges_batch_hook)
    with pytest.raises(TypeError):
        make_scorer(data, gram_cache_entries=7)
    with pytest.raises(TypeError):
        make_scorer(data, device_bank_mb=0)
    # and no DeprecationWarning machinery remains on the modern surface
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        make_scorer(data, options=EngineOptions(engine="sequential"))


# -- engine selection ----------------------------------------------------


def test_sharded_engine_matches_legacy_hook_and_sequential():
    """EngineOptions(engine="sharded") == the old hand-threaded
    ges(scorer, batch_hook=ges_batch_hook) == the sequential path, as
    equivalence classes."""
    data = _chain_data(seed=11)
    cfg = ScoreConfig(seed=4)
    r_sharded = causal_discover(
        data, config=cfg, options=EngineOptions(engine="sharded")
    )
    legacy_scorer = CVLRScorer(data, config=cfg)
    r_hook = ges(legacy_scorer, batch_hook=ges_batch_hook)
    r_seq = causal_discover(
        data, config=cfg, options=EngineOptions(engine="sequential")
    )
    np.testing.assert_array_equal(r_sharded.cpdag, r_hook.cpdag)
    np.testing.assert_array_equal(r_sharded.cpdag, r_seq.cpdag)
    assert abs(r_sharded.score - r_seq.score) <= 1e-6 * max(
        1.0, abs(r_seq.score)
    )


def test_sharded_session_actually_routes_through_stacked_pipeline():
    """The sharded session's scorer must NOT have run its local batched
    engine (its Gram-block cache stays empty) — proof the frontier went
    through the distributed stacked path."""
    data = _chain_data(seed=13)
    session = DiscoverySession(
        data, options=EngineOptions(engine="sharded"), config=ScoreConfig(seed=3)
    )
    session.run()
    assert session.scorer.cache_size > 0  # scores were filled in...
    assert len(session.scorer.gram_cache) == 0  # ...but not by the engine
    assert any(rec["n_scored"] > 0 for rec in session.sweep_log)


# -- precision policy ----------------------------------------------------


@pytest.mark.parametrize("kind", ["continuous", "mixed"])
def test_f32_gram_scores_within_policy_tolerance(kind):
    """precision="f32_gram" frontier scores stay within the policy's
    oracle_rtol (1e-5) of the sequential f64 oracle on the tier-1
    fixtures — |Z|=0, multi-parent and discrete variables included."""
    ds = generate_scm_data(d=5, n=250, density=0.4, kind=kind, seed=9)
    opts = EngineOptions(precision="f32_gram")
    spec = DataSpec.from_arrays(ds.data, dims=ds.dims, discrete=ds.discrete)
    s_f32 = make_scorer(ds.data, spec=spec, options=opts, config=ScoreConfig(seed=2))
    s_seq = make_scorer(
        ds.data,
        spec=spec,
        options=EngineOptions(engine="sequential"),
        config=ScoreConfig(seed=2),
    )
    configs = _frontier_configs(5) + [(4, (0, 1)), (3, (0, 1, 2))]
    n_done = s_f32.prefetch(configs)
    assert n_done == len(configs)
    for i, ps in configs:
        got = s_f32._score_cache[config_key(i, ps)]
        want = s_seq.local_score(i, ps)
        rel = abs(got - want) / max(1.0, abs(want))
        assert rel <= opts.oracle_rtol, (i, ps, got, want, rel)


def test_f32_gram_reaches_sharded_pipeline():
    """The precision policy must ride into the sharded engine's stacked
    Gram stage: f32_gram scores differ from bitwise at the reassociation
    level (proof the f32 path actually ran) while staying within the
    policy tolerance of the f64 oracle."""
    data = _chain_data(seed=31)
    cfg = ScoreConfig(seed=8)
    configs = _frontier_configs(3)

    def _sharded_scores(precision):
        session = DiscoverySession(
            data,
            options=EngineOptions(engine="sharded", precision=precision),
            config=cfg,
        )
        session.score_frontier(configs)
        return {
            (i, ps): session.scorer._score_cache[config_key(i, ps)]
            for i, ps in configs
        }

    s32 = _sharded_scores("f32_gram")
    s64 = _sharded_scores("bitwise")
    rtol = EngineOptions(precision="f32_gram").oracle_rtol
    assert any(s32[k] != s64[k] for k in s64), "f32 path never ran"
    for k in s64:
        assert abs(s32[k] - s64[k]) / max(1.0, abs(s64[k])) <= rtol, (
            k, s32[k], s64[k]
        )


def test_f32_gram_discovery_matches_bitwise_cpdag():
    data = _chain_data(seed=17)
    cfg = ScoreConfig(seed=7)
    r64 = causal_discover(data, config=cfg)
    r32 = causal_discover(
        data, config=cfg, options=EngineOptions(precision="f32_gram")
    )
    np.testing.assert_array_equal(r64.cpdag, r32.cpdag)


# -- ges(d=...) consistency ----------------------------------------------


def test_ges_d_param_validated_against_scorer():
    data = _chain_data(seed=19)
    scorer = CVLRScorer(data, config=ScoreConfig(seed=1))
    with pytest.raises(ValueError, match=r"ges\(d=5\) conflicts"):
        ges(scorer, d=5)
    # a consistent d is accepted and equals the inferred-run result
    r1 = ges(scorer, d=3)
    r2 = ges(CVLRScorer(data, config=ScoreConfig(seed=1)))
    np.testing.assert_array_equal(r1.cpdag, r2.cpdag)


# -- DiscoverySession lifecycle ------------------------------------------


def test_session_sweep_log_records_lifecycle():
    data = _chain_data(seed=23)
    session = DiscoverySession(data, config=ScoreConfig(seed=9))
    res = session.run()
    assert session.result is res
    assert session.spec.num_vars == 3
    assert len(session.sweep_log) >= 2  # >=1 forward + >=1 backward sweep
    phases = {rec["phase"] for rec in session.sweep_log}
    assert phases <= {"forward", "backward"} and "forward" in phases
    for rec in session.sweep_log:
        assert rec["n_configs"] > 0
        assert rec["n_scored"] >= 0
        assert set(rec["gram_cache"]) == {
            "hits", "misses", "evictions",
            "promotions", "spills", "bank_fallbacks",
        }
    # every applied GES step is recorded on exactly one sweep
    steps = [rec["step"] for rec in session.sweep_log if rec["step"] is not None]
    assert len(steps) == res.forward_steps + res.backward_steps
    assert steps == res.trace


def test_session_and_batch_hook_are_mutually_exclusive():
    data = _chain_data(seed=29)
    session = DiscoverySession(data, config=ScoreConfig(seed=0))
    with pytest.raises(ValueError, match="not both"):
        ges(session.scorer, batch_hook=ges_batch_hook, session=session)
