"""FeaturePolicy routing + FeatureBank caching + the EngineOptions /
DiscoverySession plumbing (PR 5).

The two load-bearing guarantees:

* `FeaturePolicy.default()` reproduces the pre-PR-5 hardwired routing
  bitwise (same factors, same scores, same CPDAGs), so nothing changes
  unless a user opts in;
* whatever policy is selected, the batched frontier engine equals its
  own sequential oracle (the engine is factor-agnostic), and the bank
  shares built factors across sweeps and sessions with honest hit/miss
  telemetry.
"""

import numpy as np
import pytest

import repro.core  # noqa: F401 — x64

from repro.core.api import (
    DataSpec,
    DiscoverySession,
    EngineOptions,
    VariableSpec,
    causal_discover,
    make_scorer,
)
from repro.core.score_common import ScoreConfig, config_key
from repro.data.synthetic import generate_scm_data
from repro.features.backends import build_features, lowrank_features, BuildContext
from repro.features.bank import FeatureBank
from repro.features.policy import BackendChoice, FeaturePolicy


def _mixed_ds(n=260, seed=4):
    return generate_scm_data(d=4, n=n, density=0.4, kind="mixed", seed=seed)


def _frontier(d):
    configs = [(y, ()) for y in range(d)]
    configs += [(y, (x,)) for x in range(d) for y in range(d) if x != y]
    return configs


# -- policy resolution -----------------------------------------------------


def test_default_policy_routes_like_the_old_router():
    spec = DataSpec(
        (
            VariableSpec("c0"),
            VariableSpec("c1"),
            VariableSpec("d0", kind="discrete"),
            VariableSpec("d1", kind="discrete"),
        )
    )
    pol = FeaturePolicy.default()
    assert pol.is_default
    assert pol.resolve((0,), spec).backend == "icl"
    assert pol.resolve((2,), spec).backend == "discrete_exact"
    assert pol.resolve((2, 3), spec).backend == "discrete_exact"
    # mixed sets took the ICL route before (is_discrete = ALL discrete)
    assert pol.resolve((0, 2), spec).backend == "icl"


def test_per_variable_override_rides_on_the_dataspec():
    spec = DataSpec(
        (
            VariableSpec("a", backend="rff"),
            VariableSpec("b"),
            VariableSpec(
                "c",
                kind="discrete",
                backend="nystrom",
                backend_params={"sampler": "stratified"},
            ),
        )
    )
    pol = FeaturePolicy.default()
    assert pol.resolve((0,), spec).backend == "rff"
    choice = pol.resolve((2,), spec)
    assert choice.backend == "nystrom"
    assert choice.kwargs == {"sampler": "stratified"}
    # overrides apply to a set only when every member names the same one
    assert pol.resolve((0, 1), spec).backend == "icl"
    assert pol.resolve((0, 2), spec).backend == "icl"


def test_policy_kind_choices_and_mixed_fallback():
    spec = DataSpec(
        (VariableSpec("c"), VariableSpec("d", kind="discrete"))
    )
    pol = FeaturePolicy(
        continuous="rff",
        discrete=BackendChoice.of("nystrom", sampler="stratified"),
        seed=7,
    )
    assert pol.resolve((0,), spec).backend == "rff"
    assert pol.resolve((1,), spec).backend == "nystrom"
    assert pol.resolve((0, 1), spec).backend == "rff"  # mixed -> continuous
    pol2 = FeaturePolicy(mixed=BackendChoice("nystrom"))
    assert pol2.resolve((0, 1), spec).backend == "nystrom"
    assert pol.fingerprint() != FeaturePolicy.default().fingerprint()
    assert FeaturePolicy(seed=1).fingerprint() != FeaturePolicy().fingerprint()


def test_variable_spec_override_validation():
    with pytest.raises(ValueError, match="backend"):
        VariableSpec("x", backend="")
    with pytest.raises(ValueError, match="backend_params"):
        VariableSpec("x", backend_params={"sampler": "uniform"})
    with pytest.raises(ValueError, match="levels"):
        VariableSpec("x", levels=0)


def test_engine_options_features_validation():
    with pytest.raises(ValueError, match="FeaturePolicy"):
        EngineOptions(features="rff")
    opts = EngineOptions(features=FeaturePolicy(continuous="rff"))
    assert opts.features.continuous.backend == "rff"


# -- default policy is bitwise-compatible ----------------------------------


def test_default_policy_factors_match_legacy_builder_bitwise():
    ds = _mixed_ds()
    for cols, disc in ((ds.data[:, :1], False), (ds.data[:, 1:2], ds.discrete[1])):
        legacy = lowrank_features(cols, discrete=bool(disc), m_max=48)
        via_policy = build_features(
            cols,
            FeaturePolicy.default().discrete
            if disc
            else FeaturePolicy.default().continuous,
            BuildContext(m_max=48),
        )
        assert legacy[1] == via_policy.m_eff
        np.testing.assert_array_equal(
            np.asarray(legacy[0]), np.asarray(via_policy.factor)
        )


def test_default_policy_discovery_identical_with_and_without_explicit_policy():
    ds = _mixed_ds(seed=6)
    spec = DataSpec.from_arrays(ds.data, dims=ds.dims, discrete=ds.discrete)
    cfg = ScoreConfig(seed=2)
    r_implicit = causal_discover(ds.data, spec=spec, config=cfg)
    r_explicit = causal_discover(
        ds.data,
        spec=spec,
        config=cfg,
        options=EngineOptions(features=FeaturePolicy.default()),
    )
    np.testing.assert_array_equal(r_implicit.cpdag, r_explicit.cpdag)
    assert r_implicit.score == r_explicit.score


# -- engine == oracle under every policy -----------------------------------


@pytest.mark.parametrize(
    "policy",
    [
        FeaturePolicy(continuous="rff", discrete="rff", seed=3),
        FeaturePolicy(
            continuous=BackendChoice.of("nystrom", sampler="leverage"),
            discrete=BackendChoice.of("nystrom", sampler="stratified"),
            seed=5,
        ),
    ],
    ids=["rff", "nystrom"],
)
def test_batched_engine_matches_sequential_oracle_under_policy(policy):
    """The frontier engine shares factors with the sequential path through
    the same bank, so engine == oracle must hold for ANY backend."""
    ds = _mixed_ds(seed=8)
    spec = DataSpec.from_arrays(ds.data, dims=ds.dims, discrete=ds.discrete)
    cfg = ScoreConfig(seed=1)
    opts = EngineOptions(features=policy)
    s_bat = make_scorer(ds.data, spec=spec, config=cfg, options=opts)
    s_seq = make_scorer(
        ds.data,
        spec=spec,
        config=cfg,
        options=EngineOptions(engine="sequential", features=policy),
    )
    configs = _frontier(4) + [(3, (0, 1))]
    assert s_bat.prefetch(configs) == len(configs)
    for i, ps in configs:
        a = s_bat._score_cache[config_key(i, ps)]
        b = s_seq.local_score(i, ps)
        assert abs(a - b) <= 1e-8 * max(1.0, abs(b)), (i, ps, a, b)


# -- FeatureBank -----------------------------------------------------------


def test_bank_counts_hits_misses_builds_and_evicts():
    bank = FeatureBank(max_entries=2)
    calls = []

    class _Res:
        backend = "icl"
        m_eff = 3
        info = {"gram_resid": 0.0}

    def build(tag):
        calls.append(tag)
        return _Res()

    fp = ("icl", (), 0)
    bank.get_or_build((0,), fp, lambda: build("a"))
    bank.get_or_build((0,), fp, lambda: build("a2"))  # hit
    bank.get_or_build((1,), fp, lambda: build("b"))
    bank.get_or_build((2,), fp, lambda: build("c"))  # evicts (0,)
    assert calls == ["a", "b", "c"]
    st = bank.stats
    assert (st["hits"], st["misses"], st["builds"]) == (1, 3, 3)
    assert st["evictions"] == 1 and st["entries"] == 2
    assert len(bank.entry_log()) == 2
    # distinct fingerprints never collide
    bank.get_or_build((2,), ("rff", (), 0), lambda: build("d"))
    assert calls[-1] == "d"


def test_shared_bank_avoids_rebuilds_across_scorers():
    """The multi-sweep/multi-session rebuild-avoidance win: a second
    scorer over the same data + config + policy reuses every factor."""
    ds = _mixed_ds(seed=10)
    spec = DataSpec.from_arrays(ds.data, dims=ds.dims, discrete=ds.discrete)
    cfg = ScoreConfig(seed=3)
    bank = FeatureBank()
    s1 = make_scorer(ds.data, spec=spec, config=cfg, feature_bank=bank)
    s1.prefetch(_frontier(4))
    builds_after_first = bank.stats["builds"]
    assert builds_after_first > 0

    s2 = make_scorer(ds.data, spec=spec, config=cfg, feature_bank=bank)
    s2.prefetch(_frontier(4))
    assert bank.stats["builds"] == builds_after_first  # zero rebuilds
    for key in s1._score_cache:
        assert s1._score_cache[key] == s2._score_cache[key]

    # a different fold layout must NOT share factors (fingerprint guards)
    s3 = make_scorer(
        ds.data, spec=spec, config=ScoreConfig(seed=4), feature_bank=bank
    )
    s3.features((0,))
    assert bank.stats["builds"] == builds_after_first + 1


def test_shared_bank_isolates_spec_derived_build_inputs():
    """Same resolved BackendChoice, different DataSpec kind: the
    stratified sampler keys on the spec's per-column discreteness, so
    the bank fingerprint must separate the two builds instead of serving
    one scorer the other's factor."""
    rng = np.random.default_rng(21)
    data = rng.integers(0, 3, (200, 2)).astype(float)
    bank = FeatureBank()
    cfg = ScoreConfig(seed=0)

    def _spec(kind):
        return DataSpec(
            tuple(
                VariableSpec(
                    f"x{i}",
                    kind=kind,
                    backend="nystrom",
                    backend_params={"sampler": "stratified"},
                )
                for i in range(2)
            )
        )

    s_disc = make_scorer(data, spec=_spec("discrete"), config=cfg, feature_bank=bank)
    s_cont = make_scorer(data, spec=_spec("continuous"), config=cfg, feature_bank=bank)
    s_disc.features((0,))
    s_cont.features((0,))
    assert bank.stats["builds"] == 2  # one per spec, never shared
    assert s_disc.m_eff_log[(0,)] == 3  # stratified covered the 3 levels


def test_bank_rejects_bad_bounds_and_cv_scorer():
    with pytest.raises(ValueError, match="max_entries"):
        FeatureBank(max_entries=0)
    data = np.random.default_rng(0).standard_normal((60, 3))
    with pytest.raises(ValueError, match='method="cvlr"'):
        make_scorer(data, method="cv", feature_bank=FeatureBank())


# -- DiscoverySession integration ------------------------------------------


def test_session_sweep_log_surfaces_feature_bank_telemetry():
    ds = _mixed_ds(seed=12)
    spec = DataSpec.from_arrays(ds.data, dims=ds.dims, discrete=ds.discrete)
    session = DiscoverySession(ds.data, spec=spec, config=ScoreConfig(seed=5))
    session.run()
    assert session.feature_bank is session.scorer.feature_bank
    assert len(session.sweep_log) >= 2
    for rec in session.sweep_log:
        assert set(rec["feature_bank"]) == {"hits", "misses", "builds", "build_s"}
    # sweep 1 builds factors; later sweeps mostly reuse them
    assert session.sweep_log[0]["feature_bank"]["builds"] > 0
    total_builds = sum(r["feature_bank"]["builds"] for r in session.sweep_log)
    assert total_builds == session.feature_bank.stats["builds"]

    # a second session sharing the bank rebuilds nothing on its first sweep
    session2 = DiscoverySession(
        ds.data,
        spec=spec,
        config=ScoreConfig(seed=5),
        feature_bank=session.feature_bank,
    )
    session2.run()
    assert session2.sweep_log[0]["feature_bank"]["builds"] == 0
    assert session2.sweep_log[0]["feature_bank"]["hits"] > 0
    np.testing.assert_array_equal(
        session.result.cpdag, session2.result.cpdag
    )


def test_rff_policy_discovery_runs_end_to_end():
    ds = _mixed_ds(seed=14)
    spec = DataSpec.from_arrays(ds.data, dims=ds.dims, discrete=ds.discrete)
    res = causal_discover(
        ds.data,
        spec=spec,
        config=ScoreConfig(seed=6),
        options=EngineOptions(
            features=FeaturePolicy(continuous="rff", discrete="discrete_exact")
        ),
    )
    assert res.cpdag.shape == (4, 4)


# -- satellite: the distinct-row count happens once per column -------------


def test_count_distinct_rows_runs_once_per_variable(monkeypatch):
    """`DataSpec.infer` counts each variable's levels; the discrete
    backend must consume that count instead of re-scanning the column."""
    import repro.features.backends as backends_mod

    real = backends_mod.count_distinct_rows
    calls = []

    def counting(x, cap, **kw):
        calls.append(np.asarray(x).shape)
        return real(x, cap, **kw)

    monkeypatch.setattr(backends_mod, "count_distinct_rows", counting)

    rng = np.random.default_rng(0)
    data = np.stack(
        [
            rng.integers(0, 3, 300).astype(float),
            rng.integers(0, 4, 300).astype(float),
            rng.standard_normal(300),
        ],
        axis=1,
    )
    spec = DataSpec.infer(data)
    assert [v.kind for v in spec.variables] == ["discrete", "discrete", "continuous"]
    n_infer = len(calls)
    assert n_infer == 2  # the continuous column fails the integrality gate

    scorer = make_scorer(data, spec=spec, config=ScoreConfig(seed=0))
    scorer.features((0,))
    scorer.features((1,))
    scorer.features((2,))
    assert len(calls) == n_infer  # single-variable builds never re-count

    # a multi-variable discrete set has no precomputed joint count: one
    # (and only one) scan is the documented cost
    scorer.features((0, 1))
    assert len(calls) == n_infer + 1

    # without infer (from_arrays leaves levels unknown) the build itself
    # counts exactly once per set
    calls.clear()
    spec2 = DataSpec.from_arrays(data, discrete=[True, True, False])
    scorer2 = make_scorer(data, spec=spec2, config=ScoreConfig(seed=0))
    scorer2.features((0,))
    assert len(calls) == 1
