"""Unified observability layer (PR 10): spans, metrics, exporters.

Four contracts under test:

1. **Zero overhead / zero interference when disabled** — with no active
   recorder a span is a shared no-op object, and ``obs="off"`` discovery
   output is bitwise-identical to ``obs="metrics"`` / ``obs="trace"``
   (an active recorder adds stage-boundary syncs, never arithmetic).
2. **Timeline fidelity** — a traced run emits schema-valid trace_event
   dicts (session -> sweep -> stage nesting, kernel + compile cats), the
   JSONL log survives torn tails, and the Chrome/Perfetto export loads.
3. **Registry back-compat** — the scattered stats dicts re-register as
   lazy sources; every pre-existing ``sweep_log`` / ``telemetry()`` key
   is untouched, and multi-tenant sources never leak across tenants.
4. **Hygiene at the seams** — ``end_sweep`` runs every sweep record
   through `repro.obs.json_safe`, so jax/numpy leaves can never reach
   ``RunState`` payloads.
"""

import json
import threading
import time
import urllib.request

import numpy as np
import pytest

from repro.core.api import DiscoverySession, causal_discover
from repro.core.spec import OBS_MODES, EngineOptions
from repro.obs import (
    LATENCY_BUCKETS_S,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Recorder,
    chrome_trace,
    engine_stage_split,
    json_safe,
    prometheus_text,
    read_jsonl,
    start_metrics_server,
    validate_events,
)
from repro.obs import trace as obs_trace


def _chain_data(n=150, d=4, seed=0):
    rng = np.random.default_rng(seed)
    cols = [rng.standard_normal(n)]
    for _ in range(d - 1):
        cols.append(np.tanh(cols[-1]) + 0.4 * rng.standard_normal(n))
    return np.stack(cols, axis=1)


# -- metrics registry ------------------------------------------------------


def test_counter_gauge_histogram():
    reg = MetricsRegistry()
    c = reg.counter("x.count")
    c.inc()
    c.inc(2)
    assert c.value == 3
    with pytest.raises(ValueError):
        c.inc(-1)
    assert reg.counter("x.count") is c  # get-or-create

    g = reg.gauge("x.depth")
    g.set(7)
    assert g.value == 7.0

    h = reg.histogram("x.s")
    assert h.buckets == LATENCY_BUCKETS_S
    h.observe(0.003)
    h.observe(0.003)
    h.observe(200.0)  # lands in +Inf
    d = h.to_dict()
    assert d["count"] == 3
    assert d["buckets"][0.005] == 2
    assert d["buckets"][60.0] == 2  # +Inf overflow not in cumulative buckets
    assert d["sum"] == pytest.approx(200.006)


def test_histogram_rejects_unsorted_buckets():
    with pytest.raises(ValueError):
        Histogram("bad", buckets=(1.0, 0.5))


def test_registry_snapshot_and_sources():
    reg = MetricsRegistry()
    reg.counter("a").inc()
    reg.gauge("b").set(2)
    reg.histogram("c").observe(0.01)
    stats = {"hits": 1, "misses": 2}
    reg.register_source("cache", lambda: stats)
    snap = reg.snapshot()
    assert snap["counters"] == {"a": 1.0}
    assert snap["gauges"] == {"b": 2.0}
    assert snap["histograms"]["c"]["count"] == 1
    assert snap["sources"]["cache"] == {"hits": 1, "misses": 2}
    # sources are lazy: mutations show up at the next snapshot
    stats["hits"] = 5
    assert reg.snapshot()["sources"]["cache"]["hits"] == 5
    # a dead source reports instead of poisoning the snapshot
    reg.register_source("dead", lambda: 1 / 0)
    assert "ZeroDivisionError" in reg.snapshot()["sources"]["dead"]["error"]
    reg.unregister_source("dead")
    assert "dead" not in reg.snapshot()["sources"]
    with pytest.raises(TypeError):
        reg.register_source("notcallable", 42)


def test_prometheus_text_render():
    reg = MetricsRegistry()
    reg.counter("span.fold.count").inc(3)
    reg.histogram("span.fold.s").observe(0.02)
    reg.register_source("serving.stats", lambda: {"shed": 4, "note": "x"})
    text = prometheus_text(reg)
    assert "# TYPE repro_span_fold_count counter" in text
    assert "repro_span_fold_count 3" in text
    assert 'repro_span_fold_s_bucket{le="+Inf"} 1' in text
    assert "repro_serving_stats_shed 4" in text
    assert "note" not in text  # non-numeric source fields are skipped


def test_metrics_http_endpoint():
    reg = MetricsRegistry()
    reg.counter("hits").inc(9)
    server = start_metrics_server(reg, port=0)
    try:
        port = server.server_address[1]
        body = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=5
        ).read().decode()
        assert "repro_hits 9" in body
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://127.0.0.1:{port}/nope", timeout=5
            )
    finally:
        server.shutdown()


# -- trace primitives ------------------------------------------------------


def test_span_noop_without_recorder():
    assert obs_trace.get_recorder() is None
    s1 = obs_trace.span("a")
    s2 = obs_trace.span("b", cat="kernel", attrs={"x": 1})
    assert s1 is s2  # the shared no-op object: no allocation when off
    with s1:
        pass


def test_span_records_and_nests():
    rec = Recorder(mode="trace", labels={"session": "s1"})
    with rec.activate():
        with obs_trace.span("outer", cat="sweep"):
            with obs_trace.span("inner", cat="stage", attrs={"k": 2}):
                time.sleep(0.002)
    evs = rec.events()
    assert [e["name"] for e in evs] == ["inner", "outer"]  # close order
    inner, outer = evs
    assert inner["args"] == {"session": "s1", "k": 2}
    assert outer["cat"] == "sweep" and outer["ph"] == "X"
    # nesting is implied by ts/dur containment on one tid
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3
    assert inner["tid"] == outer["tid"] == threading.get_ident()
    assert not validate_events(evs)
    # instruments updated too
    snap = rec.registry.snapshot()
    assert snap["counters"]["span.inner.count"] == 1
    assert snap["histograms"]["span.inner.s"]["count"] == 1


def test_traced_decorator():
    calls = []

    @obs_trace.traced("fancy", cat="kernel")
    def f(x):
        calls.append(x)
        return x + 1

    assert f(1) == 2  # no recorder: plain call
    rec = Recorder(mode="trace")
    with rec.activate():
        assert f(2) == 3
    assert calls == [1, 2]
    (ev,) = rec.events()
    assert ev["name"] == "fancy" and ev["cat"] == "kernel"


def test_metrics_mode_keeps_no_events():
    rec = Recorder(mode="metrics")
    with rec.activate():
        with obs_trace.span("x"):
            pass
    assert rec.events() == []
    assert rec.registry.snapshot()["counters"]["span.x.count"] == 1


def test_use_is_thread_local():
    """contextvars do not propagate into spawned threads: a worker sees
    no recorder unless it re-enters with use(rec) explicitly — exactly
    what the sharded engine does."""
    rec = Recorder(mode="trace")
    seen = []

    def worker(expect):
        seen.append((expect, obs_trace.get_recorder()))
        if expect:
            with obs_trace.span("w"):
                pass

    with rec.activate():
        t = threading.Thread(target=worker, args=(False,))
        t.start()
        t.join()

        def rewrapped():
            with obs_trace.use(rec):
                worker(True)

        t2 = threading.Thread(target=rewrapped)
        t2.start()
        t2.join()
    assert seen[0] == (False, None)
    assert seen[1] == (True, rec)
    (ev,) = rec.events()
    assert ev["name"] == "w" and ev["tid"] != threading.get_ident()


def test_compile_events_from_fresh_jit():
    jax = pytest.importorskip("jax")
    rec = Recorder(mode="trace")
    with rec.activate():
        # a never-before-seen shape + closure forces a real cache miss
        shape = (17, 13)
        x = jax.numpy.ones(shape)
        jax.jit(lambda a: (a * 3.5).sum() + shape[0]).__call__(x)
    kinds = {e["name"] for e in rec.events() if e["cat"] == "compile"}
    assert "compile:backend_compile" in kinds
    snap = rec.registry.snapshot()
    assert snap["counters"]["compile.events"] >= 1
    assert snap["histograms"]["compile.s"]["count"] >= 1


def test_recorder_begin_end_and_labels():
    rec = Recorder(mode="trace")
    rec.set_label("sweep", 3)
    h = rec.begin("sweep", cat="sweep", attrs={"phase": "forward"})
    with rec.activate(), obs_trace.span("stage_x"):
        pass
    rec.end(h)
    rec.pop_label("sweep")
    names = {e["name"]: e for e in rec.events()}
    assert names["stage_x"]["args"]["sweep"] == 3
    assert names["sweep"]["args"]["phase"] == "forward"
    assert rec.stage_seconds(cats=("stage",)).keys() == {"stage_x"}


# -- exporters -------------------------------------------------------------


def test_jsonl_roundtrip_and_torn_tail(tmp_path):
    rec = Recorder(mode="trace", trace_dir=str(tmp_path), name="t")
    with rec.activate():
        with obs_trace.span("a"):
            pass
        rec.instant("mark1")
    rec.close()
    events = read_jsonl(rec.jsonl_path)
    assert [e["name"] for e in events] == ["a", "mark1"]
    assert not validate_events(events)
    # a crash-torn final line drops silently, keeping the prefix
    with open(rec.jsonl_path, "a") as fh:
        fh.write('{"name": "torn", "cat"')
    assert [e["name"] for e in read_jsonl(rec.jsonl_path)] == ["a", "mark1"]
    # the Chrome/Perfetto document was written at close
    doc = json.load(open(rec.chrome_path))
    assert doc["displayTimeUnit"] == "ms"
    assert [e["name"] for e in doc["traceEvents"]] == ["a", "mark1"]


def test_validate_events_catches_bad_shapes():
    good = {
        "name": "x", "cat": "stage", "ph": "X",
        "ts": 1.0, "dur": 2.0, "pid": 1, "tid": 2, "args": {},
    }
    assert not validate_events([good])
    bad = [
        {**good, "ph": "B"},
        {**good, "dur": -1},
        {**good, "name": ""},
        {**good, "args": {"x": object()}},
        "not-a-dict",
    ]
    errors = validate_events(bad)
    assert len(errors) == 5


def test_chrome_trace_metadata():
    doc = chrome_trace([], metadata={"run": "r1"})
    assert doc["metadata"] == {"run": "r1"}
    json.dumps(doc)


# -- json_safe -------------------------------------------------------------


def test_json_safe_preserves_containers_and_unwraps_leaves():
    jnp = pytest.importorskip("jax.numpy")
    rec = {
        "step": ("insert", 0, 1),  # tuple MUST stay a tuple
        "n": np.int64(4),
        "score": jnp.float32(1.5),
        "arr": np.arange(3),
        "nested": [{"f": np.float64(0.25)}],
        "flag": True,
        "none": None,
    }
    out = json_safe(rec)
    assert out["step"] == ("insert", 0, 1) and isinstance(out["step"], tuple)
    assert out["n"] == 4 and type(out["n"]) is int
    assert out["score"] == 1.5 and type(out["score"]) is float
    assert out["arr"] == [0, 1, 2]
    assert type(out["nested"][0]["f"]) is float
    json.dumps(out)  # every leaf is stdlib-serializable


def test_json_safe_raises_with_key_path():
    with pytest.raises(TypeError, match=r"record\.deep\[0\]\.bad"):
        json_safe({"deep": [{"bad": object()}]})
    with pytest.raises(TypeError, match="non-string key"):
        json_safe({1: "x"})


# -- options plumbing ------------------------------------------------------


def test_engine_options_obs_validation():
    assert OBS_MODES == ("off", "metrics", "trace")
    assert EngineOptions().obs == "off"
    EngineOptions(obs="trace", trace_dir="/tmp/x")
    with pytest.raises(ValueError, match="obs"):
        EngineOptions(obs="loud")
    with pytest.raises(ValueError, match="trace_dir"):
        EngineOptions(obs="metrics", trace_dir="/tmp/x")


def test_serving_options_obs_validation():
    from repro.serving import ServingOptions

    ServingOptions(obs="trace", trace_dir="/tmp/x")
    with pytest.raises(ValueError, match="obs"):
        ServingOptions(obs="verbose")
    with pytest.raises(ValueError, match="trace_dir"):
        ServingOptions(trace_dir="/tmp/x")


# -- discovery integration -------------------------------------------------

_SMALL = dict(n=150, d=4)


@pytest.fixture(scope="module")
def small_runs(tmp_path_factory):
    """One off-run + one traced run over the same cell, shared by the
    integration tests below (discovery is the expensive part)."""
    data = _chain_data(**_SMALL)
    td = tmp_path_factory.mktemp("traces")
    off = causal_discover(data, options=EngineOptions())
    sess = DiscoverySession(
        data, options=EngineOptions(obs="trace", trace_dir=str(td))
    )
    traced = sess.run()
    return off, traced, sess, td


def test_obs_off_and_trace_bitwise_identical(small_runs):
    off, traced, _, _ = small_runs
    np.testing.assert_array_equal(off.cpdag, traced.cpdag)
    assert off.score == traced.score
    assert off.trace == traced.trace


def test_trace_run_span_hierarchy(small_runs):
    _, _, sess, _ = small_runs
    evs = sess.recorder.events()
    assert not validate_events(evs)
    cats = {e["cat"] for e in evs}
    assert {"session", "sweep", "stage"} <= cats
    names = {e["name"] for e in evs}
    # the engine stages and the GES stages all showed up
    assert {"enumerate", "select", "features", "gram", "zcores", "fold"} <= names
    # exactly one session span, containing every sweep span
    sessions = [e for e in evs if e["cat"] == "session"]
    assert len(sessions) == 1
    s0, s1 = sessions[0]["ts"], sessions[0]["ts"] + sessions[0]["dur"]
    for sweep in (e for e in evs if e["cat"] == "sweep"):
        assert s0 <= sweep["ts"] and sweep["ts"] + sweep["dur"] <= s1 + 1e-3
        assert "sweep" in sweep["args"]
    # every event carries the session label
    assert all(e["args"].get("session") for e in evs)


def test_trace_files_written_and_loadable(small_runs):
    _, _, sess, _ = small_runs
    rec = sess.recorder
    jsonl = read_jsonl(rec.jsonl_path)
    assert len(jsonl) == len(rec.events())
    doc = json.load(open(rec.chrome_path))
    assert len(doc["traceEvents"]) == len(jsonl)


def test_session_metric_sources_and_stage_split(small_runs):
    _, _, sess, _ = small_runs
    snap = sess.recorder.registry.snapshot()
    assert snap["sources"]["gram_cache"]["hits"] >= 0
    assert snap["sources"]["feature_bank"]["builds"] > 0
    assert "degradations" in snap["sources"]
    assert snap["counters"]["span.fold.count"] >= 1
    split = engine_stage_split(sess.recorder)
    assert split["path"] in ("device", "host")
    assert split["gram_s"] >= 0 and split["fold_s"] >= 0


def test_sweep_log_is_json_safe(small_runs):
    """The end_sweep seam converts every record: no numpy/jax scalars or
    arrays survive into RunState payloads, and step tuples stay tuples."""
    _, _, sess, _ = small_runs

    def walk(o):
        if isinstance(o, dict):
            for v in o.values():
                walk(v)
        elif isinstance(o, (list, tuple)):
            for v in o:
                walk(v)
        else:
            assert o is None or type(o) in (bool, int, float, str), repr(o)

    assert sess.sweep_log
    for recd in sess.sweep_log:
        walk(recd)
    applied = [r for r in sess.sweep_log if r.get("step")]
    assert applied and all(type(r["step"]) is tuple for r in applied)


def test_sweep_log_keys_unchanged_by_obs(small_runs):
    """Observability must not add/remove sweep_log keys (back-compat)."""
    off, _, sess, _ = small_runs
    data = _chain_data(**_SMALL)
    plain = DiscoverySession(data, options=EngineOptions())
    plain.run()
    assert len(plain.sweep_log) == len(sess.sweep_log)
    for a, b in zip(plain.sweep_log, sess.sweep_log):
        assert set(a.keys()) == set(b.keys())


def test_end_sweep_rejects_unsafe_record():
    data = _chain_data(**_SMALL)
    sess = DiscoverySession(data, options=EngineOptions())
    sess.begin_sweep("t")
    sess.score_frontier([(0, ())])
    sess._active["poison"] = object()
    with pytest.raises(TypeError, match="poison"):
        sess.end_sweep(None)


# -- multi-tenant aggregation ---------------------------------------------


def test_session_manager_telemetry_and_tenant_isolation(tmp_path):
    from repro.serving import (
        DiscoveryRequest,
        ServingOptions,
        SessionManager,
    )

    data = _chain_data(**_SMALL)
    mgr = SessionManager(
        data,
        serving=ServingOptions(
            max_concurrent=3, obs="trace", trace_dir=str(tmp_path)
        ),
    )
    with mgr:
        tickets = [
            mgr.submit(DiscoveryRequest(tenant=f"t{i}", seed=i))
            for i in range(3)
        ]
        mid_sources = None
        results = []
        for t in tickets:
            results.append(t.result())
            if mid_sources is None:
                mid_sources = set(mgr.metrics_snapshot()["sources"])
        tel = mgr.telemetry()
    # the full pre-existing schema, bitwise keys
    assert set(tel.keys()) == {
        "stats", "degradations", "constraint", "latency",
        "feature_bank", "gram_caches", "shared_mb",
    }
    assert set(tel["stats"]) == {
        "admitted", "shed", "completed", "deadline_exceeded",
        "cancelled", "failed",
    }
    assert tel["stats"]["admitted"] == 3 and tel["stats"]["completed"] == 3
    assert set(tel["degradations"]) == {
        "shrink_device", "evict_to_host", "reroute_backend",
    }
    assert {"sessions", "ci_tests", "cached", "pruned_pairs", "skeleton_s"} \
        <= set(tel["constraint"])
    assert tel["latency"]["n"] == 3

    # shared registry: serving sources always on; per-tenant sources are
    # prefix-namespaced while live and detached after completion
    snap = mgr.metrics_snapshot()
    assert {"serving.stats", "serving.degradations", "serving.constraint",
            "serving.feature_bank", "serving.latency"} <= set(snap["sources"])
    assert snap["sources"]["serving.stats"]["completed"] == 3
    tenant_sources = {
        s for s in (mid_sources or ()) if s.startswith("tenant.")
    }
    for s in tenant_sources:  # any live-captured tenant source was namespaced
        assert s.split(".")[1] in {"t0", "t1", "t2"}
    assert not any(s.startswith("tenant.") for s in snap["sources"])
    assert mgr.prometheus().startswith("# TYPE")

    # per-tenant trace files: every event in a tenant's file carries that
    # tenant's label and no other tenant's
    jsonls = [f for f in tmp_path.iterdir() if f.suffix == ".jsonl"]
    assert len(jsonls) == 3
    seen_tenants = set()
    for f in jsonls:
        evs = read_jsonl(str(f))
        assert evs and not validate_events(evs)
        tenants = {e["args"]["tenant"] for e in evs}
        assert len(tenants) == 1, f"cross-tenant leak in {f.name}"
        seen_tenants |= tenants
    assert seen_tenants == {"t0", "t1", "t2"}


# -- overhead smoke --------------------------------------------------------


def test_disabled_span_is_cheap():
    """Loose smoke bound: a disabled span must cost well under 10us (the
    real budget is ns — benchmarks/obs_overhead.py measures it)."""
    iters = 20_000
    t0 = time.perf_counter()
    for _ in range(iters):
        with obs_trace.span("x"):
            pass
    per = (time.perf_counter() - t0) / iters
    assert per < 10e-6, f"disabled span cost {per*1e9:.0f}ns"
