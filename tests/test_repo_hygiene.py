"""Repo hygiene: compiled caches must never ship or shadow source, and
the user-facing docs must never dangle.

Companion to the conftest.py collection guard (`_purge_stale_bytecode`):
these assert the *tracked* tree stays clean and the guard actually drops
stale cache files.  The docs-consistency tests parse README.md and
docs/ARCHITECTURE.md and fail on any file path that does not exist or any
`repro.*` dotted name that does not import — CI runs this module in its
docs job, so a refactor cannot silently strand the documentation.
"""

import importlib
import os
import re
import subprocess
import sys
import time

import pytest

from conftest import _ROOT, _purge_stale_bytecode


def _git(*args):
    return subprocess.run(
        ["git", *args], cwd=_ROOT, capture_output=True, text=True, timeout=60
    )


def test_no_bytecode_tracked_in_git():
    """`__pycache__` / `.pyc` must never be committed: a tracked cache file
    reappears on checkout and can shadow source edits forever."""
    res = _git("ls-files")
    if res.returncode != 0:
        pytest.skip("not a git checkout")
    bad = [
        f
        for f in res.stdout.splitlines()
        if "__pycache__" in f or f.endswith((".pyc", ".pyo"))
    ]
    assert bad == [], f"compiled caches tracked in git: {bad}"


def test_gitignore_covers_bytecode():
    with open(os.path.join(_ROOT, ".gitignore")) as f:
        lines = {ln.strip() for ln in f}
    assert "__pycache__/" in lines
    assert "*.pyc" in lines


_DOC_FILES = ("README.md", os.path.join("docs", "ARCHITECTURE.md"))

# path-like tokens: markdown link targets and backticked repo paths
_MD_LINK = re.compile(r"\]\(([^)#\s]+)\)")
_TICKED = re.compile(r"`([^`\n]+)`")
_PATHLIKE = re.compile(
    r"^(?:src|tests|benchmarks|examples|docs|\.github)/[\w./-]+$|^[\w.-]+\.(?:md|json|py|yml|txt)$"
)
_DOTTED = re.compile(r"\brepro(?:\.\w+)+")


def _doc_path_refs(text):
    """File references a doc makes: link targets plus backticked tokens
    that look like repo paths (``tests/foo.py::test_bar`` counts as
    ``tests/foo.py``)."""
    refs = set(_MD_LINK.findall(text))
    for tok in _TICKED.findall(text):
        tok = tok.split("::")[0].strip()
        if _PATHLIKE.match(tok):
            refs.add(tok)
    return {r.split("::")[0] for r in refs if not r.startswith("http")}


def _resolve_dotted(name: str) -> bool:
    """True iff a dotted ``repro.x.y`` reference resolves to an importable
    module or an attribute of one (longest importable prefix + getattrs)."""
    parts = name.split(".")
    for cut in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:cut]))
        except ImportError:
            continue
        try:
            for attr in parts[cut:]:
                obj = getattr(obj, attr)
        except AttributeError:
            return False
        return True
    return False


@pytest.mark.parametrize("doc", _DOC_FILES)
def test_doc_file_references_resolve(doc):
    """Every file path README/ARCHITECTURE mention must exist: dangling
    pointers in the entry-point docs are treated as broken builds."""
    doc_path = os.path.join(_ROOT, doc)
    with open(doc_path) as f:
        text = f.read()
    base = os.path.dirname(doc_path)
    missing = []
    for ref in sorted(_doc_path_refs(text)):
        # links resolve relative to the doc; bare repo paths from the root
        if not (
            os.path.exists(os.path.join(base, ref))
            or os.path.exists(os.path.join(_ROOT, ref))
        ):
            missing.append(ref)
    assert missing == [], f"{doc} references missing files: {missing}"


@pytest.mark.parametrize("doc", _DOC_FILES)
def test_doc_code_references_resolve(doc):
    """Every ``repro.*`` dotted name README/ARCHITECTURE mention must
    import (module, or attribute reachable from one)."""
    with open(os.path.join(_ROOT, doc)) as f:
        text = f.read()
    names = sorted(set(_DOTTED.findall(text)))
    assert names, f"{doc} should anchor itself to code with repro.* refs"
    bad = [n for n in names if not _resolve_dotted(n)]
    assert bad == [], f"{doc} references unresolvable code names: {bad}"


# The PR-4 declarative surface: the entry-point docs must present it and
# every presented name must import.  A plain grep for `repro.*` tokens
# cannot catch a doc that silently *stops* mentioning the public API, so
# the required names are pinned here.
_REQUIRED_API_NAMES = (
    "repro.core.spec.DataSpec",
    "repro.core.spec.EngineOptions",
    "repro.core.api.DiscoverySession",
)


def test_declarative_api_documented_and_importable():
    text = ""
    for doc in _DOC_FILES:
        with open(os.path.join(_ROOT, doc)) as f:
            text += f.read()
    for name in _REQUIRED_API_NAMES:
        short = name.rsplit(".", 1)[1]
        assert short in text, f"docs never mention {short} ({name})"
        assert _resolve_dotted(name), f"{name} does not import"


def test_repo_code_never_calls_its_own_deprecated_surface():
    """The PR-4 legacy kwargs served their one deprecation release and
    are now *removed* — calling them raises TypeError at runtime.  Keep
    the static AST scan over src/examples/benchmarks so code the suite
    never executes still fails loudly here, with file:line, instead of
    at a user's first call."""
    import ast

    deprecated_kwargs = {
        "dims", "discrete", "batched",
        "gram_cache_entries", "device_bank_mb", "batch_hook",
    }
    shimmed_fns = {"causal_discover", "make_scorer"}
    offenders = []
    roots = [
        os.path.join(_ROOT, "src", "repro"),
        os.path.join(_ROOT, "examples"),
        os.path.join(_ROOT, "benchmarks"),
    ]
    for root in roots:
        for dirpath, _, files in os.walk(root):
            if "__pycache__" in dirpath:
                continue
            for fn in files:
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                with open(path) as f:
                    tree = ast.parse(f.read(), filename=path)
                for node in ast.walk(tree):
                    if not isinstance(node, ast.Call):
                        continue
                    callee = node.func
                    name = (
                        callee.id
                        if isinstance(callee, ast.Name)
                        else callee.attr
                        if isinstance(callee, ast.Attribute)
                        else None
                    )
                    if name not in shimmed_fns:
                        continue
                    bad = sorted(
                        kw.arg
                        for kw in node.keywords
                        if kw.arg in deprecated_kwargs
                    )
                    if bad:
                        rel = os.path.relpath(path, _ROOT)
                        offenders.append(f"{rel}:{node.lineno} {name}({bad})")
    assert offenders == [], (
        f"repo code calls the deprecated kwarg surface: {offenders}"
    )


def test_repo_code_never_imports_deprecated_lowrank_location():
    """`repro.core.lowrank` served its one release as a shim over
    `repro.features.backends` and is removed; any import of it is now an
    ImportError.  This static scan keeps the failure at file:line for
    code paths the suite never executes."""
    import ast

    offenders = []
    roots = [
        os.path.join(_ROOT, "src", "repro"),
        os.path.join(_ROOT, "examples"),
        os.path.join(_ROOT, "benchmarks"),
    ]
    for root in roots:
        for dirpath, _, files in os.walk(root):
            if "__pycache__" in dirpath:
                continue
            for fn in files:
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                with open(path) as f:
                    tree = ast.parse(f.read(), filename=path)
                for node in ast.walk(tree):
                    bad = None
                    if isinstance(node, ast.ImportFrom):
                        if node.module and node.module.startswith(
                            "repro.core.lowrank"
                        ):
                            bad = node.module
                    elif isinstance(node, ast.Import):
                        for alias in node.names:
                            if alias.name.startswith("repro.core.lowrank"):
                                bad = alias.name
                    if bad:
                        rel = os.path.relpath(path, _ROOT)
                        offenders.append(f"{rel}:{node.lineno} {bad}")
    assert offenders == [], (
        "repo code imports the deprecated repro.core.lowrank shim "
        f"(use repro.features.backends): {offenders}"
    )


def test_collection_guard_purges_stale_and_orphaned_pyc(tmp_path):
    """The conftest guard must drop (a) orphaned .pyc whose source is gone
    and (b) .pyc not strictly newer than their source, while keeping a
    fresh cache."""
    pkg = tmp_path / "pkg"
    cache = pkg / "__pycache__"
    cache.mkdir(parents=True)
    tag = sys.implementation.cache_tag or "cpython-310"

    fresh_src = pkg / "fresh.py"
    fresh_src.write_text("x = 1\n")
    fresh_pyc = cache / f"fresh.{tag}.pyc"
    fresh_pyc.write_bytes(b"\x00")
    now = time.time()
    os.utime(fresh_src, (now - 100, now - 100))
    os.utime(fresh_pyc, (now, now))

    stale_src = pkg / "stale.py"
    stale_src.write_text("x = 2\n")
    stale_pyc = cache / f"stale.{tag}.pyc"
    stale_pyc.write_bytes(b"\x00")
    os.utime(stale_src, (now, now))
    os.utime(stale_pyc, (now - 100, now - 100))

    orphan_pyc = cache / f"deleted_module.{tag}.pyc"
    orphan_pyc.write_bytes(b"\x00")

    _purge_stale_bytecode(str(tmp_path))
    assert fresh_pyc.exists(), "fresh cache must be kept"
    assert not stale_pyc.exists(), "stale cache must be purged"
    assert not orphan_pyc.exists(), "orphaned cache must be purged"
