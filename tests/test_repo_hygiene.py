"""Repo hygiene: compiled caches must never ship or shadow source.

Companion to the conftest.py collection guard (`_purge_stale_bytecode`):
these assert the *tracked* tree stays clean and the guard actually drops
stale cache files.
"""

import os
import subprocess
import sys
import time

import pytest

from conftest import _ROOT, _purge_stale_bytecode


def _git(*args):
    return subprocess.run(
        ["git", *args], cwd=_ROOT, capture_output=True, text=True, timeout=60
    )


def test_no_bytecode_tracked_in_git():
    """`__pycache__` / `.pyc` must never be committed: a tracked cache file
    reappears on checkout and can shadow source edits forever."""
    res = _git("ls-files")
    if res.returncode != 0:
        pytest.skip("not a git checkout")
    bad = [
        f
        for f in res.stdout.splitlines()
        if "__pycache__" in f or f.endswith((".pyc", ".pyo"))
    ]
    assert bad == [], f"compiled caches tracked in git: {bad}"


def test_gitignore_covers_bytecode():
    with open(os.path.join(_ROOT, ".gitignore")) as f:
        lines = {ln.strip() for ln in f}
    assert "__pycache__/" in lines
    assert "*.pyc" in lines


def test_collection_guard_purges_stale_and_orphaned_pyc(tmp_path):
    """The conftest guard must drop (a) orphaned .pyc whose source is gone
    and (b) .pyc not strictly newer than their source, while keeping a
    fresh cache."""
    pkg = tmp_path / "pkg"
    cache = pkg / "__pycache__"
    cache.mkdir(parents=True)
    tag = sys.implementation.cache_tag or "cpython-310"

    fresh_src = pkg / "fresh.py"
    fresh_src.write_text("x = 1\n")
    fresh_pyc = cache / f"fresh.{tag}.pyc"
    fresh_pyc.write_bytes(b"\x00")
    now = time.time()
    os.utime(fresh_src, (now - 100, now - 100))
    os.utime(fresh_pyc, (now, now))

    stale_src = pkg / "stale.py"
    stale_src.write_text("x = 2\n")
    stale_pyc = cache / f"stale.{tag}.pyc"
    stale_pyc.write_bytes(b"\x00")
    os.utime(stale_src, (now, now))
    os.utime(stale_pyc, (now - 100, now - 100))

    orphan_pyc = cache / f"deleted_module.{tag}.pyc"
    orphan_pyc.write_bytes(b"\x00")

    _purge_stale_bytecode(str(tmp_path))
    assert fresh_pyc.exists(), "fresh cache must be kept"
    assert not stale_pyc.exists(), "stale cache must be purged"
    assert not orphan_pyc.exists(), "orphaned cache must be purged"
