"""Dry-run machinery test: 512 placeholder devices, both production meshes,
and a compile of the paper's distributed workload on the multi-pod mesh.

Runs in a subprocess because XLA_FLAGS must be set before jax initializes
(the main test process keeps 1 CPU device)."""

import subprocess
import sys
import textwrap


def test_production_meshes_and_multipod_compile():
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_production_mesh

        single = make_production_mesh()
        multi = make_production_mesh(multi_pod=True)
        assert dict(single.shape) == {"data": 16, "model": 16}, single.shape
        assert dict(multi.shape) == {"pod": 2, "data": 16, "model": 16}
        assert len(jax.devices()) == 512

        # the paper's workload (reduced size) must lower+compile multi-pod
        import repro.core  # x64
        from repro.core.distributed_score import make_sharded_scorer
        fn = make_sharded_scorer(multi, data_axis="data", model_axis="model")
        spec = jax.ShapeDtypeStruct((32, 4, 1600, 16), jnp.float64)
        sh = NamedSharding(multi, P("model", None, "data", None))
        from repro.launch.mesh import mesh_context
        with mesh_context(multi):
            compiled = jax.jit(fn, in_shardings=(sh, sh)).lower(spec, spec).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):  # jax < 0.5: one dict per program
            cost = cost[0]
        hlo = compiled.as_text()
        assert cost["flops"] > 0
        assert "all-reduce" in hlo, "expected psum over the data axis"
        print("MULTIPOD_OK", cost["flops"])
        """
    )
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=420,
        # forced-host-device test: never probe for accelerators (a present
        # libtpu otherwise stalls child startup on TPU metadata lookups)
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert "MULTIPOD_OK" in proc.stdout, proc.stderr[-3000:]
