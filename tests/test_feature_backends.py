"""Feature-backend registry + contracts (PR 5).

Every registered backend must honor the `FeatureResult` contract — a
centered, zero-padded fixed-width ``(n, m_max)`` float64 factor with live
rank ``m_eff`` — plus backend-specific accuracy guarantees: RFF within
its documented statistical tolerance, nystrom(leverage) within the eta
bound ICL satisfies on the tier-1 fixtures, the stratified sampler
recovering the exact decomposition on covered discrete data.
"""

import numpy as np
import pytest

try:  # optional dev dep (requirements-dev.txt): only gates the property test
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover
    given = None

import repro.core  # noqa: F401 — enables x64 before any factor math

from repro.core.kernel_fns import KernelSpec, kernel_matrix, median_heuristic_width, standardize
from repro.features.backends import (
    BuildContext,
    FeatureResult,
    RandomFourierBackend,
    available_backends,
    build_features,
    get_backend,
    incomplete_cholesky,
    lowrank_features,
)
from repro.features.policy import BackendChoice

ALL_BACKENDS = ("icl", "discrete_exact", "rff", "nystrom")


def _cont(n=120, d=2, seed=0):
    return np.random.default_rng(seed).standard_normal((n, d))


def _disc(n=150, card=4, seed=1):
    return np.random.default_rng(seed).integers(0, card, (n, 1)).astype(float)


def _gram_err(res: FeatureResult, x) -> float:
    """Max |factor factor^T - K~| against the centered exact kernel."""
    from repro.core.kernel_fns import center_gram

    xs = standardize(np.asarray(x, float))
    k = np.asarray(center_gram(kernel_matrix(xs, xs, res.spec)))
    approx = np.asarray(res.factor @ res.factor.T)
    return float(np.abs(approx - k).max())


# -- registry --------------------------------------------------------------


def test_registry_contains_the_four_backends():
    assert set(ALL_BACKENDS) <= set(available_backends())


def test_unknown_backend_raises_with_registered_list():
    with pytest.raises(ValueError, match="registered backends"):
        get_backend("pca")
    with pytest.raises(ValueError, match="registered backends"):
        build_features(_cont(), BackendChoice("pca"), BuildContext())


def test_unknown_backend_params_raise():
    with pytest.raises(ValueError, match="rejected params"):
        build_features(
            _cont(), BackendChoice.of("rff", frequencies=7), BuildContext()
        )
    with pytest.raises(ValueError, match="sampler"):
        build_features(
            _cont(), BackendChoice.of("nystrom", sampler="grid"), BuildContext()
        )


# -- the FeatureResult contract, all backends ------------------------------


@pytest.mark.parametrize("backend", ALL_BACKENDS)
@pytest.mark.parametrize("x_fn", [_cont, _disc])
def test_contract_fixed_width_zero_padded_centered(backend, x_fn):
    x = x_fn()
    m_max = 32
    res = build_features(
        x, BackendChoice(backend), BuildContext(m_max=m_max, salt=(0,))
    )
    lam = np.asarray(res.factor)
    assert lam.shape == (x.shape[0], m_max)
    assert lam.dtype == np.float64
    assert 1 <= res.m_eff <= m_max
    # zero-padding beyond the live rank is exact (score-neutrality relies
    # on it), and the factor is centered (H Lambda): column means ~ 0
    assert np.all(lam[:, res.m_eff :] == 0.0)
    np.testing.assert_allclose(lam.mean(axis=0), 0.0, atol=1e-9)
    assert res.backend in available_backends()
    assert "gram_resid" in res.info


# -- icl / discrete_exact (the migrated defaults) --------------------------


def test_discrete_exact_uses_known_levels_and_matches_counted_route():
    x = _disc()
    a = lowrank_features(x, discrete=True, m_max=32)
    b = lowrank_features(x, discrete=True, m_max=32, known_levels=4)
    assert a[1] == b[1]
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))


def test_discrete_exact_falls_back_to_icl_past_the_cap():
    x = np.arange(60, dtype=float)[:, None]  # 60 levels > m_max
    res = build_features(
        x, BackendChoice("discrete_exact"), BuildContext(m_max=16)
    )
    assert res.backend == "icl"
    assert res.info.get("fallback_from") == "discrete_exact"


# -- rff -------------------------------------------------------------------


def test_rff_gram_error_within_documented_tolerance():
    x = _cont(n=200, d=1, seed=3)
    m_max = 100
    res = build_features(x, BackendChoice("rff"), BuildContext(m_max=m_max))
    assert res.m_eff == 2 * (m_max // 2)
    err = _gram_err(res, x)
    tol = RandomFourierBackend.gram_error_bound(m_max // 2, x.shape[0])
    assert res.info["gram_tol"] == tol
    assert err <= tol, (err, tol)
    # and the approximation is genuinely informative, not just bounded
    assert err < 0.5


def test_rff_is_seed_deterministic_and_salt_distinct():
    x = _cont(n=80, d=2, seed=5)
    ctx = BuildContext(m_max=24, seed=11, salt=(3,))
    a = build_features(x, BackendChoice("rff"), ctx)
    b = build_features(x, BackendChoice("rff"), ctx)
    np.testing.assert_array_equal(np.asarray(a.factor), np.asarray(b.factor))
    c = build_features(
        x, BackendChoice("rff"), BuildContext(m_max=24, seed=11, salt=(4,))
    )
    assert not np.array_equal(np.asarray(a.factor), np.asarray(c.factor))
    d = build_features(
        x, BackendChoice("rff"), BuildContext(m_max=24, seed=12, salt=(3,))
    )
    assert not np.array_equal(np.asarray(a.factor), np.asarray(d.factor))


def test_rff_rejects_non_rbf_kernels_and_tiny_budget():
    x = _cont(n=40)
    with pytest.raises(ValueError, match="RBF"):
        build_features(
            x, BackendChoice("rff"), BuildContext(spec=KernelSpec("delta", 1.0))
        )
    with pytest.raises(ValueError, match="m_max"):
        build_features(x, BackendChoice("rff"), BuildContext(m_max=1))


# -- nystrom ---------------------------------------------------------------


@pytest.mark.parametrize("sampler", ["uniform", "leverage", "stratified"])
def test_nystrom_samplers_approximate_the_kernel(sampler):
    x = _cont(n=150, d=1, seed=1)
    ctx = BuildContext(m_max=64, salt=(0,), discrete_mask=(False,))
    res = build_features(x, BackendChoice.of("nystrom", sampler=sampler), ctx)
    assert res.info["sampler"] == sampler
    assert _gram_err(res, x) < 5e-2


def test_nystrom_leverage_within_icl_eta_bound_on_tier1_fixture():
    """On the tier-1 ICL fixture (150 x 1 RBF — test_icl_eta_bound), ICL
    with eta=1e-6 guarantees reconstruction error < 1e-3; leverage-score
    Nystroem at the same budget must do no worse than that bound."""
    rng = np.random.default_rng(1)
    x = rng.standard_normal((150, 1))
    spec = KernelSpec("rbf", median_heuristic_width(x))
    k = np.asarray(kernel_matrix(x, x, spec))
    lam_icl, m_icl = incomplete_cholesky(x, spec, m_max=100, eta=1e-6)
    icl_err = np.abs(np.asarray(lam_icl @ lam_icl.T) - k).max()
    assert icl_err < 1e-3  # the eta-derived bound the fixture asserts

    res = build_features(
        x,
        BackendChoice.of("nystrom", sampler="leverage"),
        BuildContext(m_max=100, standardize=False, spec=spec),
    )
    lam = np.asarray(res.factor)
    # compare against the centered kernel (the backend centers factors)
    from repro.core.kernel_fns import center_gram

    kc = np.asarray(center_gram(kernel_matrix(x, x, spec)))
    lev_err = np.abs(lam @ lam.T - kc).max()
    assert lev_err <= 1e-3, (lev_err, icl_err)


def test_nystrom_stratified_is_exact_on_covered_discrete_data():
    """When the stratified sampler's strata cover every level of a
    discrete variable and the budget reaches the cardinality, landmark
    Nystroem IS the exact Alg.-2 decomposition (up to jitter)."""
    x = _disc(n=200, card=5, seed=2)
    res = build_features(
        x,
        BackendChoice.of("nystrom", sampler="stratified"),
        BuildContext(m_max=16, discrete_mask=(True,)),
    )
    assert res.m_eff == 5  # one landmark per level, deduplicated
    assert _gram_err(res, x) < 1e-5


def test_nystrom_stratified_mixed_set_stratifies_on_discrete_cols():
    rng = np.random.default_rng(9)
    x = np.concatenate(
        [rng.integers(0, 3, (120, 1)).astype(float), rng.standard_normal((120, 1))],
        axis=1,
    )
    res = build_features(
        x,
        BackendChoice.of("nystrom", sampler="stratified"),
        BuildContext(m_max=30, discrete_mask=(True, False)),
    )
    # every stratum (3 levels) must contribute landmarks
    assert res.m_eff >= 3
    assert _gram_err(res, x) < 0.3


def test_nystrom_uniform_deterministic_under_seed():
    x = _cont(n=100, d=2, seed=8)
    ctx = BuildContext(m_max=20, seed=5, salt=(1,))
    a = build_features(x, BackendChoice.of("nystrom", sampler="uniform"), ctx)
    b = build_features(x, BackendChoice.of("nystrom", sampler="uniform"), ctx)
    np.testing.assert_array_equal(np.asarray(a.factor), np.asarray(b.factor))


# -- property test (hypothesis-gated, module still collects without it) ----

if given is not None:

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(24, 60),
        d=st.integers(1, 3),
        m_half=st.integers(3, 10),
        backend=st.sampled_from(ALL_BACKENDS),
        seed=st.integers(0, 5),
    )
    def test_property_every_backend_honors_the_factor_contract(
        n, d, m_half, backend, seed
    ):
        m_max = 2 * m_half
        rng = np.random.default_rng(seed)
        x = rng.standard_normal((n, d))
        if backend == "discrete_exact":
            x = np.round(x)  # keep the cardinality under the budget
        res = build_features(
            x,
            BackendChoice(backend),
            BuildContext(m_max=m_max, seed=seed, salt=(n,)),
        )
        lam = np.asarray(res.factor)
        assert lam.shape == (n, m_max)
        assert 1 <= res.m_eff <= m_max
        assert np.all(lam[:, res.m_eff :] == 0.0)
        np.testing.assert_allclose(lam.mean(axis=0), 0.0, atol=1e-8)

else:  # pragma: no cover

    @pytest.mark.skip(reason="hypothesis not installed (requirements-dev.txt)")
    def test_property_every_backend_honors_the_factor_contract():
        pass
