"""Batched frontier scoring engine vs the sequential oracle.

The batched path (feature bank + Gram-block cache + chunked fold algebra,
score_lowrank.cvlr_scores_batched) must reproduce the sequential
per-candidate `local_score` to <= 1e-8 — including the |Z|=0 zero-factor
specialization and discrete (Alg. 2) variables — and its Gram-block cache
must show the predicted sharing: each child's Grams computed once per
sweep, everything a hit afterwards.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import EngineOptions, causal_discover, make_scorer
from repro.core.ges import ges
from repro.features.backends import lowrank_features
from repro.core.score_common import GramBlockCache, ScoreConfig, config_key
from repro.core.score_lowrank import (
    CVLRScorer,
    cvlr_score_from_features,
    cvlr_scores_batched,
)
from repro.data.synthetic import generate_scm_data


def _rel_err(a, b):
    return abs(a - b) / max(1.0, abs(b))


def _frontier_configs(d, extra=()):
    """Sweep-1 GES frontier: every (child, single-parent) + every |Z|=0."""
    configs = [(y, ()) for y in range(d)]
    configs += [(y, (x,)) for x in range(d) for y in range(d) if x != y]
    return configs + list(extra)


@pytest.mark.parametrize("kind", ["continuous", "mixed"])
def test_batched_matches_sequential_oracle(kind):
    """Random graph data; batched scores == sequential oracle to <= 1e-8,
    covering |Z|=0, multi-parent sets and (for `mixed`) Alg.-2 discrete
    variables."""
    ds = generate_scm_data(d=5, n=250, density=0.4, kind=kind, seed=9)
    mk = lambda batched: CVLRScorer(
        ds.data,
        dims=ds.dims,
        discrete=ds.discrete,
        config=ScoreConfig(seed=2),
        batched=batched,
    )
    s_bat, s_seq = mk(True), mk(False)
    configs = _frontier_configs(
        5, extra=[(4, (0, 1)), (3, (0, 1, 2)), (0, (2, 3, 4))]
    )
    n_done = s_bat.prefetch(configs)
    assert n_done == len(configs)
    for i, ps in configs:
        got = s_bat._score_cache[config_key(i, ps)]
        want = s_seq.local_score(i, ps)
        assert _rel_err(got, want) <= 1e-8, (i, ps, got, want)


def test_batched_all_discrete():
    """Pure Alg.-2 path: every variable discrete."""
    rng = np.random.default_rng(3)
    data = rng.integers(0, 4, size=(240, 4)).astype(np.float64)
    mk = lambda batched: CVLRScorer(
        data, discrete=[True] * 4, config=ScoreConfig(seed=1), batched=batched
    )
    s_bat, s_seq = mk(True), mk(False)
    configs = _frontier_configs(4, extra=[(3, (0, 1))])
    s_bat.prefetch(configs)
    for i, ps in configs:
        got = s_bat._score_cache[config_key(i, ps)]
        want = s_seq.local_score(i, ps)
        assert _rel_err(got, want) <= 1e-8, (i, ps, got, want)


def test_cvlr_scores_batched_direct_banks():
    """Direct bank/pairs API vs per-pair sequential scores, with live-rank
    trimming exercised (m_eff << padded width) and a zero z factor."""
    rng = np.random.default_rng(0)
    n, q, m_pad = 200, 10, 24

    def factor(m_live):
        lam = rng.standard_normal((n, m_live))
        lam = np.concatenate([lam, np.zeros((n, m_pad - m_live))], axis=1)
        lam -= lam.mean(axis=0, keepdims=True)
        return jnp.asarray(lam)

    x_bank = [factor(m) for m in (3, 7, 5)]
    z_bank = [factor(m) for m in (4, 11)] + [jnp.zeros((n, m_pad))]
    m_eff_x = [3, 7, 5]
    m_eff_z = [4, 11, 0]
    pairs = [(xi, zi) for xi in range(3) for zi in range(3)]
    got = cvlr_scores_batched(
        x_bank, z_bank, pairs, q, m_eff_x=m_eff_x, m_eff_z=m_eff_z
    )
    lm = jnp.float64(0.01)
    for (xi, zi), g in zip(pairs, got):
        want = float(
            cvlr_score_from_features(x_bank[xi], z_bank[zi], q, lm, lm)
        )
        assert _rel_err(float(g), want) <= 1e-8


def test_gram_cache_hit_counts_match_predicted_sharing():
    """Sweep-1 frontier with d children: each child's diagonal Gram blocks
    are computed exactly once (d misses), the single-variable parent sets
    reuse them (d hits), cross blocks are one miss per *unordered*
    (parent, child) factor pair — U(a, b) = U(b, a)^T, so the X -> Y and
    Y -> X candidates share one block and the cross-Gram work halves —
    and a re-scored identical frontier is 100% hits."""
    rng = np.random.default_rng(7)
    d, n = 4, 200
    data = rng.standard_normal((n, d))
    s = CVLRScorer(data, config=ScoreConfig(seed=0))
    configs = _frontier_configs(d)
    s.prefetch(configs)
    n_cross = d * (d - 1) // 2  # unordered pairs
    # diag V: d misses; diag S (single-var z == child sets): d hits;
    # cross U: one miss per unordered pair (both orientations collapse
    # onto the canonical key); |Z|=0 blocks never touch the cache.
    assert s.gram_cache.misses == d + n_cross, s.gram_cache.stats
    assert s.gram_cache.hits == d, s.gram_cache.stats
    assert len(s.gram_cache) == d + n_cross
    assert s.gram_cache.evictions == 0, s.gram_cache.stats

    # same frontier again, scores wiped: every Gram lookup is a hit.
    s._score_cache.clear()
    s.prefetch(configs)
    assert s.gram_cache.misses == d + n_cross, s.gram_cache.stats
    assert s.gram_cache.hits == d + 2 * d + n_cross, s.gram_cache.stats


def test_zshared_cores_match_sequential_oracle():
    """The z-shared fold-core path (one Cholesky per parent set, reused
    across all of its children) == sequential oracle to <= 1e-8: frontiers
    where one parent set has MANY children, mixing |Z| in {0, 1, 2, 3}
    and bucket widths, so every score flows through a shared core."""
    ds = generate_scm_data(d=7, n=280, density=0.5, kind="continuous", seed=13)
    mk = lambda batched: CVLRScorer(
        ds.data,
        dims=ds.dims,
        discrete=ds.discrete,
        config=ScoreConfig(seed=3),
        batched=batched,
    )
    s_bat, s_seq = mk(True), mk(False)
    parent_sets = [(), (0,), (1, 2), (0, 3, 5)]
    configs = [
        (y, ps) for ps in parent_sets for y in range(7) if y not in ps
    ]
    n_done = s_bat.prefetch(configs)
    assert n_done == len(configs)
    for i, ps in configs:
        got = s_bat._score_cache[config_key(i, ps)]
        want = s_seq.local_score(i, ps)
        assert _rel_err(got, want) <= 1e-8, (i, ps, got, want)


def test_gram_cache_lru_eviction():
    """LRU bound: least-recently-used entries evict first, get/put refresh
    recency, and the eviction counter is exposed in stats."""
    c = GramBlockCache(max_entries=2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1  # refreshes "a" -> "b" is now LRU
    c.put("x", 3)  # evicts "b"
    assert c.get("b") is None
    assert c.get("a") == 1 and c.get("x") == 3
    assert c.evictions == 1 and len(c) == 2
    st = c.stats
    assert st["evictions"] == 1 and st["max_entries"] == 2
    assert st["hits"] == 3 and st["misses"] == 1

    unbounded = GramBlockCache()
    assert unbounded.stats["max_entries"] is None
    with pytest.raises(ValueError):
        GramBlockCache(max_entries=0)


def test_gram_cache_bound_is_configurable_and_exact_under_pressure():
    """An engine squeezed to a tiny Gram cache (via api.make_scorer) must
    recompute evicted blocks, never mis-score: results stay identical to
    an unbounded-cache scorer, with evictions actually occurring."""
    rng = np.random.default_rng(11)
    d, n = 4, 200
    data = rng.standard_normal((n, d))
    configs = _frontier_configs(d)
    tight = make_scorer(
        data,
        config=ScoreConfig(seed=0),
        options=EngineOptions(gram_cache_entries=2),
    )
    loose = make_scorer(data, config=ScoreConfig(seed=0))
    assert tight.gram_cache.max_entries == 2
    tight.prefetch(configs)
    loose.prefetch(configs)
    # two sweeps to force re-derivation from an evicted state
    tight._score_cache.clear()
    tight.prefetch(configs)
    assert tight.gram_cache.evictions > 0, tight.gram_cache.stats
    for i, ps in configs:
        a = tight._score_cache[config_key(i, ps)]
        b = loose._score_cache[config_key(i, ps)]
        assert _rel_err(a, b) <= 1e-12, (i, ps, a, b)


def test_ges_batched_default_equals_sequential_search():
    """ges() on the default batched engine selects the same equivalence
    class, same total score, as the sequential fallback."""
    rng = np.random.default_rng(1)
    n = 250
    x0 = rng.standard_normal(n)
    x1 = np.tanh(x0) + 0.3 * rng.standard_normal(n)
    x2 = np.sin(x1) + 0.3 * rng.standard_normal(n)
    data = np.stack([x0, x1, x2], axis=1)
    r_seq = ges(CVLRScorer(data, config=ScoreConfig(seed=5), batched=False))
    r_bat = ges(CVLRScorer(data, config=ScoreConfig(seed=5)))
    np.testing.assert_array_equal(r_seq.cpdag, r_bat.cpdag)
    assert _rel_err(r_bat.score, r_seq.score) <= 1e-8


def test_causal_discover_engine_option():
    """Public API: `EngineOptions(engine=...)` toggles the batched engine
    against the sequential oracle without changing the result."""
    rng = np.random.default_rng(2)
    n = 220
    x0 = rng.standard_normal(n)
    x1 = np.tanh(x0) + 0.4 * rng.standard_normal(n)
    data = np.stack([x0, x1], axis=1)
    r1 = causal_discover(data, config=ScoreConfig(seed=8))
    r2 = causal_discover(
        data,
        config=ScoreConfig(seed=8),
        options=EngineOptions(engine="sequential"),
    )
    np.testing.assert_array_equal(r1.cpdag, r2.cpdag)


def test_trimming_requires_zero_padding_invariant():
    """The trimming lever rests on ICL/Alg.-2 factors being exactly zero
    beyond m_eff — assert the invariant the engine relies on."""
    rng = np.random.default_rng(4)
    x = rng.standard_normal((150, 1))
    lam, m_eff, _ = lowrank_features(x, m_max=32)
    assert 0 < m_eff <= 32
    assert np.all(np.asarray(lam)[:, m_eff:] == 0.0)
