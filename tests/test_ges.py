"""GES end-to-end: recover structure on synthetic SCM + discrete networks."""

import numpy as np
import pytest

from repro.core.api import DataSpec, causal_discover
from repro.core.graph import dag_to_cpdag
from repro.core.metrics import shd_cpdag, skeleton_f1
from repro.core.score_common import ScoreConfig
from repro.data.networks import SACHS, sample_network
from repro.data.synthetic import generate_scm_data


def test_ges_recovers_chain():
    """x0 -> x1 -> x2 nonlinear chain: GES + CV-LR must find the skeleton."""
    rng = np.random.default_rng(0)
    n = 400
    x0 = rng.standard_normal(n)
    x1 = np.tanh(x0) + 0.3 * rng.standard_normal(n)
    x2 = np.sin(x1) + 0.3 * rng.standard_normal(n)
    data = np.stack([x0, x1, x2], axis=1)
    res = causal_discover(data, method="cvlr", config=ScoreConfig(seed=1))
    truth = np.zeros((3, 3), dtype=np.int8)
    truth[0, 1] = truth[1, 2] = 1
    f1 = skeleton_f1(res.cpdag, truth)
    assert f1 == 1.0, f"skeleton F1 {f1} (cpdag={res.cpdag})"


def test_ges_recovers_collider():
    """x0 -> x2 <- x1: the v-structure is identifiable and must be oriented."""
    rng = np.random.default_rng(4)
    n = 500
    x0 = rng.standard_normal(n)
    x1 = rng.standard_normal(n)
    x2 = np.tanh(x0) + np.sin(x1) + 0.3 * rng.standard_normal(n)
    data = np.stack([x0, x1, x2], axis=1)
    res = causal_discover(data, method="cvlr", config=ScoreConfig(seed=2))
    truth = np.zeros((3, 3), dtype=np.int8)
    truth[0, 2] = truth[1, 2] = 1
    assert skeleton_f1(res.cpdag, truth) == 1.0
    assert shd_cpdag(res.cpdag, dag_to_cpdag(truth)) == 0.0


@pytest.mark.parametrize("kind", ["continuous", "mixed"])
def test_ges_synthetic_scm(kind):
    ds = generate_scm_data(d=5, n=400, density=0.3, kind=kind, seed=7)
    res = causal_discover(
        ds.data,
        method="cvlr",
        spec=DataSpec.from_arrays(ds.data, dims=ds.dims, discrete=ds.discrete),
        config=ScoreConfig(seed=3),
    )
    f1 = skeleton_f1(res.cpdag, ds.dag)
    assert f1 >= 0.5, f"skeleton F1 too low: {f1}"


def test_ges_sachs_subset():
    """SACHS-structured discrete data, 6-node subgraph for test speed."""
    data, adj = sample_network(SACHS, n=600, seed=5)
    keep = [8, 7, 0, 1, 5, 6]  # PKC, PKA, Raf, Mek, Erk, Akt
    sub = data[:, keep]
    sub_adj = adj[np.ix_(keep, keep)]
    res = causal_discover(
        sub, method="cvlr",
        spec=DataSpec.from_arrays(sub, discrete=[True] * len(keep)),
        config=ScoreConfig(seed=4),
    )
    f1 = skeleton_f1(res.cpdag, sub_adj)
    assert f1 >= 0.6, f"skeleton F1 too low: {f1}"


def test_cv_and_cvlr_agree_on_search_result():
    """Paper Figs. 2-5: CV-LR tracks CV.  On a small instance the selected
    equivalence classes should match."""
    rng = np.random.default_rng(11)
    n = 300
    x0 = rng.standard_normal(n)
    x1 = np.sin(x0) + 0.4 * rng.standard_normal(n)
    x2 = np.tanh(x1 + x0) + 0.4 * rng.standard_normal(n)
    data = np.stack([x0, x1, x2], axis=1)
    res_cv = causal_discover(data, method="cv", config=ScoreConfig(seed=6))
    res_lr = causal_discover(data, method="cvlr", config=ScoreConfig(seed=6))
    np.testing.assert_array_equal(res_cv.cpdag, res_lr.cpdag)
