"""Pluggable feature-bank subsystem (PR 5): everything between raw data
columns and the centered ``(n, m_max)`` low-rank factors the CV-LR
scorer consumes.

Three layers, consumed in order by `repro.core.score_lowrank.CVLRScorer`:

* `repro.features.backends` — the factorization backend registry
  (``icl`` / ``discrete_exact`` migrated from the old
  ``repro.core.lowrank``, plus ``rff`` random Fourier features and
  ``nystrom`` landmark sampling with uniform / leverage / stratified
  samplers).  One contract: a centered, zero-padded fixed-width factor
  (`FeatureResult`).
* `repro.features.policy` — `FeaturePolicy`: variable-kind -> backend
  routing with per-variable overrides riding on the `DataSpec`;
  `FeaturePolicy.default()` reproduces the pre-PR-5 routing bitwise.
* `repro.features.bank` — `FeatureBank`: the session-owned keyed cache
  of built factors with rank / residual / hit-miss / build-time
  telemetry, shared across sweeps and sessions.

Select a policy through `repro.core.spec.EngineOptions(features=...)`.
"""

from repro.features.backends import (
    BuildContext,
    FeatureBackend,
    FeatureResult,
    available_backends,
    build_features,
    count_distinct_rows,
    discrete_lowrank,
    get_backend,
    incomplete_cholesky,
    lowrank_features,
    register_backend,
)
from repro.features.bank import FeatureBank
from repro.features.policy import BackendChoice, FeaturePolicy

__all__ = [
    "BackendChoice",
    "BuildContext",
    "FeatureBackend",
    "FeatureBank",
    "FeaturePolicy",
    "FeatureResult",
    "available_backends",
    "build_features",
    "count_distinct_rows",
    "discrete_lowrank",
    "get_backend",
    "incomplete_cholesky",
    "lowrank_features",
    "register_backend",
]
