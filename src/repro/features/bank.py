"""FeatureBank: the session-owned cache of built low-rank factors.

Building a variable set's factor is the expensive, sequential front of
the CV-LR pipeline (ICL's greedy pivot loop is O(n m) *per pivot*); a
GES run asks for the same sets sweep after sweep, and repeated sessions
over the same data ask for them again.  The bank is a keyed LRU cache of
`repro.features.backends.FeatureResult`s:

    key = (canonical variable-set key, build fingerprint)

where the fingerprint (composed by the scorer) pins everything that
shapes the factor — the resolved `BackendChoice` (backend + params), the
policy seed, and the score-config build knobs (m_max, eta, width_factor,
fold layout).  Two scorers sharing a bank therefore can never serve each
other a factor built under different routing; sharing a bank across
*different data matrices* is the caller's contract to avoid (the bank is
meant to be owned by a `repro.core.api.DiscoverySession` — or passed
between sessions over the same dataset, which is exactly the multi-sweep
rebuild-avoidance win).

Telemetry: hit/miss/build counters plus cumulative build seconds
(`stats`, surfaced per sweep by the session log) and per-entry
rank/backend/residual records (`entry_log`).
"""

from __future__ import annotations

import collections
import time


class FeatureBank:
    """Keyed LRU cache of built factors with build/hit/miss telemetry."""

    def __init__(self, max_entries: int | None = None):
        if max_entries is not None and int(max_entries) < 1:
            raise ValueError(
                f"max_entries must be >= 1 or None, got {max_entries!r}"
            )
        self.max_entries = None if max_entries is None else int(max_entries)
        self._store: collections.OrderedDict = collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.builds = 0
        self.evictions = 0
        self.build_s = 0.0

    # -- core interface ---------------------------------------------------
    @staticmethod
    def key(vars_key, fingerprint) -> tuple:
        return (tuple(vars_key), tuple(fingerprint))

    def lookup(self, vars_key, fingerprint):
        """Counted lookup; returns the FeatureResult or None."""
        key = self.key(vars_key, fingerprint)
        res = self._store.get(key)
        if res is None:
            self.misses += 1
            return None
        self._store.move_to_end(key)
        self.hits += 1
        return res

    def put(self, vars_key, fingerprint, result) -> None:
        key = self.key(vars_key, fingerprint)
        self._store[key] = result
        self._store.move_to_end(key)
        if self.max_entries is not None:
            while len(self._store) > self.max_entries:
                self._store.popitem(last=False)
                self.evictions += 1

    def get_or_build(self, vars_key, fingerprint, build_fn):
        """The scorer's entry: counted lookup, else build (timed) + cache.
        `build_fn` must return a `FeatureResult`."""
        res = self.lookup(vars_key, fingerprint)
        if res is not None:
            return res
        t0 = time.perf_counter()
        res = build_fn()
        self.build_s += time.perf_counter() - t0
        self.builds += 1
        self.put(vars_key, fingerprint, res)
        return res

    def clear(self) -> None:
        self._store.clear()
        self.hits = 0
        self.misses = 0
        self.builds = 0
        self.evictions = 0
        self.build_s = 0.0

    def metadata(self) -> list:
        """Checkpointable identity of every cached entry: ``(vars_key,
        fingerprint)`` pairs, insertion order.  This is what a
        `repro.core.runstate.RunState` records — factors are cheap to
        rebuild, so resume verifies fingerprints instead of restoring
        device arrays."""
        return list(self._store.keys())

    # -- telemetry --------------------------------------------------------
    def __len__(self) -> int:
        return len(self._store)

    @property
    def stats(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "builds": self.builds,
            "evictions": self.evictions,
            "entries": len(self._store),
            "build_s": round(self.build_s, 4),
        }

    def entry_log(self) -> list:
        """Per-entry rank/error telemetry (insertion order): one record
        per cached factor — which backend built which variable set at
        what live rank and trace residual."""
        return [
            {
                "vars": key[0],
                "backend": res.backend,
                "m_eff": res.m_eff,
                "gram_resid": res.info.get("gram_resid"),
            }
            for key, res in self._store.items()
        ]
