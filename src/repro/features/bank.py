"""FeatureBank: the shareable cache of built low-rank factors.

Building a variable set's factor is the expensive, sequential front of
the CV-LR pipeline (ICL's greedy pivot loop is O(n m) *per pivot*); a
GES run asks for the same sets sweep after sweep, and repeated sessions
over the same data ask for them again.  The bank is a keyed LRU cache of
`repro.features.backends.FeatureResult`s:

    key = (canonical variable-set key, build fingerprint)

where the fingerprint (composed by the scorer) pins everything that
shapes the factor — the resolved `BackendChoice` (backend + params), the
policy seed, and the score-config build knobs (m_max, eta, width_factor,
fold layout).  Two scorers sharing a bank therefore can never serve each
other a factor built under different routing; sharing a bank across
*different data matrices* is the caller's contract to avoid (the bank is
meant to be owned by a `repro.core.api.DiscoverySession`, passed between
sessions over the same dataset, or shared process-wide by a
`repro.serving.SessionManager`).

Concurrency: every public method is safe under concurrent callers.  A
single RLock guards the LRU order and the counters; builds run *outside*
that lock under per-key single-flight deduplication — the first caller
of a missing key becomes the build leader, every concurrent caller of
the same key waits on the leader's in-flight slot and receives the same
`FeatureResult` object, so N tenants requesting one factor trigger
exactly one build (`single_flight_waits` counts the followers).  A
leader that raises releases the slot; one waiting follower is promoted
to retry the build rather than caching the failure.

Telemetry: hit/miss/build/single-flight counters plus cumulative build
seconds (`stats`, surfaced per sweep by the session log) and per-entry
rank/backend/residual records (`entry_log`).
"""

from __future__ import annotations

import collections
import threading
import time

from repro.obs import trace as obs_trace


class _InFlight:
    """One in-progress build: followers wait on `done`, the leader
    publishes `result` (or `exc`) before setting it."""

    __slots__ = ("done", "result", "exc")

    def __init__(self):
        self.done = threading.Event()
        self.result = None
        self.exc = None


class FeatureBank:
    """Keyed LRU cache of built factors with build/hit/miss telemetry,
    safe for concurrent callers (single-flight builds, locked LRU)."""

    def __init__(self, max_entries: int | None = None):
        if max_entries is not None and int(max_entries) < 1:
            raise ValueError(
                f"max_entries must be >= 1 or None, got {max_entries!r}"
            )
        self.max_entries = None if max_entries is None else int(max_entries)
        self._store: collections.OrderedDict = collections.OrderedDict()
        self._lock = threading.RLock()
        self._building: dict = {}  # key -> _InFlight
        self.hits = 0
        self.misses = 0
        self.builds = 0
        self.evictions = 0
        self.single_flight_waits = 0
        self.build_s = 0.0

    # -- core interface ---------------------------------------------------
    @staticmethod
    def key(vars_key, fingerprint) -> tuple:
        return (tuple(vars_key), tuple(fingerprint))

    def lookup(self, vars_key, fingerprint):
        """Counted lookup; returns the FeatureResult or None."""
        key = self.key(vars_key, fingerprint)
        with self._lock:
            return self._lookup_locked(key)

    def _lookup_locked(self, key):
        res = self._store.get(key)
        if res is None:
            self.misses += 1
            return None
        self._store.move_to_end(key)
        self.hits += 1
        return res

    def put(self, vars_key, fingerprint, result) -> None:
        key = self.key(vars_key, fingerprint)
        with self._lock:
            self._put_locked(key, result)

    def _put_locked(self, key, result) -> None:
        self._store[key] = result
        self._store.move_to_end(key)
        if self.max_entries is not None:
            while len(self._store) > self.max_entries:
                self._store.popitem(last=False)
                self.evictions += 1

    def get_or_build(self, vars_key, fingerprint, build_fn):
        """The scorer's entry: counted lookup, else build (timed) + cache.
        `build_fn` must return a `FeatureResult`.  Concurrent callers of
        the same key are deduplicated: one builds, the rest wait and share
        the result."""
        key = self.key(vars_key, fingerprint)
        while True:
            with self._lock:
                res = self._lookup_locked(key)
                if res is not None:
                    return res
                slot = self._building.get(key)
                if slot is None:
                    slot = _InFlight()
                    self._building[key] = slot
                    leader = True
                else:
                    self.single_flight_waits += 1
                    leader = False
            if leader:
                return self._build_as_leader(key, slot, build_fn)
            slot.done.wait()
            if slot.exc is None:
                return slot.result
            # the leader failed: loop — either another follower already
            # became the new leader, or this caller will

    def _build_as_leader(self, key, slot, build_fn):
        t0 = time.perf_counter()
        try:
            # leader-only span: followers wait, so one build = one span;
            # no-op without an active repro.obs recorder
            with obs_trace.span(
                "feature_build", cat="build", attrs={"vars": list(key[0])}
            ):
                res = build_fn()
        except BaseException as exc:
            slot.exc = exc
            with self._lock:
                self._building.pop(key, None)
            slot.done.set()
            raise
        dt = time.perf_counter() - t0
        with self._lock:
            self.build_s += dt
            self.builds += 1
            self._put_locked(key, res)
            self._building.pop(key, None)
        slot.result = res
        slot.done.set()
        return res

    def clear(self) -> None:
        with self._lock:
            self._store.clear()
            self.hits = 0
            self.misses = 0
            self.builds = 0
            self.evictions = 0
            self.single_flight_waits = 0
            self.build_s = 0.0

    def metadata(self) -> list:
        """Checkpointable identity of every cached entry: ``(vars_key,
        fingerprint)`` pairs, insertion order.  This is what a
        `repro.core.runstate.RunState` records — factors are cheap to
        rebuild, so resume verifies fingerprints instead of restoring
        device arrays."""
        with self._lock:
            return list(self._store.keys())

    # -- telemetry --------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._store)

    @property
    def nbytes(self) -> int:
        """Approximate host+device bytes held by cached factors."""
        with self._lock:
            total = 0
            for res in self._store.values():
                factor = getattr(res, "factor", None)
                total += int(getattr(factor, "nbytes", 0) or 0)
            return total

    @property
    def stats(self) -> dict:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "builds": self.builds,
                "evictions": self.evictions,
                "single_flight_waits": self.single_flight_waits,
                "entries": len(self._store),
                "build_s": round(self.build_s, 4),
            }

    def entry_log(self) -> list:
        """Per-entry rank/error telemetry (insertion order): one record
        per cached factor — which backend built which variable set at
        what live rank and trace residual."""
        with self._lock:
            return [
                {
                    "vars": key[0],
                    "backend": res.backend,
                    "m_eff": res.m_eff,
                    "gram_resid": res.info.get("gram_resid"),
                }
                for key, res in self._store.items()
            ]
