"""Feature routing policy: which factorization backend serves which
variable set.

A `FeaturePolicy` maps variable *kinds* to registered backends
(`repro.features.backends`) — continuous / discrete / mixed sets each get
a `BackendChoice` (backend name + params) — and per-variable overrides
ride on the `repro.core.spec.DataSpec` itself (`VariableSpec.backend` /
`backend_params`), so a single column can opt into, say, stratified
Nystroem while the rest of the graph keeps the defaults.

`FeaturePolicy.default()` reproduces the pre-PR-5 hardwired routing
bitwise: all-discrete sets -> ``discrete_exact`` (Alg. 2, with its
over-cardinality fallback to ICL), everything else -> ``icl`` (Alg. 1).
Tier-1 CPDAGs and scores are unchanged unless a user opts in.

Resolution rule for a variable set (documented, deliberately simple):

1. If **every** member variable carries the **same** explicit override,
   the override wins (singleton sets — children and single parents, the
   common case — always resolve their own override).
2. Otherwise route by kind: all-discrete -> ``discrete``, all-continuous
   -> ``continuous``, genuinely mixed -> ``mixed`` (which defaults to the
   continuous choice, matching the old all-or-nothing discreteness test).

This module is pure stdlib (no jax, no numpy) so policies can be
constructed, fingerprinted and serialized anywhere; backend names are
validated against the registry at build time
(`repro.features.backends.get_backend` raises with the registered list).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class BackendChoice:
    """A backend name plus its policy-level params, in hashable form
    (params normalize to a sorted tuple of ``(key, value)`` pairs — the
    piece of the bank-cache fingerprint that identifies *how* a factor
    was built)."""

    backend: str
    params: tuple = ()

    def __post_init__(self):
        if not isinstance(self.backend, str) or not self.backend:
            raise ValueError(
                f"BackendChoice.backend must be a non-empty string, got "
                f"{self.backend!r}"
            )
        params = self.params
        if isinstance(params, dict):
            params = tuple(sorted(params.items()))
        params = tuple((str(k), v) for k, v in params)
        object.__setattr__(self, "params", params)

    @classmethod
    def of(cls, backend: str, **params) -> "BackendChoice":
        return cls(backend, tuple(sorted(params.items())))

    @property
    def kwargs(self) -> dict:
        return dict(self.params)


def _as_choice(value) -> BackendChoice:
    if isinstance(value, BackendChoice):
        return value
    if isinstance(value, str):
        return BackendChoice(value)
    raise ValueError(
        f"expected a BackendChoice or backend name, got {value!r}"
    )


@dataclasses.dataclass(frozen=True)
class FeaturePolicy:
    """Kind -> backend routing + the PRNG seed of the randomized backends.

    continuous / discrete / mixed: `BackendChoice` (or bare backend name)
    per variable-set kind; ``mixed=None`` routes mixed sets through the
    continuous choice.  seed: folded with the variable-set ids into the
    PRNG key every randomized backend (rff, nystrom) draws from — explicit
    and reproducible, never wall-clock.
    """

    continuous: BackendChoice = BackendChoice("icl")
    discrete: BackendChoice = BackendChoice("discrete_exact")
    mixed: BackendChoice | None = None
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "continuous", _as_choice(self.continuous))
        object.__setattr__(self, "discrete", _as_choice(self.discrete))
        if self.mixed is not None:
            object.__setattr__(self, "mixed", _as_choice(self.mixed))
        object.__setattr__(self, "seed", int(self.seed))

    @classmethod
    def default(cls) -> "FeaturePolicy":
        """The pre-PR-5 routing, bitwise: Alg. 2 for all-discrete sets,
        Alg. 1 for everything else."""
        return cls()

    def resolve(self, vars_key, data_spec) -> BackendChoice:
        """The `BackendChoice` serving one variable set (see the module
        doc for the override-then-kind resolution rule)."""
        ids = sorted({int(v) for v in vars_key})
        if not ids:
            raise ValueError("cannot resolve a backend for an empty set")
        members = [data_spec.variables[v] for v in ids]
        overrides = {
            (v.backend, tuple(v.backend_params)) for v in members
        }
        if len(overrides) == 1:
            backend, params = next(iter(overrides))
            if backend is not None:
                return BackendChoice(backend, params)
        kinds = {v.kind for v in members}
        if kinds == {"discrete"}:
            return self.discrete
        if kinds == {"continuous"}:
            return self.continuous
        return self.mixed if self.mixed is not None else self.continuous

    def fingerprint(self) -> tuple:
        """Hashable identity of the routing (kind choices + seed) — part
        of every `repro.features.bank.FeatureBank` cache key, so banks
        shared across sessions can never serve a factor built under a
        different policy."""
        mixed = self.mixed
        return (
            "feature-policy",
            (self.continuous.backend, self.continuous.params),
            (self.discrete.backend, self.discrete.params),
            None if mixed is None else (mixed.backend, mixed.params),
            self.seed,
        )

    @property
    def is_default(self) -> bool:
        return self == FeaturePolicy()
