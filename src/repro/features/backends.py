"""Low-rank factorization backends — the paper's "sampling algorithms for
different data types" made first-class.

Everything between a variable set's raw columns and the centered
``(n, m_max)`` factor the CV-LR scorer consumes lives here, behind one
contract (:class:`FeatureBackend`) and a registry:

* ``icl`` — Alg. 1 (incomplete Cholesky), the adaptive Nystroem variant:
  greedy pivot selection maximizing the residual-diagonal bound,
  restructured for accelerators as a `lax.fori_loop` whose per-step body
  is a vectorized kernel-strip evaluation + rank-1 residual update
  (O(n) per step; the eta stopping rule is carried as a flag and dead
  columns are masked to zero — zero-padded columns leave every
  downstream score identity exact, see score_lowrank.py).
* ``discrete_exact`` — Alg. 2: for a variable (set) with m_d <= m_max
  distinct rows the factorization Lambda = K_{XX'} L^{-T}
  (K_{X'} = L L^T) is *exact* (Lemma 4.3; the paper prints L^{-1}, the
  correct right factor is L^{-T} — tested to machine precision in
  tests/test_lowrank.py).  Falls back to ``icl`` past the cap, exactly
  like the pre-PR-5 hardwired router.
* ``rff`` — random Fourier features (Rahimi-Recht) for the RBF kernel:
  an O(n m) *sequential-free* factorization (no greedy pivot loop —
  embarrassingly parallel, one matmul + trig away), width from the same
  median heuristic, seeded through an explicit PRNG key (no wall-clock
  nondeterminism).  Approximation is statistical, not eta-driven; the
  documented tolerance is :meth:`RandomFourierBackend.gram_error_bound`.
* ``nystrom`` — landmark Nystroem with pluggable landmark samplers:
  ``uniform``, ``leverage`` (approximate ridge leverage scores) and
  ``stratified`` (strata from the set's discrete columns — the
  mixed-data composite sampler).  Same exact-on-the-landmarks algebra as
  Alg. 2 with sampled landmarks instead of deduplicated rows.

All backends return a :class:`FeatureResult` whose ``factor`` is a
centered, zero-padded fixed-width ``(n, m_max)`` float64 array with live
rank ``m_eff`` — the invariants every downstream engine stage relies on
(fixed shapes keep the fold pipeline jit-cacheable; padding is provably
score-neutral).  The (n, m) kernel-strip hot spot of the pivot/landmark
backends dispatches through `repro.kernels.ops.feature_strip` (Pallas on
TPU, single-jit strip elsewhere).

Routing — which backend serves which variable set — is the job of
`repro.features.policy.FeaturePolicy`; caching built factors across
sweeps and sessions is `repro.features.bank.FeatureBank`.  The old
`repro.core.lowrank` module is a one-release deprecation shim over the
implementations here.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.linalg import solve_triangular

from repro.core.kernel_fns import (
    KernelSpec,
    center_features,
    kernel_rows,
    median_heuristic_width,
    standardize,
)
from repro.kernels.ops import feature_strip


# -- shared result / context types ----------------------------------------


@dataclasses.dataclass(frozen=True)
class FeatureResult:
    """One built factor: the contract every backend returns.

    factor: centered ``(n, m_max)`` float64 jnp array, exactly zero
    beyond column ``m_eff`` (fixed width keeps downstream jits
    shape-stable; the padding is score-neutral).
    m_eff: live rank.  spec: the `repro.core.kernel_fns.KernelSpec` the
    factor approximates.  backend: registry name that built it.  info:
    telemetry (``gram_resid`` = trace residual tr(K) - ||factor||_F^2
    where cheaply available, sampler/seed details, documented tolerance
    for statistical backends) — surfaced by `repro.features.bank.
    FeatureBank` and the `DiscoverySession` sweep log.
    """

    factor: jnp.ndarray
    m_eff: int
    spec: KernelSpec
    backend: str
    info: dict


@dataclasses.dataclass(frozen=True)
class BuildContext:
    """Per-build parameters threaded from the scorer (ScoreConfig +
    FeaturePolicy + DataSpec), identical across backends so a policy can
    swap backends without renegotiating the call.

    known_levels: the variable set's distinct-row count when the
    `DataSpec` already established it (`DataSpec.infer` counts once; the
    discrete backend must not scan the column again).  None = unknown.
    discrete_mask: per-*column* discreteness of the concatenated set —
    what the stratified landmark sampler stratifies on.
    seed / salt: the explicit PRNG inputs of the randomized backends —
    ``key = fold_in(PRNGKey(seed), *salt)`` with salt the variable-set
    ids, so every set draws distinct, reproducible randomness.
    """

    m_max: int = 100
    eta: float = 1e-6
    width_factor: float = 2.0
    spec: KernelSpec | None = None
    standardize: bool = True
    known_levels: int | None = None
    discrete_mask: tuple = ()
    seed: int = 0
    salt: tuple = ()

    def key(self) -> jax.Array:
        """Deterministic PRNG key: seed folded with the salt ints."""
        key = jax.random.PRNGKey(int(self.seed))
        key = jax.random.fold_in(key, len(self.salt))
        for s in self.salt:
            key = jax.random.fold_in(key, int(s))
        return key


class FeatureBackend:
    """Protocol of a registered factorization backend.

    Subclasses set ``name`` and implement ``build(x, ctx, **params) ->
    FeatureResult`` honoring the FeatureResult contract (centered,
    zero-padded fixed-width factor).  ``params`` are the policy-supplied
    knobs of a `repro.features.policy.BackendChoice` (e.g. the nystrom
    ``sampler``); unknown params must raise, not pass silently.
    """

    name: str = ""

    def build(self, x, ctx: BuildContext, **params) -> FeatureResult:
        raise NotImplementedError


_REGISTRY: dict = {}


def register_backend(backend_cls):
    """Class decorator: instantiate and register under ``cls.name``."""
    inst = backend_cls()
    if not inst.name:
        raise ValueError(f"{backend_cls.__name__} must set a backend name")
    _REGISTRY[inst.name] = inst
    return backend_cls


def get_backend(name: str) -> FeatureBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown feature backend {name!r}; registered backends: "
            f"{available_backends()}"
        ) from None


def available_backends() -> list:
    return sorted(_REGISTRY)


def build_features(x, choice, ctx: BuildContext) -> FeatureResult:
    """Build one variable set's factor through a policy's `BackendChoice`
    (the single entry the scorer calls)."""
    backend = get_backend(choice.backend)
    try:
        return backend.build(x, ctx, **choice.kwargs)
    except TypeError as e:
        raise ValueError(
            f"feature backend {choice.backend!r} rejected params "
            f"{dict(choice.params)!r}: {e}"
        ) from e


# -- shared helpers --------------------------------------------------------


def _as_cols(x) -> np.ndarray:
    xn = np.asarray(x, dtype=np.float64)
    if xn.ndim == 1:
        xn = xn[:, None]
    return xn


def _prepare(x, ctx: BuildContext):
    """The shared front half of every backend: z-score the columns and
    pick the RBF width by the median heuristic (unless an explicit
    KernelSpec overrides) — identical op order to the pre-PR-5 router so
    the default policy stays bitwise-compatible."""
    xn = _as_cols(x)
    if ctx.standardize:
        xn = standardize(xn)
    spec = ctx.spec
    if spec is None:
        spec = KernelSpec(
            "rbf", median_heuristic_width(xn, factor=ctx.width_factor)
        )
    return xn, spec


def _kernel_trace(xn: np.ndarray, spec: KernelSpec) -> float:
    """tr(K) for the residual telemetry (k(x,x) = 1 for rbf/delta)."""
    if spec.kind in ("rbf", "delta"):
        return float(xn.shape[0])
    return float(np.sum(xn * xn))


def _finish(lam, m_eff, xn, spec, backend: str, info: dict) -> FeatureResult:
    """Center, and attach the cheap trace-residual telemetry
    tr(K) - ||Lambda||_F^2 (exact residual trace for the psd-dominated
    pivot/landmark factorizations; a signed indicator for RFF)."""
    resid = _kernel_trace(xn, spec) - float(jnp.sum(lam * lam))
    info = dict(info)
    info.setdefault("gram_resid", resid)
    info.setdefault("m_eff", int(m_eff))
    return FeatureResult(
        factor=center_features(lam),
        m_eff=int(m_eff),
        spec=spec,
        backend=backend,
        info=info,
    )


# -- Alg. 1: incomplete Cholesky (migrated from repro.core.lowrank) --------


@partial(jax.jit, static_argnames=("m_max", "kind"))
def _icl_jax(x: jnp.ndarray, width, m_max: int, eta, kind: str):
    """Jitted ICL. x: (n, d) data; returns (Lambda (n, m_max), m_eff)."""
    n = x.shape[0]
    dtype = x.dtype
    diag0 = jnp.ones((n,), dtype) if kind in ("rbf", "delta") else jnp.sum(
        x * x, axis=-1
    )
    spec_width = width

    def krow(j):
        # k(X, x_j): vectorized kernel strip — the hot spot (Pallas-served
        # on TPU via repro.kernels.ops; jnp here).
        pivot = jax.lax.dynamic_slice_in_dim(x, j, 1, axis=0)  # (1, d)
        if kind == "rbf":
            d2 = jnp.sum((x - pivot) ** 2, axis=-1)
            return jnp.exp(-d2 / (2.0 * spec_width * spec_width))
        if kind == "delta":
            d2 = jnp.sum((x - pivot) ** 2, axis=-1)
            return (d2 < 1e-18).astype(dtype)
        return x @ pivot[0]

    def body(i, carry):
        lam, d_res, unselected, m_eff, active = carry
        # Stopping rule (Alg. 1 line 6): residual trace below eta.
        still = jnp.sum(jnp.maximum(d_res, 0.0) * unselected) >= eta
        active = jnp.logical_and(active, still)
        j_star = jnp.argmax(jnp.where(unselected > 0, d_res, -jnp.inf))
        dj = jnp.maximum(d_res[j_star], 1e-30)
        nu = jnp.sqrt(dj)
        # Column i (Alg. 1 lines 11-12): columns >= i of lam are zero, so the
        # full matvec equals the [:, :i] slice without dynamic shapes.
        col = (krow(j_star) - lam @ lam[j_star]) / nu
        col = jnp.where(active, col, jnp.zeros_like(col))
        lam = lam.at[:, i].set(col)
        d_res = jnp.maximum(d_res - col * col, 0.0)
        d_res = jnp.where(active, d_res.at[j_star].set(0.0), d_res)
        unselected = jnp.where(
            active, unselected.at[j_star].set(0.0), unselected
        )
        m_eff = m_eff + jnp.where(active, 1, 0)
        return lam, d_res, unselected, m_eff, active

    lam0 = jnp.zeros((n, m_max), dtype)
    carry = (
        lam0,
        diag0,
        jnp.ones((n,), dtype),
        jnp.asarray(0, jnp.int32),
        jnp.asarray(True),
    )
    lam, _, _, m_eff, _ = jax.lax.fori_loop(0, m_max, body, carry)
    return lam, m_eff


def incomplete_cholesky(
    x,
    spec: KernelSpec,
    m_max: int = 100,
    eta: float = 1e-6,
):
    """Alg. 1.  Returns (Lambda (n, m_max) with ||Lam Lam^T - K|| <= eta
    when m_eff < m_max, m_eff)."""
    x = jnp.asarray(x, jnp.float64)
    if x.ndim == 1:
        x = x[:, None]
    return _icl_jax(
        x, jnp.asarray(spec.width, x.dtype), int(m_max), jnp.asarray(eta, x.dtype), spec.kind
    )


# -- Alg. 2: exact discrete decomposition (migrated from core.lowrank) -----


def discrete_lowrank(
    x,
    spec: KernelSpec,
    m_max: int = 100,
    jitter: float = 1e-10,
    backend: str = "jnp",
):
    """Alg. 2: exact factorization from deduplicated rows.

    Host-side unique (data-dependent shape), jitted algebra.  Returns
    (Lambda (n, m_max) zero-padded, m_d).  Requires m_d <= m_max.

    backend="pallas" routes the (n x m_d) kernel strip — the hot spot —
    through the tiled Pallas kernel (`repro.kernels.ops.feature_strip`
    with the kernel forced on; on this CPU container it runs in interpret
    mode, on TPU it lowers to Mosaic).  The Pallas strip serves RBF only:
    forcing it for another kernel kind raises ValueError instead of the
    pre-PR-5 behavior of silently falling back to the jnp strip.
    """
    xn = np.asarray(x, dtype=np.float64)
    if xn.ndim == 1:
        xn = xn[:, None]
    if backend not in ("jnp", "pallas"):
        raise ValueError(
            f"discrete_lowrank backend must be 'jnp' or 'pallas', got {backend!r}"
        )
    uniq = np.unique(xn, axis=0)
    m_d = uniq.shape[0]
    if m_d > m_max:
        raise ValueError(f"m_d={m_d} exceeds m_max={m_max}; use ICL instead")
    if backend == "pallas":
        # raises ValueError for non-RBF kinds (the Pallas strip is RBF-only)
        k_xu = feature_strip(
            xn, uniq, spec.width, kind=spec.kind, use_pallas=True
        ).astype(jnp.float64)
    else:
        k_xu = kernel_rows(xn, uniq, spec)  # (n, m_d)
    k_uu = kernel_rows(uniq, uniq, spec)  # (m_d, m_d)
    k_uu = k_uu + jitter * jnp.eye(m_d, dtype=k_uu.dtype)
    chol = jnp.linalg.cholesky(k_uu)
    # Lambda = K_{XX'} L^{-T}:  solve L Y^T = K_{XX'}^T  =>  Y = K L^{-T}.
    lam = solve_triangular(chol, k_xu.T, lower=True).T
    pad = jnp.zeros((lam.shape[0], m_max - m_d), lam.dtype)
    return jnp.concatenate([lam, pad], axis=1), m_d


def _row_codes(x: np.ndarray) -> np.ndarray:
    """Rows as comparable byte codes: one void scalar per row (C-speed
    equality through np.unique instead of per-row Python hashing).
    Rounds to 12 decimals and normalizes -0.0 -> +0.0 so the byte view
    matches == semantics — the ONE row-identity recipe shared by
    `count_distinct_rows` and the stratified landmark sampler, so the
    two can never disagree on which rows are equal."""
    r = np.round(np.asarray(x, dtype=np.float64), 12)
    r += 0.0
    r = np.ascontiguousarray(r)
    void = np.dtype((np.void, r.dtype.itemsize * r.shape[1]))
    return r.view(void).ravel()


def count_distinct_rows(x: np.ndarray, cap: int, chunk: int = 16384) -> int:
    """Number of distinct rows, early-exiting once > cap.

    Vectorized: rows are compared as raw bytes through a contiguous void
    view (`_row_codes`; one np.unique per chunk, C speed) instead of a
    per-row Python tuple()/hash loop.  The chunked scan keeps the
    early-exit-at-cap semantics: counts <= cap are exact, and any count
    beyond the cap is reported as cap + 1 (the value the incremental
    loop stopped at).
    """
    xn = np.asarray(x)
    if xn.ndim == 1:
        xn = xn[:, None]
    if xn.shape[0] == 0:
        return 0
    if xn.shape[1] == 0:
        return 1  # every zero-width row is the same (empty) row
    rows = _row_codes(xn)
    uniq = None
    for lo in range(0, rows.shape[0], chunk):
        block = np.unique(rows[lo : lo + chunk])
        uniq = block if uniq is None else np.unique(
            np.concatenate([uniq, block])
        )
        if uniq.size > cap:
            return int(cap) + 1
    return int(uniq.size)


# -- registered backends ---------------------------------------------------


@register_backend
class IclBackend(FeatureBackend):
    """Alg. 1 (incomplete Cholesky) — the default continuous route."""

    name = "icl"

    def build(self, x, ctx: BuildContext) -> FeatureResult:
        xn, spec = _prepare(x, ctx)
        lam, m_eff = incomplete_cholesky(
            xn, spec, m_max=ctx.m_max, eta=ctx.eta
        )
        return _finish(lam, int(m_eff), xn, spec, self.name, {"eta": ctx.eta})


@register_backend
class DiscreteExactBackend(FeatureBackend):
    """Alg. 2 (exact decomposition) with the pre-PR-5 over-cap fallback to
    ICL — the default discrete route.

    Honors ``ctx.known_levels``: when the `DataSpec` already counted the
    set's distinct rows (`DataSpec.infer` does), the routing decision is
    made from that count and the column is **not** scanned again.
    """

    name = "discrete_exact"

    def build(
        self, x, ctx: BuildContext, kernel_backend: str = "jnp",
        jitter: float = 1e-10,
    ) -> FeatureResult:
        xn, spec = _prepare(x, ctx)
        m_d = ctx.known_levels
        if m_d is None:
            m_d = count_distinct_rows(xn, ctx.m_max)
        if m_d > ctx.m_max:  # cardinality beyond the exact route: Alg. 1
            lam, m_eff = incomplete_cholesky(
                xn, spec, m_max=ctx.m_max, eta=ctx.eta
            )
            return _finish(
                lam, int(m_eff), xn, spec, "icl",
                {"eta": ctx.eta, "fallback_from": self.name},
            )
        lam, m_eff = discrete_lowrank(
            xn, spec, m_max=ctx.m_max, jitter=jitter, backend=kernel_backend
        )
        return _finish(
            lam, int(m_eff), xn, spec, self.name,
            {"levels": int(m_eff), "counted": ctx.known_levels is None},
        )


@partial(jax.jit, static_argnames=("m_max",))
def _rff_jax(x: jnp.ndarray, w: jnp.ndarray, m_max: int) -> jnp.ndarray:
    """Fixed-shape (n, m_max) cos/sin random-Fourier factor: one matmul +
    trig, no sequential pivot loop.  Columns beyond 2 * w.shape[1] are
    exactly zero (the FeatureResult padding contract)."""
    proj = x @ w  # (n, D)
    d_pairs = w.shape[1]
    feats = jnp.concatenate([jnp.cos(proj), jnp.sin(proj)], axis=1)
    feats = feats * jnp.sqrt(1.0 / d_pairs).astype(x.dtype)
    pad = m_max - 2 * d_pairs
    if pad:
        feats = jnp.concatenate(
            [feats, jnp.zeros((x.shape[0], pad), x.dtype)], axis=1
        )
    return feats


@register_backend
class RandomFourierBackend(FeatureBackend):
    """Random Fourier features for the RBF kernel (Rahimi-Recht).

    phi(x) = sqrt(1/D) [cos(Wx); sin(Wx)] with W ~ N(0, I/sigma^2) and
    D = m_max // 2 frequency pairs gives E[phi(x) phi(y)^T] = k(x, y)
    exactly; the realized Gram error is statistical — documented by
    :meth:`gram_error_bound` and measured per build into ``info``.
    Unlike ICL there is no data-dependent pivot recursion: the factor is
    one (n, d) x (d, D) matmul plus trig, embarrassingly parallel over
    rows — the "sketch the n axis" shape (Ramsey, *Fourier Feature
    Methods for Nonlinear Causal Discovery*).  Randomness is an explicit
    PRNG key from ``BuildContext.seed``/``salt`` — reproducible, no
    wall-clock entropy anywhere.
    """

    name = "rff"

    @staticmethod
    def gram_error_bound(d_pairs: int, n: int) -> float:
        """Documented high-probability bound on the max entrywise Gram
        error of D frequency pairs over an n-point set:
        ~ 4 sqrt(log n / D) (Hoeffding + union over n^2 entries; loose
        but honest — the property tests assert against it)."""
        return 4.0 * math.sqrt(math.log(max(int(n), 3)) / max(int(d_pairs), 1))

    def build(self, x, ctx: BuildContext) -> FeatureResult:
        xn, spec = _prepare(x, ctx)
        if spec.kind != "rbf":
            raise ValueError(
                f"rff approximates the RBF kernel only, got kind={spec.kind!r}"
            )
        d_pairs = ctx.m_max // 2
        if d_pairs < 1:
            raise ValueError(f"rff needs m_max >= 2, got {ctx.m_max}")
        w = (
            jax.random.normal(
                ctx.key(), (xn.shape[1], d_pairs), dtype=jnp.float64
            )
            / spec.width
        )
        lam = _rff_jax(jnp.asarray(xn), w, ctx.m_max)
        return _finish(
            lam, 2 * d_pairs, xn, spec, self.name,
            {
                "pairs": d_pairs,
                "seed": int(ctx.seed),
                "gram_tol": self.gram_error_bound(d_pairs, xn.shape[0]),
            },
        )


def _sample_uniform(xn, m, key, ctx):
    return np.asarray(
        jax.random.choice(key, xn.shape[0], shape=(m,), replace=False)
    )


def _sample_leverage(xn, m, key, ctx, spec, oversample=2.0, jitter=1e-10):
    """Approximate ridge-leverage-score landmark sampling (Musco-Musco
    style): score l_i = k_i^T (K_SS + lam I)^-1 k_i against a uniform
    pilot subset S, then a Gumbel-top-m draw proportional to l."""
    n = xn.shape[0]
    k_pilot, k_gumbel = jax.random.split(key)
    s = min(n, max(m + 1, int(math.ceil(oversample * m))))
    idx0 = np.asarray(
        jax.random.choice(k_pilot, n, shape=(s,), replace=False)
    )
    k_ns = np.asarray(
        feature_strip(xn, xn[idx0], spec.width, kind=spec.kind)
    )  # (n, s)
    k_ss = k_ns[idx0]  # (s, s)
    lam_reg = max(jitter, 1e-3 * float(np.trace(k_ss)) / s)
    chol = np.linalg.cholesky(k_ss + lam_reg * np.eye(s))
    y = np.linalg.solve(chol, k_ns.T)  # lower-triangular solve, (s, n)
    lev = np.maximum(np.sum(y * y, axis=0), 1e-12)
    gumbel = -jnp.log(
        -jnp.log(jax.random.uniform(k_gumbel, (n,), dtype=jnp.float64))
    )
    scores = np.log(lev) + np.asarray(gumbel)
    return np.argsort(-scores)[:m]


def _sample_stratified(xn, m, key, ctx):
    """Stratified landmark sampling for discrete/mixed sets: strata are
    the distinct patterns of the set's *discrete* columns
    (``ctx.discrete_mask``), landmarks allocated >= 1 per stratum (the m
    largest strata when there are more strata than budget) with the
    remainder proportional to stratum size, sampled uniformly within.
    Sets with no discrete columns degrade to the uniform sampler."""
    disc = [j for j, b in enumerate(ctx.discrete_mask) if b]
    if not disc:
        return _sample_uniform(xn, m, key, ctx)
    rows = _row_codes(xn[:, disc])
    _, inverse, counts = np.unique(rows, return_inverse=True, return_counts=True)
    n_strata = counts.shape[0]
    order = np.argsort(-counts, kind="stable")  # largest strata first
    alloc = np.zeros(n_strata, dtype=np.int64)
    if n_strata >= m:
        alloc[order[:m]] = 1
    else:
        alloc[:] = 1
        extra = m - n_strata
        # largest-remainder proportional split of the leftover budget
        quota = counts.astype(np.float64) * extra / counts.sum()
        alloc += np.floor(quota).astype(np.int64)
        rem = extra - int(np.floor(quota).sum())
        if rem > 0:
            alloc[np.argsort(-(quota - np.floor(quota)), kind="stable")[:rem]] += 1
        alloc = np.minimum(alloc, counts)  # a stratum can't give more rows
    picks = []
    for si in range(n_strata):
        if alloc[si] == 0:
            continue
        members = np.flatnonzero(inverse == si)
        k_s = jax.random.fold_in(key, si)
        take = min(int(alloc[si]), members.shape[0])
        sel = np.asarray(
            jax.random.choice(k_s, members.shape[0], shape=(take,), replace=False)
        )
        picks.append(members[sel])
    return np.concatenate(picks)


@register_backend
class NystromBackend(FeatureBackend):
    """Landmark Nystroem: Lambda = K_{XL} chol(K_{LL})^{-T} over sampled
    landmark rows L — Alg. 2's algebra with the deduplicated-row set
    replaced by a sampler, which is exactly how the paper's "sampling
    algorithms for different data types" generalizes past discrete data.

    samplers: ``uniform`` | ``leverage`` (approximate ridge leverage
    scores — spends the budget where the kernel's effective dimension
    is) | ``stratified`` (strata over the discrete columns; the
    mixed-data composite).  Landmarks are deduplicated before the
    factorization, so on truly discrete data a covering sample
    reproduces the exact Alg.-2 decomposition.
    """

    name = "nystrom"

    SAMPLERS = ("uniform", "leverage", "stratified")

    def build(
        self,
        x,
        ctx: BuildContext,
        sampler: str = "uniform",
        landmarks: int | None = None,
        oversample: float = 2.0,
        jitter: float = 1e-10,
    ) -> FeatureResult:
        if sampler not in self.SAMPLERS:
            raise ValueError(
                f"nystrom sampler must be one of {self.SAMPLERS}, got {sampler!r}"
            )
        xn, spec = _prepare(x, ctx)
        n = xn.shape[0]
        m = min(ctx.m_max, n)
        if landmarks is not None:
            m = min(int(landmarks), m)
        if m < 1:
            raise ValueError(f"nystrom needs >= 1 landmark, got {m}")
        key = ctx.key()
        if sampler == "uniform":
            idx = _sample_uniform(xn, m, key, ctx)
        elif sampler == "leverage":
            idx = _sample_leverage(
                xn, m, key, ctx, spec, oversample=oversample, jitter=jitter
            )
        else:
            idx = _sample_stratified(xn, m, key, ctx)
        pts = np.unique(xn[idx], axis=0)  # duplicate landmarks add no rank
        m_d = pts.shape[0]
        k_xu = feature_strip(xn, pts, spec.width, kind=spec.kind).astype(
            jnp.float64
        )
        k_uu = kernel_rows(pts, pts, spec)
        k_uu = k_uu + jitter * jnp.eye(m_d, dtype=k_uu.dtype)
        chol = jnp.linalg.cholesky(k_uu)
        lam = solve_triangular(chol, k_xu.T, lower=True).T
        lam = jnp.concatenate(
            [lam, jnp.zeros((n, ctx.m_max - m_d), lam.dtype)], axis=1
        )
        return _finish(
            lam, m_d, xn, spec, self.name,
            {"sampler": sampler, "landmarks": int(m_d), "seed": int(ctx.seed)},
        )


# -- the legacy end-to-end builder (pre-PR-5 public surface) ---------------


def lowrank_features(
    x,
    *,
    discrete: bool = False,
    m_max: int = 100,
    eta: float = 1e-6,
    width_factor: float = 2.0,
    spec: KernelSpec | None = None,
    standardize_data: bool = True,
    known_levels: int | None = None,
):
    """End-to-end feature builder used by the CV-LR scorer (paper Sec. 7.1):

    - z-score the columns,
    - pick the RBF width by the 2x-median heuristic (unless `spec` given),
    - route: Alg. 2 when the variable is discrete with m_d <= m_max,
      else Alg. 1 (ICL),
    - center the factor (Lambda~ = H Lambda).

    Returns (Lambda~ (n, m_max) float64, m_eff, spec).  This is exactly
    the `FeaturePolicy.default()` routing as one call; `known_levels`
    skips the distinct-row scan when the caller already counted
    (`repro.core.spec.DataSpec.infer` records it per variable).
    """
    ctx = BuildContext(
        m_max=m_max,
        eta=eta,
        width_factor=width_factor,
        spec=spec,
        standardize=standardize_data,
        known_levels=known_levels,
    )
    backend = get_backend("discrete_exact" if discrete else "icl")
    res = backend.build(x, ctx)
    return res.factor, res.m_eff, res.spec
