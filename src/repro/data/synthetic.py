"""Synthetic SCM data generation — paper Sec. 7.4 / Appendix A.1.

    X_i = g_i( f_i(Pa_i) + eps_i )

f_i ~ U{linear(w in [0,1.5]), sin, cos, tanh, log}
g_i ~ U{linear(w in [1,2]), exp, x^alpha (alpha in {1,2,3})}
eps_i ~ U{-0.25, 0.25} or N(0, 0.5); roots ~ N(0,1) or U(-0.5, 0.5).

Variants: continuous | mixed (50% of variables equal-frequency discretized
to 5 levels) | multi-dimensional (dims 1..5, parents mapped up/down by a
ones matrix, Appendix A.1).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import random_dag, topological_order


@dataclasses.dataclass
class SyntheticDataset:
    data: np.ndarray  # (n, total_cols)
    dag: np.ndarray  # (d, d) ground-truth DAG
    dims: list  # per-variable column widths
    discrete: list  # per-variable discreteness flags
    kind: str


def _apply_f(rng, acc):
    choice = rng.integers(0, 5)
    if choice == 0:
        return rng.uniform(0.0, 1.5) * acc
    if choice == 1:
        return np.sin(acc)
    if choice == 2:
        return np.cos(acc)
    if choice == 3:
        return np.tanh(acc)
    return np.log(np.abs(acc) + 1.0)


def _apply_g(rng, y):
    choice = rng.integers(0, 3)
    if choice == 0:
        return rng.uniform(1.0, 2.0) * y
    if choice == 1:
        # exp of standardized input to avoid overflow
        ys = (y - y.mean()) / (y.std() + 1e-9)
        return np.exp(np.clip(ys, -6, 6))
    alpha = int(rng.integers(1, 4))
    return np.sign(y) * np.abs(y) ** alpha


def _noise(rng, shape):
    if rng.random() < 0.5:
        return rng.uniform(-0.25, 0.25, size=shape)
    return rng.normal(0.0, 0.5, size=shape)


def _root(rng, shape):
    if rng.random() < 0.5:
        return rng.normal(0.0, 1.0, size=shape)
    return rng.uniform(-0.5, 0.5, size=shape)


def _equal_frequency_discretize(col: np.ndarray, levels: int = 5) -> np.ndarray:
    qs = np.quantile(col, np.linspace(0, 1, levels + 1)[1:-1])
    return np.digitize(col, qs).astype(np.float64) + 1.0  # values 1..levels


def generate_scm_data(
    d: int = 7,
    n: int = 500,
    density: float = 0.4,
    kind: str = "continuous",  # continuous | mixed | multidim
    seed: int = 0,
) -> SyntheticDataset:
    rng = np.random.default_rng(seed)
    dag = random_dag(d, density, rng)
    order = topological_order(dag)

    if kind == "multidim":
        dims = [int(rng.integers(1, 6)) for _ in range(d)]
    else:
        dims = [1] * d

    values = [None] * d
    for i in order:
        pa = list(np.flatnonzero(dag[:, i]))
        di = dims[i]
        if not pa:
            values[i] = _root(rng, (n, di))
            continue
        pa_mat = np.concatenate([values[p] for p in pa], axis=1)  # (n, sum dims)
        # Appendix A.1: map parent dims onto child dims with a ones matrix.
        ones_map = np.ones((pa_mat.shape[1], di))
        acc = pa_mat @ ones_map / pa_mat.shape[1]
        y = _apply_f(rng, acc) + _noise(rng, (n, di))
        values[i] = _apply_g(rng, y)

    discrete = [False] * d
    if kind == "mixed":
        to_disc = rng.permutation(d)[: d // 2 + d % 2]
        for i in to_disc:
            values[i] = _equal_frequency_discretize(values[i][:, 0])[:, None]
            discrete[i] = True

    data = np.concatenate(values, axis=1)
    return SyntheticDataset(data=data, dag=dag, dims=dims, discrete=discrete, kind=kind)
