from repro.data.synthetic import SyntheticDataset, generate_scm_data
from repro.data.networks import sample_network, SACHS, CHILD

__all__ = [
    "SyntheticDataset",
    "generate_scm_data",
    "sample_network",
    "SACHS",
    "CHILD",
]
