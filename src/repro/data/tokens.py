"""Deterministic synthetic token pipeline for LM training.

Cluster semantics built in:
- `TokenStream(seed, vocab, seq_len)` yields batches addressed purely by
  (step, global_row) — any worker can (re)compute exactly its shard, which
  is what makes straggler replacement and elastic restart deterministic
  (DESIGN.md §2.3): a re-joined worker replays precisely the rows it owns.
- 1-step lookahead prefetch thread to overlap host data work with device
  compute.

The stream is a mixture of short Markov chains over the vocabulary so the
loss has learnable structure (tests assert loss decreases).
"""

from __future__ import annotations

import queue
import threading

import numpy as np


class TokenStream:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int, seed: int = 0):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed
        # fixed random Markov transition (row-stochastic, peaky)
        rng = np.random.default_rng(seed)
        k = min(vocab_size, 64)
        self._proj = rng.integers(0, vocab_size, size=k)
        self._trans = rng.dirichlet(np.full(k, 0.1), size=k)

    def _row(self, step: int, row: int) -> np.ndarray:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_521 + row
        )
        k = self._trans.shape[0]
        state = rng.integers(0, k)
        out = np.empty(self.seq + 1, np.int64)
        for t in range(self.seq + 1):
            out[t] = self._proj[state]
            state = rng.choice(k, p=self._trans[state])
        return out

    def batch_at(self, step: int, rows=None) -> dict:
        """Batch for `step`; `rows` selects a shard of the global batch."""
        rows = range(self.batch) if rows is None else rows
        data = np.stack([self._row(step, r) for r in rows])
        return {"tokens": data[:, :-1].astype(np.int32), "labels": data[:, 1:].astype(np.int32)}


class PrefetchIterator:
    """1-step lookahead prefetch of TokenStream batches."""

    def __init__(self, stream: TokenStream, start_step: int = 0, rows=None):
        self.stream = stream
        self.rows = rows
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._stop = False
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self.step
        while not self._stop:
            batch = self.stream.batch_at(step, self.rows)
            self._q.put((step, batch))
            step += 1

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop = True
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
