"""Benchmark discrete networks (paper Sec. 7.5): SACHS (11 nodes, 17 edges)
and CHILD (20 nodes, 25 edges).

Structures are the published consensus graphs.  Conditional probability
tables are seeded synthetic Dirichlet draws (the original CPT files are not
redistributable); cardinalities 2..4 match the paper's "1 to 6" range.
Sampling is ancestral over the topological order.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import topological_order


@dataclasses.dataclass(frozen=True)
class Network:
    name: str
    nodes: tuple
    edges: tuple  # (parent_name, child_name)

    @property
    def d(self) -> int:
        return len(self.nodes)

    def adjacency(self) -> np.ndarray:
        idx = {v: i for i, v in enumerate(self.nodes)}
        a = np.zeros((self.d, self.d), dtype=np.int8)
        for p, c in self.edges:
            a[idx[p], idx[c]] = 1
        return a


SACHS = Network(
    name="sachs",
    nodes=(
        "Raf", "Mek", "Plcg", "PIP2", "PIP3", "Erk", "Akt", "PKA", "PKC",
        "P38", "Jnk",
    ),
    edges=(
        ("PKC", "Raf"), ("PKC", "Mek"), ("PKC", "Jnk"), ("PKC", "P38"),
        ("PKC", "PKA"), ("PKA", "Raf"), ("PKA", "Mek"), ("PKA", "Erk"),
        ("PKA", "Akt"), ("PKA", "Jnk"), ("PKA", "P38"), ("Raf", "Mek"),
        ("Mek", "Erk"), ("Erk", "Akt"), ("Plcg", "PIP2"), ("Plcg", "PIP3"),
        ("PIP3", "PIP2"),
    ),
)

CHILD = Network(
    name="child",
    nodes=(
        "BirthAsphyxia", "Disease", "Age", "LVH", "DuctFlow", "CardiacMixing",
        "LungParench", "LungFlow", "Sick", "HypDistrib", "HypoxiaInO2", "CO2",
        "ChestXray", "Grunting", "LVHreport", "LowerBodyO2", "RUQO2",
        "CO2Report", "XrayReport", "GruntingReport",
    ),
    edges=(
        ("BirthAsphyxia", "Disease"), ("Disease", "Age"), ("Disease", "LVH"),
        ("Disease", "DuctFlow"), ("Disease", "CardiacMixing"),
        ("Disease", "LungParench"), ("Disease", "LungFlow"),
        ("Disease", "Sick"), ("LVH", "LVHreport"), ("DuctFlow", "HypDistrib"),
        ("CardiacMixing", "HypDistrib"), ("CardiacMixing", "HypoxiaInO2"),
        ("LungParench", "HypoxiaInO2"), ("LungParench", "CO2"),
        ("LungParench", "ChestXray"), ("LungParench", "Grunting"),
        ("LungFlow", "ChestXray"), ("Sick", "Grunting"), ("Sick", "Age"),
        ("HypDistrib", "LowerBodyO2"), ("HypoxiaInO2", "LowerBodyO2"),
        ("HypoxiaInO2", "RUQO2"), ("CO2", "CO2Report"),
        ("ChestXray", "XrayReport"), ("Grunting", "GruntingReport"),
    ),
)

assert len(SACHS.edges) == 17 and len(CHILD.edges) == 25


def sample_network(net: Network, n: int, seed: int = 0, max_card: int = 4):
    """Ancestral sampling with seeded Dirichlet CPTs.

    Returns (data (n, d) float64 of category codes, true_dag (d, d)).
    CPTs are deterministic per (network, seed) and are made intentionally
    informative (Dirichlet alpha=0.35, peaky) so the structure is learnable.
    """
    adj = net.adjacency()
    d = net.d
    rng_card = np.random.default_rng(hash((net.name, "card")) % (2**31))
    cards = rng_card.integers(2, max_card + 1, size=d)
    rng_cpt = np.random.default_rng(hash((net.name, "cpt")) % (2**31))
    rng = np.random.default_rng(seed)

    order = topological_order(adj)
    parents = {i: list(np.flatnonzero(adj[:, i])) for i in range(d)}

    cpts = {}
    for i in range(d):
        n_conf = int(np.prod([cards[p] for p in parents[i]])) if parents[i] else 1
        cpts[i] = rng_cpt.dirichlet(np.full(cards[i], 0.35), size=n_conf)

    data = np.zeros((n, d), dtype=np.int64)
    for i in order:
        pa = parents[i]
        if pa:
            conf = np.zeros(n, dtype=np.int64)
            mult = 1
            for p in pa:
                conf = conf * cards[p] + data[:, p]
                mult *= cards[p]
        else:
            conf = np.zeros(n, dtype=np.int64)
        probs = cpts[i][conf]  # (n, card_i)
        u = rng.random((n, 1))
        data[:, i] = (u > np.cumsum(probs, axis=1)).sum(axis=1)

    return data.astype(np.float64), adj
