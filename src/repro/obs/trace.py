"""Structured tracing: spans, the active-recorder context, compile hooks.

Zero-overhead-when-disabled contract: with no recorder active,
:func:`span` costs one ``ContextVar.get`` plus a shared no-op context
manager — no allocation, no branching in callees.  Hot code therefore
instruments unconditionally::

    from repro.obs import trace

    with trace.span("fold_gram", attrs={"width": w}):
        ...

Span hierarchy (by monotonic ts/dur nesting per thread, the Perfetto
convention — no explicit parent ids): session -> sweep -> stage
(enumerate / features / gram / zcores / fold / select / constraint /
checkpoint) -> kernel dispatch, with ``compile`` spans injected from
jax's jit cache-miss monitoring events so warm-sweep compile churn is
visible and separated from execute time.

Recorders are owned by ``DiscoverySession`` / ``SessionManager`` and
activated via :func:`use`.  The active recorder rides a ``contextvars``
context, which does NOT propagate into ``ThreadPoolExecutor`` workers —
sharded workers and serving threads re-enter with ``trace.use(rec)``.
"""

from __future__ import annotations

import contextlib
import contextvars
import functools
import itertools
import os
import re
import threading
import time

from .export import JsonlWriter, write_chrome_trace
from .metrics import MetricsRegistry

_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_obs_recorder", default=None
)
_SEQ = itertools.count()
_COMPILE_PREFIX = "/jax/core/compile/"
_hook_lock = threading.Lock()
_hook_installed = False

MODES = ("metrics", "trace")


def get_recorder():
    """The recorder active in this thread/context, or None."""
    return _ACTIVE.get()


@contextlib.contextmanager
def use(recorder):
    """Make ``recorder`` the active recorder for the dynamic extent.

    ``use(None)`` is a no-op context — callers can pass an optional
    recorder straight through without branching.
    """
    if recorder is None:
        yield None
        return
    token = _ACTIVE.set(recorder)
    try:
        yield recorder
    finally:
        _ACTIVE.reset(token)


class _NoopSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP_SPAN = _NoopSpan()


class _Span:
    __slots__ = ("_rec", "name", "cat", "attrs", "_t0")

    def __init__(self, rec, name, cat, attrs):
        self._rec = rec
        self.name = name
        self.cat = cat
        self.attrs = attrs

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._rec.complete(self.name, self._t0, time.perf_counter(), self.cat, self.attrs)
        return False


def span(name: str, cat: str = "stage", attrs: dict | None = None):
    """Context manager timing a block under the active recorder."""
    rec = _ACTIVE.get()
    if rec is None:
        return _NOOP_SPAN
    return _Span(rec, name, cat, attrs)


def traced(name: str | None = None, cat: str = "stage"):
    """Decorator form of :func:`span` (label defaults to the qualname)."""

    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rec = _ACTIVE.get()
            if rec is None:
                return fn(*args, **kwargs)
            t0 = time.perf_counter()
            try:
                return fn(*args, **kwargs)
            finally:
                rec.complete(label, t0, time.perf_counter(), cat, None)

        return wrapper

    return deco


def _on_jax_event(event, duration_secs, **kw):
    """Forward jax compile-duration monitoring events to the recorder
    active in the compiling thread (jit compiles happen on the dispatch
    thread, so the contextvar lookup lands on the right session)."""
    if not event.startswith(_COMPILE_PREFIX):
        return
    rec = _ACTIVE.get()
    if rec is None:
        return
    kind = event[len(_COMPILE_PREFIX):]
    if kind.endswith("_duration"):
        kind = kind[: -len("_duration")]
    rec.compile_event(kind, float(duration_secs))


def install_compile_listener() -> bool:
    """Register the process-wide jax.monitoring listener once.

    ``backend_compile`` events fire only on actual jit cache misses;
    ``jaxpr_trace`` / ``jaxpr_to_mlir_module`` cover the tracing and
    lowering stages.  Safe without jax installed (returns False).
    """
    global _hook_installed
    if _hook_installed:
        return True
    with _hook_lock:
        if _hook_installed:
            return True
        try:
            from jax import monitoring
        except Exception:
            return False
        monitoring.register_event_duration_secs_listener(_on_jax_event)
        _hook_installed = True
        return True


def _slug(s: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]+", "-", str(s)) or "run"


class Recorder:
    """Per-session event sink: spans -> metrics, JSONL, Chrome trace.

    mode="metrics": span durations feed the registry's histograms and
    counters only — no event retention, no files.
    mode="trace": additionally retains trace_event dicts in memory and,
    when ``trace_dir`` is set, appends each event to a crash-safe JSONL
    log as it completes (same durability posture as the checkpoint
    store: a crash loses at most the partial last line).
    """

    def __init__(
        self,
        mode: str = "trace",
        labels: dict | None = None,
        registry: MetricsRegistry | None = None,
        trace_dir: str | None = None,
        name: str | None = None,
    ):
        if mode not in MODES:
            raise ValueError(f"Recorder mode must be one of {MODES}, got {mode!r}")
        self.mode = mode
        self.labels = dict(labels or {})
        self.registry = registry if registry is not None else MetricsRegistry()
        self.name = _slug(name if name is not None else self.labels.get("session", "run"))
        self.trace_dir = trace_dir
        self._lock = threading.Lock()
        self._events: list = []
        self._jsonl = None
        self.jsonl_path = None
        self.chrome_path = None
        if trace_dir is not None and mode == "trace":
            os.makedirs(trace_dir, exist_ok=True)
            stem = f"{self.name}-{os.getpid()}-{next(_SEQ)}"
            self.jsonl_path = os.path.join(trace_dir, f"events-{stem}.jsonl")
            self.chrome_path = os.path.join(trace_dir, f"trace-{stem}.json")
            self._jsonl = JsonlWriter(self.jsonl_path)
        install_compile_listener()

    # -- labels ---------------------------------------------------------

    def set_label(self, key: str, value) -> None:
        with self._lock:
            self.labels[key] = value

    def pop_label(self, key: str) -> None:
        with self._lock:
            self.labels.pop(key, None)

    # -- emission -------------------------------------------------------

    def complete(self, name, t0, t1, cat="stage", attrs=None) -> None:
        """Record a finished span [t0, t1] (perf_counter seconds)."""
        dur = max(0.0, t1 - t0)
        self.registry.counter(f"span.{name}.count").inc()
        self.registry.histogram(f"span.{name}.s").observe(dur)
        if self.mode != "trace":
            return
        with self._lock:
            ev = {
                "name": name,
                "cat": cat,
                "ph": "X",
                "ts": round(t0 * 1e6, 3),
                "dur": round(dur * 1e6, 3),
                "pid": os.getpid(),
                "tid": threading.get_ident(),
                "args": {**self.labels, **(attrs or {})},
            }
            self._events.append(ev)
            if self._jsonl is not None:
                self._jsonl.write(ev)

    def instant(self, name, cat="mark", attrs=None) -> None:
        self.registry.counter(f"mark.{name}.count").inc()
        if self.mode != "trace":
            return
        with self._lock:
            ev = {
                "name": name,
                "cat": cat,
                "ph": "i",
                "s": "t",
                "ts": round(time.perf_counter() * 1e6, 3),
                "pid": os.getpid(),
                "tid": threading.get_ident(),
                "args": {**self.labels, **(attrs or {})},
            }
            self._events.append(ev)
            if self._jsonl is not None:
                self._jsonl.write(ev)

    def compile_event(self, kind: str, duration_s: float) -> None:
        """A jit compile stage reported by jax.monitoring; rendered as a
        span ending now (the listener fires at stage completion)."""
        t1 = time.perf_counter()
        self.registry.counter("compile.events").inc()
        self.registry.histogram("compile.s").observe(duration_s)
        self.complete(f"compile:{kind}", t1 - duration_s, t1, cat="compile", attrs=None)

    def span(self, name, cat="stage", attrs=None):
        return _Span(self, name, cat, attrs)

    def begin(self, name, cat="stage", attrs=None) -> dict:
        """Open a span closed later by :meth:`end` — for spans that cross
        method boundaries (begin_sweep/end_sweep)."""
        return {"name": name, "cat": cat, "attrs": attrs, "t0": time.perf_counter()}

    def end(self, handle: dict) -> None:
        self.complete(
            handle["name"], handle["t0"], time.perf_counter(),
            handle["cat"], handle["attrs"],
        )

    def activate(self):
        return use(self)

    # -- inspection / shutdown -----------------------------------------

    def events(self) -> list:
        with self._lock:
            return list(self._events)

    def stage_seconds(self, cats=("stage",)) -> dict:
        """Summed span seconds by name over the given categories."""
        out: dict = {}
        for ev in self.events():
            if ev.get("ph") == "X" and ev.get("cat") in cats:
                out[ev["name"]] = out.get(ev["name"], 0.0) + ev["dur"] / 1e6
        return out

    def close(self) -> None:
        """Flush the JSONL log and write the Chrome/Perfetto timeline."""
        with self._lock:
            if self._jsonl is not None:
                self._jsonl.close()
                self._jsonl = None
            events = list(self._events)
        if self.chrome_path is not None:
            write_chrome_trace(self.chrome_path, events)
