"""Unified observability layer: tracing, metrics, exportable timelines.

Public surface:

- :mod:`repro.obs.trace` — ``trace.span(...)`` / ``@traced`` /
  ``trace.use(recorder)``; :class:`Recorder` owning event emission,
  JSONL + Chrome/Perfetto output, and jit compile-span capture.
- :class:`MetricsRegistry` — process-wide counters/gauges/histograms
  plus lazily-evaluated stats-dict sources.
- :mod:`repro.obs.export` — JSONL/Chrome/Prometheus writers, the trace
  event schema validator, and the ``json_safe`` sweep-record converter.

Enabled through ``EngineOptions(obs="metrics"|"trace", trace_dir=...)``
and ``ServingOptions``; everything is zero-overhead when ``obs="off"``
(no recorder active — ``trace.span`` returns a shared no-op).
"""

from . import trace
from .export import (
    chrome_trace,
    json_safe,
    prometheus_text,
    read_jsonl,
    start_metrics_server,
    validate_events,
    write_chrome_trace,
)
from .metrics import LATENCY_BUCKETS_S, Counter, Gauge, Histogram, MetricsRegistry
from .trace import Recorder, get_recorder, span, traced, use


def engine_stage_split(recorder) -> dict:
    """Aggregate a recorder's engine stage spans into the historical
    per-stage split shape: ``{"gram_s":…, "zcores_s":…, "fold_s":…,
    "path": "device"|"host"[, "small_batch": True]}`` — the keys
    BENCH_frontier.json has carried since PR 2."""
    out: dict = {}
    path = None
    small = False
    for ev in recorder.events():
        if ev.get("ph") != "X" or ev.get("cat") != "stage":
            continue
        if ev["name"] not in ("gram", "zcores", "fold"):
            continue
        key = ev["name"] + "_s"
        out[key] = out.get(key, 0.0) + ev["dur"] / 1e6
        args = ev.get("args", {})
        if args.get("path") is not None:
            path = args["path"]
        small = small or bool(args.get("small_batch"))
    if path is not None:
        out["path"] = path
    if small:
        out["small_batch"] = True
    return out


__all__ = [
    "trace",
    "Recorder",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "LATENCY_BUCKETS_S",
    "span",
    "traced",
    "use",
    "get_recorder",
    "json_safe",
    "validate_events",
    "chrome_trace",
    "write_chrome_trace",
    "read_jsonl",
    "prometheus_text",
    "start_metrics_server",
    "engine_stage_split",
]
