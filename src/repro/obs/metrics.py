"""Process-wide metrics registry: counters, gauges, histograms, sources.

The registry is the single sink the repo's scattered telemetry dicts
re-register into (``GramBlockCache.stats``, ``FeatureBank.stats``, the
degradation ladder, constraint counters, serving admission stats).  The
owning objects keep their dicts — every pre-existing ``sweep_log`` /
``telemetry()`` key stays bitwise-identical — and expose them here as
*sources*: zero-arg callables returning a flat dict, evaluated lazily at
:meth:`MetricsRegistry.snapshot` time.  New measurements (span latencies,
compile events) use first-class typed instruments.

Instrument names are dotted lowercase (``gram_cache.hits``,
``span.fold.s``); the Prometheus renderer in :mod:`repro.obs.export`
prefixes ``repro_`` and sanitizes the rest.
"""

from __future__ import annotations

import bisect
import threading

# Fixed latency buckets (seconds) shared by every duration histogram so
# percentiles stay comparable across subsystems.
LATENCY_BUCKETS_S = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 15.0, 60.0)


class Counter:
    """Monotonic counter. ``inc`` is thread-safe."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease (inc {n})")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Fixed-bucket histogram (cumulative render, Prometheus-style)."""

    __slots__ = ("name", "buckets", "_lock", "_counts", "_sum", "_count")

    def __init__(self, name: str, buckets: tuple = LATENCY_BUCKETS_S):
        if tuple(sorted(buckets)) != tuple(buckets) or not buckets:
            raise ValueError(f"histogram {name!r} buckets must be sorted, non-empty")
        self.name = name
        self.buckets = tuple(float(b) for b in buckets)
        self._lock = threading.Lock()
        self._counts = [0] * (len(self.buckets) + 1)  # last slot = +Inf
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        idx = bisect.bisect_left(self.buckets, v)
        with self._lock:
            self._counts[idx] += 1
            self._sum += v
            self._count += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def to_dict(self) -> dict:
        with self._lock:
            counts = list(self._counts)
            total, s = self._count, self._sum
        cum, out = 0, {}
        for le, c in zip(self.buckets, counts):
            cum += c
            out[le] = cum
        return {"buckets": out, "count": total, "sum": s}


class MetricsRegistry:
    """Get-or-create instrument registry + lazy dict sources.

    One :meth:`snapshot` call replaces reading five bespoke stats dicts;
    the dicts themselves are untouched (back-compat is a hard
    requirement — see ISSUE 10 acceptance criteria).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict = {}
        self._gauges: dict = {}
        self._histograms: dict = {}
        self._sources: dict = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name)
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name)
            return g

    def histogram(self, name: str, buckets: tuple = LATENCY_BUCKETS_S) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(name, buckets)
            return h

    def register_source(self, name: str, supplier) -> None:
        """Attach a zero-arg callable returning a flat stats dict.

        Re-registering a name replaces the supplier (a resumed session
        re-attaches its caches without error).
        """
        if not callable(supplier):
            raise TypeError(f"source {name!r} supplier must be callable")
        with self._lock:
            self._sources[name] = supplier

    def unregister_source(self, name: str) -> None:
        with self._lock:
            self._sources.pop(name, None)

    def snapshot(self) -> dict:
        """One call, every number: instruments + evaluated sources."""
        with self._lock:
            counters = {n: c.value for n, c in self._counters.items()}
            gauges = {n: g.value for n, g in self._gauges.items()}
            hists = {n: h.to_dict() for n, h in self._histograms.items()}
            sources = dict(self._sources)
        evaluated = {}
        for name, supplier in sources.items():
            try:
                evaluated[name] = dict(supplier())
            except Exception as e:  # a dead source must not poison the snapshot
                evaluated[name] = {"error": f"{type(e).__name__}: {e}"}
        return {
            "counters": counters,
            "gauges": gauges,
            "histograms": hists,
            "sources": evaluated,
        }
