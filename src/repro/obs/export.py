"""Exporters + hygiene helpers: JSONL, Chrome/Perfetto, Prometheus.

Three formats, one event schema (see :func:`validate_events`):

- **JSONL** — append-only, one ``trace_event`` dict per line, flushed per
  write so a crash loses at most the partial final line (same posture as
  the checkpoint store it sits alongside).
- **Chrome/Perfetto** — ``{"traceEvents": [...]}`` with "X" complete
  events; ts/dur are microseconds and nesting is implied per tid, so the
  file loads directly in ``ui.perfetto.dev`` / ``chrome://tracing``.
- **Prometheus text** — counters/gauges/histograms plus flattened
  sources, rendered with a ``repro_`` prefix and sanitized names.

Also home to :func:`json_safe`, the ``end_sweep``-seam converter that
keeps jax/numpy scalars and device arrays out of ``RunState`` payloads.
"""

from __future__ import annotations

import json
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer


class JsonlWriter:
    """Append-only, per-line-flushed JSONL sink."""

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        self._f = open(path, "a", encoding="utf-8")

    def write(self, obj) -> None:
        line = json.dumps(obj, separators=(",", ":"))
        with self._lock:
            if self._f is None:
                return
            self._f.write(line + "\n")
            self._f.flush()

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None


# -- Chrome / Perfetto --------------------------------------------------


def chrome_trace(events, metadata: dict | None = None) -> dict:
    doc = {"traceEvents": list(events), "displayTimeUnit": "ms"}
    if metadata:
        doc["metadata"] = dict(metadata)
    return doc


def write_chrome_trace(path: str, events, metadata: dict | None = None) -> str:
    with open(path, "w", encoding="utf-8") as f:
        json.dump(chrome_trace(events, metadata), f)
    return path


def read_jsonl(path: str) -> list:
    """Load a JSONL event log, tolerating a torn final line (crash)."""
    out = []
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                break  # torn tail from a crash; everything before is good
    return out


# -- event schema -------------------------------------------------------

_PHASES = ("X", "i")


def validate_events(events) -> list:
    """Schema-check trace events; returns a list of error strings.

    Required for every event: str ``name``/``cat``, ``ph`` in {X, i},
    numeric non-negative ``ts``, int ``pid``/``tid``, JSON-safe ``args``
    dict.  "X" events additionally need numeric non-negative ``dur``.
    """
    errors = []
    for i, ev in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not a dict")
            continue
        name = ev.get("name")
        if not isinstance(name, str) or not name:
            errors.append(f"{where}: bad name {name!r}")
        if not isinstance(ev.get("cat"), str):
            errors.append(f"{where} ({name}): bad cat")
        if ev.get("ph") not in _PHASES:
            errors.append(f"{where} ({name}): ph must be one of {_PHASES}")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"{where} ({name}): bad ts {ts!r}")
        if ev.get("ph") == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where} ({name}): bad dur {dur!r}")
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), int):
                errors.append(f"{where} ({name}): bad {key}")
        args = ev.get("args")
        if not isinstance(args, dict):
            errors.append(f"{where} ({name}): args must be a dict")
        else:
            try:
                json.dumps(args)
            except (TypeError, ValueError) as e:
                errors.append(f"{where} ({name}): args not JSON-safe: {e}")
    return errors


# -- sweep-record hygiene ----------------------------------------------


def json_safe(obj, path: str = "record"):
    """Return ``obj`` with numpy/jax leaves converted to plain Python.

    Container types are preserved (tuples stay tuples — ``json.dumps``
    renders them as arrays, and ``RunState`` round-trips depend on the
    step tuples keeping their type), scalar leaves are unwrapped via
    ``.item()``, small arrays via ``.tolist()``.  Anything else raises
    ``TypeError`` naming the offending key path, so a device array
    leaking into a sweep record fails loudly at the ``end_sweep`` seam
    instead of at checkpoint-serialization time.
    """
    if obj is None or isinstance(obj, (bool, str)):
        return obj
    if isinstance(obj, (int, float)):
        # unwrap numeric subclasses too: np.float64 IS a float subclass,
        # and a clean payload carries only stdlib leaves
        if type(obj) in (int, float):
            return obj
        return int(obj) if isinstance(obj, int) else float(obj)
    if isinstance(obj, dict):
        out = {}
        for k, v in obj.items():
            if not isinstance(k, str):
                raise TypeError(f"{path}: non-string key {k!r}")
            out[k] = json_safe(v, f"{path}.{k}")
        return out
    if isinstance(obj, tuple):
        return tuple(json_safe(v, f"{path}[{i}]") for i, v in enumerate(obj))
    if isinstance(obj, list):
        return [json_safe(v, f"{path}[{i}]") for i, v in enumerate(obj)]
    # numpy scalars / 0-d arrays / small jax arrays: duck-typed so this
    # module stays importable without jax.
    shape = getattr(obj, "shape", None)
    if shape == () and hasattr(obj, "item"):
        v = obj.item()
        if isinstance(v, (bool, int, float, str)):
            return v
    if shape is not None and hasattr(obj, "tolist"):
        return json_safe(obj.tolist(), path)
    raise TypeError(
        f"{path} is not JSON-safe: {type(obj).__module__}.{type(obj).__name__}"
    )


# -- Prometheus ---------------------------------------------------------

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def _prom_name(name: str) -> str:
    return "repro_" + _NAME_RE.sub("_", str(name))


def _fmt(v: float) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


def prometheus_text(registry) -> str:
    """Render a :class:`~repro.obs.metrics.MetricsRegistry` snapshot as
    Prometheus exposition text (counters, gauges, histograms, and
    numeric source fields flattened to gauges)."""
    snap = registry.snapshot()
    lines = []
    for name, value in sorted(snap["counters"].items()):
        pn = _prom_name(name)
        lines.append(f"# TYPE {pn} counter")
        lines.append(f"{pn} {_fmt(value)}")
    for name, value in sorted(snap["gauges"].items()):
        pn = _prom_name(name)
        lines.append(f"# TYPE {pn} gauge")
        lines.append(f"{pn} {_fmt(value)}")
    for name, h in sorted(snap["histograms"].items()):
        pn = _prom_name(name)
        lines.append(f"# TYPE {pn} histogram")
        for le, cum in h["buckets"].items():
            lines.append(f'{pn}_bucket{{le="{le}"}} {cum}')
        lines.append(f'{pn}_bucket{{le="+Inf"}} {h["count"]}')
        lines.append(f"{pn}_sum {_fmt(h['sum'])}")
        lines.append(f"{pn}_count {h['count']}")
    for source, stats in sorted(snap["sources"].items()):
        for key, value in sorted(stats.items()):
            if isinstance(value, bool) or not isinstance(value, (int, float)):
                continue
            pn = _prom_name(f"{source}.{key}")
            lines.append(f"# TYPE {pn} gauge")
            lines.append(f"{pn} {_fmt(value)}")
    return "\n".join(lines) + "\n"


class _MetricsHandler(BaseHTTPRequestHandler):
    registry = None

    def do_GET(self):  # noqa: N802 (stdlib API)
        if self.path.rstrip("/") not in ("", "/metrics"):
            self.send_error(404)
            return
        body = prometheus_text(self.registry).encode()
        self.send_response(200)
        self.send_header("Content-Type", "text/plain; version=0.0.4")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *args):  # keep serving output clean
        pass


def start_metrics_server(registry, host: str = "127.0.0.1", port: int = 0):
    """Serve ``/metrics`` on a daemon thread; returns the server (use
    ``server.server_address[1]`` for the bound port, ``shutdown()`` to
    stop)."""
    handler = type("_Bound", (_MetricsHandler,), {"registry": registry})
    server = ThreadingHTTPServer((host, port), handler)
    thread = threading.Thread(
        target=server.serve_forever, name="obs-metrics", daemon=True
    )
    thread.start()
    return server
