"""Pure-jnp oracles for the Pallas kernels (the correctness reference)."""

from __future__ import annotations

import jax.numpy as jnp


def rbf_gram_ref(x: jnp.ndarray, y: jnp.ndarray, width) -> jnp.ndarray:
    """K[i, j] = exp(-||x_i - y_j||^2 / (2 width^2)); x (n,d), y (m,d)."""
    xn = jnp.sum(x * x, axis=-1, keepdims=True)
    yn = jnp.sum(y * y, axis=-1, keepdims=True).T
    d2 = jnp.maximum(xn + yn - 2.0 * (x @ y.T), 0.0)
    return jnp.exp(-d2 / (2.0 * width * width))


def centered_gram_ref(lam: jnp.ndarray) -> jnp.ndarray:
    """C = (Lam - mean)^T (Lam - mean) over rows; lam (n, m) -> (m, m)."""
    lc = lam - jnp.mean(lam, axis=0, keepdims=True)
    return lc.T @ lc
