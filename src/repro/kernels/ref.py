"""Pure-jnp oracles for the Pallas kernels (the correctness reference)."""

from __future__ import annotations

import jax.numpy as jnp


def rbf_gram_ref(x: jnp.ndarray, y: jnp.ndarray, width) -> jnp.ndarray:
    """K[i, j] = exp(-||x_i - y_j||^2 / (2 width^2)); x (n,d), y (m,d)."""
    xn = jnp.sum(x * x, axis=-1, keepdims=True)
    yn = jnp.sum(y * y, axis=-1, keepdims=True).T
    d2 = jnp.maximum(xn + yn - 2.0 * (x @ y.T), 0.0)
    return jnp.exp(-d2 / (2.0 * width * width))


def feature_strip_ref(x, pivots, width, kind: str = "rbf") -> jnp.ndarray:
    """Direct (broadcast-difference) oracle for the feature_strip
    dispatcher: K[i, j] = k(x_i, p_j) for the rbf / delta / linear kinds.
    Deliberately uses the naive O(n m d) pairwise-difference form — a
    different algebra from both fast paths."""
    x = jnp.asarray(x)
    pivots = jnp.asarray(pivots)
    if x.ndim == 1:
        x = x[:, None]
    if pivots.ndim == 1:
        pivots = pivots[:, None]
    if kind == "linear":
        return x @ pivots.T
    d2 = jnp.sum((x[:, None, :] - pivots[None, :, :]) ** 2, axis=-1)
    if kind == "rbf":
        return jnp.exp(-d2 / (2.0 * width * width))
    if kind == "delta":
        return (d2 < 1e-18).astype(x.dtype)
    raise ValueError(f"unknown kernel kind {kind!r}")


def centered_gram_ref(lam: jnp.ndarray) -> jnp.ndarray:
    """C = (Lam - mean)^T (Lam - mean) over rows; lam (n, m) -> (m, m)."""
    lc = lam - jnp.mean(lam, axis=0, keepdims=True)
    return lc.T @ lc


def fold_gram_strip_ref(bank_a, bank_b, ia, ib, q: int) -> jnp.ndarray:
    """Gather-then-Gram oracle for the fused fold-Gram strip kernel.

    bank_a (Sa, n_eff, ma), bank_b (Sb, n_eff, mb), ia/ib (B,) ints with
    n_eff = q * n0 -> (B, q, ma, mb):
    out[c, f] = bank_a[ia[c], fold_f]^T bank_b[ib[c], fold_f].

    Materializes the gathered (B, q, n0, m) intermediates the fused kernel
    exists to avoid — the correctness reference, not the fast path.
    """
    n_eff = bank_a.shape[1]
    n0 = n_eff // q
    fa = bank_a[jnp.asarray(ia)].reshape(len(ia), q, n0, bank_a.shape[-1])
    fb = bank_b[jnp.asarray(ib)].reshape(len(ib), q, n0, bank_b.shape[-1])
    return jnp.einsum("cqni,cqnj->cqij", fa, fb)


def fold_gram_strip_banked_ref(bank_a, bank_b, ia, ib, out_bank, slots, q: int):
    """Oracle for the banked strip: compute the strip, then write block c
    into bank row slots[c] sequentially (later writes win on duplicate
    slots — only scratch-slot padding rows are allowed to duplicate).
    Rows not named in ``slots`` keep their prior contents."""
    import numpy as np

    grams = np.asarray(fold_gram_strip_ref(bank_a, bank_b, ia, ib, q))
    out = np.array(out_bank)
    for c, s in enumerate(np.asarray(slots)):
        out[int(s)] = grams[c].astype(out.dtype)
    return out
