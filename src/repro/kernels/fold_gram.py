"""Pallas TPU kernel: fused bank-gather + fold-blocked Gram strip.

The batched frontier engine's hot contraction is, per candidate pair
(a, b) and fold f,

    out[c, f] = A_f^T B_f,   A_f = bank_a[ia[c], f]  (n0, ma)
                             B_f = bank_b[ib[c], f]  (n0, mb)

i.e. a (B, q, n0, m) x (B, q, n0, m) -> (B, q, ma, mb) fold-Gram einsum
over *gathered* rows of two device-resident feature banks.  The unfused
form first materializes bank_a[ia] / bank_b[ib] — a (B, q, n0, m) HBM
tensor per side that is written once and read once, tripling the HBM
traffic of the contraction and dwarfing the (ma x mb) outputs.

TPU mapping (one pallas_call, no gathered intermediate):

  - grid (B, q, n0p / block_n); the candidate indices ia/ib ride in as
    scalar-prefetch operands, so each input BlockSpec's index_map picks
    the *bank row* to stream directly: block (1, 1, block_n, m) at
    (ia[c], f, t) — the gather happens in the DMA engine, factor rows
    flow HBM -> VMEM exactly once per (candidate, fold).
  - the kernel body is one MXU contraction per tile, accumulated into a
    revisited (1, 1, ma, mb) output block (zero-initialized at t == 0,
    the innermost / fastest-varying grid axis).
  - VMEM working set: block_n*(ma + mb) + ma*mb floats — ~0.5 MiB at the
    default block_n = 512 with ma = mb = 128, far under budget.  Shared
    bank rows (the same parent set against many children) additionally
    hit in VMEM across consecutive grid steps instead of being
    re-gathered per pair.

The same kernel serves the identity-gather case (ia = ib = arange) used
by the shard_map distributed scorer, where the "banks" are the already
fold-blocked per-candidate factors.

`fold_gram_strip_banked_pallas` is the device-resident-pipeline variant:
identical gather + contraction, but the *output* BlockSpec is also driven
by a scalar-prefetched index vector — block c lands at row ``slots[c]``
of a persistent, input/output-aliased block-bank tensor, so the scatter
into the engine's Gram banks happens in the output DMA and the chunk's
blocks never exist as a standalone (B, q, ma, mb) array, let alone on the
host.

Interpret mode executes the identical body on CPU (tested against the
kernels/ref.py jnp oracle in tests/test_kernels_pallas.py); dispatch
between this kernel and the jnp fallback lives in kernels/ops.py.

Precision: compiled (TPU) runs contract f64 inputs at f32 — Mosaic has
no f64 MXU path — so on TPU the batched engine matches the sequential
oracle only to f32 Gram accuracy (~1e-7 relative), the same policy as
the sibling rbf/centered kernels (documented at the api.py surface).
Interpret mode keeps the caller's dtype, preserving the engine's f64
guarantees on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fold_gram_banked_kernel(slots_ref, ia_ref, ib_ref, a_ref, b_ref, bank_ref, o_ref):
    del slots_ref, ia_ref, ib_ref, bank_ref  # indices drive the index_maps
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[0, 0]
    b = b_ref[0, 0]
    o_ref[0, 0] += jax.lax.dot_general(
        a, b, (((0,), (0,)), ((), ())), preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def fold_gram_strip_banked_pallas(
    bank_a: jnp.ndarray,
    bank_b: jnp.ndarray,
    ia: jnp.ndarray,
    ib: jnp.ndarray,
    out_bank: jnp.ndarray,
    slots: jnp.ndarray,
    *,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused strip-Gram + scatter-into-bank: the device-resident fold
    pipeline's compute stage writes each candidate's (q, ma, mb) Gram block
    straight into a *slot* of a persistent bank tensor instead of a fresh
    (B, q, ma, mb) output that a host drain would re-assemble.

    bank_a (Sa, q, n0p, ma), bank_b (Sb, q, n0p, mb), ia/ib/slots (B,)
    int32, out_bank (S_out, q, ma, mb) with n0p % block_n == 0; returns the
    updated bank:  out[slots[c], f] = bank_a[ia[c], f]^T bank_b[ib[c], f],
    every other slot byte-identical to ``out_bank``.

    The mechanism is the output BlockSpec: ``slots`` rides in as a third
    scalar-prefetch operand and the out index_map places block (c, f)'s
    accumulator at bank row ``slots[c]`` — the scatter happens in the
    output DMA, no gathered intermediate and no separate update kernel.
    ``out_bank`` is input/output-aliased, so untouched slots are preserved
    without being copied through VMEM.  Callers must NOT repeat a slot
    except for padding rows aimed at a write-only scratch slot (duplicate
    output blocks are revisited, so the last write wins but intermediate
    flushes are unspecified).  Same f64->f32 compiled-mode policy as
    `fold_gram_strip_pallas`; the contraction runs at ``out_bank.dtype``.
    """
    _, q, n0p, ma = bank_a.shape
    mb = bank_b.shape[-1]
    assert bank_b.shape[1:3] == (q, n0p), (bank_a.shape, bank_b.shape)
    assert out_bank.shape[1:] == (q, ma, mb), (out_bank.shape, (q, ma, mb))
    assert n0p % block_n == 0, (n0p, block_n)
    n_pairs = ia.shape[0]
    grid = (n_pairs, q, n0p // block_n)
    dtype = out_bank.dtype
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, 1, block_n, ma),
                lambda c, f, t, s, ia, ib: (ia[c], f, t, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_n, mb),
                lambda c, f, t, s, ia, ib: (ib[c], f, t, 0),
            ),
            pl.BlockSpec(
                (1, 1, ma, mb), lambda c, f, t, s, ia, ib: (s[c], f, 0, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, ma, mb), lambda c, f, t, s, ia, ib: (s[c], f, 0, 0)
        ),
    )
    return pl.pallas_call(
        _fold_gram_banked_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(out_bank.shape, dtype),
        # operand index 5 = out_bank (scalar-prefetch args count): alias so
        # unwritten slots keep their contents instead of starting undefined
        input_output_aliases={5: 0},
        interpret=interpret,
    )(
        jnp.asarray(slots, jnp.int32),
        jnp.asarray(ia, jnp.int32),
        jnp.asarray(ib, jnp.int32),
        bank_a.astype(dtype),
        bank_b.astype(dtype),
        out_bank,
    )


def _fold_gram_kernel(ia_ref, ib_ref, a_ref, b_ref, o_ref):
    del ia_ref, ib_ref  # consumed by the index_maps, not the body
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[0, 0]  # (block_n, ma) gathered bank tile, already in VMEM
    b = b_ref[0, 0]  # (block_n, mb)
    o_ref[0, 0] += jax.lax.dot_general(  # A^T B on the MXU
        a, b, (((0,), (0,)), ((), ())), preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def fold_gram_strip_pallas(
    bank_a: jnp.ndarray,
    bank_b: jnp.ndarray,
    ia: jnp.ndarray,
    ib: jnp.ndarray,
    *,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """bank_a (Sa, q, n0p, ma), bank_b (Sb, q, n0p, mb), ia/ib (B,) int32
    with n0p % block_n == 0; returns (B, q, ma, mb) with
    out[c, f] = bank_a[ia[c], f]^T bank_b[ib[c], f].
    """
    _, q, n0p, ma = bank_a.shape
    mb = bank_b.shape[-1]
    assert bank_b.shape[1:3] == (q, n0p), (bank_a.shape, bank_b.shape)
    assert n0p % block_n == 0, (n0p, block_n)
    n_pairs = ia.shape[0]
    grid = (n_pairs, q, n0p // block_n)
    dtype = jnp.result_type(bank_a.dtype, bank_b.dtype)
    if not interpret and dtype == jnp.float64:
        # Mosaic has no f64 MXU path: compiled (TPU) kernels contract at
        # f32, same policy as the sibling rbf/centered kernels.  Interpret
        # mode keeps the caller's f64 so the CPU tests validate the
        # engine's exact algebra.
        dtype = jnp.float32
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, 1, block_n, ma), lambda c, f, t, ia, ib: (ia[c], f, t, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_n, mb), lambda c, f, t, ia, ib: (ib[c], f, t, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, ma, mb), lambda c, f, t, ia, ib: (c, f, 0, 0)
        ),
    )
    return pl.pallas_call(
        _fold_gram_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_pairs, q, ma, mb), dtype),
        interpret=interpret,
    )(
        jnp.asarray(ia, jnp.int32),
        jnp.asarray(ib, jnp.int32),
        bank_a.astype(dtype),
        bank_b.astype(dtype),
    )
