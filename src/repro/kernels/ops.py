"""Jit'd public wrappers around the Pallas kernels.

Handle padding/masking so callers see arbitrary shapes; select interpret
mode automatically on non-TPU backends (this container is CPU-only — the
kernels are TPU-targeted and validated under interpret=True).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.centered_gram import gram_centered_pallas
from repro.kernels.rbf_gram import rbf_gram_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def rbf_gram(
    x,
    y,
    width,
    *,
    block_n: int = 256,
    block_m: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """K(X, Y) strip, any (n, d) x (m, d). Returns (n, m) float32."""
    if interpret is None:
        interpret = not _on_tpu()
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    if x.ndim == 1:
        x = x[:, None]
    if y.ndim == 1:
        y = y[:, None]
    n, m = x.shape[0], y.shape[0]
    # Zero-pad: rows -> sliced off; feature dim -> adds 0 to sq-dists.
    xp = _pad_to(_pad_to(x, 0, block_n), 1, 128)
    yp = _pad_to(_pad_to(y, 0, block_m), 1, 128)
    out = rbf_gram_pallas(
        xp, yp, width, block_n=block_n, block_m=block_m, interpret=interpret
    )
    return out[:n, :m]


def centered_gram(
    lam, *, block_n: int = 512, interpret: bool | None = None
) -> jnp.ndarray:
    """(Lam - mean)^T (Lam - mean) for Lam (n, m). Returns (m, m) float32."""
    if interpret is None:
        interpret = not _on_tpu()
    lam = jnp.asarray(lam, jnp.float32)
    n, m = lam.shape
    mu = jnp.mean(lam, axis=0, keepdims=True)  # cheap memory-bound pass
    pad = (-n) % block_n
    if pad:
        # Pad with copies of mu: padded rows contribute (mu - mu) = 0.
        lam = jnp.concatenate([lam, jnp.broadcast_to(mu, (pad, m))], axis=0)
    return gram_centered_pallas(lam, mu, block_n=block_n, interpret=interpret)
