"""Jit'd public wrappers around the Pallas kernels.

Handle padding/masking so callers see arbitrary shapes; select interpret
mode automatically on non-TPU backends (this container is CPU-only — the
kernels are TPU-targeted and validated under interpret=True).

`fold_gram_strip` / `fold_gram_blocks` are *dispatchers*: one call site in
the scoring engines, two backends — the fused Pallas strip kernel on TPU
(or under interpret=True for tests), a single-jit gather+einsum on other
backends (interpret-mode Pallas is far slower than XLA:CPU einsums, so it
is opt-in, never the production CPU path).

Every fold-Gram dispatcher takes a `precision` policy
(`repro.core.spec.EngineOptions.precision`): ``"bitwise"`` contracts at
the input dtype (f64 — the engine==oracle guarantee on CPU), while
``"f32_gram"`` makes the gather+einsum backend accumulate at float32 and
cast back.  The TPU Pallas kernels already contract at f32 (Mosaic has no
f64 MXU path), so on TPU the two policies coincide and the flag only
changes the CPU/GPU fallback.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.centered_gram import gram_centered_pallas
from repro.kernels.fold_gram import (
    fold_gram_strip_banked_pallas,
    fold_gram_strip_pallas,
)
from repro.kernels.rbf_gram import rbf_gram_pallas
from repro.obs.trace import traced

# Kernel-dispatch spans (repro.obs): the host-side dispatchers below are
# wrapped with @traced(cat="kernel") — a no-op without an active recorder.
# The spans time *dispatch* (host prep + async enqueue); device execute
# time surfaces in the engine's synced stage spans and the separate
# cat="compile" spans from jax's jit cache-miss monitoring events.
# `fold_gram_blocks` is deliberately NOT traced: it composes under
# jit/shard_map, where a host-side span would fire at trace time only.


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


_PRECISIONS = ("bitwise", "f32_gram")


def _check_precision(precision: str) -> None:
    if precision not in _PRECISIONS:
        raise ValueError(
            f"precision must be one of {_PRECISIONS}, got {precision!r}"
        )


def _pad_to(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@traced("rbf_gram", cat="kernel")
def rbf_gram(
    x,
    y,
    width,
    *,
    block_n: int = 256,
    block_m: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """K(X, Y) strip, any (n, d) x (m, d). Returns (n, m) float32."""
    if interpret is None:
        interpret = not _on_tpu()
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    if x.ndim == 1:
        x = x[:, None]
    if y.ndim == 1:
        y = y[:, None]
    n, m = x.shape[0], y.shape[0]
    # Zero-pad: rows -> sliced off; feature dim -> adds 0 to sq-dists.
    xp = _pad_to(_pad_to(x, 0, block_n), 1, 128)
    yp = _pad_to(_pad_to(y, 0, block_m), 1, 128)
    out = rbf_gram_pallas(
        xp, yp, width, block_n=block_n, block_m=block_m, interpret=interpret
    )
    return out[:n, :m]


@functools.partial(jax.jit, static_argnames=("kind",))
def _feature_strip_jnp(x, pivots, width, kind: str):
    """Non-TPU backend of the `feature_strip` dispatcher: one jit for the
    (n, m) kernel strip at the input dtype (f64 on the scorer paths).
    Identical algebra to `repro.core.kernel_fns._kernel_matrix` — the
    expanded-sq-dist form with the -2<x,y> matmul — so routing existing
    callers through the dispatcher is bitwise-neutral on CPU."""
    if kind == "linear":
        return x @ pivots.T
    xn = jnp.sum(x * x, axis=-1, keepdims=True)
    yn = jnp.sum(pivots * pivots, axis=-1, keepdims=True).T
    d2 = jnp.maximum(xn + yn - 2.0 * (x @ pivots.T), 0.0)
    if kind == "rbf":
        return jnp.exp(-d2 / (2.0 * width * width))
    if kind == "delta":
        return (d2 < 1e-18).astype(x.dtype)
    raise ValueError(f"unknown kernel kind {kind!r}")


@traced("feature_strip", cat="kernel")
def feature_strip(
    x,
    pivots,
    width,
    *,
    kind: str = "rbf",
    block_n: int = 256,
    block_m: int = 128,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """K(X, pivots): the (n, m) kernel strip — the factorization
    backends' hot spot (ICL pivot evaluation, Alg.-2 deduplicated rows,
    Nystroem landmarks; `repro.features.backends`).

    Dispatch mirrors `fold_gram_strip`: on TPU (or when forced with
    ``use_pallas=True``) the tiled Pallas kernel `repro.kernels.rbf_gram`
    serves RBF strips — rows stream HBM->VMEM once, fused sq-dist + exp,
    f32 accumulation cast back to the input dtype; elsewhere a single-jit
    strip at the input dtype (f64 on the scorer paths, bit-identical to
    the `repro.core.kernel_fns.kernel_rows` algebra).  The Pallas kernel
    implements the RBF kernel only: auto-dispatch quietly uses the jnp
    strip for other kinds, but *forcing* ``use_pallas=True`` with a
    non-RBF ``kind`` raises ValueError — silently ignoring the requested
    backend was the pre-PR-5 bug.  Oracle: `repro.kernels.ref.
    feature_strip_ref`.
    """
    x = jnp.asarray(x)
    pivots = jnp.asarray(pivots)
    if x.ndim == 1:
        x = x[:, None]
    if pivots.ndim == 1:
        pivots = pivots[:, None]
    if use_pallas is None:
        use_pallas = _on_tpu() and kind == "rbf"
    elif use_pallas and kind != "rbf":
        raise ValueError(
            "feature_strip(use_pallas=True) serves only kind='rbf' strips "
            f"(the Pallas kernel fuses sq-dist + exp); got kind={kind!r} — "
            "drop use_pallas to use the jnp strip for this kernel"
        )
    if not use_pallas:
        return _feature_strip_jnp(
            x, pivots, jnp.asarray(width, x.dtype), kind
        )
    out = rbf_gram(
        x, pivots, width, block_n=block_n, block_m=block_m,
        interpret=interpret,
    )
    return out.astype(jnp.result_type(x.dtype, pivots.dtype))


@functools.partial(jax.jit, static_argnames=("q", "precision"))
def _fold_gram_jnp(bank_a, bank_b, ia, ib, q: int, precision: str = "bitwise"):
    """Gather+fold-Gram in one jit (the non-TPU backend of the dispatcher):
    keeping the gather *inside* the jit keeps the per-chunk host work to a
    single dispatch — per-pair host-side stacking of bank slices was
    measured at ~0.2 s/chunk of pure overhead, 15x the einsum itself.
    Under ``precision="f32_gram"`` the contraction runs at float32 and the
    blocks are cast back to the banks' dtype (the f64 fold algebra
    downstream is unchanged)."""
    n_eff, ma = bank_a.shape[1:]
    n0 = n_eff // q
    fa = bank_a[ia].reshape(ia.shape[0], q, n0, ma)
    fb = bank_b[ib].reshape(ib.shape[0], q, n0, bank_b.shape[-1])
    if precision == "f32_gram":
        out_dt = jnp.result_type(bank_a.dtype, bank_b.dtype)
        return jnp.einsum(
            "cqni,cqnj->cqij",
            fa.astype(jnp.float32),
            fb.astype(jnp.float32),
        ).astype(out_dt)
    return jnp.einsum("cqni,cqnj->cqij", fa, fb)


@traced("fold_gram_strip", cat="kernel")
def fold_gram_strip(
    bank_a,
    bank_b,
    ia,
    ib,
    q: int,
    *,
    block_n: int = 512,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    precision: str = "bitwise",
) -> jnp.ndarray:
    """Per-fold Gram blocks for gathered bank pairs, any (S, n_eff, m).

    out[c, f] = bank_a[ia[c], fold_f]^T bank_b[ib[c], fold_f], shape
    (B, q, ma, mb).  On TPU this is the fused Pallas strip kernel
    (fold_gram.py): the candidate indices prefetch as scalars and the
    factor rows stream HBM->VMEM once, no (B, q, n0, m) gathered
    intermediate.  Elsewhere it is a fused single-jit gather+einsum
    unless `use_pallas=True` forces the (interpret-mode) kernel.
    `precision="f32_gram"` makes the einsum backend accumulate at f32
    (the Pallas kernel always does — module doc).
    """
    _check_precision(precision)
    if use_pallas is None:
        use_pallas = _on_tpu()
    if interpret is None:
        interpret = not _on_tpu()
    bank_a = jnp.asarray(bank_a)
    bank_b = jnp.asarray(bank_b)
    ia = jnp.asarray(ia, jnp.int32)
    ib = jnp.asarray(ib, jnp.int32)
    n_eff, ma = bank_a.shape[1:]
    mb = bank_b.shape[-1]
    assert n_eff % q == 0, (n_eff, q)  # loud on every backend
    n0 = n_eff // q
    if ma == 0 or mb == 0 or ia.shape[0] == 0:
        dt = jnp.result_type(bank_a.dtype, bank_b.dtype)
        return jnp.zeros((ia.shape[0], q, ma, mb), dt)
    if not use_pallas:
        return _fold_gram_jnp(bank_a, bank_b, ia, ib, q, precision)
    # Fold-block the banks and zero-pad each fold's rows to a tile
    # multiple (zero rows add nothing to A^T B).
    bn = min(block_n, -(-n0 // 8) * 8)
    n0p = -(-n0 // bn) * bn
    a4 = bank_a.reshape(-1, q, n0, ma)
    b4 = bank_b.reshape(-1, q, n0, mb)
    if n0p != n0:
        widths = ((0, 0), (0, 0), (0, n0p - n0), (0, 0))
        a4 = jnp.pad(a4, widths)
        b4 = jnp.pad(b4, widths)
    return fold_gram_strip_pallas(
        a4, b4, ia, ib, block_n=bn, interpret=interpret
    )


@functools.partial(
    jax.jit, static_argnames=("q", "precision"), donate_argnums=(4,)
)
def _fold_gram_banked_jnp(
    bank_a, bank_b, ia, ib, out_bank, slots, q: int, precision: str = "bitwise"
):
    """Non-TPU backend of the banked dispatcher: the same fused
    gather+fold-Gram einsum as `_fold_gram_jnp`, scattered into the bank
    inside the same jit — the chunk's Gram blocks never exist as a host
    array, and the einsum bits are identical to the unbanked path (the
    scatter is a pure data movement), which is what keeps the device-bank
    engine bitwise-equal to the host-assembly path on CPU.  ``out_bank``
    is *donated*: the scatter updates the bank buffer in place (measured
    30x per-chunk vs copying a many-MB bank tensor per update) — callers
    must treat the passed-in array as consumed and keep only the result,
    which is how the engine's cache tier manages ``DeviceGramBank.data``.
    """
    grams = _fold_gram_jnp(bank_a, bank_b, ia, ib, q, precision)
    return out_bank.at[slots].set(grams.astype(out_bank.dtype))


@traced("fold_gram_strip_banked", cat="kernel")
def fold_gram_strip_banked(
    bank_a,
    bank_b,
    ia,
    ib,
    out_bank,
    slots,
    q: int,
    *,
    block_n: int = 512,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    precision: str = "bitwise",
):
    """Fused per-fold Gram strip scattered into a device block bank.

    Same contract as `fold_gram_strip` for the compute —
    ``block[c, f] = bank_a[ia[c], fold_f]^T bank_b[ib[c], fold_f]`` over
    gathered rows of (S, n_eff, m) factor banks — but instead of returning
    the (B, q, ma, mb) strip it writes block ``c`` into row ``slots[c]`` of
    ``out_bank`` (shape (S_out, q, ma, mb)) and returns the updated bank;
    rows not named in ``slots`` are preserved bit-for-bit.  ``slots`` must
    not repeat a real slot; padding rows should all target a write-only
    scratch slot (see `DeviceGramBank.SCRATCH_SLOT`).

    Dispatch mirrors `fold_gram_strip`: on TPU the fused Pallas kernel
    scatters through its output BlockSpec (the bank row index rides in as a
    scalar-prefetch operand, input/output aliasing preserves untouched
    slots); elsewhere a single jit runs the gather+einsum and an
    ``out_bank.at[slots].set`` — one dispatch either way, no host copy.

    ``out_bank`` is updated IN PLACE on both backends (input/output
    aliasing on TPU, buffer donation on the jnp path): treat the array you
    pass as consumed and use only the returned bank — exactly how
    `repro.core.score_common.GramBlockCache` swaps ``DeviceGramBank.data``.
    ``precision="f32_gram"`` makes the jnp backend's einsum accumulate at
    f32 before the (dtype-preserving) scatter into the bank.
    """
    _check_precision(precision)
    if use_pallas is None:
        use_pallas = _on_tpu()
    if interpret is None:
        interpret = not _on_tpu()
    bank_a = jnp.asarray(bank_a)
    bank_b = jnp.asarray(bank_b)
    ia = jnp.asarray(ia, jnp.int32)
    ib = jnp.asarray(ib, jnp.int32)
    slots = jnp.asarray(slots, jnp.int32)
    n_eff, ma = bank_a.shape[1:]
    mb = bank_b.shape[-1]
    assert n_eff % q == 0, (n_eff, q)
    assert out_bank.shape[1:] == (q, ma, mb), (out_bank.shape, (q, ma, mb))
    n0 = n_eff // q
    if ma == 0 or mb == 0 or ia.shape[0] == 0:
        return out_bank
    if not use_pallas:
        return _fold_gram_banked_jnp(
            bank_a, bank_b, ia, ib, out_bank, slots, q, precision
        )
    bn = min(block_n, -(-n0 // 8) * 8)
    n0p = -(-n0 // bn) * bn
    a4 = bank_a.reshape(-1, q, n0, ma)
    b4 = bank_b.reshape(-1, q, n0, mb)
    if n0p != n0:
        widths = ((0, 0), (0, 0), (0, n0p - n0), (0, 0))
        a4 = jnp.pad(a4, widths)
        b4 = jnp.pad(b4, widths)
    return fold_gram_strip_banked_pallas(
        a4, b4, ia, ib, out_bank, slots, block_n=bn, interpret=interpret
    )


def fold_gram_blocks(
    fa,
    fb,
    *,
    block_n: int = 512,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    precision: str = "bitwise",
) -> jnp.ndarray:
    """Per-fold Grams for already fold-blocked factors (identity gather).

    fa (..., q, n0, ma), fb (..., q, n0, mb) -> (..., q, ma, mb) with
    out[..., f] = fa[..., f]^T fb[..., f].  The shard_map distributed
    scorer's Gram stage: on TPU the leading dims collapse into the fused
    strip kernel's candidate axis with ia = ib = arange; elsewhere one
    einsum (accumulated at f32 under ``precision="f32_gram"``).  Composes
    under jit/shard_map (backend choice is trace-time).
    """
    _check_precision(precision)
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        if precision == "f32_gram":
            out_dt = jnp.result_type(fa.dtype, fb.dtype)
            return jnp.einsum(
                "...qni,...qnj->...qij",
                fa.astype(jnp.float32),
                fb.astype(jnp.float32),
            ).astype(out_dt)
        return jnp.einsum("...qni,...qnj->...qij", fa, fb)
    if interpret is None:
        interpret = not _on_tpu()
    lead = fa.shape[:-3]
    q, n0, ma = fa.shape[-3:]
    mb = fb.shape[-1]
    n_lead = 1
    for s in lead:
        n_lead *= s
    if ma == 0 or mb == 0 or n_lead == 0 or n0 == 0:
        # degenerate shapes (empty shard / zero-width factor): same empty
        # result as the einsum backend instead of a kernel-launch error
        dt = jnp.result_type(fa.dtype, fb.dtype)
        return jnp.zeros(lead + (q, ma, mb), dt)
    idx = jnp.arange(n_lead, dtype=jnp.int32)
    a = fa.reshape(n_lead, q, n0, ma)
    b = fb.reshape(n_lead, q, n0, mb)
    bn = min(block_n, -(-n0 // 8) * 8)
    n0p = -(-n0 // bn) * bn
    if n0p != n0:
        widths = ((0, 0), (0, 0), (0, n0p - n0), (0, 0))
        a = jnp.pad(a, widths)
        b = jnp.pad(b, widths)
    out = fold_gram_strip_pallas(a, b, idx, idx, block_n=bn, interpret=interpret)
    return out.reshape(lead + (q, ma, mb))


@traced("centered_gram", cat="kernel")
def centered_gram(
    lam, *, block_n: int = 512, interpret: bool | None = None
) -> jnp.ndarray:
    """(Lam - mean)^T (Lam - mean) for Lam (n, m). Returns (m, m) float32."""
    if interpret is None:
        interpret = not _on_tpu()
    lam = jnp.asarray(lam, jnp.float32)
    n, m = lam.shape
    mu = jnp.mean(lam, axis=0, keepdims=True)  # cheap memory-bound pass
    pad = (-n) % block_n
    if pad:
        # Pad with copies of mu: padded rows contribute (mu - mu) = 0.
        lam = jnp.concatenate([lam, jnp.broadcast_to(mu, (pad, m))], axis=0)
    return gram_centered_pallas(lam, mu, block_n=block_n, interpret=interpret)
