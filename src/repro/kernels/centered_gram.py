"""Pallas TPU kernel: fused mean-centering + Gram contraction.

Computes C = (Lam - mu)^T (Lam - mu) for Lam (n, m) — the P/E/F/V/U/S
Gram-block stage of the CV-LR score — without ever materializing the
centered copy of Lam (the dominant O(n m) tensor) in HBM.

Numerics note: the one-pass algebraic form Lam^T Lam - n mu mu^T suffers
catastrophic fp32 cancellation when ||mu|| is large (verified by test
`test_centered_gram_nonzero_mean`), so we use the stable two-read scheme:
a cheap column-mean pass (memory-bound, done by the wrapper), then this
kernel streams row tiles HBM->VMEM, subtracts mu on the VPU and accumulates
the (m, m) Gram on the MXU into a revisited output block (zero-initialized
at grid step 0).  Total HBM traffic: 2 reads of Lam + m^2 write, vs.
2 reads + O(n m) extra write+read for the unfused center-then-matmul.

Row padding: the wrapper pads n up to a block multiple with copies of mu,
so padded rows contribute (mu - mu) = 0 to the accumulation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _centered_gram_kernel(lam_ref, mu_ref, gram_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        gram_ref[...] = jnp.zeros_like(gram_ref)

    tile = lam_ref[...] - mu_ref[...]  # (bn, m) - (1, m): VPU
    gram_ref[...] += jnp.dot(tile.T, tile, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def gram_centered_pallas(
    lam: jnp.ndarray,
    mu: jnp.ndarray,
    *,
    block_n: int = 512,
    interpret: bool = False,
):
    """lam (n, m) with n % block_n == 0, mu (1, m) -> (m, m) Gram."""
    n, m = lam.shape
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    return pl.pallas_call(
        _centered_gram_kernel,
        out_shape=jax.ShapeDtypeStruct((m, m), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, m), lambda i: (0, 0)),
        interpret=interpret,
    )(lam.astype(jnp.float32), mu.astype(jnp.float32))
