"""Pallas TPU kernel: tiled RBF kernel strip K(X, Y) = exp(-d2/(2 s^2)).

This is the Nystroem-feature / ICL hot spot of the paper's score (O(n m d)
kernel evaluations per score).  TPU mapping:

  - the width is folded into the inputs up front (x' = x/(w sqrt 2)), so the
    kernel body is scalar-free:  K = exp(-||x'_i - y'_j||^2).
  - grid (n/bn, m/bm); each step loads an X tile (bn, d) and a Y tile
    (bm, d) HBM->VMEM, forms the -2 X Y^T term on the MXU
    (jnp.dot, preferred_element_type=f32) and fuses the row/col norms and
    exp on the VPU.  The (n, m) kernel strip is written back once — no
    intermediate pairwise-distance tensor ever exists in HBM.
  - block sizes default to (256, 128): MXU-aligned (multiples of 128 in the
    lane dim) and a VMEM working set of bn*d + bm*d + bn*bm floats
    (< 1 MiB for d <= 512), far under the ~16 MiB VMEM budget.

The feature dim d is zero-padded to a multiple of 128 by the ops.py wrapper
(zero columns add nothing to squared distances).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rbf_kernel(x_ref, y_ref, o_ref):
    x = x_ref[...]  # (bn, d), pre-scaled by 1/(w sqrt 2)
    y = y_ref[...]  # (bm, d)
    xn = jnp.sum(x * x, axis=-1, keepdims=True)  # (bn, 1)   VPU
    yn = jnp.sum(y * y, axis=-1, keepdims=True).T  # (1, bm) VPU
    xy = jnp.dot(x, y.T, preferred_element_type=jnp.float32)  # MXU
    d2 = jnp.maximum(xn + yn - 2.0 * xy, 0.0)
    o_ref[...] = jnp.exp(-d2)


@functools.partial(jax.jit, static_argnames=("block_n", "block_m", "interpret"))
def rbf_gram_pallas(
    x: jnp.ndarray,
    y: jnp.ndarray,
    width,
    *,
    block_n: int = 256,
    block_m: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """x (n, d), y (m, d) with n % block_n == m % block_m == 0."""
    n, d = x.shape
    m = y.shape[0]
    assert n % block_n == 0 and m % block_m == 0, (n, m, block_n, block_m)
    scale = (1.0 / (jnp.float32(width) * jnp.sqrt(jnp.float32(2.0))))
    xs = x.astype(jnp.float32) * scale
    ys = y.astype(jnp.float32) * scale
    grid = (n // block_n, m // block_m)
    return pl.pallas_call(
        _rbf_kernel,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, block_m), lambda i, j: (i, j)),
        interpret=interpret,
    )(xs, ys)
