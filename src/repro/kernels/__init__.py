"""Pallas TPU kernels for the CV-LR hot spots.

- rbf_gram:        tiled pairwise RBF strip K(X, pivots) — the ICL/Nystroem
                   feature evaluation hot loop.
- feature_strip:   dispatcher over the (n, m) kernel strip for the
                   factorization backends (repro.features.backends):
                   the Pallas rbf_gram kernel on TPU, a single-jit strip
                   at the input dtype elsewhere.
- centered_gram:   fused mean-centering + Lam^T Lam Gram contraction — the
                   P/E/F/V/U/S block stage of the dumbbell-form score.
- fold_gram_strip: fused bank-gather + fold-blocked Gram strip — the
                   batched frontier engine's (B, q, m, m) block stage,
                   streaming gathered factor rows through VMEM once
                   instead of materializing (B, q, n0, m) intermediates.
- fold_gram_strip_banked: the same strip fused with a scatter into a
                   persistent device block bank — the device-resident fold
                   pipeline's compute stage (blocks land in bank slots, the
                   fold stage index-gathers them, no host round-trip).
- fold_gram_blocks: identity-gather variant for already fold-blocked
                   factors (the shard_map distributed scorer's Gram stage).

Validated against ref.py oracles in interpret mode (this container is
CPU-only); on TPU the same pallas_call lowers to Mosaic.  The fold-Gram
entry points are dispatchers: non-TPU backends get an equivalent fused
single-jit gather+einsum unless the Pallas path is forced.
"""

from repro.kernels.ops import (
    centered_gram,
    feature_strip,
    fold_gram_blocks,
    fold_gram_strip,
    fold_gram_strip_banked,
    rbf_gram,
)

__all__ = [
    "centered_gram",
    "feature_strip",
    "fold_gram_blocks",
    "fold_gram_strip",
    "fold_gram_strip_banked",
    "rbf_gram",
]
