"""Pallas TPU kernels for the CV-LR hot spots.

- rbf_gram:      tiled pairwise RBF strip K(X, pivots) — the ICL/Nystroem
                 feature evaluation hot loop.
- centered_gram: fused mean-centering + Lam^T Lam Gram contraction — the
                 P/E/F/V/U/S block stage of the dumbbell-form score.

Validated against ref.py oracles in interpret mode (this container is
CPU-only); on TPU the same pallas_call lowers to Mosaic.
"""

from repro.kernels.ops import centered_gram, rbf_gram

__all__ = ["centered_gram", "rbf_gram"]
