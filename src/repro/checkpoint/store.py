"""Checkpointing: atomic, elastic, async-capable.

- Layout-agnostic: checkpoints store LOGICAL arrays (gathered numpy) plus
  the pytree structure, so a restore can re-shard onto any mesh/device
  count (elastic scaling; restore takes an optional `sharding_fn`).
- Atomic commit: write to `<dir>/tmp.<step>`, fsync, then rename to
  `step_<n>` — a crash mid-write never corrupts the latest checkpoint.
  Re-committing an already-committed step is idempotent (a resumed run
  re-saving the step it restored from is a no-op, not a FileExistsError),
  and `sweep_orphaned_tmp` drops `tmp.*` litter a crashed writer left.
- Async: AsyncCheckpointer snapshots device arrays (device_get) on the
  caller thread (cheap; off critical path once donated) and serializes on
  a background thread; `wait()` joins before the next save or at exit.
  A background-write failure is never swallowed: the captured exception
  re-raises on the next `wait()` (and therefore on the next `save()`).
- Format: .npz per checkpoint + a JSON manifest with the treedef/step.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:010d}")


def _is_committed(path: str) -> bool:
    """A committed checkpoint always has its manifest: the manifest is
    fsynced before the atomic rename, so its presence == a complete write."""
    return os.path.isdir(path) and os.path.exists(
        os.path.join(path, "manifest.json")
    )


def sweep_orphaned_tmp(directory: str) -> list:
    """Remove `tmp.*` dirs left by writers that crashed mid-checkpoint.

    Called on checkpointer startup (and harmless any time): an orphaned
    tmp dir is never visible to `latest_step`/restore, but it leaks disk
    and — before same-step commits were idempotent — could collide with a
    resumed run re-writing the same step.  Returns the removed paths.
    """
    removed = []
    if not os.path.isdir(directory):
        return removed
    for name in os.listdir(directory):
        if not name.startswith("tmp."):
            continue
        path = os.path.join(directory, name)
        if os.path.isdir(path):
            shutil.rmtree(path, ignore_errors=True)
            removed.append(path)
    return removed


def save_checkpoint(directory: str, step: int, tree) -> str:
    """Atomically commit `tree` as step `step`; returns the step dir.

    Idempotent per step: if the step is already committed (manifest
    present), the existing checkpoint is kept untouched and returned —
    a resumed run re-saving the step it restored from must not crash
    with FileExistsError.  A stale `tmp.<step>` from a crashed writer is
    replaced, never reused.
    """
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}")
    final = _step_dir(directory, step)
    if _is_committed(final):
        return final  # same-step re-commit: already durable, keep it
    if os.path.isdir(final):
        # a directory without a manifest can only be pre-atomic-commit
        # litter (the rename is atomic after the manifest fsync) — replace
        shutil.rmtree(final)
    if os.path.isdir(tmp):
        shutil.rmtree(tmp)  # crashed writer's partial tmp: start clean
    flat, treedef = _flatten_with_paths(tree)
    arrays = {}
    for i, x in enumerate(flat):
        a = np.asarray(jax.device_get(x))
        if a.dtype.name == "bfloat16":  # npz cannot encode bf16
            a = a.astype(np.float32)
        arrays[f"a{i}"] = a
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "num_arrays": len(flat),
        "treedef": str(treedef),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, final)  # atomic commit
    return final


def list_steps(directory: str) -> list:
    """Sorted (ascending) committed step numbers in `directory`."""
    if not os.path.isdir(directory):
        return []
    return sorted(
        int(name.split("_")[1])
        for name in os.listdir(directory)
        if name.startswith("step_")
    )


def latest_step(directory: str):
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(directory: str, step: int, like_tree, sharding_fn=None):
    """Restore into the structure of `like_tree` (shapes must match).

    sharding_fn(leaf_path_index, np_array) -> jax.Array lets the caller
    re-place arrays under a NEW mesh (elastic restart on a different
    device count)."""
    path = _step_dir(directory, step)
    with np.load(os.path.join(path, "arrays.npz")) as data:
        flat_like, treedef = jax.tree.flatten(like_tree)
        if len(flat_like) != len(data.files):
            raise ValueError(
                f"checkpoint has {len(data.files)} arrays, tree needs {len(flat_like)}"
            )
        flat = []
        for i, like in enumerate(flat_like):
            arr = data[f"a{i}"]
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(
                    f"leaf {i}: checkpoint shape {arr.shape} != {like.shape}"
                )
            arr = arr.astype(like.dtype)
            flat.append(sharding_fn(i, arr) if sharding_fn else jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, flat)


class AsyncCheckpointer:
    """Overlaps checkpoint serialization with training compute.

    Failure contract: the background write thread never swallows an
    exception — a failed write is captured and re-raised on the next
    `wait()` (and `save()` begins with `wait()`, so at the latest the
    next save attempt fails loudly instead of silently dropping
    checkpoints forever).  `saved` appends are lock-guarded: the list is
    mutated by the writer thread and read by the caller.
    """

    def __init__(self, directory: str):
        self.directory = directory
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()
        self._pending_exc: BaseException | None = None
        self.saved: list = []
        sweep_orphaned_tmp(directory)

    def save(self, step: int, tree):
        self.wait()  # joins the previous write and re-raises its failure
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            try:
                path = save_checkpoint(self.directory, step, snapshot)
            except BaseException as e:  # surfaced by the next wait()/save()
                with self._lock:
                    self._pending_exc = e
                return
            with self._lock:
                self.saved.append(path)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        """Join any in-flight write; re-raise a captured write failure."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        with self._lock:
            exc, self._pending_exc = self._pending_exc, None
        if exc is not None:
            raise exc
