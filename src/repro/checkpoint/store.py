"""Checkpointing: atomic, elastic, async-capable.

- Layout-agnostic: checkpoints store LOGICAL arrays (gathered numpy) plus
  the pytree structure, so a restore can re-shard onto any mesh/device
  count (elastic scaling; restore takes an optional `sharding_fn`).
- Atomic commit: write to `<dir>/tmp.<step>`, fsync, then rename to
  `step_<n>` — a crash mid-write never corrupts the latest checkpoint.
- Async: AsyncCheckpointer snapshots device arrays (device_get) on the
  caller thread (cheap; off critical path once donated) and serializes on
  a background thread; `wait()` joins before the next save or at exit.
- Format: .npz per checkpoint + a JSON manifest with the treedef/step.
"""

from __future__ import annotations

import json
import os
import threading

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save_checkpoint(directory: str, step: int, tree) -> str:
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}")
    final = os.path.join(directory, f"step_{step:010d}")
    flat, treedef = _flatten_with_paths(tree)
    arrays = {}
    for i, x in enumerate(flat):
        a = np.asarray(jax.device_get(x))
        if a.dtype.name == "bfloat16":  # npz cannot encode bf16
            a = a.astype(np.float32)
        arrays[f"a{i}"] = a
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "num_arrays": len(flat),
        "treedef": str(treedef),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        raise FileExistsError(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(directory: str):
    if not os.path.isdir(directory):
        return None
    steps = [
        int(name.split("_")[1])
        for name in os.listdir(directory)
        if name.startswith("step_")
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like_tree, sharding_fn=None):
    """Restore into the structure of `like_tree` (shapes must match).

    sharding_fn(leaf_path_index, np_array) -> jax.Array lets the caller
    re-place arrays under a NEW mesh (elastic restart on a different
    device count)."""
    path = os.path.join(directory, f"step_{step:010d}")
    with np.load(os.path.join(path, "arrays.npz")) as data:
        flat_like, treedef = jax.tree.flatten(like_tree)
        if len(flat_like) != len(data.files):
            raise ValueError(
                f"checkpoint has {len(data.files)} arrays, tree needs {len(flat_like)}"
            )
        flat = []
        for i, like in enumerate(flat_like):
            arr = data[f"a{i}"]
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(
                    f"leaf {i}: checkpoint shape {arr.shape} != {like.shape}"
                )
            arr = arr.astype(like.dtype)
            flat.append(sharding_fn(i, arr) if sharding_fn else jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, flat)


class AsyncCheckpointer:
    """Overlaps checkpoint serialization with training compute."""

    def __init__(self, directory: str):
        self.directory = directory
        self._thread: threading.Thread | None = None
        self.saved: list = []

    def save(self, step: int, tree):
        self.wait()
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            path = save_checkpoint(self.directory, step, snapshot)
            self.saved.append(path)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
