"""Factor-based kernel CI test (FFCI-style) on the scorer's feature bank.

The test statistic for (x ⊥ y | Z) is a partial-association norm computed
entirely from the *same* centered low-rank factors the CV-LR scorer builds
(Ramsey's FFCI line: the random-Fourier/Nystrom/ICL features that give a
linear-time generalized score also give a linear-time kernel CI test for
mixed data).  With A = Λ_x (n, m_x), B = Λ_y (n, m_y), C = Λ_Z (n, m_Z)
and the ridge residual smoother R = I − C (CᵀC + nρI)⁻¹ Cᵀ applied to BOTH
sides, the statistic

    T = ‖(RA)ᵀ (RB)‖_F² / n

needs only m×m Gram blocks — never an n×n matrix and never a materialized
residual:

    Aᵀ R² B = G_ab − 2 G_ac W G_cb + G_ac W G_cc W G_cb   (W = (G_cc + nρI)⁻¹)
    Aᵀ R² A = G_aa − 2 G_ac W G_ca + G_ac W G_cc W G_ca   (=: S_xx)

Under H0 the null is approximated by moment-matching a gamma distribution
(T ~ Γ(k, θ) with k·θ = tr(S_xx)tr(S_yy)/n² and k·θ² matching the variance
2‖S_xx‖²‖S_yy‖²/n⁴); degenerate moments fall back to a seeded permutation
null.  |Z| = 0 reduces exactly to the unconditional test (zero C blocks).

Factor reuse contract: every factor is fetched through
``scorer.features(vars_key)`` → the session's single-flight ``FeatureBank``,
so CI tests incur **zero duplicate builds** for sets the scorer also
touches, and the fold Gram blocks the tests compute are keyed, oriented and
trimmed exactly like the batched engine's (`GramBlockCache` keys, canonical
``repr``-ordered cross pairs, per-fold (q, m_eff_a, m_eff_b) host blocks) —
a constraint phase pre-warms the score phase's Gram cache for free.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.score_common import set_key
from repro.core.score_lowrank import _bucket, _pow2_pad
from repro.kernels.ops import fold_gram_strip
from repro.obs import trace as obs_trace

# Blocks per fold_gram_strip dispatch (pow2-padded); same scale the
# batched engine uses for its small-batch pair chunks.
_BLOCK_CHUNK = 16
# Tests per batched-statistic jit dispatch (pow2-padded heights).
_STAT_CHUNK = 32


@functools.partial(jax.jit, static_argnames=("n",))
def _ci_stat_chunk(gaa, gbb, gcc, gab, gac, gbc, ridge, n: int):
    """(T, gamma-mean, gamma-var) for a stacked chunk of tests.

    All Grams are zero-padded to the chunk's bucket widths; padding is
    exact (zero rows/cols contribute nothing, and the padded diagonal of
    the ridge-regularized G_cc inverts to an unused identity block).
    """

    def one(Gaa, Gbb, Gcc, Gab, Gac, Gbc):
        wz = Gcc.shape[0]
        reg = Gcc + (n * ridge) * jnp.eye(wz, dtype=Gcc.dtype)
        L = jax.scipy.linalg.cho_factor(reg, lower=True)
        Ka = jax.scipy.linalg.cho_solve(L, Gac.T)  # W G_ca, (wz, wa)
        Kb = jax.scipy.linalg.cho_solve(L, Gbc.T)  # W G_cb, (wz, wb)
        # both sides residualized: Mr = (RA)^T (RB) = G_ab − 2 G_ac W G_cb
        #                               + G_ac W G_cc W G_cb
        Mr = Gab - 2.0 * (Gac @ Kb) + Ka.T @ Gcc @ Kb
        T = jnp.sum(Mr * Mr) / n
        AK = Gac @ Ka  # G_ac W G_ca (symmetric)
        BK = Gbc @ Kb
        Sxx = Gaa - AK - AK.T + Ka.T @ Gcc @ Ka
        Syy = Gbb - BK - BK.T + Kb.T @ Gcc @ Kb
        mean = jnp.trace(Sxx) * jnp.trace(Syy) / (float(n) ** 2)
        var = (
            2.0
            * jnp.sum(Sxx * Sxx)
            * jnp.sum(Syy * Syy)
            / (float(n) ** 4)
        )
        return T, mean, var

    return jax.vmap(one)(gaa, gbb, gcc, gab, gac, gbc)


@functools.partial(jax.jit, static_argnames=("n",))
def _perm_stats(ar, br, perms, n: int):
    """Observed statistic + permutation-null draws for one test.

    ``ar`` / ``br`` are the residualized factors; ``perms`` is (P, n_eff)
    row permutations.  ``lax.map`` (not vmap) keeps peak memory at one
    permuted copy of ``br`` instead of P of them.
    """
    g0 = ar.T @ br
    t0 = jnp.sum(g0 * g0) / n

    def one(p):
        g = ar.T @ br[p]
        return jnp.sum(g * g) / n

    return t0, jax.lax.map(one, perms)


class KernelCITest:
    """Kernel CI tests computed from a CV-LR scorer's factor/Gram caches.

    Parameters
    ----------
    scorer:
        A ``CVLRScorer`` (or API-compatible) instance; supplies
        ``features`` (FeatureBank-backed factors), ``m_eff_log``,
        ``gram_cache``, ``config.q_folds`` and ``precision``.
    ridge:
        Residual-projector regularizer ρ (the projector uses nρ on the
        Gram diagonal, matching the scorer's per-sample scaling).
    alpha:
        Default significance level for :meth:`independent`.
    null:
        ``"gamma"`` (moment-matched, with automatic permutation fallback
        on degenerate moments) or ``"permutation"`` (always permute).
    n_perm:
        Permutation-null sample count.
    seed:
        Base seed for the per-test permutation streams.
    """

    def __init__(
        self,
        scorer,
        *,
        ridge: float = 0.01,
        alpha: float = 0.05,
        null: str = "gamma",
        n_perm: int = 200,
        seed: int = 0,
    ):
        if null not in ("gamma", "permutation"):
            raise ValueError(
                f'null must be "gamma" or "permutation", got {null!r}'
            )
        if not 0.0 < alpha < 1.0:
            raise ValueError(f"alpha must be in (0, 1), got {alpha}")
        self.scorer = scorer
        self.ridge = float(ridge)
        self.alpha = float(alpha)
        self.null = null
        self.n_perm = int(n_perm)
        self.seed = int(seed)
        self._cache: dict = {}  # (x, y, z_key) -> p-value
        self.stats = {
            "ci_tests": 0,  # statistics actually computed
            "cached": 0,  # requests served from the result cache
            "gamma": 0,  # tests resolved by the gamma null
            "permutation": 0,  # tests resolved by the permutation null
            "gram_blocks_computed": 0,
            "gram_blocks_cached": 0,
        }

    # -- public API --------------------------------------------------------
    def pvalue(self, x: int, y: int, z=()) -> float:
        return self.batch([(x, y, tuple(z))])[0]

    def independent(self, x: int, y: int, z=(), alpha=None) -> bool:
        """True when the test fails to reject independence at ``alpha``."""
        a = self.alpha if alpha is None else float(alpha)
        return self.pvalue(x, y, z) >= a

    def batch(self, tests) -> list:
        """P-values for a batch of ``(x, y, z)`` tests, order-aligned.

        Deduplicates against the per-(x,y|Z) result cache, fetches every
        distinct factor once through the FeatureBank, computes missing
        Gram blocks as stacked `fold_gram_strip` dispatches (engine-keyed,
        so the score phase reuses them), then evaluates the statistics in
        width-bucketed jit chunks.
        """
        keys = [self._test_key(x, y, z) for (x, y, z) in tests]
        todo = []
        for k in dict.fromkeys(keys):  # unique, order-preserving
            if k in self._cache:
                continue
            todo.append(k)
        self.stats["cached"] += sum(1 for k in keys if k in self._cache)
        if todo:
            guard = getattr(self.scorer.gram_cache, "sweep_guard", None)
            with obs_trace.span(
                "ci_batch", cat="stage", attrs={"tests": len(todo)}
            ):
                if guard is not None:
                    with guard():
                        self._compute(todo)
                else:
                    self._compute(todo)
            self.stats["ci_tests"] += len(todo)
        return [float(self._cache[k]) for k in keys]

    # -- internals ---------------------------------------------------------
    @staticmethod
    def _test_key(x: int, y: int, z) -> tuple:
        x, y = int(x), int(y)
        if x == y:
            raise ValueError(f"CI test requires x != y, got ({x}, {y})")
        zk = set_key(z) if len(tuple(z)) else ()
        if x in zk or y in zk:
            raise ValueError(
                f"conditioning set {zk} must exclude x={x}, y={y}"
            )
        return (min(x, y), max(x, y), zk)

    def _factor(self, vars_key: tuple):
        """Trimmed (n_eff, m_eff) factor via the scorer's FeatureBank."""
        fac = self.scorer.features(vars_key)
        me = int(self.scorer.m_eff_log[vars_key])
        return fac, me

    @staticmethod
    def _cross_key(ka: tuple, kb: tuple):
        """Engine-canonical cache identity of a cross Gram block: unordered
        pair sorted by ``repr`` (see ``cvlr_scores_batched._cross_key``);
        the stored block is factor(first)_qᵀ factor(second)_q."""
        if repr(ka) <= repr(kb):
            return (ka, kb), False
        return (kb, ka), True

    def _compute(self, todo) -> None:
        scorer = self.scorer
        q = int(scorer.config.q_folds)
        m_cap = int(scorer.config.m_max)
        prec = getattr(scorer, "precision", "bitwise")

        # 1) every distinct variable set, fetched once through the bank
        factors: dict = {}  # vars_key -> (jnp (n_eff, m_max), m_eff)
        def fetch(vk):
            if vk not in factors:
                factors[vk] = self._factor(vk)
            return factors[vk]

        trivial: list = []  # keys resolved without any algebra (p = 1.0)
        live: list = []  # (key, kx, ky, kz-or-None)
        for key in todo:
            x, y, zk = key
            kx, ky = set_key((x,)), set_key((y,))
            _, mx = fetch(kx)
            _, my = fetch(ky)
            if mx == 0 or my == 0:
                trivial.append(key)  # constant marginal: independent
                continue
            kz = None
            if zk:
                _, mz = fetch(zk)
                if mz > 0:
                    kz = zk
            live.append((key, kx, ky, kz))
        for key in trivial:
            self._cache[key] = 1.0
            self.stats["gamma"] += 1

        if not live:
            return

        # 2) the Gram blocks those tests need, engine-keyed
        needed: dict = {}  # cache_key -> (ka, kb) stored orientation
        def want(ka, kb):
            ck, _ = self._cross_key(ka, kb)
            needed[ck] = ck
        for _, kx, ky, kz in live:
            want(kx, kx)
            want(ky, ky)
            want(kx, ky)
            if kz is not None:
                want(kz, kz)
                want(kz, kx)
                want(kz, ky)
        grams = self._ensure_blocks(needed, factors, q, m_cap, prec)

        def gram(ka, kb):
            """Full (m_eff_a, m_eff_b) Gram — fold-sum, oriented."""
            ck, transposed = self._cross_key(ka, kb)
            g = grams[ck]
            return g.T if transposed else g

        n_eff = next(iter(factors.values()))[0].shape[0]

        # 3) width-bucketed batched statistics (gamma null)
        pending_perm: list = []  # (key, kx, ky, kz) needing permutation
        if self.null == "permutation":
            pending_perm = list(live)
        else:
            groups: dict = {}
            for item in live:
                _, kx, ky, kz = item
                wn = _bucket(
                    max(factors[kx][1], factors[ky][1]), m_cap
                )
                wz = _bucket(factors[kz][1], m_cap) if kz else 8
                groups.setdefault((wn, wz), []).append(item)
            for (wn, wz), items in sorted(groups.items()):
                for lo in range(0, len(items), _STAT_CHUNK):
                    chunk = items[lo : lo + _STAT_CHUNK]
                    pending_perm.extend(
                        self._gamma_chunk(
                            chunk, gram, factors, wn, wz, n_eff
                        )
                    )

        # 4) permutation fallback / explicit permutation null
        for item in pending_perm:
            self._permutation_test(item, factors, n_eff)

    def _ensure_blocks(self, needed, factors, q, m_cap, prec):
        """Fetch-or-compute the per-fold Gram blocks, returning full
        (fold-summed) host Grams keyed by cache key.  Freshly computed
        blocks are stored back into ``scorer.gram_cache`` (host tier) so
        the batched score engine finds them pre-warmed."""
        cache = self.scorer.gram_cache
        grams: dict = {}
        missing: list = []
        for ck in needed:
            blk = cache.get(ck)
            if blk is not None:
                grams[ck] = np.asarray(blk, np.float64).sum(axis=0)
                self.stats["gram_blocks_cached"] += 1
            else:
                missing.append(ck)
        if not missing:
            return grams

        # group by bucket widths; one stacked dispatch per width group
        by_width: dict = {}
        for ck in missing:
            ka, kb = ck
            wa = _bucket(factors[ka][1], m_cap)
            wb = _bucket(factors[kb][1], m_cap)
            by_width.setdefault((wa, wb), []).append(ck)

        for (wa, wb), cks in sorted(by_width.items()):
            ka_keys = sorted({ck[0] for ck in cks}, key=repr)
            kb_keys = sorted({ck[1] for ck in cks}, key=repr)
            ia_of = {k: i for i, k in enumerate(ka_keys)}
            ib_of = {k: i for i, k in enumerate(kb_keys)}
            bank_a = self._stack(ka_keys, factors, wa)
            bank_b = self._stack(kb_keys, factors, wb)
            for lo in range(0, len(cks), _BLOCK_CHUNK):
                chunk = cks[lo : lo + _BLOCK_CHUNK]
                pad = _pow2_pad(len(chunk), _BLOCK_CHUNK) - len(chunk)
                ia = np.asarray(
                    [ia_of[ck[0]] for ck in chunk]
                    + [ia_of[chunk[0][0]]] * pad,
                    np.int32,
                )
                ib = np.asarray(
                    [ib_of[ck[1]] for ck in chunk]
                    + [ib_of[chunk[0][1]]] * pad,
                    np.int32,
                )
                out = np.asarray(
                    fold_gram_strip(
                        bank_a, bank_b, ia, ib, q, precision=prec
                    )
                )
                for c, ck in enumerate(chunk):
                    mea = factors[ck[0]][1]
                    meb = factors[ck[1]][1]
                    blk = np.ascontiguousarray(out[c, :, :mea, :meb])
                    cache.put(ck, blk)
                    grams[ck] = blk.astype(np.float64).sum(axis=0)
                    self.stats["gram_blocks_computed"] += 1
        return grams

    @staticmethod
    def _stack(keys, factors, w):
        """Stacked (S, n_eff, w) device bank of trimmed, width-padded
        factors (pow2-padded height with zero factors, like the engine's
        ``_stack_refs``)."""
        cols = []
        for k in keys:
            fac, me = factors[k]
            f = fac[:, :me]
            if me < w:
                f = jnp.pad(f, ((0, 0), (0, w - me)))
            cols.append(f)
        n_eff = cols[0].shape[0]
        pad = _pow2_pad(len(cols), _BLOCK_CHUNK * 2) - len(cols)
        cols.extend([jnp.zeros((n_eff, w), cols[0].dtype)] * pad)
        return jnp.stack(cols)

    def _gamma_chunk(self, chunk, gram, factors, wn, wz, n_eff):
        """Evaluate one width-bucketed chunk under the gamma null; returns
        the sub-list of tests whose moments were degenerate (these fall
        back to the permutation null)."""
        B = len(chunk)
        Bp = _pow2_pad(B, _STAT_CHUNK)
        gaa = np.zeros((Bp, wn, wn))
        gbb = np.zeros((Bp, wn, wn))
        gcc = np.zeros((Bp, wz, wz))
        gab = np.zeros((Bp, wn, wn))
        gac = np.zeros((Bp, wn, wz))
        gbc = np.zeros((Bp, wn, wz))
        for c, (key, kx, ky, kz) in enumerate(chunk):
            mx, my = factors[kx][1], factors[ky][1]
            gaa[c, :mx, :mx] = gram(kx, kx)
            gbb[c, :my, :my] = gram(ky, ky)
            gab[c, :mx, :my] = gram(kx, ky)
            if kz is not None:
                mz = factors[kz][1]
                gcc[c, :mz, :mz] = gram(kz, kz)
                gac[c, :mx, :mz] = gram(kx, kz)
                gbc[c, :my, :mz] = gram(ky, kz)
        T, mean, var = _ci_stat_chunk(
            jnp.asarray(gaa),
            jnp.asarray(gbb),
            jnp.asarray(gcc),
            jnp.asarray(gab),
            jnp.asarray(gac),
            jnp.asarray(gbc),
            jnp.float64(self.ridge),
            n_eff,
        )
        T = np.asarray(T)[:B]
        mean = np.asarray(mean)[:B]
        var = np.asarray(var)[:B]
        ok = (
            np.isfinite(T)
            & np.isfinite(mean)
            & np.isfinite(var)
            & (mean > 0.0)
            & (var > 0.0)
        )
        fallback = []
        # moment-matched gamma: shape k = mean^2/var, scale th = var/mean
        with np.errstate(divide="ignore", invalid="ignore"):
            k = np.where(ok, mean * mean / np.where(ok, var, 1.0), 1.0)
            th = np.where(ok, var / np.where(ok, mean, 1.0), 1.0)
        pv = np.asarray(
            jax.scipy.special.gammaincc(
                jnp.asarray(k), jnp.asarray(np.maximum(T, 0.0) / th)
            )
        )
        for c, item in enumerate(chunk):
            if ok[c]:
                self._cache[item[0]] = float(np.clip(pv[c], 0.0, 1.0))
                self.stats["gamma"] += 1
            else:
                fallback.append(item)
        return fallback

    def _permutation_test(self, item, factors, n_eff) -> None:
        key, kx, ky, kz = item
        x, y, zk = key
        fa, mx = factors[kx]
        fb, my = factors[ky]
        A = fa[:, :mx]
        Bm = fb[:, :my]
        if kz is not None:
            fc, mz = factors[kz]
            C = fc[:, :mz]
            reg = C.T @ C + (n_eff * self.ridge) * jnp.eye(mz, dtype=C.dtype)
            L = jax.scipy.linalg.cho_factor(reg, lower=True)
            A = A - C @ jax.scipy.linalg.cho_solve(L, C.T @ A)
            Bm = Bm - C @ jax.scipy.linalg.cho_solve(L, C.T @ Bm)
        rng = np.random.default_rng([self.seed, x, y, *zk])
        perms = np.stack(
            [rng.permutation(n_eff) for _ in range(self.n_perm)]
        ).astype(np.int32)
        t0, ts = _perm_stats(A, Bm, jnp.asarray(perms), n_eff)
        t0 = float(t0)
        ts = np.asarray(ts)
        p = (1.0 + float(np.sum(ts >= t0))) / (1.0 + self.n_perm)
        self._cache[key] = p
        self.stats["permutation"] += 1
