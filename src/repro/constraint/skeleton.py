"""Level-wise PC-stable skeleton estimation over batched kernel CI tests.

``estimate_skeleton`` runs the stable variant of the PC skeleton phase
(neighbor sets frozen per level, so the result is independent of edge
iteration order) with every level's independence tests dispatched as ONE
batched call into :class:`repro.constraint.ci_test.KernelCITest` — which
groups them into stacked-factor-bank device dispatches, exactly like the
batched score engine's frontier chunks.

The product is an :class:`EdgeMask`: the restriction contract
``EngineOptions(restrict="skeleton")`` threads through ``DiscoverySession``
into the GES candidate generators.  Gating is FORWARD-ONLY by design:
masked-out pairs never become insert candidates (and never enter the
incremental ``_FrontierDelta`` bookkeeping), while delete/reverse
candidates are never gated — under gated insertions the graph's edges are
a subset of the mask, so backward gating could only forbid repairs of
edges the mask itself admitted.
"""

from __future__ import annotations

import dataclasses
import itertools
import time

import numpy as np

from repro.obs import trace as obs_trace


@dataclasses.dataclass(frozen=True, eq=False)
class EdgeMask:
    """Symmetric boolean restriction over ordered node pairs.

    ``allowed[x, y]`` is True when the ordered candidate pair (x, y) may
    enter a forward frontier; the matrix is symmetric with a False
    diagonal.  ``full(d)`` (everything allowed) is the identity element:
    gating with it is behaviorally identical to no mask at all.
    """

    allowed: np.ndarray  # (d, d) bool, symmetric, diag False

    def __post_init__(self):
        a = np.asarray(self.allowed, dtype=bool)
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError(f"allowed must be square, got {a.shape}")
        if a.diagonal().any():
            raise ValueError("allowed must have a False diagonal")
        if not np.array_equal(a, a.T):
            raise ValueError("allowed must be symmetric")
        object.__setattr__(self, "allowed", a)

    @property
    def d(self) -> int:
        return self.allowed.shape[0]

    @property
    def pruned_pairs(self) -> int:
        """Ordered candidate pairs the mask removes from full frontiers."""
        d = self.d
        return int(d * (d - 1) - self.allowed.sum())

    def allows(self, x: int, y: int) -> bool:
        return bool(self.allowed[x, y])

    @classmethod
    def full(cls, d: int) -> "EdgeMask":
        a = np.ones((d, d), dtype=bool)
        np.fill_diagonal(a, False)
        return cls(a)

    # JSON-serializable round trip for RunState persistence
    def to_list(self) -> list:
        return self.allowed.astype(int).tolist()

    @classmethod
    def from_list(cls, rows) -> "EdgeMask":
        return cls(np.asarray(rows, dtype=bool))


def estimate_skeleton(
    ci,
    d: int,
    *,
    alpha: float = 0.05,
    max_cond: int = 2,
    max_sets_per_edge: int = 16,
    verbose: bool = False,
):
    """PC-stable skeleton over batched kernel CI tests.

    Starts from the complete graph; at each level ℓ = 0..``max_cond`` it
    freezes the adjacency, enumerates up to ``max_sets_per_edge``
    size-ℓ conditioning sets per live edge (from either endpoint's other
    neighbors, deduplicated), dispatches the whole level as one
    ``ci.batch`` call, and removes every edge with any p ≥ ``alpha``
    (independence not rejected).  Capping the enumeration only *keeps*
    edges it might otherwise remove, so the superset-of-true-skeleton
    guarantee the score phase relies on is never weakened by the cap.

    Returns ``(EdgeMask, info)`` where ``info`` carries per-level and
    total telemetry (tests, cache hits, removals, elapsed seconds).
    """
    if d < 1:
        raise ValueError(f"d must be >= 1, got {d}")
    if not 0.0 < alpha < 1.0:
        raise ValueError(f"alpha must be in (0, 1), got {alpha}")
    if max_cond < 0:
        raise ValueError(f"max_cond must be >= 0, got {max_cond}")
    t_start = time.perf_counter()
    stats0 = dict(ci.stats)
    allowed = np.ones((d, d), dtype=bool)
    np.fill_diagonal(allowed, False)
    levels: list = []

    for level in range(max_cond + 1):
        t0 = time.perf_counter()
        nbrs = {i: [j for j in range(d) if allowed[i, j]] for i in range(d)}
        tests: list = []
        owner: list = []  # aligned (x, y) edge per test
        for x in range(d):
            for y in range(x + 1, d):
                if not allowed[x, y]:
                    continue
                for z in _cond_sets(
                    nbrs, x, y, level, max_sets_per_edge
                ):
                    tests.append((x, y, z))
                    owner.append((x, y))
        if not tests:
            break
        with obs_trace.span(
            "skeleton_level",
            cat="stage",
            attrs={"level": level, "tests": len(tests)},
        ):
            pvals = ci.batch(tests)
        removed = 0
        dropped: set = set()
        for (x, y), p in zip(owner, pvals):
            if (x, y) in dropped:
                continue
            if p >= alpha:  # independence not rejected: sever the edge
                allowed[x, y] = allowed[y, x] = False
                dropped.add((x, y))
                removed += 1
        levels.append(
            {
                "level": level,
                "edges": int(allowed.sum() // 2) + removed,
                "tests": len(tests),
                "removed": removed,
                "elapsed_s": time.perf_counter() - t0,
            }
        )
        if verbose:
            print(
                f"[skeleton] level {level}: {len(tests)} tests, "
                f"{removed} removed, {int(allowed.sum() // 2)} edges left"
            )

    mask = EdgeMask(allowed)
    delta = {
        k: ci.stats[k] - stats0.get(k, 0) for k in ci.stats
    }
    info = {
        "levels": levels,
        "ci_tests": delta["ci_tests"],
        "cached": delta["cached"],
        "pruned_pairs": mask.pruned_pairs,
        "skeleton_s": time.perf_counter() - t_start,
    }
    return mask, info


def _cond_sets(nbrs, x: int, y: int, level: int, cap: int):
    """Deduplicated size-``level`` conditioning sets for edge (x, y) from
    either endpoint's frozen other-neighbors, lexicographic, capped."""
    if level == 0:
        return [()]
    pools = (
        [v for v in nbrs[x] if v != y],
        [v for v in nbrs[y] if v != x],
    )
    out: list = []
    seen: set = set()
    for pool in pools:
        if len(pool) < level:
            continue
        for z in itertools.combinations(pool, level):
            if z not in seen:
                seen.add(z)
                out.append(z)
                if len(out) >= cap:
                    return out
    return out
