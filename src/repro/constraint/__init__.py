"""Constraint subsystem: factor-based kernel CI tests + skeleton gating.

The constraint phase reuses the session's ``FeatureBank`` factors and
``GramBlockCache`` blocks (zero duplicate builds vs the score phase) to
run FFCI-style kernel CI tests and a PC-stable skeleton whose
:class:`EdgeMask` gates the GES forward frontiers
(``EngineOptions(restrict="skeleton")``).  See
docs/ARCHITECTURE.md §12.
"""

from repro.constraint.ci_test import KernelCITest
from repro.constraint.skeleton import EdgeMask, estimate_skeleton

__all__ = ["KernelCITest", "EdgeMask", "estimate_skeleton"]
