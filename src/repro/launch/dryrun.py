import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
512 placeholder host devices and extract the roofline inputs.

The XLA_FLAGS assignment above MUST run before any other import (jax locks
the device count at first backend init).

Per cell:
  - build the step function (train_step / prefill / decode_step, or the
    paper's distributed scorer for arch=cvlr_paper),
  - derive in/out shardings from the logical-axis resolver,
  - jax.jit(...).lower(*ShapeDtypeStructs).compile(),
  - record memory_analysis(), cost_analysis() (per-device, post-SPMD),
    and collective payload bytes parsed from the compiled HLO.

Results land in benchmarks/dryrun_results/<mesh>/<arch>__<shape>.json
(incremental: existing cells are skipped unless --force).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama_1b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun          # all cells
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.launch.mesh import make_production_mesh, mesh_context  # noqa: E402
from repro.launch.shardings import (  # noqa: E402
    adafactor_state_shardings,
    adamw_state_shardings,
    batch_shardings,
    cache_shardings,
    param_shardings,
)
from repro.models.config import SHAPES  # noqa: E402
from repro.models.registry import ARCH_IDS, load_arch  # noqa: E402
from repro.optim.optimizers import OptimConfig, make_optimizer  # noqa: E402

RESULTS_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "benchmarks", "dryrun_results"
)

# long_500k needs sub-quadratic attention: only SSM/hybrid archs run it
# (full-attention archs skip, per the assignment; see DESIGN.md §2.4).
SUBQUADRATIC = {"xlstm_1b", "zamba2_1b"}

COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "ragged-all-to-all",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of one 'dtype[d0,d1,...]' HLO shape literal."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op, by op kind.

    Parses lines like:
      %ar = bf16[16,128]{1,0} all-reduce(...), replica_groups=...
      %ag = (f32[4,8]{...}, f32[2]{...}) all-gather(...)
    """
    out = {k: 0 for k in COLLECTIVE_OPS}
    counts = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        line = line.strip()
        for op in COLLECTIVE_OPS:
            token = f" {op}("
            if token not in line or "= " not in line:
                continue
            if f"{op}-start" in line or f"{op}-done" in line:
                pass  # async forms also match the plain token below
            rhs = line.split("= ", 1)[1]
            shapes_part = rhs.split(f" {op}(")[0].strip()
            if shapes_part.startswith("("):
                shapes = re.findall(r"\w+\[[0-9,]*\]", shapes_part)
                out[op] += sum(_shape_bytes(s) for s in shapes)
            else:
                out[op] += _shape_bytes(shapes_part)
            counts[op] += 1
            break
    out_named = {f"{k}_bytes": v for k, v in out.items()}
    out_named.update({f"{k}_count": v for k, v in counts.items()})
    out_named["total_collective_bytes"] = sum(out.values())
    return out_named


def _jsonable_memory(compiled) -> dict:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    keys = (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    )
    return {k: int(getattr(ma, k, 0) or 0) for k in keys}


def build_lm_cell(arch: str, shape_name: str, mesh):
    """Returns (fn, example_args, in_shardings, donate) for one LM cell."""
    import dataclasses

    from repro.models.registry import build_model

    cfg, _ = load_arch(arch)
    shape = SHAPES[shape_name]
    # Unroll layer + inner chunk scans so cost_analysis counts every
    # iteration (XLA counts while bodies once — EXPERIMENTS.md §Dry-run).
    overrides = {"unroll_scans": True}
    if cfg.family in ("ssm", "hybrid") and shape.seq_len > 8192:
        overrides["ssm_chunk"] = 1024  # bound trip count x unroll size
    cfg = dataclasses.replace(cfg, **overrides)
    model = build_model(cfg)
    # eval_shape the params; capture the (static, string-leaved) logical
    # axes tree via closure — it is built at trace time with no allocation.
    box = {}

    def _init_params_only(k):
        p, a = model.init(k)
        box["axes"] = a
        return p

    params_shapes = jax.eval_shape(_init_params_only, jax.random.PRNGKey(0))
    axes_tree = box["axes"]
    p_shard, resolver = param_shardings(mesh, params_shapes, axes_tree)

    if shape.kind == "train":
        opt_kind = "adafactor" if arch == "arctic_480b" else "adamw"
        opt_init, opt_update = make_optimizer(OptimConfig(kind=opt_kind))
        opt_shapes = jax.eval_shape(opt_init, params_shapes)
        if opt_kind == "adamw":
            o_shard = adamw_state_shardings(p_shard, mesh)
        else:
            o_shard = adafactor_state_shardings(params_shapes, axes_tree, mesh)
        batch_specs = model.input_specs(shape)
        b_shard = batch_shardings(mesh, batch_specs)

        def train_step(state, batch):
            loss, grads = jax.value_and_grad(model.loss)(state["params"], batch)
            new_params, new_opt, metrics = opt_update(
                grads, state["opt"], state["params"]
            )
            return {"params": new_params, "opt": new_opt}, {
                "loss": loss,
                "grad_norm": metrics["grad_norm"],
            }

        state_shapes = {"params": params_shapes, "opt": opt_shapes}
        state_shard = {"params": p_shard, "opt": o_shard}
        return (
            train_step,
            (state_shapes, batch_specs),
            (state_shard, b_shard),
            resolver,
            (0,),
        )

    if shape.kind == "prefill":
        batch_specs = model.input_specs(shape)
        b_shard = batch_shardings(mesh, batch_specs)

        def prefill_step(params, batch):
            if hasattr(model, "prefill"):
                return model.prefill(params, batch)
            logits, _ = model.forward(params, batch)
            return logits[:, -1]

        return prefill_step, (params_shapes, batch_specs), (p_shard, b_shard), resolver, ()

    # decode
    cache_specs, tok_spec = model.decode_specs(SHAPES[shape_name])
    c_shard, _ = cache_shardings(mesh, cache_specs, model.cache_logical_axes())
    t_shard = batch_shardings(mesh, tok_spec)

    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return (
        serve_step,
        (params_shapes, cache_specs, tok_spec),
        (p_shard, c_shard, t_shard),
        resolver,
        (1,),  # donate the cache
    )


def build_cvlr_cell(mesh):
    """The paper's workload: distributed CV-LR frontier scoring.

    Samples shard over every FSDP axis (("pod", "data") multi-pod), so the
    multi-pod pass proves the pod axis shards the paper's collective too."""
    from repro.configs.cvlr_paper import config
    from repro.core.distributed_score import make_sharded_scorer

    w = config()
    data_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    fn = make_sharded_scorer(mesh, data_axis=data_axes, model_axis="model")
    spec = jax.ShapeDtypeStruct(
        (w.num_candidates, w.q_folds, w.samples_per_fold, w.m), jnp.float64
    )
    in_spec = NamedSharding(
        mesh, P("model", None, data_axes if len(data_axes) > 1 else data_axes[0], None)
    )
    return fn, (spec, spec), (in_spec, in_spec), None, ()


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str, force=False):
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, f"{arch}__{shape_name}.json")
    if os.path.exists(out_path) and not force:
        print(f"[skip] {arch} x {shape_name} ({mesh_kind}) — cached")
        return json.load(open(out_path))

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "mesh_shape": dict(mesh.shape),
        "status": "error",
    }
    try:
        if arch == "cvlr_paper":
            fn, args, in_shards, resolver, donate = build_cvlr_cell(mesh)
        else:
            fn, args, in_shards, resolver, donate = build_lm_cell(
                arch, shape_name, mesh
            )
        with mesh_context(mesh):
            jitted = jax.jit(fn, in_shardings=in_shards, donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            cost = compiled.cost_analysis() or {}
            if isinstance(cost, list):  # jax < 0.5: one dict per program
                cost = cost[0] if cost else {}
            mem = _jsonable_memory(compiled)
            hlo = compiled.as_text()
            coll = collective_bytes(hlo)
        record.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            flops=float(cost.get("flops", 0.0)),
            bytes_accessed=float(cost.get("bytes accessed", 0.0)),
            transcendentals=float(cost.get("transcendentals", 0.0)),
            memory=mem,
            collectives=coll,
            hlo_ops=len(hlo.splitlines()),
            fallbacks=[
                list(map(str, f)) for f in (resolver.fallbacks if resolver else [])
            ],
        )
        print(
            f"[ok]   {arch} x {shape_name} ({mesh_kind}): "
            f"flops/dev={record['flops']:.3e} "
            f"coll={coll['total_collective_bytes']:.3e}B "
            f"compile={t_compile:.1f}s"
        )
    except Exception as e:  # noqa: BLE001 — record and continue
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
        print(f"[FAIL] {arch} x {shape_name} ({mesh_kind}): {record['error']}")
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    return record


def cells_for(arch: str):
    if arch == "cvlr_paper":
        return ["train_4k"]  # one representative cell (shape is internal)
    cfg, _ = load_arch(arch)
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in SUBQUADRATIC:
        shapes.append("long_500k")
    return shapes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    n_fail = 0
    for mesh_kind in meshes:
        out_dir = os.path.join(args.out, mesh_kind)
        for arch in archs:
            shapes = cells_for(arch) if args.shape == "all" else args.shape.split(",")
            for shape in shapes:
                rec = run_cell(arch, shape, mesh_kind, out_dir, force=args.force)
                n_fail += rec.get("status") != "ok"
    print(f"dry-run complete; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
