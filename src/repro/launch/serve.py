"""Serving drivers.

Two modes behind one entrypoint:

* ``--mode lm`` (default) — the original batched LM driver: prefill a
  batch of prompts, then greedy-decode with the KV cache.  Runs reduced
  configs on CPU; the same step functions lower on the production mesh
  (see dryrun.py decode cells).

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama_1b \
        --batch 4 --prompt-len 32 --gen 16

* ``--mode discovery`` — a multi-tenant causal-discovery request loop
  over `repro.serving.SessionManager`: N tenants submit discovery
  requests against one dataset and one shared feature bank; each request
  resolves to a CPDAG or a structured error (shed / deadline /
  cancelled), and the loop ends with the manager's telemetry (admission
  stats, p50/p95 latency, shared-bank counters, degradation-ladder
  rungs).

    PYTHONPATH=src python -m repro.launch.serve --mode discovery \
        --tenants 4 --n 400 --d 6 --deadline-s 120
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


# -- LM mode ---------------------------------------------------------------
def serve(
    arch: str = "tinyllama_1b",
    batch: int = 4,
    prompt_len: int = 32,
    gen: int = 16,
    seed: int = 0,
    greedy: bool = True,
):
    import jax
    import jax.numpy as jnp

    from repro.models.registry import load_arch

    cfg, model = load_arch(arch, reduced=True)
    if not hasattr(model, "prefill"):
        raise SystemExit(f"{arch} has no prefill path in this driver")
    params, _ = model.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(batch, prompt_len)), jnp.int32
    )
    max_len = prompt_len + gen

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts})
    t_prefill = time.time() - t0
    out = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for _ in range(gen):
        out.append(tok)
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    generated = jnp.concatenate(out, axis=1)
    print(
        f"[serve] {arch}: prefill({batch}x{prompt_len}) {t_prefill*1e3:.1f}ms, "
        f"{gen} decode steps {t_decode*1e3:.1f}ms "
        f"({t_decode/gen*1e3:.2f} ms/tok/batch)"
    )
    return generated


# -- discovery mode --------------------------------------------------------
def _chain_data(n: int, d: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    cols = [rng.standard_normal(n)]
    for _ in range(d - 1):
        cols.append(np.tanh(cols[-1]) + 0.4 * rng.standard_normal(n))
    return np.stack(cols, axis=1)


def serve_discovery(
    tenants: int = 4,
    n: int = 400,
    d: int = 6,
    seed: int = 0,
    deadline_s: float | None = None,
    max_concurrent: int = 4,
    queue_limit: int = 16,
    device_budget_mb: float | None = None,
    obs: str = "off",
    trace_dir: str | None = None,
    metrics_out: str | None = None,
    metrics_port: int | None = None,
):
    """The ``--mode discovery`` request loop: submit one request per
    tenant, drain the tickets, print one structured line per request and
    a final telemetry report.

    Observability: ``obs``/``trace_dir`` ride into
    `repro.serving.ServingOptions` — every tenant's session records
    spans into per-tenant trace files and the manager's shared metrics
    registry.  ``metrics_out`` writes the final Prometheus text
    exposition to a file; ``metrics_port`` serves a live ``/metrics``
    endpoint for the duration of the loop (0 picks a free port)."""
    from repro.serving import (
        DiscoveryRequest,
        RequestShed,
        ServingOptions,
        SessionManager,
        structured_error,
    )

    data = _chain_data(n, d, seed=seed)
    serving = ServingOptions(
        max_concurrent=max_concurrent,
        queue_limit=queue_limit,
        default_deadline_s=deadline_s,
        device_budget_mb=device_budget_mb,
        obs=obs,
        trace_dir=trace_dir,
    )
    results = []
    with SessionManager(data, serving=serving) as mgr:
        server = None
        if metrics_port is not None:
            from repro.obs import start_metrics_server

            server = start_metrics_server(mgr.metrics, port=int(metrics_port))
            print(
                f"[serve.discovery] metrics at "
                f"http://127.0.0.1:{server.server_address[1]}/metrics"
            )
        tickets = []
        for i in range(tenants):
            req = DiscoveryRequest(tenant=f"tenant-{i}")
            try:
                tickets.append((req.tenant, mgr.submit(req)))
            except RequestShed as shed:
                payload = shed.to_dict()
                results.append(payload)
                print(f"[serve.discovery] {json.dumps(payload)}")
        for tenant, ticket in tickets:
            try:
                res = ticket.result()
                payload = {
                    "tenant": tenant,
                    "ok": True,
                    "edges": int((res.cpdag != 0).sum()),
                    "score": float(res.score),
                    "latency_s": round(ticket.latency_s, 3),
                }
            except Exception as exc:
                payload = {"tenant": tenant, "ok": False, **structured_error(exc)}
            results.append(payload)
            print(f"[serve.discovery] {json.dumps(payload)}")
        telemetry = mgr.telemetry()
        if metrics_out is not None:
            with open(metrics_out, "w") as fh:
                fh.write(mgr.prometheus())
            print(f"[serve.discovery] metrics written to {metrics_out}")
        if server is not None:
            server.shutdown()
    print(f"[serve.discovery] telemetry {json.dumps(telemetry)}")
    return results, telemetry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--mode", choices=("lm", "discovery"), default="lm",
        help="lm: batched prefill+decode driver; discovery: multi-tenant "
        "causal-discovery request loop over repro.serving.SessionManager",
    )
    # lm mode
    ap.add_argument("--arch", default="tinyllama_1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    # discovery mode
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--n", type=int, default=400)
    ap.add_argument("--d", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline-s", type=float, default=None)
    ap.add_argument("--max-concurrent", type=int, default=4)
    ap.add_argument("--queue-limit", type=int, default=16)
    ap.add_argument("--device-budget-mb", type=float, default=None)
    ap.add_argument(
        "--obs", choices=("off", "metrics", "trace"), default="off",
        help="observability mode for every admitted session "
        "(see repro.core.spec.EngineOptions)",
    )
    ap.add_argument(
        "--trace-dir", default=None,
        help='directory for per-tenant JSONL/Chrome traces (obs="trace")',
    )
    ap.add_argument(
        "--metrics-out", default=None,
        help="write the final Prometheus text exposition to this file",
    )
    ap.add_argument(
        "--metrics-port", type=int, default=None,
        help="serve a live /metrics endpoint on this port (0 = free port)",
    )
    args = ap.parse_args()
    if args.mode == "discovery":
        serve_discovery(
            tenants=args.tenants,
            n=args.n,
            d=args.d,
            seed=args.seed,
            deadline_s=args.deadline_s,
            max_concurrent=args.max_concurrent,
            queue_limit=args.queue_limit,
            device_budget_mb=args.device_budget_mb,
            obs=args.obs,
            trace_dir=args.trace_dir,
            metrics_out=args.metrics_out,
            metrics_port=args.metrics_port,
        )
    else:
        serve(args.arch, args.batch, args.prompt_len, args.gen)


if __name__ == "__main__":
    main()
