"""Batched serving driver: prefill a batch of prompts, then greedy-decode
with the KV cache.  Runs reduced configs on CPU; the same step functions
lower on the production mesh (see dryrun.py decode cells).

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama_1b \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import load_arch


def serve(
    arch: str = "tinyllama_1b",
    batch: int = 4,
    prompt_len: int = 32,
    gen: int = 16,
    seed: int = 0,
    greedy: bool = True,
):
    cfg, model = load_arch(arch, reduced=True)
    if not hasattr(model, "prefill"):
        raise SystemExit(f"{arch} has no prefill path in this driver")
    params, _ = model.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(batch, prompt_len)), jnp.int32
    )
    max_len = prompt_len + gen

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts})
    t_prefill = time.time() - t0
    out = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for _ in range(gen):
        out.append(tok)
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    generated = jnp.concatenate(out, axis=1)
    print(
        f"[serve] {arch}: prefill({batch}x{prompt_len}) {t_prefill*1e3:.1f}ms, "
        f"{gen} decode steps {t_decode*1e3:.1f}ms "
        f"({t_decode/gen*1e3:.2f} ms/tok/batch)"
    )
    return generated


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    serve(args.arch, args.batch, args.prompt_len, args.gen)


if __name__ == "__main__":
    main()
