"""Sharding derivation for the launch layer: params, optimizer state,
batches and caches -> NamedShardings on a given mesh, via the logical-axis
resolver (repro.models.config.ShardingResolver)."""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ShardingResolver


def _is_axes(x):
    return isinstance(x, tuple)


def param_shardings(mesh, params_shapes, axes_tree, rules=None):
    """NamedSharding tree congruent with params.

    axes_tree leaves are tuples of logical names (None entries allowed).
    Records divisibility fallbacks on the returned resolver."""
    resolver = ShardingResolver(mesh, rules)

    def one(shape_struct, axes):
        return NamedSharding(mesh, resolver.spec(shape_struct.shape, axes))

    tree = jax.tree.map(one, params_shapes, axes_tree, is_leaf=None)
    return tree, resolver


def batch_shardings(mesh, batch_specs):
    """Token/label/frame batches: leading (batch) dim over all FSDP axes."""
    fsdp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    fsdp_size = int(np.prod([mesh.shape[a] for a in fsdp]))

    def one(s):
        if s.shape and s.shape[0] % fsdp_size == 0:
            return NamedSharding(mesh, P(fsdp, *(None,) * (len(s.shape) - 1)))
        return NamedSharding(mesh, P(*(None,) * len(s.shape)))

    return jax.tree.map(one, batch_specs)


def cache_shardings(mesh, cache_specs, cache_axes, rules=None):
    resolver = ShardingResolver(mesh, rules)

    def one(s, axes):
        if axes is None or len(axes) != len(s.shape):
            return NamedSharding(mesh, P(*(None,) * len(s.shape)))
        return NamedSharding(mesh, resolver.spec(s.shape, axes))

    tree = jax.tree.map(
        one, cache_specs, cache_axes, is_leaf=lambda x: _is_axes(x) or x is None
    )
    return tree, resolver


def adamw_state_shardings(param_shard_tree, mesh):
    """AdamW moments mirror the parameter shardings exactly (ZeRO falls out
    of FSDP-sharded params)."""
    scalar = NamedSharding(mesh, P())
    return {
        "mu": param_shard_tree,
        "nu": param_shard_tree,
        "step": scalar,
    }


def adafactor_state_shardings(params_shapes, axes_tree, mesh, rules=None):
    """Factored stats: vr drops the last dim's axis, vc drops the
    second-to-last dim's axis (matching repro.optim.adafactor_init)."""
    resolver = ShardingResolver(mesh, rules)
    scalar = NamedSharding(mesh, P())

    def one(shape_struct, axes):
        shape = shape_struct.shape
        if len(shape) < 2:
            return {"v": NamedSharding(mesh, resolver.spec(shape, axes))}
        r, c = len(shape) - 2, len(shape) - 1
        row_shape = tuple(d for i, d in enumerate(shape) if i != c)
        row_axes = tuple(a for i, a in enumerate(axes) if i != c)
        col_shape = tuple(d for i, d in enumerate(shape) if i != r)
        col_axes = tuple(a for i, a in enumerate(axes) if i != r)
        return {
            "vr": NamedSharding(mesh, resolver.spec(row_shape, row_axes)),
            "vc": NamedSharding(mesh, resolver.spec(col_shape, col_axes)),
        }

    v = jax.tree.map(one, params_shapes, axes_tree, is_leaf=None)
    return {"v": v, "step": scalar}
