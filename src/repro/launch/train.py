"""End-to-end training driver.

Runs on anything from 1 CPU (reduced configs; the CI path and
examples/train_lm.py) to the production mesh (full configs on TPU pods):

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama_1b \
      --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ck --resume auto

Features: cosine-schedule AdamW/Adafactor, grad clipping, optional int8
error-feedback gradient compression, deterministic sharded data, async
checkpointing + auto-resume (restart-from-latest), loss logging.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokens import TokenStream
from repro.distributed.fault_tolerance import FaultTolerantRunner
from repro.models.config import ShapeConfig
from repro.models.registry import build_model, load_arch
from repro.optim.compression import ef_allreduce, init_error_state
from repro.optim.optimizers import OptimConfig, make_optimizer


def make_train_step(model, opt_update, compress: bool = False):
    def train_step(state, batch):
        loss, grads = jax.value_and_grad(model.loss)(state["params"], batch)
        if compress:
            grads, new_err = ef_allreduce(grads, state["err"])
        new_params, new_opt, metrics = opt_update(
            grads, state["opt"], state["params"]
        )
        new_state = {"params": new_params, "opt": new_opt}
        if compress:
            new_state["err"] = new_err
        return new_state, {"loss": loss, **metrics}

    return jax.jit(train_step, donate_argnums=(0,))


def train(
    arch: str = "tinyllama_1b",
    reduced: bool = True,
    steps: int = 50,
    batch: int = 8,
    seq: int = 128,
    lr: float = 3e-4,
    ckpt_dir: str | None = None,
    ckpt_every: int = 25,
    compress: bool = False,
    seed: int = 0,
    log_every: int = 10,
    opt_kind: str = "adamw",
):
    cfg, model = load_arch(arch, reduced=reduced)
    ocfg = OptimConfig(kind=opt_kind, lr=lr, warmup_steps=max(steps // 10, 1), total_steps=steps)
    opt_init, opt_update = make_optimizer(ocfg)
    params, _ = model.init(jax.random.PRNGKey(seed))
    state = {"params": params, "opt": opt_init(params)}
    if compress:
        state["err"] = init_error_state(params)
    step_fn = make_train_step(model, opt_update, compress=compress)

    stream = TokenStream(cfg.vocab_size, seq, batch, seed=seed)
    shape = ShapeConfig("cli", seq, batch, "train")
    needs_frames = cfg.is_encoder_decoder
    needs_patches = cfg.frontend == "vit_stub"
    rng = np.random.default_rng(seed)

    def batches(step):
        b = stream.batch_at(step)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        if needs_frames:
            b["frames"] = jnp.asarray(
                rng.standard_normal((batch, seq, cfg.frontend_dim)), jnp.float32
            )
        if needs_patches:
            b["patch_embeds"] = jnp.asarray(
                rng.standard_normal(
                    (batch, cfg.num_prefix_tokens, cfg.frontend_dim)
                ),
                jnp.float32,
            )
        return b

    losses = []
    t0 = time.time()
    if ckpt_dir:
        runner = FaultTolerantRunner(step_fn, state, ckpt_dir, ckpt_every=ckpt_every)
        metrics = runner.run(batches, steps)
        losses = [float(m["loss"]) for m in metrics]
        state = runner.state
    else:
        for step in range(steps):
            state, m = step_fn(state, batches(step))
            losses.append(float(m["loss"]))
            if step % log_every == 0:
                print(
                    f"step {step:5d}  loss {losses[-1]:.4f}  "
                    f"lr {float(m['lr']):.2e}  gnorm {float(m['grad_norm']):.2f}"
                )
    dt = time.time() - t0
    print(
        f"[train] {arch} {'reduced' if reduced else 'full'}: "
        f"{steps} steps in {dt:.1f}s; loss {losses[0]:.4f} -> {losses[-1]:.4f}"
    )
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--opt", default="adamw", choices=["adamw", "adafactor"])
    args = ap.parse_args()
    train(
        arch=args.arch,
        reduced=args.reduced,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        lr=args.lr,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        compress=args.compress,
        opt_kind=args.opt,
    )


if __name__ == "__main__":
    main()
