"""Production meshes.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (jax locks the device count at first backend
init, and smoke tests must see 1 CPU device while the dry-run sees 512
forced host devices)."""

from __future__ import annotations

import jax

try:  # jax >= 0.5 spells the mesh axis types explicitly
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on jax version
    AxisType = None


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; (2, 16, 16) = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def mesh_context(mesh):
    """Context manager activating `mesh` as the ambient mesh, across jax
    versions: `jax.set_mesh` where it exists (jax >= 0.5), else the legacy
    ``with mesh:`` resource context (the `Mesh` object is itself a context
    manager that sets the thread-local physical mesh, which is what
    `repro.models.layers._ambient_mesh` reads back on those versions)."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
