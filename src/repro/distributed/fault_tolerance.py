"""Fault tolerance & elasticity for the training runtime.

Mechanisms (exercised by tests/test_substrate.py and
tests/test_fault_tolerance_discovery.py, and consumed by the sharded
discovery runner in repro.core.distributed_score and launch/train.py
--resume auto):

1. **Checkpoint/restart** — periodic async checkpoints (atomic-rename
   commit), restart resumes from `latest_step`; the data pipeline is
   addressed by (step, row) so the replayed batch stream is bit-identical.
2. **Elastic re-shard** — checkpoints store logical arrays; restore
   re-places them under whatever mesh the restarted job has
   (`restore_checkpoint(..., sharding_fn=...)`), so recovery onto a
   different device count is a placement change, not a format change.
3. **Straggler / lost-worker mitigation** — on real multi-host TPU this is
   driven by the coordinator's missed-heartbeat signal; the HeartbeatMonitor
   below reproduces the detection logic (deadline-based, with a grace
   count) in a host-local, testable form.  Upon detection the runner's
   policy is restart-from-checkpoint with the survivor set (elastic) —
   the industry-standard policy for SPMD jobs, where a lost participant
   stalls every collective.
4. **Simulated failures** — FaultTolerantRunner.step() accepts a
   `fail_hook` so tests can kill arbitrary steps and assert recovery
   reproduces the uninterrupted run exactly.
"""

from __future__ import annotations

import dataclasses
import time

from repro.checkpoint.store import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
)


@dataclasses.dataclass
class HeartbeatMonitor:
    """Deadline-based liveness: worker w is suspect after `timeout` without
    a beat and dead after `grace` missed deadline *windows*.

    Misses are keyed to deadline epochs — `int(elapsed // timeout)` since
    the last beat — never to `check()` call counts.  (An earlier version
    incremented a counter per call, so two rapid `check()`s could declare
    a worker dead without `grace` real timeouts elapsing; `check` must be
    safe to call at any frequency.)"""

    num_workers: int
    timeout: float = 10.0
    grace: int = 3

    def __post_init__(self):
        now = time.monotonic()
        self.last_beat = {w: now for w in range(self.num_workers)}
        self.misses = {w: 0 for w in range(self.num_workers)}

    def beat(self, worker: int, at: float | None = None):
        self.last_beat[worker] = time.monotonic() if at is None else at
        self.misses[worker] = 0

    def check(self, at: float | None = None):
        """Returns (alive, suspect, dead) worker id lists.  Idempotent for
        a fixed `at`: misses count elapsed deadline windows, not calls."""
        now = time.monotonic() if at is None else at
        alive, suspect, dead = [], [], []
        for w in range(self.num_workers):
            elapsed = now - self.last_beat[w]
            if elapsed <= self.timeout:
                self.misses[w] = 0
                alive.append(w)
                continue
            self.misses[w] = int(elapsed // self.timeout)
            (dead if self.misses[w] >= self.grace else suspect).append(w)
        return alive, suspect, dead


class FaultTolerantRunner:
    """Wraps a jitted train_step with checkpoint-every-N + auto-resume."""

    def __init__(
        self,
        train_step,
        init_state,
        ckpt_dir: str,
        ckpt_every: int = 50,
        sharding_fn=None,
    ):
        self.train_step = train_step
        self.ckpt = AsyncCheckpointer(ckpt_dir)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.sharding_fn = sharding_fn
        resume = latest_step(ckpt_dir)
        if resume is not None:
            self.state = restore_checkpoint(
                ckpt_dir, resume, init_state, sharding_fn=sharding_fn
            )
            self.step_num = resume
        else:
            self.state = init_state
            self.step_num = 0

    def run(self, batches, num_steps: int, fail_hook=None):
        """batches: callable step -> batch.  fail_hook(step) may raise to
        simulate a mid-run crash (the exception propagates after state is
        consistent, i.e. like a real preemption)."""
        metrics = []
        try:
            while self.step_num < num_steps:
                batch = batches(self.step_num)
                if fail_hook is not None:
                    fail_hook(self.step_num)
                self.state, m = self.train_step(self.state, batch)
                self.step_num += 1
                metrics.append(m)
                if self.step_num % self.ckpt_every == 0:
                    self.ckpt.save(self.step_num, self.state)
        finally:
            # drain pending async saves even on crash, so a committed
            # checkpoint is never half-written at restart time
            self.ckpt.wait()
        return metrics
