from repro.distributed.fault_tolerance import FaultTolerantRunner, HeartbeatMonitor

__all__ = ["FaultTolerantRunner", "HeartbeatMonitor"]
