"""Model registry: family -> model class; arch id -> (config, model)."""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig
from repro.models.encdec import EncDecLM
from repro.models.mamba2 import Zamba2Hybrid
from repro.models.transformer import TransformerLM
from repro.models.xlstm import XLSTM

ARCH_IDS = (
    "tinyllama_1b",
    "gemma_2b",
    "starcoder2_15b",
    "olmo_1b",
    "arctic_480b",
    "phi35_moe",
    "internvl2_26b",
    "xlstm_1b",
    "zamba2_1b",
    "seamless_m4t_medium",
    "cvlr_paper",  # the paper's own distributed-score workload
)


def build_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return TransformerLM(cfg)
    if cfg.family == "audio":
        return EncDecLM(cfg)
    if cfg.family == "ssm":
        return XLSTM(cfg)
    if cfg.family == "hybrid":
        return Zamba2Hybrid(cfg)
    raise ValueError(f"unknown family {cfg.family}")


def load_arch(arch: str, reduced: bool = False):
    """Returns (ModelConfig, model) for an arch id from repro.configs."""
    mod = importlib.import_module(f"repro.configs.{arch}")
    cfg = mod.reduced() if reduced else mod.config()
    return cfg, build_model(cfg)


def param_count_exact(model) -> int:
    """Exact parameter count via eval_shape (no allocation; works at 480B)."""
    import jax

    shapes = jax.eval_shape(lambda k: model.init(k)[0], jax.random.PRNGKey(0))
    return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))


import numpy as np  # noqa: E402  (used by param_count_exact)
