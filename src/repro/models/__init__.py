from repro.models.config import ModelConfig, ShapeConfig, SHAPES, ShardingResolver
from repro.models.registry import ARCH_IDS, build_model, load_arch

__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "ShardingResolver",
    "ARCH_IDS",
    "build_model",
    "load_arch",
]
