"""Shared neural layers: norms, RoPE, GQA attention (full / chunked /
decode), MLP variants, and einsum-dispatch MoE.

All functions are pure; parameters are dicts of arrays.  Initializers take
(rng, cfg) and return (params, logical_axes) pytrees of identical shape.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


# ------------------------------------------------------ activation hints
_HINT_AXES = {
    "batch": ("pod", "data"),
    "heads": ("model",),
    "kv": ("model",),
    "mlp": ("model",),
    "expert": ("model",),
    "vocab": ("model",),
    "seq": ("model",),  # context-parallel fallback
    "head_dim": ("model",),  # contraction-split fallback
}

# Axis-assignment priority: preferred TP dims first, fallbacks last, so a
# model axis goes to `heads` when divisible and only falls back to
# `seq`/`head_dim` (context-/contraction-parallel attention) when not —
# e.g. gemma's 8 heads or arctic's 56 heads on a 16-way axis.
_HINT_PRIORITY = {
    "expert": 0,
    "heads": 1,
    "kv": 2,
    "mlp": 3,
    "vocab": 4,
    "batch": 5,
    "seq": 6,
    "head_dim": 7,
}


def _ambient_mesh():
    """The active mesh, across jax versions: `jax.sharding.
    get_abstract_mesh` where it exists (jax >= 0.5), else the thread-local
    physical mesh the legacy ``with mesh:`` context manager sets (an empty
    mesh — no axis names — when none is active, same contract)."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        return get()
    from jax.interpreters import pxla

    return pxla.thread_resources.env.physical_mesh


def shard_hint(x, *logical):
    """Divisibility-checked with_sharding_constraint on the ambient mesh.

    Without these hints GSPMD's propagation can leave big intermediates
    replicated over `model` whenever a producer weight was replicated
    (e.g. GQA kv heads that don't divide the axis), silently multiplying
    per-device FLOPs ~16x (measured — EXPERIMENTS.md §Perf).  No-op when
    no mesh is active (single-device smoke tests)."""
    mesh = _ambient_mesh()
    if not mesh.axis_names:
        return x
    entries = [None] * len(x.shape)
    used = set()
    order = sorted(
        range(len(logical)),
        key=lambda i: _HINT_PRIORITY.get(logical[i], 99),
    )
    for i in order:
        name = logical[i]
        dim = x.shape[i]
        axes = tuple(
            a
            for a in _HINT_AXES.get(name, ())
            if a in mesh.axis_names and a not in used
        )
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if axes and dim % size == 0:
            used.update(axes)
            entries[i] = axes if len(axes) > 1 else axes[0]
    return jax.lax.with_sharding_constraint(x, P(*entries))


def vocab_parallel_ce(logits, labels):
    """Cross-entropy that stays vocab-sharded (Megatron-style).

    Keeps the (B, S, V) logits batch+vocab sharded end to end: the max and
    logsumexp reduce over the sharded vocab dim (XLA lowers these to tiny
    (B, S)-sized all-reduces over `model`), and the label logit is picked by
    a one-hot masked sum instead of take_along_axis (whose gather would
    force a full vocab all-gather).  Cuts the 13 GB/device f32 logits
    all-gather+all-reduce pair from the naive path (EXPERIMENTS.md §Perf).
    """
    logits = shard_hint(logits.astype(jnp.float32), "batch", None, "vocab")
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    onehot_sum = jnp.sum(
        jnp.where(
            jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
            == labels[..., None],
            logits,
            0.0,
        ),
        axis=-1,
    )
    return jnp.mean(lse - onehot_sum)


# ------------------------------------------------------------------ norms
def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def nonparam_layer_norm(x, eps=1e-5):
    """OLMo's non-parametric LayerNorm: no scale, no bias."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def apply_norm(x, params, kind):
    if kind == "rmsnorm":
        return rms_norm(x, params["scale"])
    return nonparam_layer_norm(x)


def norm_init(cfg):
    if cfg.norm_kind == "rmsnorm":
        return (
            {"scale": jnp.zeros((cfg.d_model,), cfg.dtype)},
            {"scale": ("embed",)},
        )
    return {}, {}


# ------------------------------------------------------------------- RoPE
def rope_frequencies(head_dim, theta):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta):
    """x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d, theta), jnp.float32)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention
def attention_init(rng, cfg, d_model=None):
    e = d_model or cfg.d_model
    h, kv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    sd = 1.0 / float(np.sqrt(e))
    params = {
        "wq": jax.random.normal(k1, (e, h, dh), cfg.dtype) * sd,
        "wk": jax.random.normal(k2, (e, kv, dh), cfg.dtype) * sd,
        "wv": jax.random.normal(k3, (e, kv, dh), cfg.dtype) * sd,
        "wo": jax.random.normal(k4, (h, dh, e), cfg.dtype) * sd / float(np.sqrt(cfg.num_layers)),
    }
    axes = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv", "head_dim"),
        "wv": ("embed", "kv", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    return params, axes


def _repeat_kv(k, num_heads):
    """(B, S, KV, D) -> (B, S, H, D) by group repetition."""
    kv = k.shape[-2]
    if kv == num_heads:
        return k
    return jnp.repeat(k, num_heads // kv, axis=-2)


def full_causal_attention(q, k, v):
    """q,k,v: (B, S, H, D) (kv already repeated).  O(S^2) scores."""
    b, s, h, d = q.shape
    scale = 1.0 / float(np.sqrt(d))
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def chunked_causal_attention(q, k, v, chunk: int, unroll: bool = False):
    """Flash-style online-softmax attention, O(S * chunk) live memory.

    Scans over KV chunks with a running (max, denom, acc) per query; fully
    masked (future) chunks are still *computed* then masked — the 2x
    masked-FLOPs overhead vs. a triangular schedule is recorded in the
    roofline's useful-FLOPs ratio and addressed in §Perf.
    """
    b, s_orig, h, d = q.shape
    pad = (-s_orig) % chunk
    if pad:
        # pad rows/keys: padded key positions exceed every real query
        # position, so the causal mask silently drops them; padded query
        # rows are sliced off below.
        widths = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(t, widths) for t in (q, k, v))
    s = s_orig + pad
    nq = s // chunk
    scale = 1.0 / float(np.sqrt(d))
    qc = q.reshape(b, nq, chunk, h, d)
    kc = k.reshape(b, nq, chunk, h, d)
    vc = v.reshape(b, nq, chunk, h, d)
    q_pos = jnp.arange(s).reshape(nq, chunk)
    # re-assert sharding after the (S -> nq, chunk) reshape: heads when
    # divisible, else the intra-chunk query dim (context parallel)
    qc = shard_hint(qc, "batch", None, "seq", "heads", None)
    kc = shard_hint(kc, "batch", None, None, "heads", None)
    vc = shard_hint(vc, "batch", None, None, "heads", None)

    def kv_step(carry, inputs):
        m_prev, l_prev, acc_prev = carry
        k_j, v_j, kpos_j = inputs
        # scores: (b, nq, h, cq, ck)
        sc = jnp.einsum("bnqhd,bkhd->bnhqk", qc, k_j) * scale
        # (nq, 1, cq, ck) -> broadcast over (b, nq, h, cq, ck)
        mask = q_pos[:, None, :, None] >= kpos_j[None, None, None, :]
        sc = jnp.where(mask[None], sc, -1e30)
        sc = sc.astype(jnp.float32)
        m_new = jnp.maximum(m_prev, sc.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(sc - m_new[..., None])
        l_new = l_prev * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bnhqk,bkhd->bnqhd", p.astype(q.dtype), v_j)
        acc_new = (
            acc_prev * alpha.transpose(0, 1, 3, 2)[..., None]
            + pv.astype(jnp.float32)  # f32 accumulator across KV chunks
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, nq, h, chunk), -1e30, jnp.float32)
    l0 = jnp.zeros((b, nq, h, chunk), jnp.float32)
    a0 = jnp.zeros((b, nq, chunk, h, d), jnp.float32)
    kv_pos = jnp.arange(s).reshape(nq, chunk)
    (m, l, acc), _ = jax.lax.scan(
        kv_step,
        (m0, l0, a0),
        (
            kc.transpose(1, 0, 2, 3, 4),
            vc.transpose(1, 0, 2, 3, 4),
            kv_pos,
        ),
        unroll=nq if unroll else 1,
    )
    denom = l.transpose(0, 1, 3, 2)[..., None]
    out = (acc / jnp.maximum(denom, 1e-30)).astype(q.dtype)
    return out.reshape(b, s, h, d)[:, :s_orig]


def attention_forward(params, x, cfg, positions=None, bidirectional=False):
    """Self-attention over a full sequence (train / prefill).

    Returns (out, (k, v)) so callers can seed a decode cache."""
    b, s, e = x.shape
    q = jnp.einsum("bse,ehd->bshd", x, params["wq"])
    k = jnp.einsum("bse,ekd->bskd", x, params["wk"])
    v = jnp.einsum("bse,ekd->bskd", x, params["wv"])
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    kr = _repeat_kv(k, cfg.num_heads)
    vr = _repeat_kv(v, cfg.num_heads)
    # Keep the attention contraction head-sharded even when the kv
    # projections were replicated (GQA indivisibility fallback); when the
    # head count itself cannot split the axis, fall back to sharding the
    # query sequence (context parallel — k/v stay gathered, cheap for GQA).
    # The chunked path re-hints after its (S -> nq, chunk) reshape instead
    # (a reshape of a sharded dim would force a gather).
    seq_hint = "seq" if (bidirectional or s <= cfg.attn_chunk) else None
    q = shard_hint(q, "batch", seq_hint, "heads", None)
    kr = shard_hint(kr, "batch", None, "heads", None)
    vr = shard_hint(vr, "batch", None, "heads", None)
    if bidirectional:
        scale = 1.0 / float(np.sqrt(q.shape[-1]))
        sc = jnp.einsum("bqhd,bkhd->bhqk", q, kr) * scale
        probs = jax.nn.softmax(sc.astype(jnp.float32), -1).astype(x.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, vr)
    elif s > cfg.attn_chunk:
        out = chunked_causal_attention(
            q, kr, vr, cfg.attn_chunk, unroll=cfg.unroll_scans
        )
    else:
        out = full_causal_attention(q, kr, vr)
    out = jnp.einsum("bshd,hde->bse", out, params["wo"])
    return out, (k, v)


def attention_decode(params, x, cache_k, cache_v, cur_index, cfg):
    """One-token decode: x (B, 1, E); cache (B, S_max, KV, D).

    Returns (out (B, 1, E), new_cache_k, new_cache_v)."""
    b = x.shape[0]
    q = jnp.einsum("bse,ehd->bshd", x, params["wq"])  # (B,1,H,D)
    k = jnp.einsum("bse,ekd->bskd", x, params["wk"])
    v = jnp.einsum("bse,ekd->bskd", x, params["wv"])
    pos = jnp.full((b, 1), cur_index, jnp.int32)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), cur_index, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), cur_index, axis=1)
    kr = _repeat_kv(cache_k, cfg.num_heads)  # (B, S_max, H, D)
    vr = _repeat_kv(cache_v, cfg.num_heads)
    q = shard_hint(q, "batch", None, "heads", "head_dim")
    kr = shard_hint(kr, "batch", None, "heads", "head_dim")
    vr = shard_hint(vr, "batch", None, "heads", "head_dim")
    scale = 1.0 / float(np.sqrt(q.shape[-1]))
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, kr) * scale  # (B,H,1,S_max)
    valid = (jnp.arange(cache_k.shape[1]) <= cur_index)[None, None, None, :]
    sc = jnp.where(valid, sc, -1e30)
    probs = jax.nn.softmax(sc.astype(jnp.float32), -1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vr)
    out = jnp.einsum("bshd,hde->bse", out, params["wo"])
    return out, cache_k, cache_v


# -------------------------------------------------------------------- MLP
def mlp_init(rng, cfg, d_ff=None, tag="mlp"):
    e = cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(rng, 3)
    sd = 1.0 / float(np.sqrt(e))
    gated = cfg.mlp_kind in ("swiglu", "geglu")
    params = {
        "wi": jax.random.normal(k1, (e, f), cfg.dtype) * sd,
        "wo": jax.random.normal(k2, (f, e), cfg.dtype) * sd / float(np.sqrt(cfg.num_layers)),
    }
    axes = {"wi": ("embed", tag), "wo": (tag, "embed")}
    if gated:
        params["wg"] = jax.random.normal(k3, (e, f), cfg.dtype) * sd
        axes["wg"] = ("embed", tag)
    return params, axes


def mlp_forward(params, x, cfg):
    h = shard_hint(x @ params["wi"], "batch", None, "mlp")
    if cfg.mlp_kind == "swiglu":
        h = jax.nn.silu(shard_hint(x @ params["wg"], "batch", None, "mlp")) * h
    elif cfg.mlp_kind == "geglu":
        h = jax.nn.gelu(
            shard_hint(x @ params["wg"], "batch", None, "mlp"), approximate=True
        ) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    return h @ params["wo"]


# -------------------------------------------------------------------- MoE
def moe_init(rng, cfg):
    e, f, x = cfg.d_model, cfg.d_ff, cfg.num_experts
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    sd = 1.0 / float(np.sqrt(e))
    gated = cfg.mlp_kind in ("swiglu", "geglu")
    params = {
        "router": jax.random.normal(k1, (e, x), jnp.float32) * sd,
        "wi": jax.random.normal(k2, (x, e, f), cfg.dtype) * sd,
        "wo": jax.random.normal(k3, (x, f, e), cfg.dtype) * sd / float(np.sqrt(cfg.num_layers)),
    }
    axes = {
        "router": ("embed", "expert"),
        "wi": ("expert", "embed", "mlp"),
        "wo": ("expert", "mlp", "embed"),
    }
    if gated:
        params["wg"] = jax.random.normal(k4, (x, e, f), cfg.dtype) * sd
        axes["wg"] = ("expert", "embed", "mlp")
    return params, axes


def _route(params, x, cfg):
    """Shared routing: top-k gates + capacity positions.

    Returns (gate (B,S,k) f32, idx (B,S,k) i32 expert ids,
    pos (B,S,k) i32 position-in-expert with dropped = cap, aux)."""
    b, s, e = x.shape
    nx, k = cfg.num_experts, cfg.num_experts_per_tok
    cap = max(1, int(cfg.capacity_factor * s * k / nx))
    logits = jnp.einsum("bse,ex->bsx", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)  # (B, S, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(idx, nx, dtype=jnp.float32)  # (B, S, k, X)
    flat = onehot.reshape(b, s * k, nx)
    pos_f = (jnp.cumsum(flat, axis=1) - 1.0).reshape(b, s, k, nx)
    pos = (pos_f * onehot).sum(-1).astype(jnp.int32)  # (B, S, k)
    dropped = pos >= cap
    pos = jnp.where(dropped, cap, pos)  # cap == out-of-bounds sentinel
    gate = jnp.where(dropped, 0.0, gate)
    density = flat.mean(axis=1)
    aux = nx * jnp.mean(jnp.sum(density * probs.mean(axis=1), axis=-1))
    return gate, idx, pos, cap, aux


def _expert_ffn(params, xin, cfg):
    """xin: (X, B, C, E) -> (X, B, C, E); expert-sharded over `model`."""
    h = shard_hint(
        jnp.einsum("xbce,xef->xbcf", xin, params["wi"]),
        "expert", "batch", None, None,
    )
    if cfg.mlp_kind in ("swiglu", "geglu"):
        g = jnp.einsum("xbce,xef->xbcf", xin, params["wg"])
        act = jax.nn.silu if cfg.mlp_kind == "swiglu" else partial(
            jax.nn.gelu, approximate=True
        )
        h = act(g) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    return jnp.einsum("xbcf,xfe->xbce", h, params["wo"])


def moe_forward(params, x, cfg):
    """Top-k capacity-dropped MoE; two dispatch backends:

    - "einsum" (Mesh-TF style): dense (B,S,X,C) dispatch/combine one-hots;
      simple and all-to-all friendly but pays 2*B*S*X_loc*C*E dispatch
      FLOPs — measured at ~half of arctic's train FLOPs (§Perf iter. 6).
    - "gather": scatter token indices into an (B,X,C) buffer, gather
      tokens, scatter-add results back.  Dispatch costs bytes, not FLOPs.
    """
    b, s, e = x.shape
    gate, idx, pos, cap, aux = _route(params, x, cfg)

    if cfg.moe_dispatch == "gather":
        bb = jnp.arange(b)[:, None, None]
        ss = jnp.broadcast_to(jnp.arange(s)[None, :, None], idx.shape)
        # token index buffer per (expert, slot); OOB sentinel rows drop
        tok_idx = jnp.full((b, cfg.num_experts, cap + 1), s, jnp.int32)
        tok_idx = tok_idx.at[bb, idx, pos].set(ss, mode="drop")
        tok_idx = tok_idx[..., :cap]  # (B, X, C)
        gate_buf = jnp.zeros((b, cfg.num_experts, cap + 1), x.dtype)
        gate_buf = gate_buf.at[bb, idx, pos].set(gate.astype(x.dtype), mode="drop")
        gate_buf = gate_buf[..., :cap]
        x_pad = jnp.concatenate([x, jnp.zeros((b, 1, e), x.dtype)], axis=1)
        xin = x_pad[jnp.arange(b)[:, None, None], tok_idx]  # (B, X, C, E)
        xin = shard_hint(
            jnp.transpose(xin, (1, 0, 2, 3)), "expert", "batch", None, None
        )
        out = _expert_ffn(params, xin, cfg)  # (X, B, C, E)
        contrib = jnp.transpose(out, (1, 0, 2, 3)) * gate_buf[..., None]
        y = jnp.zeros((b, s + 1, e), x.dtype)
        y = y.at[jnp.arange(b)[:, None, None], tok_idx].add(contrib)[:, :s]
        return y, aux

    # einsum dispatch (baseline)
    keep = (pos < cap)[..., None]  # (B, S, k, 1)
    onehot = jax.nn.one_hot(idx, cfg.num_experts, dtype=jnp.float32) * keep
    pos_onehot = jax.nn.one_hot(jnp.clip(pos, 0, cap - 1), cap, dtype=jnp.float32)
    dispatch = jnp.einsum("bskx,bskc->bsxc", onehot, pos_onehot).astype(x.dtype)
    combine = jnp.einsum(
        "bskx,bskc,bsk->bsxc", onehot, pos_onehot, gate
    ).astype(x.dtype)
    xin = shard_hint(
        jnp.einsum("bsxc,bse->xbce", dispatch, x), "expert", "batch", None, None
    )
    out = _expert_ffn(params, xin, cfg)
    y = jnp.einsum("xbce,bsxc->bse", out, combine)
    return y, aux
