"""Mamba2 (SSD — state-space dual, chunked) + Zamba2 hybrid.

The SSD recurrence per head (state S in R^{P x N}):

    S_t = exp(dt_t * A) S_{t-1} + dt_t * x_t B_t^T        y_t = C_t S_t

is evaluated chunk-parallel: within a chunk the output is an attention-like
contraction weighted by cumulative decays; across chunks a small carried
state flows through `lax.scan`.  Per-chunk cost O(c^2 (N + P)); state
O(H P N) — this is what makes the `long_500k` decode shape tractable
(no KV cache; see DESIGN.md §2.4).

Zamba2: `attn_every` Mamba2 layers per group followed by ONE SHARED
full-attention block (same parameters every application, Zamba-style);
groups run under an outer scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.config import ModelConfig, ShapeConfig


CHUNK = 256
CONV_K = 4


def _mamba_block_init(rng, cfg: ModelConfig):
    e = cfg.d_model
    d_inner = 2 * e
    n = cfg.ssm_state
    heads = cfg.ssm_heads or max(1, d_inner // 64)
    p = d_inner // heads
    k1, k2, k3, k4, k5, k6 = jax.random.split(rng, 6)
    sd = 1.0 / float(np.sqrt(e))
    params = {
        # fused input projection: [x, z, B, C, dt]
        "in_x": jax.random.normal(k1, (e, d_inner), cfg.dtype) * sd,
        "in_z": jax.random.normal(k2, (e, d_inner), cfg.dtype) * sd,
        "in_bc": jax.random.normal(k3, (e, 2 * n), cfg.dtype) * sd,
        "in_dt": jax.random.normal(k4, (e, heads), cfg.dtype) * sd,
        "conv_w": jax.random.normal(k5, (CONV_K, d_inner), cfg.dtype) * 0.2,
        "a_log": jnp.zeros((heads,), jnp.float32),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "out": jax.random.normal(k6, (d_inner, e), cfg.dtype)
        * sd
        / float(np.sqrt(cfg.num_layers)),
        "ln": {"scale": jnp.zeros((e,), cfg.dtype)},
    }
    axes = {
        "in_x": ("embed", "mlp"),
        "in_z": ("embed", "mlp"),
        "in_bc": ("embed", "state"),
        "in_dt": ("embed", "heads"),
        "conv_w": (None, "mlp"),
        "a_log": ("heads",),
        "dt_bias": ("heads",),
        "out": ("mlp", "embed"),
        "ln": {"scale": ("embed",)},
    }
    return params, axes


def _causal_conv(x, w):
    """Depthwise causal conv: x (B, S, D), w (K, D)."""
    k = w.shape[0]
    pads = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pads[:, i : i + x.shape[1]] * w[i] for i in range(k))
    return out


def _ssd_chunked(xh, dt, bmat, cmat, a_log, state0=None, chunk=CHUNK, unroll=False):
    """Chunk-parallel SSD scan.

    xh: (B, S, H, P)  dt: (B, S, H)  bmat/cmat: (B, S, N)  a_log: (H,)
    Returns (y (B, S, H, P), final state (B, H, P, N)).
    """
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    c = min(chunk, s)
    nc = s // c
    assert s % c == 0, (s, c)

    xc = xh.reshape(b, nc, c, h, p)
    dtc = dt.reshape(b, nc, c, h)
    bc = bmat.reshape(b, nc, c, n)
    cc = cmat.reshape(b, nc, c, n)
    neg_a = -jnp.exp(a_log)  # (H,) continuous-time decay < 0
    dta = dtc * neg_a  # (B, nc, c, H) log decays
    lcum = jnp.cumsum(dta, axis=2)  # inclusive cumulative log decay

    # intra-chunk: W[b,i,j,h] = exp(l_i - l_j) for i >= j
    tri = jnp.tril(jnp.ones((c, c), bool))

    def chunk_step(state, inputs):
        x_k, dt_k, b_k, c_k, l_k, dta_k = inputs
        # scores (B, c, c): C_i . B_j
        scores = jnp.einsum("bin,bjn->bij", c_k, b_k)
        decay = jnp.exp(
            jnp.clip(l_k[:, :, None, :] - l_k[:, None, :, :], -60.0, 0.0)
        )  # (B, c, c, H) valid for i >= j
        w = scores[..., None] * decay * jnp.where(tri[None, ..., None], 1.0, 0.0)
        xbar = dt_k[..., None] * x_k  # (B, c, H, P)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xbar)
        # inter-chunk: y_inter_i = C_i (exp(l_i) * S_prev)
        y_inter = jnp.einsum("bin,bih,bhpn->bihp", c_k, jnp.exp(l_k), state)
        # state update: S_new = exp(l_c) S_prev + sum_j exp(l_c - l_j) xbar_j B_j^T
        total = l_k[:, -1, :]  # (B, H)
        carry_decay = jnp.exp(
            jnp.clip(total[:, None, :] - l_k, -60.0, 0.0)
        )  # (B, c, H)
        s_in = jnp.einsum("bjh,bjhp,bjn->bhpn", carry_decay, xbar, b_k)
        state = jnp.exp(total)[..., None, None] * state + s_in
        return state, y_intra + y_inter

    if state0 is None:
        state0 = jnp.zeros((b, h, p, n), jnp.float32)
    swap = lambda t: jnp.swapaxes(t, 0, 1)  # scan over chunks
    state, ys = jax.lax.scan(
        chunk_step,
        state0,
        (swap(xc), swap(dtc), swap(bc), swap(cc), swap(lcum), swap(dta)),
        # NOT unrolled even in dry-run costing: the intra-chunk einsums are
        # ~1-2% of SSD FLOPs at c=256 (projections dominate), and full
        # unrolling explodes compile time (EXPERIMENTS.md §Dry-run caveat).
        unroll=1,
    )
    y = jnp.swapaxes(ys, 0, 1).reshape(b, s, h, p)
    return y.astype(xh.dtype), state


def mamba_block_forward(params, h, cfg, state=None, conv_state=None):
    """h: (B, S, E).  Returns (out, (ssm_state, conv_state))."""
    b, s, e = h.shape
    d_inner = 2 * e
    heads = cfg.ssm_heads or max(1, d_inner // 64)
    p = d_inner // heads
    x = L.rms_norm(h, params["ln"]["scale"])
    xb = x @ params["in_x"]  # (B, S, 2E)
    z = jax.nn.silu(x @ params["in_z"])
    if conv_state is not None:
        # decode: roll conv window
        window = jnp.concatenate([conv_state, xb], axis=1)[:, -CONV_K:]
        conv_out = jnp.einsum("bkd,kd->bd", window, params["conv_w"])[:, None]
        new_conv_state = window[:, 1:]
    else:
        conv_out = _causal_conv(xb, params["conv_w"])
        new_conv_state = xb[:, -(CONV_K - 1) :]
    xb = jax.nn.silu(conv_out)
    bc = x @ params["in_bc"]
    bmat, cmat = jnp.split(bc, 2, axis=-1)  # (B, S, N) each
    dt = jax.nn.softplus(
        (x @ params["in_dt"]).astype(jnp.float32) + params["dt_bias"]
    )  # (B, S, H)
    xh = xb.reshape(b, s, heads, p)
    if s == 1 and state is not None:
        # recurrent single-step decode
        neg_a = -jnp.exp(params["a_log"])
        decay = jnp.exp(dt[:, 0] * neg_a)  # (B, H)
        xbar = dt[:, 0, :, None] * xh[:, 0]  # (B, H, P)
        state = decay[..., None, None] * state + jnp.einsum(
            "bhp,bn->bhpn", xbar, bmat[:, 0]
        )
        y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0], state)[:, None]
        y = y.reshape(b, 1, d_inner).astype(h.dtype)
    else:
        y, state = _ssd_chunked(
            xh,
            dt,
            bmat,
            cmat,
            params["a_log"],
            state0=state,
            chunk=cfg.ssm_chunk,
            unroll=cfg.unroll_scans,
        )
        y = y.reshape(b, s, d_inner)
    out = (y * z) @ params["out"]
    return h + out, (state, new_conv_state)


class Zamba2Hybrid:
    """Mamba2 backbone + shared attention block every `attn_every` layers."""

    def __init__(self, cfg: ModelConfig):
        assert cfg.num_layers % cfg.attn_every == 0, (
            cfg.num_layers,
            cfg.attn_every,
        )
        self.cfg = cfg
        self.groups = cfg.num_layers // cfg.attn_every

    def init(self, rng):
        cfg = self.cfg
        r_embed, r_m, r_a, r_mlp, r_head = jax.random.split(rng, 5)

        def group_init(r):
            rr = jax.random.split(r, cfg.attn_every)
            per = [_mamba_block_init(x, cfg) for x in rr]
            p = jax.tree.map(lambda *xs: jnp.stack(xs), *[q for q, _ in per])
            a = jax.tree.map(
                lambda ax: ("layers",) + ax,
                per[0][1],
                is_leaf=lambda x: isinstance(x, tuple),
            )
            return p, a

        rg = jax.random.split(r_m, self.groups)
        per_g = [group_init(x) for x in rg]
        mamba = jax.tree.map(lambda *xs: jnp.stack(xs), *[p for p, _ in per_g])
        mamba_axes = jax.tree.map(
            lambda ax: ("layers",) + ax,
            per_g[0][1],
            is_leaf=lambda x: isinstance(x, tuple),
        )
        attn_p, attn_a = L.attention_init(r_a, cfg)
        mlp_p, mlp_a = L.mlp_init(r_mlp, cfg)
        params = {
            "embed": jax.random.normal(
                r_embed, (cfg.vocab_size, cfg.d_model), cfg.dtype
            )
            * 0.02,
            "mamba": mamba,
            "shared_attn": attn_p,
            "shared_ln1": {"scale": jnp.zeros((cfg.d_model,), cfg.dtype)},
            "shared_mlp": mlp_p,
            "shared_ln2": {"scale": jnp.zeros((cfg.d_model,), cfg.dtype)},
            "ln_f": {"scale": jnp.zeros((cfg.d_model,), cfg.dtype)},
            "lm_head": jax.random.normal(
                r_head, (cfg.d_model, cfg.vocab_size), cfg.dtype
            )
            * 0.02,
        }
        axes = {
            "embed": ("vocab", "embed"),
            "mamba": mamba_axes,
            "shared_attn": attn_a,
            "shared_ln1": {"scale": ("embed",)},
            "shared_mlp": mlp_a,
            "shared_ln2": {"scale": ("embed",)},
            "ln_f": {"scale": ("embed",)},
            "lm_head": ("embed", "vocab"),
        }
        return params, axes

    def _shared_attn_block(self, params, h, positions):
        cfg = self.cfg
        x = L.rms_norm(h, params["shared_ln1"]["scale"])
        attn_out, kv = L.attention_forward(params["shared_attn"], x, cfg, positions)
        h = h + attn_out
        x = L.rms_norm(h, params["shared_ln2"]["scale"])
        return h + L.mlp_forward(params["shared_mlp"], x, cfg), kv

    def forward(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        h = (params["embed"][tokens] * float(np.sqrt(cfg.d_model))).astype(cfg.dtype)
        positions = jnp.arange(tokens.shape[1])[None, :]

        def group(hh, group_params):
            def layer(hhh, lp):
                hhh, _ = mamba_block_forward(lp, hhh, cfg)
                return hhh, None

            layer_fn = jax.checkpoint(layer) if cfg.remat else layer
            hh, _ = jax.lax.scan(
                layer_fn, hh, group_params,
                unroll=cfg.layer_unroll(cfg.attn_every),
            )
            hh, _ = self._shared_attn_block(params, hh, positions)
            return hh, None

        h, _ = jax.lax.scan(
            group, h, params["mamba"], unroll=cfg.layer_unroll(self.groups)
        )
        h = L.rms_norm(h, params["ln_f"]["scale"])
        logits = L.shard_hint(
            jnp.einsum("bse,ev->bsv", h, params["lm_head"]),
            "batch", None, "vocab",
        )
        return logits, jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch)
        return L.vocab_parallel_ce(logits, batch["labels"])

    # ---- serve: recurrent decode (ssm states + shared-attn KV cache) ----
    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        h = (params["embed"][tokens] * float(np.sqrt(cfg.d_model))).astype(cfg.dtype)
        idx = cache["index"]

        def group(carry, inputs):
            hh, g = carry
            group_params, ssm_state, conv_state, ck, cv = inputs

            def layer(inner, lp_state):
                hhh = inner
                lp, st, cst = lp_state
                hhh, (st, cst) = mamba_block_forward(
                    lp, hhh, cfg, state=st, conv_state=cst
                )
                return hhh, (st, cst)

            hh, (ssm_state, conv_state) = jax.lax.scan(
                layer,
                hh,
                (group_params, ssm_state, conv_state),
                unroll=cfg.layer_unroll(cfg.attn_every),
            )
            x = L.rms_norm(hh, params["shared_ln1"]["scale"])
            attn_out, ck, cv = L.attention_decode(
                params["shared_attn"], x, ck, cv, idx, cfg
            )
            hh = hh + attn_out
            x = L.rms_norm(hh, params["shared_ln2"]["scale"])
            hh = hh + L.mlp_forward(params["shared_mlp"], x, cfg)
            return (hh, g), (ssm_state, conv_state, ck, cv)

        (h, _), (ssm, conv, ks, vs) = jax.lax.scan(
            group,
            (h, 0),
            (
                params["mamba"],
                cache["ssm"],
                cache["conv"],
                cache["k"],
                cache["v"],
            ),
            unroll=cfg.layer_unroll(self.groups),
        )
        h = L.rms_norm(h, params["ln_f"]["scale"])
        logits = jnp.einsum("be,ev->bv", h[:, -1], params["lm_head"])
        return logits, {
            "ssm": ssm,
            "conv": conv,
            "k": ks,
            "v": vs,
            "index": idx + 1,
        }

    def input_specs(self, shape: ShapeConfig):
        b, s = shape.global_batch, shape.seq_len
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return specs

    def decode_specs(self, shape: ShapeConfig):
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        e = cfg.d_model
        d_inner = 2 * e
        heads = cfg.ssm_heads or max(1, d_inner // 64)
        p = d_inner // heads
        g, a = self.groups, cfg.attn_every
        kv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
        cache = {
            "ssm": jax.ShapeDtypeStruct((g, a, b, heads, p, cfg.ssm_state), jnp.float32),
            "conv": jax.ShapeDtypeStruct((g, a, b, CONV_K - 1, d_inner), cfg.dtype),
            "k": jax.ShapeDtypeStruct((g, b, s, kv, dh), cfg.dtype),
            "v": jax.ShapeDtypeStruct((g, b, s, kv, dh), cfg.dtype),
            "index": jax.ShapeDtypeStruct((), jnp.int32),
        }
        return cache, jax.ShapeDtypeStruct((b, 1), jnp.int32)

    def cache_logical_axes(self):
        return {
            "ssm": ("layers", "layers2", "batch", "heads", None, "state"),
            "conv": ("layers", "layers2", "batch", None, "mlp"),
            "k": ("layers", "batch", "cache_seq", "kv", "head_dim"),
            "v": ("layers", "batch", "cache_seq", "kv", "head_dim"),
            "index": (),
        }
