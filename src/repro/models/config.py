"""Model configuration + logical->physical sharding resolution.

Every parameter carries a tuple of *logical* axis names; `resolve_rules`
maps them to mesh axes with divisibility validation (e.g. gemma's 8 query
heads cannot split over a 16-way `model` axis -> that dim falls back to
replicated and the fallback is recorded for the roofline notes).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | ssm | hybrid | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    mlp_kind: str = "swiglu"  # swiglu | geglu | gelu
    norm_kind: str = "rmsnorm"  # rmsnorm | nonparam_ln
    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 2
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel w/ MoE
    dense_ff: int = 0
    capacity_factor: float = 1.0
    moe_dispatch: str = "einsum"  # einsum (Mesh-TF) | gather (scatter-based)
    # ssm / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0
    attn_every: int = 0  # zamba2: shared attention block cadence
    slstm_every: int = 0  # xlstm: sLSTM cadence (rest mLSTM)
    # enc-dec
    is_encoder_decoder: bool = False
    enc_layers: int = 0
    # frontends (stubs per assignment)
    frontend: str = ""  # "" | vit_stub | audio_stub
    num_prefix_tokens: int = 0
    frontend_dim: int = 0
    rope_theta: float = 10000.0
    dtype: Any = jnp.bfloat16
    remat: bool = True
    attn_chunk: int = 2048  # chunked attention above this seq len
    ssm_chunk: int = 256  # SSD / mLSTM chunk length
    tie_embeddings: bool = False
    # Dry-run costing knob: XLA's HLO cost analysis counts a while-loop
    # body ONCE regardless of trip count (verified in EXPERIMENTS.md
    # §Dry-run), so the dry-run unrolls layer scans and inner
    # attention/SSD chunk scans to obtain true per-step FLOPs/bytes.
    unroll_scans: bool = False

    def layer_unroll(self, n: int) -> int:
        return n if self.unroll_scans else 1

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for 6ND."""
        e, h, kv, dh, f, v = (
            self.d_model,
            self.num_heads,
            self.num_kv_heads,
            self.resolved_head_dim,
            self.d_ff,
            self.vocab_size,
        )
        n = v * e  # embed
        if not self.tie_embeddings:
            n += v * e  # lm head
        per_attn = e * h * dh + 2 * e * kv * dh + h * dh * e
        if self.family in ("ssm",):
            # mLSTM block: qkv/gates up-down projections (factor-2 inner)
            inner = 2 * e
            per_block = 3 * e * inner + inner * e + 4 * e * inner
            n += self.num_layers * per_block
            return n
        gates = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        if self.num_experts:
            per_ffn = self.num_experts * gates * e * f
            if self.moe_dense_residual:
                per_ffn += gates * e * self.dense_ff
            per_ffn += e * self.num_experts  # router
        else:
            per_ffn = gates * e * f
        layers = self.num_layers + self.enc_layers
        if self.family == "hybrid":
            # mamba2 per-layer + one shared attention block
            d_inner = 2 * e
            per_m = e * (2 * d_inner) + d_inner * e + d_inner * (
                2 * self.ssm_state
            )
            n += self.num_layers * (per_m + gates * e * f)
            n += per_attn  # shared block
            return n
        n += layers * (per_attn + per_ffn)
        return n

    def active_param_count(self) -> int:
        """Active (per-token) params for MoE (6*N_active*D)."""
        if not self.num_experts:
            return self.param_count()
        e, f = self.d_model, self.d_ff
        gates = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        total = self.param_count()
        inactive = (
            (self.num_experts - self.num_experts_per_tok)
            * gates
            * e
            * f
            * self.num_layers
        )
        return total - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# --------------------------------------------------------- sharding rules
# logical axis -> preferred mesh axes, in priority order.  "fsdp" expands to
# ("pod", "data") on the multi-pod mesh and ("data",) on a single pod.
DEFAULT_RULES = {
    "batch": ("fsdp",),
    "vocab": ("model",),
    "embed": ("fsdp",),
    "heads": ("model",),
    "kv": ("model",),
    "mlp": ("model",),
    "expert": ("model",),
    "dense_mlp": ("model",),
    "layers": (),
    "head_dim": (),
    "state": (),
    "seq": (),
    "cache_seq": (),
    "chunk": (),
}


class ShardingResolver:
    """Maps logical axis tuples to PartitionSpecs for a given mesh."""

    def __init__(self, mesh, rules=None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES, **(rules or {}))
        self.fsdp_axes = (
            ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        )
        self.fallbacks: list = []  # (logical, dim_size, axes) records

    def _expand(self, axes):
        out = []
        for ax in axes:
            out.extend(self.fsdp_axes if ax == "fsdp" else (ax,))
        return tuple(out)

    def _axes_size(self, axes) -> int:
        size = 1
        for ax in axes:
            size *= self.mesh.shape[ax]
        return size

    def spec(self, shape, logical) -> P:
        assert len(shape) == len(logical), (shape, logical)
        used = set()
        entries = []
        for dim, name in zip(shape, logical):
            axes = self._expand(self.rules.get(name, ()))
            axes = tuple(a for a in axes if a not in used)
            if axes and dim % self._axes_size(axes) == 0:
                used.update(axes)
                entries.append(axes if len(axes) > 1 else axes[0])
            else:
                if axes:
                    self.fallbacks.append((name, dim, axes))
                entries.append(None)
        return P(*entries)
