"""Decoder-only transformer LM (dense / MoE / Arctic-residual / VLM-prefix).

Layers run under `lax.scan` over stacked parameters (one HLO block for all
layers -> small programs, fast multi-cell dry-run compiles) with optional
remat on the block body.  Supports:

  - GQA/MQA attention + RoPE (full or chunked-causal by seq length)
  - RMSNorm / OLMo non-parametric LN
  - SwiGLU / GeGLU / GELU MLPs
  - top-2 einsum-dispatch MoE, optionally with Arctic's parallel dense
    residual FFN
  - VLM mode: stub patch embeddings prepended to the token stream
  - KV-cache prefill + single-token decode

Public API used by launch/dryrun/train/serve:
  init, loss, forward, prefill, decode_step, input_specs, decode_specs,
  param_logical_axes (via init's second return).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.config import ModelConfig, ShapeConfig


def _split_like(rng, n):
    return list(jax.random.split(rng, n))


def _stack_init(rng, n_layers, init_fn):
    """Initialize per-layer params and stack along a leading L axis."""
    rngs = _split_like(rng, n_layers)
    per = [init_fn(r) for r in rngs]
    params = jax.tree.map(lambda *xs: jnp.stack(xs), *[p for p, _ in per])
    axes = jax.tree.map(
        lambda a: ("layers",) + a,
        per[0][1],
        is_leaf=lambda t: isinstance(t, tuple),
    )
    return params, axes


class TransformerLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------- init
    def init(self, rng):
        cfg = self.cfg
        r_embed, r_blocks, r_head, r_front = jax.random.split(rng, 4)

        def block_init(r):
            ra, rm, rd = jax.random.split(r, 3)
            p, a = {}, {}
            p["attn"], a["attn"] = L.attention_init(ra, cfg)
            p["ln1"], a["ln1"] = L.norm_init(cfg)
            p["ln2"], a["ln2"] = L.norm_init(cfg)
            if cfg.num_experts:
                p["moe"], a["moe"] = L.moe_init(rm, cfg)
                if cfg.moe_dense_residual:
                    p["dense"], a["dense"] = L.mlp_init(
                        rd, cfg, d_ff=cfg.dense_ff, tag="dense_mlp"
                    )
            else:
                p["mlp"], a["mlp"] = L.mlp_init(rm, cfg)
            return p, a

        blocks, block_axes = _stack_init(r_blocks, cfg.num_layers, block_init)
        params = {
            "embed": jax.random.normal(
                r_embed, (cfg.vocab_size, cfg.d_model), cfg.dtype
            )
            * 0.02,
            "blocks": blocks,
            "ln_f": L.norm_init(cfg)[0],
        }
        axes = {
            "embed": ("vocab", "embed"),
            "blocks": block_axes,
            "ln_f": L.norm_init(cfg)[1],
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = (
                jax.random.normal(r_head, (cfg.d_model, cfg.vocab_size), cfg.dtype)
                * 0.02
            )
            axes["lm_head"] = ("embed", "vocab")
        if cfg.frontend == "vit_stub":
            params["vit_proj"] = (
                jax.random.normal(
                    r_front, (cfg.frontend_dim, cfg.d_model), cfg.dtype
                )
                * 0.02
            )
            axes["vit_proj"] = ("embed", None)
        return params, axes

    # ------------------------------------------------------- block body
    def _block(self, h, block_params, positions):
        cfg = self.cfg
        x = L.apply_norm(h, block_params.get("ln1"), cfg.norm_kind)
        attn_out, _ = L.attention_forward(block_params["attn"], x, cfg, positions)
        h = h + attn_out
        x = L.apply_norm(h, block_params.get("ln2"), cfg.norm_kind)
        aux = jnp.zeros((), jnp.float32)
        if cfg.num_experts:
            moe_out, aux = L.moe_forward(block_params["moe"], x, cfg)
            if cfg.moe_dense_residual:
                moe_out = moe_out + L.mlp_forward(block_params["dense"], x, cfg)
            h = h + moe_out
        else:
            h = h + L.mlp_forward(block_params["mlp"], x, cfg)
        # pin the residual stream once per block: stops GSPMD propagation
        # flip-flopping between layers (saves per-layer reshard collectives)
        h = L.shard_hint(h, "batch", None, None)
        return h, aux

    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        h = params["embed"][tokens] * float(np.sqrt(cfg.d_model))
        if cfg.frontend == "vit_stub":
            prefix = batch["patch_embeds"].astype(cfg.dtype) @ params["vit_proj"]
            h = jnp.concatenate([prefix, h], axis=1)
        return h.astype(cfg.dtype)

    # ---------------------------------------------------------- forward
    def forward(self, params, batch):
        """Returns (logits (B, S_total, V), aux_loss)."""
        cfg = self.cfg
        h = self._embed_inputs(params, batch)
        s_total = h.shape[1]
        positions = jnp.arange(s_total)[None, :]

        def body(carry, block_params):
            hh, aux = carry
            hh, a = self._block(hh, block_params, positions)
            return (hh, aux + a), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        (h, aux), _ = jax.lax.scan(
            body_fn,
            (h, jnp.zeros((), jnp.float32)),
            params["blocks"],
            unroll=cfg.layer_unroll(cfg.num_layers),
        )
        h = L.apply_norm(h, params.get("ln_f"), cfg.norm_kind)
        head = (
            params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        )
        logits = L.shard_hint(
            jnp.einsum("bse,ev->bsv", h, head), "batch", None, "vocab"
        )
        return logits, aux / cfg.num_layers

    def loss(self, params, batch):
        cfg = self.cfg
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        if cfg.frontend == "vit_stub":
            # prefix positions carry no next-token loss
            logits = logits[:, -labels.shape[1] :]
        return L.vocab_parallel_ce(logits, labels) + 0.01 * aux

    # ------------------------------------------------------------ serve
    def prefill(self, params, batch, max_len: int | None = None):
        """Full forward + KV-cache build. Returns (last_logits, cache).

        max_len: total cache capacity (>= prompt length); decode steps
        write at cache["index"], so headroom must be preallocated here."""
        cfg = self.cfg
        h = self._embed_inputs(params, batch)
        s_total = h.shape[1]
        positions = jnp.arange(s_total)[None, :]

        def body(hh, block_params):
            x = L.apply_norm(hh, block_params.get("ln1"), cfg.norm_kind)
            attn_out, (k, v) = L.attention_forward(
                block_params["attn"], x, cfg, positions
            )
            hh = hh + attn_out
            x = L.apply_norm(hh, block_params.get("ln2"), cfg.norm_kind)
            if cfg.num_experts:
                moe_out, _ = L.moe_forward(block_params["moe"], x, cfg)
                if cfg.moe_dense_residual:
                    moe_out = moe_out + L.mlp_forward(block_params["dense"], x, cfg)
                hh = hh + moe_out
            else:
                hh = hh + L.mlp_forward(block_params["mlp"], x, cfg)
            return hh, (k, v)

        h, (ks, vs) = jax.lax.scan(
            body, h, params["blocks"], unroll=cfg.layer_unroll(cfg.num_layers)
        )
        h = L.apply_norm(h, params.get("ln_f"), cfg.norm_kind)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("be,ev->bv", h[:, -1], head)
        if max_len is not None and max_len > s_total:
            pad = ((0, 0), (0, 0), (0, max_len - s_total), (0, 0), (0, 0))
            ks = jnp.pad(ks, pad)
            vs = jnp.pad(vs, pad)
        return logits, {"k": ks, "v": vs, "index": jnp.asarray(s_total, jnp.int32)}

    def decode_step(self, params, cache, tokens):
        """tokens (B, 1) -> (logits (B, V), new cache).  Scan over layers."""
        cfg = self.cfg
        h = (params["embed"][tokens] * float(np.sqrt(cfg.d_model))).astype(cfg.dtype)
        idx = cache["index"]

        def body(hh, inputs):
            block_params, ck, cv = inputs
            x = L.apply_norm(hh, block_params.get("ln1"), cfg.norm_kind)
            attn_out, ck, cv = L.attention_decode(
                block_params["attn"], x, ck, cv, idx, cfg
            )
            hh = hh + attn_out
            x = L.apply_norm(hh, block_params.get("ln2"), cfg.norm_kind)
            if cfg.num_experts:
                moe_out, _ = L.moe_forward(block_params["moe"], x, cfg)
                if cfg.moe_dense_residual:
                    moe_out = moe_out + L.mlp_forward(block_params["dense"], x, cfg)
                hh = hh + moe_out
            else:
                hh = hh + L.mlp_forward(block_params["mlp"], x, cfg)
            return hh, (ck, cv)

        h, (ks, vs) = jax.lax.scan(
            body,
            h,
            (params["blocks"], cache["k"], cache["v"]),
            unroll=cfg.layer_unroll(cfg.num_layers),
        )
        h = L.apply_norm(h, params.get("ln_f"), cfg.norm_kind)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("be,ev->bv", h[:, -1], head)
        return logits, {"k": ks, "v": vs, "index": idx + 1}

    # ------------------------------------------------------------ specs
    def input_specs(self, shape: ShapeConfig):
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if cfg.frontend == "vit_stub":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.num_prefix_tokens, cfg.frontend_dim), jnp.float32
            )
        return specs

    def decode_specs(self, shape: ShapeConfig):
        """ShapeDtypeStructs of (cache, tokens) for serve_step lowering."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        kv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
        cache = {
            "k": jax.ShapeDtypeStruct(
                (cfg.num_layers, b, s, kv, dh), cfg.dtype
            ),
            "v": jax.ShapeDtypeStruct(
                (cfg.num_layers, b, s, kv, dh), cfg.dtype
            ),
            "index": jax.ShapeDtypeStruct((), jnp.int32),
        }
        tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        return cache, tokens

    def cache_logical_axes(self):
        kv_axes = ("layers", "batch", "cache_seq", "kv", "head_dim")
        return {"k": kv_axes, "v": kv_axes, "index": ()}
