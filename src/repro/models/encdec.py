"""Encoder-decoder transformer (seamless-m4t backbone).

Per the assignment the modality frontend is a STUB: `input_specs()` supplies
precomputed audio frame embeddings (B, S_enc, frontend_dim); a single linear
projection maps them into the encoder width.  Encoder = bidirectional
self-attention blocks; decoder = causal self-attention + cross-attention.
Decode shapes lower `decode_step` with a self-attn KV cache plus the
precomputed cross-attention K/V of the encoded source.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.config import ModelConfig, ShapeConfig


def _cross_attention_init(rng, cfg):
    return L.attention_init(rng, cfg)


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.is_encoder_decoder
        self.cfg = cfg

    def init(self, rng):
        cfg = self.cfg
        r_front, r_enc, r_dec, r_embed, r_head = jax.random.split(rng, 5)

        def enc_block(r):
            ra, rm = jax.random.split(r)
            p, a = {}, {}
            p["attn"], a["attn"] = L.attention_init(ra, cfg)
            p["mlp"], a["mlp"] = L.mlp_init(rm, cfg)
            p["ln1"], a["ln1"] = L.norm_init(cfg)
            p["ln2"], a["ln2"] = L.norm_init(cfg)
            return p, a

        def dec_block(r):
            ra, rc, rm = jax.random.split(r, 3)
            p, a = {}, {}
            p["self_attn"], a["self_attn"] = L.attention_init(ra, cfg)
            p["cross_attn"], a["cross_attn"] = _cross_attention_init(rc, cfg)
            p["mlp"], a["mlp"] = L.mlp_init(rm, cfg)
            p["ln1"], a["ln1"] = L.norm_init(cfg)
            p["ln2"], a["ln2"] = L.norm_init(cfg)
            p["ln3"], a["ln3"] = L.norm_init(cfg)
            return p, a

        def stack(r, n, fn):
            rr = jax.random.split(r, n)
            per = [fn(x) for x in rr]
            p = jax.tree.map(lambda *xs: jnp.stack(xs), *[q for q, _ in per])
            a = jax.tree.map(
                lambda ax: ("layers",) + ax,
                per[0][1],
                is_leaf=lambda t: isinstance(t, tuple),
            )
            return p, a

        enc_p, enc_a = stack(r_enc, cfg.enc_layers, enc_block)
        dec_p, dec_a = stack(r_dec, cfg.num_layers, dec_block)
        params = {
            "frontend": jax.random.normal(
                r_front, (cfg.frontend_dim, cfg.d_model), cfg.dtype
            )
            * 0.02,
            "encoder": enc_p,
            "decoder": dec_p,
            "embed": jax.random.normal(
                r_embed, (cfg.vocab_size, cfg.d_model), cfg.dtype
            )
            * 0.02,
            "ln_enc": L.norm_init(cfg)[0],
            "ln_dec": L.norm_init(cfg)[0],
            "lm_head": jax.random.normal(
                r_head, (cfg.d_model, cfg.vocab_size), cfg.dtype
            )
            * 0.02,
        }
        axes = {
            "frontend": (None, "embed"),
            "encoder": enc_a,
            "decoder": dec_a,
            "embed": ("vocab", "embed"),
            "ln_enc": L.norm_init(cfg)[1],
            "ln_dec": L.norm_init(cfg)[1],
            "lm_head": ("embed", "vocab"),
        }
        return params, axes

    # ------------------------------------------------------------ encode
    def encode(self, params, frames):
        cfg = self.cfg
        h = (frames.astype(cfg.dtype) @ params["frontend"]).astype(cfg.dtype)
        positions = jnp.arange(h.shape[1])[None, :]

        def body(hh, bp):
            x = L.apply_norm(hh, bp.get("ln1"), cfg.norm_kind)
            attn, _ = L.attention_forward(
                bp["attn"], x, cfg, positions, bidirectional=True
            )
            hh = hh + attn
            x = L.apply_norm(hh, bp.get("ln2"), cfg.norm_kind)
            return hh + L.mlp_forward(bp["mlp"], x, cfg), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        h, _ = jax.lax.scan(
            body_fn, h, params["encoder"],
            unroll=cfg.layer_unroll(cfg.enc_layers),
        )
        return L.apply_norm(h, params.get("ln_enc"), cfg.norm_kind)

    def _cross_attend(self, bp, x, enc_out, cfg):
        q = jnp.einsum("bse,ehd->bshd", x, bp["cross_attn"]["wq"])
        k = jnp.einsum("bse,ekd->bskd", enc_out, bp["cross_attn"]["wk"])
        v = jnp.einsum("bse,ekd->bskd", enc_out, bp["cross_attn"]["wv"])
        kr = L._repeat_kv(k, cfg.num_heads)
        vr = L._repeat_kv(v, cfg.num_heads)
        scale = 1.0 / float(np.sqrt(q.shape[-1]))
        sc = jnp.einsum("bqhd,bkhd->bhqk", q, kr) * scale
        probs = jax.nn.softmax(sc.astype(jnp.float32), -1).astype(x.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, vr)
        return jnp.einsum("bshd,hde->bse", out, bp["cross_attn"]["wo"])

    # ------------------------------------------------------------ decode
    def forward(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        h = (params["embed"][tokens] * float(np.sqrt(cfg.d_model))).astype(cfg.dtype)
        positions = jnp.arange(tokens.shape[1])[None, :]

        def body(hh, bp):
            x = L.apply_norm(hh, bp.get("ln1"), cfg.norm_kind)
            attn, _ = L.attention_forward(bp["self_attn"], x, cfg, positions)
            hh = hh + attn
            x = L.apply_norm(hh, bp.get("ln2"), cfg.norm_kind)
            hh = hh + self._cross_attend(bp, x, enc_out, cfg)
            x = L.apply_norm(hh, bp.get("ln3"), cfg.norm_kind)
            return hh + L.mlp_forward(bp["mlp"], x, cfg), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        h, _ = jax.lax.scan(
            body_fn, h, params["decoder"],
            unroll=cfg.layer_unroll(cfg.num_layers),
        )
        h = L.apply_norm(h, params.get("ln_dec"), cfg.norm_kind)
        logits = L.shard_hint(
            jnp.einsum("bse,ev->bsv", h, params["lm_head"]),
            "batch", None, "vocab",
        )
        return logits, jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch)
        return L.vocab_parallel_ce(logits, batch["labels"])

    def decode_step(self, params, cache, tokens):
        """Single-token decode with self-attn KV cache + fixed cross K/V."""
        cfg = self.cfg
        h = (params["embed"][tokens] * float(np.sqrt(cfg.d_model))).astype(cfg.dtype)
        idx = cache["index"]

        def body(hh, inputs):
            bp, ck, cv, xk, xv = inputs
            x = L.apply_norm(hh, bp.get("ln1"), cfg.norm_kind)
            attn, ck, cv = L.attention_decode(bp["self_attn"], x, ck, cv, idx, cfg)
            hh = hh + attn
            x = L.apply_norm(hh, bp.get("ln2"), cfg.norm_kind)
            # cross-attention against precomputed enc K/V
            q = jnp.einsum("bse,ehd->bshd", x, bp["cross_attn"]["wq"])
            kr = L._repeat_kv(xk, cfg.num_heads)
            vr = L._repeat_kv(xv, cfg.num_heads)
            scale = 1.0 / float(np.sqrt(q.shape[-1]))
            sc = jnp.einsum("bqhd,bkhd->bhqk", q, kr) * scale
            probs = jax.nn.softmax(sc.astype(jnp.float32), -1).astype(x.dtype)
            out = jnp.einsum("bhqk,bkhd->bqhd", probs, vr)
            hh = hh + jnp.einsum("bshd,hde->bse", out, bp["cross_attn"]["wo"])
            x = L.apply_norm(hh, bp.get("ln3"), cfg.norm_kind)
            hh = hh + L.mlp_forward(bp["mlp"], x, cfg)
            return hh, (ck, cv)

        h, (ks, vs) = jax.lax.scan(
            body,
            h,
            (
                params["decoder"],
                cache["k"],
                cache["v"],
                cache["cross_k"],
                cache["cross_v"],
            ),
            unroll=cfg.layer_unroll(cfg.num_layers),
        )
        h = L.apply_norm(h, params.get("ln_dec"), cfg.norm_kind)
        logits = jnp.einsum("be,ev->bv", h[:, -1], params["lm_head"])
        return logits, dict(cache, k=ks, v=vs, index=idx + 1)

    # ------------------------------------------------------------ specs
    def input_specs(self, shape: ShapeConfig):
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        specs = {
            "frames": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), jnp.float32),
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return specs

    def decode_specs(self, shape: ShapeConfig):
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        kv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
        nl = cfg.num_layers
        cache = {
            "k": jax.ShapeDtypeStruct((nl, b, s, kv, dh), cfg.dtype),
            "v": jax.ShapeDtypeStruct((nl, b, s, kv, dh), cfg.dtype),
            "cross_k": jax.ShapeDtypeStruct((nl, b, s, kv, dh), cfg.dtype),
            "cross_v": jax.ShapeDtypeStruct((nl, b, s, kv, dh), cfg.dtype),
            "index": jax.ShapeDtypeStruct((), jnp.int32),
        }
        return cache, jax.ShapeDtypeStruct((b, 1), jnp.int32)

    def cache_logical_axes(self):
        kv_axes = ("layers", "batch", "cache_seq", "kv", "head_dim")
        return {
            "k": kv_axes,
            "v": kv_axes,
            "cross_k": kv_axes,
            "cross_v": kv_axes,
            "index": (),
        }
