"""xLSTM: alternating mLSTM (matrix memory, chunk-parallel) and sLSTM
(scalar memory, sequential scan) blocks — arXiv:2405.04517.

Blocks are grouped for scan-friendliness: each group is
(slstm_every - 1) mLSTM blocks + 1 sLSTM block; `num_layers` must divide.

mLSTM recurrence per head (stabilized, log-space forget gates):

    C_t = f_t C_{t-1} + i_t k_t v_t^T      n_t = f_t n_{t-1} + i_t k_t
    y_t = (q_t^T C_t) / max(|q_t^T n_t|, 1)

evaluated chunk-parallel exactly like SSD (cumulative log-decay weights
within a chunk, carried (C, n) across chunks).  No KV cache ever exists —
decode state is O(H dqk dv) per sequence, which is what makes the
`long_500k` cell feasible (DESIGN.md §2.4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.config import ModelConfig, ShapeConfig

CHUNK = 256
DQK = 256  # per-head query/key dim (value dim = d_inner / heads)


def _mlstm_init(rng, cfg):
    e = cfg.d_model
    d_inner = 2 * e
    h = cfg.num_heads
    dv = d_inner // h
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(rng, 7)
    sd = 1.0 / float(np.sqrt(e))
    params = {
        "up": jax.random.normal(k1, (e, d_inner), cfg.dtype) * sd,
        "gate": jax.random.normal(k2, (e, d_inner), cfg.dtype) * sd,
        "wq": jax.random.normal(k3, (d_inner, h, DQK), cfg.dtype) * sd,
        "wk": jax.random.normal(k4, (d_inner, h, DQK), cfg.dtype) * sd,
        "wif": jax.random.normal(k5, (e, 2 * h), cfg.dtype) * sd,
        "if_bias": jnp.concatenate(
            [jnp.zeros((h,)), 3.0 * jnp.ones((h,))]
        ).astype(jnp.float32),
        "down": jax.random.normal(k6, (d_inner, e), cfg.dtype)
        * sd
        / float(np.sqrt(cfg.num_layers)),
        "ln": {"scale": jnp.zeros((e,), cfg.dtype)},
    }
    axes = {
        "up": ("embed", "mlp"),
        "gate": ("embed", "mlp"),
        "wq": ("mlp", "heads", "head_dim"),
        "wk": ("mlp", "heads", "head_dim"),
        "wif": ("embed", "heads"),
        "if_bias": ("heads",),
        "down": ("mlp", "embed"),
        "ln": {"scale": ("embed",)},
    }
    return params, axes


def _slstm_init(rng, cfg):
    e = cfg.d_model
    h = cfg.num_heads
    dh = e // h
    k1, k2, k3 = jax.random.split(rng, 3)
    sd = 1.0 / float(np.sqrt(e))
    params = {
        # fused gates: [z, i, f, o] per head
        "wz": jax.random.normal(k1, (e, 4, h, dh), cfg.dtype) * sd,
        "rz": jax.random.normal(k2, (h, dh, 4, dh), cfg.dtype) * sd,
        "bias": jnp.zeros((4, h, dh), jnp.float32),
        "down": jax.random.normal(k3, (e, e), cfg.dtype) * sd / float(np.sqrt(cfg.num_layers)),
        "ln": {"scale": jnp.zeros((e,), cfg.dtype)},
    }
    axes = {
        "wz": ("embed", None, "heads", "head_dim"),
        "rz": ("heads", "head_dim", None, "head_dim"),
        "bias": (None, "heads", "head_dim"),
        "down": ("embed", "embed2"),
        "ln": {"scale": ("embed",)},
    }
    return params, axes


def _mlstm_chunked(q, k, v, log_f, log_i, state=None, chunk=CHUNK, unroll=False):
    """q,k: (B,S,H,DQK), v: (B,S,H,DV), log_f/log_i: (B,S,H) in log space.

    Returns (y, (C, n)) with C (B,H,DQK,DV), n (B,H,DQK)."""
    b, s, h, dqk = q.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    nc = s // c
    qc = q.reshape(b, nc, c, h, dqk)
    kc = k.reshape(b, nc, c, h, dqk)
    vc = v.reshape(b, nc, c, h, dv)
    fc = log_f.reshape(b, nc, c, h)
    ic = log_i.reshape(b, nc, c, h)
    lcum = jnp.cumsum(fc, axis=2)  # inclusive cumulative log forget
    tri = jnp.tril(jnp.ones((c, c), bool))

    def step(carry, inputs):
        C_prev, n_prev = carry
        q_k, k_k, v_k, l_k, i_k = inputs
        # intra: w[b,i,j,h] = exp(l_i - l_j + log_i_j) (q_i . k_j), i >= j
        scores = jnp.einsum("bihd,bjhd->bijh", q_k, k_k) / float(np.sqrt(dqk))
        logw = jnp.clip(l_k[:, :, None, :] - l_k[:, None, :, :] + i_k[:, None, :, :], -60.0, 20.0)
        w = scores * jnp.exp(logw) * jnp.where(tri[None, ..., None], 1.0, 0.0)
        y_intra = jnp.einsum("bijh,bjhv->bihv", w, v_k)
        norm_intra = jnp.einsum("bijh,bjhd->bihd", w, k_k)
        # inter
        decay_i = jnp.exp(jnp.clip(l_k, -60.0, 20.0))  # (B, c, H)
        y_inter = jnp.einsum("bihd,bih,bhdv->bihv", q_k, decay_i, C_prev) / float(np.sqrt(dqk))
        n_inter = jnp.einsum("bihd,bih,bhd->bih", q_k, decay_i, n_prev) / float(np.sqrt(dqk))
        # denom: |q . n_total| with n_total tracked via k-sums
        n_intra = jnp.einsum("bihd,bihd->bih", q_k, norm_intra) / float(np.sqrt(dqk))
        denom = jnp.maximum(jnp.abs(n_intra + n_inter), 1.0)[..., None]
        y = (y_intra + y_inter) / denom
        # carry update
        total = l_k[:, -1, :]
        cd = jnp.exp(jnp.clip(total[:, None, :] - l_k + i_k, -60.0, 20.0))  # (B,c,H)
        C_new = jnp.exp(jnp.clip(total, -60.0, 20.0))[:, :, None, None] * C_prev + jnp.einsum(
            "bjh,bjhd,bjhv->bhdv", cd, k_k, v_k
        )
        n_new = jnp.exp(jnp.clip(total, -60.0, 20.0))[:, :, None] * n_prev + jnp.einsum(
            "bjh,bjhd->bhd", cd, k_k
        )
        return (C_new, n_new), y

    if state is None:
        C0 = jnp.zeros((b, h, dqk, dv), jnp.float32)
        n0 = jnp.zeros((b, h, dqk), jnp.float32)
    else:
        C0, n0 = state
    swap = lambda t: jnp.swapaxes(t, 0, 1)
    (C, n), ys = jax.lax.scan(
        step,
        (C0, n0),
        (swap(qc), swap(kc), swap(vc), swap(lcum), swap(ic)),
        # NOT unrolled in dry-run costing (same rationale as mamba2: the
        # intra-chunk part is ~1-2% of block FLOPs; unrolling 48x16 bodies
        # explodes compile time)
        unroll=1,
    )
    return jnp.swapaxes(ys, 0, 1).reshape(b, s, h, dv).astype(q.dtype), (C, n)


def mlstm_forward(params, hidden, cfg, state=None):
    b, s, e = hidden.shape
    d_inner = 2 * e
    h = cfg.num_heads
    dv = d_inner // h
    x = L.rms_norm(hidden, params["ln"]["scale"])
    up = x @ params["up"]
    z = jax.nn.silu(x @ params["gate"])
    q = jnp.einsum("bsd,dhk->bshk", up, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", up, params["wk"])
    v = up.reshape(b, s, h, dv)
    gates = (x @ params["wif"]).astype(jnp.float32) + params["if_bias"]
    log_i, f_raw = jnp.split(gates, 2, axis=-1)  # (B,S,H) each
    log_f = jax.nn.log_sigmoid(f_raw)
    if s == 1 and state is not None:
        C_prev, n_prev = state
        f = jnp.exp(log_f[:, 0])
        i = jnp.exp(jnp.clip(log_i[:, 0], -60.0, 20.0))
        C = f[..., None, None] * C_prev + i[..., None, None] * jnp.einsum(
            "bhd,bhv->bhdv", k[:, 0], v[:, 0]
        )
        n = f[..., None] * n_prev + i[..., None] * k[:, 0]
        num = jnp.einsum("bhd,bhdv->bhv", q[:, 0], C) / float(np.sqrt(DQK))
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhd,bhd->bh", q[:, 0], n)) / float(np.sqrt(DQK)), 1.0
        )
        y = (num / den[..., None])[:, None].astype(hidden.dtype)
        new_state = (C, n)
    else:
        y, new_state = _mlstm_chunked(
            q, k, v, log_f, log_i, state,
            chunk=cfg.ssm_chunk, unroll=cfg.unroll_scans,
        )
    y = y.reshape(b, s, d_inner)
    return hidden + (y * z) @ params["down"], new_state


def slstm_forward(params, hidden, cfg, state=None):
    """Sequential scalar-memory LSTM with exponential gating."""
    b, s, e = hidden.shape
    h = cfg.num_heads
    dh = e // h
    x = L.rms_norm(hidden, params["ln"]["scale"])
    zs = jnp.einsum("bse,eghd->bsghd", x, params["wz"]).astype(jnp.float32)

    def step(carry, z_t):
        c_prev, n_prev, h_prev, m_prev = carry
        rec = jnp.einsum("bhd,hdgk->bghk", h_prev, params["rz"].astype(jnp.float32))
        g = z_t + rec + params["bias"]
        z_g, i_g, f_g, o_g = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
        log_f = jax.nn.log_sigmoid(f_g)
        m_new = jnp.maximum(log_f + m_prev, i_g)
        i_s = jnp.exp(i_g - m_new)
        f_s = jnp.exp(log_f + m_prev - m_new)
        c_new = f_s * c_prev + i_s * jnp.tanh(z_g)
        n_new = f_s * n_prev + i_s
        h_new = jax.nn.sigmoid(o_g) * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, h_new, m_new), h_new

    if state is None:
        zero = jnp.zeros((b, h, dh), jnp.float32)
        state = (zero, zero, zero, zero - 10.0)
    state, ys = jax.lax.scan(step, state, jnp.swapaxes(zs, 0, 1))
    y = jnp.swapaxes(ys, 0, 1).reshape(b, s, e).astype(hidden.dtype)
    return hidden + y @ params["down"], state


class XLSTM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.num_layers % cfg.slstm_every == 0
        self.cfg = cfg
        self.groups = cfg.num_layers // cfg.slstm_every
        self.m_per_group = cfg.slstm_every - 1

    def init(self, rng):
        cfg = self.cfg
        r_embed, r_m, r_s, r_head = jax.random.split(rng, 4)

        def group_m(r):
            rr = jax.random.split(r, self.m_per_group)
            per = [_mlstm_init(x, cfg) for x in rr]
            p = jax.tree.map(lambda *xs: jnp.stack(xs), *[q for q, _ in per])
            a = jax.tree.map(
                lambda ax: ("layers",) + ax,
                per[0][1],
                is_leaf=lambda t: isinstance(t, tuple),
            )
            return p, a

        rg = jax.random.split(r_m, self.groups)
        per_g = [group_m(x) for x in rg]
        mparams = jax.tree.map(lambda *xs: jnp.stack(xs), *[p for p, _ in per_g])
        maxes = jax.tree.map(
            lambda ax: ("layers",) + ax,
            per_g[0][1],
            is_leaf=lambda t: isinstance(t, tuple),
        )
        rs = jax.random.split(r_s, self.groups)
        per_s = [_slstm_init(x, cfg) for x in rs]
        sparams = jax.tree.map(lambda *xs: jnp.stack(xs), *[p for p, _ in per_s])
        saxes = jax.tree.map(
            lambda ax: ("layers",) + ax,
            per_s[0][1],
            is_leaf=lambda t: isinstance(t, tuple),
        )
        params = {
            "embed": jax.random.normal(
                r_embed, (cfg.vocab_size, cfg.d_model), cfg.dtype
            )
            * 0.02,
            "mlstm": mparams,
            "slstm": sparams,
            "ln_f": {"scale": jnp.zeros((cfg.d_model,), cfg.dtype)},
            "lm_head": jax.random.normal(
                r_head, (cfg.d_model, cfg.vocab_size), cfg.dtype
            )
            * 0.02,
        }
        axes = {
            "embed": ("vocab", "embed"),
            "mlstm": maxes,
            "slstm": saxes,
            "ln_f": {"scale": ("embed",)},
            "lm_head": ("embed", "vocab"),
        }
        return params, axes

    def forward(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        h = (params["embed"][tokens] * float(np.sqrt(cfg.d_model))).astype(cfg.dtype)

        def group(hh, gp):
            m_gp, s_gp = gp

            def mlayer(hhh, lp):
                hhh, _ = mlstm_forward(lp, hhh, cfg)
                return hhh, None

            mfn = jax.checkpoint(mlayer) if cfg.remat else mlayer
            hh, _ = jax.lax.scan(
                mfn, hh, m_gp, unroll=cfg.layer_unroll(self.m_per_group)
            )
            hh, _ = slstm_forward(s_gp, hh, cfg)
            return hh, None

        h, _ = jax.lax.scan(
            group,
            h,
            (params["mlstm"], params["slstm"]),
            unroll=cfg.layer_unroll(self.groups),
        )
        h = L.rms_norm(h, params["ln_f"]["scale"])
        logits = L.shard_hint(
            jnp.einsum("bse,ev->bsv", h, params["lm_head"]),
            "batch", None, "vocab",
        )
        return logits, jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch)
        return L.vocab_parallel_ce(logits, batch["labels"])

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        h = (params["embed"][tokens] * float(np.sqrt(cfg.d_model))).astype(cfg.dtype)

        def group(hh, inputs):
            m_gp, s_gp, mC, mn, sc = inputs

            def mlayer(hhh, lp_state):
                lp, C, n = lp_state
                hhh, (C, n) = mlstm_forward(lp, hhh, cfg, state=(C, n))
                return hhh, (C, n)

            hh, (mC, mn) = jax.lax.scan(
                mlayer, hh, (m_gp, mC, mn),
                unroll=cfg.layer_unroll(self.m_per_group),
            )
            hh, sc = slstm_forward(s_gp, hh, cfg, state=tuple(sc))
            return hh, (mC, mn, jnp.stack(sc))

        h, (mC, mn, sc) = jax.lax.scan(
            group,
            h,
            (
                params["mlstm"],
                params["slstm"],
                cache["mC"],
                cache["mn"],
                cache["slstm"],
            ),
            unroll=cfg.layer_unroll(self.groups),
        )
        h = L.rms_norm(h, params["ln_f"]["scale"])
        logits = jnp.einsum("be,ev->bv", h[:, -1], params["lm_head"])
        return logits, {
            "mC": mC,
            "mn": mn,
            "slstm": sc,
            "index": cache["index"] + 1,
        }

    def input_specs(self, shape: ShapeConfig):
        b, s = shape.global_batch, shape.seq_len
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return specs

    def decode_specs(self, shape: ShapeConfig):
        cfg = self.cfg
        b = shape.global_batch
        e = cfg.d_model
        h = cfg.num_heads
        dv = 2 * e // h
        dh = e // h
        g, m = self.groups, self.m_per_group
        cache = {
            "mC": jax.ShapeDtypeStruct((g, m, b, h, DQK, dv), jnp.float32),
            "mn": jax.ShapeDtypeStruct((g, m, b, h, DQK), jnp.float32),
            "slstm": jax.ShapeDtypeStruct((g, 4, b, h, dh), jnp.float32),
            "index": jax.ShapeDtypeStruct((), jnp.int32),
        }
        return cache, jax.ShapeDtypeStruct((b, 1), jnp.int32)

    def cache_logical_axes(self):
        return {
            "mC": ("layers", "layers2", "batch", "heads", None, None),
            "mn": ("layers", "layers2", "batch", "heads", None),
            "slstm": ("layers", None, "batch", "heads", "head_dim"),
            "index": (),
        }
