"""Gradient compression for DP all-reduce: int8 quantization with error
feedback (EF-SGD style).  Used optionally by the trainer (off by default;
quantified in EXPERIMENTS.md §Perf): the all-reduce payload drops 4x
(f32 -> int8 + one f32 scale per tensor), and the quantization error is
carried to the next step so the compressed SGD remains convergent.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x):
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def ef_allreduce(grads, error_state, axis_name=None):
    """Error-feedback compressed all-reduce over `axis_name`.

    grads/error_state: matching pytrees.  Returns (reduced_grads,
    new_error_state).  With axis_name=None (single host) the collective is
    the identity — the quantize/dequantize path still runs so the error
    dynamics are testable anywhere.
    """

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = compress_int8(corrected)
        deq = decompress_int8(q, scale)
        new_e = corrected - deq
        if axis_name is not None:
            deq = jax.lax.pmean(deq, axis_name)
        return deq.astype(g.dtype), new_e

    out = jax.tree.map(one, grads, error_state)
    reduced = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return reduced, new_err


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
