"""Optimizers: AdamW (fp32 moments) and Adafactor-style factored second
moment (the memory-extreme option that lets arctic-480b fit 16 GB/chip —
see DESIGN.md §2.3).  Pure-pytree implementations, pjit/FSDP friendly:
optimizer state mirrors the parameter sharding (same logical axes), so
ZeRO-style sharding falls out of the normal out_shardings.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    kind: str = "adamw"  # adamw | adafactor
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: OptimConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm_clip(grads, max_norm):
    norm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


# ------------------------------------------------------------------ AdamW
def adamw_init(params):
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros32, params),
        "nu": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, state, params, cfg: OptimConfig):
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    b1, b2 = cfg.betas
    grads, gnorm = global_norm_clip(grads, cfg.clip_norm)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * g32 * g32
        m_hat = m_new / (1 - b1 ** step.astype(jnp.float32))
        v_hat = v_new / (1 - b2 ** step.astype(jnp.float32))
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mu": mu, "nu": nu, "step": step}, {"grad_norm": gnorm, "lr": lr}


# -------------------------------------------------------------- Adafactor
def _factored_dims(shape):
    """Last two non-trivial dims, or None for vectors/scalars."""
    if len(shape) < 2:
        return None
    return len(shape) - 2, len(shape) - 1


def adafactor_init(params):
    def init_one(p):
        dims = _factored_dims(p.shape)
        if dims is None:
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        r, c = dims
        row_shape = tuple(d for i, d in enumerate(p.shape) if i != c)
        col_shape = tuple(d for i, d in enumerate(p.shape) if i != r)
        return {
            "vr": jnp.zeros(row_shape, jnp.float32),
            "vc": jnp.zeros(col_shape, jnp.float32),
        }

    return {
        "v": jax.tree.map(init_one, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(grads, state, params, cfg: OptimConfig):
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8
    grads, gnorm = global_norm_clip(grads, cfg.clip_norm)

    def upd(g, v, p):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + 1e-30
        dims = _factored_dims(p.shape)
        if dims is None:
            v_new = {"v": decay * v["v"] + (1 - decay) * g2}
            precond = g32 / (jnp.sqrt(v_new["v"]) + cfg.eps)
        else:
            r, c = dims
            vr = decay * v["vr"] + (1 - decay) * jnp.mean(g2, axis=c)
            vc = decay * v["vc"] + (1 - decay) * jnp.mean(g2, axis=r)
            v_new = {"vr": vr, "vc": vc}
            rmean = jnp.mean(vr, axis=-1, keepdims=True)
            rfac = jnp.expand_dims(vr / jnp.maximum(rmean, 1e-30), c)
            cfac = jnp.expand_dims(vc, r)
            precond = g32 * jax.lax.rsqrt(rfac * cfac + cfg.eps)
        delta = precond + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), v_new

    leaves_is = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
    out = jax.tree.map(upd, grads, state["v"], params, is_leaf=None)
    new_params = jax.tree.map(
        lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    v = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    del leaves_is
    return new_params, {"v": v, "step": step}, {"grad_norm": gnorm, "lr": lr}


def make_optimizer(cfg: OptimConfig):
    if cfg.kind == "adamw":
        return adamw_init, partial(adamw_update, cfg=cfg)
    if cfg.kind == "adafactor":
        return adafactor_init, partial(adafactor_update, cfg=cfg)
    raise ValueError(cfg.kind)
