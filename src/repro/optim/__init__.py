from repro.optim.optimizers import (
    OptimConfig,
    adamw_init,
    adamw_update,
    adafactor_init,
    adafactor_update,
    make_optimizer,
    cosine_schedule,
    global_norm_clip,
)
from repro.optim.compression import compress_int8, decompress_int8, ef_allreduce

__all__ = [
    "OptimConfig",
    "adamw_init",
    "adamw_update",
    "adafactor_init",
    "adafactor_update",
    "make_optimizer",
    "cosine_schedule",
    "global_norm_clip",
    "compress_int8",
    "decompress_int8",
    "ef_allreduce",
]
