"""Greedy Equivalence Search (Chickering 2002) driven by a decomposable
local score — paper Sec. 6.

Forward phase: best valid Insert(X, Y, T) until no positive improvement.
Backward phase: best valid Delete(X, Y, H) until no positive improvement.
Operator validity and score deltas follow Chickering's Theorems 15/17:

  Insert(X, Y, T):  X, Y non-adjacent; T subset of undirected neighbors of Y
    not adjacent to X.  Valid iff NA_{Y,X} u T is a clique and every
    semi-directed path Y ~> X crosses NA_{Y,X} u T.
    delta = s(Y, NA u T u Pa_Y u {X}) - s(Y, NA u T u Pa_Y)

  Delete(X, Y, H):  X -> Y or X -- Y; H subset of NA_{Y,X}.
    Valid iff NA_{Y,X} \\ H is a clique.
    delta = s(Y, (NA\\H) u Pa_Y \\ {X}) - s(Y, (NA\\H) u Pa_Y u {X})

Scores are cached inside the scorer (keyed by `score_common.config_key`),
so the search only pays for *new* local configurations.  Before any delta
is computed, each sweep iteration hands the full frontier's (node, parents)
configurations to the scorer's `prefetch` — the batched engine
(score_lowrank.cvlr_scores_batched) evaluates them in a handful of device
dispatches instead of one jit call + host sync per candidate.  This is the
default local path; a scorer whose `prefetch` declines (returns 0 without
caching, e.g. CVLRScorer(batched=False) or the exact CVScorer) falls back
to lazy per-candidate `local_score` — kept as the oracle for tests.
`batch_hook`, when set, overrides `prefetch`; the distributed runtime uses
it to evaluate the frontier on a mesh (repro.core.distributed_score).
User-facing engine selection does not thread hooks any more: a
`repro.core.api.DiscoverySession` (built from
`repro.core.spec.EngineOptions`) passes itself as `session=` and owns the
sweep lifecycle — `begin_sweep` / `score_frontier` / `end_sweep` around
every frontier evaluation — which is also the seam the planned
incremental-frontier-delta optimization needs (a session sees consecutive
frontiers and can diff them).
"""

from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core import graph as g
from repro.core.score_common import config_key
from repro.obs import trace as obs_trace


@dataclasses.dataclass
class GESResult:
    cpdag: np.ndarray
    score: float
    forward_steps: int
    backward_steps: int
    trace: list


def _na_yx(a, y, x):
    """Undirected neighbors of y that are adjacent to x."""
    return frozenset(
        v for v in g.neighbors_undir(a, y) if g.adjacent(a, v, x)
    )


def _subsets(items, max_size=None):
    items = sorted(items)
    hi = len(items) if max_size is None else min(len(items), max_size)
    for k in range(hi + 1):
        yield from itertools.combinations(items, k)


def _forward_pair_candidates(a, x, y, max_subset):
    """Insert(x, y, T) candidates of ONE ordered non-adjacent pair.

    Forward candidates carry their clique set `nat` as a 7th element so
    the frontier-delta cache can re-run the (global) semi-directed path
    filter on carried candidates without re-deriving NA/T — the filter is
    applied here for a fresh enumeration and in `_FrontierDelta` for a
    carried pair; both run the same `semi_directed_blocked` on the same
    (y, x, nat) arguments, so carried == re-enumerated exactly.
    """
    if g.adjacent(a, x, y):
        return
    na = _na_yx(a, y, x)
    t_pool = [
        v
        for v in g.neighbors_undir(a, y)
        if not g.adjacent(a, v, x) and v != x
    ]
    pa_y = frozenset(g.parents(a, y))
    for t in _subsets(t_pool, max_subset):
        nat = na | frozenset(t)
        if not g.is_clique(a, nat):
            continue
        base = nat | pa_y
        yield ("insert", x, y, frozenset(t), base | {x}, base, nat)


def _backward_pair_candidates(a, x, y, max_subset):
    """Delete(x, y, H) candidates of ONE ordered pair with x -> y or
    x -- y.  Backward validity is purely local (clique check only — no
    path filter), so carried candidates need no re-filtering; the `nat`
    slot is None."""
    if not (g.has_dir(a, x, y) or g.has_undir(a, x, y)):
        return
    na = _na_yx(a, y, x)
    pa_y = frozenset(g.parents(a, y))
    for h in _subsets(na, max_subset):
        rest = na - frozenset(h)
        if not g.is_clique(a, rest):
            continue
        base = rest | (pa_y - {x})
        yield ("delete", x, y, frozenset(h), base, base | {x}, None)


_PAIR_GENS = {
    "forward": _forward_pair_candidates,
    "backward": _backward_pair_candidates,
}


def _forward_candidates(a, max_subset, allowed=None):
    """Insert candidates over all ordered pairs; `allowed` (an optional
    (d, d) bool mask — `repro.constraint.EdgeMask.allowed`) gates which
    pairs may enter the frontier at all."""
    d = a.shape[0]
    for x, y in itertools.permutations(range(d), 2):
        if allowed is not None and not allowed[x, y]:
            continue
        for cand in _forward_pair_candidates(a, x, y, max_subset):
            if g.semi_directed_blocked(a, cand[2], cand[1], cand[6]):
                yield cand[:6]


def _backward_candidates(a, max_subset, allowed=None):
    """Delete candidates are NEVER gated: under gated insertions the
    graph's edges are a subset of the mask, and forbidding a delete could
    only pin an edge the mask itself admitted (`allowed` is accepted for
    signature symmetry and ignored)."""
    d = a.shape[0]
    for x, y in itertools.permutations(range(d), 2):
        for cand in _backward_pair_candidates(a, x, y, max_subset):
            yield cand[:6]


def step_incidence(a_prev, a_new) -> frozenset:
    """Nodes whose incident edges changed between consecutive CPDAGs — the
    per-step incidence set the frontier-delta engine diffs against.

    Computed from the actual adjacency diff, NOT from the applied step's
    (x, y, T) arguments: `pdag_to_cpdag` (Dor & Tarsi extension + Meek
    rules R1-R4) can reorient edges far from the insertion point, and any
    such reorientation lands some node in this set by construction."""
    diff = np.asarray(a_prev) != np.asarray(a_new)
    return frozenset(
        int(v) for v in np.flatnonzero(diff.any(axis=0) | diff.any(axis=1))
    )


class _FrontierDelta:
    """Per-pair candidate lists carried across the sweeps of one GES run.

    Invalidation rule (the incidence rule — docs/ARCHITECTURE.md has the
    proof sketch): let T = `step_incidence(a_prev, a_new)`.  An ordered
    pair (x, y) is re-enumerated from scratch iff ``x in T``, ``y in T``,
    or ``nbr(y) & T != {}`` (adjacent-either-way neighbors of y in the
    new graph; y not in T implies nbr(y) is unchanged, so checking the
    new graph covers the old one).  For every other pair, all the local
    ingredients of its candidates — the x~y adjacency gate, NA_{Y,X},
    the T/H pools, Pa_Y, and every clique check (edges among subsets of
    nbr(y)) — are functions of rows of {x, y} u nbr(y) only, all
    untouched, so the cached candidate list is *identical* to what fresh
    enumeration would produce, except for the forward operator's
    semi-directed path filter, which is a global property and is re-run
    per carried candidate.  tests/test_frontier_delta.py property-checks
    the diffed enumeration set-equal to the full one on random step
    sequences.
    """

    def __init__(self, max_subset, allowed=None):
        self.max_subset = max_subset
        # optional (d, d) bool EdgeMask gate: disallowed forward pairs are
        # skipped OUTRIGHT — they never enter pair_cands or the stats, so
        # a gated incremental run does no bookkeeping for pruned pairs
        self.allowed = None if allowed is None else np.asarray(allowed, bool)
        self.phase = None
        self.a_prev = None
        self.pair_cands: dict = {}  # (x, y) -> list of 7-tuples
        self.stats: dict = {}

    def candidates(self, a, phase: str) -> list:
        """The phase's full candidate list for CPDAG `a`, reusing cached
        per-pair lists for pairs the last applied step provably did not
        touch.  Also refreshes `self.stats` (telemetry for the session's
        sweep log): pairs_full / pairs_carried / touched."""
        d = a.shape[0]
        gen = _PAIR_GENS[phase]
        fresh = (
            self.phase != phase
            or self.a_prev is None
            or self.a_prev.shape != a.shape
        )
        if fresh:
            touched = None  # full enumeration
        else:
            touched = step_incidence(self.a_prev, a)
        adj = (np.asarray(a) + np.asarray(a).T) > 0
        cands = []
        n_full = n_carried = 0
        new_pairs = {}
        gated = self.allowed is not None and phase == "forward"
        for x, y in itertools.permutations(range(d), 2):
            if gated and not self.allowed[x, y]:
                continue
            carried = None
            if touched is not None and x not in touched and y not in touched:
                nbr_y = np.flatnonzero(adj[y])
                if not any(int(v) in touched for v in nbr_y):
                    carried = self.pair_cands.get((x, y), ())
            if carried is None:
                pair = list(gen(a, x, y, self.max_subset))
                n_full += 1
            else:
                pair = carried
                n_carried += 1
            new_pairs[(x, y)] = pair
            if phase == "forward":
                cands.extend(
                    c[:6]
                    for c in pair
                    if g.semi_directed_blocked(a, c[2], c[1], c[6])
                )
            else:
                cands.extend(c[:6] for c in pair)
        self.pair_cands = new_pairs
        self.phase = phase
        self.a_prev = np.asarray(a, dtype=np.int8).copy()
        self.stats = {
            "pairs_full": n_full,
            "pairs_carried": n_carried,
            "touched": len(touched) if touched is not None else d,
        }
        return cands


def _apply_insert(a, x, y, t):
    a = a.copy()
    a[x, y] = 1
    a[y, x] = 0
    for v in t:
        a[v, y] = 1
        a[y, v] = 0
    return g.pdag_to_cpdag(a)


def _apply_delete(a, x, y, h):
    a = a.copy()
    a[x, y] = a[y, x] = 0
    for v in h:
        # orient y -- v as y -> v and x -- v as x -> v
        if g.has_undir(a, y, v):
            a[y, v] = 1
            a[v, y] = 0
        if g.has_undir(a, x, v):
            a[x, v] = 1
            a[v, x] = 0
    return g.pdag_to_cpdag(a)


def ges(
    scorer,
    d: int | None = None,
    max_subset: int | None = None,
    batch_hook=None,
    verbose: bool = False,
    session=None,
    state=None,
) -> GESResult:
    """Run GES with the given local scorer (CVScorer / CVLRScorer / ...).

    d: number of variables — inferred from the scorer's view; passing it
    explicitly is only accepted when it agrees (a mismatch used to be
    silently hazardous and now raises).  `session`: a
    `repro.core.api.DiscoverySession` that owns the sweep lifecycle and
    routes frontier scoring by its `EngineOptions` (mutually exclusive
    with the low-level `batch_hook`).

    state: a `repro.core.runstate.RunState` to resume from.  GES is
    replayable: candidate enumeration is a pure function of the CPDAG
    and scoring is deterministic, so re-entering the search with the
    restored CPDAG / phase / applied-step log reproduces the
    uninterrupted run's remaining sweeps exactly — a completed forward
    phase is skipped, `phase == "done"` skips straight to the final
    score.  The returned trace and step counters include the restored
    prefix, so resumed and uninterrupted results compare equal.
    """
    num_vars = getattr(getattr(scorer, "view", None), "num_vars", None)
    if d is None:
        if num_vars is None:
            raise ValueError(
                "ges() needs d= when the scorer has no .view to infer the "
                "variable count from"
            )
        d = num_vars
    elif num_vars is not None and int(d) != num_vars:
        raise ValueError(
            f"ges(d={d}) conflicts with the scorer's view over {num_vars} "
            "variables — drop the d argument, it is inferred from the scorer"
        )
    d = int(d)
    if session is not None and batch_hook is not None:
        raise ValueError("pass either session= or batch_hook=, not both")
    if state is None:
        a = np.zeros((d, d), dtype=np.int8)
        trace = []
        fwd = bwd = 0
        start_phase = "forward"
    else:
        if state.cpdag.shape != (d, d):
            raise ValueError(
                f"resume state carries a {state.cpdag.shape} CPDAG but the "
                f"scorer views {d} variables"
            )
        a = np.asarray(state.cpdag, dtype=np.int8).copy()
        trace = list(state.trace)
        fwd, bwd = int(state.forward_steps), int(state.backward_steps)
        start_phase = state.phase

    # Optional EdgeMask restriction (duck-typed off the session so bare
    # ges() callers can pass none): gates FORWARD pair enumeration only.
    mask = getattr(session, "edge_mask", None) if session is not None else None
    allowed = None
    if mask is not None:
        allowed = np.asarray(getattr(mask, "allowed", mask), dtype=bool)
        if allowed.shape != (d, d):
            raise ValueError(
                f"session.edge_mask is {allowed.shape} but the scorer views "
                f"{d} variables"
            )

    # One delta cache per ges() call, shared across phases: the session
    # seam opts in (EngineOptions.incremental); bare ges() keeps the full
    # re-enumeration path as the differential oracle.
    delta_cache = (
        _FrontierDelta(max_subset, allowed=allowed)
        if session is not None and getattr(session, "incremental", False)
        else None
    )

    def sweep(phase):
        nonlocal a
        steps = 0
        gen = _forward_candidates if phase == "forward" else _backward_candidates
        while True:
            with obs_trace.span("enumerate", cat="stage", attrs={"phase": phase}):
                if delta_cache is not None:
                    cands = delta_cache.candidates(a, phase)
                else:
                    cands = list(gen(a, max_subset, allowed))
            if not cands:
                break
            configs = set()
            for _, _, y, _, with_set, without_set in cands:
                configs.add(config_key(y, with_set))
                configs.add(config_key(y, without_set))
            # Group the frontier by parent set (then node): the batched
            # engine computes its z-side fold cores once per parent set,
            # and handing it each parent set's children contiguously keeps
            # a sweep's shared-core chunks dense instead of interleaved.
            configs = sorted(configs, key=lambda c: (c[1], c[0]))
            if session is not None:
                session.begin_sweep(
                    phase,
                    enum_stats=delta_cache.stats if delta_cache else None,
                )
                session.score_frontier(configs)
            elif batch_hook is not None:
                batch_hook(scorer, configs)
            else:
                prefetch = getattr(scorer, "prefetch", None)
                if prefetch is not None:
                    prefetch(configs)
            with obs_trace.span("select", cat="stage", attrs={"n_cands": len(cands)}):
                best_delta, best = 0.0, None
                for op, x, y, sub, with_set, without_set in cands:
                    delta = scorer.local_score(y, with_set) - scorer.local_score(
                        y, without_set
                    )
                    if delta > best_delta + 1e-12:
                        best_delta, best = delta, (op, x, y, sub)
            step = None
            if best is not None:
                op, x, y, sub = best
                a = (
                    _apply_insert(a, x, y, sub)
                    if op == "insert"
                    else _apply_delete(a, x, y, sub)
                )
                steps += 1
                step = (op, x, y, tuple(sorted(sub)), best_delta)
                trace.append(step)
                if verbose:
                    print(f"[GES/{phase}] {op}({x},{y},{tuple(sorted(sub))}) "
                          f"delta={best_delta:.4f}")
            if session is not None:
                session.end_sweep(step, cpdag=a)
            if best is None:
                break
        return steps

    if start_phase == "forward":
        fwd += sweep("forward")
    if start_phase in ("forward", "backward"):
        bwd += sweep("backward")
    # start_phase == "done": a finished run re-entered — score and return
    total = scorer.score_graph(g.pdag_to_dag(a)) if a.any() else scorer.score_graph(a)
    return GESResult(cpdag=a, score=total, forward_steps=fwd, backward_steps=bwd, trace=trace)
