"""Greedy Equivalence Search (Chickering 2002) driven by a decomposable
local score — paper Sec. 6.

Forward phase: best valid Insert(X, Y, T) until no positive improvement.
Backward phase: best valid Delete(X, Y, H) until no positive improvement.
Operator validity and score deltas follow Chickering's Theorems 15/17:

  Insert(X, Y, T):  X, Y non-adjacent; T subset of undirected neighbors of Y
    not adjacent to X.  Valid iff NA_{Y,X} u T is a clique and every
    semi-directed path Y ~> X crosses NA_{Y,X} u T.
    delta = s(Y, NA u T u Pa_Y u {X}) - s(Y, NA u T u Pa_Y)

  Delete(X, Y, H):  X -> Y or X -- Y; H subset of NA_{Y,X}.
    Valid iff NA_{Y,X} \\ H is a clique.
    delta = s(Y, (NA\\H) u Pa_Y \\ {X}) - s(Y, (NA\\H) u Pa_Y u {X})

Scores are cached inside the scorer (keyed by `score_common.config_key`),
so the search only pays for *new* local configurations.  Before any delta
is computed, each sweep iteration hands the full frontier's (node, parents)
configurations to the scorer's `prefetch` — the batched engine
(score_lowrank.cvlr_scores_batched) evaluates them in a handful of device
dispatches instead of one jit call + host sync per candidate.  This is the
default local path; a scorer whose `prefetch` declines (returns 0 without
caching, e.g. CVLRScorer(batched=False) or the exact CVScorer) falls back
to lazy per-candidate `local_score` — kept as the oracle for tests.
`batch_hook`, when set, overrides `prefetch`; the distributed runtime uses
it to evaluate the frontier on a mesh (repro.core.distributed_score).
User-facing engine selection does not thread hooks any more: a
`repro.core.api.DiscoverySession` (built from
`repro.core.spec.EngineOptions`) passes itself as `session=` and owns the
sweep lifecycle — `begin_sweep` / `score_frontier` / `end_sweep` around
every frontier evaluation — which is also the seam the planned
incremental-frontier-delta optimization needs (a session sees consecutive
frontiers and can diff them).
"""

from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core import graph as g
from repro.core.score_common import config_key


@dataclasses.dataclass
class GESResult:
    cpdag: np.ndarray
    score: float
    forward_steps: int
    backward_steps: int
    trace: list


def _na_yx(a, y, x):
    """Undirected neighbors of y that are adjacent to x."""
    return frozenset(
        v for v in g.neighbors_undir(a, y) if g.adjacent(a, v, x)
    )


def _subsets(items, max_size=None):
    items = sorted(items)
    hi = len(items) if max_size is None else min(len(items), max_size)
    for k in range(hi + 1):
        yield from itertools.combinations(items, k)


def _forward_candidates(a, max_subset):
    d = a.shape[0]
    for x, y in itertools.permutations(range(d), 2):
        if g.adjacent(a, x, y):
            continue
        na = _na_yx(a, y, x)
        t_pool = [
            v
            for v in g.neighbors_undir(a, y)
            if not g.adjacent(a, v, x) and v != x
        ]
        pa_y = frozenset(g.parents(a, y))
        for t in _subsets(t_pool, max_subset):
            nat = na | frozenset(t)
            if not g.is_clique(a, nat):
                continue
            if not g.semi_directed_blocked(a, y, x, nat):
                continue
            base = nat | pa_y
            yield ("insert", x, y, frozenset(t), base | {x}, base)


def _backward_candidates(a, max_subset):
    d = a.shape[0]
    for x, y in itertools.permutations(range(d), 2):
        if not (g.has_dir(a, x, y) or g.has_undir(a, x, y)):
            continue
        na = _na_yx(a, y, x)
        pa_y = frozenset(g.parents(a, y))
        for h in _subsets(na, max_subset):
            rest = na - frozenset(h)
            if not g.is_clique(a, rest):
                continue
            base = rest | (pa_y - {x})
            yield ("delete", x, y, frozenset(h), base, base | {x})


def _apply_insert(a, x, y, t):
    a = a.copy()
    a[x, y] = 1
    a[y, x] = 0
    for v in t:
        a[v, y] = 1
        a[y, v] = 0
    return g.pdag_to_cpdag(a)


def _apply_delete(a, x, y, h):
    a = a.copy()
    a[x, y] = a[y, x] = 0
    for v in h:
        # orient y -- v as y -> v and x -- v as x -> v
        if g.has_undir(a, y, v):
            a[y, v] = 1
            a[v, y] = 0
        if g.has_undir(a, x, v):
            a[x, v] = 1
            a[v, x] = 0
    return g.pdag_to_cpdag(a)


def ges(
    scorer,
    d: int | None = None,
    max_subset: int | None = None,
    batch_hook=None,
    verbose: bool = False,
    session=None,
    state=None,
) -> GESResult:
    """Run GES with the given local scorer (CVScorer / CVLRScorer / ...).

    d: number of variables — inferred from the scorer's view; passing it
    explicitly is only accepted when it agrees (a mismatch used to be
    silently hazardous and now raises).  `session`: a
    `repro.core.api.DiscoverySession` that owns the sweep lifecycle and
    routes frontier scoring by its `EngineOptions` (mutually exclusive
    with the low-level `batch_hook`).

    state: a `repro.core.runstate.RunState` to resume from.  GES is
    replayable: candidate enumeration is a pure function of the CPDAG
    and scoring is deterministic, so re-entering the search with the
    restored CPDAG / phase / applied-step log reproduces the
    uninterrupted run's remaining sweeps exactly — a completed forward
    phase is skipped, `phase == "done"` skips straight to the final
    score.  The returned trace and step counters include the restored
    prefix, so resumed and uninterrupted results compare equal.
    """
    num_vars = getattr(getattr(scorer, "view", None), "num_vars", None)
    if d is None:
        if num_vars is None:
            raise ValueError(
                "ges() needs d= when the scorer has no .view to infer the "
                "variable count from"
            )
        d = num_vars
    elif num_vars is not None and int(d) != num_vars:
        raise ValueError(
            f"ges(d={d}) conflicts with the scorer's view over {num_vars} "
            "variables — drop the d argument, it is inferred from the scorer"
        )
    d = int(d)
    if session is not None and batch_hook is not None:
        raise ValueError("pass either session= or batch_hook=, not both")
    if state is None:
        a = np.zeros((d, d), dtype=np.int8)
        trace = []
        fwd = bwd = 0
        start_phase = "forward"
    else:
        if state.cpdag.shape != (d, d):
            raise ValueError(
                f"resume state carries a {state.cpdag.shape} CPDAG but the "
                f"scorer views {d} variables"
            )
        a = np.asarray(state.cpdag, dtype=np.int8).copy()
        trace = list(state.trace)
        fwd, bwd = int(state.forward_steps), int(state.backward_steps)
        start_phase = state.phase

    def sweep(phase):
        nonlocal a
        steps = 0
        gen = _forward_candidates if phase == "forward" else _backward_candidates
        while True:
            cands = list(gen(a, max_subset))
            if not cands:
                break
            configs = set()
            for _, _, y, _, with_set, without_set in cands:
                configs.add(config_key(y, with_set))
                configs.add(config_key(y, without_set))
            # Group the frontier by parent set (then node): the batched
            # engine computes its z-side fold cores once per parent set,
            # and handing it each parent set's children contiguously keeps
            # a sweep's shared-core chunks dense instead of interleaved.
            configs = sorted(configs, key=lambda c: (c[1], c[0]))
            if session is not None:
                session.begin_sweep(phase)
                session.score_frontier(configs)
            elif batch_hook is not None:
                batch_hook(scorer, configs)
            else:
                prefetch = getattr(scorer, "prefetch", None)
                if prefetch is not None:
                    prefetch(configs)
            best_delta, best = 0.0, None
            for op, x, y, sub, with_set, without_set in cands:
                delta = scorer.local_score(y, with_set) - scorer.local_score(
                    y, without_set
                )
                if delta > best_delta + 1e-12:
                    best_delta, best = delta, (op, x, y, sub)
            step = None
            if best is not None:
                op, x, y, sub = best
                a = (
                    _apply_insert(a, x, y, sub)
                    if op == "insert"
                    else _apply_delete(a, x, y, sub)
                )
                steps += 1
                step = (op, x, y, tuple(sorted(sub)), best_delta)
                trace.append(step)
                if verbose:
                    print(f"[GES/{phase}] {op}({x},{y},{tuple(sorted(sub))}) "
                          f"delta={best_delta:.4f}")
            if session is not None:
                session.end_sweep(step, cpdag=a)
            if best is None:
                break
        return steps

    if start_phase == "forward":
        fwd += sweep("forward")
    if start_phase in ("forward", "backward"):
        bwd += sweep("backward")
    # start_phase == "done": a finished run re-entered — score and return
    total = scorer.score_graph(g.pdag_to_dag(a)) if a.any() else scorer.score_graph(a)
    return GESResult(cpdag=a, score=total, forward_steps=fwd, backward_steps=bwd, trace=trace)
