"""Deprecated shim (one release): the low-rank factorization layer moved
to the pluggable feature-bank subsystem, `repro.features`.

Every name this module used to define lives on — implementation
unchanged — in `repro.features.backends`:

    incomplete_cholesky   (Alg. 1, the ``icl`` backend)
    discrete_lowrank      (Alg. 2, the ``discrete_exact`` backend)
    count_distinct_rows
    lowrank_features      (the default-policy end-to-end builder)

Importing them from here keeps working for one release and emits a
`DeprecationWarning` attributed to the *caller*; the tier-1 pytest.ini
filterwarnings gate escalates that warning to an error when the caller
is a ``repro.*`` module, so repo code can never quietly stay on the old
path while user code gets a clean migration window.
"""

from __future__ import annotations

import warnings

_MOVED = (
    "incomplete_cholesky",
    "discrete_lowrank",
    "count_distinct_rows",
    "lowrank_features",
)

__all__ = list(_MOVED)


def __getattr__(name):
    if name in _MOVED:
        warnings.warn(
            f"repro.core.lowrank.{name} is deprecated; import it from "
            "repro.features.backends (the old location keeps working for "
            "one release and re-exports the identical implementation)",
            DeprecationWarning,
            stacklevel=2,
        )
        from repro.features import backends

        return getattr(backends, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_MOVED))
