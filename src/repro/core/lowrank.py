"""Low-rank kernel factorizations (paper Sec. 4).

Two samplers:

* `incomplete_cholesky` — Alg. 1 (ICL), the adaptive Nystroem variant: greedy
  pivot selection maximizing the residual-diagonal bound.  Restructured for
  accelerators as a `lax.fori_loop` whose per-step body is a *vectorized*
  kernel-strip evaluation + rank-1 residual update (O(n) per step, no Python
  early-exit: the eta stopping rule is carried as a flag and dead columns are
  masked to zero — zero-padded columns leave every downstream score identity
  exact, see score_lowrank.py).

* `discrete_lowrank` — Alg. 2: for a variable (set) with m_d <= m distinct
  rows the factorization Lambda = K_{XX'} L^{-T} (K_{X'} = L L^T) is *exact*
  (Lemma 4.3).  Note the paper prints L^{-1}; the correct right factor is
  L^{-T} — tested to machine precision in tests/test_lowrank.py.

Both return a fixed-width (n, m_max) factor plus the effective rank, so all
downstream score computations are fixed-shape and jit-cacheable.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.linalg import solve_triangular

from repro.core.kernel_fns import (
    KernelSpec,
    center_features,
    kernel_rows,
    median_heuristic_width,
    standardize,
)


@partial(jax.jit, static_argnames=("m_max", "kind"))
def _icl_jax(x: jnp.ndarray, width, m_max: int, eta, kind: str):
    """Jitted ICL. x: (n, d) data; returns (Lambda (n, m_max), m_eff)."""
    n = x.shape[0]
    dtype = x.dtype
    diag0 = jnp.ones((n,), dtype) if kind in ("rbf", "delta") else jnp.sum(
        x * x, axis=-1
    )
    spec_width = width

    def krow(j):
        # k(X, x_j): vectorized kernel strip — the hot spot (Pallas-served
        # on TPU via repro.kernels.ops.rbf_gram; jnp here).
        pivot = jax.lax.dynamic_slice_in_dim(x, j, 1, axis=0)  # (1, d)
        if kind == "rbf":
            d2 = jnp.sum((x - pivot) ** 2, axis=-1)
            return jnp.exp(-d2 / (2.0 * spec_width * spec_width))
        if kind == "delta":
            d2 = jnp.sum((x - pivot) ** 2, axis=-1)
            return (d2 < 1e-18).astype(dtype)
        return x @ pivot[0]

    def body(i, carry):
        lam, d_res, unselected, m_eff, active = carry
        # Stopping rule (Alg. 1 line 6): residual trace below eta.
        still = jnp.sum(jnp.maximum(d_res, 0.0) * unselected) >= eta
        active = jnp.logical_and(active, still)
        j_star = jnp.argmax(jnp.where(unselected > 0, d_res, -jnp.inf))
        dj = jnp.maximum(d_res[j_star], 1e-30)
        nu = jnp.sqrt(dj)
        # Column i (Alg. 1 lines 11-12): columns >= i of lam are zero, so the
        # full matvec equals the [:, :i] slice without dynamic shapes.
        col = (krow(j_star) - lam @ lam[j_star]) / nu
        col = jnp.where(active, col, jnp.zeros_like(col))
        lam = lam.at[:, i].set(col)
        d_res = jnp.maximum(d_res - col * col, 0.0)
        d_res = jnp.where(active, d_res.at[j_star].set(0.0), d_res)
        unselected = jnp.where(
            active, unselected.at[j_star].set(0.0), unselected
        )
        m_eff = m_eff + jnp.where(active, 1, 0)
        return lam, d_res, unselected, m_eff, active

    lam0 = jnp.zeros((n, m_max), dtype)
    carry = (
        lam0,
        diag0,
        jnp.ones((n,), dtype),
        jnp.asarray(0, jnp.int32),
        jnp.asarray(True),
    )
    lam, _, _, m_eff, _ = jax.lax.fori_loop(0, m_max, body, carry)
    return lam, m_eff


def incomplete_cholesky(
    x,
    spec: KernelSpec,
    m_max: int = 100,
    eta: float = 1e-6,
):
    """Alg. 1.  Returns (Lambda (n, m_max) with ||Lam Lam^T - K|| <= eta
    when m_eff < m_max, m_eff)."""
    x = jnp.asarray(x, jnp.float64)
    if x.ndim == 1:
        x = x[:, None]
    return _icl_jax(
        x, jnp.asarray(spec.width, x.dtype), int(m_max), jnp.asarray(eta, x.dtype), spec.kind
    )


def discrete_lowrank(
    x,
    spec: KernelSpec,
    m_max: int = 100,
    jitter: float = 1e-10,
    backend: str = "jnp",
):
    """Alg. 2: exact factorization from deduplicated rows.

    Host-side unique (data-dependent shape), jitted algebra.  Returns
    (Lambda (n, m_max) zero-padded, m_d).  Requires m_d <= m_max.

    backend="pallas" routes the (n x m_d) kernel strip — the hot spot —
    through the tiled Pallas kernel (repro.kernels.ops.rbf_gram); on this
    CPU container it runs in interpret mode, on TPU it lowers to Mosaic.
    """
    xn = np.asarray(x, dtype=np.float64)
    if xn.ndim == 1:
        xn = xn[:, None]
    uniq = np.unique(xn, axis=0)
    m_d = uniq.shape[0]
    if m_d > m_max:
        raise ValueError(f"m_d={m_d} exceeds m_max={m_max}; use ICL instead")
    if backend == "pallas" and spec.kind == "rbf":
        from repro.kernels.ops import rbf_gram

        k_xu = rbf_gram(xn, uniq, spec.width).astype(jnp.float64)
    else:
        k_xu = kernel_rows(xn, uniq, spec)  # (n, m_d)
    k_uu = kernel_rows(uniq, uniq, spec)  # (m_d, m_d)
    k_uu = k_uu + jitter * jnp.eye(m_d, dtype=k_uu.dtype)
    chol = jnp.linalg.cholesky(k_uu)
    # Lambda = K_{XX'} L^{-T}:  solve L Y^T = K_{XX'}^T  =>  Y = K L^{-T}.
    lam = solve_triangular(chol, k_xu.T, lower=True).T
    pad = jnp.zeros((lam.shape[0], m_max - m_d), lam.dtype)
    return jnp.concatenate([lam, pad], axis=1), m_d


def count_distinct_rows(x: np.ndarray, cap: int, chunk: int = 16384) -> int:
    """Number of distinct rows, early-exiting once > cap.

    Vectorized: rows are compared as raw bytes through a contiguous void
    view (one np.unique per chunk, C speed) instead of a per-row Python
    tuple()/hash loop.  The chunked scan keeps the early-exit-at-cap
    semantics: counts <= cap are exact, and any count beyond the cap is
    reported as cap + 1 (the value the incremental loop stopped at).
    """
    xn = np.asarray(x)
    if xn.ndim == 1:
        xn = xn[:, None]
    if xn.shape[0] == 0:
        return 0
    if xn.shape[1] == 0:
        return 1  # every zero-width row is the same (empty) row
    r = np.round(np.asarray(xn, dtype=np.float64), 12)
    r += 0.0  # normalize -0.0 -> +0.0 so the byte view matches == semantics
    r = np.ascontiguousarray(r)
    void = np.dtype((np.void, r.dtype.itemsize * r.shape[1]))
    rows = r.view(void).ravel()
    uniq = None
    for lo in range(0, rows.shape[0], chunk):
        block = np.unique(rows[lo : lo + chunk])
        uniq = block if uniq is None else np.unique(
            np.concatenate([uniq, block])
        )
        if uniq.size > cap:
            return int(cap) + 1
    return int(uniq.size)


def lowrank_features(
    x,
    *,
    discrete: bool = False,
    m_max: int = 100,
    eta: float = 1e-6,
    width_factor: float = 2.0,
    spec: KernelSpec | None = None,
    standardize_data: bool = True,
):
    """End-to-end feature builder used by the CV-LR scorer (paper Sec. 7.1):

    - z-score the columns,
    - pick the RBF width by the 2x-median heuristic (unless `spec` given),
    - route: Alg. 2 when the variable is discrete with m_d <= m_max,
      else Alg. 1 (ICL),
    - center the factor (Lambda~ = H Lambda).

    Returns (Lambda~ (n, m_max) float64, m_eff, spec).
    """
    xn = np.asarray(x, dtype=np.float64)
    if xn.ndim == 1:
        xn = xn[:, None]
    if standardize_data:
        xn = standardize(xn)
    if spec is None:
        spec = KernelSpec("rbf", median_heuristic_width(xn, factor=width_factor))
    if discrete:
        m_d = count_distinct_rows(xn, m_max)
        if m_d <= m_max:
            lam, m_eff = discrete_lowrank(xn, spec, m_max=m_max)
            return center_features(lam), int(m_eff), spec
    lam, m_eff = incomplete_cholesky(xn, spec, m_max=m_max, eta=eta)
    return center_features(lam), int(m_eff), spec
