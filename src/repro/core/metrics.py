"""Accuracy metrics (paper Sec. 7.1): skeleton F1 and normalized SHD."""

from __future__ import annotations

import numpy as np

from repro.core import graph as g


def skeleton_f1(est, true) -> float:
    """F1 over undirected skeleton edges."""
    se = g.skeleton(np.asarray(est))
    st = g.skeleton(np.asarray(true))
    iu = np.triu_indices(se.shape[0], k=1)
    e, t = se[iu].astype(bool), st[iu].astype(bool)
    tp = int(np.sum(e & t))
    fp = int(np.sum(e & ~t))
    fn = int(np.sum(~e & t))
    if tp == 0:
        return 0.0 if (fp or fn) else 1.0
    prec = tp / (tp + fp)
    rec = tp / (tp + fn)
    return 2 * prec * rec / (prec + rec)


def _edge_mark(a, i, j) -> int:
    """0 none, 1 i->j, 2 j->i, 3 undirected."""
    if g.has_undir(a, i, j):
        return 3
    if g.has_dir(a, i, j):
        return 1
    if g.has_dir(a, j, i):
        return 2
    return 0


def shd_cpdag(est, true, normalize: bool = True) -> float:
    """Structural Hamming distance between CPDAGs.

    Counts pairs whose edge mark differs (missing/extra/misoriented each
    cost 1), normalized by the number of possible pairs d(d-1)/2 —
    matching the paper's 'normalized SHD' scale (~0.1-0.3)."""
    est = np.asarray(est)
    true = np.asarray(true)
    d = est.shape[0]
    dist = 0
    for i in range(d):
        for j in range(i + 1, d):
            if _edge_mark(est, i, j) != _edge_mark(true, i, j):
                dist += 1
    return dist / (d * (d - 1) / 2) if normalize else float(dist)
