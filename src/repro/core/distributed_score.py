"""Distributed CV-LR scoring — the paper's O(n) claim mapped onto a mesh.

Two parallelism axes (DESIGN.md §2.3):

* **data** — samples.  Each device holds an (n/p, m) row shard of the
  centered factors; every Gram block (P/E/F/V/U/S) is a local contraction
  followed by one `psum` over the data axis (the ONLY collective the score
  needs: 6 m x m tensors per candidate, ~6*128^2*8B = 786 KB — latency-bound,
  not bandwidth-bound).  The m x m fold algebra is replicated: O(Q m^3)
  redundant FLOPs per device, negligible vs the O(n m^2 / p) Gram work.

* **model** — GES frontier candidates.  The forward/backward sweep needs
  hundreds of local scores per step; they batch into a leading axis that
  shards over `model`.

`cvlr_scores_sharded` composes both: (B, Q, n0, m) factors, B over `model`,
n0 over `data`.  Under `shard_map` the collective schedule is explicit and
inspectable — the dry-run (launch/dryrun.py --arch cvlr_paper) lowers this
exact function on the production mesh.

All fold math lives in `score_lowrank.scores_from_fold_blocks`, which is
itself a thin wrapper over the single fold-algebra copy
(`score_lowrank._candidate_fold_scores` — the same core the local engine's
device-bank fold jit gathers into, z-cores + batched Qm Cholesky included)
— this module only adds the einsum-to-blocks step and the collective
schedule, so the local batched frontier engine and the sharded scorer can
never drift apart numerically.  (The local engine's device *bank* tier is
deliberately not used here: under shard_map every candidate's factors are
already device-resident shards with no cross-candidate sharing to cache.)
"""

from __future__ import annotations

import concurrent.futures
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.runstate import InjectedFault
from repro.core.score_common import config_key
from repro.core.score_lowrank import scores_from_fold_blocks
from repro.distributed.fault_tolerance import HeartbeatMonitor
from repro.kernels import fold_gram_blocks
from repro.obs import trace as obs_trace

try:  # jax >= 0.5 exports shard_map at top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - depends on jax version
    from jax.experimental.shard_map import shard_map as _shard_map


def _block_grams(lam_x_b, lam_z_b, data_axes=None, precision="bitwise"):
    """Per-fold test Gram blocks (V, U, S) from fold-blocked factors.

    lam_x_b, lam_z_b: (..., Q, n0_local, m) with any leading batch dims.
    The contraction routes through `repro.kernels.fold_gram_blocks` — the
    same fused fold-Gram strip kernel as the local batched frontier
    engine (tiled Pallas on TPU, einsum elsewhere), so the local and
    sharded scorers share both the fold algebra AND the Gram kernel —
    including the `precision` policy (f32 accumulation on the einsum
    backend under ``"f32_gram"``).
    When `data_axes` is given, the n0 axis is a shard and the blocks are
    summed across it with one fused psum (3 tensors per *batch*, not per
    candidate: batching the all-reduce amortizes collective latency across
    the GES frontier).
    (A concat-Gram [X|Z]^T[X|Z] single-einsum variant was tried and
    REFUTED: the materialized concat costs an extra write+read that
    exceeds the duplicate-stream saving — EXPERIMENTS.md §Perf.)
    """
    V = fold_gram_blocks(lam_x_b, lam_x_b, precision=precision)
    U = fold_gram_blocks(lam_z_b, lam_x_b, precision=precision)
    S = fold_gram_blocks(lam_z_b, lam_z_b, precision=precision)
    if data_axes is not None:
        V, U, S = jax.lax.psum((V, U, S), data_axes)
    return V, U, S


def block_folds(lam: jnp.ndarray, q: int) -> jnp.ndarray:
    """(n_eff, m) -> (Q, n0, m) fold-blocked view (centering preserved)."""
    n_eff, m = lam.shape
    n0 = n_eff // q
    return lam[: q * n0].reshape(q, n0, m)


def cvlr_scores_stacked(lam_x_b, lam_z_b, lmbda=0.01, gamma=0.01, precision="bitwise"):
    """Batched scores for a GES frontier from pre-blocked stacked factors.

    lam_x_b, lam_z_b: (B, Q, n0, m) fold-blocked centered factors.
    Returns (B,) scores.  Pure einsum + the shared fold kernel — shard the
    B axis with pjit for candidate parallelism.  `precision` is the Gram
    accumulation policy (`repro.core.spec.EngineOptions.precision`).
    (The local search path uses `score_lowrank.cvlr_scores_batched`
    instead — a different, bank+pairs signature — which shares Gram
    blocks across candidates through the Gram-block cache.)
    """
    _, q, n0, _ = lam_x_b.shape
    n1 = (q - 1) * n0
    lm = jnp.asarray(lmbda, lam_x_b.dtype)
    gm = jnp.asarray(gamma, lam_x_b.dtype)
    V, U, S = _block_grams(lam_x_b, lam_z_b, precision=precision)
    return scores_from_fold_blocks(V, U, S, n0, n1, lm, gm)


def make_sharded_scorer(
    mesh: Mesh,
    data_axis="data",
    model_axis: str = "model",
    precision: str = "bitwise",
):
    """shard_map CV-LR frontier scorer on `mesh`.

    Returns a jit'd fn of ((B, Q, n0, m), (B, Q, n0, m)) -> (B,) with
    B sharded over `model_axis` and n0 sharded over `data_axis` (a name or
    a tuple of names — pass ("pod", "data") on the multi-pod mesh so the
    sample shards span pods); Gram blocks psum over the data axes exactly
    as described in the module doc.  `precision` is the Gram accumulation
    policy (`repro.core.spec.EngineOptions.precision`).
    """
    data_axes = (data_axis,) if isinstance(data_axis, str) else tuple(data_axis)
    data_size = 1
    for a in data_axes:
        data_size *= mesh.shape[a]

    def local_fn(lam_x_b, lam_z_b):
        # shapes here are per-device: (B/pm, Q, n0/pd, m)
        _, q, n0_local, _ = lam_x_b.shape
        n0 = n0_local * data_size
        n1 = (q - 1) * n0
        lm = jnp.asarray(0.01, lam_x_b.dtype)
        gm = jnp.asarray(0.01, lam_x_b.dtype)
        V, U, S = _block_grams(lam_x_b, lam_z_b, data_axes, precision=precision)
        return scores_from_fold_blocks(V, U, S, n0, n1, lm, gm)

    spec_in = P(model_axis, None, data_axes if len(data_axes) > 1 else data_axes[0], None)
    spec_out = P(model_axis)
    fn = _shard_map(
        local_fn, mesh=mesh, in_specs=(spec_in, spec_in), out_specs=spec_out
    )
    return jax.jit(fn)


def ges_batch_hook(scorer, configs, lmbda=None, gamma=None, precision=None):
    """`batch_hook` for repro.core.ges.ges: evaluate a whole sweep's local
    scores in one batched (vmapped) call and fill the scorer cache.

    configs: list of (node, parents_tuple).  With default hyperparameters
    this delegates to the scorer's own batched frontier engine
    (`CVLRScorer.prefetch`), which shares Gram blocks across candidates;
    with explicit lmbda/gamma overrides it falls back to stacking the
    scorer's feature bank and scoring through the same shared fold kernel.
    `precision` defaults to the scorer's own Gram accumulation policy.
    """
    cfg = scorer.config
    if precision is None:
        precision = getattr(scorer, "precision", "bitwise")
    if lmbda is None and gamma is None and getattr(scorer, "batched", False):
        return scorer.prefetch(configs)
    lmbda = cfg.lmbda if lmbda is None else lmbda
    gamma = cfg.gamma if gamma is None else gamma
    todo = _uncached_keys(scorer, configs)
    if not todo:
        return 0
    scores = _stacked_scores_for_keys(scorer, todo, lmbda, gamma, precision)
    return _finalize_scores(scorer, todo, scores)


def _uncached_keys(scorer, configs) -> list:
    """Deduplicated canonical keys of a frontier's uncached configs."""
    todo, seen = [], set()
    for node, parents in configs:
        key = config_key(node, parents)
        if key not in scorer._score_cache and key not in seen:
            seen.add(key)
            todo.append(key)
    return todo


def _stacked_scores_for_keys(scorer, keys, lmbda, gamma, precision):
    """(len(keys),) scores through the stacked pipeline.  Per-candidate
    algebra is batch-independent (vmapped), so any partition of a frontier
    into shards produces bitwise-identical per-key scores — the invariant
    the fault-tolerant runner's re-shard relies on."""
    q = scorer.config.q_folds
    lxs, lzs = [], []
    for node, parents in keys:
        lam_x = scorer.features((node,))
        lam_z = (
            scorer.features(parents) if parents else jnp.zeros_like(lam_x)
        )
        lxs.append(block_folds(lam_x, q))
        lzs.append(block_folds(lam_z, q))
    return np.asarray(
        cvlr_scores_stacked(
            jnp.stack(lxs), jnp.stack(lzs), lmbda=lmbda, gamma=gamma,
            precision=precision,
        ),
        dtype=np.float64,
    )


def _finalize_scores(scorer, keys, scores, sweep=None) -> int:
    """Inject (FaultPlan NaN poisoning), recover (the scorer's numerical
    degradation ladder), and commit scores to the scorer cache."""
    scores = np.asarray(scores, dtype=np.float64)
    plan = getattr(scorer, "fault_plan", None)
    if plan is not None:
        if sweep is None:
            sweep = getattr(scorer, "fault_sweep", None)
        scores = plan.corrupt_scores(scores, sweep)
    recover = getattr(scorer, "_recover_score", None)
    for key, s in zip(keys, scores):
        val = float(s)
        if not np.isfinite(val) and recover is not None:
            val = float(recover(key[0], key[1]))
        scorer._memo_put(key, val)
    return len(keys)


_BACKOFF_S = 0.05  # base of the exponential retry backoff
_DEFAULT_HB_TIMEOUT_S = 10.0  # heartbeat window when no per-shard timeout


def _partition(items: list, k: int) -> list:
    """k near-equal contiguous slices (some possibly empty).

    Deterministic in the input order, and per-key scores are
    partition-independent (`_stacked_scores_for_keys`), so it makes no
    difference whether the session hands the runner a full frontier or
    just its incremental delta (`EngineOptions(incremental=True)` routes
    only new-config keys here): a delta's keys arrive in the same sorted
    frontier order and score bitwise-identically to the same keys inside
    a full-frontier shard."""
    n = len(items)
    base, extra = divmod(n, k)
    out, lo = [], 0
    for w in range(k):
        hi = lo + base + (1 if w < extra else 0)
        out.append(items[lo:hi])
        lo = hi
    return out


def _run_resharding(
    scorer, todo, lmbda, gamma, precision,
    workers, retries, timeout_s, fault_plan, sweep, telemetry,
):
    """Score `todo` across logical shard workers with bounded retry and
    heartbeat-driven survivor re-shard; returns {key: score} for every
    key a live worker completed (missing keys => caller falls back).

    Liveness policy: a worker that *raises* is retried with exponential
    backoff and declared dead after `retries` + 1 failed attempts; a
    worker that *times out* (per-shard `timeout_s`) is judged by the
    `HeartbeatMonitor` — it beats only on successful completion, so each
    timed-out attempt advances its missed-deadline epochs, and grace =
    retries + 1 windows declares it dead.  A dead worker's remaining
    slice is re-partitioned across the survivors mid-sweep; per-candidate
    scores are partition-independent (see `_stacked_scores_for_keys`), so
    the re-sharded sweep's scores are bitwise-identical to an undisturbed
    one."""
    hb_timeout = timeout_s if timeout_s is not None else _DEFAULT_HB_TIMEOUT_S
    monitor = HeartbeatMonitor(
        num_workers=workers, timeout=hb_timeout, grace=retries + 1
    )
    pending = {
        w: part
        for w, part in enumerate(_partition(todo, workers))
        if part
    }
    live = set(range(workers))
    attempts = {w: 0 for w in range(workers)}
    results: dict = {}

    # The active recorder is captured HERE, on the dispatching thread:
    # contextvars do not propagate into pool workers, so each job
    # re-enters the trace context explicitly and tags its span with the
    # shard id and retry epoch (a no-op end to end when obs is off).
    rec = obs_trace.get_recorder()

    def job(w, keys):
        with obs_trace.use(rec), obs_trace.span(
            "shard",
            cat="stage",
            attrs={
                "shard": w,
                "epoch": attempts[w],
                "keys": len(keys),
                "sweep": sweep,
            },
        ):
            if fault_plan is not None and fault_plan.shard_faulted(w, sweep):
                if fault_plan.shard_fault == "hang":
                    time.sleep(fault_plan.shard_hang_s)  # straggler: trips
                    # the per-shard timeout; the raise below keeps the late
                    # result from ever landing
                raise InjectedFault(f"injected shard fault: worker {w}")
            return _stacked_scores_for_keys(scorer, keys, lmbda, gamma, precision)

    # +2 headroom: a timed-out attempt's thread cannot be interrupted, so
    # its retry must not have to wait for the straggler to release a slot
    with concurrent.futures.ThreadPoolExecutor(max_workers=workers + 2) as pool:
        while pending and live:
            futs = {
                w: pool.submit(job, w, keys)
                for w, keys in pending.items()
                if w in live
            }
            for w, fut in futs.items():
                dead_now = False
                try:
                    scores = fut.result(timeout=timeout_s)
                except concurrent.futures.TimeoutError:
                    attempts[w] += 1
                    fut.cancel()
                    # no beat since dispatch: the monitor's missed epochs
                    # have genuinely advanced by this attempt's window
                    _, _, dead = monitor.check()
                    dead_now = w in dead
                except Exception:
                    attempts[w] += 1
                    dead_now = attempts[w] > retries
                else:
                    monitor.beat(w)
                    results.update(zip(pending.pop(w), scores))
                    continue
                telemetry["retries"] += 0 if dead_now else 1
                if dead_now:
                    live.discard(w)
                    telemetry["dead_workers"].append(w)
                else:
                    time.sleep(_BACKOFF_S * (2 ** (attempts[w] - 1)))
            # survivor-set re-shard: dead workers' unfinished slices are
            # re-partitioned across the live workers mid-sweep
            orphaned = [w for w in pending if w not in live]
            if orphaned and live:
                strays = [k for w in orphaned for k in pending.pop(w)]
                telemetry["resharded"] += len(strays)
                survivors = sorted(live)
                for lw, extra in zip(survivors, _partition(strays, len(survivors))):
                    if extra:
                        pending[lw] = pending.get(lw, []) + extra
    return results


def sharded_batch_hook(
    scorer, configs, *, options=None, fault_plan=None, sweep=None,
    telemetry=None,
) -> int:
    """The ``EngineOptions(engine="sharded")`` frontier path: score a GES
    sweep through the *stacked* distributed pipeline (`cvlr_scores_stacked`
    — fold-blocked factors, candidate axis vmapped locally / shardable over
    a mesh's `model` axis) regardless of the scorer's own engine setting.

    `repro.core.api.DiscoverySession` routes frontiers here when the
    options select the sharded engine, so user code never threads a raw
    ``batch_hook`` callable again.  The scorer's `precision` policy rides
    along, so ``EngineOptions(engine="sharded", precision="f32_gram")``
    accumulates the stacked pipeline's Grams at f32 exactly like the
    local engine.

    Fault tolerance (``options.shard_workers > 1``, or any `fault_plan`):
    the frontier's uncached keys are partitioned across logical shard
    workers (`_run_resharding`) with per-shard timeout
    (``options.shard_timeout_s``), bounded exponential-backoff retry
    (``options.shard_retries``), `HeartbeatMonitor`-driven survivor-set
    re-shard, and — when every worker is lost — a terminal fallback that
    scores the stranded keys in-process through the same stacked
    pipeline, so a discovery never fails outright from shard loss.  Per-candidate
    scores are partition-independent, so every recovery path produces
    the same numbers as an undisturbed sweep.  The default options
    (1 worker, no plan) keep the original single-dispatch pipeline.

    telemetry: optional dict accumulating ``retries`` / ``resharded`` /
    ``dead_workers`` / ``fallback_keys`` for the session sweep log.
    `fault_plan` / `sweep` are the injection context
    (`repro.core.runstate.FaultPlan`).
    """
    cfg = scorer.config
    precision = getattr(scorer, "precision", "bitwise")
    workers = int(getattr(options, "shard_workers", 1) or 1) if options else 1
    if workers <= 1 and fault_plan is None:
        return ges_batch_hook(
            scorer, configs, lmbda=cfg.lmbda, gamma=cfg.gamma,
            precision=precision,
        )
    retries = int(getattr(options, "shard_retries", 2)) if options else 2
    timeout_s = getattr(options, "shard_timeout_s", None) if options else None
    todo = _uncached_keys(scorer, configs)
    if not todo:
        return 0
    tel = telemetry if telemetry is not None else {}
    tel.setdefault("workers", workers)
    tel.setdefault("retries", 0)
    tel.setdefault("resharded", 0)
    tel.setdefault("dead_workers", [])
    tel.setdefault("fallback_keys", 0)
    results = _run_resharding(
        scorer, todo, cfg.lmbda, cfg.gamma, precision,
        workers, retries, timeout_s, fault_plan, sweep, tel,
    )
    scored = [k for k in todo if k in results]
    _finalize_scores(
        scorer, scored, [results[k] for k in scored], sweep=sweep
    )
    stranded = [k for k in todo if k not in results]
    if stranded:
        # terminal fallback: every worker died — score the stranded keys
        # in-process through the SAME stacked pipeline the shards run
        # (not the chunked prefetch engine, whose reduction order differs
        # at the last ulp), so recovery stays bitwise-identical to an
        # undisturbed sweep
        tel["fallback_keys"] += len(stranded)
        with obs_trace.span(
            "shard_fallback", cat="stage", attrs={"keys": len(stranded)}
        ):
            scores = _stacked_scores_for_keys(
                scorer, stranded, cfg.lmbda, cfg.gamma, precision
            )
        _finalize_scores(scorer, stranded, scores, sweep=sweep)
    return len(todo)
