"""Distributed CV-LR scoring — the paper's O(n) claim mapped onto a mesh.

Two parallelism axes (DESIGN.md §2.3):

* **data** — samples.  Each device holds an (n/p, m) row shard of the
  centered factors; every Gram block (P/E/F/V/U/S) is a local contraction
  followed by one `psum` over the data axis (the ONLY collective the score
  needs: 6 m x m tensors per candidate, ~6*128^2*8B = 786 KB — latency-bound,
  not bandwidth-bound).  The m x m fold algebra is replicated: O(Q m^3)
  redundant FLOPs per device, negligible vs the O(n m^2 / p) Gram work.

* **model** — GES frontier candidates.  The forward/backward sweep needs
  hundreds of local scores per step; they batch into a leading axis that
  shards over `model`.

`cvlr_scores_sharded` composes both: (B, Q, n0, m) factors, B over `model`,
n0 over `data`.  Under `shard_map` the collective schedule is explicit and
inspectable — the dry-run (launch/dryrun.py --arch cvlr_paper) lowers this
exact function on the production mesh.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.score_lowrank import _fold_score_lr


def _score_from_blocked(lam_x_b, lam_z_b, n0, n1, lmbda, gamma, data_axis=None):
    """Score from fold-blocked factors (Q, n0_local, m); psum over data."""
    q = lam_x_b.shape[0]
    V = jnp.einsum("qni,qnj->qij", lam_x_b, lam_x_b)
    U = jnp.einsum("qni,qnj->qij", lam_z_b, lam_x_b)
    S = jnp.einsum("qni,qnj->qij", lam_z_b, lam_z_b)
    if data_axis is not None:
        V = jax.lax.psum(V, data_axis)
        U = jax.lax.psum(U, data_axis)
        S = jax.lax.psum(S, data_axis)
    Gxx = jnp.sum(V, axis=0)
    Gzx = jnp.sum(U, axis=0)
    Gzz = jnp.sum(S, axis=0)
    Pb = Gxx[None] - V
    Eb = Gzx[None] - U
    Fb = Gzz[None] - S
    fold = jax.vmap(
        lambda p, e, f, v, u, s: _fold_score_lr(p, e, f, v, u, s, n0, n1, lmbda, gamma)
    )
    return jnp.mean(fold(Pb, Eb, Fb, V, U, S))


def block_folds(lam: jnp.ndarray, q: int) -> jnp.ndarray:
    """(n_eff, m) -> (Q, n0, m) fold-blocked view (centering preserved)."""
    n_eff, m = lam.shape
    n0 = n_eff // q
    return lam[: q * n0].reshape(q, n0, m)


def cvlr_scores_batched(lam_x_b, lam_z_b, lmbda=0.01, gamma=0.01):
    """Batched scores for a GES frontier.

    lam_x_b, lam_z_b: (B, Q, n0, m) fold-blocked centered factors.
    Returns (B,) scores.  Pure vmap — shard the B axis with pjit for
    candidate parallelism.
    """
    _, q, n0, _ = lam_x_b.shape
    n1 = (q - 1) * n0
    lm = jnp.asarray(lmbda, lam_x_b.dtype)
    gm = jnp.asarray(gamma, lam_x_b.dtype)
    return jax.vmap(
        lambda lx, lz: _score_from_blocked(lx, lz, n0, n1, lm, gm)
    )(lam_x_b, lam_z_b)


def make_sharded_scorer(mesh: Mesh, data_axis="data", model_axis: str = "model"):
    """shard_map CV-LR frontier scorer on `mesh`.

    Returns a jit'd fn of ((B, Q, n0, m), (B, Q, n0, m)) -> (B,) with
    B sharded over `model_axis` and n0 sharded over `data_axis` (a name or
    a tuple of names — pass ("pod", "data") on the multi-pod mesh so the
    sample shards span pods); Gram blocks psum over the data axes exactly
    as described in the module doc.
    """
    data_axes = (data_axis,) if isinstance(data_axis, str) else tuple(data_axis)
    data_size = 1
    for a in data_axes:
        data_size *= mesh.shape[a]

    def local_fn(lam_x_b, lam_z_b):
        # shapes here are per-device: (B/pm, Q, n0/pd, m)
        b, q, n0_local, _ = lam_x_b.shape
        n0 = n0_local * data_size
        n1 = (q - 1) * n0
        lm = jnp.asarray(0.01, lam_x_b.dtype)
        gm = jnp.asarray(0.01, lam_x_b.dtype)
        # Local Gram blocks for the WHOLE candidate batch, then one fused
        # all-reduce over the data axis (3 tensors, not 3*B): batching the
        # psum amortizes collective latency across the GES frontier.
        # (A concat-Gram [X|Z]^T[X|Z] single-einsum variant was tried and
        # REFUTED: the materialized concat costs an extra write+read that
        # exceeds the duplicate-stream saving — §Perf iteration 7.)
        V = jnp.einsum("bqni,bqnj->bqij", lam_x_b, lam_x_b)
        U = jnp.einsum("bqni,bqnj->bqij", lam_z_b, lam_x_b)
        S = jnp.einsum("bqni,bqnj->bqij", lam_z_b, lam_z_b)
        V, U, S = jax.lax.psum((V, U, S), data_axes)

        def one(v, u, s):
            gxx, gzx, gzz = (jnp.sum(t, axis=0) for t in (v, u, s))
            pb, eb, fb = gxx[None] - v, gzx[None] - u, gzz[None] - s
            fold = jax.vmap(
                lambda p, e, f, vv, uu, ss: _fold_score_lr(
                    p, e, f, vv, uu, ss, n0, n1, lm, gm
                )
            )
            return jnp.mean(fold(pb, eb, fb, v, u, s))

        return jax.vmap(one)(V, U, S)

    spec_in = P(model_axis, None, data_axes if len(data_axes) > 1 else data_axes[0], None)
    spec_out = P(model_axis)
    fn = jax.shard_map(
        local_fn, mesh=mesh, in_specs=(spec_in, spec_in), out_specs=spec_out
    )
    return jax.jit(fn)


def ges_batch_hook(scorer, configs, lmbda=None, gamma=None):
    """`batch_hook` for repro.core.ges.ges: evaluate a whole sweep's local
    scores in one batched (vmapped) call and fill the scorer cache.

    configs: list of (node, parents_tuple).  Uses the scorer's feature
    cache for Lambda construction (host-side ICL), then one vmapped score
    kernel for everything uncached.
    """
    cfg = scorer.config
    lmbda = cfg.lmbda if lmbda is None else lmbda
    gamma = cfg.gamma if gamma is None else gamma
    todo = []
    for node, parents in configs:
        key = (int(node), frozenset(int(p) for p in parents))
        if key not in scorer._score_cache:
            todo.append((node, tuple(sorted(parents))))
    if not todo:
        return 0
    q = cfg.q_folds
    lxs, lzs = [], []
    for node, parents in todo:
        lam_x = scorer.features((node,))
        lam_z = (
            scorer.features(parents) if parents else jnp.zeros_like(lam_x)
        )
        lxs.append(block_folds(lam_x, q))
        lzs.append(block_folds(lam_z, q))
    scores = cvlr_scores_batched(
        jnp.stack(lxs), jnp.stack(lzs), lmbda=lmbda, gamma=gamma
    )
    for (node, parents), s in zip(todo, np.asarray(scores)):
        scorer._score_cache[(int(node), frozenset(parents))] = float(s)
    return len(todo)
