"""Distributed CV-LR scoring — the paper's O(n) claim mapped onto a mesh.

Two parallelism axes (DESIGN.md §2.3):

* **data** — samples.  Each device holds an (n/p, m) row shard of the
  centered factors; every Gram block (P/E/F/V/U/S) is a local contraction
  followed by one `psum` over the data axis (the ONLY collective the score
  needs: 6 m x m tensors per candidate, ~6*128^2*8B = 786 KB — latency-bound,
  not bandwidth-bound).  The m x m fold algebra is replicated: O(Q m^3)
  redundant FLOPs per device, negligible vs the O(n m^2 / p) Gram work.

* **model** — GES frontier candidates.  The forward/backward sweep needs
  hundreds of local scores per step; they batch into a leading axis that
  shards over `model`.

`cvlr_scores_sharded` composes both: (B, Q, n0, m) factors, B over `model`,
n0 over `data`.  Under `shard_map` the collective schedule is explicit and
inspectable — the dry-run (launch/dryrun.py --arch cvlr_paper) lowers this
exact function on the production mesh.

All fold math lives in `score_lowrank.scores_from_fold_blocks`, which is
itself a thin wrapper over the single fold-algebra copy
(`score_lowrank._candidate_fold_scores` — the same core the local engine's
device-bank fold jit gathers into, z-cores + batched Qm Cholesky included)
— this module only adds the einsum-to-blocks step and the collective
schedule, so the local batched frontier engine and the sharded scorer can
never drift apart numerically.  (The local engine's device *bank* tier is
deliberately not used here: under shard_map every candidate's factors are
already device-resident shards with no cross-candidate sharing to cache.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.score_common import config_key
from repro.core.score_lowrank import scores_from_fold_blocks
from repro.kernels import fold_gram_blocks

try:  # jax >= 0.5 exports shard_map at top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - depends on jax version
    from jax.experimental.shard_map import shard_map as _shard_map


def _block_grams(lam_x_b, lam_z_b, data_axes=None, precision="bitwise"):
    """Per-fold test Gram blocks (V, U, S) from fold-blocked factors.

    lam_x_b, lam_z_b: (..., Q, n0_local, m) with any leading batch dims.
    The contraction routes through `repro.kernels.fold_gram_blocks` — the
    same fused fold-Gram strip kernel as the local batched frontier
    engine (tiled Pallas on TPU, einsum elsewhere), so the local and
    sharded scorers share both the fold algebra AND the Gram kernel —
    including the `precision` policy (f32 accumulation on the einsum
    backend under ``"f32_gram"``).
    When `data_axes` is given, the n0 axis is a shard and the blocks are
    summed across it with one fused psum (3 tensors per *batch*, not per
    candidate: batching the all-reduce amortizes collective latency across
    the GES frontier).
    (A concat-Gram [X|Z]^T[X|Z] single-einsum variant was tried and
    REFUTED: the materialized concat costs an extra write+read that
    exceeds the duplicate-stream saving — EXPERIMENTS.md §Perf.)
    """
    V = fold_gram_blocks(lam_x_b, lam_x_b, precision=precision)
    U = fold_gram_blocks(lam_z_b, lam_x_b, precision=precision)
    S = fold_gram_blocks(lam_z_b, lam_z_b, precision=precision)
    if data_axes is not None:
        V, U, S = jax.lax.psum((V, U, S), data_axes)
    return V, U, S


def block_folds(lam: jnp.ndarray, q: int) -> jnp.ndarray:
    """(n_eff, m) -> (Q, n0, m) fold-blocked view (centering preserved)."""
    n_eff, m = lam.shape
    n0 = n_eff // q
    return lam[: q * n0].reshape(q, n0, m)


def cvlr_scores_stacked(lam_x_b, lam_z_b, lmbda=0.01, gamma=0.01, precision="bitwise"):
    """Batched scores for a GES frontier from pre-blocked stacked factors.

    lam_x_b, lam_z_b: (B, Q, n0, m) fold-blocked centered factors.
    Returns (B,) scores.  Pure einsum + the shared fold kernel — shard the
    B axis with pjit for candidate parallelism.  `precision` is the Gram
    accumulation policy (`repro.core.spec.EngineOptions.precision`).
    (The local search path uses `score_lowrank.cvlr_scores_batched`
    instead — a different, bank+pairs signature — which shares Gram
    blocks across candidates through the Gram-block cache.)
    """
    _, q, n0, _ = lam_x_b.shape
    n1 = (q - 1) * n0
    lm = jnp.asarray(lmbda, lam_x_b.dtype)
    gm = jnp.asarray(gamma, lam_x_b.dtype)
    V, U, S = _block_grams(lam_x_b, lam_z_b, precision=precision)
    return scores_from_fold_blocks(V, U, S, n0, n1, lm, gm)


def make_sharded_scorer(
    mesh: Mesh,
    data_axis="data",
    model_axis: str = "model",
    precision: str = "bitwise",
):
    """shard_map CV-LR frontier scorer on `mesh`.

    Returns a jit'd fn of ((B, Q, n0, m), (B, Q, n0, m)) -> (B,) with
    B sharded over `model_axis` and n0 sharded over `data_axis` (a name or
    a tuple of names — pass ("pod", "data") on the multi-pod mesh so the
    sample shards span pods); Gram blocks psum over the data axes exactly
    as described in the module doc.  `precision` is the Gram accumulation
    policy (`repro.core.spec.EngineOptions.precision`).
    """
    data_axes = (data_axis,) if isinstance(data_axis, str) else tuple(data_axis)
    data_size = 1
    for a in data_axes:
        data_size *= mesh.shape[a]

    def local_fn(lam_x_b, lam_z_b):
        # shapes here are per-device: (B/pm, Q, n0/pd, m)
        _, q, n0_local, _ = lam_x_b.shape
        n0 = n0_local * data_size
        n1 = (q - 1) * n0
        lm = jnp.asarray(0.01, lam_x_b.dtype)
        gm = jnp.asarray(0.01, lam_x_b.dtype)
        V, U, S = _block_grams(lam_x_b, lam_z_b, data_axes, precision=precision)
        return scores_from_fold_blocks(V, U, S, n0, n1, lm, gm)

    spec_in = P(model_axis, None, data_axes if len(data_axes) > 1 else data_axes[0], None)
    spec_out = P(model_axis)
    fn = _shard_map(
        local_fn, mesh=mesh, in_specs=(spec_in, spec_in), out_specs=spec_out
    )
    return jax.jit(fn)


def ges_batch_hook(scorer, configs, lmbda=None, gamma=None, precision=None):
    """`batch_hook` for repro.core.ges.ges: evaluate a whole sweep's local
    scores in one batched (vmapped) call and fill the scorer cache.

    configs: list of (node, parents_tuple).  With default hyperparameters
    this delegates to the scorer's own batched frontier engine
    (`CVLRScorer.prefetch`), which shares Gram blocks across candidates;
    with explicit lmbda/gamma overrides it falls back to stacking the
    scorer's feature bank and scoring through the same shared fold kernel.
    `precision` defaults to the scorer's own Gram accumulation policy.
    """
    cfg = scorer.config
    if precision is None:
        precision = getattr(scorer, "precision", "bitwise")
    if lmbda is None and gamma is None and getattr(scorer, "batched", False):
        return scorer.prefetch(configs)
    lmbda = cfg.lmbda if lmbda is None else lmbda
    gamma = cfg.gamma if gamma is None else gamma
    todo = []
    for node, parents in configs:
        key = config_key(node, parents)
        if key not in scorer._score_cache:
            todo.append(key)
    if not todo:
        return 0
    q = cfg.q_folds
    lxs, lzs = [], []
    for node, parents in todo:
        lam_x = scorer.features((node,))
        lam_z = (
            scorer.features(parents) if parents else jnp.zeros_like(lam_x)
        )
        lxs.append(block_folds(lam_x, q))
        lzs.append(block_folds(lam_z, q))
    scores = cvlr_scores_stacked(
        jnp.stack(lxs), jnp.stack(lzs), lmbda=lmbda, gamma=gamma,
        precision=precision,
    )
    for key, s in zip(todo, np.asarray(scores)):
        scorer._score_cache[key] = float(s)
    return len(todo)


def sharded_batch_hook(scorer, configs) -> int:
    """The ``EngineOptions(engine="sharded")`` frontier path: score a GES
    sweep through the *stacked* distributed pipeline (`cvlr_scores_stacked`
    — fold-blocked factors, candidate axis vmapped locally / shardable over
    a mesh's `model` axis) regardless of the scorer's own engine setting.

    `repro.core.api.DiscoverySession` routes frontiers here when the
    options select the sharded engine, so user code never threads a raw
    ``batch_hook`` callable again; passing the scorer's own
    hyperparameters explicitly is what pins `ges_batch_hook` to the
    stacked path instead of delegating back to the local prefetch engine.
    The scorer's `precision` policy rides along, so
    ``EngineOptions(engine="sharded", precision="f32_gram")`` accumulates
    the stacked pipeline's Grams at f32 exactly like the local engine.
    """
    cfg = scorer.config
    return ges_batch_hook(
        scorer,
        configs,
        lmbda=cfg.lmbda,
        gamma=cfg.gamma,
        precision=getattr(scorer, "precision", "bitwise"),
    )
