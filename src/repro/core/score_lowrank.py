"""CV-LR: the paper's low-rank approximate score (Sec. 5) — O(n m^2) time,
O(n m) memory.

Given centered low-rank factors  X = Lambda~_X (n, m),  Z = Lambda~_Z (n, m)
(zero-padded to the fixed pivot budget m; padding is *exact*, every identity
below only ever inverts regularized matrices), one fold with train rows X1/Z1
and test rows X0/Z0 needs only the m x m Gram blocks

    P = X1^T X1   E = Z1^T X1   F = Z1^T Z1          (train)
    V = X0^T X0   U = Z0^T X0   S = Z0^T Z0          (test)

and the score follows from the dumbbell-form identities (paper Eqs. 13-26;
we use the equivalent push-through forms, verified to machine precision in
tests/test_score_lowrank.py):

    D  = (n1 l I + F)^-1                         (Woodbury core, Eq. 13)
    Jt = Z1^T A X1 = (I - F D) E / (n1 l)
    M  = X1^T A^2 X1 = (P - 2 E^T D E + E^T D F D E) / (n1 l)^2   (Eq. 17)
    Q  = I + n1 b M                              (Weinstein-Aronszajn, Eq. 21)
    G  = Q^-1,   W = X1^T C X1 = M G             (push-through of Eqs. 18-19)

    T1 = tr V                                    (Eq. 22)
    T3 = tr(U Jt^T)                              (Eq. 22)
    T2 = tr(S Jt Jt^T)                           (Eq. 22)
    T4 = tr(V W)                                 (Eq. 23)
    T6 = tr(U W Jt^T)                            (Eq. 24)
    T5 = tr(S Jt W Jt^T)                         (Eq. 25)

score = -n0^2/2 log 2pi - n0/2 logdet Q - n0 n1/2 log g
        - [T1 + T2 - 2 T3 - n1 b (T4 + T5) + 2 n1 b T6] / (2 g).

Cross-fold trick (beyond paper, exact): with contiguous test blocks the full
Grams G_xx = X^T X etc. fall out of the per-fold test Grams by summing the
fold axis, and each fold's train blocks are P_q = G_xx - V_q — O(n m^2)
total for ALL Q folds instead of O(Q n m^2).

The module has one copy of the per-fold algebra (`_fold_score_lr_core`,
reached via `scores_from_fold_blocks` when the z-core is computed inline
and via `_scores_zshared_idx` when it is shared), consumed three ways:

* `cvlr_score_from_features` — single-config sequential score (the oracle);
* `cvlr_scores_batched` — the GES frontier engine: a device-resident
  feature bank, an LRU Gram-block cache keyed on (set_a, set_b) so V/U/S
  blocks are computed once per feature *pair* instead of once per
  candidate, live-rank bucketed trimming (zero padding is score-neutral,
  so slicing to the batch's max m_eff is exact), the fused fold-Gram
  strip kernel (`repro.kernels.fold_gram_strip`) for every Gram-block
  stage, a *z-shared fold-core* stage (`_z_fold_cores`: F and the
  Cholesky of (F + n1 l I) depend only on (parent set, fold), so they
  are computed once per parent set and reused across all of its
  children), and chunked batched fold algebra — one device dispatch per
  ~64 candidates instead of one (plus a host sync) per candidate;
* `repro.core.distributed_score` — the same fold algebra and fused
  Gram kernel under shard_map, with Gram blocks psum'd over the data
  axis.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lowrank import lowrank_features
from repro.kernels import fold_gram_strip
from repro.core.score_common import (
    GramBlockCache,
    ScoreConfig,
    ScorerBase,
    VariableView,
    config_key,
    set_key,
)


def _fold_score_lr(P, E, F, V, U, S, n0, n1, lmbda, gamma):
    """One fold from Gram blocks; all O(m^3) or cheaper.

    D = (F + n1 l I)^-1 is never materialized: F is PSD, so one Cholesky
    of the regularized matrix serves every F-solve, and the identities
    only ever need D E (an mz x mx solve, usually mx << mz) and F D E —
    O(mz^2 mx) instead of the O(mz^3) explicit inverse."""
    n1l = n1 * lmbda
    eye_z = jnp.eye(F.shape[0], dtype=P.dtype)
    chol_f = jnp.linalg.cholesky(F + n1l * eye_z)
    return _fold_score_lr_core(P, E, F, chol_f, V, U, S, n0, n1, lmbda, gamma)


def _fold_score_lr_core(P, E, F, chol_f, V, U, S, n0, n1, lmbda, gamma):
    """The single copy of the per-fold dumbbell algebra, with the z-side
    Cholesky factor of (F + n1 l I) supplied by the caller.

    F and chol_f depend only on the *parent set* and the fold — never on
    the child — so the batched frontier engine computes them once per
    (parent set, fold) in its shared-core stage and reuses them across
    every child of that parent set; `_fold_score_lr` recomputes them
    inline for the single-config / distributed paths."""
    mx = P.shape[0]
    dtype = P.dtype
    beta = lmbda * lmbda / gamma
    n1l = n1 * lmbda
    eye_x = jnp.eye(mx, dtype=dtype)

    DE = jax.scipy.linalg.cho_solve((chol_f, True), E)  # D E
    FDE = F @ DE
    Jt = (E - FDE) / n1l  # (I - F D) E / (n1 l) = Z1^T A X1
    M = (P - 2.0 * (E.T @ DE) + DE.T @ FDE) / (n1l * n1l)
    Qm = eye_x + (n1 * beta) * M
    chol = jnp.linalg.cholesky(Qm)
    logdet_q = 2.0 * jnp.sum(jnp.log(jnp.diagonal(chol)))
    G = jax.scipy.linalg.cho_solve((chol, True), eye_x)
    W = M @ G

    SJt = S @ Jt
    t1 = jnp.trace(V)
    t2 = jnp.sum(SJt * Jt)  # tr(S Jt Jt^T)
    t3 = jnp.sum(U * Jt)  # tr(U Jt^T)
    t4 = jnp.sum(V * W.T)  # tr(V W)
    t5 = jnp.sum(SJt * (Jt @ W.T))  # tr(S Jt W Jt^T)
    t6 = jnp.sum((U @ W.T) * Jt)  # tr(U W Jt^T)
    trace_total = t1 + t2 - 2.0 * t3 - (n1 * beta) * (t4 + t5) + 2.0 * (n1 * beta) * t6

    return (
        -0.5 * n0 * n0 * jnp.log(2.0 * jnp.pi)
        - 0.5 * n0 * logdet_q
        - 0.5 * n0 * n1 * jnp.log(gamma)
        - trace_total / (2.0 * gamma)
    )


@partial(jax.jit, static_argnames=("q",))
def cvlr_score_from_features(lam_x, lam_z, q: int, lmbda, gamma):
    """Mean CV-LR score over Q contiguous-block folds.

    lam_x, lam_z: centered factors, shape (n_eff, m) with n_eff = q * n0.
    Total cost O(n m^2) for the Grams + O(q m^3) for the fold algebra.
    Thin single-config wrapper over the shared batched fold kernel: the
    per-fold *test* Grams are one reshape+einsum each, and the full-data
    Grams / train blocks fall out of the fold axis by sum + subtraction
    inside `scores_from_fold_blocks` (exact; no separate full-Gram einsum).
    """
    n_eff, mx = lam_x.shape
    mz = lam_z.shape[1]
    n0 = n_eff // q
    n1 = n_eff - n0

    xb = lam_x.reshape(q, n0, mx)
    zb = lam_z.reshape(q, n0, mz)
    V = jnp.einsum("qni,qnj->qij", xb, xb)
    U = jnp.einsum("qni,qnj->qij", zb, xb)
    S = jnp.einsum("qni,qnj->qij", zb, zb)
    return scores_from_fold_blocks(
        V[None], U[None], S[None], n0, n1, lmbda, gamma
    )[0]


def scores_from_fold_blocks(V, U, S, n0, n1, lmbda, gamma):
    """Batched CV-LR scores from per-fold *test* Gram blocks.

    V: (B, q, mx, mx)  X_q^T X_q       U: (B, q, mz, mx)  Z_q^T X_q
    S: (B, q, mz, mz)  Z_q^T Z_q       ->  (B,) mean-over-folds scores.

    Full-data Grams are recovered by summing the fold axis and each fold's
    train blocks by subtraction (the cross-fold trick, exact).  This is the
    single copy of the fold algebra: the sequential scorer, the batched
    frontier engine and the shard_map distributed scorer all route here.
    Traceable (no jit) so it composes under shard_map/vmap.
    """

    def one(v, u, s):
        gxx = jnp.sum(v, axis=0)
        gzx = jnp.sum(u, axis=0)
        gzz = jnp.sum(s, axis=0)
        fold = jax.vmap(
            lambda p, e, f, vv, uu, ss: _fold_score_lr(
                p, e, f, vv, uu, ss, n0, n1, lmbda, gamma
            )
        )
        return jnp.mean(fold(gxx[None] - v, gzx[None] - u, gzz[None] - s, v, u, s))

    return jax.vmap(one)(V, U, S)


@jax.jit
def _z_fold_cores(S, n1l):
    """Shared z-side fold cores, once per (parent set, fold).

    S: (Nz, q, mz, mz) stacked per-fold test Grams Z_q^T Z_q of the
    distinct parent sets of a sweep.  Returns (F, chol_f), each
    (Nz, q, mz, mz): the train Gram F_q = G_zz - S_q (cross-fold trick)
    and the Cholesky factor of (F_q + n1 l I) — the O(mz^3) piece of the
    fold algebra that does NOT depend on the child, hoisted out of the
    per-candidate score so a parent set pays for it once no matter how
    many of its children the frontier scores.  An all-zero S row (the
    |Z|=0 specialization) yields chol_f = sqrt(n1 l) I exactly.
    """
    gzz = jnp.sum(S, axis=1, keepdims=True)
    F = gzz - S
    eye_z = jnp.eye(S.shape[-1], dtype=S.dtype)
    chol_f = jnp.linalg.cholesky(F + n1l * eye_z)
    return F, chol_f


@partial(jax.jit, static_argnames=("n0", "n1"))
def _scores_zshared_idx(V, U, s_bank, f_bank, chol_bank, iz, n0, n1, lmbda, gamma):
    """Batched CV-LR scores from per-candidate V/U blocks + shared z-cores.

    V: (B, q, mx, mx), U: (B, q, mz, mx) per candidate;
    s_bank/f_bank/chol_bank: (Nz, q, mz, mz) per *parent set* (from
    `_z_fold_cores`); iz: (B,) parent-set bank index per candidate.
    Gathering the cores inside the jit keeps the chunk to one dispatch and
    never re-materializes S per candidate on the host.
    """

    def one(v, u, s, f, ch):
        gxx = jnp.sum(v, axis=0)
        gzx = jnp.sum(u, axis=0)
        fold = jax.vmap(
            lambda p, e, ff, chh, vv, uu, ss: _fold_score_lr_core(
                p, e, ff, chh, vv, uu, ss, n0, n1, lmbda, gamma
            )
        )
        return jnp.mean(
            fold(gxx[None] - v, gzx[None] - u, f, ch, v, u, s)
        )

    return jax.vmap(one)(V, U, s_bank[iz], f_bank[iz], chol_bank[iz])


def _bucket(m: int, cap: int) -> int:
    """Round a live rank up to a small ladder of bucket widths (bounds the
    jit cache) without ever exceeding the padded factor width."""
    m = min(max(int(m), 1), cap)
    for b in _BUCKET_LADDER:
        if m <= b <= cap:
            return b
    return cap


# An extra 80 step between 64 and 96 was tried and REFUTED: the trim
# saving is outweighed by group fragmentation (more bank restacks, more
# pow2-padded short chunks) — measured 32/s vs 75/s on the d=32/n=10k
# frontier cell.
_BUCKET_LADDER = (8, 16, 32, 48, 64, 96)


def _pow2_pad(k: int, hi: int) -> int:
    """Next power of two >= k, capped at hi (shape-stable stack heights)."""
    p = 1
    while p < min(k, hi):
        p *= 2
    return min(p, hi)


def cvlr_scores_batched(
    lam_x_bank,
    lam_z_bank,
    pairs,
    q: int,
    lmbda: float = 0.01,
    gamma: float = 0.01,
    *,
    m_eff_x=None,
    m_eff_z=None,
    x_keys=None,
    z_keys=None,
    gram_cache: GramBlockCache | None = None,
    pair_chunk: int = 32,
    score_chunk: int = 64,
) -> np.ndarray:
    """Score a whole GES frontier in a handful of device dispatches.

    lam_x_bank / lam_z_bank: the *feature bank* — sequences of centered
    (n_eff, m) factors, one entry per distinct variable set (children on
    the x side, candidate parent sets on the z side; a |Z|=0 entry is an
    all-zero factor, the exact Eq.-9 specialization).
    pairs: (B, 2) ints, pairs[b] = (x_bank_idx, z_bank_idx) — one row per
    frontier configuration.  Returns (B,) float64 scores.

    Work is shared at two levels.  Gram blocks: V = X_q^T X_q once per
    child, S = Z_q^T Z_q once per parent set, U = Z_q^T X_q once per
    *unordered* (parent-set, child) factor pair (U(a, b) = U(b, a)^T, so
    the X -> Y and Y -> X candidates of a symmetric frontier share one
    block) — never once per candidate — all produced by
    the fused fold-Gram strip kernel (`repro.kernels.fold_gram_strip`:
    bank-gather + fold-blocked contraction in one dispatch, a tiled
    Pallas kernel on TPU) and stored in `gram_cache` (LRU, keyed on
    (set_key_a, set_key_b)) so they persist across sweeps.  Fold cores:
    the z-side train Gram F_q and its Cholesky factor depend only on
    (parent set, fold), so `_z_fold_cores` computes them once per parent
    set and every child of that set reuses them (the candidates are
    grouped by parent set; see `_scores_zshared_idx`).  Every factor
    takes part only at its *bucketed live rank*:
    zero-padded columns are provably score-neutral
    (tests/test_score_lowrank.py::test_zero_padding_is_exact), so slicing
    to a per-set bucket is exact while cutting the m^2/m^3 terms by the
    (m_max / m_eff)^2 the padding was wasting — and because m_eff varies a
    lot across variable sets (9..88 observed on one SCM draw), the einsum
    and fold phases are *grouped by bucket shape* rather than padded to
    the batch max.  Within a group everything is chunked and padded to
    fixed chunk heights, so the jit cache stays small and no call
    dispatches more than O(B / chunk) kernels.
    """
    pairs = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
    n_pairs = pairs.shape[0]
    if n_pairs == 0:
        return np.zeros((0,), dtype=np.float64)
    lam_x_bank = [jnp.asarray(a) for a in lam_x_bank]
    lam_z_bank = [jnp.asarray(a) for a in lam_z_bank]
    n_eff = lam_x_bank[0].shape[0]
    n0 = n_eff // q
    n1 = n_eff - n0
    if m_eff_x is None:
        m_eff_x = [a.shape[1] for a in lam_x_bank]
    if m_eff_z is None:
        m_eff_z = [a.shape[1] for a in lam_z_bank]
    if x_keys is None:
        x_keys = [("_x", i) for i in range(len(lam_x_bank))]
    if z_keys is None:
        z_keys = [("_z", i) for i in range(len(lam_z_bank))]
    cache = gram_cache if gram_cache is not None else GramBlockCache()

    xs_used = sorted({int(p) for p in pairs[:, 0]})
    zs_used = sorted({int(p) for p in pairs[:, 1]})
    bx = {i: _bucket(m_eff_x[i], lam_x_bank[i].shape[1]) for i in xs_used}
    bz = {
        i: _bucket(m_eff_z[i], lam_z_bank[i].shape[1])
        for i in zs_used
        if m_eff_z[i] > 0
    }

    def _take(a, w):
        return a[:, :w] if a.shape[1] >= w else jnp.pad(
            a, ((0, 0), (0, w - a.shape[1]))
        )

    blocks: dict = {}  # cache-key -> host (q, me_a, me_b) block for this call

    def _gather_missing(needed):
        """One counted cache lookup per needed key; returns keys to compute."""
        missing = []
        for key, spec in needed.items():
            blk = cache.get(key)
            if blk is None:
                missing.append((key, spec))
            else:
                blocks[key] = blk
        return missing

    def _store(key, out_row, ea, eb):
        # copy: a view would pin the whole padded chunk buffer in the cache
        blk = np.ascontiguousarray(out_row[:, :ea, :eb])
        blocks[key] = blk
        cache.put(key, blk)

    def _drain(pending, trim):
        """Second half of the submit/drain pipeline: convert the in-flight
        device chunks to host blocks.  Draining only after every chunk is
        submitted lets JAX's async dispatch overlap device einsums with the
        host-side chunk preparation instead of syncing per chunk."""
        for out_dev, chunk in pending:
            out = np.asarray(out_dev)
            for j, (key, spec) in enumerate(chunk):
                ea, eb = trim(spec)
                _store(key, out[j], ea, eb)

    banks = {"x": lam_x_bank, "z": lam_z_bank}
    m_effs = {"x": m_eff_x, "z": m_eff_z}
    bucks = {"x": bx, "z": bz}

    def _stack_refs(refs, w, cap):
        """One stacked, trimmed device bank for the fused strip kernel:
        refs are (side, bank_idx) pairs; height is pow2-padded (capped at
        `cap`) with zero factors so chunk shapes stay jit-stable."""
        dt = banks[refs[0][0]][0].dtype
        return jnp.stack(
            [_take(banks[s][i], w) for s, i in refs]
            + [jnp.zeros((n_eff, w), dt)]
            * (_pow2_pad(len(refs), cap) - len(refs))
        )

    def _diag_blocks(missing, side):
        """Diagonal per-fold Grams, grouped by bucket width.  Each group
        stacks its unique trimmed factors once (pow2-padded height) and
        runs fused strip-kernel chunks with ia == ib — one dispatch per
        `pair_chunk` sets, no per-chunk restacking."""
        buckets, m_eff = bucks[side], m_effs[side]
        groups: dict = {}
        for key, i in missing:
            groups.setdefault(buckets[i], []).append((key, i))
        pending = []
        for w, items in sorted(groups.items()):
            ids = sorted({i for _, i in items})
            loc = {i: k for k, i in enumerate(ids)}
            st = _stack_refs([(side, i) for i in ids], w, len(banks[side]))
            for c0 in range(0, len(items), pair_chunk):
                chunk = items[c0 : c0 + pair_chunk]
                cpad = _pow2_pad(len(chunk), pair_chunk)
                ii = [loc[i] for _, i in chunk]
                ii += [ii[0]] * (cpad - len(ii))
                idx = np.asarray(ii, np.int32)
                pending.append((fold_gram_strip(st, st, idx, idx, q), chunk))
        _drain(pending, lambda i: (m_eff[i], m_eff[i]))

    def _cross_key(zi, xi):
        """Canonical cache identity of the cross block U = Z_q^T X_q.

        U(a, b) and U(b, a) are fold-wise transposes, so the block is
        keyed on the *unordered* factor pair (ordered by a total,
        type-safe repr order): a frontier that scores both X -> Y and
        Y -> X — every symmetric sweep — computes one block, not two.
        Returns (cache_key, transposed, ((side, idx) canonical a, b)):
        `transposed` tells the consumer the stored block is X_q^T Z_q.
        """
        zk, xk = z_keys[zi], x_keys[xi]
        if repr(zk) <= repr(xk):
            return (zk, xk), False, (("z", zi), ("x", xi))
        return (xk, zk), True, (("x", xi), ("z", zi))

    def _cross_blocks(missing):
        """Cross per-fold Grams A_q^T B_q for canonical factor pairs,
        grouped by (bucket_a, bucket_b).  Each group stacks its unique
        factors once per side (pow2-padded heights) and runs fused
        strip-kernel chunks — one dispatch per `pair_chunk` pairs; on TPU
        the factor rows stream HBM->VMEM once with no gathered
        (B, q, n0, m) intermediate."""
        groups: dict = {}
        for key, (ra, rb) in missing:
            wa = bucks[ra[0]][ra[1]]
            wb = bucks[rb[0]][rb[1]]
            groups.setdefault((wa, wb), []).append((key, (ra, rb)))
        pending = []
        cap = len(lam_x_bank) + len(lam_z_bank)
        for (wa, wb), items in sorted(groups.items()):
            a_refs = sorted({ra for _, (ra, _) in items})
            b_refs = sorted({rb for _, (_, rb) in items})
            a_loc = {r: k for k, r in enumerate(a_refs)}
            b_loc = {r: k for k, r in enumerate(b_refs)}
            aa = _stack_refs(a_refs, wa, cap)
            bb = _stack_refs(b_refs, wb, cap)
            for c0 in range(0, len(items), pair_chunk):
                chunk = items[c0 : c0 + pair_chunk]
                cpad = _pow2_pad(len(chunk), pair_chunk)
                ia = [a_loc[ra] for _, (ra, _) in chunk]
                ib = [b_loc[rb] for _, (_, rb) in chunk]
                ia += [ia[0]] * (cpad - len(ia))
                ib += [ib[0]] * (cpad - len(ib))
                pending.append(
                    (
                        fold_gram_strip(
                            aa, bb, np.asarray(ia, np.int32),
                            np.asarray(ib, np.int32), q,
                        ),
                        chunk,
                    )
                )
        _drain(
            pending,
            lambda ab: (m_effs[ab[0][0]][ab[0][1]], m_effs[ab[1][0]][ab[1][1]]),
        )

    # -- diagonal blocks: V once per child set, S once per parent set ----
    need_v = {}
    for i in xs_used:
        if m_eff_x[i] > 0:
            need_v[(x_keys[i], x_keys[i])] = i
        else:
            blocks[(x_keys[i], x_keys[i])] = np.zeros((q, 0, 0))
    _diag_blocks(_gather_missing(need_v), "x")
    need_s = {}
    for i in zs_used:
        if m_eff_z[i] > 0:
            need_s[(z_keys[i], z_keys[i])] = i
        else:
            blocks[(z_keys[i], z_keys[i])] = np.zeros((q, 0, 0))
    _diag_blocks(_gather_missing(need_s), "z")
    # -- cross blocks: one per unordered (parent-set, child) factor pair -
    need_u = {}
    for xi, zi in {(int(a), int(b)) for a, b in pairs}:
        key, transposed, refs = _cross_key(zi, xi)
        if m_eff_z[zi] == 0:
            mx = m_eff_x[xi]
            blocks[key] = np.zeros((q, mx, 0) if transposed else (q, 0, mx))
        else:
            need_u[key] = refs
    _cross_blocks(_gather_missing(need_u))

    # -- z-shared fold cores: Cholesky once per (parent set, fold) --------
    lm = jnp.asarray(lmbda, jnp.float64)
    gm = jnp.asarray(gamma, jnp.float64)
    n1l = jnp.asarray(n1 * lmbda, jnp.float64)
    wz_of = {zi: bz.get(zi, _BUCKET_LADDER[0]) for zi in zs_used}
    score_groups: dict = {}
    for b, (xi, zi) in enumerate(pairs):
        score_groups.setdefault((wz_of[zi], bx[xi]), []).append(b)
    # Group the sweep's distinct parent sets by bucket width and build the
    # per-width core banks: stacked S blocks -> (F, chol_f) once per
    # parent set, device-resident, reused by every child of that set.  A
    # |Z|=0 set contributes an all-zero S row (the exact specialization).
    z_by_w: dict = {}
    for zi in zs_used:
        z_by_w.setdefault(wz_of[zi], []).append(zi)
    z_cores: dict = {}  # wz -> (s_bank, f_bank, chol_bank) device tensors
    z_loc: dict = {}  # zi -> row in its width's core bank
    for w, zids in sorted(z_by_w.items()):
        npad = _pow2_pad(len(zids), len(lam_z_bank))
        s_host = np.zeros((npad, q, w, w))
        for k, zi in enumerate(sorted(zids)):
            z_loc[zi] = k
            bs = blocks[(z_keys[zi], z_keys[zi])]
            s_host[k, :, : bs.shape[1], : bs.shape[2]] = bs
        s_bank = jnp.asarray(s_host)
        f_bank, chol_bank = _z_fold_cores(s_bank, n1l)
        z_cores[w] = (s_bank, f_bank, chol_bank)

    # -- fold algebra: grouped by (bucket_z, bucket_x), fixed-size chunks -
    scores = np.empty((n_pairs,), dtype=np.float64)
    in_flight = []  # (device scores, target pair indices) — drained at the end
    for (wz, wx), idxs in sorted(score_groups.items()):
        s_bank, f_bank, chol_bank = z_cores[wz]
        g = len(idxs)
        c0 = 0
        while c0 < g:
            # few chunk heights (bounds compile variants): the full chunk,
            # or a pow2 short chunk when the tail is small — padding a
            # 9-pair group to 64 at a large bucket wastes ~7x the fold work
            rem = g - c0
            size = (
                score_chunk
                if rem >= score_chunk // 2
                else max(score_chunk // 4, _pow2_pad(rem, score_chunk))
            )
            hi = min(c0 + size, g)
            # assemble ONLY this chunk's padded V/U blocks: peak host
            # memory stays O(score_chunk), not O(frontier), and the mz x mz
            # S/F/chol tensors are never re-stacked per candidate — the
            # chunk indexes the shared core banks; pad rows repeat row 0
            V = np.zeros((size, q, wx, wx))
            U = np.zeros((size, q, wz, wx))
            iz = np.zeros((size,), np.int32)
            chunk_idxs = idxs[c0:hi] + [idxs[c0]] * (size - (hi - c0))
            for row, b in enumerate(chunk_idxs):
                xi, zi = int(pairs[b, 0]), int(pairs[b, 1])
                bv = blocks[(x_keys[xi], x_keys[xi])]
                ck, transposed, _ = _cross_key(zi, xi)
                bu = blocks[ck]
                if transposed:  # stored as X_q^T Z_q; assignment copies
                    bu = bu.transpose(0, 2, 1)
                V[row, :, : bv.shape[1], : bv.shape[2]] = bv
                U[row, :, : bu.shape[1], : bu.shape[2]] = bu
                iz[row] = z_loc[zi]
            out = _scores_zshared_idx(
                jnp.asarray(V), jnp.asarray(U),
                s_bank, f_bank, chol_bank, jnp.asarray(iz),
                n0, n1, lm, gm,
            )
            in_flight.append((out, np.asarray(idxs[c0:hi])))
            c0 = hi
    for out, target in in_flight:
        scores[target] = np.asarray(out)[: target.shape[0]]
    return scores



class CVLRScorer(ScorerBase):
    """The paper's method: CV-LR local score with Alg. 1/Alg. 2 features."""

    # LRU bound on the Gram-block cache, sized to the sweep working set: a
    # sweep touches d diagonal V blocks, O(d) S blocks and one U block per
    # (parent set, child) pair — ~d + d^2 entries on a sweep-1 frontier —
    # so 4096 holds every block of a d <= 60 sweep with room for the
    # previous sweep's overlap, while bounding a long search's footprint
    # (blocks are (q, m, m) float64, worst case ~0.7 MB each at m = 96).
    DEFAULT_GRAM_CACHE_ENTRIES = 4096

    def __init__(
        self,
        data,
        dims=None,
        discrete=None,
        config: ScoreConfig | None = None,
        batched: bool = True,
        gram_cache_entries: int | None = DEFAULT_GRAM_CACHE_ENTRIES,
    ):
        config = config or ScoreConfig()
        super().__init__(VariableView(data, dims, discrete), config)
        self._feat_cache: dict = {}
        self.m_eff_log: dict = {}  # vars_key -> effective rank (diagnostics)
        self.batched = batched  # False => ges() falls back to lazy local_score
        self.gram_cache = GramBlockCache(max_entries=gram_cache_entries)

    def features(self, vars_key: tuple) -> jnp.ndarray:
        """Centered (n_eff, m_max) factor for a variable set (cached).

        The per-set factors double as the device-resident feature bank of
        the batched frontier engine (`prefetch`)."""
        vars_key = set_key(vars_key)
        if vars_key not in self._feat_cache:
            cols = self.view.columns(vars_key)[self.perm]
            lam, m_eff, _ = lowrank_features(
                cols,
                discrete=self.view.is_discrete(vars_key),
                m_max=self.config.m_max,
                eta=self.config.eta,
                width_factor=self.config.width_factor,
            )
            self._feat_cache[vars_key] = lam
            self.m_eff_log[vars_key] = m_eff
        return self._feat_cache[vars_key]

    def _compute(self, i: int, parents: tuple) -> float:
        """Sequential single-config score — the oracle the batched engine is
        tested against (tests/test_frontier_batch.py)."""
        lam_x = self.features((i,))
        if parents:
            lam_z = self.features(tuple(parents))
        else:
            lam_z = jnp.zeros_like(lam_x)  # exact |Z|=0 specialization
        return float(
            cvlr_score_from_features(
                lam_x,
                lam_z,
                self.config.q_folds,
                jnp.asarray(self.config.lmbda, lam_x.dtype),
                jnp.asarray(self.config.gamma, lam_x.dtype),
            )
        )

    def prefetch(self, configs) -> int:
        """Batched frontier engine: evaluate every uncached (node, parents)
        configuration through `cvlr_scores_batched`, sharing Gram blocks via
        `self.gram_cache`.  Called by ges() once per sweep iteration."""
        if not self.batched:
            return 0
        todo = []
        seen = set()
        for node, parents in configs:
            key = config_key(node, parents)
            if key not in self._score_cache and key not in seen:
                seen.add(key)
                todo.append(key)
        if not todo:
            return 0
        x_sets = sorted({(i,) for i, _ in todo})
        z_sets = sorted({ps for _, ps in todo})
        x_index = {k: j for j, k in enumerate(x_sets)}
        z_index = {k: j for j, k in enumerate(z_sets)}
        lam_x_bank = [self.features(k) for k in x_sets]
        zero = jnp.zeros_like(lam_x_bank[0])
        lam_z_bank = [self.features(k) if k else zero for k in z_sets]
        m_eff_x = [self.m_eff_log[k] for k in x_sets]
        m_eff_z = [self.m_eff_log[k] if k else 0 for k in z_sets]
        pairs = np.array([[x_index[(i,)], z_index[ps]] for i, ps in todo])
        scores = cvlr_scores_batched(
            lam_x_bank,
            lam_z_bank,
            pairs,
            self.config.q_folds,
            self.config.lmbda,
            self.config.gamma,
            m_eff_x=m_eff_x,
            m_eff_z=m_eff_z,
            x_keys=x_sets,
            z_keys=z_sets,
            gram_cache=self.gram_cache,
        )
        for key, s in zip(todo, scores):
            self._score_cache[key] = float(s)
        return len(todo)
