"""CV-LR: the paper's low-rank approximate score (Sec. 5) — O(n m^2) time,
O(n m) memory.

Given centered low-rank factors  X = Lambda~_X (n, m),  Z = Lambda~_Z (n, m)
(zero-padded to the fixed pivot budget m; padding is *exact*, every identity
below only ever inverts regularized matrices), one fold with train rows X1/Z1
and test rows X0/Z0 needs only the m x m Gram blocks

    P = X1^T X1   E = Z1^T X1   F = Z1^T Z1          (train)
    V = X0^T X0   U = Z0^T X0   S = Z0^T Z0          (test)

and the score follows from the dumbbell-form identities (paper Eqs. 13-26;
we use the equivalent push-through forms, verified to machine precision in
tests/test_score_lowrank.py):

    D  = (n1 l I + F)^-1                         (Woodbury core, Eq. 13)
    Jt = Z1^T A X1 = (I - F D) E / (n1 l)
    M  = X1^T A^2 X1 = (P - 2 E^T D E + E^T D F D E) / (n1 l)^2   (Eq. 17)
    Q  = I + n1 b M                              (Weinstein-Aronszajn, Eq. 21)
    G  = Q^-1,   W = X1^T C X1 = M G             (push-through of Eqs. 18-19)

    T1 = tr V                                    (Eq. 22)
    T3 = tr(U Jt^T)                              (Eq. 22)
    T2 = tr(S Jt Jt^T)                           (Eq. 22)
    T4 = tr(V W)                                 (Eq. 23)
    T6 = tr(U W Jt^T)                            (Eq. 24)
    T5 = tr(S Jt W Jt^T)                         (Eq. 25)

score = -n0^2/2 log 2pi - n0/2 logdet Q - n0 n1/2 log g
        - [T1 + T2 - 2 T3 - n1 b (T4 + T5) + 2 n1 b T6] / (2 g).

Cross-fold trick (beyond paper, exact): with contiguous test blocks the full
Grams G_xx = X^T X etc. fall out of the per-fold test Grams by summing the
fold axis, and each fold's train blocks are P_q = G_xx - V_q — O(n m^2)
total for ALL Q folds instead of O(Q n m^2).

The module has one copy of the fold algebra (`scores_from_fold_blocks`),
consumed three ways:

* `cvlr_score_from_features` — single-config sequential score (the oracle);
* `cvlr_scores_batched` — the GES frontier engine: a device-resident
  feature bank, a Gram-block cache keyed on (set_a, set_b) so V/U/S blocks
  are computed once per feature *pair* instead of once per candidate, live-
  rank bucketed trimming (zero padding is score-neutral, so slicing to the
  batch's max m_eff is exact), and chunked batched fold algebra — one
  device dispatch per ~64 candidates instead of one (plus a host sync) per
  candidate;
* `repro.core.distributed_score` — the same kernel under shard_map, with
  Gram blocks psum'd over the data axis.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lowrank import lowrank_features
from repro.core.score_common import (
    GramBlockCache,
    ScoreConfig,
    ScorerBase,
    VariableView,
    config_key,
    set_key,
)


def _fold_score_lr(P, E, F, V, U, S, n0, n1, lmbda, gamma):
    """One fold from Gram blocks; all O(m^3) or cheaper.

    D = (F + n1 l I)^-1 is never materialized: F is PSD, so one Cholesky
    of the regularized matrix serves every F-solve, and the identities
    only ever need D E (an mz x mx solve, usually mx << mz) and F D E —
    O(mz^2 mx) instead of the O(mz^3) explicit inverse."""
    mx, mz = P.shape[0], F.shape[0]
    dtype = P.dtype
    beta = lmbda * lmbda / gamma
    n1l = n1 * lmbda
    eye_x = jnp.eye(mx, dtype=dtype)
    eye_z = jnp.eye(mz, dtype=dtype)

    chol_f = jnp.linalg.cholesky(F + n1l * eye_z)
    DE = jax.scipy.linalg.cho_solve((chol_f, True), E)  # D E
    FDE = F @ DE
    Jt = (E - FDE) / n1l  # (I - F D) E / (n1 l) = Z1^T A X1
    M = (P - 2.0 * (E.T @ DE) + DE.T @ FDE) / (n1l * n1l)
    Qm = eye_x + (n1 * beta) * M
    chol = jnp.linalg.cholesky(Qm)
    logdet_q = 2.0 * jnp.sum(jnp.log(jnp.diagonal(chol)))
    G = jax.scipy.linalg.cho_solve((chol, True), eye_x)
    W = M @ G

    SJt = S @ Jt
    t1 = jnp.trace(V)
    t2 = jnp.sum(SJt * Jt)  # tr(S Jt Jt^T)
    t3 = jnp.sum(U * Jt)  # tr(U Jt^T)
    t4 = jnp.sum(V * W.T)  # tr(V W)
    t5 = jnp.sum(SJt * (Jt @ W.T))  # tr(S Jt W Jt^T)
    t6 = jnp.sum((U @ W.T) * Jt)  # tr(U W Jt^T)
    trace_total = t1 + t2 - 2.0 * t3 - (n1 * beta) * (t4 + t5) + 2.0 * (n1 * beta) * t6

    return (
        -0.5 * n0 * n0 * jnp.log(2.0 * jnp.pi)
        - 0.5 * n0 * logdet_q
        - 0.5 * n0 * n1 * jnp.log(gamma)
        - trace_total / (2.0 * gamma)
    )


@partial(jax.jit, static_argnames=("q",))
def cvlr_score_from_features(lam_x, lam_z, q: int, lmbda, gamma):
    """Mean CV-LR score over Q contiguous-block folds.

    lam_x, lam_z: centered factors, shape (n_eff, m) with n_eff = q * n0.
    Total cost O(n m^2) for the Grams + O(q m^3) for the fold algebra.
    Thin single-config wrapper over the shared batched fold kernel: the
    per-fold *test* Grams are one reshape+einsum each, and the full-data
    Grams / train blocks fall out of the fold axis by sum + subtraction
    inside `scores_from_fold_blocks` (exact; no separate full-Gram einsum).
    """
    n_eff, mx = lam_x.shape
    mz = lam_z.shape[1]
    n0 = n_eff // q
    n1 = n_eff - n0

    xb = lam_x.reshape(q, n0, mx)
    zb = lam_z.reshape(q, n0, mz)
    V = jnp.einsum("qni,qnj->qij", xb, xb)
    U = jnp.einsum("qni,qnj->qij", zb, xb)
    S = jnp.einsum("qni,qnj->qij", zb, zb)
    return scores_from_fold_blocks(
        V[None], U[None], S[None], n0, n1, lmbda, gamma
    )[0]


def scores_from_fold_blocks(V, U, S, n0, n1, lmbda, gamma):
    """Batched CV-LR scores from per-fold *test* Gram blocks.

    V: (B, q, mx, mx)  X_q^T X_q       U: (B, q, mz, mx)  Z_q^T X_q
    S: (B, q, mz, mz)  Z_q^T Z_q       ->  (B,) mean-over-folds scores.

    Full-data Grams are recovered by summing the fold axis and each fold's
    train blocks by subtraction (the cross-fold trick, exact).  This is the
    single copy of the fold algebra: the sequential scorer, the batched
    frontier engine and the shard_map distributed scorer all route here.
    Traceable (no jit) so it composes under shard_map/vmap.
    """

    def one(v, u, s):
        gxx = jnp.sum(v, axis=0)
        gzx = jnp.sum(u, axis=0)
        gzz = jnp.sum(s, axis=0)
        fold = jax.vmap(
            lambda p, e, f, vv, uu, ss: _fold_score_lr(
                p, e, f, vv, uu, ss, n0, n1, lmbda, gamma
            )
        )
        return jnp.mean(fold(gxx[None] - v, gzx[None] - u, gzz[None] - s, v, u, s))

    return jax.vmap(one)(V, U, S)


cvlr_scores_from_blocks = partial(jax.jit, static_argnames=("n0", "n1"))(
    scores_from_fold_blocks
)


@partial(jax.jit, static_argnames=("q",))
def _fold_block_grams(fa, fb, q: int):
    """Per-fold test Gram blocks for a stack of factor pairs.

    fa: (B, n_eff, ma), fb: (B, n_eff, mb)  ->  (B, q, ma, mb) with
    out[b, i] = fa[b, fold_i]^T fb[b, fold_i].  One einsum for the whole
    stack: O(B n ma mb) and a single device dispatch.
    """
    b, n_eff, ma = fa.shape
    n0 = n_eff // q
    fa_b = fa.reshape(b, q, n0, ma)
    fb_b = fb.reshape(b, q, n0, fb.shape[-1])
    return jnp.einsum("bqni,bqnj->bqij", fa_b, fb_b)


@partial(jax.jit, static_argnames=("q",))
def _fold_block_grams_idx(bank_a, bank_b, ia, ib, q: int):
    """Gather-then-Gram, fused in one dispatch: bank_a (Sa, n_eff, ma) and
    bank_b (Sb, n_eff, mb) are stacked trimmed feature banks, ia/ib (C,)
    index the pairs of a chunk.  Gathering *inside* the jit keeps the
    per-chunk host work to a single call — per-pair jnp.stack of bank
    slices was measured at ~0.2 s/chunk of pure dispatch overhead, 15x the
    einsum itself."""
    return _fold_block_grams(bank_a[ia], bank_b[ib], q)


def _bucket(m: int, cap: int) -> int:
    """Round a live rank up to a small ladder of bucket widths (bounds the
    jit cache) without ever exceeding the padded factor width."""
    m = min(max(int(m), 1), cap)
    for b in _BUCKET_LADDER:
        if m <= b <= cap:
            return b
    return cap


_BUCKET_LADDER = (8, 16, 32, 48, 64, 96)


def _pow2_pad(k: int, hi: int) -> int:
    """Next power of two >= k, capped at hi (shape-stable stack heights)."""
    p = 1
    while p < min(k, hi):
        p *= 2
    return min(p, hi)


def cvlr_scores_batched(
    lam_x_bank,
    lam_z_bank,
    pairs,
    q: int,
    lmbda: float = 0.01,
    gamma: float = 0.01,
    *,
    m_eff_x=None,
    m_eff_z=None,
    x_keys=None,
    z_keys=None,
    gram_cache: GramBlockCache | None = None,
    pair_chunk: int = 32,
    score_chunk: int = 64,
) -> np.ndarray:
    """Score a whole GES frontier in a handful of device dispatches.

    lam_x_bank / lam_z_bank: the *feature bank* — sequences of centered
    (n_eff, m) factors, one entry per distinct variable set (children on
    the x side, candidate parent sets on the z side; a |Z|=0 entry is an
    all-zero factor, the exact Eq.-9 specialization).
    pairs: (B, 2) ints, pairs[b] = (x_bank_idx, z_bank_idx) — one row per
    frontier configuration.  Returns (B,) float64 scores.

    Work is shared at the Gram-block level: V = X_q^T X_q once per child,
    S = Z_q^T Z_q once per parent set, U = Z_q^T X_q once per (parent-set,
    child) pair — never once per candidate — with blocks stored in
    `gram_cache` (keyed on (set_key_a, set_key_b)) so they persist across
    sweeps.  Every factor takes part only at its *bucketed live rank*:
    zero-padded columns are provably score-neutral
    (tests/test_score_lowrank.py::test_zero_padding_is_exact), so slicing
    to a per-set bucket is exact while cutting the m^2/m^3 terms by the
    (m_max / m_eff)^2 the padding was wasting — and because m_eff varies a
    lot across variable sets (9..88 observed on one SCM draw), the einsum
    and fold phases are *grouped by bucket shape* rather than padded to
    the batch max.  Within a group everything is chunked and padded to
    fixed chunk heights, so the jit cache stays small and no call
    dispatches more than O(B / chunk) kernels.
    """
    pairs = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
    n_pairs = pairs.shape[0]
    if n_pairs == 0:
        return np.zeros((0,), dtype=np.float64)
    lam_x_bank = [jnp.asarray(a) for a in lam_x_bank]
    lam_z_bank = [jnp.asarray(a) for a in lam_z_bank]
    n_eff = lam_x_bank[0].shape[0]
    n0 = n_eff // q
    n1 = n_eff - n0
    if m_eff_x is None:
        m_eff_x = [a.shape[1] for a in lam_x_bank]
    if m_eff_z is None:
        m_eff_z = [a.shape[1] for a in lam_z_bank]
    if x_keys is None:
        x_keys = [("_x", i) for i in range(len(lam_x_bank))]
    if z_keys is None:
        z_keys = [("_z", i) for i in range(len(lam_z_bank))]
    cache = gram_cache if gram_cache is not None else GramBlockCache()

    xs_used = sorted({int(p) for p in pairs[:, 0]})
    zs_used = sorted({int(p) for p in pairs[:, 1]})
    bx = {i: _bucket(m_eff_x[i], lam_x_bank[i].shape[1]) for i in xs_used}
    bz = {
        i: _bucket(m_eff_z[i], lam_z_bank[i].shape[1])
        for i in zs_used
        if m_eff_z[i] > 0
    }

    def _take(a, w):
        return a[:, :w] if a.shape[1] >= w else jnp.pad(
            a, ((0, 0), (0, w - a.shape[1]))
        )

    blocks: dict = {}  # cache-key -> host (q, me_a, me_b) block for this call

    def _gather_missing(needed):
        """One counted cache lookup per needed key; returns keys to compute."""
        missing = []
        for key, spec in needed.items():
            blk = cache.get(key)
            if blk is None:
                missing.append((key, spec))
            else:
                blocks[key] = blk
        return missing

    def _store(key, out_row, ea, eb):
        # copy: a view would pin the whole padded chunk buffer in the cache
        blk = np.ascontiguousarray(out_row[:, :ea, :eb])
        blocks[key] = blk
        cache.put(key, blk)

    def _drain(pending, trim):
        """Second half of the submit/drain pipeline: convert the in-flight
        device chunks to host blocks.  Draining only after every chunk is
        submitted lets JAX's async dispatch overlap device einsums with the
        host-side chunk preparation instead of syncing per chunk."""
        for out_dev, chunk in pending:
            out = np.asarray(out_dev)
            for j, (key, spec) in enumerate(chunk):
                ea, eb = trim(spec)
                _store(key, out[j], ea, eb)

    def _diag_blocks(missing, bank, m_eff, buckets):
        """Diagonal per-fold Grams, grouped by bucket width, chunked with
        pow2-padded stack heights (shape-stable, cheap einsum variants)."""
        groups: dict = {}
        for key, i in missing:
            groups.setdefault(buckets[i], []).append((key, i))
        pending = []
        for w, items in sorted(groups.items()):
            for c0 in range(0, len(items), pair_chunk):
                chunk = items[c0 : c0 + pair_chunk]
                cpad = _pow2_pad(len(chunk), pair_chunk)
                ids = [i for _, i in chunk]
                ids += [ids[0]] * (cpad - len(ids))
                st = jnp.stack([_take(bank[i], w) for i in ids])
                pending.append((_fold_block_grams(st, st, q), chunk))
        _drain(pending, lambda i: (m_eff[i], m_eff[i]))

    def _cross_blocks(missing):
        """Cross per-fold Grams U = Z_q^T X_q, grouped by (bucket_z,
        bucket_x).  Each group stacks its unique z / x factors once
        (pow2-padded heights) and runs fused gather+Gram chunks — one
        dispatch per `pair_chunk` pairs."""
        groups: dict = {}
        for key, (zi, xi) in missing:
            groups.setdefault((bz[zi], bx[xi]), []).append((key, (zi, xi)))
        pending = []
        for (wz, wx), items in sorted(groups.items()):
            z_ids = sorted({zi for _, (zi, _) in items})
            x_ids = sorted({xi for _, (_, xi) in items})
            z_pad = _pow2_pad(len(z_ids), len(lam_z_bank))
            x_pad = _pow2_pad(len(x_ids), len(lam_x_bank))
            z_loc = {i: k for k, i in enumerate(z_ids)}
            x_loc = {i: k for k, i in enumerate(x_ids)}
            dt = lam_z_bank[0].dtype
            za = jnp.stack(
                [_take(lam_z_bank[i], wz) for i in z_ids]
                + [jnp.zeros((n_eff, wz), dt)] * (z_pad - len(z_ids))
            )
            xa = jnp.stack(
                [_take(lam_x_bank[i], wx) for i in x_ids]
                + [jnp.zeros((n_eff, wx), dt)] * (x_pad - len(x_ids))
            )
            for c0 in range(0, len(items), pair_chunk):
                chunk = items[c0 : c0 + pair_chunk]
                cpad = _pow2_pad(len(chunk), pair_chunk)
                ia = [z_loc[zi] for _, (zi, _) in chunk]
                ib = [x_loc[xi] for _, (_, xi) in chunk]
                ia += [ia[0]] * (cpad - len(ia))
                ib += [ib[0]] * (cpad - len(ib))
                pending.append(
                    (
                        _fold_block_grams_idx(
                            za, xa, jnp.asarray(ia), jnp.asarray(ib), q
                        ),
                        chunk,
                    )
                )
        _drain(pending, lambda zx: (m_eff_z[zx[0]], m_eff_x[zx[1]]))

    # -- diagonal blocks: V once per child set, S once per parent set ----
    need_v = {}
    for i in xs_used:
        if m_eff_x[i] > 0:
            need_v[(x_keys[i], x_keys[i])] = i
        else:
            blocks[(x_keys[i], x_keys[i])] = np.zeros((q, 0, 0))
    _diag_blocks(_gather_missing(need_v), lam_x_bank, m_eff_x, bx)
    need_s = {}
    for i in zs_used:
        if m_eff_z[i] > 0:
            need_s[(z_keys[i], z_keys[i])] = i
        else:
            blocks[(z_keys[i], z_keys[i])] = np.zeros((q, 0, 0))
    _diag_blocks(_gather_missing(need_s), lam_z_bank, m_eff_z, bz)
    # -- cross blocks: U once per (parent-set, child) pair ---------------
    need_u = {}
    for xi, zi in {(int(a), int(b)) for a, b in pairs}:
        if m_eff_z[zi] == 0:
            blocks[(z_keys[zi], x_keys[xi])] = np.zeros((q, 0, m_eff_x[xi]))
        else:
            need_u[(z_keys[zi], x_keys[xi])] = (zi, xi)
    _cross_blocks(_gather_missing(need_u))

    # -- fold algebra: grouped by (bucket_z, bucket_x), fixed-size chunks -
    lm = jnp.asarray(lmbda, jnp.float64)
    gm = jnp.asarray(gamma, jnp.float64)
    score_groups: dict = {}
    for b, (xi, zi) in enumerate(pairs):
        wkey = (bz.get(zi, _BUCKET_LADDER[0]), bx[xi])
        score_groups.setdefault(wkey, []).append(b)
    scores = np.empty((n_pairs,), dtype=np.float64)
    in_flight = []  # (device scores, target pair indices) — drained at the end
    for (wz, wx), idxs in sorted(score_groups.items()):
        g = len(idxs)
        c0 = 0
        while c0 < g:
            # few chunk heights (bounds compile variants): the full chunk,
            # or a pow2 short chunk when the tail is small — padding a
            # 9-pair group to 64 at a large bucket wastes ~7x the fold work
            rem = g - c0
            size = (
                score_chunk
                if rem >= score_chunk // 2
                else max(score_chunk // 4, _pow2_pad(rem, score_chunk))
            )
            hi = min(c0 + size, g)
            # assemble ONLY this chunk's padded blocks: peak host memory
            # stays O(score_chunk), not O(frontier); pad rows repeat row 0
            V = np.zeros((size, q, wx, wx))
            U = np.zeros((size, q, wz, wx))
            S = np.zeros((size, q, wz, wz))
            chunk_idxs = idxs[c0:hi] + [idxs[c0]] * (size - (hi - c0))
            for row, b in enumerate(chunk_idxs):
                xi, zi = int(pairs[b, 0]), int(pairs[b, 1])
                bv = blocks[(x_keys[xi], x_keys[xi])]
                bu = blocks[(z_keys[zi], x_keys[xi])]
                bs = blocks[(z_keys[zi], z_keys[zi])]
                V[row, :, : bv.shape[1], : bv.shape[2]] = bv
                U[row, :, : bu.shape[1], : bu.shape[2]] = bu
                S[row, :, : bs.shape[1], : bs.shape[2]] = bs
            out = cvlr_scores_from_blocks(
                jnp.asarray(V), jnp.asarray(U), jnp.asarray(S),
                n0, n1, lm, gm,
            )
            in_flight.append((out, np.asarray(idxs[c0:hi])))
            c0 = hi
    for out, target in in_flight:
        scores[target] = np.asarray(out)[: target.shape[0]]
    return scores



class CVLRScorer(ScorerBase):
    """The paper's method: CV-LR local score with Alg. 1/Alg. 2 features."""

    def __init__(
        self,
        data,
        dims=None,
        discrete=None,
        config: ScoreConfig | None = None,
        batched: bool = True,
    ):
        config = config or ScoreConfig()
        super().__init__(VariableView(data, dims, discrete), config)
        self._feat_cache: dict = {}
        self.m_eff_log: dict = {}  # vars_key -> effective rank (diagnostics)
        self.batched = batched  # False => ges() falls back to lazy local_score
        self.gram_cache = GramBlockCache()

    def features(self, vars_key: tuple) -> jnp.ndarray:
        """Centered (n_eff, m_max) factor for a variable set (cached).

        The per-set factors double as the device-resident feature bank of
        the batched frontier engine (`prefetch`)."""
        vars_key = set_key(vars_key)
        if vars_key not in self._feat_cache:
            cols = self.view.columns(vars_key)[self.perm]
            lam, m_eff, _ = lowrank_features(
                cols,
                discrete=self.view.is_discrete(vars_key),
                m_max=self.config.m_max,
                eta=self.config.eta,
                width_factor=self.config.width_factor,
            )
            self._feat_cache[vars_key] = lam
            self.m_eff_log[vars_key] = m_eff
        return self._feat_cache[vars_key]

    def _compute(self, i: int, parents: tuple) -> float:
        """Sequential single-config score — the oracle the batched engine is
        tested against (tests/test_frontier_batch.py)."""
        lam_x = self.features((i,))
        if parents:
            lam_z = self.features(tuple(parents))
        else:
            lam_z = jnp.zeros_like(lam_x)  # exact |Z|=0 specialization
        return float(
            cvlr_score_from_features(
                lam_x,
                lam_z,
                self.config.q_folds,
                jnp.asarray(self.config.lmbda, lam_x.dtype),
                jnp.asarray(self.config.gamma, lam_x.dtype),
            )
        )

    def prefetch(self, configs) -> int:
        """Batched frontier engine: evaluate every uncached (node, parents)
        configuration through `cvlr_scores_batched`, sharing Gram blocks via
        `self.gram_cache`.  Called by ges() once per sweep iteration."""
        if not self.batched:
            return 0
        todo = []
        seen = set()
        for node, parents in configs:
            key = config_key(node, parents)
            if key not in self._score_cache and key not in seen:
                seen.add(key)
                todo.append(key)
        if not todo:
            return 0
        x_sets = sorted({(i,) for i, _ in todo})
        z_sets = sorted({ps for _, ps in todo})
        x_index = {k: j for j, k in enumerate(x_sets)}
        z_index = {k: j for j, k in enumerate(z_sets)}
        lam_x_bank = [self.features(k) for k in x_sets]
        zero = jnp.zeros_like(lam_x_bank[0])
        lam_z_bank = [self.features(k) if k else zero for k in z_sets]
        m_eff_x = [self.m_eff_log[k] for k in x_sets]
        m_eff_z = [self.m_eff_log[k] if k else 0 for k in z_sets]
        pairs = np.array([[x_index[(i,)], z_index[ps]] for i, ps in todo])
        scores = cvlr_scores_batched(
            lam_x_bank,
            lam_z_bank,
            pairs,
            self.config.q_folds,
            self.config.lmbda,
            self.config.gamma,
            m_eff_x=m_eff_x,
            m_eff_z=m_eff_z,
            x_keys=x_sets,
            z_keys=z_sets,
            gram_cache=self.gram_cache,
        )
        for key, s in zip(todo, scores):
            self._score_cache[key] = float(s)
        return len(todo)
