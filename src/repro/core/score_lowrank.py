"""CV-LR: the paper's low-rank approximate score (Sec. 5) — O(n m^2) time,
O(n m) memory.

Given centered low-rank factors  X = Lambda~_X (n, m),  Z = Lambda~_Z (n, m)
(zero-padded to the fixed pivot budget m; padding is *exact*, every identity
below only ever inverts regularized matrices), one fold with train rows X1/Z1
and test rows X0/Z0 needs only the m x m Gram blocks

    P = X1^T X1   E = Z1^T X1   F = Z1^T Z1          (train)
    V = X0^T X0   U = Z0^T X0   S = Z0^T Z0          (test)

and the score follows from the dumbbell-form identities (paper Eqs. 13-26;
we use the equivalent push-through forms, verified to machine precision in
tests/test_score_lowrank.py):

    D  = (n1 l I + F)^-1                         (Woodbury core, Eq. 13)
    Jt = Z1^T A X1 = (I - F D) E / (n1 l)
    M  = X1^T A^2 X1 = (P - 2 E^T D E + E^T D F D E) / (n1 l)^2   (Eq. 17)
    Q  = I + n1 b M                              (Weinstein-Aronszajn, Eq. 21)
    G  = Q^-1,   W = X1^T C X1 = M G             (push-through of Eqs. 18-19)

    T1 = tr V                                    (Eq. 22)
    T3 = tr(U Jt^T)                              (Eq. 22)
    T2 = tr(S Jt Jt^T)                           (Eq. 22)
    T4 = tr(V W)                                 (Eq. 23)
    T6 = tr(U W Jt^T)                            (Eq. 24)
    T5 = tr(S Jt W Jt^T)                         (Eq. 25)

score = -n0^2/2 log 2pi - n0/2 logdet Q - n0 n1/2 log g
        - [T1 + T2 - 2 T3 - n1 b (T4 + T5) + 2 n1 b T6] / (2 g).

Cross-fold trick (beyond paper, exact): with contiguous test blocks the full
Grams G_xx = X^T X etc. are computed once and each fold's train blocks are
P_q = G_xx - V_q — O(n m^2) total for ALL Q folds instead of O(Q n m^2).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lowrank import lowrank_features
from repro.core.score_common import ScoreConfig, ScorerBase, VariableView


def _fold_score_lr(P, E, F, V, U, S, n0, n1, lmbda, gamma):
    """One fold from Gram blocks; all O(m^3)."""
    mx, mz = P.shape[0], F.shape[0]
    dtype = P.dtype
    beta = lmbda * lmbda / gamma
    n1l = n1 * lmbda
    eye_x = jnp.eye(mx, dtype=dtype)
    eye_z = jnp.eye(mz, dtype=dtype)

    D = jnp.linalg.solve(F + n1l * eye_z, eye_z)
    IFD = eye_z - F @ D  # (I - F D);  (I - D F) = IFD^T
    Jt = (IFD @ E) / n1l  # Z1^T A X1
    DE = D @ E
    M = (P - 2.0 * (E.T @ DE) + DE.T @ F @ DE) / (n1l * n1l)
    Qm = eye_x + (n1 * beta) * M
    chol = jnp.linalg.cholesky(Qm)
    logdet_q = 2.0 * jnp.sum(jnp.log(jnp.diagonal(chol)))
    G = jax.scipy.linalg.cho_solve((chol, True), eye_x)
    W = M @ G

    SJt = S @ Jt
    t1 = jnp.trace(V)
    t2 = jnp.sum(SJt * Jt)  # tr(S Jt Jt^T)
    t3 = jnp.sum(U * Jt)  # tr(U Jt^T)
    t4 = jnp.sum(V * W.T)  # tr(V W)
    t5 = jnp.sum(SJt * (Jt @ W.T))  # tr(S Jt W Jt^T)
    t6 = jnp.sum((U @ W.T) * Jt)  # tr(U W Jt^T)
    trace_total = t1 + t2 - 2.0 * t3 - (n1 * beta) * (t4 + t5) + 2.0 * (n1 * beta) * t6

    return (
        -0.5 * n0 * n0 * jnp.log(2.0 * jnp.pi)
        - 0.5 * n0 * logdet_q
        - 0.5 * n0 * n1 * jnp.log(gamma)
        - trace_total / (2.0 * gamma)
    )


@partial(jax.jit, static_argnames=("q",))
def cvlr_score_from_features(lam_x, lam_z, q: int, lmbda, gamma):
    """Mean CV-LR score over Q contiguous-block folds.

    lam_x, lam_z: centered factors, shape (n_eff, m) with n_eff = q * n0.
    Total cost O(n m^2) for the Grams + O(q m^3) for the fold algebra.
    """
    n_eff, mx = lam_x.shape
    mz = lam_z.shape[1]
    n0 = n_eff // q
    n1 = n_eff - n0

    xb = lam_x.reshape(q, n0, mx)
    zb = lam_z.reshape(q, n0, mz)
    # Per-fold *test* Grams, all folds at once: O(n m^2).
    V = jnp.einsum("qni,qnj->qij", xb, xb)
    U = jnp.einsum("qni,qnj->qij", zb, xb)
    S = jnp.einsum("qni,qnj->qij", zb, zb)
    # Full-data Grams once; train blocks by subtraction (exact).
    Gxx = lam_x.T @ lam_x
    Gzx = lam_z.T @ lam_x
    Gzz = lam_z.T @ lam_z
    P = Gxx[None] - V
    E = Gzx[None] - U
    F = Gzz[None] - S

    fold = jax.vmap(
        lambda p, e, f, v, u, s: _fold_score_lr(
            p, e, f, v, u, s, n0, n1, lmbda, gamma
        )
    )
    return jnp.mean(fold(P, E, F, V, U, S))


class CVLRScorer(ScorerBase):
    """The paper's method: CV-LR local score with Alg. 1/Alg. 2 features."""

    def __init__(
        self,
        data,
        dims=None,
        discrete=None,
        config: ScoreConfig | None = None,
    ):
        config = config or ScoreConfig()
        super().__init__(VariableView(data, dims, discrete), config)
        self._feat_cache: dict = {}
        self.m_eff_log: dict = {}  # vars_key -> effective rank (diagnostics)

    def features(self, vars_key: tuple) -> jnp.ndarray:
        """Centered (n_eff, m_max) factor for a variable set (cached)."""
        vars_key = tuple(sorted(int(v) for v in vars_key))
        if vars_key not in self._feat_cache:
            cols = self.view.columns(vars_key)[self.perm]
            lam, m_eff, _ = lowrank_features(
                cols,
                discrete=self.view.is_discrete(vars_key),
                m_max=self.config.m_max,
                eta=self.config.eta,
                width_factor=self.config.width_factor,
            )
            self._feat_cache[vars_key] = lam
            self.m_eff_log[vars_key] = m_eff
        return self._feat_cache[vars_key]

    def _compute(self, i: int, parents: tuple) -> float:
        lam_x = self.features((i,))
        if parents:
            lam_z = self.features(tuple(parents))
        else:
            lam_z = jnp.zeros_like(lam_x)  # exact |Z|=0 specialization
        return float(
            cvlr_score_from_features(
                lam_x,
                lam_z,
                self.config.q_folds,
                jnp.asarray(self.config.lmbda, lam_x.dtype),
                jnp.asarray(self.config.gamma, lam_x.dtype),
            )
        )
