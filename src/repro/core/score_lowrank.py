"""CV-LR: the paper's low-rank approximate score (Sec. 5) — O(n m^2) time,
O(n m) memory.

Given centered low-rank factors  X = Lambda~_X (n, m),  Z = Lambda~_Z (n, m)
(zero-padded to the fixed pivot budget m; padding is *exact*, every identity
below only ever inverts regularized matrices), one fold with train rows X1/Z1
and test rows X0/Z0 needs only the m x m Gram blocks

    P = X1^T X1   E = Z1^T X1   F = Z1^T Z1          (train)
    V = X0^T X0   U = Z0^T X0   S = Z0^T Z0          (test)

and the score follows from the dumbbell-form identities (paper Eqs. 13-26;
we use the equivalent push-through forms, verified to machine precision in
tests/test_score_lowrank.py):

    D  = (n1 l I + F)^-1                         (Woodbury core, Eq. 13)
    Jt = Z1^T A X1 = (I - F D) E / (n1 l)
    M  = X1^T A^2 X1 = (P - 2 E^T D E + E^T D F D E) / (n1 l)^2   (Eq. 17)
    Q  = I + n1 b M                              (Weinstein-Aronszajn, Eq. 21)
    G  = Q^-1,   W = X1^T C X1 = M G             (push-through of Eqs. 18-19)

    T1 = tr V                                    (Eq. 22)
    T3 = tr(U Jt^T)                              (Eq. 22)
    T2 = tr(S Jt Jt^T)                           (Eq. 22)
    T4 = tr(V W)                                 (Eq. 23)
    T6 = tr(U W Jt^T)                            (Eq. 24)
    T5 = tr(S Jt W Jt^T)                         (Eq. 25)

score = -n0^2/2 log 2pi - n0/2 logdet Q - n0 n1/2 log g
        - [T1 + T2 - 2 T3 - n1 b (T4 + T5) + 2 n1 b T6] / (2 g).

Cross-fold trick (beyond paper, exact): with contiguous test blocks the full
Grams G_xx = X^T X etc. fall out of the per-fold test Grams by summing the
fold axis, and each fold's train blocks are P_q = G_xx - V_q — O(n m^2)
total for ALL Q folds instead of O(Q n m^2).

The module has ONE copy of the per-fold algebra, `_candidate_fold_scores`
(all folds of one candidate; the z-side Cholesky is supplied per parent set
and the x-side Qm Cholesky is one *batched* factorization across the folds
— under the candidate vmap that makes it one LAPACK-batched call per score
chunk), consumed three ways:

* `cvlr_score_from_features` — single-config sequential score (the oracle),
  via `scores_from_fold_blocks`;
* `cvlr_scores_batched` — the GES frontier engine: a device-resident
  feature bank, a two-tier LRU Gram-block cache keyed on (set_a, set_b),
  live-rank bucketed trimming, the fused fold-Gram strip kernels for every
  Gram-block stage, z-shared fold cores (F + Cholesky once per parent
  set), and — the device-resident fold pipeline — Gram blocks scattered at
  compute time into padded per-width device bank tensors
  (`score_common.DeviceGramBank`) that the fold stage index-gathers inside
  one jit (`_scores_bankfold_idx`), so blocks never round-trip through
  host `np.zeros` chunk assembly between the Gram and fold stages;
* `repro.core.distributed_score` — the same candidate fold core under
  shard_map, with Gram blocks psum'd over the data axis.
"""

from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spec import (
    DEFAULT_DEVICE_BANK_MB,
    DEFAULT_GRAM_CACHE_ENTRIES,
    EngineOptions,
)
from repro.features.bank import FeatureBank
from repro.features.policy import FeaturePolicy
from repro.kernels import fold_gram_strip, fold_gram_strip_banked
from repro.obs import trace as obs_trace
from repro.core.score_common import (
    DeviceGramBank,
    GramBlockCache,
    ScoreConfig,
    ScorerBase,
    VariableView,
    config_key,
    set_key,
)


def _candidate_fold_scores(v, u, s, f, chol_f, n0, n1, lmbda, gamma, jitter=0.0):
    """Mean CV-LR score over all folds of ONE candidate — the single copy
    of the dumbbell-form fold algebra.

    v (q, mx, mx), u (q, mz, mx), s (q, mz, mz): per-fold *test* Grams;
    f / chol_f (q, mz, mz): the z-side train Gram F_q = G_zz - S_q and the
    Cholesky factor of (F_q + n1 l I).  F and chol_f depend only on the
    *parent set* and the fold — never on the child — so the batched
    frontier engine computes them once per (parent set, fold) in its
    shared-core stage (`_z_fold_cores`) and reuses them across every child
    of that parent set; `scores_from_fold_blocks` recomputes them inline
    for the single-config / distributed paths.

    Train P/E blocks fall out of the test blocks by the cross-fold trick
    (sum over folds, then subtract).  D = (F + n1 l I)^-1 is never
    materialized: the supplied Cholesky serves every F-solve, and the
    identities only need D E (an mz x mx solve, usually mx << mz) and
    F D E — O(mz^2 mx) instead of the O(mz^3) explicit inverse.  The
    x-side Qm = I + n1 b M Cholesky — the only remaining per-candidate
    O(mx^3) piece — is factored for all q folds in ONE batched call
    (between the two fold vmaps below), so a score chunk of B candidates
    issues a single (B, q, mx, mx) batched factorization.

    jitter: an extra Tikhonov term on the Qm factorization (and, threaded
    by the callers, on the z-side core) for the numerical degradation
    ladder — a *Python* float, branched at trace time, so the default
    jitter=0.0 path emits exactly the pre-ladder jaxpr (bitwise identity
    preserved).
    """
    mx = v.shape[-1]
    dtype = v.dtype
    beta = lmbda * lmbda / gamma
    n1l = n1 * lmbda
    eye_x = jnp.eye(mx, dtype=dtype)

    gxx = jnp.sum(v, axis=0)
    gzx = jnp.sum(u, axis=0)
    p = gxx[None] - v  # train P_q = G_xx - V_q (cross-fold trick)
    e = gzx[None] - u

    def pre(p_f, e_f, f_f, ch_f):
        DE = jax.scipy.linalg.cho_solve((ch_f, True), e_f)  # D E
        FDE = f_f @ DE
        jt = (e_f - FDE) / n1l  # (I - F D) E / (n1 l) = Z1^T A X1
        m = (p_f - 2.0 * (e_f.T @ DE) + DE.T @ FDE) / (n1l * n1l)
        return jt, m

    Jt, M = jax.vmap(pre)(p, e, f, chol_f)
    Qm = eye_x + (n1 * beta) * M  # (q, mx, mx)
    if jitter:
        Qm = Qm + jitter * eye_x
    chol_q = jnp.linalg.cholesky(Qm)  # one batched factorization, all folds

    def post(m, ch, jt, v_f, u_f, s_f):
        logdet_q = 2.0 * jnp.sum(jnp.log(jnp.diagonal(ch)))
        # Every trace below consumes W only as W^T = Q^-1 M (M and Q are
        # symmetric), so solve for W^T directly — one triangular
        # solve-pair against M instead of materializing G = Q^-1 and
        # forming W = M G (saves ~2 mx^3 FLOPs per fold).
        WT = jax.scipy.linalg.cho_solve((ch, True), m)
        SJt = s_f @ jt
        t1 = jnp.trace(v_f)
        t2 = jnp.sum(SJt * jt)  # tr(S Jt Jt^T)
        t3 = jnp.sum(u_f * jt)  # tr(U Jt^T)
        t4 = jnp.sum(v_f * WT)  # tr(V W)
        t5 = jnp.sum(SJt * (jt @ WT))  # tr(S Jt W Jt^T)
        t6 = jnp.sum((u_f @ WT) * jt)  # tr(U W Jt^T)
        trace_total = (
            t1 + t2 - 2.0 * t3 - (n1 * beta) * (t4 + t5) + 2.0 * (n1 * beta) * t6
        )
        return (
            -0.5 * n0 * n0 * jnp.log(2.0 * jnp.pi)
            - 0.5 * n0 * logdet_q
            - 0.5 * n0 * n1 * jnp.log(gamma)
            - trace_total / (2.0 * gamma)
        )

    return jnp.mean(jax.vmap(post)(M, chol_q, Jt, v, u, s))


def _z_cores_one(s, n1l, jitter=0.0):
    """z-side fold cores of one parent set from its per-fold test Grams
    s (q, mz, mz): the train Gram F_q = G_zz - S_q (cross-fold trick) and
    the Cholesky factor of (F_q + n1 l I) — the O(mz^3) piece of the fold
    algebra that does NOT depend on the child.  An all-zero s (the |Z|=0
    specialization) yields chol_f = sqrt(n1 l) I exactly.  `jitter` (a
    Python float; trace-time branch, default path unchanged) strengthens
    the regularizer for the degradation ladder's re-solves."""
    gzz = jnp.sum(s, axis=0, keepdims=True)
    f = gzz - s
    eye_z = jnp.eye(s.shape[-1], dtype=s.dtype)
    reg = n1l + jitter if jitter else n1l
    return f, jnp.linalg.cholesky(f + reg * eye_z)


@partial(jax.jit, static_argnames=("q", "jitter"))
def cvlr_score_from_features(lam_x, lam_z, q: int, lmbda, gamma, *, jitter=0.0):
    """Mean CV-LR score over Q contiguous-block folds.

    lam_x, lam_z: centered factors, shape (n_eff, m) with n_eff = q * n0.
    Total cost O(n m^2) for the Grams + O(q m^3) for the fold algebra.
    Thin single-config wrapper over the shared batched fold kernel: the
    per-fold *test* Grams are one reshape+einsum each, and the full-data
    Grams / train blocks fall out of the fold axis by sum + subtraction
    inside `scores_from_fold_blocks` (exact; no separate full-Gram einsum).
    `jitter` (static, default 0.0 = the unchanged bitwise path) is the
    degradation ladder's extra Tikhonov term on both Cholesky stages.
    """
    n_eff, mx = lam_x.shape
    mz = lam_z.shape[1]
    n0 = n_eff // q
    n1 = n_eff - n0

    xb = lam_x.reshape(q, n0, mx)
    zb = lam_z.reshape(q, n0, mz)
    V = jnp.einsum("qni,qnj->qij", xb, xb)
    U = jnp.einsum("qni,qnj->qij", zb, xb)
    S = jnp.einsum("qni,qnj->qij", zb, zb)
    return scores_from_fold_blocks(
        V[None], U[None], S[None], n0, n1, lmbda, gamma, jitter=jitter
    )[0]


def scores_from_fold_blocks(V, U, S, n0, n1, lmbda, gamma, jitter=0.0):
    """Batched CV-LR scores from per-fold *test* Gram blocks.

    V: (B, q, mx, mx)  X_q^T X_q       U: (B, q, mz, mx)  Z_q^T X_q
    S: (B, q, mz, mz)  Z_q^T Z_q       ->  (B,) mean-over-folds scores.

    Full-data Grams are recovered by summing the fold axis and each fold's
    train blocks by subtraction (the cross-fold trick, exact).  Routes into
    the single fold-algebra copy `_candidate_fold_scores` (with the z-side
    cores computed inline per candidate) — the sequential scorer, the
    batched frontier engine and the shard_map distributed scorer all share
    that core, so the paths can never drift apart numerically.  Traceable
    (no jit) so it composes under shard_map/vmap.  `jitter` (Python
    float; trace-time branch) is the degradation ladder's extra Tikhonov
    term — 0.0 keeps the default path bitwise-unchanged.
    """
    n1l = n1 * lmbda

    def one(v, u, s):
        f, chol_f = _z_cores_one(s, n1l, jitter)
        return _candidate_fold_scores(
            v, u, s, f, chol_f, n0, n1, lmbda, gamma, jitter
        )

    return jax.vmap(one)(V, U, S)


@jax.jit
def _z_fold_cores(S, n1l):
    """Shared z-side fold cores, once per (parent set, fold).

    S: (Nz, q, mz, mz) stacked per-fold test Grams Z_q^T Z_q of the
    distinct parent sets of a sweep.  Returns (F, chol_f), each
    (Nz, q, mz, mz) — `_z_cores_one` hoisted out of the per-candidate
    score so a parent set pays for its O(mz^3) factorizations once no
    matter how many of its children the frontier scores.
    """
    return jax.vmap(lambda s: _z_cores_one(s, n1l))(S)


@jax.jit
def _z_fold_cores_from_bank(dbank, slots, n1l):
    """Shared z-side fold cores gathered straight out of a device Gram
    bank: dbank (n_slots, q, mz, mz) is the (mz, mz)-width
    `DeviceGramBank` tensor holding the sweep's S blocks, slots (Nz,) the
    parent sets' slot indices (`DeviceGramBank.ZERO_SLOT` for |Z|=0 rows —
    the permanent all-zero block, i.e. the exact specialization).  Returns
    (S, F, chol_f) device-resident; the host never stacks S blocks.
    """
    S = dbank[slots]
    f, ch = jax.vmap(lambda s: _z_cores_one(s, n1l))(S)
    return S, f, ch


def _zshared_scores(V, U, S, F, CH, n0, n1, lmbda, gamma):
    """(B,) scores from per-candidate V/U and gathered per-parent-set
    cores — the shared fold entry of both chunk paths below."""
    return jax.vmap(
        lambda v, u, s, f, ch: _candidate_fold_scores(
            v, u, s, f, ch, n0, n1, lmbda, gamma
        )
    )(V, U, S, F, CH)


@partial(jax.jit, static_argnames=("n0", "n1"))
def _scores_zshared_idx(V, U, s_bank, f_bank, chol_bank, iz, n0, n1, lmbda, gamma):
    """Host-assembly fold path (device banks disabled or fallen back):
    V (B, q, mx, mx) / U (B, q, mz, mx) are host-assembled per-candidate
    chunks; s/f/chol banks (Nz, q, mz, mz) are per *parent set* (from
    `_z_fold_cores`); iz (B,) gathers each candidate's shared core inside
    the jit, so the mz x mz tensors are never re-stacked per candidate."""
    return _zshared_scores(
        V, U, s_bank[iz], f_bank[iz], chol_bank[iz], n0, n1, lmbda, gamma
    )


@partial(jax.jit, static_argnames=("n0", "n1", "mode"))
def _scores_bankfold_idx(
    v_bank, u_bank, ut_bank, iv, iu, it, tu,
    s_bank, f_bank, chol_bank, iz, n0, n1, lmbda, gamma, mode="mixed",
):
    """Device-resident fold path: one index-gather jit over the Gram banks.

    v_bank (Sv, q, wx, wx): the (wx, wx)-width `DeviceGramBank` tensor
    (diagonal V blocks); u_bank (Su, q, wz, wx) / ut_bank (St, q, wx, wz):
    the two cross banks a chunk may draw from — U blocks are cached under
    the *unordered* factor pair, so a candidate's block is stored either
    directly (Z^T X, gathered via iu) or transposed (X^T Z, gathered via
    it and fold-wise swapped); tu (B,) bool selects per candidate.  Rows
    with nothing to gather (|Z|=0, rank-0 children, the inactive side of
    the tu select) point at slot 0, the bank's permanent all-zero block.
    s/f/chol banks + iz as in `_scores_zshared_idx`.  The chunk's V/U
    tensors are materialized by XLA gathers on device — the host only
    builds the (B,) index vectors.

    mode (static): the engine sorts each score group by the transpose
    flag, so almost every chunk is homogeneous — "direct" / "transposed"
    gather exactly one U bank; only the rare straddling chunk pays the
    gather-both-and-select cost of "mixed".
    """
    V = v_bank[iv]
    if mode == "direct":
        U = u_bank[iu]
    elif mode == "transposed":
        U = jnp.swapaxes(ut_bank[it], -1, -2)
    else:
        U = jnp.where(
            tu[:, None, None, None],
            jnp.swapaxes(ut_bank[it], -1, -2),
            u_bank[iu],
        )
    return _zshared_scores(
        V, U, s_bank[iz], f_bank[iz], chol_bank[iz], n0, n1, lmbda, gamma
    )


def _bucket(m: int, cap: int) -> int:
    """Round a live rank up to a small ladder of bucket widths (bounds the
    jit cache) without ever exceeding the padded factor width."""
    m = min(max(int(m), 1), cap)
    for b in _BUCKET_LADDER:
        if m <= b <= cap:
            return b
    return cap


# An extra 80 step between 64 and 96 was tried and REFUTED: the trim
# saving is outweighed by group fragmentation (more bank restacks, more
# pow2-padded short chunks) — measured 32/s vs 75/s on the d=32/n=10k
# frontier cell.
_BUCKET_LADDER = (8, 16, 32, 48, 64, 96)

# Default byte budget (MB) for the Gram-block cache's device tier — sized
# so a d <= 48 sweep-1 working set (a few hundred blocks, <= ~0.74 MB each
# at wz = wx = 96 / q = 10 / f64) stays device-resident with headroom;
# `EngineOptions(device_bank_mb=...)` overrides, 0 disables.  The number
# itself lives in repro.core.spec (single source for the API defaults).
_DEFAULT_DEVICE_BANK_MB = DEFAULT_DEVICE_BANK_MB


def _pow2_pad(k: int, hi: int) -> int:
    """Next power of two >= k, capped at hi (shape-stable stack heights)."""
    p = 1
    while p < min(k, hi):
        p *= 2
    return min(p, hi)


_DUMMY_BANKS: dict = {}

_UNSET = object()  # CVLRScorer: distinguishes "kwarg not passed" from a value


def _dummy_bank(q: int, wa: int, wb: int, dtype):
    """A one-slot all-zero stand-in bank for width pairs the sweep never
    materialized (e.g. every parent set at this width is |Z|=0): gathers
    against slot 0 read exact zeros, same as a real bank's ZERO_SLOT."""
    key = (int(q), int(wa), int(wb), np.dtype(dtype).str)
    if key not in _DUMMY_BANKS:
        _DUMMY_BANKS[key] = jnp.zeros((1, q, wa, wb), dtype)
    return _DUMMY_BANKS[key]


def cvlr_scores_batched(
    lam_x_bank,
    lam_z_bank,
    pairs,
    q: int,
    lmbda: float = 0.01,
    gamma: float = 0.01,
    *,
    m_eff_x=None,
    m_eff_z=None,
    x_keys=None,
    z_keys=None,
    gram_cache: GramBlockCache | None = None,
    pair_chunk: int = 32,
    score_chunk: int = 64,
    precision: str = "bitwise",
    small_batch: bool = False,
) -> np.ndarray:
    """Score a whole GES frontier in a handful of device dispatches.

    lam_x_bank / lam_z_bank: the *feature bank* — sequences of centered
    (n_eff, m) factors, one entry per distinct variable set (children on
    the x side, candidate parent sets on the z side; a |Z|=0 entry is an
    all-zero factor, the exact Eq.-9 specialization).
    pairs: (B, 2) ints, pairs[b] = (x_bank_idx, z_bank_idx) — one row per
    frontier configuration.  Returns (B,) float64 scores.

    Work is shared at two levels.  Gram blocks: V = X_q^T X_q once per
    child, S = Z_q^T Z_q once per parent set, U = Z_q^T X_q once per
    *unordered* (parent-set, child) factor pair (U(a, b) = U(b, a)^T, so
    the X -> Y and Y -> X candidates of a symmetric frontier share one
    block) — never once per candidate — all produced by the fused
    fold-Gram strip kernels (`repro.kernels.fold_gram_strip` /
    `fold_gram_strip_banked`) and cached in `gram_cache` across sweeps.
    Fold cores: the z-side train Gram F_q and its Cholesky factor depend
    only on (parent set, fold), so they are computed once per parent set
    and every child of that set reuses them; the remaining per-candidate
    Qm Cholesky is one batched factorization per chunk
    (`_candidate_fold_scores`).

    **Device-resident pipeline** (default): the sweep's working set is
    pinned into the cache's device tier (`GramBlockCache.
    begin_device_sweep`), fused Gram kernels scatter each block straight
    into a per-width `DeviceGramBank` slot at compute time, and the fold
    stage gathers chunks out of the banks inside one jit
    (`_scores_bankfold_idx`) — between the Gram and fold stages no block
    crosses the host boundary, replacing the per-chunk `np.zeros` V/U
    assembly + re-upload of the host path.  Cached blocks stay
    device-resident across sweeps (host spill only on LRU eviction).  The
    host-assembly path remains both the opt-out (`device_bank_mb=0` on
    `api.make_scorer`, or a cache built without a device tier) and the
    automatic fallback when a sweep's working set cannot fit the device
    budget — both paths produce bit-identical scores on CPU.

    Every factor takes part only at its *bucketed live rank*: zero-padded
    columns are provably score-neutral
    (tests/test_score_lowrank.py::test_zero_padding_is_exact), so slicing
    to a per-set bucket is exact while cutting the m^2/m^3 terms by the
    (m_max / m_eff)^2 the padding was wasting — and because m_eff varies a
    lot across variable sets (9..88 observed on one SCM draw), the einsum
    and fold phases are *grouped by bucket shape* rather than padded to
    the batch max.  Within a group everything is chunked and padded to
    fixed chunk heights, so the jit cache stays small and no call
    dispatches more than O(B / chunk) kernels.

    Stage profiling (the former benchmark-only ``timings=`` dict) now
    rides the observability layer: when a `repro.obs` recorder is active
    (``trace.use(recorder)`` / `EngineOptions(obs=...)`), the engine emits
    "gram" / "zcores" / "fold" stage spans — tiling the call's wall time,
    with device syncs at the boundaries so the splits are honest, and
    carrying ``path`` ("device"|"host") and ``small_batch`` attrs.  With
    no recorder active there are no syncs and async dispatch is
    untouched; `repro.obs.engine_stage_split` reproduces the historical
    ``{"gram_s", "zcores_s", "fold_s", "path"}`` dict from a recorder.

    precision: the Gram accumulation policy
    (`repro.core.spec.EngineOptions.precision`) forwarded to the fold-Gram
    dispatchers — ``"f32_gram"`` relaxes the CPU engine==oracle bitwise
    guarantee to ~1e-7-relative Gram accuracy in exchange for f32
    contractions on the gather+einsum backend (the fold algebra stays f64).

    small_batch: the incremental frontier-delta fast path — a warm sweep's
    delta is tens of configs, and routing it through the full machinery
    pays two costs the delta doesn't need: (1) the device-resident
    pipeline's jit signatures are keyed on *bank* shapes, which grow as
    the search discovers factors, so each delta sweep recompiles; (2) the
    default padding caps (`len(bank)`) are themselves bank-size-dependent,
    so stack heights like 23 -> 23 leak data-dependent shapes into the jit
    cache.  ``small_batch=True`` forces the host-assembly path (whose jit
    signatures depend only on chunk shapes), shrinks the chunks
    (pair_chunk <= 8, score_chunk <= 16 — a 20-config delta fills a chunk
    instead of 1/8th of one), and pads every stack height to a pure power
    of two (uncapped), so after a handful of sweeps every shape recurs and
    dispatch is compile-free.  Scores are bitwise-identical to the default
    path on CPU (the host path guarantee); it is purely a
    latency/compile-churn trade, chosen per call by `CVLRScorer.prefetch`.
    """
    pairs = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
    n_pairs = pairs.shape[0]
    if n_pairs == 0:
        return np.zeros((0,), dtype=np.float64)
    if small_batch:
        pair_chunk = min(pair_chunk, 8)
        score_chunk = min(score_chunk, 16)
    # pow2 stack-height cap: bank length by default (no point padding a
    # 5-entry bank to 8 rows of zeros) — UNCAPPED in small-batch mode,
    # where shape recurrence matters more than a few zero rows
    pad_cap = (1 << 30) if small_batch else None
    lam_x_bank = [jnp.asarray(a) for a in lam_x_bank]
    lam_z_bank = [jnp.asarray(a) for a in lam_z_bank]
    dtype = lam_x_bank[0].dtype
    n_eff = lam_x_bank[0].shape[0]
    n0 = n_eff // q
    n1 = n_eff - n0
    if m_eff_x is None:
        m_eff_x = [a.shape[1] for a in lam_x_bank]
    if m_eff_z is None:
        m_eff_z = [a.shape[1] for a in lam_z_bank]
    if x_keys is None:
        x_keys = [("_x", i) for i in range(len(lam_x_bank))]
    if z_keys is None:
        z_keys = [("_z", i) for i in range(len(lam_z_bank))]
    cache = (
        gram_cache
        if gram_cache is not None
        else GramBlockCache(device_bank_mb=_DEFAULT_DEVICE_BANK_MB)
    )

    xs_used = sorted({int(p) for p in pairs[:, 0]})
    zs_used = sorted({int(p) for p in pairs[:, 1]})
    bx = {i: _bucket(m_eff_x[i], lam_x_bank[i].shape[1]) for i in xs_used}
    bz = {
        i: _bucket(m_eff_z[i], lam_z_bank[i].shape[1])
        for i in zs_used
        if m_eff_z[i] > 0
    }

    # Stage spans (repro.obs): each _mark closes the interval since the
    # previous mark as one span, so the three stage spans tile this call.
    # The block_until_ready syncs run ONLY when a recorder is active —
    # the obs="off" path keeps full async dispatch.
    tr = obs_trace.get_recorder()
    t_mark = [time.perf_counter()]
    stage_attrs: dict = {}

    def _mark(name, sync=()):
        if tr is None:
            return
        for arr in sync:
            if arr is not None:
                arr.block_until_ready()
        now = time.perf_counter()
        tr.complete(name, t_mark[0], now, cat="stage", attrs=dict(stage_attrs))
        t_mark[0] = now

    def _take(a, w):
        return a[:, :w] if a.shape[1] >= w else jnp.pad(
            a, ((0, 0), (0, w - a.shape[1]))
        )

    banks = {"x": lam_x_bank, "z": lam_z_bank}
    m_effs = {"x": m_eff_x, "z": m_eff_z}
    bucks = {"x": bx, "z": bz}

    def _stack_refs(refs, w, cap):
        """One stacked, trimmed device bank for the fused strip kernel:
        refs are (side, bank_idx) pairs; height is pow2-padded (capped at
        `cap`) with zero factors so chunk shapes stay jit-stable."""
        dt = banks[refs[0][0]][0].dtype
        return jnp.stack(
            [_take(banks[s][i], w) for s, i in refs]
            + [jnp.zeros((n_eff, w), dt)]
            * (_pow2_pad(len(refs), cap) - len(refs))
        )

    def _cross_key(zi, xi):
        """Canonical cache identity of the cross block U = Z_q^T X_q.

        U(a, b) and U(b, a) are fold-wise transposes, so the block is
        keyed on the *unordered* factor pair (ordered by a total,
        type-safe repr order): a frontier that scores both X -> Y and
        Y -> X — every symmetric sweep — computes one block, not two.
        Returns (cache_key, transposed, ((side, idx) canonical a, b)):
        `transposed` tells the consumer the stored block is X_q^T Z_q.
        """
        zk, xk = z_keys[zi], x_keys[xi]
        if repr(zk) <= repr(xk):
            return (zk, xk), False, (("z", zi), ("x", xi))
        return (xk, zk), True, (("x", xi), ("z", zi))

    # -- needed blocks + device-tier width specs -------------------------
    blocks: dict = {}  # host path: cache-key -> (q, me_a, me_b) host block
    slot_of: dict = {}  # bank path: cache-key -> device bank slot
    specs: dict = {}  # cache-key -> (wa, wb, ea, eb) for the device tier
    conflict = [False]

    def _spec(key, wa, wb, ea, eb):
        prev = specs.get(key)
        if prev is not None and prev != (wa, wb, ea, eb):
            conflict[0] = True  # same key, different widths: host path
        specs[key] = (wa, wb, ea, eb)

    need_v = {}
    for i in xs_used:
        key = (x_keys[i], x_keys[i])
        if m_eff_x[i] > 0:
            need_v[key] = i
            _spec(key, bx[i], bx[i], m_eff_x[i], m_eff_x[i])
        else:
            blocks[key] = np.zeros((q, 0, 0))
    need_s = {}
    for i in zs_used:
        key = (z_keys[i], z_keys[i])
        if m_eff_z[i] > 0:
            need_s[key] = i
            _spec(key, bz[i], bz[i], m_eff_z[i], m_eff_z[i])
        else:
            blocks[key] = np.zeros((q, 0, 0))
    need_u = {}
    for xi, zi in {(int(a), int(b)) for a, b in pairs}:
        key, transposed, refs = _cross_key(zi, xi)
        if m_eff_z[zi] == 0:
            mx = m_eff_x[xi]
            blocks[key] = np.zeros((q, mx, 0) if transposed else (q, 0, mx))
        else:
            need_u[key] = refs
            ra, rb = refs
            _spec(
                key,
                bucks[ra[0]][ra[1]],
                bucks[rb[0]][rb[1]],
                m_effs[ra[0]][ra[1]],
                m_effs[rb[0]][rb[1]],
            )

    use_banks = (
        (not small_batch)
        and (not conflict[0])
        and cache.begin_device_sweep(specs, q=q, dtype=dtype)
    )
    stage_attrs["path"] = "device" if use_banks else "host"
    if small_batch:
        stage_attrs["small_batch"] = True

    def _gather_missing(needed):
        """One counted cache lookup per needed key; returns keys to compute."""
        missing = []
        for key, spec in needed.items():
            if use_banks:
                slot = cache.device_lookup(key)
                if slot is None:
                    missing.append((key, spec))
                else:
                    slot_of[key] = slot
            else:
                blk = cache.get(key)
                if blk is None:
                    missing.append((key, spec))
                else:
                    blocks[key] = blk
        return missing

    def _store(key, out_row, ea, eb):
        # copy: a view would pin the whole padded chunk buffer in the cache
        blk = np.ascontiguousarray(out_row[:, :ea, :eb])
        blocks[key] = blk
        cache.put(key, blk)

    def _drain(pending, trim):
        """Second half of the host path's submit/drain pipeline: convert the
        in-flight device chunks to host blocks.  Draining only after every
        chunk is submitted lets JAX's async dispatch overlap device einsums
        with the host-side chunk preparation instead of syncing per chunk."""
        for out_dev, chunk in pending:
            out = np.asarray(out_dev)
            for j, (key, spec) in enumerate(chunk):
                ea, eb = trim(spec)
                _store(key, out[j], ea, eb)

    def _submit_chunks(gen, trim):
        """Run the generated Gram chunks through the path's sink.

        Bank path: adopt a slot per block and run the fused
        compute+scatter kernel (`fold_gram_strip_banked`) straight into
        the bank tensor — nothing returns to the host, padding rows land
        in the write-only scratch slot.  Host path: submit all strips,
        then drain to trimmed host blocks (PR-2 behavior)."""
        if use_banks:
            for aa, bb, ia, ib, chunk, widths in gen:
                slots = [cache.device_adopt(key) for key, _ in chunk]
                for (key, _), s in zip(chunk, slots):
                    slot_of[key] = s
                slots += [DeviceGramBank.SCRATCH_SLOT] * (len(ia) - len(slots))
                cache.set_bank_data(
                    widths,
                    fold_gram_strip_banked(
                        aa, bb,
                        np.asarray(ia, np.int32), np.asarray(ib, np.int32),
                        cache.bank_data(widths),
                        np.asarray(slots, np.int32), q,
                        precision=precision,
                    ),
                )
        else:
            pending = [
                (
                    fold_gram_strip(
                        aa, bb,
                        np.asarray(ia, np.int32), np.asarray(ib, np.int32), q,
                        precision=precision,
                    ),
                    chunk,
                )
                for aa, bb, ia, ib, chunk, widths in gen
            ]
            _drain(pending, trim)

    def _diag_chunks(missing, side):
        """Diagonal per-fold Grams, grouped by bucket width.  Each group
        stacks its unique trimmed factors once (pow2-padded height) and
        yields fused strip-kernel chunks with ia == ib — one dispatch per
        `pair_chunk` sets, no per-chunk restacking."""
        buckets = bucks[side]
        groups: dict = {}
        for key, i in missing:
            groups.setdefault(buckets[i], []).append((key, i))
        for w, items in sorted(groups.items()):
            ids = sorted({i for _, i in items})
            loc = {i: k for k, i in enumerate(ids)}
            st = _stack_refs(
                [(side, i) for i in ids], w, pad_cap or len(banks[side])
            )
            for c0 in range(0, len(items), pair_chunk):
                chunk = items[c0 : c0 + pair_chunk]
                cpad = _pow2_pad(len(chunk), pair_chunk)
                ii = [loc[i] for _, i in chunk]
                ii += [ii[0]] * (cpad - len(ii))
                yield st, st, ii, ii, chunk, (w, w)

    def _cross_chunks(missing):
        """Cross per-fold Grams A_q^T B_q for canonical factor pairs,
        grouped by (bucket_a, bucket_b).  Each group stacks its unique
        factors once per side (pow2-padded heights) and yields fused
        strip-kernel chunks — one dispatch per `pair_chunk` pairs; on TPU
        the factor rows stream HBM->VMEM once with no gathered
        (B, q, n0, m) intermediate."""
        groups: dict = {}
        for key, (ra, rb) in missing:
            wa = bucks[ra[0]][ra[1]]
            wb = bucks[rb[0]][rb[1]]
            groups.setdefault((wa, wb), []).append((key, (ra, rb)))
        cap = pad_cap or (len(lam_x_bank) + len(lam_z_bank))
        for (wa, wb), items in sorted(groups.items()):
            a_refs = sorted({ra for _, (ra, _) in items})
            b_refs = sorted({rb for _, (_, rb) in items})
            a_loc = {r: k for k, r in enumerate(a_refs)}
            b_loc = {r: k for k, r in enumerate(b_refs)}
            aa = _stack_refs(a_refs, wa, cap)
            bb = _stack_refs(b_refs, wb, cap)
            for c0 in range(0, len(items), pair_chunk):
                chunk = items[c0 : c0 + pair_chunk]
                cpad = _pow2_pad(len(chunk), pair_chunk)
                ia = [a_loc[ra] for _, (ra, _) in chunk]
                ib = [b_loc[rb] for _, (_, rb) in chunk]
                ia += [ia[0]] * (cpad - len(ia))
                ib += [ib[0]] * (cpad - len(ib))
                yield aa, bb, ia, ib, chunk, (wa, wb)

    try:
        # -- diagonal blocks: V once per child set, S once per parent set -
        _submit_chunks(
            _diag_chunks(_gather_missing(need_v), "x"),
            lambda i: (m_eff_x[i], m_eff_x[i]),
        )
        _submit_chunks(
            _diag_chunks(_gather_missing(need_s), "z"),
            lambda i: (m_eff_z[i], m_eff_z[i]),
        )
        # -- cross blocks: one per unordered (parent-set, child) pair -----
        _submit_chunks(
            _cross_chunks(_gather_missing(need_u)),
            lambda ab: (m_effs[ab[0][0]][ab[0][1]], m_effs[ab[1][0]][ab[1][1]]),
        )
        _mark(
            "gram",
            sync=[cache.bank_data(w[:2]) for w in specs.values()]
            if use_banks
            else (),
        )

        # -- z-shared fold cores: Cholesky once per (parent set, fold) ----
        lm = jnp.asarray(lmbda, jnp.float64)
        gm = jnp.asarray(gamma, jnp.float64)
        n1l = jnp.asarray(n1 * lmbda, jnp.float64)
        wz_of = {zi: bz.get(zi, _BUCKET_LADDER[0]) for zi in zs_used}
        score_groups: dict = {}
        for b, (xi, zi) in enumerate(pairs):
            score_groups.setdefault((wz_of[zi], bx[xi]), []).append(b)
        # Group the sweep's distinct parent sets by bucket width and build
        # the per-width core banks: S blocks -> (F, chol_f) once per parent
        # set, device-resident, reused by every child of that set.  A |Z|=0
        # set contributes an all-zero S row (the exact specialization).  On
        # the bank path the S rows are index-gathered straight out of the
        # (w, w) device Gram bank — the host never stacks them.
        z_by_w: dict = {}
        for zi in zs_used:
            z_by_w.setdefault(wz_of[zi], []).append(zi)
        z_cores: dict = {}  # wz -> (s_bank, f_bank, chol_bank) device tensors
        z_loc: dict = {}  # zi -> row in its width's core bank
        for w, zids in sorted(z_by_w.items()):
            npad = _pow2_pad(len(zids), pad_cap or len(lam_z_bank))
            if use_banks:
                zslots = []
                for k, zi in enumerate(sorted(zids)):
                    z_loc[zi] = k
                    zslots.append(
                        slot_of[(z_keys[zi], z_keys[zi])]
                        if m_eff_z[zi] > 0
                        else DeviceGramBank.ZERO_SLOT
                    )
                zslots += [DeviceGramBank.ZERO_SLOT] * (npad - len(zslots))
                dbank = cache.bank_data((w, w))
                if dbank is None:
                    dbank = _dummy_bank(q, w, w, dtype)
                z_cores[w] = _z_fold_cores_from_bank(
                    dbank, jnp.asarray(np.asarray(zslots, np.int32)), n1l
                )
            else:
                s_host = np.zeros((npad, q, w, w))
                for k, zi in enumerate(sorted(zids)):
                    z_loc[zi] = k
                    bs = blocks[(z_keys[zi], z_keys[zi])]
                    s_host[k, :, : bs.shape[1], : bs.shape[2]] = bs
                s_bank = jnp.asarray(s_host)
                f_bank, chol_bank = _z_fold_cores(s_bank, n1l)
                z_cores[w] = (s_bank, f_bank, chol_bank)
        _mark("zcores", sync=[c[2] for c in z_cores.values()])

        # -- fold algebra: grouped by (bucket_z, bucket_x), fixed chunks --
        scores = np.empty((n_pairs,), dtype=np.float64)
        in_flight = []  # (device scores, target pair indices)
        for (wz, wx), idxs in sorted(score_groups.items()):
            s_bank, f_bank, chol_bank = z_cores[wz]
            if use_banks:
                v_data = cache.bank_data((wx, wx))
                if v_data is None:
                    v_data = _dummy_bank(q, wx, wx, dtype)
                u_data = cache.bank_data((wz, wx))  # direct Z^T X blocks
                if u_data is None:
                    u_data = _dummy_bank(q, wz, wx, dtype)
                ut_data = cache.bank_data((wx, wz))  # transposed X^T Z store
                if ut_data is None:
                    ut_data = _dummy_bank(q, wx, wz, dtype)
                # sort the group by the cross-block transpose flag (stable)
                # so chunks are homogeneous and the fold jit gathers only
                # one U bank per chunk (mode= below); scores are
                # per-candidate, so reordering is exact
                idxs = sorted(
                    idxs,
                    key=lambda b: (
                        m_eff_z[int(pairs[b, 1])] > 0
                        and _cross_key(int(pairs[b, 1]), int(pairs[b, 0]))[1]
                    ),
                )
            g = len(idxs)
            c0 = 0
            while c0 < g:
                # few chunk heights (bounds compile variants): the full
                # chunk, or a pow2 short chunk when the tail is small —
                # padding a 9-pair group to 64 at a large bucket wastes
                # ~7x the fold work
                rem = g - c0
                size = (
                    score_chunk
                    if rem >= score_chunk // 2
                    else max(score_chunk // 4, _pow2_pad(rem, score_chunk))
                )
                hi = min(c0 + size, g)
                chunk_idxs = idxs[c0:hi] + [idxs[c0]] * (size - (hi - c0))
                if use_banks:
                    # the chunk is FOUR small index vectors — the V/U
                    # gathers happen on device inside the fold jit
                    iv = np.zeros((size,), np.int32)
                    iud = np.zeros((size,), np.int32)
                    iut = np.zeros((size,), np.int32)
                    tu = np.zeros((size,), bool)
                    iz = np.zeros((size,), np.int32)
                    for row, b in enumerate(chunk_idxs):
                        xi, zi = int(pairs[b, 0]), int(pairs[b, 1])
                        if m_eff_x[xi] > 0:
                            iv[row] = slot_of[(x_keys[xi], x_keys[xi])]
                        if m_eff_z[zi] > 0:
                            ck, transposed, _ = _cross_key(zi, xi)
                            if transposed:
                                iut[row] = slot_of[ck]
                                tu[row] = True
                            else:
                                iud[row] = slot_of[ck]
                        iz[row] = z_loc[zi]
                    has_t = bool(tu.any())
                    mode = (
                        "mixed"
                        if has_t and not tu.all()
                        else ("transposed" if has_t else "direct")
                    )
                    out = _scores_bankfold_idx(
                        v_data, u_data, ut_data,
                        jnp.asarray(iv), jnp.asarray(iud), jnp.asarray(iut),
                        jnp.asarray(tu),
                        s_bank, f_bank, chol_bank, jnp.asarray(iz),
                        n0, n1, lm, gm, mode=mode,
                    )
                else:
                    # assemble ONLY this chunk's padded V/U blocks: peak
                    # host memory stays O(score_chunk), not O(frontier);
                    # pad rows repeat row 0
                    V = np.zeros((size, q, wx, wx))
                    U = np.zeros((size, q, wz, wx))
                    iz = np.zeros((size,), np.int32)
                    for row, b in enumerate(chunk_idxs):
                        xi, zi = int(pairs[b, 0]), int(pairs[b, 1])
                        bv = blocks[(x_keys[xi], x_keys[xi])]
                        ck, transposed, _ = _cross_key(zi, xi)
                        bu = blocks[ck]
                        if transposed:  # stored as X_q^T Z_q; copy on assign
                            bu = bu.transpose(0, 2, 1)
                        V[row, :, : bv.shape[1], : bv.shape[2]] = bv
                        U[row, :, : bu.shape[1], : bu.shape[2]] = bu
                        iz[row] = z_loc[zi]
                    out = _scores_zshared_idx(
                        jnp.asarray(V), jnp.asarray(U),
                        s_bank, f_bank, chol_bank, jnp.asarray(iz),
                        n0, n1, lm, gm,
                    )
                in_flight.append((out, np.asarray(idxs[c0:hi])))
                c0 = hi
        for out, target in in_flight:
            scores[target] = np.asarray(out)[: target.shape[0]]
        _mark("fold")
    finally:
        if use_banks:
            cache.end_device_sweep()
    return scores


class CVLRScorer(ScorerBase):
    """The paper's method: CV-LR local score with Alg. 1/Alg. 2 features."""

    # LRU bound on the Gram-block cache, sized to the sweep working set: a
    # sweep touches d diagonal V blocks, O(d) S blocks and one U block per
    # (parent set, child) pair — ~d + d^2 entries on a sweep-1 frontier —
    # so 4096 holds every block of a d <= 60 sweep with room for the
    # previous sweep's overlap, while bounding a long search's footprint
    # (blocks are (q, m, m) float64, worst case ~0.7 MB each at m = 96).
    # The numbers live in repro.core.spec (shared with EngineOptions).
    DEFAULT_GRAM_CACHE_ENTRIES = DEFAULT_GRAM_CACHE_ENTRIES

    # Byte budget (MB) for the cache's device tier — the device-resident
    # fold pipeline.  0 / None disables it (pure host-assembly engine).
    DEFAULT_DEVICE_BANK_MB = _DEFAULT_DEVICE_BANK_MB

    def __init__(
        self,
        data,
        dims=None,
        discrete=None,
        config: ScoreConfig | None = None,
        batched: bool = _UNSET,
        gram_cache_entries: int | None = _UNSET,
        device_bank_mb: float | None = _UNSET,
        spec=None,
        options: EngineOptions | None = None,
        precision: str = _UNSET,
        feature_bank: FeatureBank | None = None,
        gram_cache: GramBlockCache | None = None,
    ):
        """`spec` (a `repro.core.spec.DataSpec`) supersedes the legacy
        `dims`/`discrete` lists; `options` (a `repro.core.spec.
        EngineOptions`) supersedes the loose engine kwargs (`batched`,
        `gram_cache_entries`, `device_bank_mb`, `precision`) — passing
        both raises, so a loose value can never be silently overridden.
        Either way the resolved policy is inspectable as `self.options`.
        Loose-kwarg defaults: batched=True,
        `DEFAULT_GRAM_CACHE_ENTRIES`, `DEFAULT_DEVICE_BANK_MB`,
        precision="bitwise".

        `options.features` (a `repro.features.policy.FeaturePolicy`)
        routes each variable set to a factorization backend; the default
        reproduces the pre-PR-5 ICL / exact-discrete routing bitwise.
        `feature_bank` (a `repro.features.bank.FeatureBank`) holds built
        factors — pass the same bank to several scorers over the same
        data (and fold layout) to skip rebuilding across sessions; by
        default every scorer owns a fresh one.  `gram_cache` (a
        `repro.core.score_common.GramBlockCache`) likewise injects a
        shared Gram-block cache — the serving layer hands sessions with
        identical build fingerprints one cache so frontier Gram blocks
        are computed once process-wide; callers must guarantee the
        fingerprint match (the cache keys carry no config identity)."""
        loose = {
            "batched": batched,
            "gram_cache_entries": gram_cache_entries,
            "device_bank_mb": device_bank_mb,
            "precision": precision,
        }
        passed = sorted(k for k, v in loose.items() if v is not _UNSET)
        if options is not None:
            if passed:
                raise ValueError(
                    f"pass either options=EngineOptions(...) or the loose "
                    f"engine kwargs {passed}, not both"
                )
            batched = options.batched
            gram_cache_entries = options.gram_cache_entries
            device_bank_mb = options.device_bank_mb
            precision = options.precision
        else:
            batched = True if batched is _UNSET else batched
            if gram_cache_entries is _UNSET:
                gram_cache_entries = self.DEFAULT_GRAM_CACHE_ENTRIES
            if device_bank_mb is _UNSET:
                device_bank_mb = self.DEFAULT_DEVICE_BANK_MB
            precision = "bitwise" if precision is _UNSET else precision
            options = EngineOptions(
                engine="batched" if batched else "sequential",
                gram_cache_entries=gram_cache_entries,
                device_bank_mb=device_bank_mb,
                precision=precision,
            )
        config = config or ScoreConfig()
        super().__init__(
            VariableView(data, dims, discrete, spec=spec), config
        )
        self.m_eff_log: dict = {}  # vars_key -> effective rank (diagnostics)
        self.options = options
        self.batched = batched  # False => ges() falls back to lazy local_score
        self.precision = precision
        self.score_memo_max = options.score_memo_entries
        self.policy = (
            options.features
            if options.features is not None
            else FeaturePolicy.default()
        )
        self.feature_bank = (
            feature_bank if feature_bank is not None else FeatureBank()
        )
        self.gram_cache = (
            gram_cache
            if gram_cache is not None
            else GramBlockCache(
                max_entries=gram_cache_entries, device_bank_mb=device_bank_mb
            )
        )
        # Numerical graceful degradation (the jitter -> f64 -> exact
        # escalation ladder in `_recover_score`): cumulative counters,
        # surfaced per sweep by the session log.  fault_plan / fault_sweep
        # are the injection context a DiscoverySession threads in
        # (`repro.core.runstate.FaultPlan`); None => no injection.
        self.degradations = {
            "jittered": 0, "f64_resolve": 0, "exact_fallback": 0,
            "unrecovered": 0,
        }
        self.fault_plan = None
        self.fault_sweep = None
        self._exact_fallback = None

    def _feature_fingerprint(self, vars_key: tuple, choice) -> tuple:
        """Bank-cache identity of a factor built for THIS scorer: the
        resolved backend choice plus everything else that shapes the
        factor — the whole routing policy (`FeaturePolicy.fingerprint`,
        seed included), the spec-derived build inputs (known levels and
        the per-column discreteness the stratified sampler keys on), the
        build knobs, and the fold layout (q_folds + seed pick the row
        permutation/truncation the factor is built on) — so sessions
        sharing a bank over the same data can never collide across
        configs or specs."""
        known, mask = self._spec_build_inputs(vars_key)
        return (
            choice.backend,
            choice.params,
            self.policy.fingerprint(),
            known,
            mask,
            self.config.m_max,
            self.config.eta,
            self.config.width_factor,
            self.config.q_folds,
            self.config.seed,
        )

    def _spec_build_inputs(self, vars_key: tuple):
        """(known_levels, per-column discrete mask) for a variable set —
        the DataSpec-derived inputs a backend build consumes."""
        known = None
        if len(vars_key) == 1:
            # DataSpec.infer records the distinct-row count per variable;
            # threading it through means the column is scanned once, ever
            known = self.view.spec.variables[vars_key[0]].levels
        mask = []
        for v in vars_key:
            mask.extend([bool(self.view.discrete[v])] * self.view.dims[v])
        return known, tuple(mask)

    def _build_features(self, vars_key: tuple, choice):
        # Lazy import: repro.features.backends imports repro.core.kernel_fns,
        # and this module is imported by repro.core's package __init__ — a
        # module-level import here would make `import repro.features` cycle.
        from repro.features.backends import BuildContext, build_features

        plan = self.fault_plan
        if plan is not None and plan.build_delay_s:
            # injected contention storm: stretch the build so concurrent
            # requesters pile onto the bank's single-flight slot
            import time as _time

            _time.sleep(float(plan.build_delay_s))
        cols = self.view.columns(vars_key)[self.perm]
        known, mask = self._spec_build_inputs(vars_key)
        ctx = BuildContext(
            m_max=self.config.m_max,
            eta=self.config.eta,
            width_factor=self.config.width_factor,
            known_levels=known,
            discrete_mask=mask,
            seed=self.policy.seed,
            salt=tuple(vars_key),
        )
        return build_features(cols, choice, ctx)

    def features(self, vars_key: tuple) -> jnp.ndarray:
        """Centered (n_eff, m_max) factor for a variable set, built by the
        backend `self.policy` routes the set to and cached in
        `self.feature_bank` (shared across sweeps, and across sessions
        when a bank is passed in).

        The per-set factors double as the device-resident feature bank of
        the batched frontier engine (`prefetch`)."""
        vars_key = set_key(vars_key)
        choice = self.policy.resolve(vars_key, self.view.spec)
        res = self.feature_bank.get_or_build(
            vars_key,
            self._feature_fingerprint(vars_key, choice),
            lambda: self._build_features(vars_key, choice),
        )
        self.m_eff_log[vars_key] = res.m_eff
        return res.factor

    def _compute(self, i: int, parents: tuple) -> float:
        """Sequential single-config score — the oracle the batched engine is
        tested against (tests/test_frontier_batch.py)."""
        lam_x = self.features((i,))
        if parents:
            lam_z = self.features(tuple(parents))
        else:
            lam_z = jnp.zeros_like(lam_x)  # exact |Z|=0 specialization
        s = float(
            cvlr_score_from_features(
                lam_x,
                lam_z,
                self.config.q_folds,
                jnp.asarray(self.config.lmbda, lam_x.dtype),
                jnp.asarray(self.config.gamma, lam_x.dtype),
            )
        )
        if not np.isfinite(s):
            s = self._recover_score(i, tuple(parents))
        return s

    def _exact_fallback_scorer(self):
        """Lazily-built exact O(n^3) oracle (`repro.core.score_exact.
        CVScorer`) over the same view/config — the degradation ladder's
        terminal rung.  Built at most once per scorer; a run that never
        degrades never pays for it."""
        if self._exact_fallback is None:
            from repro.core.score_exact import CVScorer  # avoid import cycle

            self._exact_fallback = CVScorer(
                self.view.data, spec=self.view.spec, config=self.config
            )
        return self._exact_fallback

    def _recover_score(self, i: int, parents: tuple) -> float:
        """Condition-triggered escalation ladder for a non-finite CV-LR
        score (on CPU/GPU an ill-conditioned fold Cholesky yields NaNs,
        not exceptions — every engine path funnels non-finite scores
        here instead of silently caching them):

          rung 1 — jittered retry: re-solve with a small extra Tikhonov
            term on both Cholesky stages (native dtype);
          rung 2 — f64 re-solve: factors upcast to float64 (a no-op
            upcast under the default f64 builds, where the rung's value
            is the 100x stronger jitter) with a 100x jitter;
          rung 3 — per-candidate exact score: the O(n^3) `CVScorer`
            oracle, which never factorizes a near-singular m x m core.

        The first finite rung wins and is counted in `self.degradations`
        (surfaced per sweep by the session log); if everything fails the
        candidate scores -inf — GES simply never applies it — and
        `unrecovered` is counted.  A `FaultPlan.fail_rungs` injection
        pretends the first k rungs failed, so tests drive escalation
        deterministically."""
        plan = self.fault_plan
        fail_rungs = int(plan.fail_rungs) if plan is not None else 0
        parents = tuple(parents)
        lam_x = self.features((i,))
        lam_z = (
            self.features(parents) if parents else jnp.zeros_like(lam_x)
        )
        q = self.config.q_folds
        n_eff = lam_x.shape[0]
        n1 = n_eff - n_eff // q
        base_jitter = 1e-8 * max(n1 * self.config.lmbda, 1.0)
        ladder = [
            ("jittered", lam_x, lam_z, base_jitter),
            (
                "f64_resolve",
                lam_x.astype(jnp.float64),
                lam_z.astype(jnp.float64),
                100.0 * base_jitter,
            ),
        ]
        for rung, (name, lx, lz, jit_term) in enumerate(ladder, start=1):
            if fail_rungs >= rung:
                continue  # injected: pretend this rung also failed
            s = float(
                cvlr_score_from_features(
                    lx, lz, q,
                    jnp.asarray(self.config.lmbda, lx.dtype),
                    jnp.asarray(self.config.gamma, lx.dtype),
                    jitter=float(jit_term),
                )
            )
            if np.isfinite(s):
                self.degradations[name] += 1
                return s
        if fail_rungs < 3:
            try:
                s = float(
                    self._exact_fallback_scorer().local_score(i, parents)
                )
            except Exception:
                s = float("nan")
            if np.isfinite(s):
                self.degradations["exact_fallback"] += 1
                return s
        self.degradations["unrecovered"] += 1
        return float("-inf")

    # Uncached-config count at or below which a small-batch-eligible
    # `prefetch` flips the engine into its small-batch mode (host path,
    # small chunks, pure-pow2 padding — see `cvlr_scores_batched`).  A
    # warm incremental sweep's delta is typically O(d) configs; the
    # crossover where the device path's bank-shaped jit signatures pay
    # for themselves sits well above this on CPU (measured: a ~50-config
    # delta runs ~5x faster small-batch than through the device
    # pipeline's recompiles).
    SMALL_BATCH_CONFIGS = 128

    def prefetch(self, configs, small_batch: bool = False) -> int:
        """Batched frontier engine: evaluate every uncached (node, parents)
        configuration through `cvlr_scores_batched`, sharing Gram blocks via
        `self.gram_cache` (device-resident when its device tier is enabled).
        Called by ges() once per sweep iteration.  When a `repro.obs`
        recorder is active, the dispatch emits a "features" span for the
        factor builds plus the engine's "gram"/"zcores"/"fold" stage spans
        (the span layer replaced the old benchmark-only ``timings=`` dict).

        small_batch: marks this dispatch small-batch-ELIGIBLE — the
        incremental session seam passes True for warm delta sweeps, whose
        uncached count is a sweep-over-sweep delta, not a full frontier.
        The engine's `small_batch` fast path (bitwise-equal scores,
        compile-stable shapes) then engages once the uncached count is at
        most `SMALL_BATCH_CONFIGS`.  Default False: a directly-driven
        scorer keeps its configured device/host path regardless of
        frontier size (the device-bank contract in
        tests/test_device_bank.py)."""
        if not self.batched:
            return 0
        todo = []
        seen = set()
        for node, parents in configs:
            key = config_key(node, parents)
            if key not in self._score_cache and key not in seen:
                seen.add(key)
                todo.append(key)
        if not todo:
            return 0
        x_sets = sorted({(i,) for i, _ in todo})
        z_sets = sorted({ps for _, ps in todo})
        x_index = {k: j for j, k in enumerate(x_sets)}
        z_index = {k: j for j, k in enumerate(z_sets)}
        # The whole dispatch — factor builds included — runs under the
        # cache's sweep guard: the device sweep's donated bank writes must
        # never interleave with a competing session's sweep over a shared
        # cache.  A private cache pays one uncontended acquire.
        with self.gram_cache.sweep_guard():
            with obs_trace.span(
                "features", cat="stage", attrs={"sets": len(x_sets) + len(z_sets)}
            ):
                lam_x_bank = [self.features(k) for k in x_sets]
                zero = jnp.zeros_like(lam_x_bank[0])
                lam_z_bank = [self.features(k) if k else zero for k in z_sets]
                m_eff_x = [self.m_eff_log[k] for k in x_sets]
                m_eff_z = [self.m_eff_log[k] if k else 0 for k in z_sets]
            pairs = np.array([[x_index[(i,)], z_index[ps]] for i, ps in todo])
            scores = cvlr_scores_batched(
                lam_x_bank,
                lam_z_bank,
                pairs,
                self.config.q_folds,
                self.config.lmbda,
                self.config.gamma,
                m_eff_x=m_eff_x,
                m_eff_z=m_eff_z,
                x_keys=x_sets,
                z_keys=z_sets,
                gram_cache=self.gram_cache,
                precision=self.precision,
                small_batch=small_batch and len(todo) <= self.SMALL_BATCH_CONFIGS,
            )
        if self.fault_plan is not None:
            scores = self.fault_plan.corrupt_scores(scores, self.fault_sweep)
        for key, s in zip(todo, scores):
            val = float(s)
            if not np.isfinite(val):
                val = self._recover_score(key[0], key[1])
            self._memo_put(key, val)
        return len(todo)
