"""PDAG / CPDAG machinery for GES (paper Sec. 6).

Adjacency convention (d x d int matrix):
  directed   i -> j :  A[i, j] = 1 and A[j, i] = 0
  undirected i -- j :  A[i, j] = A[j, i] = 1
  no edge            :  A[i, j] = A[j, i] = 0
"""

from __future__ import annotations

import itertools

import numpy as np


# ---------------------------------------------------------------- basic ops
def has_dir(a, i, j) -> bool:
    return bool(a[i, j] and not a[j, i])


def has_undir(a, i, j) -> bool:
    return bool(a[i, j] and a[j, i])


def adjacent(a, i, j) -> bool:
    return bool(a[i, j] or a[j, i])


def parents(a, j) -> list:
    return [i for i in range(a.shape[0]) if has_dir(a, i, j)]


def neighbors_undir(a, j) -> list:
    return [i for i in range(a.shape[0]) if has_undir(a, i, j)]


def adjacencies(a, j) -> list:
    return [i for i in range(a.shape[0]) if adjacent(a, i, j)]


def skeleton(a) -> np.ndarray:
    return ((a + a.T) > 0).astype(np.int8)


def is_clique(a, nodes) -> bool:
    nodes = list(nodes)
    return all(
        adjacent(a, x, y) for x, y in itertools.combinations(nodes, 2)
    )


def semi_directed_blocked(a, src, dst, blocked) -> bool:
    """True iff EVERY semi-directed path src ~> dst passes through `blocked`.

    Semi-directed: each hop is undirected or directed along travel.
    BFS over allowed hops avoiding blocked nodes; reachable => not blocked.
    """
    d = a.shape[0]
    blocked = set(blocked)
    if src in blocked or dst in blocked:
        return True
    seen = {src}
    stack = [src]
    while stack:
        u = stack.pop()
        if u == dst:
            return False
        for v in range(d):
            if v in seen or v in blocked:
                continue
            if has_dir(a, u, v) or has_undir(a, u, v):
                seen.add(v)
                stack.append(v)
    return True


# -------------------------------------------------------------- DAG checks
def is_dag(a) -> bool:
    d = a.shape[0]
    if np.any(a & a.T):
        return False
    indeg = a.sum(axis=0).astype(int)
    queue = [i for i in range(d) if indeg[i] == 0]
    seen = 0
    while queue:
        u = queue.pop()
        seen += 1
        for v in np.flatnonzero(a[u]):
            indeg[v] -= 1
            if indeg[v] == 0:
                queue.append(int(v))
    return seen == d


def topological_order(a) -> list:
    d = a.shape[0]
    indeg = a.sum(axis=0).astype(int)
    queue = sorted(i for i in range(d) if indeg[i] == 0)
    order = []
    while queue:
        u = queue.pop(0)
        order.append(u)
        for v in np.flatnonzero(a[u]):
            indeg[v] -= 1
            if indeg[v] == 0:
                queue.append(int(v))
        queue.sort()
    if len(order) != d:
        raise ValueError("not a DAG")
    return order


# ------------------------------------------------------------- Meek rules
def apply_meek_rules(a) -> np.ndarray:
    """Close a PDAG under Meek rules R1-R4 (orientation propagation)."""
    a = a.copy()
    d = a.shape[0]
    changed = True
    while changed:
        changed = False
        for x, y in itertools.permutations(range(d), 2):
            if not has_undir(a, x, y):
                continue
            # R1: z -> x, z not adjacent y  =>  x -> y
            if any(
                has_dir(a, z, x) and not adjacent(a, z, y)
                for z in range(d)
                if z not in (x, y)
            ):
                a[y, x] = 0
                changed = True
                continue
            # R2: x -> z -> y  =>  x -> y
            if any(
                has_dir(a, x, z) and has_dir(a, z, y)
                for z in range(d)
                if z not in (x, y)
            ):
                a[y, x] = 0
                changed = True
                continue
            # R3: x -- z1 -> y, x -- z2 -> y, z1 != z2 non-adjacent => x -> y
            zs = [
                z
                for z in range(d)
                if z not in (x, y) and has_undir(a, x, z) and has_dir(a, z, y)
            ]
            if any(
                not adjacent(a, z1, z2)
                for z1, z2 in itertools.combinations(zs, 2)
            ):
                a[y, x] = 0
                changed = True
                continue
            # R4: x -- z1, z1 -> z2, z2 -> y, x -- z2 (z1, y non-adjacent)
            done = False
            for z1 in range(d):
                if z1 in (x, y) or not has_undir(a, x, z1):
                    continue
                for z2 in range(d):
                    if z2 in (x, y, z1):
                        continue
                    if (
                        has_dir(a, z1, z2)
                        and has_dir(a, z2, y)
                        and adjacent(a, x, z2)
                        and not adjacent(a, z1, y)
                    ):
                        a[y, x] = 0
                        changed = True
                        done = True
                        break
                if done:
                    break
    return a


def dag_to_cpdag(dag) -> np.ndarray:
    """CPDAG = skeleton + v-structures, closed under Meek rules."""
    dag = np.asarray(dag, dtype=np.int8)
    d = dag.shape[0]
    pat = skeleton(dag).copy()
    # v-structures x -> z <- y with x, y non-adjacent stay directed
    for z in range(d):
        pa = np.flatnonzero(dag[:, z])
        for x, y in itertools.combinations(pa, 2):
            if not (dag[x, y] or dag[y, x]):
                pat[z, x] = 0
                pat[z, y] = 0
    return apply_meek_rules(pat)


def pdag_to_dag(pdag) -> np.ndarray:
    """Dor & Tarsi consistent extension; raises if none exists."""
    a = np.asarray(pdag, dtype=np.int8).copy()
    out = a.copy()  # orientations get written here
    alive = list(range(a.shape[0]))
    while alive:
        found = None
        for x in alive:
            others = [v for v in alive if v != x]
            # (a) x is a sink among alive: no directed edge x -> v
            if any(has_dir(a, x, v) for v in others):
                continue
            # (b) undirected neighbors of x adjacent to all adjacents of x
            nb = [v for v in others if has_undir(a, x, v)]
            adj = [v for v in others if adjacent(a, x, v)]
            ok = all(
                adjacent(a, u, v) for u in nb for v in adj if u != v
            )
            if ok:
                found = x
                break
        if found is None:
            raise ValueError("PDAG admits no consistent extension")
        x = found
        for v in alive:
            if v != x and has_undir(a, x, v):
                out[x, v] = 0  # orient v -> x
                out[v, x] = 1
        for v in alive:
            if v != x:
                a[x, v] = a[v, x] = 0
        alive.remove(x)
    assert is_dag(out), "extension failed to produce a DAG"
    return out


def pdag_to_cpdag(pdag) -> np.ndarray:
    """Rebuild the CPDAG of the equivalence class containing `pdag`."""
    return dag_to_cpdag(pdag_to_dag(pdag))


def random_dag(d: int, density: float, rng) -> np.ndarray:
    """Random DAG with expected edge density (paper Sec. 7.4)."""
    order = rng.permutation(d)
    a = np.zeros((d, d), dtype=np.int8)
    for i in range(d):
        for j in range(i + 1, d):
            if rng.random() < density:
                a[order[i], order[j]] = 1
    return a
