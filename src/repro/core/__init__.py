"""Core library: the paper's contribution (CV-LR generalized score) in JAX.

The causal-discovery score algebra needs float64: score magnitudes are
O(n * 1e1) while GES decisions hinge on O(1) differences, and the
machine-precision identity tests (exact score == low-rank score on low-rank
kernels) are meaningless in float32.  We therefore enable x64 here, at core
import time.  All LM-model code passes explicit f32/bf16 dtypes and is
unaffected.
"""

from jax import config as _config

_config.update("jax_enable_x64", True)

from repro.core.kernel_fns import (  # noqa: E402
    KernelSpec,
    median_heuristic_width,
    kernel_matrix,
    kernel_rows,
)
from repro.core.spec import (  # noqa: E402
    DataSpec,
    EngineOptions,
    VariableSpec,
)
from repro.core.score_exact import CVScorer  # noqa: E402
from repro.core.score_lowrank import CVLRScorer  # noqa: E402
from repro.core.api import (  # noqa: E402
    DiscoverySession,
    FaultPlan,
    RunState,
    causal_discover,
    make_scorer,
)
from repro.core.runstate import (  # noqa: E402
    DeadlineExceeded,
    SessionCancelled,
)

# The factorization layer lives in repro.features (PR 5); its one-release
# `repro.core.lowrank` deprecation shim is gone — import
# incomplete_cholesky / discrete_lowrank / lowrank_features from
# repro.features.backends.

__all__ = [
    "KernelSpec",
    "median_heuristic_width",
    "kernel_matrix",
    "kernel_rows",
    "DataSpec",
    "VariableSpec",
    "EngineOptions",
    "DiscoverySession",
    "DeadlineExceeded",
    "SessionCancelled",
    "FaultPlan",
    "RunState",
    "CVScorer",
    "CVLRScorer",
    "causal_discover",
    "make_scorer",
]
