"""Core library: the paper's contribution (CV-LR generalized score) in JAX.

The causal-discovery score algebra needs float64: score magnitudes are
O(n * 1e1) while GES decisions hinge on O(1) differences, and the
machine-precision identity tests (exact score == low-rank score on low-rank
kernels) are meaningless in float32.  We therefore enable x64 here, at core
import time.  All LM-model code passes explicit f32/bf16 dtypes and is
unaffected.
"""

from jax import config as _config

_config.update("jax_enable_x64", True)

from repro.core.kernel_fns import (  # noqa: E402
    KernelSpec,
    median_heuristic_width,
    kernel_matrix,
    kernel_rows,
)
from repro.core.spec import (  # noqa: E402
    DataSpec,
    EngineOptions,
    VariableSpec,
)
from repro.core.score_exact import CVScorer  # noqa: E402
from repro.core.score_lowrank import CVLRScorer  # noqa: E402
from repro.core.api import (  # noqa: E402
    DiscoverySession,
    FaultPlan,
    RunState,
    causal_discover,
    make_scorer,
)

# The factorization layer moved to repro.features (PR 5).  The names stay
# reachable from repro.core for one release through this lazy re-export —
# lazy both for the deprecation window and because an eager import would
# cycle (repro.features.backends imports repro.core.kernel_fns).
_MOVED_TO_FEATURES = (
    "incomplete_cholesky",
    "discrete_lowrank",
    "lowrank_features",
)


def __getattr__(name):
    if name in _MOVED_TO_FEATURES:
        import warnings

        warnings.warn(
            f"repro.core.{name} is deprecated; import it from "
            "repro.features.backends (the old location keeps working for "
            "one release and re-exports the identical implementation)",
            DeprecationWarning,
            stacklevel=2,
        )
        from repro.features import backends

        return getattr(backends, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "KernelSpec",
    "median_heuristic_width",
    "kernel_matrix",
    "kernel_rows",
    "incomplete_cholesky",
    "discrete_lowrank",
    "lowrank_features",
    "DataSpec",
    "VariableSpec",
    "EngineOptions",
    "DiscoverySession",
    "FaultPlan",
    "RunState",
    "CVScorer",
    "CVLRScorer",
    "causal_discover",
    "make_scorer",
]
