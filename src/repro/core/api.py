"""Public API: build scorers, run causal discovery end-to-end."""

from __future__ import annotations

import numpy as np

from repro.core.ges import ges, GESResult
from repro.core.score_common import ScoreConfig
from repro.core.score_exact import CVScorer
from repro.core.score_lowrank import CVLRScorer


def make_scorer(
    data,
    method: str = "cvlr",
    dims=None,
    discrete=None,
    config: ScoreConfig | None = None,
    batched: bool = True,
):
    """method: 'cvlr' (the paper) or 'cv' (exact O(n^3) baseline).

    batched: let the CV-LR scorer evaluate GES frontiers through the
    batched engine (default); False forces the sequential per-candidate
    oracle path.  Ignored by the exact scorer, which is always lazy.
    """
    if method == "cvlr":
        return CVLRScorer(
            data, dims=dims, discrete=discrete, config=config, batched=batched
        )
    if method == "cv":
        return CVScorer(data, dims=dims, discrete=discrete, config=config)
    raise ValueError(f"unknown scoring method {method!r}")


def causal_discover(
    data,
    method: str = "cvlr",
    dims=None,
    discrete=None,
    config: ScoreConfig | None = None,
    max_subset: int | None = None,
    batch_hook=None,
    verbose: bool = False,
    batched: bool = True,
) -> GESResult:
    """GES + (CV-LR | CV) generalized score on an (n, cols) data matrix.

    dims: per-variable column widths (multi-dim variables); default all 1.
    discrete: per-variable discreteness flags (routes Alg. 2).
    batched: evaluate each GES frontier through the batched scoring engine
    (CV-LR only; the default).  Results are identical to the sequential
    path up to machine-precision reassociation.
    Returns a GESResult whose `cpdag` is the estimated equivalence class.
    """
    scorer = make_scorer(
        data, method=method, dims=dims, discrete=discrete, config=config,
        batched=batched,
    )
    return ges(scorer, max_subset=max_subset, batch_hook=batch_hook, verbose=verbose)
