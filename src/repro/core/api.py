"""Public API: declarative causal discovery.

The surface is three objects plus two functions:

* `repro.core.spec.DataSpec` — *what the data is*: one
  `VariableSpec(name, dim, kind)` per variable, built explicitly
  (`DataSpec.from_arrays`) or by heuristics (`DataSpec.infer`).
* `repro.core.spec.EngineOptions` — *how to run*: engine selection
  (`"batched"` | `"sequential"` | `"sharded"`), Gram-block cache bounds,
  and the Gram-accumulation `precision` policy.
* `DiscoverySession` — scorer construction + the GES loop, owning the
  sweep lifecycle (`begin_sweep` / `score_frontier` / `end_sweep`) and a
  per-sweep log; `causal_discover` is the one-call wrapper over it.
* `make_scorer` — construct just the local scorer (`CVLRScorer`, the
  paper's O(n) method, or `CVScorer`, the exact O(n^3) baseline).
* `causal_discover` — session + GES in one call; returns the CPDAG.

The pre-PR-4 kwargs (`dims=`, `discrete=`, `batched=`,
`gram_cache_entries=`, `device_bank_mb=`, `batch_hook=`) keep working for
one release through a deprecation shim — they emit `DeprecationWarning`
and produce identical results.  See README.md §Migration for the old →
new mapping and docs/ARCHITECTURE.md for the engine behind the options.
"""

from __future__ import annotations

import warnings

from repro.core.ges import ges, GESResult
from repro.core.score_common import ScoreConfig
from repro.core.score_exact import CVScorer
from repro.core.score_lowrank import CVLRScorer
from repro.core.spec import DataSpec, EngineOptions, VariableSpec, resolve_spec

__all__ = [
    "DataSpec",
    "VariableSpec",
    "EngineOptions",
    "DiscoverySession",
    "make_scorer",
    "causal_discover",
]

_UNSET = object()  # distinguishes "not passed" from an explicit None


def _deprecated(old: str, new: str, stacklevel: int = 3) -> None:
    # stacklevel must land on the *caller of the public API*, not on this
    # module: the CI gate runs the suite with -W error::DeprecationWarning
    # filtered to repro.*, so repo code calling its own deprecated surface
    # fails loudly while user/test code merely sees the warning.
    warnings.warn(
        f"{old} is deprecated; {new} (the old form keeps working for one "
        "release and produces identical results)",
        DeprecationWarning,
        stacklevel=stacklevel,
    )


def _resolve_legacy_spec(data, spec, dims, discrete):
    """Fold the deprecated dims=/discrete= lists into a DataSpec."""
    if dims is not _UNSET:
        _deprecated(
            "the dims= list",
            "describe variables with spec=DataSpec.from_arrays(...)",
            stacklevel=4,
        )
    if discrete is not _UNSET:
        _deprecated(
            "the discrete= list",
            "describe variables with spec=DataSpec.from_arrays(...)",
            stacklevel=4,
        )
    return resolve_spec(
        data,
        spec=spec,
        dims=None if dims is _UNSET else dims,
        discrete=None if discrete is _UNSET else discrete,
    )


def _resolve_legacy_options(options, batched, gram_cache_entries, device_bank_mb):
    """Fold the deprecated loose engine kwargs into an EngineOptions."""
    legacy = {
        "batched=": batched,
        "gram_cache_entries=": gram_cache_entries,
        "device_bank_mb=": device_bank_mb,
    }
    passed = {k: v for k, v in legacy.items() if v is not _UNSET}
    if options is not None:
        if passed:
            raise ValueError(
                f"pass either options=EngineOptions(...) or the legacy "
                f"kwargs {sorted(passed)}, not both"
            )
        if not isinstance(options, EngineOptions):
            raise ValueError(
                f"options must be an EngineOptions, got {type(options).__name__}"
            )
        return options
    for name in sorted(passed):
        field = {
            "batched=": 'engine="batched"/"sequential"',
            "gram_cache_entries=": "gram_cache_entries=",
            "device_bank_mb=": "device_bank_mb=",
        }[name]
        _deprecated(name, f"set {field} on options=EngineOptions(...)", stacklevel=4)
    kw = {}
    if batched is not _UNSET:
        kw["engine"] = "batched" if batched else "sequential"
    if gram_cache_entries is not _UNSET:
        kw["gram_cache_entries"] = gram_cache_entries
    if device_bank_mb is not _UNSET:
        kw["device_bank_mb"] = device_bank_mb
    return EngineOptions(**kw)


def make_scorer(
    data,
    method: str = "cvlr",
    spec: DataSpec | None = None,
    options: EngineOptions | None = None,
    config: ScoreConfig | None = None,
    feature_bank=None,
    # -- deprecated (one release): the pre-PR-4 loose kwargs -------------
    dims=_UNSET,
    discrete=_UNSET,
    batched=_UNSET,
    gram_cache_entries=_UNSET,
    device_bank_mb=_UNSET,
):
    """Build a local scorer over an (n, cols) data matrix.

    method: 'cvlr' (the paper's low-rank CV score) or 'cv' (exact O(n^3)
    baseline).  spec: a `repro.core.spec.DataSpec` describing the
    variables (default: every column a continuous 1-D variable; use
    `DataSpec.infer(data)` for dtype/cardinality heuristics).  options: a
    `repro.core.spec.EngineOptions` — engine selection, Gram-block cache
    bounds (`gram_cache_entries`, `device_bank_mb`), the `precision`
    policy, and the `features` factorization policy
    (`repro.features.policy.FeaturePolicy`); every field is documented
    there.  feature_bank: a `repro.features.bank.FeatureBank` to reuse
    built factors across scorers/sessions over the same data (CV-LR
    only — passing one with method='cv' raises).  The exact scorer
    ignores the engine options except that `engine="sharded"` is
    rejected (the distributed pipeline is CV-LR only).  config: score
    hyperparameters (`ScoreConfig`; paper defaults).

    The legacy kwargs (`dims`/`discrete`/`batched`/`gram_cache_entries`/
    `device_bank_mb`) are deprecated shims over the two objects.
    """
    spec = _resolve_legacy_spec(data, spec, dims, discrete)
    options = _resolve_legacy_options(
        options, batched, gram_cache_entries, device_bank_mb
    )
    if method == "cvlr":
        return CVLRScorer(
            data, spec=spec, config=config, options=options,
            feature_bank=feature_bank,
        )
    if method == "cv":
        if options.engine == "sharded":
            raise ValueError(
                'EngineOptions(engine="sharded") requires method="cvlr" — '
                "the distributed pipeline scores low-rank factors only"
            )
        if feature_bank is not None:
            raise ValueError(
                'feature_bank= requires method="cvlr" — the exact scorer '
                "builds no low-rank factors"
            )
        return CVScorer(data, spec=spec, config=config)
    raise ValueError(f"unknown scoring method {method!r}")


class DiscoverySession:
    """One causal-discovery run: scorer construction + the GES loop, with
    the session owning the sweep lifecycle.

    `repro.core.ges.ges` calls `begin_sweep(phase)` /
    `score_frontier(configs)` / `end_sweep(step)` around every frontier
    evaluation; the session routes the scoring by its `EngineOptions`
    (`"batched"` → the scorer's prefetch engine, `"sharded"` → the
    distributed stacked pipeline, `"sequential"` → lazy per-candidate
    scores) and records one entry per sweep in `sweep_log`:
    ``{phase, sweep, n_configs, n_scored, step, gram_cache,
    feature_bank}`` with the Gram-cache and feature-bank counter deltas
    for that sweep.  This is the seam the planned
    incremental-frontier-delta optimization plugs into — a session sees
    consecutive frontiers and can diff them.

    The session owns a `repro.features.bank.FeatureBank` (exposed as
    `feature_bank`): built factors persist across the run's sweeps, and
    passing the same bank to a later session over the same data skips
    rebuilding entirely — the sweep log's ``feature_bank`` deltas show
    the hits.

    Typical use is through `causal_discover`; instantiate directly when
    you want the scorer, the per-sweep log, or custom search parameters:

        session = DiscoverySession(data, options=EngineOptions())
        result = session.run()
        session.sweep_log  # per-sweep engine/cache telemetry
        session.feature_bank.stats  # factor-build/hit/miss counters
    """

    def __init__(
        self,
        data,
        spec: DataSpec | None = None,
        options: EngineOptions | None = None,
        *,
        method: str = "cvlr",
        config: ScoreConfig | None = None,
        max_subset: int | None = None,
        verbose: bool = False,
        feature_bank=None,
    ):
        self.options = options if options is not None else EngineOptions()
        self.scorer = make_scorer(
            data, method=method, spec=spec, options=self.options,
            config=config, feature_bank=feature_bank,
        )
        self.spec = self.scorer.view.spec
        self.feature_bank = getattr(self.scorer, "feature_bank", None)
        self.max_subset = max_subset
        self.verbose = verbose
        self.sweep_log: list = []
        self.result: GESResult | None = None
        self._active: dict | None = None
        if self.options.engine == "sharded":
            # resolved once, loudly, instead of failing mid-search
            from repro.core.distributed_score import sharded_batch_hook

            self._sharded_hook = sharded_batch_hook
        else:
            self._sharded_hook = None

    # -- sweep lifecycle (driven by repro.core.ges.ges) -------------------
    def begin_sweep(self, phase: str) -> None:
        stats = getattr(self.scorer, "gram_cache", None)
        self._active = {
            "phase": phase,
            "sweep": len(self.sweep_log),
            "n_configs": 0,
            "n_scored": 0,
            "step": None,
            "_stats0": dict(stats.stats) if stats is not None else None,
            "_bank0": dict(self.feature_bank.stats)
            if self.feature_bank is not None
            else None,
        }

    def score_frontier(self, configs) -> int:
        """Evaluate one sweep's (node, parents) frontier through the
        engine the options selected; returns the number of scores
        actually computed (cached configurations cost nothing)."""
        if self._active is None:
            self.begin_sweep("adhoc")
        self._active["n_configs"] = len(configs)
        if self._sharded_hook is not None:
            n = self._sharded_hook(self.scorer, configs)
        elif self.options.batched:
            prefetch = getattr(self.scorer, "prefetch", None)
            n = prefetch(configs) if prefetch is not None else 0
        else:
            n = 0  # sequential: ges falls back to lazy local_score
        self._active["n_scored"] = int(n)
        return int(n)

    def end_sweep(self, step=None) -> None:
        rec, self._active = self._active, None
        if rec is None:
            return
        rec["step"] = step
        stats0 = rec.pop("_stats0")
        cache = getattr(self.scorer, "gram_cache", None)
        if cache is not None and stats0 is not None:
            counters = (
                "hits", "misses", "evictions",
                "promotions", "spills", "bank_fallbacks",
            )
            rec["gram_cache"] = {
                k: cache.stats[k] - stats0[k] for k in counters
            }
        bank0 = rec.pop("_bank0")
        if self.feature_bank is not None and bank0 is not None:
            rec["feature_bank"] = {
                k: round(self.feature_bank.stats[k] - bank0[k], 4)
                for k in ("hits", "misses", "builds", "build_s")
            }
        self.sweep_log.append(rec)

    # -- the run ----------------------------------------------------------
    def run(self) -> GESResult:
        """GES end to end; returns (and retains as `self.result`) the
        `GESResult` whose `cpdag` is the estimated equivalence class."""
        self.result = ges(
            self.scorer,
            max_subset=self.max_subset,
            verbose=self.verbose,
            session=self,
        )
        return self.result


def causal_discover(
    data,
    method: str = "cvlr",
    spec: DataSpec | None = None,
    options: EngineOptions | None = None,
    config: ScoreConfig | None = None,
    max_subset: int | None = None,
    verbose: bool = False,
    # -- deprecated (one release): the pre-PR-4 loose kwargs -------------
    dims=_UNSET,
    discrete=_UNSET,
    batched=_UNSET,
    gram_cache_entries=_UNSET,
    device_bank_mb=_UNSET,
    batch_hook=_UNSET,
) -> GESResult:
    """GES + (CV-LR | CV) generalized score on an (n, cols) data matrix.

    spec: `DataSpec` describing the variables — `DataSpec.from_arrays`
    absorbs explicit dims/discreteness, `DataSpec.infer` guesses kinds
    from dtype/cardinality (routing the paper's Alg.-2 sampling for
    discrete variables).  options: `EngineOptions` — engine
    (`"batched"`/`"sequential"`/`"sharded"`), cache bounds, `precision`.
    Selecting `"sharded"` routes every GES frontier through
    `repro.core.distributed_score` internally; no `batch_hook` callable
    needed.  Returns a GESResult whose `cpdag` is the estimated
    equivalence class; the underlying `DiscoverySession` (scorer handle,
    per-sweep log) is one `DiscoverySession(...).run()` away when you
    need it.

    The legacy kwargs are deprecated shims: `dims`/`discrete` fold into
    `spec`, `batched`/`gram_cache_entries`/`device_bank_mb` into
    `options`, and `batch_hook=` is replaced by
    `EngineOptions(engine="sharded")` for the supported paths.
    """
    spec = _resolve_legacy_spec(data, spec, dims, discrete)
    options = _resolve_legacy_options(
        options, batched, gram_cache_entries, device_bank_mb
    )
    # an explicit batch_hook=None was the old default ("no hook") — treat
    # it as not passed rather than warning about a no-op value
    if batch_hook is not _UNSET and batch_hook is not None:
        _deprecated(
            "causal_discover(batch_hook=...)",
            'select options=EngineOptions(engine="sharded") instead',
        )
        scorer = make_scorer(
            data, method=method, spec=spec, options=options, config=config
        )
        return ges(
            scorer, max_subset=max_subset, batch_hook=batch_hook, verbose=verbose
        )
    return DiscoverySession(
        data,
        spec=spec,
        options=options,
        method=method,
        config=config,
        max_subset=max_subset,
        verbose=verbose,
    ).run()
