"""Public API: build scorers, run causal discovery end-to-end."""

from __future__ import annotations

import numpy as np

from repro.core.ges import ges, GESResult
from repro.core.score_common import ScoreConfig
from repro.core.score_exact import CVScorer
from repro.core.score_lowrank import CVLRScorer


def make_scorer(
    data,
    method: str = "cvlr",
    dims=None,
    discrete=None,
    config: ScoreConfig | None = None,
    batched: bool = True,
    gram_cache_entries: int | None = CVLRScorer.DEFAULT_GRAM_CACHE_ENTRIES,
):
    """method: 'cvlr' (the paper) or 'cv' (exact O(n^3) baseline).

    batched: let the CV-LR scorer evaluate GES frontiers through the
    batched engine (default); False forces the sequential per-candidate
    oracle path.  Ignored by the exact scorer, which is always lazy.

    gram_cache_entries: LRU bound on the CV-LR Gram-block cache (None =
    unbounded).  The default is sized to a sweep's working set — see
    CVLRScorer.DEFAULT_GRAM_CACHE_ENTRIES; shrink it on memory-tight
    hosts, grow it for very large frontiers.  Ignored by the exact
    scorer.
    """
    if method == "cvlr":
        return CVLRScorer(
            data, dims=dims, discrete=discrete, config=config, batched=batched,
            gram_cache_entries=gram_cache_entries,
        )
    if method == "cv":
        return CVScorer(data, dims=dims, discrete=discrete, config=config)
    raise ValueError(f"unknown scoring method {method!r}")


def causal_discover(
    data,
    method: str = "cvlr",
    dims=None,
    discrete=None,
    config: ScoreConfig | None = None,
    max_subset: int | None = None,
    batch_hook=None,
    verbose: bool = False,
    batched: bool = True,
    gram_cache_entries: int | None = CVLRScorer.DEFAULT_GRAM_CACHE_ENTRIES,
) -> GESResult:
    """GES + (CV-LR | CV) generalized score on an (n, cols) data matrix.

    dims: per-variable column widths (multi-dim variables); default all 1.
    discrete: per-variable discreteness flags (routes Alg. 2).
    batched: evaluate each GES frontier through the batched scoring engine
    (CV-LR only; the default).  On CPU (and under interpret mode) results
    are identical to the sequential path up to machine-precision
    reassociation; on TPU the fused fold-Gram kernel contracts at f32
    (Mosaic has no f64 MXU path — see repro/kernels/fold_gram.py), so
    batched scores there agree with the oracle only to f32 Gram accuracy
    (~1e-7 relative), like every other compiled kernel in repro.kernels.
    gram_cache_entries: LRU bound on the Gram-block cache (see
    `make_scorer`).
    Returns a GESResult whose `cpdag` is the estimated equivalence class.
    """
    scorer = make_scorer(
        data, method=method, dims=dims, discrete=discrete, config=config,
        batched=batched, gram_cache_entries=gram_cache_entries,
    )
    return ges(scorer, max_subset=max_subset, batch_hook=batch_hook, verbose=verbose)
