"""Public API: build scorers, run causal discovery end-to-end.

Two entry points:

* `make_scorer` — construct a decomposable local scorer (`CVLRScorer`,
  the paper's O(n) method, or `CVScorer`, the exact O(n^3) baseline) with
  the engine knobs documented below.
* `causal_discover` — `make_scorer` + GES in one call; returns the
  estimated CPDAG.

See README.md for a quickstart and docs/ARCHITECTURE.md for how the
batched scoring engine behind these knobs is put together.
"""

from __future__ import annotations

import numpy as np

from repro.core.ges import ges, GESResult
from repro.core.score_common import ScoreConfig
from repro.core.score_exact import CVScorer
from repro.core.score_lowrank import CVLRScorer


def make_scorer(
    data,
    method: str = "cvlr",
    dims=None,
    discrete=None,
    config: ScoreConfig | None = None,
    batched: bool = True,
    gram_cache_entries: int | None = CVLRScorer.DEFAULT_GRAM_CACHE_ENTRIES,
    device_bank_mb: float | None = CVLRScorer.DEFAULT_DEVICE_BANK_MB,
):
    """Build a local scorer over an (n, cols) data matrix.

    method: 'cvlr' (the paper's low-rank CV score) or 'cv' (exact O(n^3)
    baseline).  dims / discrete: per-variable column widths and
    discreteness flags (see `causal_discover`).  config: hyperparameters
    (`ScoreConfig`; paper defaults).

    batched: let the CV-LR scorer evaluate GES frontiers through the
    batched engine (default); False forces the sequential per-candidate
    oracle path.  Ignored by the exact scorer, which is always lazy.

    gram_cache_entries: LRU bound on the CV-LR Gram-block cache — the
    total entry count across its host and device tiers (None = unbounded).
    The default is sized to a sweep's working set — see
    `CVLRScorer.DEFAULT_GRAM_CACHE_ENTRIES`; shrink it on memory-tight
    hosts, grow it for very large frontiers.  Ignored by the exact scorer.

    device_bank_mb: byte budget (in MB) for the Gram-block cache's
    *device tier* — the device-resident fold pipeline, where the fused
    Gram kernels scatter blocks straight into padded per-width device bank
    tensors and the fold stage index-gathers them, with no host round-trip
    between the stages (see `repro.core.score_lowrank.cvlr_scores_batched`
    and docs/ARCHITECTURE.md).  Cached blocks persist on device across
    sweeps and spill to the host tier only on LRU eviction.  0 or None
    disables the tier: the engine then runs the host-assembly path (same
    scores, bit-identical on CPU); a sweep whose working set exceeds the
    budget falls back to that path automatically for just that sweep.
    Default `CVLRScorer.DEFAULT_DEVICE_BANK_MB`.  Ignored by the exact
    scorer.
    """
    if method == "cvlr":
        return CVLRScorer(
            data, dims=dims, discrete=discrete, config=config, batched=batched,
            gram_cache_entries=gram_cache_entries,
            device_bank_mb=device_bank_mb,
        )
    if method == "cv":
        return CVScorer(data, dims=dims, discrete=discrete, config=config)
    raise ValueError(f"unknown scoring method {method!r}")


def causal_discover(
    data,
    method: str = "cvlr",
    dims=None,
    discrete=None,
    config: ScoreConfig | None = None,
    max_subset: int | None = None,
    batch_hook=None,
    verbose: bool = False,
    batched: bool = True,
    gram_cache_entries: int | None = CVLRScorer.DEFAULT_GRAM_CACHE_ENTRIES,
    device_bank_mb: float | None = CVLRScorer.DEFAULT_DEVICE_BANK_MB,
) -> GESResult:
    """GES + (CV-LR | CV) generalized score on an (n, cols) data matrix.

    dims: per-variable column widths (multi-dim variables); default all 1.
    discrete: per-variable discreteness flags (routes Alg. 2).
    batched: evaluate each GES frontier through the batched scoring engine
    (CV-LR only; the default).  On CPU (and under interpret mode) results
    are identical to the sequential path up to machine-precision
    reassociation — this holds for both the device-bank and host-assembly
    engine paths; on TPU the fused fold-Gram kernels contract at f32
    (Mosaic has no f64 MXU path — see repro/kernels/fold_gram.py), so
    batched scores there agree with the oracle only to f32 Gram accuracy
    (~1e-7 relative), like every other compiled kernel in repro.kernels.
    gram_cache_entries / device_bank_mb: Gram-block cache bounds — entry
    count and device-tier byte budget (see `make_scorer`).
    Returns a GESResult whose `cpdag` is the estimated equivalence class.
    """
    scorer = make_scorer(
        data, method=method, dims=dims, discrete=discrete, config=config,
        batched=batched, gram_cache_entries=gram_cache_entries,
        device_bank_mb=device_bank_mb,
    )
    return ges(scorer, max_subset=max_subset, batch_hook=batch_hook, verbose=verbose)
