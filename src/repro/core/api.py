"""Public API: build scorers, run causal discovery end-to-end."""

from __future__ import annotations

import numpy as np

from repro.core.ges import ges, GESResult
from repro.core.score_common import ScoreConfig
from repro.core.score_exact import CVScorer
from repro.core.score_lowrank import CVLRScorer


def make_scorer(
    data,
    method: str = "cvlr",
    dims=None,
    discrete=None,
    config: ScoreConfig | None = None,
):
    """method: 'cvlr' (the paper) or 'cv' (exact O(n^3) baseline)."""
    if method == "cvlr":
        return CVLRScorer(data, dims=dims, discrete=discrete, config=config)
    if method == "cv":
        return CVScorer(data, dims=dims, discrete=discrete, config=config)
    raise ValueError(f"unknown scoring method {method!r}")


def causal_discover(
    data,
    method: str = "cvlr",
    dims=None,
    discrete=None,
    config: ScoreConfig | None = None,
    max_subset: int | None = None,
    batch_hook=None,
    verbose: bool = False,
) -> GESResult:
    """GES + (CV-LR | CV) generalized score on an (n, cols) data matrix.

    dims: per-variable column widths (multi-dim variables); default all 1.
    discrete: per-variable discreteness flags (routes Alg. 2).
    Returns a GESResult whose `cpdag` is the estimated equivalence class.
    """
    scorer = make_scorer(data, method=method, dims=dims, discrete=discrete, config=config)
    return ges(scorer, max_subset=max_subset, batch_hook=batch_hook, verbose=verbose)
