"""Public API: declarative causal discovery.

The surface is three objects plus two functions:

* `repro.core.spec.DataSpec` — *what the data is*: one
  `VariableSpec(name, dim, kind)` per variable, built explicitly
  (`DataSpec.from_arrays`) or by heuristics (`DataSpec.infer`).
* `repro.core.spec.EngineOptions` — *how to run*: engine selection
  (`"batched"` | `"sequential"` | `"sharded"`), Gram-block cache bounds,
  and the Gram-accumulation `precision` policy.
* `DiscoverySession` — scorer construction + the GES loop, owning the
  sweep lifecycle (`begin_sweep` / `score_frontier` / `end_sweep`) and a
  per-sweep log; `causal_discover` is the one-call wrapper over it.
* `make_scorer` — construct just the local scorer (`CVLRScorer`, the
  paper's O(n) method, or `CVScorer`, the exact O(n^3) baseline).
* `causal_discover` — session + GES in one call; returns the CPDAG.

The pre-PR-4 kwargs (`dims=`, `discrete=`, `batched=`,
`gram_cache_entries=`, `device_bank_mb=`, `batch_hook=`) keep working for
one release through a deprecation shim — they emit `DeprecationWarning`
and produce identical results.  See README.md §Migration for the old →
new mapping and docs/ARCHITECTURE.md for the engine behind the options.
"""

from __future__ import annotations

import warnings

import numpy as np

from repro.checkpoint.store import AsyncCheckpointer
from repro.core.ges import ges, GESResult
from repro.core.runstate import (
    FaultPlan,
    InjectedFault,
    RunState,
    _norm_step,
    load_latest_runstate,
)
from repro.core.score_common import ScoreConfig
from repro.core.score_exact import CVScorer
from repro.core.score_lowrank import CVLRScorer
from repro.core.spec import DataSpec, EngineOptions, VariableSpec, resolve_spec

__all__ = [
    "DataSpec",
    "VariableSpec",
    "EngineOptions",
    "DiscoverySession",
    "FaultPlan",
    "RunState",
    "make_scorer",
    "causal_discover",
]

RESUME_MODES = ("never", "auto")

_UNSET = object()  # distinguishes "not passed" from an explicit None


def _deprecated(old: str, new: str, stacklevel: int = 3) -> None:
    # stacklevel must land on the *caller of the public API*, not on this
    # module: the CI gate runs the suite with -W error::DeprecationWarning
    # filtered to repro.*, so repo code calling its own deprecated surface
    # fails loudly while user/test code merely sees the warning.
    warnings.warn(
        f"{old} is deprecated; {new} (the old form keeps working for one "
        "release and produces identical results)",
        DeprecationWarning,
        stacklevel=stacklevel,
    )


def _resolve_legacy_spec(data, spec, dims, discrete):
    """Fold the deprecated dims=/discrete= lists into a DataSpec."""
    if dims is not _UNSET:
        _deprecated(
            "the dims= list",
            "describe variables with spec=DataSpec.from_arrays(...)",
            stacklevel=4,
        )
    if discrete is not _UNSET:
        _deprecated(
            "the discrete= list",
            "describe variables with spec=DataSpec.from_arrays(...)",
            stacklevel=4,
        )
    return resolve_spec(
        data,
        spec=spec,
        dims=None if dims is _UNSET else dims,
        discrete=None if discrete is _UNSET else discrete,
    )


def _resolve_legacy_options(options, batched, gram_cache_entries, device_bank_mb):
    """Fold the deprecated loose engine kwargs into an EngineOptions."""
    legacy = {
        "batched=": batched,
        "gram_cache_entries=": gram_cache_entries,
        "device_bank_mb=": device_bank_mb,
    }
    passed = {k: v for k, v in legacy.items() if v is not _UNSET}
    if options is not None:
        if passed:
            raise ValueError(
                f"pass either options=EngineOptions(...) or the legacy "
                f"kwargs {sorted(passed)}, not both"
            )
        if not isinstance(options, EngineOptions):
            raise ValueError(
                f"options must be an EngineOptions, got {type(options).__name__}"
            )
        return options
    for name in sorted(passed):
        field = {
            "batched=": 'engine="batched"/"sequential"',
            "gram_cache_entries=": "gram_cache_entries=",
            "device_bank_mb=": "device_bank_mb=",
        }[name]
        _deprecated(name, f"set {field} on options=EngineOptions(...)", stacklevel=4)
    kw = {}
    if batched is not _UNSET:
        kw["engine"] = "batched" if batched else "sequential"
    if gram_cache_entries is not _UNSET:
        kw["gram_cache_entries"] = gram_cache_entries
    if device_bank_mb is not _UNSET:
        kw["device_bank_mb"] = device_bank_mb
    return EngineOptions(**kw)


def make_scorer(
    data,
    method: str = "cvlr",
    spec: DataSpec | None = None,
    options: EngineOptions | None = None,
    config: ScoreConfig | None = None,
    feature_bank=None,
    # -- deprecated (one release): the pre-PR-4 loose kwargs -------------
    dims=_UNSET,
    discrete=_UNSET,
    batched=_UNSET,
    gram_cache_entries=_UNSET,
    device_bank_mb=_UNSET,
):
    """Build a local scorer over an (n, cols) data matrix.

    method: 'cvlr' (the paper's low-rank CV score) or 'cv' (exact O(n^3)
    baseline).  spec: a `repro.core.spec.DataSpec` describing the
    variables (default: every column a continuous 1-D variable; use
    `DataSpec.infer(data)` for dtype/cardinality heuristics).  options: a
    `repro.core.spec.EngineOptions` — engine selection, Gram-block cache
    bounds (`gram_cache_entries`, `device_bank_mb`), the `precision`
    policy, and the `features` factorization policy
    (`repro.features.policy.FeaturePolicy`); every field is documented
    there.  feature_bank: a `repro.features.bank.FeatureBank` to reuse
    built factors across scorers/sessions over the same data (CV-LR
    only — passing one with method='cv' raises).  The exact scorer
    ignores the engine options except that `engine="sharded"` is
    rejected (the distributed pipeline is CV-LR only).  config: score
    hyperparameters (`ScoreConfig`; paper defaults).

    The legacy kwargs (`dims`/`discrete`/`batched`/`gram_cache_entries`/
    `device_bank_mb`) are deprecated shims over the two objects.
    """
    spec = _resolve_legacy_spec(data, spec, dims, discrete)
    options = _resolve_legacy_options(
        options, batched, gram_cache_entries, device_bank_mb
    )
    if method == "cvlr":
        return CVLRScorer(
            data, spec=spec, config=config, options=options,
            feature_bank=feature_bank,
        )
    if method == "cv":
        if options.engine == "sharded":
            raise ValueError(
                'EngineOptions(engine="sharded") requires method="cvlr" — '
                "the distributed pipeline scores low-rank factors only"
            )
        if feature_bank is not None:
            raise ValueError(
                'feature_bank= requires method="cvlr" — the exact scorer '
                "builds no low-rank factors"
            )
        return CVScorer(data, spec=spec, config=config)
    raise ValueError(f"unknown scoring method {method!r}")


class DiscoverySession:
    """One causal-discovery run: scorer construction + the GES loop, with
    the session owning the sweep lifecycle.

    `repro.core.ges.ges` calls `begin_sweep(phase)` /
    `score_frontier(configs)` / `end_sweep(step)` around every frontier
    evaluation; the session routes the scoring by its `EngineOptions`
    (`"batched"` → the scorer's prefetch engine, `"sharded"` → the
    distributed stacked pipeline, `"sequential"` → lazy per-candidate
    scores) and records one entry per sweep in `sweep_log`:
    ``{phase, sweep, n_configs, n_scored, step, gram_cache,
    feature_bank}`` with the Gram-cache and feature-bank counter deltas
    for that sweep.  This is the seam the planned
    incremental-frontier-delta optimization plugs into — a session sees
    consecutive frontiers and can diff them.

    The session owns a `repro.features.bank.FeatureBank` (exposed as
    `feature_bank`): built factors persist across the run's sweeps, and
    passing the same bank to a later session over the same data skips
    rebuilding entirely — the sweep log's ``feature_bank`` deltas show
    the hits.

    **Survivability**: the session keeps a `repro.core.runstate.RunState`
    (`run_state`) — CPDAG, GES phase, applied-step log, the sweep log
    itself, FeatureBank metadata, degradation counters — updated on the
    `end_sweep` seam.  With `EngineOptions(checkpoint_dir=...)` the state
    is committed through the atomic `repro.checkpoint.store.
    AsyncCheckpointer` every `checkpoint_every` completed sweeps, and
    `resume="auto"` restores the newest loadable checkpoint (falling
    back past corrupted steps), re-verifies every recorded factor
    fingerprint against this session's build policy, and replays the
    remaining sweeps — reproducing the uninterrupted run's CPDAG and
    applied-step sequence exactly (GES is deterministic given the
    restored state).  `fault_plan` (a `repro.core.runstate.FaultPlan`)
    injects deterministic failures — session kill, shard death,
    checkpoint corruption, NaN scores — for tests and recovery
    benchmarks.

    Typical use is through `causal_discover`; instantiate directly when
    you want the scorer, the per-sweep log, or custom search parameters:

        session = DiscoverySession(data, options=EngineOptions())
        result = session.run()
        session.sweep_log  # per-sweep engine/cache telemetry
        session.feature_bank.stats  # factor-build/hit/miss counters
    """

    def __init__(
        self,
        data,
        spec: DataSpec | None = None,
        options: EngineOptions | None = None,
        *,
        method: str = "cvlr",
        config: ScoreConfig | None = None,
        max_subset: int | None = None,
        verbose: bool = False,
        feature_bank=None,
        fault_plan: FaultPlan | None = None,
        resume: str = "never",
    ):
        self.options = options if options is not None else EngineOptions()
        self.scorer = make_scorer(
            data, method=method, spec=spec, options=self.options,
            config=config, feature_bank=feature_bank,
        )
        self.spec = self.scorer.view.spec
        self.feature_bank = getattr(self.scorer, "feature_bank", None)
        self.max_subset = max_subset
        self.verbose = verbose
        self.sweep_log: list = []
        self.result: GESResult | None = None
        self._active: dict | None = None
        if self.options.engine == "sharded":
            # resolved once, loudly, instead of failing mid-search
            from repro.core.distributed_score import sharded_batch_hook

            self._sharded_hook = sharded_batch_hook
        else:
            self._sharded_hook = None
        if fault_plan is not None and not isinstance(fault_plan, FaultPlan):
            raise ValueError(
                f"fault_plan must be a repro.core.runstate.FaultPlan or "
                f"None, got {type(fault_plan).__name__}"
            )
        self.fault_plan = fault_plan
        if fault_plan is not None:
            self.scorer.fault_plan = fault_plan
        if resume not in RESUME_MODES:
            raise ValueError(
                f"resume must be one of {RESUME_MODES}, got {resume!r}"
            )
        ckpt_dir = self.options.checkpoint_dir
        if resume == "auto" and ckpt_dir is None:
            raise ValueError(
                'resume="auto" needs EngineOptions(checkpoint_dir=...) to '
                "know where the checkpoints live"
            )
        self._checkpointer = (
            AsyncCheckpointer(ckpt_dir) if ckpt_dir is not None else None
        )
        self._last_ckpt: int | None = None
        d = self.spec.num_vars
        restored = (
            load_latest_runstate(ckpt_dir) if resume == "auto" else None
        )
        if restored is not None:
            step, state = restored
            if state.cpdag.shape != (d, d):
                raise ValueError(
                    f"resume: checkpoint step {step} carries a "
                    f"{state.cpdag.shape} CPDAG but this session's data has "
                    f"{d} variables"
                )
            self._verify_bank_meta(state)
            self.run_state = state
            self.sweep_log = state.sweep_log  # aliased: appends persist
            self._last_ckpt = step
            self.resumed_from: int | None = step
        else:
            self.run_state = RunState.fresh(d)
            self.run_state.sweep_log = self.sweep_log  # aliased
            self.resumed_from = None

    def _verify_bank_meta(self, state: RunState) -> None:
        """Re-admit checkpointed FeatureBank entries by *fingerprint*, not
        by trusting stale device state: every recorded (variable set,
        build fingerprint) must match what THIS session's policy/config
        would build, else resuming would silently mix factor families."""
        fp_fn = getattr(self.scorer, "_feature_fingerprint", None)
        policy = getattr(self.scorer, "policy", None)
        if fp_fn is None or policy is None:
            return
        for vars_list, fp_repr in state.bank_meta:
            vk = tuple(int(v) for v in vars_list)
            choice = policy.resolve(vk, self.scorer.view.spec)
            if repr(fp_fn(vk, choice)) != fp_repr:
                raise ValueError(
                    f"resume: the checkpointed factor fingerprint for "
                    f"variable set {vk} does not match this session's build "
                    "policy/config — the checkpoint was written by a "
                    "different configuration; refusing to mix factor "
                    "families"
                )

    # -- sweep lifecycle (driven by repro.core.ges.ges) -------------------
    def begin_sweep(self, phase: str) -> None:
        sweep_idx = len(self.sweep_log)
        if self.fault_plan is not None:
            if self.fault_plan.should_kill(sweep_idx):
                raise InjectedFault(f"injected kill at sweep {sweep_idx}")
            self.scorer.fault_sweep = sweep_idx
        stats = getattr(self.scorer, "gram_cache", None)
        deg = getattr(self.scorer, "degradations", None)
        self._active = {
            "phase": phase,
            "sweep": sweep_idx,
            "n_configs": 0,
            "n_scored": 0,
            "step": None,
            "_stats0": dict(stats.stats) if stats is not None else None,
            "_bank0": dict(self.feature_bank.stats)
            if self.feature_bank is not None
            else None,
            "_deg0": dict(deg) if deg is not None else None,
        }

    def score_frontier(self, configs) -> int:
        """Evaluate one sweep's (node, parents) frontier through the
        engine the options selected; returns the number of scores
        actually computed (cached configurations cost nothing)."""
        if self._active is None:
            self.begin_sweep("adhoc")
        self._active["n_configs"] = len(configs)
        if self._sharded_hook is not None:
            tel: dict = {}
            n = self._sharded_hook(
                self.scorer,
                configs,
                options=self.options,
                fault_plan=self.fault_plan,
                sweep=self._active["sweep"],
                telemetry=tel,
            )
            if any(
                tel.get(k)
                for k in ("retries", "resharded", "dead_workers", "fallback_keys")
            ):
                self._active["shards"] = tel
        elif self.options.batched:
            prefetch = getattr(self.scorer, "prefetch", None)
            n = prefetch(configs) if prefetch is not None else 0
        else:
            n = 0  # sequential: ges falls back to lazy local_score
        self._active["n_scored"] = int(n)
        return int(n)

    def end_sweep(self, step=None, cpdag=None) -> None:
        rec, self._active = self._active, None
        if rec is None:
            return
        rec["step"] = _norm_step(step)
        stats0 = rec.pop("_stats0")
        cache = getattr(self.scorer, "gram_cache", None)
        if cache is not None and stats0 is not None:
            counters = (
                "hits", "misses", "evictions",
                "promotions", "spills", "bank_fallbacks",
            )
            rec["gram_cache"] = {
                k: cache.stats[k] - stats0[k] for k in counters
            }
        bank0 = rec.pop("_bank0")
        if self.feature_bank is not None and bank0 is not None:
            rec["feature_bank"] = {
                k: round(self.feature_bank.stats[k] - bank0[k], 4)
                for k in ("hits", "misses", "builds", "build_s")
            }
        deg0 = rec.pop("_deg0", None)
        deg = getattr(self.scorer, "degradations", None)
        if deg is not None and deg0 is not None:
            delta = {k: deg[k] - deg0.get(k, 0) for k in deg}
            if any(delta.values()):
                rec["degradations"] = delta
        self.sweep_log.append(rec)
        self._advance_run_state(rec, cpdag)

    def _advance_run_state(self, rec: dict, cpdag) -> None:
        """Fold one completed sweep into `run_state` and checkpoint on
        schedule.  A null step closes the phase (forward -> backward ->
        done), mirroring the GES control flow the resume replays."""
        rs = self.run_state
        step = rec["step"]
        if cpdag is not None:
            rs.cpdag = np.asarray(cpdag, dtype=np.int8).copy()
        rs.sweep = len(self.sweep_log)
        if step is not None:
            rs.trace.append(step)
            if rec["phase"] == "forward":
                rs.forward_steps += 1
            elif rec["phase"] == "backward":
                rs.backward_steps += 1
        elif rec["phase"] == "forward":
            rs.phase = "backward"
        elif rec["phase"] == "backward":
            rs.phase = "done"
        deg = getattr(self.scorer, "degradations", None)
        if deg is not None:
            rs.degradations = dict(deg)
        if self.feature_bank is not None:
            rs.bank_meta = [
                [list(vk), repr(fp)]
                for vk, fp in self.feature_bank.metadata()
            ]
        if (
            self._checkpointer is not None
            and rs.sweep % self.options.checkpoint_every == 0
        ):
            self._checkpoint(rs.sweep)

    def _checkpoint(self, step: int) -> None:
        self._checkpointer.save(step, self.run_state.to_tree())
        self._last_ckpt = step
        if (
            self.fault_plan is not None
            and self.fault_plan.corrupt_checkpoint == step
        ):
            # injection: let the write commit, then trash it on disk
            self._checkpointer.wait()
            self.fault_plan.maybe_corrupt_checkpoint(
                self.options.checkpoint_dir, step
            )

    # -- the run ----------------------------------------------------------
    def run(self) -> GESResult:
        """GES end to end; returns (and retains as `self.result`) the
        `GESResult` whose `cpdag` is the estimated equivalence class.
        Resumes from the restored `run_state` when the session was built
        with `resume="auto"` (a fresh state replays from scratch, which
        is the ordinary run)."""
        try:
            self.result = ges(
                self.scorer,
                max_subset=self.max_subset,
                verbose=self.verbose,
                session=self,
                state=self.run_state,
            )
        finally:
            if self._checkpointer is not None:
                # drain the in-flight write even on a crash, so the last
                # committed checkpoint is never half-written at restart
                self._checkpointer.wait()
        rs = self.run_state
        rs.phase = "done"
        rs.cpdag = np.asarray(self.result.cpdag, dtype=np.int8).copy()
        if self._checkpointer is not None and self._last_ckpt != rs.sweep:
            self._checkpoint(rs.sweep)
            self._checkpointer.wait()
        return self.result


def causal_discover(
    data,
    method: str = "cvlr",
    spec: DataSpec | None = None,
    options: EngineOptions | None = None,
    config: ScoreConfig | None = None,
    max_subset: int | None = None,
    verbose: bool = False,
    resume: str = "never",
    fault_plan: FaultPlan | None = None,
    # -- deprecated (one release): the pre-PR-4 loose kwargs -------------
    dims=_UNSET,
    discrete=_UNSET,
    batched=_UNSET,
    gram_cache_entries=_UNSET,
    device_bank_mb=_UNSET,
    batch_hook=_UNSET,
) -> GESResult:
    """GES + (CV-LR | CV) generalized score on an (n, cols) data matrix.

    spec: `DataSpec` describing the variables — `DataSpec.from_arrays`
    absorbs explicit dims/discreteness, `DataSpec.infer` guesses kinds
    from dtype/cardinality (routing the paper's Alg.-2 sampling for
    discrete variables).  options: `EngineOptions` — engine
    (`"batched"`/`"sequential"`/`"sharded"`), cache bounds, `precision`.
    Selecting `"sharded"` routes every GES frontier through
    `repro.core.distributed_score` internally; no `batch_hook` callable
    needed.  Returns a GESResult whose `cpdag` is the estimated
    equivalence class; the underlying `DiscoverySession` (scorer handle,
    per-sweep log) is one `DiscoverySession(...).run()` away when you
    need it.

    resume: ``"never"`` (default) or ``"auto"`` — with
    `EngineOptions(checkpoint_dir=...)`, ``"auto"`` restores the newest
    loadable checkpoint and replays the remaining sweeps, reproducing the
    uninterrupted run's CPDAG exactly.  fault_plan: a
    `repro.core.runstate.FaultPlan` injecting deterministic failures
    (tests/benchmarks).

    The legacy kwargs are deprecated shims: `dims`/`discrete` fold into
    `spec`, `batched`/`gram_cache_entries`/`device_bank_mb` into
    `options`, and `batch_hook=` is replaced by
    `EngineOptions(engine="sharded")` for the supported paths.
    """
    spec = _resolve_legacy_spec(data, spec, dims, discrete)
    options = _resolve_legacy_options(
        options, batched, gram_cache_entries, device_bank_mb
    )
    # an explicit batch_hook=None was the old default ("no hook") — treat
    # it as not passed rather than warning about a no-op value
    if batch_hook is not _UNSET and batch_hook is not None:
        if resume != "never" or fault_plan is not None:
            raise ValueError(
                "resume=/fault_plan= require the session engine — drop the "
                'deprecated batch_hook= (use EngineOptions(engine="sharded"))'
            )
        _deprecated(
            "causal_discover(batch_hook=...)",
            'select options=EngineOptions(engine="sharded") instead',
        )
        scorer = make_scorer(
            data, method=method, spec=spec, options=options, config=config
        )
        return ges(
            scorer, max_subset=max_subset, batch_hook=batch_hook, verbose=verbose
        )
    return DiscoverySession(
        data,
        spec=spec,
        options=options,
        method=method,
        config=config,
        max_subset=max_subset,
        verbose=verbose,
        resume=resume,
        fault_plan=fault_plan,
    ).run()
