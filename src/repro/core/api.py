"""Public API: declarative causal discovery.

The surface is three objects plus two functions:

* `repro.core.spec.DataSpec` — *what the data is*: one
  `VariableSpec(name, dim, kind)` per variable, built explicitly
  (`DataSpec.from_arrays`) or by heuristics (`DataSpec.infer`).
* `repro.core.spec.EngineOptions` — *how to run*: engine selection
  (`"batched"` | `"sequential"` | `"sharded"`), Gram-block cache bounds,
  and the Gram-accumulation `precision` policy.
* `DiscoverySession` — scorer construction + the GES loop, owning the
  sweep lifecycle (`begin_sweep` / `score_frontier` / `end_sweep`) and a
  per-sweep log; `causal_discover` is the one-call wrapper over it.
* `make_scorer` — construct just the local scorer (`CVLRScorer`, the
  paper's O(n) method, or `CVScorer`, the exact O(n^3) baseline).
* `causal_discover` — session + GES in one call; returns the CPDAG.

The pre-PR-4 loose kwargs (`dims=`, `discrete=`, `batched=`,
`gram_cache_entries=`, `device_bank_mb=`, `batch_hook=`) finished their
one-release deprecation window and are gone — passing them now raises
`TypeError`.  See README.md §Migration for the old → new mapping and
docs/ARCHITECTURE.md for the engine behind the options.
"""

from __future__ import annotations

import contextlib
import hashlib
import time

import numpy as np

from repro.checkpoint.store import AsyncCheckpointer
from repro.obs import Recorder, json_safe, trace
from repro.core.ges import ges, GESResult
from repro.core.runstate import (
    DeadlineExceeded,
    FaultPlan,
    InjectedFault,
    RunState,
    SessionCancelled,
    _norm_step,
    load_latest_runstate,
)
from repro.core.score_common import ScoreConfig, config_key
from repro.core.score_exact import CVScorer
from repro.core.score_lowrank import CVLRScorer
from repro.core.spec import DataSpec, EngineOptions, VariableSpec, resolve_spec

__all__ = [
    "DataSpec",
    "VariableSpec",
    "EngineOptions",
    "DiscoverySession",
    "FaultPlan",
    "RunState",
    "make_scorer",
    "causal_discover",
]

RESUME_MODES = ("never", "auto")


def _resolve_options(options) -> EngineOptions:
    if options is None:
        return EngineOptions()
    if not isinstance(options, EngineOptions):
        raise ValueError(
            f"options must be an EngineOptions, got {type(options).__name__}"
        )
    return options


def make_scorer(
    data,
    method: str = "cvlr",
    spec: DataSpec | None = None,
    options: EngineOptions | None = None,
    config: ScoreConfig | None = None,
    feature_bank=None,
    gram_cache=None,
):
    """Build a local scorer over an (n, cols) data matrix.

    method: 'cvlr' (the paper's low-rank CV score) or 'cv' (exact O(n^3)
    baseline).  spec: a `repro.core.spec.DataSpec` describing the
    variables (default: every column a continuous 1-D variable; use
    `DataSpec.infer(data)` for dtype/cardinality heuristics).  options: a
    `repro.core.spec.EngineOptions` — engine selection, Gram-block cache
    bounds (`gram_cache_entries`, `device_bank_mb`), the `precision`
    policy, and the `features` factorization policy
    (`repro.features.policy.FeaturePolicy`); every field is documented
    there.  feature_bank: a `repro.features.bank.FeatureBank` to reuse
    built factors across scorers/sessions over the same data (CV-LR
    only — passing one with method='cv' raises).  gram_cache: a
    `repro.core.score_common.GramBlockCache` to share frontier Gram
    blocks across sessions with identical build fingerprints (CV-LR
    only; the serving layer's job — see `repro.serving`).  The exact
    scorer ignores the engine options except that `engine="sharded"` is
    rejected (the distributed pipeline is CV-LR only).  config: score
    hyperparameters (`ScoreConfig`; paper defaults).
    """
    spec = resolve_spec(data, spec=spec)
    options = _resolve_options(options)
    if method == "cvlr":
        return CVLRScorer(
            data, spec=spec, config=config, options=options,
            feature_bank=feature_bank, gram_cache=gram_cache,
        )
    if method == "cv":
        if options.engine == "sharded":
            raise ValueError(
                'EngineOptions(engine="sharded") requires method="cvlr" — '
                "the distributed pipeline scores low-rank factors only"
            )
        if feature_bank is not None:
            raise ValueError(
                'feature_bank= requires method="cvlr" — the exact scorer '
                "builds no low-rank factors"
            )
        if gram_cache is not None:
            raise ValueError(
                'gram_cache= requires method="cvlr" — the exact scorer '
                "caches kernel matrices internally"
            )
        return CVScorer(data, spec=spec, config=config)
    raise ValueError(f"unknown scoring method {method!r}")


class DiscoverySession:
    """One causal-discovery run: scorer construction + the GES loop, with
    the session owning the sweep lifecycle.

    `repro.core.ges.ges` calls `begin_sweep(phase)` /
    `score_frontier(configs)` / `end_sweep(step)` around every frontier
    evaluation; the session routes the scoring by its `EngineOptions`
    (`"batched"` → the scorer's prefetch engine, `"sharded"` → the
    distributed stacked pipeline, `"sequential"` → lazy per-candidate
    scores) and records one entry per sweep in `sweep_log`:
    ``{phase, sweep, n_configs, n_scored, step, elapsed_s, frontier,
    enum, score_cache, gram_cache, feature_bank}`` with the Gram-cache
    and feature-bank counter deltas for that sweep.

    **Incremental frontier deltas** (`EngineOptions(incremental=True)`,
    the default; docs/ARCHITECTURE.md, "Incremental frontier-delta
    engine"): the session is the seam that sees consecutive frontiers,
    so it keeps the previous sweep's config-key set and hands the
    scoring engine only the *delta* — configs the last applied step
    could actually have changed — while `repro.core.ges` carries
    candidate lists for provably-untouched pairs across sweeps (the
    incidence rule).  Each sweep record's ``frontier`` entry counts
    ``{carried, delta, invalidated}`` config keys, ``enum`` counts
    ``{pairs_full, pairs_carried, touched}`` from the enumeration
    cache, and ``score_cache`` snapshots the scorer's local-score memo
    ``{entries, evictions}``.  `EngineOptions(incremental=False)` keeps
    full re-enumeration + full-frontier routing as the differential
    oracle (tests/test_frontier_delta.py proves both produce bitwise
    identical CPDAGs, traces, and scores).  Correctness never rests on
    the diff: every engine re-checks its own cache, and lazy
    `local_score` backstops any config a diff could miss.

    The session owns a `repro.features.bank.FeatureBank` (exposed as
    `feature_bank`): built factors persist across the run's sweeps, and
    passing the same bank to a later session over the same data skips
    rebuilding entirely — the sweep log's ``feature_bank`` deltas show
    the hits.

    **Survivability**: the session keeps a `repro.core.runstate.RunState`
    (`run_state`) — CPDAG, GES phase, applied-step log, the sweep log
    itself, FeatureBank metadata, degradation counters — updated on the
    `end_sweep` seam.  With `EngineOptions(checkpoint_dir=...)` the state
    is committed through the atomic `repro.checkpoint.store.
    AsyncCheckpointer` every `checkpoint_every` completed sweeps, and
    `resume="auto"` restores the newest loadable checkpoint (falling
    back past corrupted steps), re-verifies every recorded factor
    fingerprint against this session's build policy, and replays the
    remaining sweeps — reproducing the uninterrupted run's CPDAG and
    applied-step sequence exactly (GES is deterministic given the
    restored state).  `fault_plan` (a `repro.core.runstate.FaultPlan`)
    injects deterministic failures — session kill, shard death,
    checkpoint corruption, NaN scores — for tests and recovery
    benchmarks.

    **Serving** (`repro.serving.SessionManager` threads these in; they
    are inert by default): `tenant` labels the session in structured
    errors; `EngineOptions(deadline_s=...)` (or an absolute monotonic
    `deadline_at`) bounds the run's wall clock, checked at every sweep
    seam and raised as `repro.core.runstate.DeadlineExceeded`;
    `cancel_event` (a `threading.Event`) cancels the run at the next
    seam (`repro.core.runstate.SessionCancelled`); `gram_cache` injects
    a shared Gram-block cache; `serving_info` is a live dict of the
    admission controller's degradation counters, recorded into every
    sweep-log entry under ``"serving"``.

    Typical use is through `causal_discover`; instantiate directly when
    you want the scorer, the per-sweep log, or custom search parameters:

        session = DiscoverySession(data, options=EngineOptions())
        result = session.run()
        session.sweep_log  # per-sweep engine/cache telemetry
        session.feature_bank.stats  # factor-build/hit/miss counters
    """

    def __init__(
        self,
        data,
        spec: DataSpec | None = None,
        options: EngineOptions | None = None,
        *,
        method: str = "cvlr",
        config: ScoreConfig | None = None,
        max_subset: int | None = None,
        verbose: bool = False,
        feature_bank=None,
        gram_cache=None,
        fault_plan: FaultPlan | None = None,
        resume: str = "never",
        tenant: str | None = None,
        cancel_event=None,
        deadline_at: float | None = None,
        serving_info: dict | None = None,
        metrics_registry=None,
    ):
        self.options = _resolve_options(options)
        self.tenant = tenant
        self._cancel_event = cancel_event
        self._deadline_at = deadline_at  # absolute time.monotonic() stamp
        self._deadline_s = self.options.deadline_s
        self._t_start: float | None = None
        self.serving_info = serving_info
        self.scorer = make_scorer(
            data, method=method, spec=spec, options=self.options,
            config=config, feature_bank=feature_bank, gram_cache=gram_cache,
        )
        self.spec = self.scorer.view.spec
        self.feature_bank = getattr(self.scorer, "feature_bank", None)
        # Incremental frontier-delta engine state: the previous sweep's
        # config-key set (None until a sweep completes), read by
        # `score_frontier` to route only the delta, and by ges() via the
        # `incremental` attribute to enable its candidate-carrying cache.
        self.incremental = self.options.incremental
        self._prev_frontier: set | None = None
        if self.options.score_memo_entries is not None:
            self.scorer.score_memo_max = self.options.score_memo_entries
        self._score_fp = self._score_fingerprint(method)
        # Constraint phase (EngineOptions.restrict="skeleton"): the
        # EdgeMask gating this run's forward frontiers, estimated (or
        # restored) lazily at run() start — `repro.core.ges` reads
        # `edge_mask` duck-typed off the session.
        if self.options.restrict == "skeleton" and method != "cvlr":
            raise ValueError(
                'EngineOptions(restrict="skeleton") requires method="cvlr" '
                "— the constraint phase computes its CI tests from the "
                "low-rank factor bank"
            )
        self.edge_mask = None
        self._constraint: dict | None = None
        self._skeleton_fp = hashlib.sha1(
            f"{self._score_fp}|{self.options.ci_alpha}"
            f"|{self.options.ci_max_cond}".encode()
        ).hexdigest()
        # Observability (EngineOptions.obs; repro.obs): the session owns
        # the recorder's lifecycle — spans open at the sweep seams, the
        # scorer/kernels pick the recorder up from the ambient trace
        # context, and `run()` flushes the trace files on exit.  With a
        # shared `metrics_registry` (the SessionManager's), the stats
        # sources register under a per-tenant prefix so tenants never
        # collide in one process-wide snapshot.
        self.recorder = None
        if self.options.obs != "off":
            labels = {"session": self._score_fp[:8]}
            if tenant is not None:
                labels["tenant"] = tenant
            self.recorder = Recorder(
                mode=self.options.obs,
                labels=labels,
                registry=metrics_registry,
                trace_dir=self.options.trace_dir,
                name=tenant if tenant is not None else f"session-{self._score_fp[:8]}",
            )
            self._register_metric_sources()
        self.max_subset = max_subset
        self.verbose = verbose
        self.sweep_log: list = []
        self.result: GESResult | None = None
        self._active: dict | None = None
        if self.options.engine == "sharded":
            # resolved once, loudly, instead of failing mid-search
            from repro.core.distributed_score import sharded_batch_hook

            self._sharded_hook = sharded_batch_hook
        else:
            self._sharded_hook = None
        if fault_plan is not None and not isinstance(fault_plan, FaultPlan):
            raise ValueError(
                f"fault_plan must be a repro.core.runstate.FaultPlan or "
                f"None, got {type(fault_plan).__name__}"
            )
        self.fault_plan = fault_plan
        if fault_plan is not None:
            self.scorer.fault_plan = fault_plan
        if resume not in RESUME_MODES:
            raise ValueError(
                f"resume must be one of {RESUME_MODES}, got {resume!r}"
            )
        ckpt_dir = self.options.checkpoint_dir
        if resume == "auto" and ckpt_dir is None:
            raise ValueError(
                'resume="auto" needs EngineOptions(checkpoint_dir=...) to '
                "know where the checkpoints live"
            )
        self._checkpointer = (
            AsyncCheckpointer(ckpt_dir) if ckpt_dir is not None else None
        )
        self._last_ckpt: int | None = None
        d = self.spec.num_vars
        restored = (
            load_latest_runstate(ckpt_dir) if resume == "auto" else None
        )
        if restored is not None:
            step, state = restored
            if state.cpdag.shape != (d, d):
                raise ValueError(
                    f"resume: checkpoint step {step} carries a "
                    f"{state.cpdag.shape} CPDAG but this session's data has "
                    f"{d} variables"
                )
            self._verify_bank_meta(state)
            self._restore_warm_state(state)
            self.run_state = state
            self.sweep_log = state.sweep_log  # aliased: appends persist
            self._last_ckpt = step
            self.resumed_from: int | None = step
        else:
            self.run_state = RunState.fresh(d)
            self.run_state.sweep_log = self.sweep_log  # aliased
            self.resumed_from = None

    def _obs_source_prefix(self) -> str:
        return f"tenant.{self.tenant}." if self.tenant is not None else ""

    def _register_metric_sources(self) -> None:
        """Re-register the session's scattered stats dicts as lazy
        registry sources — the dicts themselves (and every sweep_log /
        telemetry key computed from them) stay untouched."""
        reg = self.recorder.registry
        pre = self._obs_source_prefix()
        cache = getattr(self.scorer, "gram_cache", None)
        if cache is not None:
            reg.register_source(pre + "gram_cache", lambda c=cache: c.stats)
        if self.feature_bank is not None:
            reg.register_source(
                pre + "feature_bank", lambda b=self.feature_bank: b.stats
            )
        deg = getattr(self.scorer, "degradations", None)
        if deg is not None:
            reg.register_source(pre + "degradations", lambda d=deg: d)
        reg.register_source(
            pre + "constraint", lambda s=self: s._constraint or {}
        )

    def close_obs(self) -> None:
        """Flush the recorder (JSONL + Chrome/Perfetto timeline when
        `trace_dir` is set) and detach this session's metric sources
        from a shared registry.  Idempotent; no-op when obs='off'."""
        if self.recorder is None:
            return
        pre = self._obs_source_prefix()
        for name in ("gram_cache", "feature_bank", "degradations", "constraint"):
            self.recorder.registry.unregister_source(pre + name)
        self.recorder.close()

    def _score_fingerprint(self, method: str) -> str:
        """Identity of everything a memo'd local score depends on: the raw
        data bytes, the score hyperparameters, the feature routing policy
        (seed included), and the scoring method.  Guards the checkpointed
        score memo / frontier on resume — scores are pure functions of
        this fingerprint plus the (node, parents) key, so a match makes
        carrying them exact and a mismatch drops them (cold but correct).
        """
        h = hashlib.sha1()
        view = self.scorer.view
        h.update(np.ascontiguousarray(view.data).tobytes())
        h.update(repr(self.spec).encode())
        h.update(repr(self.scorer.config).encode())
        h.update(type(self.scorer).__name__.encode())
        h.update(method.encode())
        policy = getattr(self.scorer, "policy", None)
        if policy is not None:
            h.update(repr(policy.fingerprint()).encode())
        return h.hexdigest()

    def _restore_warm_state(self, state: RunState) -> None:
        """Warm-start the scorer's score memo and the delta engine's
        previous-frontier set from a checkpoint — only under an exact
        score-fingerprint match (`_score_fingerprint`); anything else
        silently resumes cold, which is always correct, just slower."""
        if state.score_fp is None or state.score_fp != self._score_fp:
            return
        memo_put = getattr(self.scorer, "_memo_put", None)
        if memo_put is not None:
            for node, parents, val in state.score_memo:
                memo_put(config_key(int(node), parents), float(val))
        if self.incremental and state.frontier is not None:
            self._prev_frontier = {
                config_key(int(n), ps) for n, ps in state.frontier
            }

    def _verify_bank_meta(self, state: RunState) -> None:
        """Re-admit checkpointed FeatureBank entries by *fingerprint*, not
        by trusting stale device state: every recorded (variable set,
        build fingerprint) must match what THIS session's policy/config
        would build, else resuming would silently mix factor families."""
        fp_fn = getattr(self.scorer, "_feature_fingerprint", None)
        policy = getattr(self.scorer, "policy", None)
        if fp_fn is None or policy is None:
            return
        for vars_list, fp_repr in state.bank_meta:
            vk = tuple(int(v) for v in vars_list)
            choice = policy.resolve(vk, self.scorer.view.spec)
            if repr(fp_fn(vk, choice)) != fp_repr:
                raise ValueError(
                    f"resume: the checkpointed factor fingerprint for "
                    f"variable set {vk} does not match this session's build "
                    "policy/config — the checkpoint was written by a "
                    "different configuration; refusing to mix factor "
                    "families"
                )

    # -- serving seam: deadline + cancellation -----------------------------
    def _check_interrupt(self, sweep_idx: int) -> None:
        """Deadline/cancellation gate, hit at every sweep seam.  Cheap
        (two comparisons) when neither is configured."""
        if self._cancel_event is not None and self._cancel_event.is_set():
            raise SessionCancelled(self.tenant, sweep_idx)
        now = time.monotonic()
        if self._t_start is None:
            self._t_start = now
        deadline_at = self._deadline_at
        if deadline_at is None and self._deadline_s is not None:
            deadline_at = self._t_start + self._deadline_s
        if deadline_at is not None and now > deadline_at:
            elapsed = now - self._t_start
            budget = (
                self._deadline_s
                if self._deadline_s is not None
                else elapsed - (now - deadline_at)
            )
            raise DeadlineExceeded(self.tenant, sweep_idx, elapsed, budget)

    # -- sweep lifecycle (driven by repro.core.ges.ges) -------------------
    def begin_sweep(self, phase: str, enum_stats: dict | None = None) -> None:
        sweep_idx = len(self.sweep_log)
        self._check_interrupt(sweep_idx)
        if self.fault_plan is not None:
            stall = self.fault_plan.stall_seconds(sweep_idx)
            if stall > 0:
                time.sleep(stall)  # injected slow tenant
            if self.fault_plan.should_kill(sweep_idx):
                raise InjectedFault(f"injected kill at sweep {sweep_idx}")
            if self.fault_plan.evict_storm:
                cache = getattr(self.scorer, "gram_cache", None)
                if cache is not None:
                    cache.spill_device()  # injected eviction storm
            self.scorer.fault_sweep = sweep_idx
        stats = getattr(self.scorer, "gram_cache", None)
        deg = getattr(self.scorer, "degradations", None)
        self._active = {
            "phase": phase,
            "sweep": sweep_idx,
            "n_configs": 0,
            "n_scored": 0,
            "step": None,
            "_enum": dict(enum_stats) if enum_stats else None,
            "_t0": time.perf_counter(),
            "_stats0": dict(stats.stats) if stats is not None else None,
            "_bank0": dict(self.feature_bank.stats)
            if self.feature_bank is not None
            else None,
            "_deg0": dict(deg) if deg is not None else None,
        }
        if self.recorder is not None:
            self.recorder.set_label("sweep", sweep_idx)
            self._active["_span"] = self.recorder.begin(
                "sweep", cat="sweep", attrs={"phase": phase}
            )

    def score_frontier(self, configs) -> int:
        """Evaluate one sweep's (node, parents) frontier through the
        engine the options selected; returns the number of scores
        actually computed (cached configurations cost nothing)."""
        if self._active is None:
            self.begin_sweep("adhoc")
        self._check_interrupt(self._active["sweep"])
        configs = list(configs)
        self._active["n_configs"] = len(configs)
        # Incremental frontier delta: score only configs that were not in
        # the previous sweep's frontier.  Carried configs were all scored
        # last sweep (every engine commits the full frontier to the
        # scorer's memo, and the lazy path scores every candidate), so
        # skipping them here is exact; if one was LRU-evicted from a
        # bounded memo, ges's lazy `local_score` fallback recomputes it.
        prev = self._prev_frontier if self.incremental else None
        memo = getattr(self.scorer, "_score_cache", {})
        if prev is not None:
            # a carried config evicted from a bounded memo is re-scored
            # through the engine, not left to the lazy fallback
            to_score = [c for c in configs if c not in prev or c not in memo]
            cur = set(configs)
            self._active["frontier"] = {
                "carried": len(configs) - len(to_score),
                "delta": len(to_score),
                "invalidated": len(prev - cur),
            }
        else:
            to_score = configs
            cur = set(configs)
            if self.incremental:
                self._active["frontier"] = {
                    "carried": 0,
                    "delta": len(configs),
                    "invalidated": 0,
                }
        if self.incremental:
            self._prev_frontier = cur
        # ambient recorder for the engine's stage/kernel spans — a no-op
        # context when obs is off, and redundant-but-harmless when run()
        # already activated it (seam-driven sessions have no run() frame)
        with trace.use(self.recorder):
            if self._sharded_hook is not None:
                tel: dict = {}
                n = (
                    self._sharded_hook(
                        self.scorer,
                        to_score,
                        options=self.options,
                        fault_plan=self.fault_plan,
                        sweep=self._active["sweep"],
                        telemetry=tel,
                    )
                    if to_score
                    else 0
                )
                if any(
                    tel.get(k)
                    for k in ("retries", "resharded", "dead_workers", "fallback_keys")
                ):
                    self._active["shards"] = tel
            elif self.options.batched:
                prefetch = getattr(self.scorer, "prefetch", None)
                # warm incremental sweeps (prev frontier known) mark their
                # delta small-batch-eligible: the uncached count is a
                # sweep-over-sweep delta, and the engine's small-batch path
                # sidesteps the device pipeline's bank-shaped recompiles
                n = (
                    prefetch(to_score, small_batch=prev is not None)
                    if prefetch is not None and to_score
                    else 0
                )
            else:
                n = 0  # sequential: ges falls back to lazy local_score
        self._active["n_scored"] = int(n)
        return int(n)

    def end_sweep(self, step=None, cpdag=None) -> None:
        rec, self._active = self._active, None
        if rec is None:
            return
        self._check_interrupt(rec["sweep"])
        sweep_span = rec.pop("_span", None)
        rec["step"] = _norm_step(step)
        rec["elapsed_s"] = round(time.perf_counter() - rec.pop("_t0"), 6)
        enum = rec.pop("_enum", None)
        if enum:
            rec["enum"] = enum
        memo = getattr(self.scorer, "_score_cache", None)
        if memo is not None:
            rec["score_cache"] = {
                "entries": len(memo),
                "evictions": int(
                    getattr(self.scorer, "score_memo_evictions", 0)
                ),
            }
        stats0 = rec.pop("_stats0")
        cache = getattr(self.scorer, "gram_cache", None)
        if cache is not None and stats0 is not None:
            counters = (
                "hits", "misses", "evictions",
                "promotions", "spills", "bank_fallbacks",
            )
            rec["gram_cache"] = {
                k: cache.stats[k] - stats0[k] for k in counters
            }
        bank0 = rec.pop("_bank0")
        if self.feature_bank is not None and bank0 is not None:
            rec["feature_bank"] = {
                k: round(self.feature_bank.stats[k] - bank0[k], 4)
                for k in ("hits", "misses", "builds", "build_s")
            }
        deg0 = rec.pop("_deg0", None)
        deg = getattr(self.scorer, "degradations", None)
        if deg is not None and deg0 is not None:
            delta = {k: deg[k] - deg0.get(k, 0) for k in deg}
            if any(delta.values()):
                rec["degradations"] = delta
        if self._constraint is not None:
            # constraint-phase telemetry (static per run: the skeleton is
            # estimated once, before the first sweep) — attached to every
            # sweep record so log consumers see the gating context inline
            rec["constraint"] = dict(self._constraint)
        if self.serving_info:
            # admission-controller degradation counters (live dict shared
            # with the SessionManager): snapshot per sweep
            rec["serving"] = dict(self.serving_info)
        # hygiene at the seam: every sweep record must be json.dumps-able
        # before it can reach RunState (checkpoint payloads serialize the
        # whole log) — numpy/jax scalars unwrap, device arrays fail loudly
        rec = json_safe(rec, path=f"sweep_log[{rec['sweep']}]")
        self.sweep_log.append(rec)
        try:
            self._advance_run_state(rec, cpdag)
        finally:
            if sweep_span is not None:
                self.recorder.end(sweep_span)
                self.recorder.pop_label("sweep")

    def _advance_run_state(self, rec: dict, cpdag) -> None:
        """Fold one completed sweep into `run_state` and checkpoint on
        schedule.  A null step closes the phase (forward -> backward ->
        done), mirroring the GES control flow the resume replays."""
        rs = self.run_state
        step = rec["step"]
        if cpdag is not None:
            rs.cpdag = np.asarray(cpdag, dtype=np.int8).copy()
        rs.sweep = len(self.sweep_log)
        if step is not None:
            rs.trace.append(step)
            if rec["phase"] == "forward":
                rs.forward_steps += 1
            elif rec["phase"] == "backward":
                rs.backward_steps += 1
        elif rec["phase"] == "forward":
            rs.phase = "backward"
        elif rec["phase"] == "backward":
            rs.phase = "done"
        deg = getattr(self.scorer, "degradations", None)
        if deg is not None:
            rs.degradations = dict(deg)
        if self.feature_bank is not None:
            rs.bank_meta = [
                [list(vk), repr(fp)]
                for vk, fp in self.feature_bank.metadata()
                if self._owns_bank_entry(vk, fp)
            ]
        if self._checkpointer is not None:
            # Warm-resume payload: the scorer's score memo (LRU order
            # preserved) + the delta engine's previous frontier, guarded
            # by the score fingerprint.  Only maintained when checkpoints
            # are on — nothing else reads it.
            memo = getattr(self.scorer, "_score_cache", None)
            if memo is not None:
                rs.score_memo = [
                    [int(k[0]), [int(p) for p in k[1]], float(v)]
                    for k, v in memo.items()
                ]
            rs.frontier = (
                [[int(k[0]), [int(p) for p in k[1]]]
                 for k in sorted(self._prev_frontier)]
                if self._prev_frontier is not None
                else None
            )
            rs.score_fp = self._score_fp
            if rs.sweep % self.options.checkpoint_every == 0:
                self._checkpoint(rs.sweep)

    def _owns_bank_entry(self, vars_key, fp) -> bool:
        """Fingerprint isolation on a *shared* bank: a checkpoint must
        record only THIS session's factor family.  Another tenant's
        entries (different seed/policy/config -> different fingerprint)
        would poison this tenant's resume — `_verify_bank_meta` rightly
        refuses foreign fingerprints."""
        fp_fn = getattr(self.scorer, "_feature_fingerprint", None)
        policy = getattr(self.scorer, "policy", None)
        if fp_fn is None or policy is None:
            return True
        try:
            choice = policy.resolve(tuple(vars_key), self.scorer.view.spec)
            return fp_fn(tuple(vars_key), choice) == fp
        except Exception:
            return False  # e.g. a foreign tenant's out-of-range vars_key

    # -- constraint phase (EngineOptions.restrict) ------------------------
    def _ensure_constraint(self) -> None:
        """``restrict="skeleton"``: estimate — or restore from the run
        state — the `repro.constraint.EdgeMask` gating this run's forward
        frontiers.  Runs once, before the first sweep.  The CI tests
        fetch factors through this session's FeatureBank and store their
        Gram blocks engine-keyed in the scorer's Gram cache, so the
        constraint phase incurs zero duplicate factor builds and
        pre-warms the score phase."""
        if self.options.restrict != "skeleton" or self.edge_mask is not None:
            return
        from repro.constraint import EdgeMask, KernelCITest, estimate_skeleton

        rs = self.run_state
        if rs.skeleton is not None and rs.skeleton_fp == self._skeleton_fp:
            mask = EdgeMask.from_list(rs.skeleton)
            self.edge_mask = mask
            self._constraint = {
                "ci_tests": 0,
                "cached": 0,
                "pruned_pairs": mask.pruned_pairs,
                "skeleton_s": 0.0,
                "restored": True,
            }
            return
        self._check_interrupt(len(self.sweep_log))
        with trace.use(self.recorder), trace.span("constraint", cat="stage"):
            ci = KernelCITest(self.scorer, alpha=self.options.ci_alpha)
            mask, info = estimate_skeleton(
                ci,
                self.spec.num_vars,
                alpha=self.options.ci_alpha,
                max_cond=self.options.ci_max_cond,
                verbose=self.verbose,
            )
        self.edge_mask = mask
        self._constraint = {
            "ci_tests": int(info["ci_tests"]),
            "cached": int(info["cached"]),
            "pruned_pairs": int(info["pruned_pairs"]),
            "skeleton_s": round(float(info["skeleton_s"]), 6),
        }
        rs.skeleton = mask.to_list()
        rs.skeleton_fp = self._skeleton_fp

    def _checkpoint(self, step: int) -> None:
        ckpt_span = (
            self.recorder.span("checkpoint", cat="stage", attrs={"step": step})
            if self.recorder is not None
            else contextlib.nullcontext()
        )
        with ckpt_span:
            self._checkpointer.save(step, self.run_state.to_tree())
        self._last_ckpt = step
        if (
            self.fault_plan is not None
            and self.fault_plan.corrupt_checkpoint == step
        ):
            # injection: let the write commit, then trash it on disk
            self._checkpointer.wait()
            self.fault_plan.maybe_corrupt_checkpoint(
                self.options.checkpoint_dir, step
            )

    # -- the run ----------------------------------------------------------
    def run(self) -> GESResult:
        """GES end to end; returns (and retains as `self.result`) the
        `GESResult` whose `cpdag` is the estimated equivalence class.
        Resumes from the restored `run_state` when the session was built
        with `resume="auto"` (a fresh state replays from scratch, which
        is the ordinary run).  With `EngineOptions(obs=)` enabled the
        whole run executes under a root "session" span and the trace
        files flush on exit (even on a crash)."""
        rec_obs = self.recorder
        if rec_obs is None:
            return self._run_inner()
        try:
            with rec_obs.activate(), rec_obs.span("session", cat="session"):
                return self._run_inner()
        finally:
            self.recorder.close()

    def _run_inner(self) -> GESResult:
        self._ensure_constraint()
        try:
            self.result = ges(
                self.scorer,
                max_subset=self.max_subset,
                verbose=self.verbose,
                session=self,
                state=self.run_state,
            )
        finally:
            if self._checkpointer is not None:
                # drain the in-flight write even on a crash, so the last
                # committed checkpoint is never half-written at restart
                self._checkpointer.wait()
        rs = self.run_state
        rs.phase = "done"
        rs.cpdag = np.asarray(self.result.cpdag, dtype=np.int8).copy()
        if self._checkpointer is not None and self._last_ckpt != rs.sweep:
            self._checkpoint(rs.sweep)
            self._checkpointer.wait()
        return self.result


def causal_discover(
    data,
    method: str = "cvlr",
    spec: DataSpec | None = None,
    options: EngineOptions | None = None,
    config: ScoreConfig | None = None,
    max_subset: int | None = None,
    verbose: bool = False,
    resume: str = "never",
    fault_plan: FaultPlan | None = None,
) -> GESResult:
    """GES + (CV-LR | CV) generalized score on an (n, cols) data matrix.

    spec: `DataSpec` describing the variables — `DataSpec.from_arrays`
    absorbs explicit dims/discreteness, `DataSpec.infer` guesses kinds
    from dtype/cardinality (routing the paper's Alg.-2 sampling for
    discrete variables).  options: `EngineOptions` — engine
    (`"batched"`/`"sequential"`/`"sharded"`), cache bounds, `precision`.
    Selecting `"sharded"` routes every GES frontier through
    `repro.core.distributed_score` internally; no `batch_hook` callable
    needed.  Returns a GESResult whose `cpdag` is the estimated
    equivalence class; the underlying `DiscoverySession` (scorer handle,
    per-sweep log) is one `DiscoverySession(...).run()` away when you
    need it.

    resume: ``"never"`` (default) or ``"auto"`` — with
    `EngineOptions(checkpoint_dir=...)`, ``"auto"`` restores the newest
    loadable checkpoint and replays the remaining sweeps, reproducing the
    uninterrupted run's CPDAG exactly.  fault_plan: a
    `repro.core.runstate.FaultPlan` injecting deterministic failures
    (tests/benchmarks).

    The pre-PR-4 loose kwargs (`dims`/`discrete`/`batched`/
    `gram_cache_entries`/`device_bank_mb`/`batch_hook`) are gone after
    their deprecation release: `dims`/`discrete` fold into `spec`, the
    engine knobs into `options`, and `batch_hook=` is
    `EngineOptions(engine="sharded")` (the low-level `repro.core.ges.ges`
    still accepts a raw hook for custom pipelines).
    """
    return DiscoverySession(
        data,
        spec=spec,
        options=options,
        method=method,
        config=config,
        max_subset=max_subset,
        verbose=verbose,
        resume=resume,
        fault_plan=fault_plan,
    ).run()
