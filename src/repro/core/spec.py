"""Declarative discovery API: typed descriptions of *what the data is*
(`DataSpec` / `VariableSpec`) and *how the engine should run*
(`EngineOptions`).

Until PR 4 the public surface grew one ad-hoc kwarg per engine change
(`batched=`, `gram_cache_entries=`, `device_bank_mb=`), the distributed
path needed a hand-threaded `batch_hook` callable, and per-variable
structure rode in as two parallel untyped lists (`dims=`, `discrete=`).
This module replaces all of that with two frozen, inspectable objects:

* `DataSpec` — one `VariableSpec(name, dim, kind)` per variable.  Built
  explicitly (`DataSpec.from_arrays`, absorbing the old lists) or by
  dtype/cardinality heuristics (`DataSpec.infer`), it routes the paper's
  per-data-type sampling (Alg. 1 ICL for continuous sets, Alg. 2 exact
  factorization for discrete sets) and validates the data matrix once, up
  front, with real error messages.

* `EngineOptions` — engine selection (`"batched"` | `"sequential"` |
  `"sharded"`), the Gram-block cache bounds, and the **precision
  policy**: `"bitwise"` keeps the engine bit-identical to the sequential
  f64 oracle on CPU; `"f32_gram"` lets the gather+einsum Gram fallback
  accumulate at float32 (what the TPU MXU kernels already do), attacking
  the cross-Gram einsum floor at the cost of ~1e-7-relative Gram accuracy.
  The oracle-comparison tolerance tests and benchmarks should use is keyed
  off the policy (`EngineOptions.oracle_rtol`).

The module is deliberately dependency-light (numpy only at import time) so
specs can be constructed, serialized and validated without touching JAX.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

# Single source of truth for the engine-knob defaults (CVLRScorer and the
# legacy api kwargs alias these).  GRAM_CACHE: sized to a sweep working
# set — see the CVLRScorer class comment.  DEVICE_BANK: byte budget (MB)
# for the Gram-block cache's device tier; 0/None disables it.
DEFAULT_GRAM_CACHE_ENTRIES = 4096
DEFAULT_DEVICE_BANK_MB = 256

VARIABLE_KINDS = ("continuous", "discrete")
ENGINES = ("batched", "sequential", "sharded")
PRECISIONS = ("bitwise", "f32_gram")
RESTRICTS = ("none", "skeleton")
OBS_MODES = ("off", "metrics", "trace")


@dataclasses.dataclass(frozen=True)
class VariableSpec:
    """One variable of the data matrix: `dim` contiguous columns, routed
    to a factorization backend by its `kind` (Alg. 1 for continuous,
    Alg. 2 for discrete under the default `repro.features.policy.
    FeaturePolicy`).

    levels: the variable's known distinct-row count, recorded by
    `DataSpec.infer` so the discrete feature backend never re-scans the
    column (None = unknown; `DataSpec.from_arrays` leaves it unknown and
    the backend counts once at build time).

    backend / backend_params: an optional per-variable feature-backend
    override riding on the spec — e.g. ``backend="nystrom",
    backend_params={"sampler": "stratified"}`` — consulted by
    `FeaturePolicy.resolve` ahead of the kind routing (a set uses an
    override when every member names the same one).
    """

    name: str
    dim: int = 1
    kind: str = "continuous"
    levels: int | None = None
    backend: str | None = None
    backend_params: tuple = ()

    def __post_init__(self):
        if not isinstance(self.name, str) or not self.name:
            raise ValueError(
                f"VariableSpec.name must be a non-empty string, got {self.name!r}"
            )
        if int(self.dim) < 1:
            raise ValueError(
                f"VariableSpec {self.name!r}: dim must be >= 1, got {self.dim!r}"
            )
        object.__setattr__(self, "dim", int(self.dim))
        if self.kind not in VARIABLE_KINDS:
            raise ValueError(
                f"VariableSpec {self.name!r}: kind must be one of "
                f"{VARIABLE_KINDS}, got {self.kind!r}"
            )
        if self.levels is not None:
            if int(self.levels) < 1:
                raise ValueError(
                    f"VariableSpec {self.name!r}: levels must be >= 1 or "
                    f"None, got {self.levels!r}"
                )
            object.__setattr__(self, "levels", int(self.levels))
        if self.backend is not None and (
            not isinstance(self.backend, str) or not self.backend
        ):
            raise ValueError(
                f"VariableSpec {self.name!r}: backend must be a non-empty "
                f"string or None, got {self.backend!r}"
            )
        params = self.backend_params
        if isinstance(params, dict):
            params = tuple(sorted(params.items()))
        params = tuple((str(k), v) for k, v in params)
        if params and self.backend is None:
            raise ValueError(
                f"VariableSpec {self.name!r}: backend_params given without "
                "a backend override"
            )
        object.__setattr__(self, "backend_params", params)

    @property
    def discrete(self) -> bool:
        return self.kind == "discrete"


@dataclasses.dataclass(frozen=True)
class DataSpec:
    """Typed description of an (n, total_cols) data matrix as variables.

    Construct with `from_arrays` (explicit dims/discreteness — the typed
    replacement for the old parallel lists) or `infer` (dtype/cardinality
    heuristics).  `validate(data)` checks the matrix against the spec once,
    up front, and returns the float64 matrix every scorer consumes.
    """

    variables: tuple

    def __post_init__(self):
        variables = tuple(self.variables)
        if not variables:
            raise ValueError("DataSpec needs at least one variable")
        for v in variables:
            if not isinstance(v, VariableSpec):
                raise ValueError(
                    f"DataSpec.variables must be VariableSpec instances, got {v!r}"
                )
        names = [v.name for v in variables]
        dupes = sorted({n for n in names if names.count(n) > 1})
        if dupes:
            raise ValueError(f"DataSpec variable names must be unique: {dupes}")
        object.__setattr__(self, "variables", variables)

    # -- views the scorers consume ---------------------------------------
    @property
    def num_vars(self) -> int:
        return len(self.variables)

    @property
    def names(self) -> list:
        return [v.name for v in self.variables]

    @property
    def dims(self) -> list:
        return [v.dim for v in self.variables]

    @property
    def discrete(self) -> list:
        return [v.discrete for v in self.variables]

    @property
    def total_cols(self) -> int:
        return sum(v.dim for v in self.variables)

    # -- constructors ----------------------------------------------------
    @classmethod
    def from_arrays(cls, data, dims=None, discrete=None, names=None) -> "DataSpec":
        """Spec from the legacy per-variable lists (`dims`, `discrete`).

        `data` supplies the column count; omitted lists default the way the
        old kwargs did (all dims 1, all continuous).  Mismatched list
        lengths or dims that do not tile the matrix raise immediately with
        the offending numbers spelled out.
        """
        arr = _as_matrix(data)
        total = arr.shape[1]
        if dims is None:
            dims = [1] * total
        dims = [int(d) for d in dims]
        if sum(dims) != total:
            raise ValueError(
                f"dims {dims} cover {sum(dims)} columns but the data matrix "
                f"has {total}"
            )
        d = len(dims)
        if discrete is None:
            discrete = [False] * d
        if len(discrete) != d:
            raise ValueError(
                f"discrete has {len(discrete)} entries for {d} variables "
                f"(dims={dims})"
            )
        if names is None:
            names = [f"x{i}" for i in range(d)]
        if len(names) != d:
            raise ValueError(
                f"names has {len(names)} entries for {d} variables"
            )
        return cls(
            tuple(
                VariableSpec(
                    name=str(nm),
                    dim=dm,
                    kind="discrete" if bool(dc) else "continuous",
                )
                for nm, dm, dc in zip(names, dims, discrete)
            )
        )

    @classmethod
    def infer(cls, data, dims=None, max_levels: int | None = None) -> "DataSpec":
        """Infer per-variable kinds by dtype/cardinality heuristics.

        A variable is tagged ``discrete`` — routing the paper's exact
        Alg.-2 factorization — when every one of its columns is
        integer-valued (bool/int dtype, or floats that are all whole
        numbers) AND the variable's rows take at most `max_levels` distinct
        values (default ``min(20, max(2, n // 10))``: a discrete kernel on
        near-continuous cardinality would defeat Alg. 2's m_d <= m_max
        requirement).  Everything else is ``continuous``.

        `dims` groups columns into multi-dimensional variables before
        inference (cardinality is then counted on the joint rows); by
        default every column is its own variable.
        """
        arr = _as_matrix(data)
        n, total = arr.shape
        if dims is None:
            dims = [1] * total
        dims = [int(d) for d in dims]
        if sum(dims) != total:
            raise ValueError(
                f"dims {dims} cover {sum(dims)} columns but the data matrix "
                f"has {total}"
            )
        if max_levels is None:
            max_levels = min(20, max(2, n // 10))
        # lazy: repro.features imports back into the scorer stack
        from repro.features.backends import count_distinct_rows

        variables = []
        offset = 0
        for i, dm in enumerate(dims):
            block = arr[:, offset : offset + dm]
            offset += dm
            integral = bool(
                np.all(np.isfinite(block)) and np.all(block == np.round(block))
            )
            kind, levels = "continuous", None
            if integral:
                count = count_distinct_rows(block, max_levels)
                if count <= max_levels:
                    # exact count (the scan early-exits only past the cap):
                    # recorded on the spec so the discrete feature backend
                    # routes without scanning this column a second time
                    kind, levels = "discrete", count
            variables.append(
                VariableSpec(name=f"x{i}", dim=dm, kind=kind, levels=levels)
            )
        return cls(tuple(variables))

    # -- validation ------------------------------------------------------
    def validate(self, data) -> np.ndarray:
        """Check `data` against this spec; returns the (n, total_cols)
        float64 matrix.  Raises ValueError naming the variable and the
        offending shape/value — the one up-front shape check every scorer
        relies on instead of failing deep inside a kernel.
        """
        arr = _as_matrix(data)
        n, total = arr.shape
        if total != self.total_cols:
            raise ValueError(
                f"DataSpec describes {self.num_vars} variables over "
                f"{self.total_cols} columns (dims={self.dims}) but the data "
                f"matrix has {total} columns"
            )
        if n < 2:
            raise ValueError(f"need at least 2 samples, got data shape {arr.shape}")
        if not np.all(np.isfinite(arr)):
            offsets = np.concatenate([[0], np.cumsum(self.dims)])
            bad = sorted(
                self.variables[i].name
                for i in range(self.num_vars)
                if not np.all(np.isfinite(arr[:, offsets[i] : offsets[i + 1]]))
            )
            raise ValueError(
                f"data contains non-finite values in variable(s) {bad}; "
                "clean or impute before scoring"
            )
        return arr


def _as_matrix(data) -> np.ndarray:
    """(n,) or (n, cols) array-likes -> float64 (n, cols) matrix."""
    arr = np.asarray(data, dtype=np.float64)
    if arr.ndim == 1:
        arr = arr[:, None]
    if arr.ndim != 2:
        raise ValueError(
            f"data must be a 1-D or 2-D array, got shape {arr.shape}"
        )
    return arr


def resolve_spec(data, spec=None, dims=None, discrete=None) -> DataSpec:
    """One resolution rule for every scorer: an explicit `DataSpec` wins
    (passing the legacy lists alongside it is an error, not a silent
    override); otherwise the legacy lists build one via `from_arrays`."""
    if spec is not None:
        if dims is not None or discrete is not None:
            raise ValueError(
                "pass either spec= or the legacy dims=/discrete= lists, not both"
            )
        if not isinstance(spec, DataSpec):
            raise ValueError(f"spec must be a DataSpec, got {type(spec).__name__}")
        return spec
    return DataSpec.from_arrays(data, dims=dims, discrete=discrete)


@dataclasses.dataclass(frozen=True)
class EngineOptions:
    """How a discovery run should execute — one frozen, inspectable object
    consolidating what used to be loose kwargs plus a user-threaded hook.

    engine:
      * ``"batched"`` (default) — the batched frontier engine
        (`repro.core.score_lowrank.cvlr_scores_batched`): feature bank,
        two-tier Gram-block cache, fused fold-Gram kernels.
      * ``"sequential"`` — the lazy per-candidate oracle path (the old
        ``batched=False``).
      * ``"sharded"`` — the GES frontier routes through
        `repro.core.distributed_score` (stacked fold-blocked factors,
        the shard_map-able scoring pipeline); no hand-rolled
        ``batch_hook`` needed.

    gram_cache_entries / device_bank_mb: the Gram-block cache bounds
    (total LRU entry count across tiers / device-tier byte budget), as
    before — see `repro.core.score_common.GramBlockCache`.

    precision:
      * ``"bitwise"`` (default) — f64 Gram accumulation on CPU/GPU; the
        engine is bit-identical to the sequential oracle on CPU.
      * ``"f32_gram"`` — the gather+einsum Gram fallback accumulates at
        float32 and casts back (exactly what the TPU Mosaic kernels
        already do — there the two policies coincide), relaxing
        engine==oracle to ~1e-7-relative Gram accuracy in exchange for
        ~2x cheaper cross-Gram contractions on the CPU/GPU paths.
        Downstream fold algebra (Cholesky solves, logdets) stays f64.
        Oracle-comparison tolerances must key off `oracle_rtol`.

    features: a `repro.features.policy.FeaturePolicy` selecting the
      factorization backend per variable kind (``icl`` /
      ``discrete_exact`` / ``rff`` / ``nystrom`` — see
      `repro.features.backends`), with per-variable overrides riding on
      the `DataSpec`.  None (the default) means
      `FeaturePolicy.default()`, which reproduces the pre-PR-5 ICL /
      exact-discrete routing bitwise.

    checkpoint_dir / checkpoint_every: sweep-granular checkpointing.
      When `checkpoint_dir` is set, the `DiscoverySession` commits its
      `repro.core.runstate.RunState` (CPDAG, phase, applied-step log,
      sweep telemetry, FeatureBank metadata) through the atomic async
      checkpoint store every `checkpoint_every` completed sweeps;
      `causal_discover(..., resume="auto")` restores from the newest
      loadable step and reproduces the uninterrupted run bit-for-bit.
      None (the default) disables checkpointing.

    shard_workers / shard_retries / shard_timeout_s: the sharded
      engine's fault-tolerance shape.  The frontier is partitioned
      across `shard_workers` logical workers; a failed shard attempt is
      retried with exponential backoff up to `shard_retries` times, a
      worker whose heartbeat misses `shard_retries + 1` deadline windows
      (each `shard_timeout_s` long; None = no per-shard timeout) is
      declared dead and its remaining slice is re-partitioned across the
      survivors, and a sweep with no survivors scores its stranded keys
      in-process through the same stacked pipeline the shards run (so
      recovery stays score-bitwise-identical).  The default (1 worker)
      keeps the pre-fault-tolerance single-dispatch stacked pipeline.

    deadline_s: per-request wall-clock budget (seconds).  The
      `DiscoverySession` checks it at every sweep seam (`begin_sweep` /
      `score_frontier` / `end_sweep`) and raises a structured
      `repro.core.runstate.DeadlineExceeded` once the budget is spent —
      the serving layer's load-shedding hook (`repro.serving`).  None
      (the default) means no deadline.

    incremental: the sweep-over-sweep frontier-delta engine (default
      True).  A `DiscoverySession` diffs each sweep's frontier against
      the previous one: configurations already scored are *carried* from
      the scorer's memo without re-dispatch, only the *delta* (new
      configurations incident to the applied step) is scored — through
      the small-batch fast path when the delta is small — and the GES
      candidate enumerator carries per-pair candidate lists across
      sweeps, re-enumerating only pairs the applied step touched.
      ``False`` restores the full re-enumerate/re-dispatch behavior —
      kept as the differential oracle (tests/test_frontier_delta.py
      proves the two paths bitwise-equal).  Sweep-log entries record the
      carried/delta/invalidated counts either way.

    restrict / ci_alpha / ci_max_cond: the constraint phase
      (`repro.constraint` — docs/ARCHITECTURE.md §12).
      ``restrict="skeleton"`` makes the `DiscoverySession` estimate a
      PC-stable skeleton with factor-based kernel CI tests *before* the
      score phase and gate every GES forward frontier with the resulting
      `EdgeMask` — masked-out pairs never become insert candidates and
      never enter the incremental frontier-delta bookkeeping; deletes
      and reversals are never gated.  The CI tests fetch factors through
      the session's FeatureBank (zero duplicate builds) and pre-warm the
      Gram-block cache with engine-keyed blocks.  ``ci_alpha`` is the
      per-test significance level (an edge is severed when independence
      is NOT rejected, p >= alpha, so *larger* alpha keeps more edges);
      ``ci_max_cond`` caps the conditioning-set size (PC level).
      ``restrict="none"`` (default) is bitwise-identical to the ungated
      engine on every path.  Requires ``method="cvlr"``.

    score_memo_entries: optional LRU bound on the scorer's (node,
      parents) -> score memo (`ScorerBase._score_cache`), which is
      otherwise unbounded — a long multi-tenant session's memo can only
      grow.  Eviction is safe (scores are pure functions of the
      configuration and recompute on demand, at re-dispatch cost); the
      per-sweep log exposes the entry count and cumulative evictions
      under ``"score_cache"`` either way.  A bound that still holds the
      sweep working set never evicts mid-search and changes nothing; a
      bound *below* the working set keeps the search correct (same
      equivalence class) but relaxes bitwise reproducibility vs an
      unbounded run to the engine==oracle 1e-8 tolerance, because
      evicted configurations are recomputed through the lazy per-config
      path.  None (default) = unbounded.

    obs / trace_dir: the observability layer (`repro.obs` —
      docs/ARCHITECTURE.md §13).
      * ``"off"`` (default) — no recorder; `repro.obs.trace.span` is a
        shared no-op and the engine's results/wall-clock are unchanged.
      * ``"metrics"`` — the session owns a `repro.obs.Recorder` feeding
        a `repro.obs.MetricsRegistry` (span latency histograms, compile
        counters, cache/bank/ladder sources) with no event retention.
      * ``"trace"`` — additionally retains structured trace events
        (session → sweep → stage → kernel spans, jit compile spans) and,
        when ``trace_dir`` is set, streams them to an append-only JSONL
        log and writes a Chrome/Perfetto ``trace_event`` timeline at
        session close.  ``trace_dir`` requires ``obs="trace"``.
      Either mode adds per-stage device syncs inside the batched engine
      (the span boundaries are honest), so `obs != "off"` trades a few
      percent of wall-clock for measurement; ``"off"`` is the
      production-default zero-overhead path.
    """

    engine: str = "batched"
    gram_cache_entries: int | None = DEFAULT_GRAM_CACHE_ENTRIES
    device_bank_mb: float | None = DEFAULT_DEVICE_BANK_MB
    precision: str = "bitwise"
    features: object | None = None
    checkpoint_dir: str | None = None
    checkpoint_every: int = 1
    shard_workers: int = 1
    shard_retries: int = 2
    shard_timeout_s: float | None = None
    deadline_s: float | None = None
    incremental: bool = True
    restrict: str = "none"
    ci_alpha: float = 0.05
    ci_max_cond: int = 2
    score_memo_entries: int | None = None
    obs: str = "off"
    trace_dir: str | None = None

    def __post_init__(self):
        if self.engine not in ENGINES:
            raise ValueError(
                f"engine must be one of {ENGINES}, got {self.engine!r}"
            )
        if self.precision not in PRECISIONS:
            raise ValueError(
                f"precision must be one of {PRECISIONS}, got {self.precision!r}"
            )
        if self.gram_cache_entries is not None and int(self.gram_cache_entries) < 1:
            raise ValueError(
                "gram_cache_entries must be >= 1 or None, got "
                f"{self.gram_cache_entries!r}"
            )
        if self.device_bank_mb is not None:
            mb = float(self.device_bank_mb)
            if math.isnan(mb) or mb < 0:
                raise ValueError(
                    f"device_bank_mb must be >= 0 or None, got {self.device_bank_mb!r}"
                )
        if self.features is not None:
            # lazy: policy objects are stdlib-only, but keep spec.py free
            # of the repro.features import unless a policy is actually set
            from repro.features.policy import FeaturePolicy

            if not isinstance(self.features, FeaturePolicy):
                raise ValueError(
                    "features must be a repro.features.policy.FeaturePolicy "
                    f"or None, got {type(self.features).__name__}"
                )
        if self.checkpoint_dir is not None and not isinstance(
            self.checkpoint_dir, str
        ):
            raise ValueError(
                f"checkpoint_dir must be a path string or None, got "
                f"{self.checkpoint_dir!r}"
            )
        if int(self.checkpoint_every) < 1:
            raise ValueError(
                f"checkpoint_every must be >= 1, got {self.checkpoint_every!r}"
            )
        object.__setattr__(self, "checkpoint_every", int(self.checkpoint_every))
        if int(self.shard_workers) < 1:
            raise ValueError(
                f"shard_workers must be >= 1, got {self.shard_workers!r}"
            )
        object.__setattr__(self, "shard_workers", int(self.shard_workers))
        if int(self.shard_retries) < 0:
            raise ValueError(
                f"shard_retries must be >= 0, got {self.shard_retries!r}"
            )
        object.__setattr__(self, "shard_retries", int(self.shard_retries))
        if self.shard_timeout_s is not None:
            t = float(self.shard_timeout_s)
            if math.isnan(t) or t <= 0:
                raise ValueError(
                    f"shard_timeout_s must be > 0 or None, got "
                    f"{self.shard_timeout_s!r}"
                )
            object.__setattr__(self, "shard_timeout_s", t)
        if self.deadline_s is not None:
            dl = float(self.deadline_s)
            if math.isnan(dl) or dl <= 0:
                raise ValueError(
                    f"deadline_s must be > 0 or None, got {self.deadline_s!r}"
                )
            object.__setattr__(self, "deadline_s", dl)
        object.__setattr__(self, "incremental", bool(self.incremental))
        if self.restrict not in RESTRICTS:
            raise ValueError(
                f"restrict must be one of {RESTRICTS}, got {self.restrict!r}"
            )
        a = float(self.ci_alpha)
        if math.isnan(a) or not 0.0 < a < 1.0:
            raise ValueError(
                f"ci_alpha must be in (0, 1), got {self.ci_alpha!r}"
            )
        object.__setattr__(self, "ci_alpha", a)
        if int(self.ci_max_cond) < 0:
            raise ValueError(
                f"ci_max_cond must be >= 0, got {self.ci_max_cond!r}"
            )
        object.__setattr__(self, "ci_max_cond", int(self.ci_max_cond))
        if self.score_memo_entries is not None:
            if int(self.score_memo_entries) < 1:
                raise ValueError(
                    "score_memo_entries must be >= 1 or None, got "
                    f"{self.score_memo_entries!r}"
                )
            object.__setattr__(
                self, "score_memo_entries", int(self.score_memo_entries)
            )
        if self.obs not in OBS_MODES:
            raise ValueError(
                f"obs must be one of {OBS_MODES}, got {self.obs!r}"
            )
        if self.trace_dir is not None:
            if not isinstance(self.trace_dir, str):
                raise ValueError(
                    f"trace_dir must be a path string or None, got "
                    f"{self.trace_dir!r}"
                )
            if self.obs != "trace":
                raise ValueError(
                    f"trace_dir requires obs='trace', got obs={self.obs!r}"
                )

    @property
    def batched(self) -> bool:
        """Whether the scorer's batched prefetch engine should serve GES
        frontiers (the ``"sharded"`` engine scores frontiers through the
        distributed pipeline instead, so its scorer stays lazy)."""
        return self.engine == "batched"

    @property
    def oracle_rtol(self) -> float:
        """Relative tolerance vs the sequential f64 oracle that this
        policy guarantees on CPU — what tests and benchmarks should
        assert against instead of hard-coding a number."""
        return 1e-8 if self.precision == "bitwise" else 1e-5
