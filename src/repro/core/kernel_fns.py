"""Kernel functions for the generalized score.

The paper (following Huang et al. 2018) uses an RBF kernel with the
"2x median distance" width heuristic on z-scored data; discrete variables use
the same kernel but are routed through the exact decomposition (Alg. 2).
A delta kernel is provided for strictly-categorical use.

All pairwise computations are expressed so the hot block — k(X, pivots),
an (n x m) strip of the kernel matrix — can be served either by plain jnp
(CPU / this container) or by the Pallas TPU kernel in repro.kernels.rbf_gram
(HBM->VMEM tiled, fused sq-dist + exp).  See repro.kernels.ops.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """A kernel on a (possibly multi-dimensional) variable set.

    kind: "rbf" | "delta" | "linear"
    width: RBF bandwidth sigma (k(x,y) = exp(-||x-y||^2 / (2 sigma^2))).
    """

    kind: str = "rbf"
    width: float = 1.0

    def diag_value(self) -> float:
        # k(x, x) for translation-invariant kernels used here.
        if self.kind in ("rbf", "delta"):
            return 1.0
        raise ValueError(f"diag undefined for kernel {self.kind}")


def median_heuristic_width(
    x: np.ndarray, factor: float = 2.0, max_points: int = 1024
) -> float:
    """sigma = factor * median pairwise distance, on a capped subsample.

    The exact median is O(n^2); capping at `max_points` keeps the scorer
    linear in n while matching the heuristic to <1% on iid data.
    Deterministic: takes an evenly strided subsample.
    """
    x = np.asarray(x, dtype=np.float64)
    if x.ndim == 1:
        x = x[:, None]
    n = x.shape[0]
    if n > max_points:
        idx = np.linspace(0, n - 1, max_points).astype(np.int64)
        x = x[idx]
    d2 = np.sum((x[:, None, :] - x[None, :, :]) ** 2, axis=-1)
    iu = np.triu_indices(x.shape[0], k=1)
    vals = np.sqrt(np.maximum(d2[iu], 0.0))
    vals = vals[vals > 0]
    med = float(np.median(vals)) if vals.size else 1.0
    return max(factor * med, 1e-8)


def _sqdist(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Pairwise squared distances, (n, d) x (m, d) -> (n, m).

    Uses the expanded ||x||^2 - 2<x,y> + ||y||^2 form: the -2<x,y> term is a
    matmul (MXU work on TPU) instead of an O(n m d) broadcast subtract.
    """
    xn = jnp.sum(x * x, axis=-1, keepdims=True)  # (n, 1)
    yn = jnp.sum(y * y, axis=-1, keepdims=True).T  # (1, m)
    d2 = xn + yn - 2.0 * (x @ y.T)
    return jnp.maximum(d2, 0.0)


@partial(jax.jit, static_argnames=("kind",))
def _kernel_matrix(x, y, width, kind: str):
    if kind == "rbf":
        return jnp.exp(-_sqdist(x, y) / (2.0 * width * width))
    if kind == "delta":
        d2 = _sqdist(x, y)
        return (d2 < 1e-18).astype(x.dtype)
    if kind == "linear":
        return x @ y.T
    raise ValueError(f"unknown kernel kind {kind}")


def kernel_matrix(x, y, spec: KernelSpec) -> jnp.ndarray:
    """Full (or strip) kernel matrix k(x_i, y_j)."""
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    if x.ndim == 1:
        x = x[:, None]
    if y.ndim == 1:
        y = y[:, None]
    return _kernel_matrix(x, y, jnp.asarray(spec.width, x.dtype), spec.kind)


def kernel_rows(x, pivots, spec: KernelSpec) -> jnp.ndarray:
    """k(X, pivots): the (n, m) strip — the ICL / Nystroem hot spot."""
    return kernel_matrix(x, pivots, spec)


def center_gram(k: jnp.ndarray) -> jnp.ndarray:
    """K~ = H K H with H = I - 11^T/n (double centering)."""
    row = jnp.mean(k, axis=0, keepdims=True)
    col = jnp.mean(k, axis=1, keepdims=True)
    tot = jnp.mean(k)
    return k - row - col + tot


def center_features(lam: jnp.ndarray) -> jnp.ndarray:
    """Lambda~ = H Lambda (so Lambda~ Lambda~^T = H Lambda Lambda^T H)."""
    return lam - jnp.mean(lam, axis=0, keepdims=True)


def standardize(x: np.ndarray) -> np.ndarray:
    """Column z-scoring (constant columns pass through centered)."""
    x = np.asarray(x, dtype=np.float64)
    if x.ndim == 1:
        x = x[:, None]
    mu = x.mean(axis=0, keepdims=True)
    sd = x.std(axis=0, keepdims=True)
    sd = np.where(sd < 1e-12, 1.0, sd)
    return (x - mu) / sd
