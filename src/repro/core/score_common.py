"""Shared scaffolding for the exact (CV) and low-rank (CV-LR) scorers.

Fold layout: the scorer permutes the dataset once (seeded) and truncates to
n_eff = Q * (n // Q) rows, so fold q's *test* block is the contiguous row
range [q*n0, (q+1)*n0) and the train set is its complement.  Contiguous
blocks over permuted rows == random folds, and they let the low-rank path
compute all per-fold Gram blocks with one reshape+einsum (see
score_lowrank.py) instead of Q gathers — a 10x constant-factor win over the
naive per-fold recomputation (recorded in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import collections
import dataclasses

import numpy as np


def set_key(vars_idx) -> tuple:
    """Canonical variable-set key: deduplicated sorted tuple of ints.

    The one normalization used everywhere a variable set indexes a cache —
    feature banks, Gram-block caches, kernel caches, score caches — so the
    search layer and the scorers can never disagree on identity.
    """
    if isinstance(vars_idx, (int, np.integer)):
        return (int(vars_idx),)
    return tuple(sorted({int(v) for v in vars_idx}))


def config_key(i, parents=()) -> tuple:
    """Canonical (node, parent-set) key for local-score caches and the GES
    frontier: ``(int, sorted-tuple)``."""
    return int(i), set_key(parents)


class GramBlockCache:
    """Host-side LRU cache of per-fold Gram blocks keyed on ``(key_a,
    key_b)`` canonical variable-set keys (``set_key`` tuples).

    The batched frontier engine stores each diagonal block V = X_q^T X_q
    under ``(kx, kx)``, each S = Z_q^T Z_q under ``(kz, kz)`` and each cross
    block U = Z_q^T X_q under ``(kz, kx)`` — so a child's Grams are computed
    once per sweep no matter how many candidate parent sets reference it,
    and persist across sweeps.  Hit/miss/eviction counters expose the
    sharing structure to tests and perf tooling.  The exact-CV scorer
    reuses the same interface for its centered kernel matrices.

    ``max_entries`` bounds the store with least-recently-used eviction
    (both get and put refresh recency): a long GES search would otherwise
    grow the cache monotonically — one U block per (parent set, child)
    pair ever scored.  None (the default here) means unbounded; the
    CV-LR scorer sizes it to the sweep working set (see
    ``CVLRScorer.DEFAULT_GRAM_CACHE_ENTRIES``).
    """

    def __init__(self, max_entries: int | None = None):
        if max_entries is not None and max_entries < 1:
            raise ValueError(f"max_entries must be >= 1 or None, got {max_entries}")
        self._store: collections.OrderedDict = collections.OrderedDict()
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __contains__(self, key) -> bool:
        return key in self._store

    def __len__(self) -> int:
        return len(self._store)

    def get(self, key):
        """Counted lookup: returns the block or None (and tallies hit/miss)."""
        try:
            value = self._store[key]
        except KeyError:
            self.misses += 1
            return None
        self._store.move_to_end(key)
        self.hits += 1
        return value

    def put(self, key, value) -> None:
        self._store[key] = value
        self._store.move_to_end(key)
        if self.max_entries is not None:
            while len(self._store) > self.max_entries:
                self._store.popitem(last=False)
                self.evictions += 1

    def clear(self) -> None:
        self._store.clear()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @property
    def stats(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "entries": len(self._store),
            "max_entries": self.max_entries,
        }


@dataclasses.dataclass(frozen=True)
class ScoreConfig:
    """Paper defaults (Sec. 7.1 / Appendix A.2)."""

    lmbda: float = 0.01  # ridge regularizer lambda
    gamma: float = 0.01  # covariance jitter gamma  (beta = lmbda^2/gamma)
    q_folds: int = 10  # 10-fold cross-validated likelihood
    m_max: int = 100  # maximal rank / pivot budget (paper Sec. 7.2)
    eta: float = 1e-6  # ICL precision parameter
    width_factor: float = 2.0  # "2x median distance" kernel width
    seed: int = 0

    @property
    def beta(self) -> float:
        return self.lmbda * self.lmbda / self.gamma


def fold_layout(n: int, q: int, seed: int):
    """Returns (perm, n_eff, n0, n1, train_idx (q, n1)).

    perm: permutation applied to the data rows once at scorer build time.
    After permutation, fold i tests rows [i*n0, (i+1)*n0).
    """
    if n < 2 * q:
        raise ValueError(f"need n >= 2*Q samples, got n={n}, Q={q}")
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    n0 = n // q
    n_eff = n0 * q
    n1 = n_eff - n0
    all_idx = np.arange(n_eff)
    train_idx = np.stack(
        [np.delete(all_idx, np.arange(i * n0, (i + 1) * n0)) for i in range(q)]
    )
    return perm[:n_eff], n_eff, n0, n1, train_idx


class VariableView:
    """Column-slice view of a (n, total_cols) data matrix into variables.

    Supports multi-dimensional variables (paper Sec. 7.4) via `dims`:
    variable i owns columns [offsets[i], offsets[i]+dims[i]).
    """

    def __init__(self, data: np.ndarray, dims=None, discrete=None):
        data = np.asarray(data, dtype=np.float64)
        if data.ndim == 1:
            data = data[:, None]
        self.data = data
        if dims is None:
            dims = [1] * data.shape[1]
        self.dims = list(dims)
        self.offsets = np.concatenate([[0], np.cumsum(self.dims)]).astype(int)
        if self.offsets[-1] != data.shape[1]:
            raise ValueError("dims do not cover the data columns")
        self.num_vars = len(self.dims)
        self.discrete = list(discrete) if discrete is not None else [False] * self.num_vars

    def columns(self, vars_idx) -> np.ndarray:
        """Concatenate columns of the given variables (sorted order)."""
        if isinstance(vars_idx, (int, np.integer)):
            vars_idx = (int(vars_idx),)
        cols = [
            self.data[:, self.offsets[v] : self.offsets[v + 1]]
            for v in sorted(int(v) for v in vars_idx)
        ]
        return np.concatenate(cols, axis=1)

    def is_discrete(self, vars_idx) -> bool:
        if isinstance(vars_idx, (int, np.integer)):
            vars_idx = (int(vars_idx),)
        return all(self.discrete[int(v)] for v in vars_idx)


class ScorerBase:
    """Decomposable local-score interface shared by CV and CV-LR."""

    def __init__(self, view: VariableView, config: ScoreConfig):
        self.view = view
        self.config = config
        perm, n_eff, n0, n1, train_idx = fold_layout(
            view.data.shape[0], config.q_folds, config.seed
        )
        self.perm = perm
        self.n_eff, self.n0, self.n1 = n_eff, n0, n1
        self.train_idx = train_idx
        self._score_cache: dict = {}

    # -- public API ------------------------------------------------------
    def local_score(self, i: int, parents=()) -> float:
        key = config_key(i, parents)
        if key not in self._score_cache:
            self._score_cache[key] = float(self._compute(key[0], key[1]))
        return self._score_cache[key]

    def prefetch(self, configs) -> int:
        """Batch-evaluate ``(node, parents)`` configurations ahead of the
        `local_score` lookups of a GES sweep.  Returns the number of scores
        actually computed.  The base implementation is lazy (0 computed;
        `local_score` falls back to per-candidate evaluation) — batched
        scorers override this with a single-dispatch engine.
        """
        return 0

    def score_graph(self, adj: np.ndarray) -> float:
        """S(G) = sum_i S(X_i, Pa_i) — decomposability (paper Eq. 31)."""
        d = adj.shape[0]
        return float(
            sum(self.local_score(i, tuple(np.flatnonzero(adj[:, i]))) for i in range(d))
        )

    @property
    def cache_size(self) -> int:
        return len(self._score_cache)

    def _compute(self, i: int, parents: tuple) -> float:  # pragma: no cover
        raise NotImplementedError
