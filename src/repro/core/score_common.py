"""Shared scaffolding for the exact (CV) and low-rank (CV-LR) scorers.

Fold layout: the scorer permutes the dataset once (seeded) and truncates to
n_eff = Q * (n // Q) rows, so fold q's *test* block is the contiguous row
range [q*n0, (q+1)*n0) and the train set is its complement.  Contiguous
blocks over permuted rows == random folds, and they let the low-rank path
compute all per-fold Gram blocks with one reshape+einsum (see
score_lowrank.py) instead of Q gathers — a 10x constant-factor win over the
naive per-fold recomputation (recorded in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import threading

import numpy as np

try:  # the device tier stores jax arrays; the host tier is numpy-only
    import jax
    import jax.numpy as jnp
except ImportError:  # pragma: no cover - jax is a hard dep of the scorers
    jax = jnp = None

if jax is not None:
    # Promotion write: donated so re-promoting spilled blocks updates the
    # bank buffer in place instead of copying the whole bank per upload
    # (same policy as the fused compute-scatter in repro.kernels.ops).
    # One scatter applies a whole batch of queued promotions — host-tier
    # hits found during a sweep are QUEUED by `device_lookup` and flushed
    # as one upload per bucket width (pow2-padded row counts keep the jit
    # variant set small), instead of one dispatch per block.
    @functools.partial(jax.jit, donate_argnums=(0,))
    def _bank_set_rows(bank, slots, rows):
        return bank.at[slots].set(rows)


def set_key(vars_idx) -> tuple:
    """Canonical variable-set key: deduplicated sorted tuple of ints.

    The one normalization used everywhere a variable set indexes a cache —
    feature banks, Gram-block caches, kernel caches, score caches — so the
    search layer and the scorers can never disagree on identity.
    """
    if isinstance(vars_idx, (int, np.integer)):
        return (int(vars_idx),)
    return tuple(sorted({int(v) for v in vars_idx}))


def config_key(i, parents=()) -> tuple:
    """Canonical (node, parent-set) key for local-score caches and the GES
    frontier: ``(int, sorted-tuple)``."""
    return int(i), set_key(parents)


class DeviceGramBank:
    """One padded device tensor of per-fold Gram-block *slots* at a fixed
    ``(wa, wb)`` bucket width: ``data`` has shape ``(n_slots, q, wa, wb)``.

    Slot 0 is a permanent all-zero block (the exact |Z|=0 / rank-0 row any
    gather may point at) and slot 1 is write-only scratch (chunk padding
    rows scatter there so chunk shapes stay jit-stable without slicing);
    neither is ever allocated to a key.  ``data`` updates are IN PLACE —
    buffer donation on the jnp scatter paths, input/output aliasing in the
    banked Pallas kernel — so the array object held in ``data`` before an
    update is *consumed* (using it afterwards raises jax's deleted-array
    error, loudly).  Never keep a reference to ``data`` across a scatter /
    promotion; re-read it at use time.  In-flight reads are still safe:
    on a single device stream every dispatched gather completes before a
    later donated write executes.
    """

    ZERO_SLOT = 0  # permanent all-zero block; gather target for |Z|=0 rows
    SCRATCH_SLOT = 1  # write-only; chunk padding rows scatter here
    RESERVED_SLOTS = 2

    def __init__(self, widths: tuple, q: int, dtype, n_slots: int):
        self.widths = (int(widths[0]), int(widths[1]))
        self.q = int(q)
        self.dtype = np.dtype(dtype)
        n_slots = max(int(n_slots), self.RESERVED_SLOTS + 1)
        self.data = jnp.zeros(
            (n_slots, self.q) + self.widths, dtype=self.dtype
        )
        self.free = list(range(n_slots - 1, self.RESERVED_SLOTS - 1, -1))

    @property
    def n_slots(self) -> int:
        return self.data.shape[0]

    @property
    def slot_nbytes(self) -> int:
        return self.q * self.widths[0] * self.widths[1] * self.dtype.itemsize

    @property
    def nbytes(self) -> int:
        return self.n_slots * self.slot_nbytes

    def grow_to(self, n_slots: int) -> None:
        old = self.n_slots
        if n_slots <= old:
            return
        new = jnp.zeros((n_slots, self.q) + self.widths, dtype=self.dtype)
        self.data = new.at[:old].set(self.data)
        self.free.extend(range(n_slots - 1, old - 1, -1))


class GramBlockCache:
    """Two-tier LRU cache of per-fold Gram blocks keyed on ``(key_a,
    key_b)`` canonical variable-set keys (``set_key`` tuples).

    The batched frontier engine stores each diagonal block V = X_q^T X_q
    under ``(kx, kx)``, each S = Z_q^T Z_q under ``(kz, kz)`` and each cross
    block U = Z_q^T X_q under ``(kz, kx)`` — so a child's Grams are computed
    once per sweep no matter how many candidate parent sets reference it,
    and persist across sweeps.  Hit/miss/eviction counters expose the
    sharing structure to tests and perf tooling.  The exact-CV scorer
    reuses the same (host-tier) interface for its centered kernel matrices.

    **Host tier** (always on): trimmed ``(q, m_eff_a, m_eff_b)`` numpy
    blocks in an OrderedDict, exactly the PR-2 behavior.

    **Device tier** (``device_bank_mb > 0``): blocks live *on device*, as
    slots of padded per-width :class:`DeviceGramBank` tensors, so the
    batched engine's fused Gram kernels scatter straight into them and the
    fold stage index-gathers out of them — no host round-trip.  The tier is
    driven by the engine through a sweep protocol:

    1. ``begin_device_sweep(specs, q, dtype)`` pins the sweep's working set
       and pre-arranges slot capacity (growing banks within the byte budget,
       else spilling LRU *unpinned* slots to the host tier).  Returns False
       — and the engine falls back to the host path wholesale — when the
       working set cannot be made device-resident (budget or ``max_entries``
       too small, or width bookkeeping conflicts).
    2. per block: ``device_lookup`` (counted hit/miss; host-tier hits are
       *promoted* into a slot) then ``device_adopt`` for misses, whose slot
       the engine scatters the freshly computed block into.
    3. ``end_device_sweep()`` unpins.

    Eviction policy: ``max_entries`` bounds the **total** entry count across
    both tiers with global-LRU eviction (dropped outright, counted in
    ``evictions``); the ``device_bank_mb`` byte budget bounds the device
    tier, whose slot reuse *spills* the displaced block to the host tier
    (counted in ``spills``) — a later sweep re-promotes it instead of
    recomputing.  None (the default) means unbounded entries / no device
    tier; the CV-LR scorer sizes both to the sweep working set (see
    ``CVLRScorer.DEFAULT_GRAM_CACHE_ENTRIES`` and
    ``CVLRScorer.DEFAULT_DEVICE_BANK_MB``).

    Concurrency (lock striping): two locks make a shared cache safe for
    concurrent sessions.  A *state* lock guards every LRU/counter mutation,
    so eviction/promotion/hit counts can never be lost to a race; a
    separate reentrant *dispatch* lock (``sweep_guard``) serializes whole
    device-sweep spans — ``DeviceGramBank.data`` updates are donated
    in-place writes, so two interleaved sweeps would read each other's
    consumed buffers.  The engine takes ``sweep_guard`` around each
    frontier dispatch; per-block host-tier get/put from other threads
    stays concurrent under the state lock alone.
    """

    def __init__(
        self,
        max_entries: int | None = None,
        device_bank_mb: float | None = None,
    ):
        if max_entries is not None and max_entries < 1:
            raise ValueError(f"max_entries must be >= 1 or None, got {max_entries}")
        if device_bank_mb is not None and device_bank_mb < 0:
            raise ValueError(f"device_bank_mb must be >= 0 or None, got {device_bank_mb}")
        self._store: collections.OrderedDict = collections.OrderedDict()
        self.max_entries = max_entries
        self.device_bank_mb = device_bank_mb
        self._lock = threading.RLock()  # state: LRU order + counters
        self._dispatch_lock = threading.RLock()  # whole device-sweep spans
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # device tier state
        self._banks: dict = {}  # (wa, wb) -> DeviceGramBank
        self._dev: collections.OrderedDict = collections.OrderedDict()
        # key -> (widths, slot, ea, eb); order is recency
        self._touch: dict = {}  # key -> monotonic tick (cross-tier LRU)
        self._misplaced: set = set()  # spilled keys out of dict-recency order
        self._tick = 0
        self._pinned: frozenset = frozenset()
        self._sweep_specs: dict = {}  # key -> (wa, wb, ea, eb) during a sweep
        # deferred host->device promotion queue: (wa, wb) -> list of
        # (slot, padded host row); flushed as ONE donated scatter per
        # width at every bank-read seam (see _flush_promos_locked)
        self._pending_promos: dict = {}
        self.promotions = 0
        self.promotion_uploads = 0  # scatter dispatches (<= promotions)
        self.spills = 0
        self.bank_fallbacks = 0

    # -- shared bookkeeping ----------------------------------------------
    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._store or key in self._dev

    def __len__(self) -> int:
        with self._lock:
            return len(self._store) + len(self._dev)

    def sweep_guard(self):
        """Reentrant lock serializing a full engine dispatch (device sweep
        included) over this cache.  Sessions sharing one cache take it
        around `prefetch`; a private cache pays one uncontended acquire."""
        return self._dispatch_lock

    def _touched(self, key) -> None:
        self._tick += 1
        self._touch[key] = self._tick
        # a touch always moves the key to its dict's MRU end, so it is
        # back in recency order even if a spill had misplaced it
        self._misplaced.discard(key)

    def _evict_one(self) -> bool:
        """Drop the globally least-recently-used *unpinned* entry (either
        tier).  Returns False when nothing is evictable.  The touch tick
        is the source of truth for recency: normally both dicts are
        recency-ordered and comparing their heads is O(1), but a spill
        re-inserts a key into the host dict at the tail while keeping its
        old tick — while any such misplaced key exists, fall back to a
        full tick scan so the globally oldest entry still goes first."""
        if self._misplaced:
            best = None  # (tick, tier, key)
            for tier, store in (("host", self._store), ("dev", self._dev)):
                for k in store:
                    if k in self._pinned:
                        continue
                    t = self._touch.get(k, 0)
                    if best is None or t < best[0]:
                        best = (t, tier, k)
            if best is None:
                return False
            _, tier, key = best
            host = tier == "host"
        else:
            hk = next((k for k in self._store if k not in self._pinned), None)
            dk = next((k for k in self._dev if k not in self._pinned), None)
            if hk is not None and dk is not None:
                host = self._touch.get(hk, 0) <= self._touch.get(dk, 0)
            elif hk is None and dk is None:
                return False
            else:
                host = dk is None
            key = hk if host else dk
        if host:
            del self._store[key]
        else:
            widths, slot, _, _ = self._dev.pop(key)
            self._banks[widths].free.append(slot)
        self._touch.pop(key, None)
        self._misplaced.discard(key)
        self.evictions += 1
        return True

    def _enforce_entry_bound(self) -> None:
        if self.max_entries is None:
            return
        while len(self) > self.max_entries and self._evict_one():
            pass

    # -- host-tier interface (PR-2 behavior; device-transparent reads) ----
    def get(self, key):
        """Counted lookup: returns the (host numpy) block or None.

        A device-resident block is materialized to a trimmed host array on
        the fly (one small device->host copy) so host-path consumers — the
        engine's fallback sweeps, the exact scorer — always see the same
        numpy interface regardless of where the block lives.
        """
        with self._lock:
            if key in self._store:
                value = self._store[key]
                self._store.move_to_end(key)
                self._touched(key)
                self.hits += 1
                return value
            if key in self._dev:
                widths, slot, ea, eb = self._dev[key]
                self._dev.move_to_end(key)
                self._touched(key)
                self.hits += 1
                self._flush_promos_locked(widths)  # slot may be queued
                blk = self._banks[widths].data[slot]
                return np.ascontiguousarray(np.asarray(blk)[:, :ea, :eb])
            self.misses += 1
            return None

    def put(self, key, value) -> None:
        with self._lock:
            if key in self._dev:  # host put supersedes a device entry
                widths, slot, _, _ = self._dev.pop(key)
                # a queued promotion targeting the freed slot would later
                # scatter into whoever re-adopts it: flush the width first
                self._flush_promos_locked(widths)
                self._banks[widths].free.append(slot)
            self._store[key] = value
            self._store.move_to_end(key)
            self._touched(key)
            self._enforce_entry_bound()

    def clear(self) -> None:
        with self._lock:
            self._store.clear()
            self._banks.clear()
            self._dev.clear()
            self._touch.clear()
            self._misplaced.clear()
            self._pinned = frozenset()
            self._sweep_specs = {}
            self._pending_promos = {}
            self.hits = 0
            self.misses = 0
            self.evictions = 0
            self.promotions = 0
            self.promotion_uploads = 0
            self.spills = 0
            self.bank_fallbacks = 0

    # -- device tier -------------------------------------------------------
    @property
    def device_enabled(self) -> bool:
        return bool(self.device_bank_mb) and jnp is not None

    @property
    def device_nbytes(self) -> int:
        with self._lock:
            return sum(b.nbytes for b in self._banks.values())

    def spill_device(self) -> int:
        """Degradation-ladder rung: demote every *unpinned* device entry to
        the host tier and drop the emptied bank tensors, freeing device
        bytes without losing any block.  Returns the number spilled."""
        with self._lock:
            victims = [k for k in self._dev if k not in self._pinned]
            for key in victims:
                self._spill(key)
            if not self._dev:
                self._banks.clear()
            return len(victims)

    def set_device_budget(self, device_bank_mb: float | None) -> None:
        """Degradation-ladder rung: lower (or disable) the device-tier byte
        budget.  Existing device entries above the new budget are spilled
        to host; future sweeps size themselves to the new budget."""
        with self._lock:
            self.device_bank_mb = device_bank_mb
            if not self.device_enabled:
                self.spill_device()
                return
            budget = int(float(device_bank_mb) * 2**20)
            if self.device_nbytes > budget:
                # bank tensors are per-width monoliths: reclaiming bytes
                # means emptying them, so over-budget shrink spills all
                self.spill_device()

    def bank_data(self, widths: tuple):
        """The (n_slots, q, wa, wb) device tensor for a width pair, or None.
        Queued promotions for the width flush first, so every reader sees
        the promoted blocks."""
        with self._lock:
            widths = tuple(widths)
            self._flush_promos_locked(widths)
            bank = self._banks.get(widths)
            return None if bank is None else bank.data

    def set_bank_data(self, widths: tuple, data) -> None:
        """Engine write-back after a fused compute+scatter into the bank."""
        with self._lock:
            bank = self._banks[tuple(widths)]
            assert data.shape == bank.data.shape, (data.shape, bank.data.shape)
            bank.data = data

    def _spill(self, key) -> None:
        """Move a device entry's block to the host tier (frees its slot)."""
        widths, slot, ea, eb = self._dev.pop(key)
        self._flush_promos_locked(widths)  # the block may still be queued
        bank = self._banks[widths]
        self._store[key] = np.ascontiguousarray(
            np.asarray(bank.data[slot])[:, :ea, :eb]
        )
        # recency (tick) is intentionally preserved: a spill is a demotion,
        # not a use, so the block keeps its place in the LRU order — the
        # key is marked misplaced because it now sits at the host dict's
        # tail despite its old tick (see _evict_one).
        self._misplaced.add(key)
        bank.free.append(slot)
        self.spills += 1

    def begin_device_sweep(self, specs: dict, q: int, dtype) -> bool:
        """Pin a sweep's working set and pre-arrange device capacity.

        specs: ``{key: (wa, wb, ea, eb)}`` — bucket widths and live-rank
        trims for every Gram block the sweep will touch.  On success every
        key in ``specs`` is pinned (safe from eviction until
        ``end_device_sweep``) and each width group is guaranteed enough free
        slots for its not-yet-resident keys.  Returns False (counting a
        ``bank_fallbacks``) when the working set cannot be device-resident:
        the caller must then run its host path for this sweep.
        """
        with self._lock:
            return self._begin_device_sweep_locked(specs, q, dtype)

    def _begin_device_sweep_locked(self, specs: dict, q: int, dtype) -> bool:
        if not self.device_enabled:
            return False
        if self.max_entries is not None and len(specs) > self.max_entries:
            self.bank_fallbacks += 1
            return False
        pinned = frozenset(specs)
        budget = int(float(self.device_bank_mb) * 2**20)
        dtype = np.dtype(dtype)

        by_width: dict = {}
        for key, (wa, wb, _, _) in specs.items():
            ent = self._dev.get(key)
            if ent is not None and ent[0] != (wa, wb):
                self.bank_fallbacks += 1  # width drifted for a live key
                return False
            by_width.setdefault((int(wa), int(wb)), []).append(key)

        created: list = []  # banks built for THIS sweep — rolled back on fail

        def _fail():
            # a later width group failed: drop the (still-empty) banks this
            # call created so a refused sweep leaves no zombie allocations
            # counting against future budget checks
            for w in created:
                del self._banks[w]
            self.bank_fallbacks += 1
            return False

        for widths, keys in sorted(by_width.items()):
            bank = self._banks.get(widths)
            newcomers = sum(1 for k in keys if k not in self._dev)
            if bank is None:
                want = _pow2_slots(newcomers + DeviceGramBank.RESERVED_SLOTS)
                nbytes = want * q * widths[0] * widths[1] * dtype.itemsize
                if self.device_nbytes + nbytes > budget:
                    return _fail()
                self._banks[widths] = DeviceGramBank(widths, q, dtype, want)
                created.append(widths)
                continue
            if bank.q != q or bank.dtype != dtype:
                return _fail()
            if len(bank.free) >= newcomers:
                continue
            # grow within budget first (pow2 slot counts bound jit variants)
            occupied = bank.n_slots - len(bank.free)
            want = _pow2_slots(occupied + newcomers)
            growth = (want - bank.n_slots) * bank.slot_nbytes
            if growth > 0 and self.device_nbytes + growth <= budget:
                bank.grow_to(want)
            # then reuse LRU unpinned slots of this bank (spill to host)
            while len(bank.free) < newcomers:
                victim = next(
                    (
                        k
                        for k, ent in self._dev.items()
                        if ent[0] == widths and k not in pinned
                    ),
                    None,
                )
                if victim is None:
                    return _fail()
                self._spill(victim)
        self._pinned = pinned
        self._sweep_specs = dict(specs)
        return True

    def end_device_sweep(self) -> None:
        with self._lock:
            self._flush_promos_locked()  # commit every queued promotion
            self._pinned = frozenset()
            self._sweep_specs = {}
            self._enforce_entry_bound()

    def device_lookup(self, key):
        """Counted device lookup during a sweep: returns the key's slot (a
        host-tier hit is promoted into a fresh slot first), or None on miss
        — the caller computes the block and ``device_adopt``s it.

        Promotions are DEFERRED: the padded row is queued per bucket width
        and the whole batch uploads as one donated scatter at the next
        bank-read seam (``bank_data`` / ``get`` / ``_spill`` /
        ``end_device_sweep``) — one dispatch per width per sweep instead
        of one per block.  ``promotions`` keeps block-count semantics;
        ``promotion_uploads`` counts the actual scatter dispatches."""
        with self._lock:
            ent = self._dev.get(key)
            if ent is not None:
                self._dev.move_to_end(key)
                self._touched(key)
                self.hits += 1
                return ent[1]
            if key in self._store:
                self.hits += 1
                blk = self._store.pop(key)
                wa, wb, ea, eb = self._sweep_specs[key]
                slot = self._adopt(key, wa, wb, ea, eb)
                bank = self._banks[(wa, wb)]
                row = np.zeros((bank.q, wa, wb), bank.dtype)
                row[:, : blk.shape[1], : blk.shape[2]] = blk
                pending = self._pending_promos.setdefault((wa, wb), [])
                if any(s == slot for s, _ in pending):
                    # a freed-and-readopted slot with a stale queued row:
                    # flush so scatter order can never interleave slots
                    self._flush_promos_locked((wa, wb))
                    pending = self._pending_promos.setdefault((wa, wb), [])
                pending.append((slot, row))
                self.promotions += 1
                return slot
            self.misses += 1
            return None

    def _flush_promos_locked(self, widths=None) -> None:
        """Apply queued host->device promotions — one donated pow2-padded
        scatter per bucket width (padding rows target the write-only
        SCRATCH_SLOT so row counts stay jit-shape-stable).  ``widths``
        limits the flush to one width pair; None flushes everything.
        Caller holds the state lock."""
        if not self._pending_promos:
            return
        targets = (
            [widths] if widths is not None else list(self._pending_promos)
        )
        for w in targets:
            pending = self._pending_promos.pop(w, None)
            if not pending:
                continue
            bank = self._banks.get(w)
            if bank is None:
                continue  # width dropped wholesale (clear/spill_device)
            pad = _pow2_slots(len(pending)) - len(pending)
            slots = np.asarray(
                [s for s, _ in pending]
                + [DeviceGramBank.SCRATCH_SLOT] * pad,
                np.int32,
            )
            rows = np.stack(
                [r for _, r in pending]
                + [np.zeros_like(pending[0][1])] * pad
            )
            bank.data = _bank_set_rows(
                bank.data, jnp.asarray(slots), jnp.asarray(rows)
            )
            self.promotion_uploads += 1

    def device_adopt(self, key) -> int:
        """Assign a slot to a freshly computed block (capacity was arranged
        by ``begin_device_sweep``); the engine scatters the block into the
        bank tensor itself (fused with the Gram kernel when possible)."""
        with self._lock:
            wa, wb, ea, eb = self._sweep_specs[key]
            return self._adopt(key, wa, wb, ea, eb)

    def _adopt(self, key, wa, wb, ea, eb) -> int:
        bank = self._banks[(wa, wb)]
        assert bank.free, (key, (wa, wb))  # begin_device_sweep guarantees
        slot = bank.free.pop()
        self._dev[key] = ((wa, wb), slot, int(ea), int(eb))
        self._touched(key)
        self._enforce_entry_bound()
        return slot

    @property
    def stats(self) -> dict:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "entries": len(self),
                "max_entries": self.max_entries,
                "device_entries": len(self._dev),
                "device_bytes": self.device_nbytes,
                "device_bank_mb": self.device_bank_mb,
                "promotions": self.promotions,
                "promotion_uploads": self.promotion_uploads,
                "spills": self.spills,
                "bank_fallbacks": self.bank_fallbacks,
            }


def _pow2_slots(k: int) -> int:
    """Next power of two >= max(k, 4): slot counts stay shape-stable so
    bank growth produces few distinct gather-jit variants."""
    p = 4
    while p < k:
        p *= 2
    return p


@dataclasses.dataclass(frozen=True)
class ScoreConfig:
    """Paper defaults (Sec. 7.1 / Appendix A.2)."""

    lmbda: float = 0.01  # ridge regularizer lambda
    gamma: float = 0.01  # covariance jitter gamma  (beta = lmbda^2/gamma)
    q_folds: int = 10  # 10-fold cross-validated likelihood
    m_max: int = 100  # maximal rank / pivot budget (paper Sec. 7.2)
    eta: float = 1e-6  # ICL precision parameter
    width_factor: float = 2.0  # "2x median distance" kernel width
    seed: int = 0

    @property
    def beta(self) -> float:
        return self.lmbda * self.lmbda / self.gamma


def fold_layout(n: int, q: int, seed: int):
    """Returns (perm, n_eff, n0, n1, train_idx (q, n1)).

    perm: permutation applied to the data rows once at scorer build time.
    After permutation, fold i tests rows [i*n0, (i+1)*n0).
    """
    if n < 2 * q:
        raise ValueError(f"need n >= 2*Q samples, got n={n}, Q={q}")
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    n0 = n // q
    n_eff = n0 * q
    n1 = n_eff - n0
    all_idx = np.arange(n_eff)
    train_idx = np.stack(
        [np.delete(all_idx, np.arange(i * n0, (i + 1) * n0)) for i in range(q)]
    )
    return perm[:n_eff], n_eff, n0, n1, train_idx


class VariableView:
    """Column-slice view of a (n, total_cols) data matrix into variables.

    Supports multi-dimensional variables (paper Sec. 7.4) via `dims`:
    variable i owns columns [offsets[i], offsets[i]+dims[i]).

    Since PR 4 every view is backed by a `repro.core.spec.DataSpec` —
    pass one as `spec`, or the legacy `dims`/`discrete` lists are
    absorbed into one (`DataSpec.from_arrays`).  The spec validates the
    matrix once, up front (column coverage, finiteness), with error
    messages that name the offending variable.
    """

    def __init__(self, data: np.ndarray, dims=None, discrete=None, spec=None):
        from repro.core.spec import resolve_spec

        self.spec = resolve_spec(data, spec=spec, dims=dims, discrete=discrete)
        self.data = self.spec.validate(data)
        self.dims = self.spec.dims
        self.offsets = np.concatenate([[0], np.cumsum(self.dims)]).astype(int)
        self.num_vars = self.spec.num_vars
        self.discrete = self.spec.discrete

    def columns(self, vars_idx) -> np.ndarray:
        """Concatenate columns of the given variables (sorted order)."""
        if isinstance(vars_idx, (int, np.integer)):
            vars_idx = (int(vars_idx),)
        cols = [
            self.data[:, self.offsets[v] : self.offsets[v + 1]]
            for v in sorted(int(v) for v in vars_idx)
        ]
        return np.concatenate(cols, axis=1)

    def is_discrete(self, vars_idx) -> bool:
        if isinstance(vars_idx, (int, np.integer)):
            vars_idx = (int(vars_idx),)
        return all(self.discrete[int(v)] for v in vars_idx)


class ScorerBase:
    """Decomposable local-score interface shared by CV and CV-LR.

    The (node, parents) -> score memo (`_score_cache`) is an ordered dict
    so it can optionally run as an LRU: `score_memo_max` (None = unbounded,
    the historical behavior; `EngineOptions.score_memo_entries` threads it
    in) bounds the entry count, evicting least-recently-scored
    configurations.  Eviction is always *safe* — a local score is a pure
    function of its configuration, so an evicted entry just recomputes on
    the next lookup — but it trades memory for re-dispatch time, so the
    memo's size and cumulative evictions are exposed (`cache_size` /
    `score_memo_evictions`) and surfaced in the session's per-sweep log.
    """

    def __init__(self, view: VariableView, config: ScoreConfig):
        self.view = view
        self.config = config
        perm, n_eff, n0, n1, train_idx = fold_layout(
            view.data.shape[0], config.q_folds, config.seed
        )
        self.perm = perm
        self.n_eff, self.n0, self.n1 = n_eff, n0, n1
        self.train_idx = train_idx
        self._score_cache: collections.OrderedDict = collections.OrderedDict()
        self.score_memo_max: int | None = None
        self.score_memo_evictions = 0

    def _memo_put(self, key, val: float) -> None:
        """Single write point for the score memo: insert + LRU bound."""
        self._score_cache[key] = val
        cap = self.score_memo_max
        if cap is not None:
            while len(self._score_cache) > cap:
                self._score_cache.popitem(last=False)
                self.score_memo_evictions += 1

    # -- public API ------------------------------------------------------
    def local_score(self, i: int, parents=()) -> float:
        key = config_key(i, parents)
        cached = self._score_cache.get(key)
        if cached is None:
            self._memo_put(key, float(self._compute(key[0], key[1])))
            return self._score_cache[key]
        if self.score_memo_max is not None:
            # recency only matters when the memo is bounded; the unbounded
            # (default) path skips the per-lookup reorder
            self._score_cache.move_to_end(key)
        return cached

    def prefetch(self, configs, small_batch: bool = False) -> int:
        """Batch-evaluate ``(node, parents)`` configurations ahead of the
        `local_score` lookups of a GES sweep.  Returns the number of scores
        actually computed.  The base implementation is lazy (0 computed;
        `local_score` falls back to per-candidate evaluation) — batched
        scorers override this with a single-dispatch engine.  `small_batch`
        marks the dispatch small-batch-eligible (a warm incremental
        sweep's delta); scorers without a fast path ignore it.
        """
        return 0

    def score_graph(self, adj: np.ndarray) -> float:
        """S(G) = sum_i S(X_i, Pa_i) — decomposability (paper Eq. 31)."""
        d = adj.shape[0]
        return float(
            sum(self.local_score(i, tuple(np.flatnonzero(adj[:, i]))) for i in range(d))
        )

    @property
    def cache_size(self) -> int:
        return len(self._score_cache)

    def _compute(self, i: int, parents: tuple) -> float:  # pragma: no cover
        raise NotImplementedError
