"""Survivable discovery runs: RunState checkpointing + FaultPlan injection.

GES is a deterministic replayable search: candidate enumeration is a pure
function of the current CPDAG, fold layouts and feature builds are seeded,
and every applied Insert/Delete is logged.  That makes sweep-granular
checkpoint/resume exact — restoring the CPDAG, phase, and applied-step
log after sweep k and re-entering the search reproduces the uninterrupted
run's remaining sweeps bit-for-bit (same frontiers, same scores, same
argmax).  `RunState` is the object that crosses the crash:

* ``cpdag`` — the (d, d) int8 adjacency after the last completed sweep;
* ``phase`` / ``sweep`` — where the search is (``"forward"`` /
  ``"backward"`` / ``"done"``; sweep == completed-sweep count);
* ``forward_steps`` / ``backward_steps`` / ``trace`` — the applied-step
  log (op, x, y, subset, delta), so a resumed run's final trace equals
  the uninterrupted one;
* ``sweep_log`` — the session's per-sweep telemetry as recorded so far;
* ``bank_meta`` — FeatureBank *metadata* (variable-set keys + build
  fingerprints).  Factors themselves are cheap to rebuild and device
  state cannot be trusted across a crash, so resume re-admits factors by
  re-verifying each recorded fingerprint against the new scorer's policy
  instead of restoring arrays;
* ``degradations`` — cumulative numerical-degradation counters;
* ``score_memo`` / ``frontier`` / ``score_fp`` — the incremental
  frontier-delta engine's warm state: the scorer's local-score memo, the
  last sweep's config keys, and a fingerprint guarding both (a resumed
  session with a different data/config/policy fingerprint drops them and
  runs cold — correctness never depends on the warm state, only speed);
* ``skeleton`` / ``skeleton_fp`` — a ``restrict="skeleton"`` session's
  estimated `repro.constraint.EdgeMask` (0/1 rows) plus the fingerprint
  of everything it depends on; a matching resume reuses the mask and
  skips the constraint phase (re-estimating would give the same mask —
  the CI tests are deterministic — this just skips the cost).

Serialization rides the existing atomic checkpoint store
(`repro.checkpoint.store.save_checkpoint` / `AsyncCheckpointer`): the
state becomes a two-leaf pytree — the int8 CPDAG plus a uint8 JSON
payload — so the commit inherits the tmp+fsync+rename atomicity and the
idempotent same-step re-save.  `load_latest_runstate` walks committed
steps newest-first and falls back past a corrupted checkpoint.

`FaultPlan` is the injection side: deterministic, declarative failures
(kill the session at sweep s; kill shard k from sweep s by raise or
hang; corrupt the checkpoint written at sweep s; force NaN scores into a
sweep; force degradation-ladder rungs to fail) threaded through
`repro.core.api.DiscoverySession` and the sharded runner so every
recovery path is exercisable in CI without monkeypatching internals.
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.checkpoint.store import list_steps, save_checkpoint


class InjectedFault(RuntimeError):
    """Raised by FaultPlan injection points (a simulated crash)."""


class DeadlineExceeded(RuntimeError):
    """A session ran past its per-request deadline and was shed at a sweep
    seam (`begin_sweep` / `score_frontier` / `end_sweep`).  Structured:
    `to_dict()` is what a serving layer returns to the tenant."""

    def __init__(self, tenant, sweep, elapsed_s, deadline_s, retry_after_s=None):
        self.tenant = tenant
        self.sweep = sweep
        self.elapsed_s = float(elapsed_s)
        self.deadline_s = float(deadline_s)
        self.retry_after_s = retry_after_s
        super().__init__(
            f"deadline exceeded for tenant {tenant!r} at sweep {sweep}: "
            f"{self.elapsed_s:.3f}s elapsed > {self.deadline_s:.3f}s budget"
        )

    def to_dict(self) -> dict:
        return {
            "error": "deadline_exceeded",
            "tenant": self.tenant,
            "sweep": self.sweep,
            "elapsed_s": round(self.elapsed_s, 4),
            "deadline_s": self.deadline_s,
            "retry_after_s": self.retry_after_s,
        }


class SessionCancelled(RuntimeError):
    """A session's cancel token fired (mid-request kill / manager
    shutdown); raised at the next sweep seam."""

    def __init__(self, tenant, sweep):
        self.tenant = tenant
        self.sweep = sweep
        super().__init__(
            f"session cancelled for tenant {tenant!r} at sweep {sweep}"
        )

    def to_dict(self) -> dict:
        return {"error": "cancelled", "tenant": self.tenant, "sweep": self.sweep}


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Declarative fault injection for tests and recovery benchmarks.

    kill_at_sweep: raise `InjectedFault` from the session's `begin_sweep`
      when the global sweep counter reaches this value — a preemption at
      a sweep boundary.
    kill_shard: ``(worker, sweep)`` — from sweep `sweep` on, shard
      `worker` of the sharded runner fails every attempt.
    shard_fault: how the killed shard fails — ``"raise"`` (worker raises
      immediately: a crashed process) or ``"hang"`` (worker sleeps
      `shard_hang_s` then raises: a straggler that trips the per-shard
      timeout + heartbeat path).
    corrupt_checkpoint: after the checkpoint for this completed-sweep
      count commits, overwrite its arrays file with garbage — resume must
      fall back to the previous committed step.
    nan_scores: ``(sweep, count)`` — poison the first `count` frontier
      scores of sweep `sweep` with NaN before they reach the cache,
      driving the numerical degradation ladder.
    fail_rungs: pretend the first `fail_rungs` rungs of the degradation
      ladder (jittered retry, f64 re-solve) also fail, so tests can force
      escalation all the way to the exact-score fallback.

    Concurrent-serving faults (multi-tenant injection, PR 7):

    stall_sweep: ``(sweep, seconds)`` — the session sleeps that long in
      `begin_sweep` when the sweep counter matches: a slow/stalled tenant
      that should trip its deadline (and must not corrupt anyone else).
    build_delay_s: stretch every feature build by this many seconds — a
      bank-contention storm widener, forcing concurrent tenants onto the
      FeatureBank's single-flight build path.
    evict_storm: an adversarial tenant that spills the (possibly shared)
      Gram cache's entire device tier at every one of its sweep starts —
      eviction racing a competing session's sweep; competitors must
      re-promote/recompute and stay bitwise-correct.
    """

    kill_at_sweep: int | None = None
    kill_shard: tuple | None = None
    shard_fault: str = "raise"
    shard_hang_s: float = 1.0
    corrupt_checkpoint: int | None = None
    nan_scores: tuple | None = None
    fail_rungs: int = 0
    stall_sweep: tuple | None = None
    build_delay_s: float = 0.0
    evict_storm: bool = False

    def __post_init__(self):
        if self.shard_fault not in ("raise", "hang"):
            raise ValueError(
                f'shard_fault must be "raise" or "hang", got {self.shard_fault!r}'
            )
        if self.kill_shard is not None:
            w, s = self.kill_shard
            object.__setattr__(self, "kill_shard", (int(w), int(s)))
        if self.nan_scores is not None:
            s, c = self.nan_scores
            object.__setattr__(self, "nan_scores", (int(s), int(c)))
        if self.stall_sweep is not None:
            s, sec = self.stall_sweep
            object.__setattr__(self, "stall_sweep", (int(s), float(sec)))
        if self.build_delay_s < 0:
            raise ValueError(
                f"build_delay_s must be >= 0, got {self.build_delay_s!r}"
            )

    # -- injection predicates (all no-ops on a default plan) --------------
    def should_kill(self, sweep: int) -> bool:
        return self.kill_at_sweep is not None and sweep == self.kill_at_sweep

    def stall_seconds(self, sweep: int) -> float:
        """Seconds to stall at this sweep's `begin_sweep` (0.0 = none)."""
        if self.stall_sweep is None:
            return 0.0
        s, sec = self.stall_sweep
        return sec if int(sweep) == s else 0.0

    def shard_faulted(self, worker: int, sweep) -> bool:
        """Persistent from the kill sweep on: a dead worker stays dead."""
        if self.kill_shard is None or sweep is None:
            return False
        w, s = self.kill_shard
        return worker == w and int(sweep) >= s

    def corrupt_scores(self, scores: np.ndarray, sweep) -> np.ndarray:
        """Poison the sweep's first `count` scores with NaN (copy)."""
        if self.nan_scores is None or sweep is None:
            return scores
        s, count = self.nan_scores
        if int(sweep) != s or count <= 0:
            return scores
        out = np.array(scores, dtype=np.float64, copy=True)
        out[: min(count, out.shape[0])] = np.nan
        return out

    def maybe_corrupt_checkpoint(self, directory: str, step: int) -> bool:
        if self.corrupt_checkpoint is None or step != self.corrupt_checkpoint:
            return False
        corrupt_checkpoint_file(directory, step)
        return True


def corrupt_checkpoint_file(directory: str, step: int) -> str:
    """Overwrite a committed checkpoint's arrays file with garbage —
    the FaultPlan's simulated disk corruption.  Returns the path."""
    path = os.path.join(directory, f"step_{step:010d}", "arrays.npz")
    with open(path, "wb") as f:
        f.write(b"\x00corrupted-by-faultplan")
    return path


def _norm_step(step):
    """Canonical plain-python form of a GES trace step — identical whether
    it came straight from the search or through a JSON round-trip."""
    if step is None:
        return None
    op, x, y, sub, delta = step
    return (str(op), int(x), int(y), tuple(int(v) for v in sub), float(delta))


def _norm_sweep_rec(rec: dict) -> dict:
    rec = dict(rec)
    if "step" in rec:
        rec["step"] = _norm_step(rec["step"])
    return rec


@dataclasses.dataclass
class RunState:
    """Everything a discovery run needs to cross a crash (module doc)."""

    cpdag: np.ndarray
    phase: str = "forward"
    sweep: int = 0
    forward_steps: int = 0
    backward_steps: int = 0
    trace: list = dataclasses.field(default_factory=list)
    sweep_log: list = dataclasses.field(default_factory=list)
    bank_meta: list = dataclasses.field(default_factory=list)
    degradations: dict = dataclasses.field(default_factory=dict)
    # Warm-resume state for the incremental frontier-delta engine (all
    # optional — absent in pre-PR-8 checkpoints, restored via `.get()`
    # defaults so the "repro.runstate.v1" format id is unchanged):
    # score_memo: the scorer's local-score memo as [node, [parents], score]
    # rows in LRU order; frontier: the last completed sweep's config keys
    # as [node, [parents]] rows (None = no sweep completed / not
    # incremental); score_fp: fingerprint of everything the memo'd scores
    # depend on (data, config, policy, method) — a resume whose session
    # fingerprint differs silently drops both and runs cold.
    score_memo: list = dataclasses.field(default_factory=list)
    frontier: list | None = None
    score_fp: str | None = None
    # Constraint-phase state (restrict="skeleton" sessions; optional like
    # the warm state above): skeleton is the EdgeMask's allowed matrix as
    # 0/1 rows, skeleton_fp fingerprints everything the estimate depends
    # on (score_fp + ci_alpha + ci_max_cond) — a matching resume reuses
    # the persisted mask and skips re-estimation entirely.
    skeleton: list | None = None
    skeleton_fp: str | None = None

    @classmethod
    def fresh(cls, d: int) -> "RunState":
        return cls(cpdag=np.zeros((int(d), int(d)), dtype=np.int8))

    # -- serialization ----------------------------------------------------
    def to_tree(self) -> dict:
        """Two-leaf pytree for the atomic checkpoint store: the int8
        CPDAG plus a uint8 JSON payload.  Fresh arrays every call, so an
        async writer can serialize while the live state keeps mutating."""
        payload = {
            "format": "repro.runstate.v1",
            "phase": self.phase,
            "sweep": int(self.sweep),
            "forward_steps": int(self.forward_steps),
            "backward_steps": int(self.backward_steps),
            "trace": [list(s[:3]) + [list(s[3]), s[4]] for s in self.trace],
            "sweep_log": self.sweep_log,
            "bank_meta": self.bank_meta,
            "degradations": self.degradations,
            "score_memo": self.score_memo,
            "frontier": self.frontier,
            "score_fp": self.score_fp,
            "skeleton": self.skeleton,
            "skeleton_fp": self.skeleton_fp,
        }
        raw = np.frombuffer(
            json.dumps(payload).encode("utf-8"), dtype=np.uint8
        ).copy()
        return {
            "cpdag": np.asarray(self.cpdag, dtype=np.int8).copy(),
            "payload": raw,
        }

    @classmethod
    def from_tree(cls, cpdag: np.ndarray, payload_bytes: np.ndarray) -> "RunState":
        payload = json.loads(bytes(payload_bytes).decode("utf-8"))
        if payload.get("format") != "repro.runstate.v1":
            raise ValueError(
                f"not a RunState checkpoint payload: {payload.get('format')!r}"
            )
        trace = [
            _norm_step((op, x, y, sub, delta))
            for op, x, y, sub, delta in payload["trace"]
        ]
        return cls(
            cpdag=np.asarray(cpdag, dtype=np.int8).copy(),
            phase=str(payload["phase"]),
            sweep=int(payload["sweep"]),
            forward_steps=int(payload["forward_steps"]),
            backward_steps=int(payload["backward_steps"]),
            trace=trace,
            sweep_log=[_norm_sweep_rec(r) for r in payload["sweep_log"]],
            bank_meta=[list(e) for e in payload["bank_meta"]],
            degradations=dict(payload["degradations"]),
            score_memo=[
                [int(n), [int(p) for p in ps], float(v)]
                for n, ps, v in payload.get("score_memo", [])
            ],
            frontier=(
                [[int(n), [int(p) for p in ps]] for n, ps in payload["frontier"]]
                if payload.get("frontier") is not None
                else None
            ),
            score_fp=payload.get("score_fp"),
            skeleton=(
                [[int(v) for v in row] for row in payload["skeleton"]]
                if payload.get("skeleton") is not None
                else None
            ),
            skeleton_fp=payload.get("skeleton_fp"),
        )

    def save(self, directory: str, step: int) -> str:
        """Synchronous atomic commit (the async path goes through
        `AsyncCheckpointer.save(step, state.to_tree())`)."""
        return save_checkpoint(directory, step, self.to_tree())


def load_runstate(directory: str, step: int) -> RunState:
    """Load one committed step; raises on a missing/corrupt checkpoint."""
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest.get("num_arrays") != 2:
        raise ValueError(
            f"step {step}: expected the 2-leaf RunState tree, manifest says "
            f"{manifest.get('num_arrays')} arrays"
        )
    with np.load(os.path.join(path, "arrays.npz")) as data:
        # jax.tree flattens dicts in sorted-key order: "cpdag" < "payload"
        cpdag, payload = data["a0"], data["a1"]
    if cpdag.ndim != 2 or payload.ndim != 1:
        raise ValueError(f"step {step}: unexpected RunState array shapes")
    return RunState.from_tree(cpdag, payload)


def load_latest_runstate(directory: str):
    """Newest loadable (step, RunState), falling back past corrupted
    checkpoints; None when no committed step loads."""
    for step in sorted(list_steps(directory), reverse=True):
        try:
            return step, load_runstate(directory, step)
        except Exception:
            continue  # corrupted/foreign step: fall back to the previous
    return None
