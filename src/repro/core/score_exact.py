"""Exact cross-validated likelihood score ("CV", Huang et al. 2018; paper
Eq. 8/9).  O(n^3) time, O(n^2) memory — the paper's baseline and our
correctness oracle.

One unified code path: the empty-conditioning-set case (Eq. 9) is Eq. 8
specialized to K_Z = 0 (see DESIGN.md §1 for the Eq. 9 typo note), so we
simply pass a zero K_Z.  Folds run under `lax.map` (sequential) to bound
memory at one (n1, n1) working set.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernel_fns import (
    KernelSpec,
    center_gram,
    kernel_matrix,
    median_heuristic_width,
    standardize,
)
from repro.core.score_common import (
    GramBlockCache,
    ScoreConfig,
    ScorerBase,
    VariableView,
    set_key,
)


def _fold_score(kx, kz, tr, te, n0, n1, lmbda, gamma):
    """Eq. 8 on one fold. kx, kz: centered full (n_eff, n_eff) kernels."""
    beta = lmbda * lmbda / gamma
    KX1 = kx[tr][:, tr]
    KX0 = kx[te][:, te]
    KX01 = kx[te][:, tr]
    KZ1 = kz[tr][:, tr]
    KZ01 = kz[te][:, tr]

    eye1 = jnp.eye(n1, dtype=kx.dtype)
    reg = KZ1 + (n1 * lmbda) * eye1
    A = jnp.linalg.solve(reg, eye1)  # (K~1_Z + n1 lambda I)^-1
    B = A @ KX1 @ A
    Qm = eye1 + (n1 * beta) * B
    sign, logdet_q = jnp.linalg.slogdet(Qm)
    Qinv = jnp.linalg.solve(Qm, eye1)
    C = A @ Qinv @ A

    AKZ10 = A @ KZ01.T
    CKX10 = C @ KX01.T
    t1 = jnp.trace(KX0)
    t2 = jnp.trace(KZ01 @ B @ KZ01.T)
    t3 = jnp.trace(KX01 @ AKZ10)
    t4 = jnp.trace(KX01 @ CKX10)
    t5 = jnp.trace((KZ01 @ A @ KX1) @ C @ (KX1 @ AKZ10))
    t6 = jnp.trace(KX01 @ C @ KX1 @ AKZ10)
    trace_total = t1 + t2 - 2.0 * t3 - (n1 * beta) * (t4 + t5) + 2.0 * (n1 * beta) * t6

    return (
        -0.5 * n0 * n0 * jnp.log(2.0 * jnp.pi)
        - 0.5 * n0 * logdet_q
        - 0.5 * n0 * n1 * jnp.log(gamma)
        - trace_total / (2.0 * gamma)
    )


@partial(jax.jit, static_argnames=("n0", "n1", "q"))
def cv_score_from_kernels(kx, kz, train_idx, n0: int, n1: int, q: int, lmbda, gamma):
    """Mean Eq.-8 score over Q folds given centered kernel matrices."""
    n_eff = q * n0

    def per_fold(args):
        fold, tr = args
        te = fold * n0 + jnp.arange(n0)
        return _fold_score(kx, kz, tr, te, n0, n1, lmbda, gamma)

    scores = jax.lax.map(per_fold, (jnp.arange(q), train_idx))
    del n_eff
    return jnp.mean(scores)


class CVScorer(ScorerBase):
    """Exact CV likelihood local score (the paper's baseline).

    Takes the same `repro.core.spec.DataSpec` frontend as the low-rank
    scorer (`spec=` supersedes the legacy `dims`/`discrete` lists).  The
    engine knobs of `repro.core.spec.EngineOptions` do not apply here —
    this scorer is always lazy/sequential, O(n^3) per local score.
    """

    def __init__(
        self,
        data,
        dims=None,
        discrete=None,
        config: ScoreConfig | None = None,
        spec=None,
    ):
        config = config or ScoreConfig()
        super().__init__(VariableView(data, dims, discrete, spec=spec), config)
        # Same keyed-cache interface as the low-rank scorer's Gram-block
        # cache: (set_key, set_key)-keyed with hit/miss accounting.  An
        # (n, n) centered kernel is the m -> n degenerate Gram block.
        self.kernel_cache = GramBlockCache()

    def _centered_kernel(self, vars_key: tuple) -> jnp.ndarray:
        key = set_key(vars_key)
        k = self.kernel_cache.get((key, key))
        if k is None:
            cols = standardize(self.view.columns(key))[self.perm]
            width = median_heuristic_width(cols, factor=self.config.width_factor)
            k = center_gram(kernel_matrix(cols, cols, KernelSpec("rbf", width)))
            self.kernel_cache.put((key, key), k)
        return k

    def _compute(self, i: int, parents: tuple) -> float:
        kx = self._centered_kernel((i,))
        if parents:
            kz = self._centered_kernel(tuple(parents))
        else:
            kz = jnp.zeros_like(kx)  # Eq. 9 == Eq. 8 with K_Z = 0
        return float(
            cv_score_from_kernels(
                kx,
                kz,
                jnp.asarray(self.train_idx),
                self.n0,
                self.n1,
                self.config.q_folds,
                jnp.asarray(self.config.lmbda, kx.dtype),
                jnp.asarray(self.config.gamma, kx.dtype),
            )
        )
