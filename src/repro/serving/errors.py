"""Structured serving errors.

Every way a request can fail without a tenant bug maps to one exception
type carrying a `to_dict()` payload — the admission controller never
wedges a queue on a misbehaving request, it *rejects with structure*:

* `RequestShed` — admission refused (queue full / manager shutting
  down); carries ``retry_after_s``, the controller's backoff hint.
* `DeadlineExceeded` (from `repro.core.runstate`) — the per-request
  wall-clock budget ran out; raised at a sweep seam, or by the admission
  controller for requests whose deadline passed while queued.
* `SessionCancelled` (from `repro.core.runstate`) — the request's cancel
  token fired (client abandon / mid-request kill / manager shutdown).

`structured_error` normalizes any of them (plus `InjectedFault` and
unexpected exceptions) to the wire-shaped dict `launch/serve.py` prints.
"""

from __future__ import annotations

from repro.core.runstate import (  # noqa: F401  (re-exported)
    DeadlineExceeded,
    InjectedFault,
    SessionCancelled,
)


class RequestShed(RuntimeError):
    """Admission refused: the bounded queue is full (or the manager is
    shutting down).  The request never ran; retry after `retry_after_s`."""

    def __init__(self, tenant, reason: str, retry_after_s: float):
        self.tenant = tenant
        self.reason = reason
        self.retry_after_s = float(retry_after_s)
        super().__init__(
            f"request from tenant {tenant!r} shed ({reason}); "
            f"retry after {self.retry_after_s:.2f}s"
        )

    def to_dict(self) -> dict:
        return {
            "error": "shed",
            "tenant": self.tenant,
            "reason": self.reason,
            "retry_after_s": round(self.retry_after_s, 3),
        }


def structured_error(exc: BaseException) -> dict:
    """The wire-shaped error payload for any request failure."""
    to_dict = getattr(exc, "to_dict", None)
    if callable(to_dict):
        return to_dict()
    if isinstance(exc, InjectedFault):
        return {"error": "injected_fault", "detail": str(exc)}
    return {"error": "internal", "type": type(exc).__name__, "detail": str(exc)}
