"""Multi-tenant discovery serving (`repro.serving`).

`SessionManager` admits concurrent `DiscoverySession`s over one shared
`FeatureBank` / per-workload `GramBlockCache`; `manager.py` has the
architecture, `errors.py` the structured failure vocabulary.
"""

from repro.serving.errors import (
    DeadlineExceeded,
    InjectedFault,
    RequestShed,
    SessionCancelled,
    structured_error,
)
from repro.serving.manager import (
    DiscoveryRequest,
    ServingOptions,
    SessionManager,
    SessionTicket,
)

__all__ = [
    "DeadlineExceeded",
    "DiscoveryRequest",
    "InjectedFault",
    "RequestShed",
    "ServingOptions",
    "SessionCancelled",
    "SessionManager",
    "SessionTicket",
    "structured_error",
]
