"""Multi-tenant discovery serving: admission control over shared banks.

The paper's O(n) score makes one discovery run cheap; the serving
problem is surviving *many concurrent runs over shared state*.  The
`SessionManager` owns one dataset and admits concurrent
`repro.core.api.DiscoverySession`s over:

* one process-wide `repro.features.bank.FeatureBank` — safe because the
  bank's keys carry each factor's full build fingerprint and its builds
  are single-flight deduplicated (two tenants requesting the same factor
  trigger exactly one build; see `repro.features.bank`);
* one `repro.core.score_common.GramBlockCache` **per workload
  fingerprint** — Gram-block keys carry no config identity, so only
  sessions whose (score config, feature policy, precision) coincide may
  share a cache; the manager keys a registry on exactly that fingerprint
  (per-request ``seed`` overrides land in the fingerprint, giving
  per-session PRNG isolation for free).  Device sweeps over a shared
  cache serialize through the cache's ``sweep_guard`` (donated
  device-bank writes must never interleave).

**Admission** (`submit`): a bounded queue in front of a fixed worker
pool.  A request past ``queue_limit`` is *shed* with a structured
`RequestShed` carrying a retry-after estimate (EMA of completed-run
latency scaled by queue depth) instead of wedging the queue.  Deadlines
start at submission: the session checks them at every sweep seam
(`begin_sweep` / `score_frontier` / `end_sweep`) and raises a structured
`DeadlineExceeded` — a request whose deadline passed while queued sheds
at its first seam before any scoring.  Cancellation (`SessionTicket.
cancel`) flips a per-request event checked at the same seam.

**Memory-pressure degradation ladder** (mirrors the numerical ladder of
PR 6): when ``device_budget_mb`` is set, admission measures the shared
footprint (feature-bank factor bytes + Gram-cache device bytes) and
escalates new sessions through three rungs — (1) *shrink*: halve the
session's ``device_bank_mb`` and lower the shared cache's budget;
(2) *evict-to-host*: spill every device-tier Gram block and run the
session on the host path; (3) *reroute*: route new factor builds to the
cheapest backend (`FeaturePolicy(continuous="rff")`).  Each session's
sweep log records the rung counters under ``"serving"``.

**Fault isolation**: a tenant's `repro.core.runstate.FaultPlan` rides
only its own session.  A stalled tenant trips its own deadline; a
mid-request kill raises its own `InjectedFault`; a bank-contention storm
(``build_delay_s``) only widens the single-flight window; an eviction
storm (``evict_storm``) only forces competitors to re-promote — every
surviving tenant's CPDAG and scores stay bitwise-equal to a solo run
(tests/test_serving.py is the proof).
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor

from repro.core.api import DiscoverySession
from repro.core.score_common import GramBlockCache, ScoreConfig
from repro.core.spec import OBS_MODES, DataSpec, EngineOptions, resolve_spec
from repro.features.bank import FeatureBank
from repro.features.policy import FeaturePolicy
from repro.obs import MetricsRegistry, prometheus_text
from repro.serving.errors import RequestShed, structured_error


@dataclasses.dataclass(frozen=True)
class ServingOptions:
    """Admission-controller shape: pool size, queue bound, deadlines,
    shedding backoff, and the memory-pressure ladder's budget.

    max_concurrent: sessions running at once (worker-pool width).
    queue_limit: admitted-but-not-started requests beyond which
      `SessionManager.submit` sheds with `RequestShed`.
    default_deadline_s: per-request deadline when the request carries
      none (None = no deadline).
    retry_after_s: floor for the shed response's retry-after hint; the
      controller scales it by queue depth x observed mean latency.
    device_budget_mb: shared-footprint budget (feature-bank factor bytes
      + Gram-cache device bytes) driving the degradation ladder; None
      disables the ladder.
    min_device_bank_mb: rung-1 shrink floor for a session's device tier.
    checkpoint_root: directory namespace for per-tenant checkpointing —
      a request with ``checkpoint=True`` gets
      ``checkpoint_root/<tenant>`` as its isolated checkpoint_dir.
    obs: serving-level observability mode (see
      `repro.core.spec.EngineOptions`); when not ``"off"`` it overrides
      every admitted session's ``obs``/``trace_dir``, each session
      records into the manager's shared `repro.obs.MetricsRegistry`,
      and spans/sources are tagged with the request's tenant.
    trace_dir: directory for per-tenant JSONL/Chrome trace files
      (requires ``obs="trace"``).
    """

    max_concurrent: int = 4
    queue_limit: int = 16
    default_deadline_s: float | None = None
    retry_after_s: float = 1.0
    device_budget_mb: float | None = None
    min_device_bank_mb: float = 16.0
    checkpoint_root: str | None = None
    obs: str = "off"
    trace_dir: str | None = None

    def __post_init__(self):
        if int(self.max_concurrent) < 1:
            raise ValueError(
                f"max_concurrent must be >= 1, got {self.max_concurrent!r}"
            )
        if int(self.queue_limit) < 0:
            raise ValueError(
                f"queue_limit must be >= 0, got {self.queue_limit!r}"
            )
        if self.obs not in OBS_MODES:
            raise ValueError(
                f"obs must be one of {OBS_MODES}, got {self.obs!r}"
            )
        if self.trace_dir is not None and self.obs != "trace":
            raise ValueError(
                'trace_dir requires obs="trace", got '
                f"obs={self.obs!r} with trace_dir={self.trace_dir!r}"
            )
        object.__setattr__(self, "max_concurrent", int(self.max_concurrent))
        object.__setattr__(self, "queue_limit", int(self.queue_limit))


@dataclasses.dataclass(frozen=True)
class DiscoveryRequest:
    """One tenant's discovery request against the manager's dataset.

    tenant: label riding every structured error and checkpoint
      namespace.  seed: per-session PRNG isolation — overrides the score
      config's seed (fold layout + feature-policy randomness), changing
      the session's build fingerprints so it can never collide with
      another tenant's factors or Gram blocks.  deadline_s: wall-clock
      budget from *submission* (falls back to the manager's default).
      fault_plan: injected faults for THIS session only.  checkpoint:
      sweep-granular checkpointing under the manager's
      ``checkpoint_root/<tenant>`` namespace; resume="auto" restores the
      newest loadable checkpoint from that same namespace.
    """

    tenant: str
    deadline_s: float | None = None
    seed: int | None = None
    max_subset: int | None = None
    fault_plan: object | None = None
    checkpoint: bool = False
    resume: str = "never"


class SessionTicket:
    """Handle for an admitted request: result / cancel / telemetry."""

    def __init__(self, tenant: str, cancel_event: threading.Event):
        self.tenant = tenant
        self._cancel_event = cancel_event
        self._future = None  # set by the manager right after construction
        self.session: DiscoverySession | None = None  # set when started
        self.submitted_at = time.monotonic()
        self.latency_s: float | None = None
        self.error: dict | None = None  # structured payload on failure

    def result(self, timeout: float | None = None):
        """The tenant's `GESResult`; re-raises the structured failure
        (`DeadlineExceeded` / `SessionCancelled` / `InjectedFault` / ...)
        when the run did not survive."""
        return self._future.result(timeout)

    def done(self) -> bool:
        return self._future.done()

    def cancel(self) -> None:
        """Mid-request kill: the session sheds at its next sweep seam."""
        self._cancel_event.set()


class SessionManager:
    """Admits concurrent `DiscoverySession`s over one dataset and one
    process-wide shared `FeatureBank` / per-fingerprint `GramBlockCache`
    registry (module docstring has the full story)."""

    def __init__(
        self,
        data,
        spec: DataSpec | None = None,
        options: EngineOptions | None = None,
        config: ScoreConfig | None = None,
        serving: ServingOptions | None = None,
        feature_bank: FeatureBank | None = None,
    ):
        self.data = data
        self.spec = resolve_spec(data, spec=spec)
        self.options = options if options is not None else EngineOptions()
        self.config = config if config is not None else ScoreConfig()
        self.serving = serving if serving is not None else ServingOptions()
        self.feature_bank = (
            feature_bank if feature_bank is not None else FeatureBank()
        )
        self._gram_caches: dict = {}  # workload fingerprint -> GramBlockCache
        self._lock = threading.Lock()
        self._pending = 0
        self._running = 0
        self._closed = False
        self._lat: list = []  # completed-run latencies (seconds)
        self.stats = {
            "admitted": 0,
            "shed": 0,
            "completed": 0,
            "deadline_exceeded": 0,
            "cancelled": 0,
            "failed": 0,
        }
        self.degradations = {
            "shrink_device": 0,
            "evict_to_host": 0,
            "reroute_backend": 0,
        }
        # aggregated constraint-phase counters across finished sessions
        # (restrict="skeleton" tenants; see repro.constraint)
        self.constraint_totals = {
            "sessions": 0,
            "ci_tests": 0,
            "cached": 0,
            "pruned_pairs": 0,
            "skeleton_s": 0.0,
        }
        # shared metrics registry: every admitted session's recorder
        # (serving obs != "off") registers its counters/histograms here,
        # plus the manager's own admission/ladder/bank suppliers.  Always
        # constructed — it is a few dicts — so `metrics_snapshot()` and
        # `prometheus()` work regardless of mode.
        self.metrics = MetricsRegistry()
        self.metrics.register_source("serving.stats", self._stats_source)
        self.metrics.register_source(
            "serving.degradations", self._degradations_source
        )
        self.metrics.register_source(
            "serving.constraint", self._constraint_source
        )
        self.metrics.register_source(
            "serving.feature_bank", lambda: dict(self.feature_bank.stats)
        )
        self.metrics.register_source(
            "serving.latency", self.latency_percentiles
        )
        self._pool = ThreadPoolExecutor(
            max_workers=self.serving.max_concurrent,
            thread_name_prefix="discovery",
        )

    def _stats_source(self) -> dict:
        with self._lock:
            return dict(self.stats)

    def _degradations_source(self) -> dict:
        with self._lock:
            return dict(self.degradations)

    def _constraint_source(self) -> dict:
        with self._lock:
            return dict(self.constraint_totals)

    # -- shared-state plumbing --------------------------------------------
    def _policy_for(self, options: EngineOptions) -> FeaturePolicy:
        return (
            options.features
            if options.features is not None
            else FeaturePolicy.default()
        )

    def _workload_fingerprint(self, config, options) -> tuple:
        """Gram-cache sharing key: everything that shapes a Gram block's
        *values* — the score config (fold layout seed included), the
        resolved feature policy, and the Gram-accumulation precision.
        Sessions with different fingerprints get different caches
        (fingerprint isolation); `device_bank_mb` is placement, not
        value, so rung-degraded sessions still share."""
        return (
            config,
            self._policy_for(options).fingerprint(),
            options.precision,
        )

    def _gram_cache_for(self, config, options) -> GramBlockCache:
        fp = self._workload_fingerprint(config, options)
        with self._lock:
            cache = self._gram_caches.get(fp)
            if cache is None:
                cache = GramBlockCache(
                    max_entries=options.gram_cache_entries,
                    device_bank_mb=options.device_bank_mb,
                )
                self._gram_caches[fp] = cache
            return cache

    def shared_bytes(self) -> int:
        """The ladder's measured footprint: feature-bank factor bytes +
        every workload cache's device-tier bytes."""
        with self._lock:
            caches = list(self._gram_caches.values())
        return self.feature_bank.nbytes + sum(
            c.device_nbytes for c in caches
        )

    def _degrade(self, options: EngineOptions, serving_info: dict):
        """Memory-pressure ladder, applied at admission.  Returns the
        (possibly degraded) EngineOptions for the new session and records
        the rung in `serving_info` (surfaced in its sweep log)."""
        budget_mb = self.serving.device_budget_mb
        if budget_mb is None:
            return options
        usage = self.shared_bytes() / 2**20
        rung = 0
        if usage > budget_mb:
            rung = 3
        elif usage > 0.75 * budget_mb:
            rung = 2
        elif usage > 0.5 * budget_mb:
            rung = 1
        serving_info["pressure_rung"] = rung
        if rung == 0:
            return options
        with self._lock:
            caches = list(self._gram_caches.values())
        if rung == 1:
            shrunk = max(
                self.serving.min_device_bank_mb,
                float(options.device_bank_mb or 0) / 2,
            )
            for c in caches:
                if c.device_enabled and float(c.device_bank_mb) > shrunk:
                    c.set_device_budget(shrunk)
            serving_info["shrink_device"] = (
                serving_info.get("shrink_device", 0) + 1
            )
            with self._lock:
                self.degradations["shrink_device"] += 1
            return dataclasses.replace(options, device_bank_mb=shrunk)
        if rung == 2:
            for c in caches:
                c.spill_device()
            serving_info["evict_to_host"] = (
                serving_info.get("evict_to_host", 0) + 1
            )
            with self._lock:
                self.degradations["evict_to_host"] += 1
            return dataclasses.replace(options, device_bank_mb=0)
        # rung 3: also route NEW builds to the cheapest backend — rff has
        # no sequential pivot loop and the smallest factor footprint.
        # The rerouted policy changes build fingerprints, so these
        # sessions land in their own bank entries / Gram namespace and
        # can never pollute full-fidelity tenants.
        for c in caches:
            c.spill_device()
        base = self._policy_for(options)
        rerouted = dataclasses.replace(base, continuous="rff", mixed="rff")
        serving_info["evict_to_host"] = serving_info.get("evict_to_host", 0) + 1
        serving_info["reroute_backend"] = (
            serving_info.get("reroute_backend", 0) + 1
        )
        with self._lock:
            self.degradations["evict_to_host"] += 1
            self.degradations["reroute_backend"] += 1
        return dataclasses.replace(
            options, device_bank_mb=0, features=rerouted
        )

    # -- admission ---------------------------------------------------------
    def _retry_after(self) -> float:
        with self._lock:
            depth = self._pending + self._running
            mean = sum(self._lat) / len(self._lat) if self._lat else None
        if mean is None:
            return self.serving.retry_after_s
        return max(
            self.serving.retry_after_s,
            depth * mean / self.serving.max_concurrent,
        )

    def submit(self, request: DiscoveryRequest) -> SessionTicket:
        """Admit (or shed) one request; returns immediately with a
        `SessionTicket` whose `result()` blocks for the outcome."""
        if not isinstance(request, DiscoveryRequest):
            raise ValueError(
                "submit takes a DiscoveryRequest, got "
                f"{type(request).__name__}"
            )
        with self._lock:
            if self._closed:
                shed_reason = "manager is shut down"
            elif self._pending >= self.serving.queue_limit:
                shed_reason = (
                    f"queue full ({self._pending} pending >= "
                    f"queue_limit={self.serving.queue_limit})"
                )
            else:
                shed_reason = None
            if shed_reason is None:
                self._pending += 1
                self.stats["admitted"] += 1
            else:
                self.stats["shed"] += 1
        if shed_reason is not None:
            raise RequestShed(request.tenant, shed_reason, self._retry_after())
        deadline_s = (
            request.deadline_s
            if request.deadline_s is not None
            else self.serving.default_deadline_s
        )
        deadline_at = (
            time.monotonic() + deadline_s if deadline_s is not None else None
        )
        ticket = SessionTicket(request.tenant, threading.Event())
        ticket._future = self._pool.submit(
            self._serve, ticket, request, deadline_s, deadline_at
        )
        return ticket

    def run(self, request: DiscoveryRequest):
        """Synchronous convenience: submit + result."""
        return self.submit(request).result()

    # -- the worker --------------------------------------------------------
    def _session_options(self, request, deadline_s, serving_info):
        options = self.options
        if deadline_s is not None:
            options = dataclasses.replace(options, deadline_s=deadline_s)
        if request.checkpoint or request.resume != "never":
            root = self.serving.checkpoint_root
            if root is None:
                raise ValueError(
                    "request.checkpoint/resume need "
                    "ServingOptions(checkpoint_root=...) — per-tenant "
                    "checkpoints are namespaced under it"
                )
            options = dataclasses.replace(
                options,
                checkpoint_dir=os.path.join(root, str(request.tenant)),
            )
        if self.serving.obs != "off":
            options = dataclasses.replace(
                options,
                obs=self.serving.obs,
                trace_dir=self.serving.trace_dir,
            )
        return self._degrade(options, serving_info)

    def _serve(self, ticket, request, deadline_s, deadline_at):
        with self._lock:
            self._pending -= 1
            self._running += 1
        t0 = time.monotonic()
        try:
            serving_info: dict = {}
            options = self._session_options(request, deadline_s, serving_info)
            config = self.config
            if request.seed is not None:
                config = dataclasses.replace(config, seed=int(request.seed))
            session = DiscoverySession(
                self.data,
                spec=self.spec,
                options=options,
                config=config,
                max_subset=request.max_subset,
                feature_bank=self.feature_bank,
                gram_cache=self._gram_cache_for(config, options),
                fault_plan=request.fault_plan,
                resume=request.resume,
                tenant=request.tenant,
                cancel_event=ticket._cancel_event,
                deadline_at=deadline_at,
                serving_info=serving_info or None,
                metrics_registry=self.metrics,
            )
            ticket.session = session
            try:
                result = session.run()
            finally:
                # flush the tenant's trace files and drop its per-tenant
                # sources from the shared registry (keeps the registry
                # bounded over a long-lived manager); the recorder's
                # counters/histograms stay — they aggregate across tenants
                session.close_obs()
        except BaseException as exc:
            ticket.error = structured_error(exc)
            code = ticket.error.get("error")
            key = {
                "deadline_exceeded": "deadline_exceeded",
                "cancelled": "cancelled",
            }.get(code, "failed")
            with self._lock:
                self.stats[key] += 1
                self._running -= 1
            raise
        ticket.latency_s = time.monotonic() - t0
        constraint = getattr(session, "_constraint", None)
        with self._lock:
            self.stats["completed"] += 1
            self._lat.append(ticket.latency_s)
            self._running -= 1
            if constraint:
                tot = self.constraint_totals
                tot["sessions"] += 1
                for k in ("ci_tests", "cached", "pruned_pairs"):
                    tot[k] += int(constraint.get(k, 0))
                tot["skeleton_s"] = round(
                    tot["skeleton_s"] + float(constraint.get("skeleton_s", 0.0)),
                    6,
                )
        return result

    # -- lifecycle / telemetry --------------------------------------------
    def shutdown(self, wait: bool = True, cancel_active: bool = False) -> None:
        """Stop admitting; optionally cancel in-flight sessions (they shed
        at their next sweep seam) and wait the pool down."""
        with self._lock:
            self._closed = True
        if cancel_active:
            # cancel reaches sessions through their tickets; callers keep
            # those.  The manager-side switch just stops new admissions.
            pass
        self._pool.shutdown(wait=wait, cancel_futures=not wait)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown(wait=True)
        return False

    @property
    def queue_depth(self) -> int:
        with self._lock:
            return self._pending

    def latency_percentiles(self) -> dict:
        """p50/p95 of completed-run latency (seconds), for benchmarks and
        the serve loop's report."""
        with self._lock:
            lat = sorted(self._lat)
        if not lat:
            return {"p50": None, "p95": None, "n": 0}

        def _pct(p):
            i = min(len(lat) - 1, max(0, int(round(p * (len(lat) - 1)))))
            return round(lat[i], 4)

        return {"p50": _pct(0.50), "p95": _pct(0.95), "n": len(lat)}

    def metrics_snapshot(self) -> dict:
        """Point-in-time dump of the shared `repro.obs.MetricsRegistry`:
        recorder counters/histograms plus the manager's registered
        sources (admission stats, ladder, constraint totals, bank)."""
        return self.metrics.snapshot()

    def prometheus(self) -> str:
        """The shared registry rendered as Prometheus text exposition
        (see `repro.obs.prometheus_text`)."""
        return prometheus_text(self.metrics)

    def telemetry(self) -> dict:
        """One dict for logs/benchmarks: admission stats, ladder counters,
        latencies, shared-bank and per-workload-cache counters."""
        with self._lock:
            caches = {
                repr(fp): c.stats for fp, c in self._gram_caches.items()
            }
            stats = dict(self.stats)
            degradations = dict(self.degradations)
            constraint = dict(self.constraint_totals)
        return {
            "stats": stats,
            "degradations": degradations,
            "constraint": constraint,
            "latency": self.latency_percentiles(),
            "feature_bank": self.feature_bank.stats,
            "gram_caches": caches,
            "shared_mb": round(self.shared_bytes() / 2**20, 2),
        }
