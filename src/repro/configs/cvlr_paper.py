"""The paper's own workload as a dry-runnable 'architecture': distributed
CV-LR frontier scoring (repro.core.distributed_score) on the production
mesh.  Shapes: B candidates x (Q folds x n0 samples x m pivots)."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class CVLRWorkload:
    name: str = "cvlr_paper"
    family: str = "paper"
    num_candidates: int = 256  # GES frontier batch (shards over `model`)
    q_folds: int = 10
    samples_per_fold: int = 100_000  # n = 1M samples (shards over `data`)
    m: int = 128  # pivot budget, MXU-aligned


def config() -> CVLRWorkload:
    return CVLRWorkload()


def reduced() -> CVLRWorkload:
    return CVLRWorkload(num_candidates=4, q_folds=4, samples_per_fold=40, m=16)
