"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

48L d_model=2048 4H d_ff=0 (block-internal factor-2 up/down projection).
Pattern: groups of 7 mLSTM + 1 sLSTM (xLSTM[7:1])."""

import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm_1b",
        family="ssm",
        num_layers=48,
        d_model=2048,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        norm_kind="rmsnorm",
        slstm_every=8,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        num_layers=4,
        d_model=64,
        num_heads=2,
        num_kv_heads=2,
        vocab_size=256,
        slstm_every=2,
    )
