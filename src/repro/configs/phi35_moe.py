"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2
[hf:microsoft/Phi-3.5-MoE-instruct; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16e top-2."""

import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi35_moe",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=6400,
        vocab_size=32064,
        mlp_kind="swiglu",
        norm_kind="rmsnorm",
        num_experts=16,
        num_experts_per_tok=2,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=96,
        vocab_size=256,
        num_experts=4,
        attn_chunk=32,
    )
