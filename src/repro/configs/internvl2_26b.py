"""internvl2-26b [vlm] — InternViT + InternLM2 [arXiv:2404.16821; hf].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.  The InternViT
frontend is a STUB per the assignment: input_specs supplies precomputed
patch embeddings (num_prefix_tokens x frontend_dim) that a linear
projection maps into the backbone width."""

import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2_26b",
        family="vlm",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=16384,
        vocab_size=92553,
        mlp_kind="swiglu",
        norm_kind="rmsnorm",
        frontend="vit_stub",
        num_prefix_tokens=256,
        frontend_dim=3200,  # InternViT-6B output width
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        num_prefix_tokens=8,
        frontend_dim=48,
        attn_chunk=32,
    )
