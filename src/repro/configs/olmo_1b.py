"""olmo-1b [dense] — non-parametric LayerNorm [arXiv:2402.00838; hf].

16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304."""

import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmo_1b",
        family="dense",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=8192,
        vocab_size=50304,
        mlp_kind="swiglu",
        norm_kind="nonparam_ln",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        attn_chunk=32,
    )
