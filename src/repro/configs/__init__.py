"""One config module per assigned architecture (+ the paper's workload).

Each module exports `config()` (the exact assigned full-scale config) and
`reduced()` (a same-family miniature for CPU smoke tests)."""
