"""starcoder2-15b [dense] — GQA, RoPE [arXiv:2402.19173; hf].

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152."""

import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2_15b",
        family="dense",
        num_layers=40,
        d_model=6144,
        num_heads=48,
        num_kv_heads=4,
        d_ff=24576,
        vocab_size=49152,
        mlp_kind="gelu",
        norm_kind="rmsnorm",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        num_layers=2,
        d_model=96,
        num_heads=6,
        num_kv_heads=2,
        d_ff=192,
        vocab_size=384,
        attn_chunk=32,
    )
