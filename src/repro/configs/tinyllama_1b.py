"""tinyllama-1.1b [dense] — llama2-arch small [arXiv:2401.02385; hf].

22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000."""

import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama_1b",
        family="dense",
        num_layers=22,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        d_ff=5632,
        vocab_size=32000,
        mlp_kind="swiglu",
        norm_kind="rmsnorm",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        attn_chunk=32,
    )
