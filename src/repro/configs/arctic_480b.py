"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf].

35L d_model=7168 56H (GQA kv=8) d_ff=4864, MoE 128e top-2, dense residual."""

import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic_480b",
        family="moe",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=4864,
        vocab_size=32000,
        mlp_kind="swiglu",
        norm_kind="rmsnorm",
        num_experts=128,
        num_experts_per_tok=2,
        moe_dense_residual=True,
        dense_ff=4864,  # arctic's parallel dense FFN residual path
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=96,
        vocab_size=256,
        num_experts=4,
        dense_ff=96,
        attn_chunk=32,
    )
