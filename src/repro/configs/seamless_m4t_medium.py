"""seamless-m4t-medium [audio] — enc-dec, multimodal [arXiv:2308.11596; hf].

12L (decoder) + 12L encoder, d_model=1024 16H (kv=16) d_ff=4096
vocab=256206.  The audio frontend is a STUB per the assignment:
input_specs supplies precomputed 80-mel frame embeddings."""

import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless_m4t_medium",
        family="audio",
        num_layers=12,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=256206,
        mlp_kind="gelu",
        norm_kind="rmsnorm",
        is_encoder_decoder=True,
        enc_layers=12,
        frontend="audio_stub",
        frontend_dim=160,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        num_layers=2,
        enc_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        frontend_dim=16,
        attn_chunk=32,
    )
