"""zamba2-1.2b [hybrid] — Mamba2 + shared attn blocks [arXiv:2411.15242; hf].

38L d_model=2048 32H (GQA kv=32) d_ff=8192, ssm_state=64.  38 Mamba2
layers in 2 groups of 19, one SHARED attention(+MLP) block applied after
each group (Zamba-style parameter sharing)."""

import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2_1b",
        family="hybrid",
        num_layers=38,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=32000,
        mlp_kind="swiglu",
        norm_kind="rmsnorm",
        ssm_state=64,
        ssm_heads=64,  # d_inner 4096 / head dim 64
        attn_every=19,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        ssm_state=16,
        ssm_heads=4,
        attn_every=2,
        attn_chunk=32,
    )
