"""gemma-2b [dense] — GeGLU, head_dim=256, MQA [arXiv:2403.08295; hf].

18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=256000."""

import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma_2b",
        family="dense",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=256000,
        mlp_kind="geglu",
        norm_kind="rmsnorm",
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=256,
        vocab_size=512,
        attn_chunk=32,
    )
