"""Paper Table 1: CV vs CV-LR score values and relative error at m=100,
for continuous/discrete data with |Z| in {0, 6}, across sample sizes."""

from __future__ import annotations

import numpy as np

from repro.core.score_common import ScoreConfig
from repro.core.score_exact import CVScorer
from repro.core.score_lowrank import CVLRScorer
from repro.data.networks import CHILD, sample_network
from repro.data.synthetic import generate_scm_data


def run(ns=(200, 500, 1000, 2000), quick=False):
    if quick:
        ns = (200, 500)
    cont = generate_scm_data(d=7, n=max(ns), density=0.4, kind="continuous", seed=2)
    disc, _ = sample_network(CHILD, n=max(ns), seed=2)
    rows = []
    for kind, data, is_disc in (
        ("continuous", cont.data, False),
        ("discrete", disc, True),
    ):
        for z in (0, 6):
            parents = tuple(range(1, 1 + z))
            for n in ns:
                cfg = ScoreConfig(seed=3)
                d = data.shape[1]
                cv = CVScorer(data[:n], discrete=[is_disc] * d, config=cfg)
                lr = CVLRScorer(data[:n], discrete=[is_disc] * d, config=cfg)
                s_cv = cv.local_score(0, parents)
                s_lr = lr.local_score(0, parents)
                rel = abs(s_lr - s_cv) / abs(s_cv) * 100
                rows.append(dict(kind=kind, z=z, n=n, cv=s_cv, cvlr=s_lr, rel_pct=rel))
                print(
                    f"table1,{kind},|Z|={z},n={n},cv={s_cv:.6f},"
                    f"cvlr={s_lr:.6f},rel_err={rel:.4f}%"
                )
    worst = max(r["rel_pct"] for r in rows)
    print(f"table1,worst_relative_error={worst:.4f}% (paper bound: 0.5%)")
    return rows


if __name__ == "__main__":
    run()
