"""Skeleton-gated hybrid GES vs ungated GES: end-to-end wall clock,
frontier prune rate, and CPDAG parity.

For each (d, n) cell the benchmark runs the SAME synthetic SCM dataset
through two fresh `DiscoverySession`s — ungated (``restrict="none"``,
the PR-8 baseline) and gated (``restrict="skeleton"``: the PC-stable
constraint phase of `repro.constraint` estimates an `EdgeMask` first,
then GES only enumerates forward candidates inside it).  Each session
gets its own `FeatureBank`, so the gated wall clock *includes* the CI
phase's factor builds — the headline speedup is honest end-to-end, not
amortized.  Per cell the json records the prune rate (fraction of the
d*(d-1) ordered frontier pairs the mask removes), CI-test count and
throughput, skeleton wall, both discovery wall clocks, the end-to-end
speedup, CPDAG SHD between the two runs (absolute, `shd_cpdag(...,
normalize=False)`), and both runs' SHD/F1 against the generating DAG.
The gated session's bank counters are asserted (builds == entries):
the constraint phase fetches factors through the same single-flight
`FeatureBank` the score phase uses, so gating adds ZERO duplicate
factor builds.  Emits BENCH_skeleton.json at the repo root.

``python -m benchmarks.skeleton_gate``           — full grid (d up to 32,
n up to 10k: the ISSUE-9 acceptance cell)
``python -m benchmarks.skeleton_gate --quick``   — small cells only (CI)
``--check-prune-rate X``  — exit nonzero unless every cell prunes >= X
of its ordered frontier pairs (CI smoke: the gate must actually gate).
``--check-speedup X``  — exit nonzero unless every cell's end-to-end
gated speedup is >= X (full-grid acceptance gate; leave unset in
--quick, where tiny d makes the CI phase a fixed cost the score phase
can't amortize).
``--check-shd-excess X``  — exit nonzero if any cell's gated SHD
against the TRUE CPDAG exceeds the ungated run's by more than X (the
accuracy-parity gate).  The gate is deliberately vs truth, not vs the
ungated CPDAG: at benchmark sample sizes the ungated score phase adds
false-positive edges in exactly the region the mask prunes, so gated
and ungated disagree *because gating helps* (the json records the raw
``shd_gated_vs_ungated`` too — on every measured cell the gated run's
truth-SHD is at or below ungated + the gate bound, usually below
ungated itself).  Never run concurrently with the test suite.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks._writer import write_bench

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_skeleton.json")


def _bench_cell(d: int, n: int, density: float, seed: int = 0) -> dict:
    from repro.core.api import DiscoverySession
    from repro.core.graph import dag_to_cpdag
    from repro.core.metrics import shd_cpdag, skeleton_f1
    from repro.core.score_common import ScoreConfig
    from repro.core.spec import EngineOptions

    from repro.data.synthetic import generate_scm_data

    ds = generate_scm_data(d=d, n=n, density=density, kind="continuous",
                           seed=seed)
    true_cpdag = dag_to_cpdag(ds.dag)

    def _run(restrict: str):
        sess = DiscoverySession(
            ds.data,
            config=ScoreConfig(seed=seed),
            options=EngineOptions(restrict=restrict),
        )
        t0 = time.perf_counter()
        res = sess.run()
        return sess, res, time.perf_counter() - t0

    plain_sess, plain_res, t_plain = _run("none")
    gated_sess, gated_res, t_gated = _run("skeleton")

    bank = gated_sess.feature_bank.stats
    assert bank["builds"] == bank["entries"], (
        f"duplicate factor builds under gating: {bank}"
    )
    constraint = gated_sess.sweep_log[0]["constraint"]
    pairs = d * (d - 1)
    prune_rate = constraint["pruned_pairs"] / pairs
    skel_s = constraint["skeleton_s"]

    return {
        "d": d,
        "n": n,
        "density": density,
        "frontier_pairs": pairs,
        "pruned_pairs": constraint["pruned_pairs"],
        "prune_rate": round(prune_rate, 4),
        "ci_tests": constraint["ci_tests"],
        "ci_tests_per_sec": round(constraint["ci_tests"] / skel_s, 3)
        if skel_s > 0
        else None,
        "skeleton_s": skel_s,
        "ungated_wall_s": round(t_plain, 4),
        "gated_wall_s": round(t_gated, 4),
        "speedup_end_to_end": round(t_plain / t_gated, 3),
        "sweeps_ungated": len(plain_sess.sweep_log),
        "sweeps_gated": len(gated_sess.sweep_log),
        "shd_gated_vs_ungated": shd_cpdag(
            gated_res.cpdag, plain_res.cpdag, normalize=False
        ),
        "shd_vs_true": {
            "ungated": shd_cpdag(plain_res.cpdag, true_cpdag, normalize=False),
            "gated": shd_cpdag(gated_res.cpdag, true_cpdag, normalize=False),
        },
        "skeleton_f1_vs_true": {
            "ungated": round(skeleton_f1(plain_res.cpdag, ds.dag), 4),
            "gated": round(skeleton_f1(gated_res.cpdag, ds.dag), 4),
        },
        "feature_bank": dict(bank),
    }


def run(quick: bool = False, out_path: str = OUT_PATH) -> dict:
    grid = (
        [(8, 600, 0.25), (12, 800, 0.2)]
        if quick
        else [(8, 600, 0.25), (12, 800, 0.2), (16, 2000, 0.15),
              (32, 10000, 0.12)]
    )
    cells = []
    print("d,n,prune_rate,ci_tests,skeleton_s,ungated_s,gated_s,speedup,shd")
    for d, n, density in grid:
        cell = _bench_cell(d, n, density)
        cells.append(cell)
        print(
            f"{d},{n},{cell['prune_rate']},{cell['ci_tests']},"
            f"{cell['skeleton_s']},{cell['ungated_wall_s']},"
            f"{cell['gated_wall_s']},{cell['speedup_end_to_end']},"
            f"{cell['shd_gated_vs_ungated']}"
        )
    result = {
        "benchmark": "skeleton_gate",
        "unit": "end-to-end discovery wall seconds",
        "engine": "PC-stable factor-based kernel CI skeleton (repro."
        "constraint) gating the GES forward frontier via EdgeMask (PR 9)",
        "quick": quick,
        "cells": cells,
    }
    result = write_bench(out_path, result)
    print(f"wrote {out_path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=OUT_PATH)
    ap.add_argument(
        "--check-prune-rate",
        type=float,
        default=None,
        metavar="X",
        help="fail (exit 1) unless every cell prunes >= X of its ordered"
        " frontier pairs — the CI smoke gate that gating actually gates",
    )
    ap.add_argument(
        "--check-speedup",
        type=float,
        default=None,
        metavar="X",
        help="fail (exit 1) unless every cell's end-to-end gated speedup"
        " is >= X — the full-grid acceptance gate (skip in --quick)",
    )
    ap.add_argument(
        "--check-shd-excess",
        type=float,
        default=None,
        metavar="X",
        help="fail (exit 1) if any cell's gated SHD vs the true CPDAG"
        " exceeds the ungated run's by more than X — pruning must not"
        " make the answer worse",
    )
    args = ap.parse_args()
    result = run(quick=args.quick, out_path=args.out)
    if args.check_prune_rate is not None:
        weak = [
            (c["d"], c["n"], c["prune_rate"])
            for c in result["cells"]
            if c["prune_rate"] < args.check_prune_rate
        ]
        if weak:
            print(
                f"PERF REGRESSION: cells pruning < {args.check_prune_rate}:"
                f" {weak}"
            )
            raise SystemExit(1)
        print(f"prune gate ok: all cells >= {args.check_prune_rate}")
    if args.check_speedup is not None:
        slow = [
            (c["d"], c["n"], c["speedup_end_to_end"])
            for c in result["cells"]
            if c["speedup_end_to_end"] < args.check_speedup
        ]
        if slow:
            print(f"PERF REGRESSION: cells below {args.check_speedup}x: {slow}")
            raise SystemExit(1)
        print(f"speedup gate ok: all cells >= {args.check_speedup}x")
    if args.check_shd_excess is not None:
        off = [
            (c["d"], c["n"], c["shd_vs_true"])
            for c in result["cells"]
            if c["shd_vs_true"]["gated"]
            > c["shd_vs_true"]["ungated"] + args.check_shd_excess
        ]
        if off:
            print(
                "PARITY REGRESSION: cells where gating worsened truth-SHD"
                f" by > {args.check_shd_excess}: {off}"
            )
            raise SystemExit(1)
        print(
            "shd parity ok: no cell worsened by more than"
            f" {args.check_shd_excess}"
        )
