"""Paper Fig. 1: run-time of a single score evaluation, CV vs CV-LR, as a
function of sample size, for |Z| in {0, 6} on continuous and discrete data.

The claim under test is the complexity class: CV is O(n^3), CV-LR is O(n).
We report per-call wall times, the speedup at each n, and the fitted
log-log scaling exponent of each method.  The exact CV score is measured
up to n = `cv_cap` (2000 by default — one call already takes ~2 minutes on
this container's CPU, which is the paper's point); CV-LR is measured to
the full range.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.score_common import ScoreConfig
from repro.core.score_exact import CVScorer
from repro.core.score_lowrank import CVLRScorer
from repro.data.networks import CHILD, sample_network
from repro.data.synthetic import generate_scm_data


def _time_once(fn, reps=1):
    fn()  # warmup/compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def one_setting(data, discrete, z_size, n, cv_cap, seed=0):
    cfg = ScoreConfig(seed=seed)
    d = data.shape[1]
    parents = tuple(range(1, 1 + z_size))
    rows = {}
    for name, cls in (("CV", CVScorer), ("CV-LR", CVLRScorer)):
        if name == "CV" and n > cv_cap:
            rows[name] = float("nan")
            continue
        sc = cls(data[:n], discrete=[discrete] * d, config=cfg)

        def call():
            sc._score_cache.clear()
            sc.local_score(0, parents)

        rows[name] = _time_once(call)
    return rows


def _fit_exponent(ns, ts):
    pts = [(n, t) for n, t in zip(ns, ts) if np.isfinite(t)]
    if len(pts) < 2:
        return float("nan")
    x = np.log([p[0] for p in pts])
    y = np.log([p[1] for p in pts])
    return float(np.polyfit(x, y, 1)[0])


def run(ns=(200, 500, 1000, 2000, 4000), z_sizes=(0, 6), cv_cap=2000, quick=False):
    if quick:
        ns, cv_cap = (200, 500), 500
    results = []
    cont = generate_scm_data(d=7, n=max(ns), density=0.4, kind="continuous", seed=1)
    disc, _ = sample_network(CHILD, n=max(ns), seed=1)
    for kind, data, is_disc in (("continuous", cont.data, False), ("discrete", disc, True)):
        for z in z_sizes:
            cv_ts, lr_ts = [], []
            for n in ns:
                r = one_setting(data, is_disc, z, n, cv_cap)
                cv_ts.append(r["CV"])
                lr_ts.append(r["CV-LR"])
                ratio = r["CV"] / r["CV-LR"] if r["CV-LR"] else float("nan")
                results.append(
                    dict(kind=kind, z=z, n=n, cv_s=r["CV"], cvlr_s=r["CV-LR"], speedup=ratio)
                )
                print(
                    f"fig1,{kind},|Z|={z},n={n},cv={r['CV']:.4f}s,"
                    f"cvlr={r['CV-LR']:.4f}s,speedup={ratio:.1f}x",
                    flush=True,
                )
            print(
                f"fig1,{kind},|Z|={z},scaling_exponent_cv={_fit_exponent(ns, cv_ts):.2f},"
                f"scaling_exponent_cvlr={_fit_exponent(ns, lr_ts):.2f}",
                flush=True,
            )
    return results


if __name__ == "__main__":
    run()
