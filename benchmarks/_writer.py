"""Shared benchmark-report writer: every BENCH_*.json carries provenance.

Benchmark numbers with no record of *what produced them* are
uncomparable across the PR trajectory — a regression against a number
measured on a different commit, jax version, or device kind is noise.
`write_bench` is the single sink all benchmark drivers write through:
it stamps the payload with a ``provenance`` block (commit sha, dirty
flag, jax version, backend + device kind, host, python, UTC timestamp)
and runs it through `repro.obs.json_safe` so a stray numpy scalar in a
result dict fails loudly at write time, not in a downstream reader.

Every field is collected fault-tolerantly: a benchmark run outside a
git checkout, or before jax is importable, still writes — the missing
fields read ``None``.
"""

from __future__ import annotations

import datetime
import json
import os
import platform
import subprocess

from repro.obs import json_safe

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _git(*args: str) -> str | None:
    try:
        out = subprocess.run(
            ("git", *args),
            cwd=_REPO_ROOT,
            capture_output=True,
            text=True,
            timeout=10,
        )
    except (OSError, subprocess.SubprocessError):
        return None
    if out.returncode != 0:
        return None
    return out.stdout.strip()


def provenance() -> dict:
    """Identity of this benchmark run: commit, toolchain, device, time."""
    sha = _git("rev-parse", "HEAD")
    dirty = None
    if sha is not None:
        status = _git("status", "--porcelain")
        dirty = bool(status) if status is not None else None
    jax_version = backend = device_kind = None
    try:
        import jax

        jax_version = jax.__version__
        dev = jax.devices()[0]
        backend = dev.platform
        device_kind = dev.device_kind
    except Exception:
        pass
    return {
        "commit": sha,
        "dirty": dirty,
        "jax": jax_version,
        "backend": backend,
        "device_kind": device_kind,
        "hostname": platform.node(),
        "python": platform.python_version(),
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"
        ),
    }


def write_bench(path: str, payload: dict) -> dict:
    """Stamp ``payload`` with provenance and write it as indented JSON.

    Returns the stamped payload (what landed on disk).  Raises
    ``TypeError`` naming the offending key when the payload carries a
    non-JSON-serializable value (device arrays, numpy scalars)."""
    if not isinstance(payload, dict):
        raise TypeError(
            f"benchmark payload must be a dict, got {type(payload).__name__}"
        )
    stamped = dict(payload)
    stamped["provenance"] = provenance()
    stamped = json_safe(stamped, path=os.path.basename(path))
    with open(path, "w") as fh:
        json.dump(stamped, fh, indent=2)
        fh.write("\n")
    return stamped
