"""§Perf before/after: diff two dry-run result directories
(default: the snapshotted baseline vs the optimized re-sweep)."""

from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "dryrun_results")


def _load(d):
    out = {}
    if not os.path.isdir(d):
        return out
    for name in sorted(os.listdir(d)):
        if name.endswith(".json"):
            r = json.load(open(os.path.join(d, name)))
            if r.get("status") == "ok":
                out[(r["arch"], r["shape"])] = r
    return out


def compare(before_dir="single_baseline", after_dir="single"):
    before = _load(os.path.join(RESULTS, before_dir))
    after = _load(os.path.join(RESULTS, after_dir))
    hdr = (
        f"{'arch':22s} {'shape':12s} {'flops before':>13s} {'after':>10s} "
        f"{'x':>6s} | {'coll before':>12s} {'after':>10s} {'x':>6s} "
        f"| {'AR#':>9s} {'A2A#':>9s}"
    )
    print(hdr)
    print("-" * len(hdr))
    rows = []
    for key in sorted(before):
        if key not in after:
            continue
        b, a = before[key], after[key]
        fb, fa = b["flops"], a["flops"]
        cb = b["collectives"]["total_collective_bytes"]
        ca = a["collectives"]["total_collective_bytes"]
        arb = b["collectives"].get("all-reduce_count", 0)
        ara = a["collectives"].get("all-reduce_count", 0)
        a2b = b["collectives"].get("all-to-all_count", 0)
        a2a = a["collectives"].get("all-to-all_count", 0)
        print(
            f"{key[0]:22s} {key[1]:12s} {fb:13.3e} {fa:10.3e} "
            f"{fb/max(fa,1):6.2f} | {cb:12.3e} {ca:10.3e} {cb/max(ca,1):6.2f} "
            f"| {arb:4d}->{ara:<4d} {a2b:4d}->{a2a:<4d}"
        )
        rows.append((key, fb, fa, cb, ca))
    return rows


if __name__ == "__main__":
    import sys

    compare(*(sys.argv[1:3] if len(sys.argv) > 2 else ()))
