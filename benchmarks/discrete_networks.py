"""Paper Fig. 5: F1 on the SACHS and CHILD discrete networks + CV vs CV-LR
run-time on a full GES pass."""

from __future__ import annotations

import time

import numpy as np

from repro.core.api import DataSpec, causal_discover
from repro.core.metrics import skeleton_f1
from repro.core.score_common import ScoreConfig
from repro.data.networks import CHILD, SACHS, sample_network


def run(ns=(200, 500), reps=2, include_cv=True, networks=(SACHS,), quick=False):
    if quick:
        ns, reps, include_cv = (200,), 1, False
    rows = []
    for net in networks:
        for n in ns:
            for method in (("cvlr", "cv") if include_cv else ("cvlr",)):
                f1s, times = [], []
                for rep in range(reps):
                    data, adj = sample_network(net, n=n, seed=rep)
                    spec = DataSpec.from_arrays(data, discrete=[True] * net.d)
                    t0 = time.perf_counter()
                    res = causal_discover(
                        data,
                        method=method,
                        spec=spec,
                        config=ScoreConfig(seed=rep),
                    )
                    times.append(time.perf_counter() - t0)
                    f1s.append(skeleton_f1(res.cpdag, adj))
                rows.append(
                    dict(
                        net=net.name, n=n, method=method,
                        f1=float(np.mean(f1s)), time_s=float(np.mean(times)),
                    )
                )
                print(
                    f"fig5,{net.name},n={n},{method},"
                    f"f1={np.mean(f1s):.3f},time={np.mean(times):.1f}s"
                )
    return rows


if __name__ == "__main__":
    run()
