"""Benchmark entry point: one function per paper table/figure + roofline.

``python -m benchmarks.run``           — quick pass (CI-sized)
``python -m benchmarks.run --full``    — paper-sized settings

Prints ``name,...`` CSV lines per benchmark.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument(
        "--only",
        default="all",
        help="comma list: table1,fig1,figs234,fig5,roofline,frontier",
    )
    args, _ = ap.parse_known_args()
    quick = not args.full
    which = set(args.only.split(","))
    t0 = time.time()

    from benchmarks import (
        approx_error,
        discrete_networks,
        frontier_scoring,
        roofline,
        runtime_scaling,
        synthetic_accuracy,
    )

    if which & {"all", "table1"}:
        print("# Table 1 — approximation error (m=100)")
        approx_error.run(quick=quick)
    if which & {"all", "fig1"}:
        print("# Fig. 1 — run-time scaling CV vs CV-LR")
        runtime_scaling.run(quick=quick)
    if which & {"all", "figs234"}:
        print("# Figs. 2-4 — synthetic accuracy (F1 / SHD)")
        synthetic_accuracy.run(quick=quick)
    if which & {"all", "fig5"}:
        print("# Fig. 5 — discrete networks (SACHS/CHILD)")
        discrete_networks.run(quick=quick)
    if which & {"all", "frontier"}:
        print("# Frontier scoring — sequential vs batched engine")
        frontier_scoring.run(quick=quick)
    if which & {"all", "roofline"}:
        print("# Roofline — from dry-run artifacts")
        roofline.main()
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
