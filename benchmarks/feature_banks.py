"""Feature-backend grid: build time + downstream score fidelity per
registered factorization backend (PR 5).

For each (backend, n, data-kind) cell the benchmark routes EVERY variable
set of a small SCM through one backend
(`repro.features.policy.FeaturePolicy`), then measures:

* **build** — wall time to build the frontier's factors cold (the bank's
  ``build_s``), plus the live-rank range and the bank's trace-residual
  telemetry;
* **score deviation** — max |CV-LR score - exact CV score| over a probe
  set of local configurations, against the exact-Gram O(n^3) oracle
  (`repro.core.score_exact.CVScorer`) on the oracle-sized cells (the
  exact kernel score is the ground truth all low-rank backends
  approximate; ICL's row is the baseline the new backends are judged
  against);
* **bank reuse** — a second scorer sharing the `FeatureBank` must build
  zero factors (the multi-sweep/multi-session rebuild-avoidance win),
  timed so the saving is a number, not a claim.

Emits BENCH_features.json at the repo root.

``python -m benchmarks.feature_banks``            — full grid
``python -m benchmarks.feature_banks --quick``    — CI smoke (small cells)
Never run concurrently with the test suite (2-vCPU box; see
EXPERIMENTS.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks._writer import write_bench

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_features.json")

BACKENDS = (
    ("icl", {}),
    ("rff", {}),
    ("nystrom", {"sampler": "uniform"}),
    ("nystrom", {"sampler": "leverage"}),
    ("nystrom", {"sampler": "stratified"}),
)


def _policy(backend: str, params: dict):
    from repro.features.policy import BackendChoice, FeaturePolicy

    choice = BackendChoice.of(backend, **params)
    if backend == "icl":
        # the default policy: ICL + exact-discrete — the baseline row
        return FeaturePolicy.default()
    return FeaturePolicy(continuous=choice, discrete=choice, mixed=choice, seed=0)


def _probe_configs(d: int):
    configs = [(y, ()) for y in range(d)]
    configs += [(y, (x,)) for x in range(d) for y in range(d) if x != y]
    configs += [(d - 1, (0, 1))]
    return configs


def _oracle_scores(ds, spec, cfg, configs) -> dict:
    """Exact-Gram CV scores for the probe configs (computed once per
    dataset; every backend row of that dataset is judged against it)."""
    from repro.core.api import make_scorer

    oracle = make_scorer(ds.data, method="cv", spec=spec, config=cfg)
    return {c: oracle.local_score(*c) for c in configs}


def _bench_cell(
    backend: str, params: dict, ds, spec, cfg, d: int,
    oracle: dict | None = None,
) -> dict:
    from repro.core.api import EngineOptions, make_scorer
    from repro.core.score_common import config_key
    from repro.features.bank import FeatureBank

    n = ds.data.shape[0]
    kind = ds.kind
    opts = EngineOptions(features=_policy(backend, params))
    configs = _probe_configs(d)

    bank = FeatureBank()
    scorer = make_scorer(
        ds.data, spec=spec, config=cfg, options=opts, feature_bank=bank
    )
    t0 = time.perf_counter()
    scorer.prefetch(configs)
    t_total = time.perf_counter() - t0
    stats = dict(bank.stats)
    m_effs = sorted(scorer.m_eff_log.values())
    resid = [
        e["gram_resid"] for e in bank.entry_log() if e["gram_resid"] is not None
    ]

    # -- bank reuse: a second scorer over the same data rebuilds nothing --
    scorer2 = make_scorer(
        ds.data, spec=spec, config=cfg, options=opts, feature_bank=bank
    )
    t0 = time.perf_counter()
    scorer2.prefetch(configs)
    t_reuse = time.perf_counter() - t0
    rebuilds = bank.stats["builds"] - stats["builds"]

    cell = {
        "backend": backend,
        "params": params,
        "n": n,
        "d": d,
        "kind": kind,
        "n_configs": len(configs),
        "feature_build_s": round(stats["build_s"], 4),
        "frontier_total_s": round(t_total, 4),
        "shared_bank_frontier_s": round(t_reuse, 4),
        "shared_bank_rebuilds": int(rebuilds),
        "m_eff_range": [int(m_effs[0]), int(m_effs[-1])],
        "max_gram_resid": round(float(max(resid)), 6) if resid else None,
        "bank": stats,
    }

    # -- downstream fidelity vs the exact-Gram oracle ---------------------
    if oracle is not None:
        max_abs = max_rel = 0.0
        for i, ps in configs:
            got = scorer._score_cache[config_key(i, ps)]
            want = oracle[(i, ps)]
            max_abs = max(max_abs, abs(got - want))
            max_rel = max(max_rel, abs(got - want) / max(1.0, abs(want)))
        cell["score_dev_vs_exact_abs"] = max_abs
        cell["score_dev_vs_exact_rel"] = max_rel
    return cell


def run(quick: bool = False, out_path: str = OUT_PATH) -> dict:
    from repro.core.api import DataSpec
    from repro.core.score_common import ScoreConfig
    from repro.data.synthetic import generate_scm_data

    # oracle rows keep n small (the exact CV score is O(n^3) per config);
    # the larger n rows measure build scaling only
    grid = (
        [(400, "mixed", True)]
        if quick
        else [
            (400, "continuous", True),
            (400, "mixed", True),
            (1000, "mixed", True),
            (4000, "mixed", False),
        ]
    )
    d, seed = 5, 0
    cells = []
    print("backend,params,n,kind,build_s,reuse_s,rebuilds,score_dev_rel")
    for n, kind, with_oracle in grid:
        ds = generate_scm_data(d=d, n=n, density=0.35, kind=kind, seed=seed)
        spec = DataSpec.from_arrays(ds.data, dims=ds.dims, discrete=ds.discrete)
        cfg = ScoreConfig(seed=seed)
        oracle = (
            _oracle_scores(ds, spec, cfg, _probe_configs(d))
            if with_oracle
            else None
        )
        for backend, params in BACKENDS:
            cell = _bench_cell(backend, params, ds, spec, cfg, d, oracle=oracle)
            cells.append(cell)
            dev = cell.get("score_dev_vs_exact_rel")
            print(
                f"{backend},{params or '-'},{n},{kind},"
                f"{cell['feature_build_s']},{cell['shared_bank_frontier_s']},"
                f"{cell['shared_bank_rebuilds']},"
                + (f"{dev:.2e}" if dev is not None else "-")
            )
            assert cell["shared_bank_rebuilds"] == 0, (
                "shared FeatureBank must avoid every rebuild"
            )
    result = {
        "benchmark": "feature_banks",
        "unit": "seconds / max score deviation vs repro.core.score_exact",
        "engine": "repro.features backend registry + FeaturePolicy routing "
        "+ session-owned FeatureBank (PR 5)",
        "quick": quick,
        "cells": cells,
    }
    result = write_bench(out_path, result)
    print(f"wrote {out_path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    run(quick=args.quick, out_path=args.out)
